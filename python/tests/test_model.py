"""Model zoo: spec consistency, forward shapes, conv correctness of the
im2col formulation against lax.conv, and quantized-path sanity."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile.kernels.qformat import FloatFormat, FixedFormat, format_params
from compile.model import (
    NETWORKS,
    count_params,
    forward,
    init_params,
    max_chain,
    weight_shapes,
    _im2col,
)

ALL_NETS = sorted(NETWORKS)


@pytest.fixture(scope="module")
def tiny_params():
    return {
        name: {k: jnp.asarray(v) for k, v in init_params(NETWORKS[name], 0).items()}
        for name in ALL_NETS
    }


@pytest.mark.parametrize("name", ALL_NETS)
def test_forward_shapes_exact_and_quantized(name, tiny_params):
    spec = NETWORKS[name]
    x = jnp.zeros((2, *spec["input"]), jnp.float32)
    y = forward(spec, tiny_params[name], x)
    assert y.shape == (2, spec["classes"])
    fmt = (format_params(FloatFormat(7, 6)), "float")
    yq = forward(spec, tiny_params[name], x, fmt=fmt)
    assert yq.shape == (2, spec["classes"])
    fmt = (format_params(FixedFormat(6, 6)), "fixed")
    yx = forward(spec, tiny_params[name], x, fmt=fmt)
    assert yx.shape == (2, spec["classes"])


@pytest.mark.parametrize("name", ALL_NETS)
def test_weight_shapes_match_params(name):
    spec = NETWORKS[name]
    params = init_params(spec, 1)
    shapes = dict(weight_shapes(spec))
    assert set(shapes) == set(params)
    for k, s in shapes.items():
        assert params[k].shape == tuple(s), k
    assert count_params(spec) == sum(v.size for v in params.values())


def test_chain_length_ordering_matches_design():
    # DESIGN.md: googlenet > alexnet > vgg > cifarnet > lenet5
    chains = {n: max_chain(NETWORKS[n]) for n in ALL_NETS}
    order = sorted(chains, key=chains.get, reverse=True)
    assert order == ["googlenet-mini", "alexnet-mini", "vgg-mini", "cifarnet", "lenet5"]


def test_exact_quantized_f23e8_close_to_exact_path():
    # per-op rounding at F(23,8) is identity; only summation ORDER
    # differs from jnp.matmul, so logits agree to fp tolerance
    spec = NETWORKS["lenet5"]
    params = {k: jnp.asarray(v) for k, v in init_params(spec, 3).items()}
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, *spec["input"])).astype(np.float32))
    y_exact = np.asarray(forward(spec, params, x))
    y_q = np.asarray(forward(spec, params, x, fmt=(format_params(FloatFormat(23, 8)), "float")))
    np.testing.assert_allclose(y_q, y_exact, rtol=2e-4, atol=2e-5)


def test_im2col_conv_matches_lax_conv():
    """The exact-path conv (im2col + matmul) must equal lax.conv."""
    rng = np.random.default_rng(5)
    x = rng.standard_normal((2, 8, 8, 3)).astype(np.float32)
    w = rng.standard_normal((3, 3, 3, 5)).astype(np.float32)
    patches, (b, oh, ow) = _im2col(jnp.asarray(x), 3, 3, 1, 1)
    y = (patches @ w.reshape(27, 5)).reshape(b, oh, ow, 5)
    ref = jax.lax.conv_general_dilated(
        jnp.asarray(x), jnp.asarray(w),
        window_strides=(1, 1), padding=((1, 1), (1, 1)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_im2col_stride_2():
    rng = np.random.default_rng(6)
    x = rng.standard_normal((1, 9, 9, 2)).astype(np.float32)
    w = rng.standard_normal((3, 3, 2, 4)).astype(np.float32)
    patches, (b, oh, ow) = _im2col(jnp.asarray(x), 3, 3, 2, 0)
    assert (oh, ow) == (4, 4)
    y = (patches @ w.reshape(18, 4)).reshape(b, oh, ow, 4)
    ref = jax.lax.conv_general_dilated(
        jnp.asarray(x), jnp.asarray(w),
        window_strides=(2, 2), padding="VALID",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    np.testing.assert_allclose(np.asarray(y), np.asarray(ref), rtol=1e-5, atol=1e-5)


def test_quantized_narrow_format_changes_logits():
    spec = NETWORKS["cifarnet"]
    params = {k: jnp.asarray(v) for k, v in init_params(spec, 4).items()}
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((2, *spec["input"])).astype(np.float32))
    y_wide = np.asarray(forward(spec, params, x, fmt=(format_params(FloatFormat(16, 8)), "float")))
    y_narrow = np.asarray(forward(spec, params, x, fmt=(format_params(FloatFormat(2, 3)), "float")))
    assert not np.allclose(y_wide, y_narrow)


def test_init_is_deterministic_per_seed():
    spec = NETWORKS["lenet5"]
    a = init_params(spec, 9)
    b = init_params(spec, 9)
    c = init_params(spec, 10)
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    assert any((a[k] != c[k]).any() for k in a if k.endswith(".w"))
