"""Trainer: loss decreases, accuracy metric semantics, determinism."""

import numpy as np

from compile.datagen import digits
from compile.model import NETWORKS
from compile.train import topk_accuracy, train


def test_topk_accuracy_semantics():
    logits = np.array([[0.1, 0.9, 0.0], [0.8, 0.1, 0.1]], np.float32)
    labels = np.array([1, 2], np.int32)
    assert topk_accuracy(logits, labels, 1) == 0.5
    assert topk_accuracy(logits, labels, 3) == 1.0


def test_topk_ties_break_to_lower_index():
    # matches rust/src/eval/metrics.rs: stable argsort of -logits
    logits = np.array([[5.0, 5.0, 5.0, 5.0]], np.float32)
    assert topk_accuracy(logits, np.array([0], np.int32), 1) == 1.0
    assert topk_accuracy(logits, np.array([3], np.int32), 1) == 0.0
    assert topk_accuracy(logits, np.array([1], np.int32), 2) == 1.0


def test_short_training_reduces_loss():
    spec = NETWORKS["lenet5"]
    x, y = digits(512, 16, seed=3)
    _, hist = train(spec, x, y, steps=60, log_every=59, seed=0)
    first = hist[0][1]
    last = hist[-1][1]
    assert last < first * 0.8, f"loss {first} -> {last}"


def test_training_is_deterministic():
    spec = NETWORKS["lenet5"]
    x, y = digits(256, 16, seed=3)
    p1, _ = train(spec, x, y, steps=12, log_every=100, seed=5)
    p2, _ = train(spec, x, y, steps=12, log_every=100, seed=5)
    for k in p1:
        np.testing.assert_array_equal(p1[k], p2[k])
