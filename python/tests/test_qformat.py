"""Quantizer correctness: jnp bit-trick vs the independent frexp oracle,
plus the algebraic invariants every real rounding unit must satisfy."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.qformat import (
    FixedFormat,
    FloatFormat,
    fixed_params,
    float_params,
    format_params,
    quantize,
)
from compile.kernels.ref import ref_quantize

F32_MAX = 3.4028234663852886e38

float_formats = st.builds(
    FloatFormat,
    mantissa=st.integers(min_value=0, max_value=23),
    exponent=st.integers(min_value=2, max_value=8),
)
fixed_formats = st.builds(
    FixedFormat,
    int_bits=st.integers(min_value=0, max_value=16),
    frac_bits=st.integers(min_value=0, max_value=16),
)
finite_f32 = st.floats(
    min_value=np.float32(-1e30),
    max_value=np.float32(1e30),
    allow_nan=False,
    allow_infinity=False,
    width=32,
)


def q(x, fmt):
    kind = "float" if isinstance(fmt, FloatFormat) else "fixed"
    return np.asarray(quantize(jnp.asarray(x, dtype=jnp.float32), format_params(fmt), kind))


def bits(a):
    return np.asarray(a, dtype=np.float32).view(np.uint32)


@settings(max_examples=60, deadline=None)
@given(xs=st.lists(finite_f32, min_size=1, max_size=32), fmt=float_formats)
def test_float_matches_oracle_bitexact(xs, fmt):
    x = np.array(xs, dtype=np.float32)
    got = q(x, fmt)
    want = ref_quantize(x, fmt)
    # -0.0 vs +0.0 both mean "flushed"; compare canonicalized bits
    got, want = got + 0.0, want + 0.0
    np.testing.assert_array_equal(bits(got), bits(want))


@settings(max_examples=60, deadline=None)
@given(xs=st.lists(finite_f32, min_size=1, max_size=32), fmt=fixed_formats)
def test_fixed_matches_oracle(xs, fmt):
    x = np.array(xs, dtype=np.float32)
    np.testing.assert_allclose(q(x, fmt), ref_quantize(x, fmt), rtol=0, atol=0)


@settings(max_examples=40, deadline=None)
@given(xs=st.lists(finite_f32, min_size=1, max_size=16), fmt=float_formats)
def test_float_idempotent(xs, fmt):
    x = np.array(xs, dtype=np.float32)
    once = q(x, fmt)
    np.testing.assert_array_equal(bits(once + 0.0), bits(q(once, fmt) + 0.0))


@settings(max_examples=40, deadline=None)
@given(xs=st.lists(finite_f32, min_size=1, max_size=16), fmt=fixed_formats)
def test_fixed_idempotent(xs, fmt):
    x = np.array(xs, dtype=np.float32)
    once = q(x, fmt)
    np.testing.assert_array_equal(once, q(once, fmt))


@settings(max_examples=40, deadline=None)
@given(x=finite_f32, y=finite_f32, fmt=float_formats)
def test_float_monotone(x, y, fmt):
    lo, hi = sorted([x, y])
    a = q(np.array([lo], np.float32), fmt)[0]
    b = q(np.array([hi], np.float32), fmt)[0]
    assert a <= b


@settings(max_examples=40, deadline=None)
@given(xs=st.lists(finite_f32, min_size=1, max_size=16), fmt=float_formats)
def test_float_odd_symmetry(xs, fmt):
    x = np.array(xs, dtype=np.float32)
    np.testing.assert_array_equal(bits(q(-x, fmt) + 0.0), bits(-q(x, fmt) + 0.0))


@settings(max_examples=40, deadline=None)
@given(xs=st.lists(finite_f32, min_size=1, max_size=16), fmt=float_formats)
def test_float_saturation_bound(xs, fmt):
    x = np.array(xs, dtype=np.float32)
    y = q(x, fmt)
    assert np.all(np.abs(y) <= fmt.max_value)


@settings(max_examples=40, deadline=None)
@given(xs=st.lists(finite_f32, min_size=1, max_size=16), fmt=fixed_formats)
def test_fixed_grid_and_bound(xs, fmt):
    x = np.array(xs, dtype=np.float32)
    y = q(x, fmt).astype(np.float64)
    # the clamp bound lives on the f32 carrier, so compare against the
    # carrier-rounded max (exact only while 1 + l + r <= 25)
    assert np.all(np.abs(y) <= np.float32(fmt.max_value))
    if fmt.total_bits <= 25:
        # every output lies exactly on the 2^-r grid
        k = y * fmt.scale
        np.testing.assert_array_equal(k, np.round(k))


def test_f23e8_is_identity_on_normals():
    rng = np.random.default_rng(1)
    x = (rng.standard_normal(4096).astype(np.float32)
         * np.exp2(rng.integers(-100, 100, 4096)).astype(np.float32))
    fmt = FloatFormat(23, 8)
    np.testing.assert_array_equal(bits(q(x, fmt)), bits(x))


def test_flush_to_zero_below_min_normal():
    fmt = FloatFormat(4, 4)  # emin = -7, min_normal = 2^-7
    x = np.array([2.0**-8, -(2.0**-8), 2.0**-7, 0.0], np.float32)
    y = q(x, fmt)
    np.testing.assert_array_equal(y + 0.0, np.array([0, 0, 2.0**-7, 0], np.float32))


def test_saturate_at_max():
    fmt = FloatFormat(4, 4)  # emax = 8, max = (2 - 2^-4) * 256 = 496
    x = np.array([1e6, -1e6, 496.0], np.float32)
    y = q(x, fmt)
    np.testing.assert_array_equal(y, np.array([496.0, -496.0, 496.0], np.float32))


def test_round_half_even_float():
    # m=2: grid at 1.00, 1.25, 1.50, 1.75, 2.0; ties go to even mantissa
    fmt = FloatFormat(2, 4)
    x = np.array([1.125, 1.375, 1.625, 1.875], np.float32)
    y = q(x, fmt)
    np.testing.assert_array_equal(y, np.array([1.0, 1.5, 1.5, 2.0], np.float32))


def test_round_half_even_fixed():
    fmt = FixedFormat(4, 1)  # step 0.5
    x = np.array([0.25, 0.75, 1.25, 1.75], np.float32)
    y = q(x, fmt)
    np.testing.assert_array_equal(y, np.array([0.0, 1.0, 1.0, 2.0], np.float32))


def test_fixed_16bit_center_radix_max_is_256ish():
    # the paper's §4.3 example: 16 bits, radix point in the center,
    # saturates just above 255
    fmt = FixedFormat(8, 8)
    assert fmt.max_value == pytest.approx(256.0, abs=0.01)
    assert q(np.array([300.0], np.float32), fmt)[0] == np.float32(fmt.max_value)


def test_e8_carrier_clamps():
    fmt = FloatFormat(7, 8)
    assert fmt.max_value <= F32_MAX
    assert fmt.min_normal >= 2.0**-126


def test_param_vectors():
    f = FloatFormat(7, 6)
    p = np.asarray(float_params(f))
    assert p[0] == 16 and p[1] == np.float32(f.min_normal) and p[2] == np.float32(f.max_value)
    g = FixedFormat(4, 4)
    p = np.asarray(fixed_params(g))
    assert p[0] == 16.0 and p[1] == np.float32(1 / 16.0) and p[2] == np.float32(g.max_value)


def test_invalid_formats_rejected():
    with pytest.raises(ValueError):
        FloatFormat(24, 8)
    with pytest.raises(ValueError):
        FloatFormat(5, 0)
    with pytest.raises(ValueError):
        FixedFormat(-1, 3)
    with pytest.raises(ValueError):
        quantize(jnp.zeros(3), jnp.zeros(4), "decimal")
