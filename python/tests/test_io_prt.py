""".prt container: python round-trip (the Rust reader is tested on the
same byte layout in rust/src/tensor/io.rs)."""

import os
import tempfile

import numpy as np
import pytest

from compile.io_prt import read_prt, write_prt


def test_roundtrip_order_and_dtypes():
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "t.prt")
        tensors = [
            ("w", np.arange(24, dtype=np.float32).reshape(2, 3, 4)),
            ("y", np.array([3, -1, 0], dtype=np.int32)),
            ("b", np.zeros((7,), dtype=np.float32)),
        ]
        write_prt(p, tensors)
        back = read_prt(p)
        assert [n for n, _ in back] == ["w", "y", "b"]
        for (n0, a0), (n1, a1) in zip(tensors, back):
            assert a0.dtype == a1.dtype
            np.testing.assert_array_equal(a0, a1)


def test_rejects_unsupported_dtype():
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "t.prt")
        with pytest.raises(TypeError):
            write_prt(p, [("x", np.zeros(3, dtype=np.float64))])


def test_rejects_bad_magic():
    with tempfile.TemporaryDirectory() as d:
        p = os.path.join(d, "bad.prt")
        with open(p, "wb") as f:
            f.write(b"\x00" * 16)
        with pytest.raises(ValueError):
            read_prt(p)
