"""Pallas qmatmul vs the accumulation-order-faithful oracle.

The kernel must be BIT-exact against ref_qmatmul: same per-op rounding,
same serial-K order, independent of the (block_m, block_n) tiling chosen.
One exception is normative: the SIGN OF ZERO is unspecified (XLA's
algebraic simplifier rewrites `0 + x -> x`, which differs from strict
IEEE for x = -0.0), so comparisons canonicalize zeros with `+ 0.0`.
All zeros behave identically in every downstream op we use.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.qformat import FixedFormat, FloatFormat, format_params
from compile.kernels.qmatmul import pick_block, qmatmul, qmatmul_coarse
from compile.kernels.ref import ref_qmatmul, ref_matmul_exact, ref_quantize


def canon(a):
    """Canonicalize -0.0 to +0.0 for bit comparison."""
    return (np.asarray(a, dtype=np.float32) + 0.0).view(np.uint32)


def kind_of(fmt):
    return "float" if isinstance(fmt, FloatFormat) else "fixed"


def run_qmm(a, b, fmt, **kw):
    return np.asarray(
        qmatmul(jnp.asarray(a), jnp.asarray(b), format_params(fmt), kind=kind_of(fmt), **kw)
    )


small_formats = st.sampled_from(
    [
        FloatFormat(7, 6),
        FloatFormat(2, 8),
        FloatFormat(10, 4),
        FloatFormat(23, 8),
        FloatFormat(4, 3),
        FixedFormat(8, 8),
        FixedFormat(2, 6),
        FixedFormat(12, 2),
        FixedFormat(0, 8),
    ]
)


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 10),
    k=st.integers(1, 24),
    n=st.integers(1, 10),
    fmt=small_formats,
    seed=st.integers(0, 2**31 - 1),
)
def test_qmatmul_matches_oracle(m, k, n, fmt, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    got = run_qmm(a, b, fmt)
    want = ref_qmatmul(a, b, fmt)
    np.testing.assert_array_equal(canon(got), canon(want))


@pytest.mark.parametrize("bm,bn", [(1, 1), (2, 4), (4, 2), (8, 8), (128, 128)])
def test_tiling_invariance(bm, bn):
    """The output must not depend on the BlockSpec tiling."""
    rng = np.random.default_rng(7)
    a = rng.standard_normal((8, 16)).astype(np.float32)
    b = rng.standard_normal((16, 8)).astype(np.float32)
    fmt = FloatFormat(7, 6)
    want = ref_qmatmul(a, b, fmt)
    got = run_qmm(a, b, fmt, block_m=bm, block_n=bn)
    np.testing.assert_array_equal(canon(got), canon(want))


def test_exact_format_equals_serial_f32():
    rng = np.random.default_rng(3)
    a = rng.standard_normal((6, 40)).astype(np.float32)
    b = rng.standard_normal((40, 5)).astype(np.float32)
    got = run_qmm(a, b, FloatFormat(23, 8))
    want = ref_matmul_exact(a, b)
    np.testing.assert_array_equal(canon(got), canon(want))


def test_saturation_visible_in_long_accumulation():
    """Paper §4.3: with a narrow fixed format the running sum saturates;
    the final dot product must equal the saturated bound, not the true sum."""
    fmt = FixedFormat(4, 4)  # max 16 - 1/16
    k = 64
    a = np.ones((1, k), np.float32)
    b = np.ones((k, 1), np.float32)
    got = run_qmm(a, b, fmt)[0, 0]
    assert got == np.float32(fmt.max_value)  # saturated, not 64


def test_coarse_ablation_differs_from_per_op():
    """qmatmul_coarse (wide-accumulator ablation) must be the quantized
    exact product — strictly more accurate than the per-op chain when the
    chain saturates."""
    fmt = FixedFormat(4, 4)
    k = 64
    rng = np.random.default_rng(11)
    a = np.abs(rng.standard_normal((2, k))).astype(np.float32)
    b = np.abs(rng.standard_normal((k, 2))).astype(np.float32)
    coarse = np.asarray(
        qmatmul_coarse(jnp.asarray(a), jnp.asarray(b), format_params(fmt), kind="fixed")
    )
    want = ref_quantize(np.matmul(a, b), fmt)
    np.testing.assert_array_equal(coarse, want)


def test_pick_block():
    assert pick_block(128, 128) == 128
    assert pick_block(96, 128) == 96
    assert pick_block(10, 4) == 2
    assert pick_block(7, 4) == 1
    assert pick_block(12, 8) == 6


def test_shape_mismatch_raises():
    with pytest.raises(ValueError):
        qmatmul(
            jnp.zeros((2, 3)), jnp.zeros((4, 2)),
            format_params(FloatFormat(7, 6)), kind="float",
        )
