"""Synthetic dataset generators: determinism, seed semantics, shapes."""

import numpy as np
import pytest

from compile.datagen import digits, make_dataset, synclass


def test_synclass_shapes_and_labels():
    x, y = synclass(64, (12, 12, 3), 10, proto_seed=1, sample_seed=2)
    assert x.shape == (64, 12, 12, 3)
    assert x.dtype == np.float32
    assert y.shape == (64,) and y.dtype == np.int32
    assert y.min() >= 0 and y.max() < 10


def test_synclass_split_semantics():
    # same task (proto_seed), different draws (sample_seed)
    x1, y1 = synclass(32, (8, 8, 3), 5, proto_seed=7, sample_seed=1)
    x2, y2 = synclass(32, (8, 8, 3), 5, proto_seed=7, sample_seed=2)
    x3, _ = synclass(32, (8, 8, 3), 5, proto_seed=8, sample_seed=1)
    assert not np.array_equal(x1, x2)  # different samples
    assert not np.array_equal(x1, x3)  # different task
    # determinism
    x1b, y1b = synclass(32, (8, 8, 3), 5, proto_seed=7, sample_seed=1)
    np.testing.assert_array_equal(x1, x1b)
    np.testing.assert_array_equal(y1, y1b)


def test_synclass_classes_are_distinguishable():
    # nearest-prototype classification on clean prototypes must beat chance
    x, y = synclass(128, (12, 12, 3), 4, proto_seed=3, sample_seed=4, noise=0.3)
    protos = np.stack([x[y == c].mean(axis=0) for c in range(4)])
    pred = np.array([
        np.argmin([np.linalg.norm(s - p) for p in protos]) for s in x
    ])
    assert (pred == y).mean() > 0.5


def test_digits_shapes_and_determinism():
    x, y = digits(48, 16, seed=5)
    assert x.shape == (48, 16, 16, 1)
    assert y.min() >= 0 and y.max() <= 9
    x2, y2 = digits(48, 16, seed=5)
    np.testing.assert_array_equal(x, x2)
    np.testing.assert_array_equal(y, y2)


def test_digits_have_ink():
    x, y = digits(16, 16, seed=1, noise=0.0)
    for img in x:
        assert img.max() > 0.5  # a glyph was stamped


def test_make_dataset_dispatch():
    x, y = make_dataset("digits", 8, [16, 16, 1], 10, task_seed=0, split_seed=1)
    assert x.shape == (8, 16, 16, 1)
    x, y = make_dataset("synclass", 8, [10, 10, 3], 7, task_seed=0, split_seed=1)
    assert x.shape == (8, 10, 10, 3)
    with pytest.raises(ValueError):
        make_dataset("imagenet", 8, [224, 224, 3], 1000, task_seed=0, split_seed=1)
