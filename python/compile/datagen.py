"""Deterministic synthetic datasets (build-time only).

Substitutes for the paper's MNIST / CIFAR-10 / ImageNet validation sets
(not available offline; see DESIGN.md §1).  Two families:

* `synclass`  — smooth random class prototypes + per-sample interference,
  noise and random circular shifts.  Difficulty is controlled by the
  noise level and prototype smoothness; the resulting tasks train to
  ~90-97% accuracy, leaving enough headroom for precision-induced
  degradation to be measurable (the paper's accuracy cliffs).
* `digits`    — rasterized 5x7-font digits with random placement, scale
  jitter and noise; the MNIST stand-in for lenet5.

Everything is seeded and pure-numpy: the same seeds reproduce the same
bytes in `artifacts/*.eval.prt` on every run.
"""

from __future__ import annotations

import numpy as np

__all__ = ["synclass", "digits", "make_dataset"]


def _smooth(img: np.ndarray, iters: int = 3) -> np.ndarray:
    """Separable 3-tap box blur (axis 1 and 2), applied `iters` times."""
    out = img
    for _ in range(iters):
        for ax in (1, 2):
            out = (np.roll(out, 1, axis=ax) + out + np.roll(out, -1, axis=ax)) / 3.0
    return out


def synclass(
    n: int,
    shape: tuple[int, int, int],
    classes: int,
    proto_seed: int,
    sample_seed: int,
    noise: float = 0.9,
    shift: int = 2,
    similarity: float = 0.85,
):
    """Cluster-classification images: y = class of the dominant prototype.

    `proto_seed` fixes the class prototypes (the *task*); `sample_seed`
    draws the samples — train and eval splits share the proto_seed and
    differ only in sample_seed, exactly like a held-out validation set.

    `similarity` mixes a shared base field into every prototype so the
    class-discriminative signal is only the (1 - similarity) component —
    this is what keeps trained accuracy off the ceiling (the paper's
    networks sit at 75-90%, leaving room for precision-induced cliffs).
    """
    h, w, c = shape
    prng = np.random.default_rng(proto_seed)
    base = _smooth(prng.standard_normal((1, h, w, c)))
    delta = _smooth(prng.standard_normal((classes, h, w, c)))
    protos = np.sqrt(similarity) * base + np.sqrt(1.0 - similarity) * delta
    protos /= protos.std(axis=(1, 2, 3), keepdims=True) + 1e-9

    rng = np.random.default_rng(sample_seed)
    labels = rng.integers(0, classes, size=n)
    # per-sample interference from a second (wrong) prototype keeps the
    # task from being linearly separable at high SNR
    other = (labels + 1 + rng.integers(0, classes - 1, size=n)) % classes
    alpha = rng.uniform(0.15, 0.4, size=(n, 1, 1, 1)).astype(np.float64)
    x = protos[labels] * (1.0 - alpha) + protos[other] * alpha
    x = x + rng.standard_normal((n, h, w, c)) * noise
    if shift > 0:
        sh = rng.integers(-shift, shift + 1, size=(n, 2))
        for i in range(n):
            x[i] = np.roll(x[i], (sh[i, 0], sh[i, 1]), axis=(0, 1))
    x = x.astype(np.float32)
    return x, labels.astype(np.int32)


# 5x7 bitmap font for digits 0-9 (rows top->bottom, 1 = ink)
_FONT = {
    0: ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["01110", "10001", "00001", "00110", "01000", "10000", "11111"],
    3: ["01110", "10001", "00001", "00110", "00001", "10001", "01110"],
    4: ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    5: ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    6: ["01110", "10000", "11110", "10001", "10001", "10001", "01110"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    9: ["01110", "10001", "10001", "01111", "00001", "00001", "01110"],
}


def _glyph(d: int) -> np.ndarray:
    return np.array([[int(ch) for ch in row] for row in _FONT[d]], dtype=np.float32)


def digits(n: int, size: int, seed: int, noise: float = 0.1):
    """MNIST stand-in: noisy rasterized digits on a `size` x `size` canvas."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, 10, size=n).astype(np.int32)
    x = np.zeros((n, size, size, 1), dtype=np.float32)
    for i in range(n):
        g = _glyph(int(labels[i]))
        # nearest-neighbour upscale by 1x or 2x
        s = int(rng.integers(1, 3)) if size >= 15 else 1
        g = np.kron(g, np.ones((s, s), dtype=np.float32))
        gh, gw = g.shape
        oy = int(rng.integers(0, size - gh + 1))
        ox = int(rng.integers(0, size - gw + 1))
        x[i, oy : oy + gh, ox : ox + gw, 0] = g * float(rng.uniform(0.7, 1.3))
    x += rng.standard_normal(x.shape).astype(np.float32) * noise
    return x.astype(np.float32), labels


def make_dataset(kind: str, n: int, shape, classes: int, *, task_seed: int, split_seed: int):
    """task_seed pins the task (prototypes / font); split_seed picks the
    sample draw — train/eval share task_seed, differ in split_seed."""
    if kind == "digits":
        assert shape[2] == 1
        return digits(n, shape[0], split_seed)
    if kind == "synclass":
        return synclass(n, tuple(shape), classes, task_seed, split_seed)
    raise ValueError(f"unknown dataset kind {kind!r}")
