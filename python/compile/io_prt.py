"""Writer for the `.prt` tensor container (read by rust/src/tensor/io.rs).

Layout (all little-endian):
    u32 magic = 0x50525431 ("PRT1")
    u32 tensor_count
    per tensor:
        u16 name_len, name bytes (utf-8)
        u8  dtype   (0 = f32, 1 = i32)
        u8  ndim
        u32 dims[ndim]
        raw data, row-major
"""

from __future__ import annotations

import struct

import numpy as np

MAGIC = 0x50525431

_DTYPES = {np.dtype(np.float32): 0, np.dtype(np.int32): 1}


def write_prt(path: str, tensors: list[tuple[str, np.ndarray]]) -> None:
    with open(path, "wb") as f:
        f.write(struct.pack("<II", MAGIC, len(tensors)))
        for name, arr in tensors:
            arr = np.ascontiguousarray(arr)
            if arr.dtype not in _DTYPES:
                raise TypeError(f"{name}: unsupported dtype {arr.dtype}")
            nb = name.encode("utf-8")
            f.write(struct.pack("<H", len(nb)))
            f.write(nb)
            f.write(struct.pack("<BB", _DTYPES[arr.dtype], arr.ndim))
            f.write(struct.pack(f"<{arr.ndim}I", *arr.shape))
            f.write(arr.tobytes(order="C"))


def read_prt(path: str) -> list[tuple[str, np.ndarray]]:
    """Reader (tests + round-trip verification only; Rust owns the runtime)."""
    out = []
    with open(path, "rb") as f:
        magic, count = struct.unpack("<II", f.read(8))
        if magic != MAGIC:
            raise ValueError(f"{path}: bad magic {magic:#x}")
        for _ in range(count):
            (nlen,) = struct.unpack("<H", f.read(2))
            name = f.read(nlen).decode("utf-8")
            dt, ndim = struct.unpack("<BB", f.read(2))
            dims = struct.unpack(f"<{ndim}I", f.read(4 * ndim))
            dtype = np.float32 if dt == 0 else np.int32
            n = int(np.prod(dims)) if ndim else 1
            data = np.frombuffer(f.read(4 * n), dtype=dtype).reshape(dims)
            out.append((name, data.copy()))
    return out
