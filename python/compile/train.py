"""Build-time training of the model zoo (exact f32 forward).

The paper evaluates *pre-trained* networks (Caffe model zoo); the training
loop here produces our equivalent pre-trained weights on the synthetic
datasets.  Plain SGD + momentum with cosine decay and cross-entropy loss;
deliberately dependency-free (no optax in the image).
"""

from __future__ import annotations

import time

import numpy as np

import jax
import jax.numpy as jnp

from .model import forward, init_params

__all__ = ["train", "evaluate", "topk_accuracy"]


def _cross_entropy(logits, labels):
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


def topk_accuracy(logits: np.ndarray, labels: np.ndarray, k: int) -> float:
    """Top-k accuracy with deterministic tie handling (argsort is stable,
    we take the k largest by value, ties broken toward lower index —
    matches rust/src/eval/metrics.rs)."""
    idx = np.argsort(-logits, axis=-1, kind="stable")[:, :k]
    return float(np.mean(np.any(idx == labels[:, None], axis=-1)))


def evaluate(spec, params, x, y, k: int, batch: int = 64) -> float:
    outs = []
    for i in range(0, len(x), batch):
        outs.append(np.asarray(forward(spec, params, jnp.asarray(x[i : i + batch]))))
    return topk_accuracy(np.concatenate(outs), y, k)


def train(
    spec,
    x_train: np.ndarray,
    y_train: np.ndarray,
    *,
    steps: int = 600,
    batch: int = 64,
    lr: float = 2e-3,
    beta1: float = 0.9,
    beta2: float = 0.999,
    eps: float = 1e-8,
    weight_decay: float = 1e-4,
    seed: int = 0,
    log_every: int = 100,
):
    """Adam + cosine decay.  Returns (params, history); history is a list
    of (step, loss) pairs recorded every `log_every` steps."""
    params = {k: jnp.asarray(v) for k, v in init_params(spec, seed).items()}
    m0 = {k: jnp.zeros_like(v) for k, v in params.items()}
    v0 = {k: jnp.zeros_like(v) for k, v in params.items()}

    def loss_fn(p, xb, yb):
        logits = forward(spec, p, xb)
        return _cross_entropy(logits, yb)

    @jax.jit
    def step_fn(p, m, v, xb, yb, stepk):
        loss, grads = jax.value_and_grad(loss_fn)(p, xb, yb)
        cur_lr = lr * 0.5 * (1.0 + jnp.cos(jnp.pi * stepk / steps))
        t = stepk + 1.0
        new_p, new_m, new_v = {}, {}, {}
        for k in p:
            g = grads[k] + weight_decay * p[k]
            new_m[k] = beta1 * m[k] + (1 - beta1) * g
            new_v[k] = beta2 * v[k] + (1 - beta2) * g * g
            mhat = new_m[k] / (1 - beta1**t)
            vhat = new_v[k] / (1 - beta2**t)
            new_p[k] = p[k] - cur_lr * mhat / (jnp.sqrt(vhat) + eps)
        return new_p, new_m, new_v, loss

    rng = np.random.default_rng(seed + 1)
    history = []
    t0 = time.time()
    for s in range(steps):
        idx = rng.integers(0, len(x_train), size=batch)
        params, m0, v0, loss = step_fn(
            params, m0, v0, jnp.asarray(x_train[idx]), jnp.asarray(y_train[idx]),
            jnp.float32(s),
        )
        if s % log_every == 0 or s == steps - 1:
            history.append((s, float(loss)))
            print(f"    step {s:4d}  loss {float(loss):.4f}  ({time.time()-t0:.1f}s)")
    return {k: np.asarray(v) for k, v in params.items()}, history
