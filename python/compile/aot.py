"""AOT builder: train the zoo, export weights/eval sets, lower HLO text.

This is the single build-time entry point (`make artifacts`).  Python
never runs again after it: the Rust coordinator loads
`artifacts/<net>_<kind>.hlo.txt` via PJRT and the `.prt` containers
natively.

Interchange is HLO **text**, not a serialized HloModuleProto: jax >= 0.5
emits 64-bit instruction ids that the xla_extension 0.5.1 proto parser
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Artifact signature, per (network, kind):
    inputs : x f32[B, H, W, C], fmt f32[4], then the weights in
             meta.json["networks"][net]["weights"] order
    output : 1-tuple of logits f32[B, classes]   (return_tuple=True)
"""

from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .datagen import make_dataset
from .io_prt import write_prt
from .model import NETWORKS, count_params, forward, max_chain, weight_shapes
from .train import evaluate, train, topk_accuracy

BATCH = 32  # static batch baked into the HLO artifacts
N_TRAIN = 4096
N_EVAL = 512
KINDS = ("float", "fixed")

TRAIN_STEPS = {
    "lenet5": 400,
    "cifarnet": 500,
    "alexnet-mini": 600,
    "vgg-mini": 600,
    "googlenet-mini": 600,
}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_network(spec, kind: str, batch: int) -> str:
    """Lower the quantized forward pass to HLO text (one artifact serves
    the entire design space of this representation kind — the format is
    the runtime fmt[4] parameter)."""
    wshapes = weight_shapes(spec)

    def fn(x, fmtp, *ws):
        params = {name: w for (name, _), w in zip(wshapes, ws)}
        return (forward(spec, params, x, fmt=(fmtp, kind)),)

    args = [
        jax.ShapeDtypeStruct((batch, *spec["input"]), jnp.float32),
        jax.ShapeDtypeStruct((4,), jnp.float32),
    ] + [jax.ShapeDtypeStruct(shape, jnp.float32) for _, shape in wshapes]
    lowered = jax.jit(fn).lower(*args)
    return to_hlo_text(lowered)


def build_network(name: str, out_dir: str, steps: int | None, seed: int) -> dict:
    spec = NETWORKS[name]
    print(f"== {name}: params={count_params(spec)} max_chain={max_chain(spec)}")

    dskind = spec["dataset"]
    shape = spec["input"]
    classes = spec["classes"]
    x_train, y_train = make_dataset(
        dskind, N_TRAIN, shape, classes, task_seed=seed, split_seed=seed + 1
    )
    x_eval, y_eval = make_dataset(
        dskind, N_EVAL, shape, classes, task_seed=seed, split_seed=seed + 2
    )

    n_steps = steps or TRAIN_STEPS[name]
    t0 = time.time()
    params, history = train(spec, x_train, y_train, steps=n_steps, seed=seed)
    train_time = time.time() - t0

    k = spec["topk"]
    acc_train = evaluate(spec, params, x_train[:1024], y_train[:1024], k)
    acc_eval = evaluate(spec, params, x_eval, y_eval, k)
    print(f"   trained {n_steps} steps in {train_time:.0f}s; "
          f"top-{k} train={acc_train:.3f} eval={acc_eval:.3f}")

    wshapes = weight_shapes(spec)
    write_prt(
        os.path.join(out_dir, f"{name}.weights.prt"),
        [(n, params[n]) for n, _ in wshapes],
    )
    write_prt(
        os.path.join(out_dir, f"{name}.eval.prt"),
        [("x", x_eval), ("y", y_eval)],
    )

    hlo_files = {}
    for kind in KINDS:
        t0 = time.time()
        text = lower_network(spec, kind, BATCH)
        fname = f"{name}_{kind}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        hlo_files[kind] = fname
        print(f"   lowered {kind}: {len(text)/1e6:.2f} MB in {time.time()-t0:.0f}s")

    return {
        "input": shape,
        "classes": classes,
        "topk": k,
        "dataset": dskind,
        "layers": spec["layers"],
        "weights": [n for n, _ in wshapes],
        "weight_shapes": {n: list(s) for n, s in wshapes},
        "params": count_params(spec),
        "max_chain": max_chain(spec),
        "hlo": hlo_files,
        "weights_file": f"{name}.weights.prt",
        "eval_file": f"{name}.eval.prt",
        "train_steps": n_steps,
        "train_history": history,
        "train_acc": acc_train,
        "eval_acc_exact": acc_eval,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument(
        "--out-dir",
        default=os.path.join(os.path.dirname(__file__), "..", "..", "artifacts"),
    )
    ap.add_argument("--nets", nargs="*", default=list(NETWORKS))
    ap.add_argument("--steps", type=int, default=None, help="override train steps (all nets)")
    ap.add_argument("--seed", type=int, default=2018)
    args = ap.parse_args()

    out_dir = os.path.abspath(args.out_dir)
    os.makedirs(out_dir, exist_ok=True)

    meta = {"batch": BATCH, "n_eval": N_EVAL, "seed": args.seed, "networks": {}}
    for i, name in enumerate(args.nets):
        meta["networks"][name] = build_network(name, out_dir, args.steps, args.seed + 100 * i)

    with open(os.path.join(out_dir, "meta.json"), "w") as f:
        json.dump(meta, f, indent=1)
    # build stamp for the Makefile
    with open(os.path.join(out_dir, ".stamp"), "w") as f:
        f.write(str(time.time()))
    print(f"wrote {out_dir}/meta.json")


if __name__ == "__main__":
    main()
