"""L1 Pallas kernel: customized-precision matmul with per-MAC-step rounding.

This is the paper's compute hot-spot: every multiply and every add of the
MAC chain is immediately re-quantized to the customized format ("we ...
truncate the mantissa and exponent to the desired format after each
arithmetic operation", §3.1).  The K dimension of the GEMM is therefore a
*serial* dependence chain; M and N remain data-parallel.

TPU mapping of the paper's insight (see DESIGN.md §Hardware-Adaptation):
the grid tiles M×N for VMEM residency via BlockSpec (each program owns a
(block_m, block_n) output tile plus the (block_m, K) / (K, block_n) operand
panels); the accumulator tile lives in registers/VMEM across the whole
fori_loop — the quantize epilogue is fused into the loop body, so no value
ever round-trips to HBM between MAC steps.  The rank-1-update formulation
keeps every step a dense (block_m, block_n) VPU op.

`interpret=True` always: the CPU PJRT client cannot execute Mosaic
custom-calls; interpret mode lowers the kernel to plain HLO, which is what
`aot.py` ships to the Rust runtime.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from .qformat import quantize

__all__ = ["qmatmul", "qmatmul_coarse", "pick_block"]


def pick_block(dim: int, preferred: int) -> int:
    """Largest divisor of `dim` that is <= preferred (VMEM-friendly tiles
    without padding logic; model dims are chosen MXU-aligned upstream)."""
    b = min(dim, preferred)
    while dim % b != 0:
        b -= 1
    return b


def _qmm_kernel(fmt_ref, a_ref, b_ref, o_ref, *, kind: str, k_dim: int):
    """One (block_m, block_n) output tile: serial quantized MAC chain over K."""
    fmt = fmt_ref[...]
    a = a_ref[...]  # (bm, K)
    b = b_ref[...]  # (K, bn)
    bm, _ = a.shape
    _, bn = b.shape

    def body(k, acc):
        col = lax.dynamic_slice(a, (0, k), (bm, 1))  # (bm, 1)
        row = lax.dynamic_slice(b, (k, 0), (1, bn))  # (1, bn)
        prod = quantize(col * row, fmt, kind)  # q after the multiply
        return quantize(acc + prod, fmt, kind)  # q after the add

    acc0 = jnp.zeros((bm, bn), dtype=jnp.float32)
    o_ref[...] = lax.fori_loop(0, k_dim, body, acc0)


@functools.partial(jax.jit, static_argnames=("kind", "block_m", "block_n"))
def qmatmul(a, b, fmt, *, kind: str, block_m: int = 128, block_n: int = 128):
    """Quantized matmul  c = qmac(a @ b)  with per-op rounding.

    a: (M, K) f32, b: (K, N) f32, fmt: (4,) f32 runtime format descriptor
    (see qformat module docstring).  `kind` is static ("float"/"fixed").
    Inputs are assumed already quantized by the caller (layer code
    quantizes weights and activations before the GEMM, as the simulated
    hardware stores them in the custom format).
    """
    m, k = a.shape
    k2, n = b.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {a.shape} @ {b.shape}")
    bm = pick_block(m, block_m)
    bn = pick_block(n, block_n)
    grid = (m // bm, n // bn)
    kernel = functools.partial(_qmm_kernel, kind=kind, k_dim=k)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((4,), lambda i, j: (0,)),  # fmt: broadcast
            pl.BlockSpec((bm, k), lambda i, j: (i, 0)),  # A panel
            pl.BlockSpec((k, bn), lambda i, j: (0, j)),  # B panel
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(fmt, a, b)


@functools.partial(jax.jit, static_argnames=("kind",))
def qmatmul_coarse(a, b, fmt, *, kind: str):
    """Ablation variant: exact f32 accumulation, ONE quantization of the
    final dot product (what an accelerator with a wide internal
    accumulator would do).  Used by the ablation benches to measure how
    much of the paper's accuracy cliff comes from per-step rounding."""
    c = jnp.matmul(a, b, preferred_element_type=jnp.float32)
    return quantize(c, fmt, kind)
