"""Independent pure-numpy / pure-jnp oracles for the quantizers and the
per-op-truncated matmul.

`ref_quantize_float` is deliberately implemented via `np.frexp` floating
point arithmetic (NOT bit manipulation) so that it constitutes an
*independent* derivation of the same semantics as qformat.quantize_float;
pytest cross-checks them bit-exactly.  `ref_qmatmul` is the slow, obviously
correct accumulation-order-faithful matmul the Pallas kernel must match.
"""

from __future__ import annotations

import numpy as np

from .qformat import FixedFormat, FloatFormat

__all__ = [
    "ref_quantize_float",
    "ref_quantize_fixed",
    "ref_quantize",
    "ref_qmatmul",
    "ref_matmul_exact",
]


def ref_quantize_float(x, fmt: FloatFormat):
    """Oracle float quantizer: frexp-based snap-to-grid with RNE.

    For each element: decompose |x| = f * 2^ex (f in [0.5, 1)), so the
    normalized exponent is ex - 1; the representable grid around x has
    step 2^(exp - m).  x/step = 1.mantissa * 2^m <= 2^24 is exactly
    representable in f64, so np.round (half-to-even) on it implements RNE
    exactly.  Overflow saturates, underflow flushes — same as qformat.
    """
    x = np.asarray(x, dtype=np.float32)
    out = np.zeros_like(x)
    flat = x.ravel()
    res = out.ravel()
    for i, v in enumerate(flat):
        if v == 0.0 or np.isnan(v):
            res[i] = v
            continue
        a = abs(float(v))
        _, ex = np.frexp(a)
        exp = int(ex) - 1  # a = 1.mant * 2^exp
        step = 2.0 ** (exp - fmt.mantissa)
        q = np.round(a / step) * step  # RNE; exact in f64
        if q > fmt.max_value:
            q = fmt.max_value
        if q < fmt.min_normal:
            q = 0.0
        res[i] = np.float32(np.copysign(q, v))
    return out


def ref_quantize_fixed(x, fmt: FixedFormat):
    """Oracle fixed quantizer: f64 snap-to-grid with RNE + symmetric clamp."""
    x = np.asarray(x, dtype=np.float32).astype(np.float64)
    y = np.clip(x, -fmt.max_value, fmt.max_value)
    y = np.round(y * fmt.scale) / fmt.scale
    y = np.clip(y, -fmt.max_value, fmt.max_value)
    return y.astype(np.float32)


def ref_quantize(x, fmt):
    if isinstance(fmt, FloatFormat):
        return ref_quantize_float(x, fmt)
    if isinstance(fmt, FixedFormat):
        return ref_quantize_fixed(x, fmt)
    raise TypeError(f"unsupported format: {fmt!r}")


def ref_qmatmul(a, b, fmt):
    """Accumulation-order-faithful quantized matmul oracle.

    c[i, j] = q(... q(q(c_0 + q(a[i,0]*b[0,j])) + q(a[i,1]*b[1,j])) ...)
    — quantize after every multiply and after every add, accumulating in
    increasing k order, exactly the MAC-chain semantics of §2 and of the
    Pallas kernel.  Inputs are NOT pre-quantized here; callers quantize
    weights/activations first (as the layers do).
    """
    a = np.asarray(a, dtype=np.float32)
    b = np.asarray(b, dtype=np.float32)
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    acc = np.zeros((m, n), dtype=np.float32)
    for kk in range(k):
        prod = ref_quantize(np.outer(a[:, kk], b[kk, :]).astype(np.float32), fmt)
        acc = ref_quantize((acc + prod).astype(np.float32), fmt)
    return acc


def ref_matmul_exact(a, b):
    """Serial-K f32 matmul (the exact-baseline semantics: F(23,8) per-op
    quantization is the identity, so the chain is plain f32 accumulation
    in increasing k order)."""
    a = np.asarray(a, dtype=np.float32)
    b = np.asarray(b, dtype=np.float32)
    m, k = a.shape
    _, n = b.shape
    acc = np.zeros((m, n), dtype=np.float32)
    for kk in range(k):
        acc = (acc + np.outer(a[:, kk], b[kk, :]).astype(np.float32)).astype(
            np.float32
        )
    return acc
