"""Customized-precision quantizers (the paper's §2.2 number formats).

These are the *normative* semantics of the whole repository — the Pallas
kernel (qmatmul.py), the pure-jnp oracle (ref.py) and the Rust softfloat
(rust/src/numerics/) all implement exactly this behaviour and are
cross-checked bit-exactly against each other:

* custom float  F(m, e, bias):  sign + m-bit mantissa (hidden leading 1)
  + e-bit exponent (unsigned, offset by `bias`).  Round-to-nearest-even
  at m mantissa bits; exponent overflow SATURATES to +/- max-finite;
  exponent underflow FLUSHES TO ZERO (no subnormals).  F(23, 8, 127) is
  IEEE-754 single precision minus the inf/NaN encodings and is used as
  the exact baseline.
* custom fixed  X(l, r):  sign + l integer bits + r fractional bits
  (sign-magnitude, symmetric saturation).  Round-to-nearest-even at step
  2^-r, saturate to +/- (2^l - 2^-r).

Like the paper (which modified Caffe but "continue[d] to store values as
C floats"), we *simulate* the formats on f32 carriers: a quantizer maps
f32 -> f32 values representable in the custom format.  The simulation is
exact while the format's values are exactly representable in f32
(m <= 23, l + r <= 24 for round-trip-exact fixed point); wider formats
degrade gracefully exactly as the paper's float-carrier simulation did.

Runtime parameterization: one HLO artifact per (network, representation
kind) serves the *entire* design space — the format is a length-4 f32
vector parameter `fmt`:

  kind == "float": fmt = [shift, min_normal, max_val, 0]
      shift       = 23 - m          (bits of f32 mantissa to drop)
      min_normal  = 2^emin          (emin = -bias)
      max_val     = 2^emax * (2 - 2^-m)   (emax = 2^e - 1 - bias)
  kind == "fixed": fmt = [scale, inv_scale, max_val, 0]
      scale = 2^r, inv_scale = 2^-r, max_val = 2^l - 2^-r

The representation *kind* is static (staged into the HLO); everything
else is a runtime scalar, so the Rust coordinator sweeps hundreds of
configurations without recompiling anything.
"""

from __future__ import annotations

import dataclasses

import numpy as np

import jax.numpy as jnp
from jax import lax

__all__ = [
    "FloatFormat",
    "FixedFormat",
    "quantize",
    "quantize_float",
    "quantize_fixed",
    "float_params",
    "fixed_params",
    "format_params",
]

# numpy scalars (not jnp arrays): they stage as literals, so quantize_*
# remains usable inside Pallas kernels (which forbid captured jax consts).
_SIGN_MASK = np.uint32(0x8000_0000)
_MAG_MASK = np.uint32(0x7FFF_FFFF)
_ONE = np.uint32(1)
_ONE_F32_BITS = np.uint32(0x3F80_0000)


@dataclasses.dataclass(frozen=True)
class FloatFormat:
    """Custom floating-point format descriptor F(m, e, bias)."""

    mantissa: int
    exponent: int
    bias: int | None = None  # default: 2^(e-1) - 1

    def __post_init__(self):
        if not (0 <= self.mantissa <= 23):
            raise ValueError(f"mantissa bits must be in [0, 23], got {self.mantissa}")
        if not (1 <= self.exponent <= 8):
            raise ValueError(f"exponent bits must be in [1, 8], got {self.exponent}")

    @property
    def effective_bias(self) -> int:
        return (1 << (self.exponent - 1)) - 1 if self.bias is None else self.bias

    @property
    def emin(self) -> int:
        return -self.effective_bias

    @property
    def emax(self) -> int:
        return (1 << self.exponent) - 1 - self.effective_bias

    @property
    def total_bits(self) -> int:
        return 1 + self.mantissa + self.exponent

    @property
    def min_normal(self) -> float:
        # f32-carrier clamp: below 2^-126 the carrier is subnormal and the
        # mantissa bit-trick rounds at the wrong granularity, so the
        # simulated format's normal range is clipped to the carrier's.
        # (Semantically irrelevant for DNN activations; documented in
        # DESIGN.md §2 and mirrored by the Rust softfloat.)
        return 2.0 ** max(self.emin, -126)

    @property
    def max_value(self) -> float:
        # f32-carrier clamp on the other end: emax = 128 (e = 8, all
        # exponent codes usable) exceeds the carrier's largest finite
        # exponent, so saturate at f32::MAX instead.
        return min((2.0 - 2.0**-self.mantissa) * 2.0**self.emax, 3.4028234663852886e38)

    def name(self) -> str:
        return f"float_m{self.mantissa}e{self.exponent}"


@dataclasses.dataclass(frozen=True)
class FixedFormat:
    """Custom fixed-point format descriptor X(l, r): sign + l int + r frac bits."""

    int_bits: int
    frac_bits: int

    def __post_init__(self):
        if self.int_bits < 0 or self.frac_bits < 0:
            raise ValueError("int/frac bits must be non-negative")

    @property
    def total_bits(self) -> int:
        return 1 + self.int_bits + self.frac_bits

    @property
    def scale(self) -> float:
        return 2.0**self.frac_bits

    @property
    def max_value(self) -> float:
        return 2.0**self.int_bits - 2.0**-self.frac_bits

    def name(self) -> str:
        return f"fixed_l{self.int_bits}r{self.frac_bits}"


def float_params(fmt: FloatFormat) -> jnp.ndarray:
    """Runtime fmt vector for a float format (see module docstring)."""
    return jnp.array(
        [23 - fmt.mantissa, fmt.min_normal, fmt.max_value, 0.0], dtype=jnp.float32
    )


def fixed_params(fmt: FixedFormat) -> jnp.ndarray:
    """Runtime fmt vector for a fixed format (see module docstring)."""
    return jnp.array(
        [fmt.scale, 1.0 / fmt.scale, fmt.max_value, 0.0], dtype=jnp.float32
    )


def format_params(fmt) -> jnp.ndarray:
    if isinstance(fmt, FloatFormat):
        return float_params(fmt)
    if isinstance(fmt, FixedFormat):
        return fixed_params(fmt)
    raise TypeError(f"unsupported format: {fmt!r}")


def quantize_float(x: jnp.ndarray, fmt: jnp.ndarray) -> jnp.ndarray:
    """Quantize f32 values to the custom float format described by `fmt`.

    Exact bit manipulation on the f32 carrier: round-to-nearest-even of
    the mantissa by integer arithmetic on the raw bits (the carry from a
    mantissa all-ones round-up propagates into the exponent field, which
    is precisely the semantics of normalized rounding), then saturate /
    flush against the format's max / min-normal.
    """
    shift = fmt[0].astype(jnp.uint32)
    min_normal = fmt[1]
    max_val = fmt[2]

    bits = lax.bitcast_convert_type(x, jnp.uint32)
    sign = bits & _SIGN_MASK
    mag = bits & _MAG_MASK

    # round-half-to-even at bit `shift` of the mantissa:
    #   half = 2^(shift-1) - 1 + lsb   (lsb = bit `shift`, the tie-breaker)
    # `shift == 0` (m == 23) is the identity; both where-branches are
    # evaluated, and XLA defines out-of-range shifts to produce 0, so the
    # dead branch is harmless.
    lsb = (mag >> shift) & _ONE
    half = (_ONE << (shift - _ONE)) - _ONE + lsb
    rounded = ((mag + half) >> shift) << shift
    rmag = jnp.where(shift == 0, mag, rounded)

    y = lax.bitcast_convert_type(rmag, jnp.float32)  # |rounded x|
    y = jnp.where(y > max_val, max_val, y)  # exponent overflow: saturate
    y = jnp.where(y < min_normal, 0.0, y)  # underflow: flush to zero
    signf = lax.bitcast_convert_type(sign | _ONE_F32_BITS, jnp.float32)  # +/-1.0
    return y * signf


def quantize_fixed(x: jnp.ndarray, fmt: jnp.ndarray) -> jnp.ndarray:
    """Quantize f32 values to the custom fixed format described by `fmt`.

    Pre-clamps to the representable range (so the scaled value stays in
    f32's exactly-rounding integer range whenever l + r <= 24), rounds
    half-to-even at step 2^-r, and saturates symmetrically.
    """
    scale = fmt[0]
    inv_scale = fmt[1]
    max_val = fmt[2]
    y = jnp.clip(x, -max_val, max_val)
    y = jnp.round(y * scale) * inv_scale  # jnp.round is round-half-even
    return jnp.clip(y, -max_val, max_val)


def quantize(x: jnp.ndarray, fmt: jnp.ndarray, kind: str) -> jnp.ndarray:
    """Dispatch on the *static* representation kind ("float" | "fixed")."""
    if kind == "float":
        return quantize_float(x, fmt)
    if kind == "fixed":
        return quantize_fixed(x, fmt)
    raise ValueError(f"unknown representation kind: {kind!r}")
