"""L2: the DNN model zoo — spec-driven forward passes calling the L1 kernel.

Five networks mirror the paper's suite (GoogLeNet, VGG, AlexNet, CIFARNET,
LeNet-5) as architecture-faithful scaled-down versions sized for the
single-core CPU testbed (DESIGN.md §1).  What is preserved is what drives
the paper's findings: the *ordering of accumulation-chain lengths* (max
dot-product K per network: googlenet-mini 1000 > alexnet-mini 600 >
vgg-mini 432 > cifarnet 400 > lenet5 256), inception structure for
googlenet-mini, uniformly small 3x3 kernels for vgg-mini, and large
first-layer kernels + deep dense stack for alexnet-mini.

A network is a JSON-able layer list (`spec["layers"]`).  The same spec is
exported to artifacts/meta.json and interpreted by the Rust-native engine
(rust/src/nn/), which must match this forward pass BIT-exactly in
quantized mode.  Normative layout decisions (mirrored in Rust):

* activations are NHWC, f32; flatten is row-major (H, W, C);
* im2col patch index = ((ki*kw + kj)*C + c)  (kernel-position major);
* conv/dense weights: w[kh, kw, cin, cout] reshaped to (kh*kw*cin, cout),
  dense w[in, out]; bias per output channel;
* quantized forward: q(input); per conv/dense: q(w), q(b), per-op-rounded
  MAC chain (L1 kernel), then q(acc + b); relu/maxpool are exact
  (selection never creates unrepresentable values); zero padding; global
  avgpool accumulates serially with per-add rounding then multiplies by
  q(1/HW) with a final rounding.

`forward(..., fmt=None)` is the exact f32 path used for training;
`fmt=(params, kind)` is the customized-precision path that gets AOT-lowered.
"""

from __future__ import annotations

import numpy as np

import jax
import jax.numpy as jnp
from jax import lax

from .kernels.qformat import quantize
from .kernels.qmatmul import qmatmul

__all__ = ["NETWORKS", "init_params", "forward", "weight_names", "count_params", "max_chain"]


def conv(name, kh, kw, in_ch, out_ch, stride=1, pad=None):
    if pad is None:
        pad = (kh - 1) // 2  # 'same' for odd kernels, stride 1
    return {
        "op": "conv", "name": name, "kh": kh, "kw": kw,
        "in_ch": in_ch, "out_ch": out_ch, "stride": stride, "pad": pad,
    }


def dense(name, in_dim, out_dim):
    return {"op": "dense", "name": name, "in_dim": in_dim, "out_dim": out_dim}


def inception(name, in_ch, c1, c3, c5, cp):
    """Mini inception module: 1x1, 3x3, 5x5 and maxpool(3x3,s1,p1)+1x1
    branches, channel-concatenated (in that order)."""
    return {
        "op": "inception", "name": name, "in_ch": in_ch,
        "c1": c1, "c3": c3, "c5": c5, "cp": cp,
    }


RELU = {"op": "relu"}
FLAT = {"op": "flatten"}


def maxpool(k=2, stride=2, pad=0):
    return {"op": "maxpool", "k": k, "stride": stride, "pad": pad}


GAVG = {"op": "gavgpool"}


NETWORKS = {
    # ---- the two "small prior-work" networks -------------------------
    "lenet5": {
        "input": [16, 16, 1], "classes": 10, "topk": 1, "dataset": "digits",
        "layers": [
            conv("conv1", 5, 5, 1, 6), RELU, maxpool(),
            conv("conv2", 5, 5, 6, 16), RELU, maxpool(),
            FLAT,
            dense("fc1", 256, 120), RELU,
            dense("fc2", 120, 84), RELU,
            dense("fc3", 84, 10),
        ],
    },
    "cifarnet": {
        "input": [16, 16, 3], "classes": 10, "topk": 1, "dataset": "synclass",
        "layers": [
            conv("conv1", 5, 5, 3, 16), RELU, maxpool(),
            conv("conv2", 5, 5, 16, 24), RELU, maxpool(),
            conv("conv3", 3, 3, 24, 32), RELU, maxpool(),
            FLAT,
            dense("fc1", 128, 64), RELU,
            dense("fc2", 64, 10),
        ],
    },
    # ---- the three "production-grade" networks -----------------------
    "alexnet-mini": {
        "input": [20, 20, 3], "classes": 20, "topk": 5, "dataset": "synclass",
        "layers": [
            conv("conv1", 7, 7, 3, 24), RELU, maxpool(),
            conv("conv2", 5, 5, 24, 32), RELU, maxpool(),
            conv("conv3", 3, 3, 32, 48), RELU,
            conv("conv4", 3, 3, 48, 32), RELU, maxpool(),
            FLAT,
            dense("fc1", 128, 128), RELU,
            dense("fc2", 128, 64), RELU,
            dense("fc3", 64, 20),
        ],
    },
    "vgg-mini": {
        "input": [20, 20, 3], "classes": 20, "topk": 5, "dataset": "synclass",
        "layers": [
            conv("conv1a", 3, 3, 3, 16), RELU,
            conv("conv1b", 3, 3, 16, 16), RELU, maxpool(),
            conv("conv2a", 3, 3, 16, 32), RELU,
            conv("conv2b", 3, 3, 32, 32), RELU, maxpool(),
            conv("conv3a", 3, 3, 32, 48), RELU,
            conv("conv3b", 3, 3, 48, 48), RELU, maxpool(),
            FLAT,
            dense("fc1", 192, 128), RELU,
            dense("fc2", 128, 20),
        ],
    },
    "googlenet-mini": {
        "input": [20, 20, 3], "classes": 20, "topk": 5, "dataset": "synclass",
        "layers": [
            conv("conv1", 5, 5, 3, 16), RELU, maxpool(),
            inception("inc1", 16, 8, 16, 8, 8), RELU, maxpool(),
            inception("inc2", 40, 12, 24, 12, 12), RELU,
            GAVG,
            dense("fc", 60, 20),
        ],
    },
}


# ----------------------------------------------------------------------
# parameters


def _conv_weights(layer):
    yield layer["name"] + ".w", (layer["kh"], layer["kw"], layer["in_ch"], layer["out_ch"])
    yield layer["name"] + ".b", (layer["out_ch"],)


def _inception_convs(layer):
    """The four branch convolutions of an inception module, as conv specs."""
    n, ic = layer["name"], layer["in_ch"]
    return [
        conv(n + ".1x1", 1, 1, ic, layer["c1"]),
        conv(n + ".3x3", 3, 3, ic, layer["c3"]),
        conv(n + ".5x5", 5, 5, ic, layer["c5"]),
        conv(n + ".proj", 1, 1, ic, layer["cp"]),
    ]


def weight_shapes(spec):
    """Ordered (name, shape) pairs — the order of HLO parameters."""
    out = []
    for layer in spec["layers"]:
        if layer["op"] == "conv":
            out.extend(_conv_weights(layer))
        elif layer["op"] == "dense":
            out.append((layer["name"] + ".w", (layer["in_dim"], layer["out_dim"])))
            out.append((layer["name"] + ".b", (layer["out_dim"],)))
        elif layer["op"] == "inception":
            for c in _inception_convs(layer):
                out.extend(_conv_weights(c))
    return out


def weight_names(spec):
    return [n for n, _ in weight_shapes(spec)]


def count_params(spec):
    return sum(int(np.prod(s)) for _, s in weight_shapes(spec))


def max_chain(spec):
    """Longest MAC accumulation chain (the driver of precision demand)."""
    best = 0
    for layer in spec["layers"]:
        if layer["op"] == "conv":
            best = max(best, layer["kh"] * layer["kw"] * layer["in_ch"])
        elif layer["op"] == "dense":
            best = max(best, layer["in_dim"])
        elif layer["op"] == "inception":
            for c in _inception_convs(layer):
                best = max(best, c["kh"] * c["kw"] * c["in_ch"])
    return best


def init_params(spec, seed: int) -> dict[str, np.ndarray]:
    """He-normal init, deterministic per (network, seed)."""
    rng = np.random.default_rng(seed)
    params = {}
    for name, shape in weight_shapes(spec):
        if name.endswith(".b"):
            params[name] = np.zeros(shape, dtype=np.float32)
        else:
            fan_in = int(np.prod(shape[:-1]))
            std = float(np.sqrt(2.0 / fan_in))
            params[name] = (rng.standard_normal(shape) * std).astype(np.float32)
    return params


# ----------------------------------------------------------------------
# forward pass


def _im2col(x, kh, kw, stride, pad):
    """NHWC -> (B*oh*ow, kh*kw*C) patches; index ((ki*kw+kj)*C + c)."""
    b, h, w, c = x.shape
    if pad > 0:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
    oh = (h + 2 * pad - kh) // stride + 1
    ow = (w + 2 * pad - kw) // stride + 1
    cols = []
    for i in range(kh):
        for j in range(kw):
            cols.append(
                x[:, i : i + (oh - 1) * stride + 1 : stride,
                  j : j + (ow - 1) * stride + 1 : stride, :]
            )
    p = jnp.stack(cols, axis=3)  # (B, oh, ow, kh*kw, C)
    return p.reshape(b * oh * ow, kh * kw * c), (b, oh, ow)


def _matmul(a, w, fmt):
    """Dispatch: exact f32 GEMM for training, L1 quantized kernel otherwise."""
    if fmt is None:
        return jnp.matmul(a, w, preferred_element_type=jnp.float32)
    params, kind = fmt
    return qmatmul(a, w, params, kind=kind)


def _q(x, fmt):
    if fmt is None:
        return x
    params, kind = fmt
    return quantize(x, params, kind)


def _conv_apply(x, layer, params, fmt):
    w = params[layer["name"] + ".w"]
    bia = params[layer["name"] + ".b"]
    patches, (b, oh, ow) = _im2col(x, layer["kh"], layer["kw"], layer["stride"], layer["pad"])
    w2 = jnp.reshape(w, (layer["kh"] * layer["kw"] * layer["in_ch"], layer["out_ch"]))
    y = _matmul(patches, _q(w2, fmt), fmt)
    y = _q(y + _q(bia, fmt), fmt)
    return y.reshape(b, oh, ow, layer["out_ch"])


def _maxpool(x, k, stride, pad):
    b, h, w, c = x.shape
    if pad > 0:
        x = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))  # zero pad
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w + 2 * pad - k) // stride + 1
    best = None
    for i in range(k):
        for j in range(k):
            v = x[:, i : i + (oh - 1) * stride + 1 : stride,
                  j : j + (ow - 1) * stride + 1 : stride, :]
            best = v if best is None else jnp.maximum(best, v)
    return best


def _gavgpool(x, fmt):
    b, h, w, c = x.shape
    flat = x.reshape(b, h * w, c)
    if fmt is None:
        return jnp.mean(flat, axis=1)
    # serial adder chain with per-add rounding, then one rounded multiply
    def body(i, acc):
        return _q(acc + lax.dynamic_slice(flat, (0, i, 0), (b, 1, c))[:, 0, :], fmt)

    acc = lax.fori_loop(0, h * w, body, jnp.zeros((b, c), jnp.float32))
    inv = _q(jnp.float32(1.0 / (h * w)), fmt)
    return _q(acc * inv, fmt)


def forward(spec, params, x, fmt=None):
    """Run the network; returns logits (B, classes).

    fmt: None for the exact f32 path, or (format_params, kind) for the
    customized-precision path (this is what aot.py lowers).
    """
    x = _q(x, fmt)
    for layer in spec["layers"]:
        op = layer["op"]
        if op == "conv":
            x = _conv_apply(x, layer, params, fmt)
        elif op == "dense":
            w = _q(params[layer["name"] + ".w"], fmt)
            bia = _q(params[layer["name"] + ".b"], fmt)
            x = _q(_matmul(x, w, fmt) + bia, fmt)
        elif op == "relu":
            x = jnp.maximum(x, 0.0)
        elif op == "maxpool":
            x = _maxpool(x, layer["k"], layer["stride"], layer["pad"])
        elif op == "flatten":
            x = x.reshape(x.shape[0], -1)
        elif op == "gavgpool":
            x = _gavgpool(x, fmt)
        elif op == "inception":
            branches = []
            for c in _inception_convs(layer):
                if c["name"].endswith(".proj"):
                    pooled = _maxpool(x, 3, 1, 1)
                    branches.append(_conv_apply(pooled, c, params, fmt))
                else:
                    branches.append(_conv_apply(x, c, params, fmt))
            x = jnp.concatenate(branches, axis=-1)
        else:
            raise ValueError(f"unknown layer op {op!r}")
    return x
