#!/usr/bin/env python3
"""Generate the cross-language quantization golden vectors.

Runs the NORMATIVE quantizers (python/compile/kernels/qformat.py, the
same jnp code the Pallas kernel and the AOT HLO artifacts stage) over a
curated set of edge-case and random inputs for ~a dozen float and fixed
formats, and writes the resulting (input bits, output bits) pairs to

    rust/tests/golden/quant_golden.json

which is CHECKED IN.  The tier-1 test rust/tests/golden_quant.rs then
asserts `precis::numerics` reproduces every vector bit-exactly on every
fresh clone — no artifacts, no Python, no JAX needed at test time.  The
pjrt_cross_check integration test proves the same contract end-to-end
through whole networks, but only when artifacts and a PJRT runtime
exist; this file is the always-on conformance anchor.

Edge cases covered per format: signed zero, subnormal flush (just below
min_normal, and f32-carrier subnormals), saturation (just above
max_value, huge values, infinities), exact round-half-to-even ties on
both sides of the even/odd grid step, plus seeded random values across
the dynamic range.

Regenerate with:  python3 python/gen_golden_vectors.py
(The output is deterministic; regeneration must be a no-op unless
qformat.py's semantics changed — which is exactly what the Rust test
would then catch.)
"""

from __future__ import annotations

import json
import os
import sys

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from python.compile.kernels.qformat import (  # noqa: E402
    FixedFormat,
    FloatFormat,
    fixed_params,
    float_params,
    format_params,
    quantize,
)

OUT_PATH = os.path.join(
    os.path.dirname(__file__), "..", "rust", "tests", "golden", "quant_golden.json"
)

# ~a dozen formats spanning the design space: the exact baseline, the
# paper's headline pick F(7,6), extremes of each knob, and centered /
# skewed fixed points (including l=0, which saturates at < 1).
FLOAT_FORMATS = [
    FloatFormat(23, 8),  # exact baseline (identity + carrier clamps)
    FloatFormat(7, 6),   # paper's 14-bit pick
    FloatFormat(4, 4),
    FloatFormat(10, 3),
    FloatFormat(2, 8),
    FloatFormat(1, 2),
    FloatFormat(0, 5),   # hidden-one only: pure powers of two
]
FIXED_FORMATS = [
    FixedFormat(8, 8),   # paper §4.3 16-bit centered
    FixedFormat(4, 4),
    FixedFormat(0, 2),   # saturates below 1.0
    FixedFormat(2, 12),
    FixedFormat(12, 2),
    FixedFormat(1, 3),
]

# Split-precision (w, a) pairs (ISSUE 9): a weight staged on the
# weight-half grid entering an activation-half MAC chain composes the
# two quantizers, q = q_a(q_w(x)).  Pair cases are APPENDED under
# separate JSON keys with a SECONDARY seeded rng so the single-format
# `cases` above stay byte-identical across regeneration.
PAIR_FORMATS = [
    (FloatFormat(4, 5), FixedFormat(4, 8)),   # the plan-syntax example pair
    (FixedFormat(8, 8), FloatFormat(4, 5)),   # mixed kinds, other direction
    (FloatFormat(7, 6), FixedFormat(4, 4)),   # headline float into fixed
    (FixedFormat(2, 12), FloatFormat(2, 8)),
    (FloatFormat(23, 8), FixedFormat(0, 2)),  # exact weights, saturating acts
    (FloatFormat(10, 3), FloatFormat(1, 2)),  # float/float split
    (FixedFormat(12, 2), FixedFormat(1, 3)),  # fixed/fixed split
]


def _kind(fmt) -> str:
    return "float" if isinstance(fmt, FloatFormat) else "fixed"


def _name(fmt) -> str:
    if isinstance(fmt, FloatFormat):
        return f"float:m{fmt.mantissa}e{fmt.exponent}"
    return f"fixed:l{fmt.int_bits}r{fmt.frac_bits}"


def f32(x) -> np.float32:
    return np.float32(x)


def bits(x: np.float32) -> int:
    return int(np.asarray(x, dtype=np.float32).view(np.uint32))


def float_inputs(fmt: FloatFormat, rng: np.random.Generator) -> list[np.float32]:
    xs: list[np.float32] = []
    mn = f32(fmt.min_normal)
    mx = f32(fmt.max_value)
    xs += [f32(0.0), f32(-0.0), f32(1.0), f32(-1.0), f32(2.0 / 3.0), f32(-np.pi)]
    # flush-to-zero: just below min normal (both signs), and an
    # f32-carrier subnormal
    xs += [np.nextafter(mn, f32(0.0)), -np.nextafter(mn, f32(0.0)), f32(1e-40), f32(-1e-40)]
    # the min normal itself must survive
    xs += [mn, -mn]
    # saturation: just above max, far above max, infinities
    xs += [np.nextafter(mx, f32(np.inf)), f32(-1e38), f32(np.inf), f32(-np.inf)]
    if fmt.max_value < 1e38:
        xs += [f32(fmt.max_value * 1.5), f32(-fmt.max_value * 1.5)]
    xs += [mx, -mx]
    # exact round-half-to-even ties at m bits: 1 + (2k+1)/2^(m+1) sits
    # exactly between grid steps k and k+1 (representable: m+1 <= 23)
    if fmt.mantissa < 23:
        for k in (0, 1, 2, 5):
            tie = f32(1.0 + (2 * k + 1) / 2.0 ** (fmt.mantissa + 1))
            xs += [tie, -tie, f32(4.0) * tie]
    # random values across the dynamic range
    for _ in range(10):
        mag = rng.uniform(0.0, 1.0) * 2.0 ** rng.integers(-30, 31)
        xs.append(f32(mag if rng.uniform() < 0.5 else -mag))
    return xs


def fixed_inputs(fmt: FixedFormat, rng: np.random.Generator) -> list[np.float32]:
    xs: list[np.float32] = []
    step = 2.0 ** -fmt.frac_bits
    mx = f32(fmt.max_value)
    xs += [f32(0.0), f32(-0.0), f32(1.0), f32(-1.0), f32(2.0 / 3.0), f32(-np.pi)]
    # carrier subnormal rounds to zero
    xs += [f32(1e-40), f32(-1e-40)]
    # saturation both ways, including far overflow and infinities
    xs += [mx, -mx, f32(fmt.max_value + 1.0), f32(-fmt.max_value - 1.0), f32(1e30), f32(np.inf)]
    # exact ties at half a grid step: (2k+1) * step/2 (representable
    # whenever the scaled value fits f32's exact-integer range)
    for k in (0, 1, 2, 5):
        tie = f32((2 * k + 1) * step / 2.0)
        xs += [tie, -tie]
    # random values, mostly in range with some overflow
    for _ in range(10):
        v = rng.uniform(-2.0, 2.0) * max(fmt.max_value, step)
        xs.append(f32(v))
    return xs


def main() -> None:
    rng = np.random.default_rng(2018)
    cases = []
    for fmt in FLOAT_FORMATS:
        params = float_params(fmt)
        name = f"float:m{fmt.mantissa}e{fmt.exponent}"
        for x in float_inputs(fmt, rng):
            y = np.asarray(quantize(x, params, "float"), dtype=np.float32)
            cases.append({"fmt": name, "x": f"{bits(x):08x}", "q": f"{bits(y):08x}"})
    for fmt in FIXED_FORMATS:
        params = fixed_params(fmt)
        name = f"fixed:l{fmt.int_bits}r{fmt.frac_bits}"
        for x in fixed_inputs(fmt, rng):
            y = np.asarray(quantize(x, params, "fixed"), dtype=np.float32)
            cases.append({"fmt": name, "x": f"{bits(x):08x}", "q": f"{bits(y):08x}"})

    # split-precision pairs: q = q_a(q_w(x)), with the intermediate
    # weight-grid value recorded so both hops are pinned independently.
    # A fresh rng keeps the single-format cases above byte-identical.
    prng = np.random.default_rng(20181)
    pair_cases = []
    for w, a in PAIR_FORMATS:
        name = f"w:{_name(w)}+a:{_name(a)}"
        wp, ap = format_params(w), format_params(a)
        ins = (
            float_inputs(w, prng)
            if isinstance(w, FloatFormat)
            else fixed_inputs(w, prng)
        )
        for x in ins:
            qw = np.asarray(quantize(x, wp, _kind(w)), dtype=np.float32)
            q = np.asarray(quantize(qw, ap, _kind(a)), dtype=np.float32)
            pair_cases.append(
                {
                    "fmt": name,
                    "x": f"{bits(x):08x}",
                    "qw": f"{bits(qw):08x}",
                    "q": f"{bits(q):08x}",
                }
            )

    out = {
        "_generator": "python/gen_golden_vectors.py (normative: qformat.py)",
        "_seed": 2018,
        "formats": sorted({c["fmt"] for c in cases}),
        "cases": cases,
        "pair_formats": sorted({c["fmt"] for c in pair_cases}),
        "pair_cases": pair_cases,
    }
    os.makedirs(os.path.dirname(OUT_PATH), exist_ok=True)
    with open(OUT_PATH, "w") as fh:
        json.dump(out, fh, indent=1)
        fh.write("\n")
    print(
        f"wrote {len(cases)} cases for {len(out['formats'])} formats "
        f"+ {len(pair_cases)} pair cases for {len(out['pair_formats'])} pairs "
        f"-> {OUT_PATH}"
    )


if __name__ == "__main__":
    main()
