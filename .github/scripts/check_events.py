#!/usr/bin/env python3
"""Validate a precis `--events-out` JSON-lines event log.

The CI smoke lanes run the serving example with `--events-out
events.jsonl` and gate on this script: every line must be a valid JSON
object with the envelope fields (`seq`, `t_s`, `kind`), sequence
numbers must be UNIQUE (not monotonic — the sink is a lock-free MPSC
queue, so concurrent emitters can drain out of seq order, and a
dropped event consumes its seq), timestamps must be finite and
non-negative, and the session lifecycle must balance: every
`session_open` is matched by exactly one `session_close` once the
gateway has shut down.

Exit codes: 0 valid, 1 invalid, 2 usage/IO error.

Usage: check_events.py events.jsonl [--min-events 1]
"""

import argparse
import json
import math
import sys

# the event vocabulary of precis::obs::Event::kind()
KINDS = {
    "session_open",
    "session_close",
    "store_evict",
    "store_reject",
    "shed",
    "slo_state",
    "alert",
}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("log")
    ap.add_argument(
        "--min-events",
        type=int,
        default=1,
        help="fail when the log carries fewer events than this — an empty "
        "log from a lane that definitely opened sessions means the sink "
        "was never wired (default 1)",
    )
    args = ap.parse_args()

    try:
        with open(args.log, "r", encoding="utf-8") as fh:
            lines = [ln for ln in fh.read().splitlines() if ln.strip()]
    except OSError as e:
        print(f"ERROR: cannot read {args.log}: {e}", file=sys.stderr)
        return 2

    errors = []
    seqs = set()
    kinds = {}
    for i, line in enumerate(lines, 1):
        try:
            ev = json.loads(line)
        except json.JSONDecodeError as e:
            errors.append(f"line {i}: not valid JSON: {e}")
            continue
        if not isinstance(ev, dict):
            errors.append(f"line {i}: not a JSON object")
            continue
        seq = ev.get("seq")
        if isinstance(seq, bool) or not isinstance(seq, (int, float)):
            errors.append(f"line {i}: 'seq' missing or not a number")
        elif seq in seqs:
            errors.append(f"line {i}: duplicate seq {seq}")
        else:
            seqs.add(seq)
        t = ev.get("t_s")
        if (
            isinstance(t, bool)
            or not isinstance(t, (int, float))
            or not math.isfinite(float(t))
            or float(t) < 0.0
        ):
            errors.append(f"line {i}: 't_s' missing or not a finite non-negative number")
        kind = ev.get("kind")
        if kind not in KINDS:
            errors.append(f"line {i}: unknown kind {kind!r}")
        else:
            kinds[kind] = kinds.get(kind, 0) + 1

    opens = kinds.get("session_open", 0)
    closes = kinds.get("session_close", 0)
    if opens != closes:
        errors.append(
            f"unbalanced session lifecycle: {opens} session_open vs "
            f"{closes} session_close (gateway shutdown must close every session)"
        )
    if len(lines) < args.min_events:
        errors.append(
            f"only {len(lines)} events (< --min-events {args.min_events}) — "
            f"was the sink wired?"
        )

    by_kind = ", ".join(f"{k}={v}" for k, v in sorted(kinds.items())) or "none"
    print(f"{args.log}: {len(lines)} events ({by_kind})")
    if errors:
        for e in errors:
            print(f"INVALID: {e}", file=sys.stderr)
        return 1
    print("event log valid: JSON lines well-formed, seqs unique, open/close balanced")
    return 0


if __name__ == "__main__":
    sys.exit(main())
