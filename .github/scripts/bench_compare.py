#!/usr/bin/env python3
"""Diff two BENCH_*.json files with a noise-tolerant threshold.

The perf-regression half of the repo's bench pipeline (DESIGN.md
§Perf): `repro bench --json BENCH_<tag>.json` (or `make bench-json`)
emits a machine-readable report of the headless hot-path suite; this
script compares a current report against a checked-in baseline.

Structure is validated STRICTLY (schema tag, field types, finite
non-negative timings) and any violation exits 2 regardless of flags —
a malformed report must never pass as "no regressions".  Timing
comparison is noise-tolerant: only median slowdowns beyond --threshold
count as regressions, and --warn-only downgrades even those to warnings
(the bring-up mode the CI perf-smoke lane starts in, since shared
runners are noisy).

Section drift is tolerated by name, not by schema: benchmarks present
on only one side are warnings/notes (e.g. the PR-5 weight-store
`forward_cached/*` / `pack/*` sections, the PR-6 `forward_packed/*`
lanes, the PR-8 lock-free/SIMD sections behind the
`warm_lockfree_over_locked`, `gemm_simd_over_scalar/<fmt>`, and
`packed_int_simd_over_scalar/<lane>` ratios, the PR-9
split-precision section — `forward_split/<w>+<a>` /
`forward_act_uniform/*` results with the
`split_over_activation_uniform/<pair>` ratios — and the PR-10
observability section — `obs_overhead/*` results pricing the
metrics/profiling hot paths with the `obs_profile_overhead/tiny-conv`
ratio — are all absent from the PR-4 baseline; that must not fail the
lane).  The one structural condition
on the PAIR of reports is a non-empty overlap: two reports sharing NO
benchmark names cannot be meaningfully compared and exit 2.

Opt-in tracks layer semantic checks over the ratio families.
`--track packed_gap` compares how much of the hardware model's
predicted speedup the packed kernels actually realize — per format,
realization = `packed_forward_over_f32/<fmt>` /
`hw_speedup_predicted/<fmt>` — between the two reports.  A format
whose realization falls more than --threshold below the baseline's
counts as a regression (downgraded by --warn-only like any other), and
a measured ratio without its prediction (or vice versa) is a warning.

Exit codes: 0 ok / warnings only, 1 regressions (without --warn-only),
2 structural error.

A LOW overlap (names mostly differing, but not disjoint) is still a
comparison — but one where most of the suite escaped the regression
check.  `--min-overlap` (a fraction of the smaller report's names,
default 0.5) prints a prominent warning when the shared slice is that
thin, so a wholesale section rename cannot silently pass as "compared
fine" (`test_bench_compare.py` pins all of these behaviours).

Usage:
  bench_compare.py BASELINE.json CURRENT.json [--threshold 0.25]
                   [--warn-only] [--min-seconds 1e-6] [--min-overlap 0.5]
                   [--track packed_gap]
"""

import argparse
import json
import math
import sys

SCHEMA = "precis-bench/1"

RESULT_FIELDS = {
    "name": str,
    "median_s": (int, float),
    "p10_s": (int, float),
    "p90_s": (int, float),
    "iters_per_batch": (int, float),
    "batches": (int, float),
}


class StructureError(Exception):
    pass


def load_report(path):
    """Load and strictly validate one BENCH report; raise StructureError."""
    try:
        with open(path, "r", encoding="utf-8") as fh:
            doc = json.load(fh)
    except (OSError, json.JSONDecodeError) as e:
        raise StructureError(f"{path}: cannot load: {e}") from e
    if not isinstance(doc, dict):
        raise StructureError(f"{path}: top level is not an object")
    if doc.get("schema") != SCHEMA:
        raise StructureError(
            f"{path}: schema {doc.get('schema')!r} is not {SCHEMA!r}"
        )
    for key in ("tag", "preset"):
        if not isinstance(doc.get(key), str):
            raise StructureError(f"{path}: {key!r} missing or not a string")
    results = doc.get("results")
    if not isinstance(results, list) or not results:
        raise StructureError(f"{path}: 'results' missing, not a list, or empty")
    seen = set()
    for i, r in enumerate(results):
        if not isinstance(r, dict):
            raise StructureError(f"{path}: results[{i}] is not an object")
        for field, ty in RESULT_FIELDS.items():
            if not isinstance(r.get(field), ty) or isinstance(r.get(field), bool):
                raise StructureError(
                    f"{path}: results[{i}].{field} missing or mistyped"
                )
        for field in ("median_s", "p10_s", "p90_s"):
            v = float(r[field])
            if not math.isfinite(v) or v < 0.0:
                raise StructureError(
                    f"{path}: results[{i}] ({r['name']!r}): {field} = {v}"
                )
        if r["name"] in seen:
            raise StructureError(f"{path}: duplicate result name {r['name']!r}")
        seen.add(r["name"])
    ratios = doc.get("ratios")
    if not isinstance(ratios, dict):
        raise StructureError(f"{path}: 'ratios' missing or not an object")
    for k, v in ratios.items():
        if isinstance(v, bool) or not isinstance(v, (int, float)) or not math.isfinite(float(v)):
            raise StructureError(f"{path}: ratio {k!r} = {v!r} is not a finite number")
    return doc


def packed_gap(ratios):
    """Per-format speedup realization (measured packed / hw-model predicted).

    Returns ({fmt: realization}, [fmt with only one side of the pair]).
    """
    measured, predicted = {}, {}
    for name, v in ratios.items():
        if name.startswith("packed_forward_over_f32/"):
            measured[name.split("/", 1)[1]] = float(v)
        elif name.startswith("hw_speedup_predicted/"):
            predicted[name.split("/", 1)[1]] = float(v)
    gaps = {
        fmt: measured[fmt] / predicted[fmt]
        for fmt in measured
        if fmt in predicted and predicted[fmt] > 0.0
    }
    return gaps, sorted(set(measured) ^ set(predicted))


def human(seconds):
    if seconds < 1e-6:
        return f"{seconds * 1e9:.1f}ns"
    if seconds < 1e-3:
        return f"{seconds * 1e6:.1f}us"
    if seconds < 1.0:
        return f"{seconds * 1e3:.2f}ms"
    return f"{seconds:.3f}s"


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("baseline")
    ap.add_argument("current")
    ap.add_argument(
        "--threshold",
        type=float,
        default=0.25,
        help="relative median slowdown tolerated before a benchmark "
        "counts as regressed (default 0.25 = 25%%)",
    )
    ap.add_argument(
        "--warn-only",
        action="store_true",
        help="report regressions but exit 0 (structural errors still exit 2)",
    )
    ap.add_argument(
        "--min-seconds",
        type=float,
        default=1e-6,
        help="ignore benchmarks whose baseline median is below this "
        "(sub-microsecond timings are all noise on shared runners)",
    )
    ap.add_argument(
        "--track",
        action="append",
        default=[],
        choices=["packed_gap"],
        help="opt-in semantic checks over the ratio families: 'packed_gap' "
        "regresses when a format's measured/predicted packed-speedup "
        "realization drops more than --threshold below the baseline's",
    )
    ap.add_argument(
        "--min-overlap",
        type=float,
        default=0.5,
        help="warn when the fraction of benchmark names shared by the two "
        "reports (relative to the smaller one) falls below this — low "
        "overlap usually means a wholesale section rename left only a "
        "sliver being compared, which would mask regressions as 'drift' "
        "(default 0.5)",
    )
    args = ap.parse_args()

    try:
        base = load_report(args.baseline)
        cur = load_report(args.current)
    except StructureError as e:
        print(f"STRUCTURE ERROR: {e}", file=sys.stderr)
        return 2

    base_by_name = {r["name"]: r for r in base["results"]}
    cur_by_name = {r["name"]: r for r in cur["results"]}

    print(
        f"baseline {args.baseline} (tag={base['tag']}, preset={base['preset']}) "
        f"vs current {args.current} (tag={cur['tag']}, preset={cur['preset']})"
    )
    if base["preset"] != cur["preset"]:
        print(
            f"warning: comparing different presets "
            f"({base['preset']} vs {cur['preset']}) — overlap only"
        )

    regressions, improvements, skipped = [], [], []
    common = [n for n in base_by_name if n in cur_by_name]
    if not common:
        print(
            "STRUCTURE ERROR: the reports share no benchmark names — "
            "nothing to compare (wrong baseline file?)",
            file=sys.stderr,
        )
        return 2
    smaller = min(len(base_by_name), len(cur_by_name))
    overlap = len(common) / smaller
    if overlap < args.min_overlap:
        print(
            f"warning: only {overlap:.0%} of benchmark names overlap "
            f"({len(common)}/{smaller} of the smaller report, "
            f"--min-overlap {args.min_overlap:.0%}) — name-level drift this "
            f"wide usually means a section rename, and every renamed "
            f"benchmark silently escapes regression checking"
        )
    print(f"\n{'benchmark':<46} {'baseline':>10} {'current':>10} {'delta':>8}")
    for name in common:
        b, c = float(base_by_name[name]["median_s"]), float(cur_by_name[name]["median_s"])
        if b < args.min_seconds:
            skipped.append(name)
            continue
        delta = (c - b) / b
        marker = ""
        if delta > args.threshold:
            regressions.append((name, delta))
            marker = "  << REGRESSED"
        elif delta < -args.threshold:
            improvements.append((name, delta))
            marker = "  (improved)"
        print(f"{name:<46} {human(b):>10} {human(c):>10} {delta:>+7.1%}{marker}")

    # missing/new sections are name-level drift, never a failure: a new
    # suite section (or one retired from the baseline) is reported and
    # the comparison proceeds over the overlap
    missing = sorted(set(base_by_name) - set(cur_by_name))
    new = sorted(set(cur_by_name) - set(base_by_name))
    for name in missing:
        print(f"warning: baseline benchmark {name!r} missing from current report")
    for name in new:
        print(f"note: new benchmark {name!r} (no baseline yet)")
    if missing or new:
        print(
            f"(section drift: {len(missing)} baseline-only, {len(new)} new; "
            f"{len(common)} compared)"
        )
    if skipped:
        print(f"({len(skipped)} sub-{human(args.min_seconds)} benchmarks skipped as noise)")

    # derived speedup ratios: informational trajectory, plus the repo's
    # standing expectation that the blocked kernel beats the naive one
    print(f"\n{'ratio':<56} {'baseline':>9} {'current':>9}")
    for name in sorted(set(base["ratios"]) | set(cur["ratios"])):
        b = base["ratios"].get(name)
        c = cur["ratios"].get(name)
        fmt = lambda v: f"{v:.2f}x" if v is not None else "-"
        print(f"{name:<56} {fmt(b):>9} {fmt(c):>9}")
    slow_blocked = [
        (name, v)
        for name, v in cur["ratios"].items()
        if name.startswith("gemm_blocked_over_naive/") and float(v) < 1.0
    ]
    for name, v in slow_blocked:
        print(f"warning: {name} = {float(v):.2f}x — blocked kernel slower than naive")

    # opt-in track: how much of the hardware model's predicted speedup
    # the packed kernels realize, format by format, vs the baseline
    if "packed_gap" in args.track:
        base_gap, _ = packed_gap(base["ratios"])
        cur_gap, cur_lone = packed_gap(cur["ratios"])
        print(f"\n{'packed_gap (measured/predicted)':<56} {'baseline':>9} {'current':>9}")
        show = lambda v: f"{v:.2f}" if v is not None else "-"
        for fmt_id in sorted(set(base_gap) | set(cur_gap)):
            b, c = base_gap.get(fmt_id), cur_gap.get(fmt_id)
            print(f"{'packed_gap/' + fmt_id:<56} {show(b):>9} {show(c):>9}")
            if b is not None and c is not None and b > 0.0:
                delta = (c - b) / b
                if delta < -args.threshold:
                    regressions.append((f"packed_gap/{fmt_id}", delta))
        for fmt_id in cur_lone:
            print(
                f"warning: packed_gap/{fmt_id}: measured or predicted ratio "
                f"present without its pair"
            )
        if not cur_gap:
            print(
                "warning: --track packed_gap: current report has no "
                "packed_forward_over_f32 / hw_speedup_predicted pairs"
            )

    print(
        f"\n{len(common)} compared, {len(regressions)} regressed, "
        f"{len(improvements)} improved (threshold {args.threshold:.0%})"
    )
    if regressions:
        for name, delta in regressions:
            print(f"REGRESSION: {name} {delta:+.1%}", file=sys.stderr)
        if args.warn_only:
            print("(--warn-only: exiting 0 despite regressions)")
            return 0
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
