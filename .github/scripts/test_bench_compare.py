#!/usr/bin/env python3
"""Unit tests for bench_compare.py over synthetic report pairs.

Runs the comparator as a subprocess (the same way CI does) against
generated BENCH_*.json files and asserts on exit codes and the
load-bearing output lines: strict structure validation (exit 2),
noise-tolerant regression detection (exit 1 / 0 with --warn-only),
name-level section drift as notes, zero-overlap as a structural error,
and the low-overlap warning that keeps a wholesale section rename from
passing silently.

Usage: python3 .github/scripts/test_bench_compare.py
"""

import json
import os
import subprocess
import sys
import tempfile
import unittest

SCRIPT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "bench_compare.py")


def result(name, median):
    return {
        "name": name,
        "median_s": median,
        "p10_s": median * 0.9,
        "p90_s": median * 1.1,
        "iters_per_batch": 100,
        "batches": 10,
    }


def report(names, median=1e-3, ratios=None, tag="t", preset="quick", schema="precis-bench/1"):
    return {
        "schema": schema,
        "tag": tag,
        "preset": preset,
        "results": [result(n, median) for n in names],
        "ratios": ratios if ratios is not None else {},
    }


class BenchCompareTest(unittest.TestCase):
    def setUp(self):
        self.dir = tempfile.TemporaryDirectory()

    def tearDown(self):
        self.dir.cleanup()

    def write(self, name, doc):
        path = os.path.join(self.dir.name, name)
        with open(path, "w", encoding="utf-8") as fh:
            if isinstance(doc, str):
                fh.write(doc)
            else:
                json.dump(doc, fh)
        return path

    def run_compare(self, base, cur, *flags):
        return subprocess.run(
            [sys.executable, SCRIPT, base, cur, *flags],
            capture_output=True,
            text=True,
            check=False,
        )

    def test_identical_reports_pass(self):
        doc = report(["a/1", "b/2"], ratios={"gemm_blocked_over_naive/x": 2.0})
        p = self.run_compare(self.write("b.json", doc), self.write("c.json", doc))
        self.assertEqual(p.returncode, 0, p.stderr)
        self.assertIn("0 regressed", p.stdout)

    def test_regression_beyond_threshold_fails(self):
        base = self.write("b.json", report(["a/1"], median=1e-3))
        cur = self.write("c.json", report(["a/1"], median=2e-3))
        p = self.run_compare(base, cur, "--threshold", "0.5")
        self.assertEqual(p.returncode, 1, p.stdout)
        self.assertIn("REGRESSION: a/1", p.stderr)

    def test_warn_only_downgrades_regressions(self):
        base = self.write("b.json", report(["a/1"], median=1e-3))
        cur = self.write("c.json", report(["a/1"], median=2e-3))
        p = self.run_compare(base, cur, "--threshold", "0.5", "--warn-only")
        self.assertEqual(p.returncode, 0, p.stdout)
        self.assertIn("REGRESSION: a/1", p.stderr)

    def test_slowdown_within_threshold_passes(self):
        base = self.write("b.json", report(["a/1"], median=1e-3))
        cur = self.write("c.json", report(["a/1"], median=1.2e-3))
        p = self.run_compare(base, cur, "--threshold", "0.5")
        self.assertEqual(p.returncode, 0, p.stdout)

    def test_sub_min_seconds_noise_is_skipped(self):
        # a 10x "regression" in the nanoseconds is noise, not a failure
        base = self.write("b.json", report(["a/1"], median=1e-8))
        cur = self.write("c.json", report(["a/1"], median=1e-7))
        p = self.run_compare(base, cur, "--threshold", "0.1")
        self.assertEqual(p.returncode, 0, p.stdout)
        self.assertIn("skipped as noise", p.stdout)

    def test_malformed_structure_exits_2_even_warn_only(self):
        good = report(["a/1"])
        for doc in [
            "not json at all{",
            report(["a/1"], schema="other/9"),
            {**report(["a/1"]), "results": []},
            {**report(["a/1", "a/1"])},  # duplicate names
            {**good, "ratios": {"r": float("nan")}},
            {**good, "results": [dict(result("a/1", 1e-3), median_s="fast")]},
        ]:
            base = self.write("b.json", good)
            cur = self.write("c.json", doc)
            p = self.run_compare(base, cur, "--warn-only")
            self.assertEqual(p.returncode, 2, f"{doc!r}: {p.stdout}")
            self.assertIn("STRUCTURE ERROR", p.stderr)

    def test_zero_overlap_is_a_structural_error(self):
        base = self.write("b.json", report(["a/1", "a/2"]))
        cur = self.write("c.json", report(["z/1", "z/2"]))
        p = self.run_compare(base, cur, "--warn-only")
        self.assertEqual(p.returncode, 2, p.stdout)
        self.assertIn("share no benchmark names", p.stderr)

    def test_section_drift_is_notes_not_failure(self):
        # the PR-6 case: new packed-exec sections absent from an older
        # baseline must be notes, and retired names warnings — exit 0
        base = self.write("b.json", report(["a/1", "old/1"]))
        cur = self.write("c.json", report(["a/1", "forward_packed/tiny"]))
        p = self.run_compare(base, cur)
        self.assertEqual(p.returncode, 0, p.stdout)
        self.assertIn("note: new benchmark 'forward_packed/tiny'", p.stdout)
        self.assertIn("warning: baseline benchmark 'old/1' missing", p.stdout)

    def test_low_overlap_warns_by_fraction(self):
        # 1 shared name out of 4: a wholesale rename masked as drift —
        # the comparison still runs, but the warning must be loud
        base = self.write("b.json", report(["a/1", "b/1", "b/2", "b/3"]))
        cur = self.write("c.json", report(["a/1", "c/1", "c/2", "c/3"]))
        p = self.run_compare(base, cur)
        self.assertEqual(p.returncode, 0, p.stdout)
        self.assertIn("of benchmark names overlap", p.stdout)
        self.assertIn("escapes regression checking", p.stdout)

    def test_healthy_overlap_does_not_warn(self):
        base = self.write("b.json", report(["a/1", "a/2", "a/3", "new/1"]))
        cur = self.write("c.json", report(["a/1", "a/2", "a/3", "other/1"]))
        p = self.run_compare(base, cur)
        self.assertEqual(p.returncode, 0, p.stdout)
        self.assertNotIn("of benchmark names overlap", p.stdout)

    def test_min_overlap_flag_tightens_the_bar(self):
        base = self.write("b.json", report(["a/1", "a/2", "b/1"]))
        cur = self.write("c.json", report(["a/1", "a/2", "c/1"]))
        p = self.run_compare(base, cur, "--min-overlap", "0.9")
        self.assertEqual(p.returncode, 0, p.stdout)
        self.assertIn("of benchmark names overlap", p.stdout)

    @staticmethod
    def gap_ratios(measured, predicted):
        ratios = {f"packed_forward_over_f32/{f}": v for f, v in measured.items()}
        ratios.update({f"hw_speedup_predicted/{f}": v for f, v in predicted.items()})
        return ratios

    def test_packed_gap_track_passes_when_realization_holds(self):
        ratios = self.gap_ratios({"fixed:l8r8": 4.0}, {"fixed:l8r8": 8.0})
        base = self.write("b.json", report(["a/1"], ratios=ratios))
        cur = self.write("c.json", report(["a/1"], ratios=ratios))
        p = self.run_compare(base, cur, "--track", "packed_gap")
        self.assertEqual(p.returncode, 0, p.stdout)
        self.assertIn("packed_gap/fixed:l8r8", p.stdout)
        self.assertIn("0.50", p.stdout)  # the realization column

    def test_packed_gap_realization_drop_is_a_regression(self):
        # prediction unchanged, measured speedup halved: the kernels now
        # realize half as much of the model — that's the regression the
        # track exists to catch, even though no raw timing regressed
        base_r = self.gap_ratios({"fixed:l8r8": 4.0}, {"fixed:l8r8": 8.0})
        cur_r = self.gap_ratios({"fixed:l8r8": 2.0}, {"fixed:l8r8": 8.0})
        base = self.write("b.json", report(["a/1"], ratios=base_r))
        cur = self.write("c.json", report(["a/1"], ratios=cur_r))
        p = self.run_compare(base, cur, "--track", "packed_gap")
        self.assertEqual(p.returncode, 1, p.stdout)
        self.assertIn("REGRESSION: packed_gap/fixed:l8r8", p.stderr)

    def test_packed_gap_regression_respects_warn_only(self):
        base_r = self.gap_ratios({"fixed:l8r8": 4.0}, {"fixed:l8r8": 8.0})
        cur_r = self.gap_ratios({"fixed:l8r8": 2.0}, {"fixed:l8r8": 8.0})
        base = self.write("b.json", report(["a/1"], ratios=base_r))
        cur = self.write("c.json", report(["a/1"], ratios=cur_r))
        p = self.run_compare(base, cur, "--track", "packed_gap", "--warn-only")
        self.assertEqual(p.returncode, 0, p.stdout)
        self.assertIn("REGRESSION: packed_gap/fixed:l8r8", p.stderr)

    def test_packed_gap_unpaired_ratio_warns(self):
        # a measured ratio with no prediction (or vice versa) cannot be
        # a realization — warn, don't crash or silently skip
        cur_r = self.gap_ratios({"fixed:l8r8": 4.0, "fixed:l3r3": 3.0}, {"fixed:l8r8": 8.0})
        base = self.write("b.json", report(["a/1"], ratios={}))
        cur = self.write("c.json", report(["a/1"], ratios=cur_r))
        p = self.run_compare(base, cur, "--track", "packed_gap")
        self.assertEqual(p.returncode, 0, p.stdout)
        self.assertIn("warning: packed_gap/fixed:l3r3", p.stdout)

    def test_packed_gap_not_checked_without_the_flag(self):
        base_r = self.gap_ratios({"fixed:l8r8": 4.0}, {"fixed:l8r8": 8.0})
        cur_r = self.gap_ratios({"fixed:l8r8": 2.0}, {"fixed:l8r8": 8.0})
        base = self.write("b.json", report(["a/1"], ratios=base_r))
        cur = self.write("c.json", report(["a/1"], ratios=cur_r))
        p = self.run_compare(base, cur)
        self.assertEqual(p.returncode, 0, p.stdout)
        self.assertNotIn("packed_gap", p.stdout)


if __name__ == "__main__":
    unittest.main(verbosity=2)
