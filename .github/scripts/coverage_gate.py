#!/usr/bin/env python3
"""Per-module line-coverage report + floor gate over a cargo-llvm-cov
JSON export (`cargo llvm-cov --json`).

Reports aggregate line coverage for the numerics, formats, and serving
modules, and FAILS if `numerics` drops below the floor established when
the coverage lane landed (the cross-language golden-vector suite plus
the quantizer property tests put numerics well above it; the floor is
deliberately conservative — ratchet it upward, never down).

Usage: coverage_gate.py <coverage.json>
"""

import json
import sys

# module path fragment -> floor percent (None = report only)
MODULES = {
    "rust/src/numerics/": 85.0,
    "rust/src/formats/": None,
    "rust/src/serving/": None,
}


def main() -> int:
    with open(sys.argv[1]) as fh:
        export = json.load(fh)
    files = export["data"][0]["files"]

    failed = False
    print(f"{'module':<24} {'lines':>8} {'covered':>8} {'percent':>8}  floor")
    for frag, floor in MODULES.items():
        count = covered = 0
        for f in files:
            if frag in f["filename"].replace("\\", "/"):
                lines = f["summary"]["lines"]
                count += lines["count"]
                covered += lines["covered"]
        if count == 0:
            print(f"{frag:<24} {'-':>8} {'-':>8} {'-':>8}  NO FILES MATCHED")
            failed = True
            continue
        pct = 100.0 * covered / count
        floor_s = f">= {floor:.0f}%" if floor is not None else "(report only)"
        verdict = ""
        if floor is not None and pct < floor:
            verdict = "  <-- BELOW FLOOR"
            failed = True
        print(f"{frag:<24} {count:>8} {covered:>8} {pct:>7.1f}%  {floor_s}{verdict}")

    if failed:
        print("\ncoverage gate FAILED", file=sys.stderr)
        return 1
    print("\ncoverage gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
