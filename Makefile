# Build-time entry points.  `artifacts` is the only step that needs
# Python/JAX; everything after it is pure cargo (DESIGN.md §2).

.PHONY: verify artifacts bench bench-json bench-compare clean-artifacts

# tier-1 verify (ROADMAP.md)
verify:
	cargo build --release && cargo test -q

# train the mini zoo + AOT-lower the HLO artifacts into artifacts/
artifacts: artifacts/.stamp

artifacts/.stamp: python/compile/aot.py python/compile/model.py \
		python/compile/train.py python/compile/datagen.py \
		python/compile/io_prt.py python/compile/kernels/qformat.py \
		python/compile/kernels/qmatmul.py python/compile/kernels/ref.py
	python3 -m python.compile.aot --out-dir artifacts

bench:
	cargo bench

# machine-readable perf trajectory (DESIGN.md §Perf): run the headless
# hot-path suite and write BENCH_$(BENCH_TAG).json.  Diff two files:
#   make bench-compare BASE=BENCH_pr4_baseline.json CUR=BENCH_local.json
BENCH_TAG ?= local
bench-json:
	cargo run --release --bin repro -- bench --preset full \
		--tag $(BENCH_TAG) --json BENCH_$(BENCH_TAG).json

BASE ?= BENCH_pr4_baseline.json
CUR ?= BENCH_local.json
bench-compare:
	python3 .github/scripts/bench_compare.py $(BASE) $(CUR)

clean-artifacts:
	rm -rf artifacts
