//! Network = layer graph + trained weights + eval set, loaded from
//! `artifacts/` (meta.json + .prt containers).  [`Zoo`] is the set of
//! all networks an artifact directory provides.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::nn::layers::Layer;
use crate::tensor::io::read_container;
use crate::tensor::Tensor;
use crate::util::json::Json;

/// One loaded network.
#[derive(Debug)]
pub struct Network {
    pub name: String,
    /// input spatial shape [H, W, C]
    pub input: [usize; 3],
    pub classes: usize,
    /// accuracy metric arity (1 or 5, per the paper's methodology §3.1)
    pub topk: usize,
    pub layers: Vec<Layer>,
    /// HLO parameter order (after x and fmt)
    pub weight_order: Vec<String>,
    pub weights: BTreeMap<String, Tensor>,
    /// held-out eval set
    pub eval_x: Tensor,
    pub eval_y: Vec<i32>,
    /// exact-path eval accuracy recorded by the trainer (meta.json)
    pub eval_acc_exact: f64,
    /// artifact file names per representation kind ("float"/"fixed")
    pub hlo_files: BTreeMap<String, String>,
    pub n_params: usize,
    pub max_chain: usize,
}

impl Network {
    fn from_meta(name: &str, meta: &Json, dir: &Path) -> Result<Network> {
        // every scalar manifest field is validated UP FRONT with a typed
        // error naming the network and the offending key — a malformed
        // manifest must surface the loader contract's loud Err, never an
        // unwrap panic, and must do so before any file IO
        let req_str = |key: &str| -> Result<String> {
            Ok(meta
                .req(key)
                .with_context(|| format!("network {name}: manifest"))?
                .as_str()
                .ok_or_else(|| {
                    anyhow!("network {name}: manifest key {key:?} must be a string")
                })?
                .to_string())
        };
        let req_usize = |key: &str| -> Result<usize> {
            meta.req(key)
                .with_context(|| format!("network {name}: manifest"))?
                .as_usize()
                .ok_or_else(|| anyhow!("network {name}: manifest key {key:?} must be a number"))
        };
        let wfile = req_str("weights_file")?;
        let efile = req_str("eval_file")?;
        let classes = req_usize("classes")?;
        let topk = req_usize("topk")?;
        let n_params = req_usize("params")?;
        let max_chain = req_usize("max_chain")?;

        let input: Vec<usize> = meta
            .req("input")?
            .as_arr()
            .ok_or_else(|| anyhow!("input must be an array"))?
            .iter()
            .map(|v| v.as_usize().unwrap_or(0))
            .collect();
        if input.len() != 3 {
            bail!("network {name}: input must be [H, W, C]");
        }

        let layers = meta
            .req("layers")?
            .as_arr()
            .ok_or_else(|| anyhow!("layers must be an array"))?
            .iter()
            .map(Layer::from_json)
            .collect::<Result<Vec<_>>>()?;

        let weight_order: Vec<String> = meta
            .req("weights")?
            .as_arr()
            .ok_or_else(|| anyhow!("weights must be an array"))?
            .iter()
            .map(|v| v.as_str().unwrap_or("").to_string())
            .collect();

        let weights_c = read_container(&dir.join(&wfile))
            .with_context(|| format!("loading weights for {name}"))?;
        let mut weights = BTreeMap::new();
        for wname in &weight_order {
            weights.insert(wname.clone(), weights_c.f32(wname)?.clone());
        }

        let eval_c = read_container(&dir.join(&efile))
            .with_context(|| format!("loading eval set for {name}"))?;
        let eval_x = eval_c.f32("x")?.clone();
        let eval_y = eval_c.i32("y")?.data.clone();
        if eval_x.shape()[0] != eval_y.len() {
            bail!("network {name}: eval x/y length mismatch");
        }

        let mut hlo_files = BTreeMap::new();
        if let Some(hlo) = meta.get("hlo").and_then(|h| h.as_obj()) {
            for (kind, fname) in hlo {
                hlo_files.insert(kind.clone(), fname.as_str().unwrap_or("").to_string());
            }
        }

        Ok(Network {
            name: name.to_string(),
            input: [input[0], input[1], input[2]],
            classes,
            topk,
            layers,
            weight_order,
            weights,
            eval_x,
            eval_y,
            eval_acc_exact: meta.req("eval_acc_exact")?.as_f64().unwrap_or(0.0),
            hlo_files,
            n_params,
            max_chain,
        })
    }

    pub fn eval_len(&self) -> usize {
        self.eval_y.len()
    }

    /// Weight tensor by name (panics on unknown name — a spec bug).
    pub fn weight(&self, name: &str) -> &Tensor {
        self.weights
            .get(name)
            .unwrap_or_else(|| panic!("weight {name:?} missing in {}", self.name))
    }

    /// Names of the quantized (GEMM) layers in execution order — the
    /// layers a mixed-precision plan assigns formats to.  Inception
    /// modules contribute their four branch convolutions
    /// (`<name>.1x1`, `.3x3`, `.5x5`, `.proj`).
    pub fn quantized_layer_names(&self) -> Vec<String> {
        let mut out = Vec::new();
        for l in &self.layers {
            match l {
                Layer::Conv { name, .. } | Layer::Dense { name, .. } => out.push(name.clone()),
                Layer::Inception { .. } => {
                    for b in l.inception_branches() {
                        if let Layer::Conv { name, .. } = b {
                            out.push(name);
                        }
                    }
                }
                _ => {}
            }
        }
        out
    }

    /// Per-sample MAC count of every quantized layer, in execution
    /// order (the weights for `hw::plan_speedup`'s MAC-weighted
    /// aggregate).  Tracks activation shapes with the same arithmetic
    /// the engine uses.
    pub fn quantized_layer_macs(&self) -> Vec<(String, usize)> {
        let (mut h, mut w) = (self.input[0], self.input[1]);
        let out_dim = |x: usize, k: usize, s: usize, p: usize| (x + 2 * p - k) / s + 1;
        let mut out = Vec::new();
        for l in &self.layers {
            match l {
                Layer::Conv { name, kh, kw, in_ch, out_ch, stride, pad } => {
                    let oh = out_dim(h, *kh, *stride, *pad);
                    let ow = out_dim(w, *kw, *stride, *pad);
                    out.push((name.clone(), oh * ow * kh * kw * in_ch * out_ch));
                    h = oh;
                    w = ow;
                }
                Layer::Dense { name, in_dim, out_dim } => {
                    out.push((name.clone(), in_dim * out_dim));
                }
                Layer::MaxPool { k, stride, pad } => {
                    h = out_dim(h, *k, *stride, *pad);
                    w = out_dim(w, *k, *stride, *pad);
                }
                Layer::GAvgPool => {
                    h = 1;
                    w = 1;
                }
                Layer::Inception { .. } => {
                    // branches preserve HxW (stride 1, same-padding)
                    for b in l.inception_branches() {
                        if let Layer::Conv { name, kh, kw, in_ch, out_ch, .. } = b {
                            out.push((name, h * w * kh * kw * in_ch * out_ch));
                        }
                    }
                }
                Layer::Relu | Layer::Flatten => {}
            }
        }
        out
    }

    /// Absolute path of the HLO artifact for a representation kind.
    pub fn hlo_path(&self, dir: &Path, kind: &str) -> Result<PathBuf> {
        let f = self
            .hlo_files
            .get(kind)
            .ok_or_else(|| anyhow!("{}: no HLO artifact for kind {kind:?}", self.name))?;
        Ok(dir.join(f))
    }
}

/// All networks in an artifact directory.
pub struct Zoo {
    pub dir: PathBuf,
    pub batch: usize,
    networks: BTreeMap<String, Arc<Network>>,
}

impl Zoo {
    pub fn load(dir: impl AsRef<Path>) -> Result<Zoo> {
        let dir = dir.as_ref().to_path_buf();
        let meta_path = dir.join("meta.json");
        let text = std::fs::read_to_string(&meta_path)
            .with_context(|| format!("reading {} (run `make artifacts` first)", meta_path.display()))?;
        let meta = Json::parse(&text).context("parsing meta.json")?;
        let batch = meta.req("batch")?.as_usize().unwrap_or(32);

        let mut networks = BTreeMap::new();
        for (name, nm) in meta
            .req("networks")?
            .as_obj()
            .ok_or_else(|| anyhow!("networks must be an object"))?
        {
            networks.insert(name.clone(), Arc::new(Network::from_meta(name, nm, &dir)?));
        }
        Ok(Zoo { dir, batch, networks })
    }

    pub fn network(&self, name: &str) -> Result<Arc<Network>> {
        self.networks
            .get(name)
            .cloned()
            .ok_or_else(|| anyhow!("unknown network {name:?} (have: {:?})", self.names()))
    }

    pub fn names(&self) -> Vec<&str> {
        self.networks.keys().map(|s| s.as_str()).collect()
    }

    /// Networks ordered by descending size (the paper's Fig 11 ordering).
    pub fn by_size_desc(&self) -> Vec<Arc<Network>> {
        let mut v: Vec<_> = self.networks.values().cloned().collect();
        v.sort_by(|a, b| b.n_params.cmp(&a.n_params));
        v
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// A structurally complete manifest whose scalar fields are spliced
    /// in verbatim — callers pass JSON fragments (`"\"w.prt\""`, `"7"`)
    /// so each case can corrupt exactly one field's type.
    fn manifest(
        wfile: &str,
        efile: &str,
        classes: &str,
        topk: &str,
        params: &str,
        max_chain: &str,
    ) -> String {
        format!(
            r#"{{
                "input": [2, 2, 1], "layers": [], "weights": [],
                "weights_file": {wfile}, "eval_file": {efile},
                "classes": {classes}, "topk": {topk},
                "eval_acc_exact": 1.0,
                "params": {params}, "max_chain": {max_chain}
            }}"#
        )
    }

    fn try_load(text: &str) -> Result<Network> {
        let meta = Json::parse(text).expect("test manifests are syntactically valid JSON");
        Network::from_meta("m", &meta, Path::new("/nonexistent"))
    }

    /// ISSUE 8 satellite: a manifest with a wrong-typed scalar field
    /// surfaces a typed `Err` naming the network and the offending key —
    /// the old `.as_str().unwrap()` on `weights_file`/`eval_file`
    /// panicked instead.  Validation runs before any file IO, so the
    /// matrix needs no artifact files on disk.
    #[test]
    fn malformed_manifest_fields_surface_typed_errors_not_panics() {
        let s = |v: &str| format!("{v:?}"); // JSON string literal
        let cases: Vec<(String, &str)> = vec![
            // non-string file fields (the original panic sites)
            (manifest("7", &s("e.prt"), "10", "1", "0", "0"), "weights_file"),
            (manifest("[1, 2]", &s("e.prt"), "10", "1", "0", "0"), "weights_file"),
            (manifest(&s("w.prt"), "3.5", "10", "1", "0", "0"), "eval_file"),
            // non-numeric count fields (same unwrap pattern, same fix)
            (manifest(&s("w.prt"), &s("e.prt"), &s("ten"), "1", "0", "0"), "classes"),
            (manifest(&s("w.prt"), &s("e.prt"), "10", "[]", "0", "0"), "topk"),
            (manifest(&s("w.prt"), &s("e.prt"), "10", "1", &s("big"), "0"), "params"),
            (manifest(&s("w.prt"), &s("e.prt"), "10", "1", "0", "{}"), "max_chain"),
        ];
        for (text, key) in &cases {
            let err = format!("{:#}", try_load(text).expect_err(key));
            assert!(err.contains(&format!("{key:?}")), "{key}: {err}");
            assert!(err.contains("network m"), "{key}: error must name the network: {err}");
        }
        // a missing key reports through the same contract
        let text = manifest(&s("w.prt"), &s("e.prt"), "10", "1", "0", "0")
            .replace(r#""eval_file": "e.prt","#, "");
        let err = format!("{:#}", try_load(&text).unwrap_err());
        assert!(err.contains("eval_file"), "{err}");
        assert!(err.contains("network m"), "{err}");
        // an all-valid manifest gets PAST field validation: its failure
        // is the weights-file IO (no artifacts on disk), proving the
        // checks run before — and do not mask — the load itself
        let err = format!(
            "{:#}",
            try_load(&manifest(&s("w.prt"), &s("e.prt"), "10", "1", "0", "0")).unwrap_err()
        );
        assert!(err.contains("loading weights for m"), "{err}");
    }
}
