//! The forward-pass interpreter (sweep hot path).
//!
//! Bit-exactness contract with `python/compile/model.py` (and therefore
//! with the AOT HLO artifacts):
//! * activations NHWC, flatten row-major;
//! * im2col patch index ((ki*kw + kj)*C + c); conv weights (kh,kw,cin,cout)
//!   row-major are *already* the (K, N) GEMM operand in that indexing;
//! * quantize input once; per conv/dense: quantize weights & bias, run
//!   the per-op-rounded MAC chain in increasing-k order starting from a
//!   zero accumulator, then one rounded bias add;
//! * relu/maxpool are exact (selection); zero padding;
//! * global avgpool: serial per-add-rounded accumulation over row-major
//!   spatial positions, then one rounded multiply by q(1/HW).
//!
//! The forward pass consumes a resolved per-layer quantizer table
//! ([`QuantTable`]) rather than a single format: each conv/dense (and
//! inception branch) runs under its assigned quantizer, so per-layer
//! mixed-precision plans and the legacy uniform setting execute the
//! SAME code path — a uniform table makes every entry the same
//! quantizer, which is the bit-exactness anchor (DESIGN.md §Mixed
//! precision).
//!
//! The engine owns scratch buffers so a sweep makes **zero heap
//! allocations per forward** after warm-up (tables are resolved once
//! per spec by the backend, not per forward), and the GEMM at its core
//! is the M/N cache-blocked [`gemm_q`] with a strictly serial k chain
//! per output element (§Perf L3 target; DESIGN.md §4).
//!
//! Weight staging goes through the [`crate::store::WeightStore`]
//! (DESIGN.md §Storage): weights are constant per `(layer, resolved
//! format)`, so each conv/dense reads its pre-quantized tensor from the
//! store by reference — the quantize-and-copy staging pass survives
//! only as the store-miss fallback ([`Engine::stage_quantized_weights`]
//! into the scratch `wq` buffer), which is bit-identical by
//! construction (the store runs the same `quantize_slice`).
//! `Format::SINGLE` layers whose weights the identity op leaves
//! bit-identical skip even that: the table marks them
//! [`Staging::Direct`] and the kernels borrow the network's tensor
//! in place (checked once per table resolution, so a weight tensor
//! containing carrier subnormals still stages — the flush is part of
//! the bit-exactness contract).
//!
//! Every quantized kernel here is **monomorphized** per representation
//! kind (DESIGN.md §Perf): each layer's [`Quantizer`] is dispatched
//! ONCE per kernel call via [`crate::with_quant_op!`], selecting the
//! `gemm_q::<Q>` / `add_bias_q::<Q>` / `gavgpool_q::<Q>` instantiation
//! for `QFloat` / `QFixed` / `QIdentity` — so no kind branch survives
//! inside any per-MAC loop.
//!
//! `Engine` is crate-private: all consumers — offline sweeps and the
//! request path alike — run it through `serving::NativeBackend`, the
//! native implementation of the one execution substrate
//! (DESIGN.md §Serving).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::formats::{Format, FormatPair, PrecisionSpec};
use crate::nn::layers::Layer;
use crate::nn::network::Network;
use crate::numerics::{quantize_slice, QIdentity, QuantOp, Quantizer};
use crate::obs::LayerSpan;
use crate::store::{
    gemm_packed_int, gemm_packed_lut, ExecScratch, Lease, PackedPlan, PackedTensor, StoreEntry,
    StoreKey, WeightStore, LUT_MAX_WIDTH,
};
use crate::tensor::Tensor;
use crate::{with_packed_op, with_quant_op};

/// The engine-facing form of a [`PrecisionSpec`]: one prebuilt
/// [`Quantizer`] per layer position, resolved and validated against a
/// network ONCE and then applied per forward — so per-layer plans cost
/// nothing on the hot path and the "zero heap allocations per forward"
/// contract survives the mixed-precision refactor.
///
/// Assignment semantics (DESIGN.md §Mixed precision): named quantized
/// layers (conv / dense / inception branch convs) use their assigned
/// format; **unnamed** quantized ops — the input staging pass and
/// global average pooling — inherit the format of the next named layer
/// downstream, whose operand they compute (for an inception module,
/// its first branch).  Exact ops (relu / maxpool / flatten) quantize
/// nothing.  Under a uniform assignment every entry is the same
/// quantizer, which is why a uniform plan is bit-identical to the
/// legacy single-format forward.
pub struct QuantTable {
    /// quantizer for the input staging pass (the first named layer's)
    input: Quantizer,
    /// one entry per network layer, in execution order
    per_layer: Vec<LayerQuant>,
}

enum LayerQuant {
    /// conv / dense: the layer's own entry; unnamed quantized ops: the
    /// inherited downstream quantizer; exact ops: unused
    One(LayerQ),
    /// inception: per-branch entries in concat order
    Branches(Vec<LayerQ>),
}

/// One layer's resolved quantization entry: the kernel dispatchers plus
/// how its weight operand is staged.  Built once per table resolution,
/// so the hot path performs neither format resolution nor store-key
/// allocation.
///
/// With split precision (DESIGN.md §Mixed precision, second axis) one
/// layer carries TWO quantizers: `q` (the **activation** half) runs the
/// MAC chain, bias add, input staging and gavgpool — everything the
/// flowing activations touch — while `wq` (the **weight** half) stages
/// the constant weight tensor (and keys the store, via `staging`).  A
/// uniform pair makes them the same quantizer, which is the
/// bit-exactness anchor for every pre-existing single-format spec.
struct LayerQ {
    /// activation-half quantizer: the MAC-chain dispatcher
    q: Quantizer,
    /// weight-half quantizer: the scratch-staging fallback op (the
    /// store path quantizes under the same format via the store key)
    wq: Quantizer,
    /// the resolved (weight, activation) pair — the packed router's
    /// input
    pair: FormatPair,
    staging: Staging,
    /// where this layer's GEMM executes (DESIGN.md §Packed execution);
    /// [`PackedPlan::Staged`] unless the table was resolved with packed
    /// execution enabled AND the router admitted the layer
    packed: PackedPlan,
    /// the lock-free warm path (DESIGN.md §Storage): the last [`Lease`]
    /// the store issued for this layer's key.  While it validates
    /// ([`WeightStore::hit_if_current`] — one atomic load), the forward
    /// touches no store mutex; eviction/clear invalidate it and the
    /// next forward re-prepares through the locked path.  `RefCell`
    /// because a table is owned by one backend (one thread) but the
    /// forward only holds `&QuantTable`.
    cache: RefCell<Option<Lease>>,
}

impl LayerQ {
    /// The staged store entry for this layer: cached-lease validation
    /// first (lock-free), locked `prepare_lease` on a miss or stale
    /// epoch (the fresh lease replaces the cache).  `None` = no store,
    /// not store-staged, or budget-rejected — callers fall back to
    /// scratch staging, bit-identical by construction.
    fn staged_entry(
        &self,
        store: Option<&WeightStore>,
        weights: &[f32],
    ) -> Option<Arc<StoreEntry>> {
        let (Staging::Store(key), Some(s)) = (&self.staging, store) else {
            return None;
        };
        if let Some(lease) = self.cache.borrow().as_ref() {
            if let Some(entry) = s.hit_if_current(lease) {
                return Some(entry);
            }
        }
        let lease = s.prepare_lease(key, weights);
        let entry = lease.as_ref().map(|l| l.entry().clone());
        *self.cache.borrow_mut() = lease;
        entry
    }
}

/// How a layer's weight tensor reaches the GEMM (module docs;
/// DESIGN.md §Storage).  Classification and store keying follow the
/// **weight** half of the layer's pair alone: weights are constant per
/// `(layer, weight format)`, so sessions that differ only in their
/// activation half share the same store entries (pinned by
/// `tests/store_contract.rs`).
enum Staging {
    /// no weight operand (exact ops, input staging, gavgpool)
    NoWeights,
    /// weight half `Format::SINGLE` over weights the identity op leaves
    /// bit-identical: borrow the network's tensor directly — no copy,
    /// no quantization, no store bytes
    Direct,
    /// read the pre-quantized tensor from the [`WeightStore`] under
    /// this prebuilt key (keyed on the weight half); scratch-stage on a
    /// miss the budget cannot admit
    Store(StoreKey),
}

/// Build a named layer's entry, classifying its staging path (the key
/// is prebuilt here so store lookups allocate nothing per forward).
fn named_layer_q(net: &Network, name: &str, pair: FormatPair) -> LayerQ {
    let q = Quantizer::new(&pair.a);
    let wq = Quantizer::new(&pair.w);
    let staging = if wq.is_identity() && identity_clean(net.weight(&format!("{name}.w")).data()) {
        Staging::Direct
    } else {
        Staging::Store(StoreKey::new(&net.name, name, pair.w))
    };
    LayerQ { q, wq, pair, staging, packed: PackedPlan::Staged, cache: RefCell::new(None) }
}

/// True when the identity op maps every value to itself — i.e. the
/// tensor holds no carrier subnormal that `Format::SINGLE` would flush.
fn identity_clean(w: &[f32]) -> bool {
    w.iter().all(|&v| QIdentity.q(v).to_bits() == v.to_bits())
}

impl QuantTable {
    /// Resolve `spec` against `net` (validating plan coverage) and
    /// prebuild every layer's quantizer.  Uniform specs never fail —
    /// the legacy single-format behaviour for any network shape.
    pub fn resolve(net: &Network, spec: &PrecisionSpec) -> Result<QuantTable> {
        match spec {
            PrecisionSpec::Uniform(f) => Ok(QuantTable::uniform_for(net, f)),
            PrecisionSpec::PerLayer(p) => {
                let resolved = p.resolve(net)?;
                let fmt_of = |name: &str| -> FormatPair {
                    resolved
                        .format_for(name)
                        .unwrap_or_else(|| panic!("resolved plan misses layer {name:?}"))
                };
                let mut per_layer: Vec<LayerQuant> = Vec::with_capacity(net.layers.len());
                // reverse pass: unnamed quantized ops inherit the next
                // named layer downstream (see type docs) — specifically
                // its ACTIVATION half, whose operand they compute.
                // `None` means no named layer follows — fatal for an op
                // that actually quantizes (gavgpool), harmless for
                // exact ops whose table entry is never read.
                let mut next: Option<(Quantizer, FormatPair)> = None;
                for layer in net.layers.iter().rev() {
                    let lq = match layer {
                        Layer::Conv { name, .. } | Layer::Dense { name, .. } => {
                            let lq = named_layer_q(net, name, fmt_of(name));
                            next = Some((lq.q, lq.pair));
                            LayerQuant::One(lq)
                        }
                        Layer::Inception { .. } => {
                            let qs: Vec<LayerQ> = layer
                                .inception_branches()
                                .iter()
                                .map(|b| match b {
                                    Layer::Conv { name, .. } => {
                                        named_layer_q(net, name, fmt_of(name))
                                    }
                                    _ => unreachable!("inception branches are convs"),
                                })
                                .collect();
                            next = Some((qs[0].q, qs[0].pair));
                            LayerQuant::Branches(qs)
                        }
                        Layer::GAvgPool => {
                            let Some((q, pair)) = next else {
                                bail!(
                                    "{}: global average pool has no named quantized layer \
                                     downstream to inherit a format from — per-layer plans \
                                     need one (DESIGN.md §Mixed precision)",
                                    net.name
                                );
                            };
                            LayerQuant::One(LayerQ {
                                q,
                                wq: q,
                                pair,
                                staging: Staging::NoWeights,
                                packed: PackedPlan::Staged,
                                cache: RefCell::new(None),
                            })
                        }
                        // exact ops never consult their entry; the
                        // placeholder is unreachable by construction
                        _ => {
                            let (q, pair) = next.unwrap_or_else(|| {
                                (
                                    Quantizer::new(&Format::SINGLE),
                                    FormatPair::uniform(Format::SINGLE),
                                )
                            });
                            LayerQuant::One(LayerQ {
                                q,
                                wq: q,
                                pair,
                                staging: Staging::NoWeights,
                                packed: PackedPlan::Staged,
                                cache: RefCell::new(None),
                            })
                        }
                    };
                    per_layer.push(lq);
                }
                per_layer.reverse();
                let Some((input, _)) = next else {
                    // unreachable: p.resolve() errors when the network
                    // has no quantized layers; kept as a hard error so
                    // a future refactor cannot silently mis-quantize
                    bail!("{}: no quantized layer to derive the input format from", net.name);
                };
                Ok(QuantTable { input, per_layer })
            }
        }
    }

    /// The table a single format induces: the same quantizer
    /// everywhere.  Infallible (no names to validate).
    pub fn uniform_for(net: &Network, fmt: &Format) -> QuantTable {
        let q = Quantizer::new(fmt);
        let pair = FormatPair::uniform(*fmt);
        let per_layer = net
            .layers
            .iter()
            .map(|l| match l {
                Layer::Conv { name, .. } | Layer::Dense { name, .. } => {
                    LayerQuant::One(named_layer_q(net, name, pair))
                }
                Layer::Inception { .. } => LayerQuant::Branches(
                    l.inception_branches()
                        .iter()
                        .map(|b| match b {
                            Layer::Conv { name, .. } => named_layer_q(net, name, pair),
                            _ => unreachable!("inception branches are convs"),
                        })
                        .collect(),
                ),
                _ => LayerQuant::One(LayerQ {
                    q,
                    wq: q,
                    pair,
                    staging: Staging::NoWeights,
                    packed: PackedPlan::Staged,
                    cache: RefCell::new(None),
                }),
            })
            .collect();
        QuantTable { input: q, per_layer }
    }

    /// [`QuantTable::resolve`], then — when `packed_exec` is on — run
    /// the packed-execution router over the resolved table
    /// ([`assign_packed`](Self::assign_packed)).  The backends' entry
    /// point: `resolve_for(net, spec, false)` ≡ `resolve(net, spec)`.
    pub fn resolve_for(
        net: &Network,
        spec: &PrecisionSpec,
        packed_exec: bool,
    ) -> Result<QuantTable> {
        let mut table = QuantTable::resolve(net, spec)?;
        if packed_exec {
            table.assign_packed(net);
        }
        Ok(table)
    }

    /// The packed-execution router pass (DESIGN.md §Packed execution):
    /// walk the network FORWARD tracking which quantizer's grid the
    /// flowing activations live on, and give each named layer the
    /// [`PackedPlan`] that [`crate::store::route`] admits under that
    /// premise.  Grid tracking is the integer lanes' soundness
    /// condition — `gemm_packed_int` stages activations with an *exact*
    /// grid conversion, so it may only run when every activation
    /// entering the layer is an output of the layer's own quantizer:
    ///
    /// * the input staging pass puts the input on `self.input`'s grid;
    /// * conv / dense / gavgpool outputs are on their own quantizer's
    ///   grid (every kernel ends each element with `q(..)`);
    /// * relu (negatives to `0.0`, on every grid), maxpool (selection,
    ///   `0.0` pad) and flatten (relayout) preserve the grid;
    /// * an identity-quantized layer (`Format::SINGLE`) emits raw f32 —
    ///   tracked as the identity grid, which no fixed grid equals, so
    ///   downstream integer lanes are refused;
    /// * an inception module's concat is on a single grid only when
    ///   every branch resolved to the same quantizer.
    ///
    /// Split pairs: grid tracking follows each layer's **activation**
    /// half (that is the grid its outputs land on), and routing goes
    /// through [`crate::store::route_pair`] — a mixed pair can never
    /// satisfy the integer premise (activations would have to be on the
    /// *weight* grid), so it pins to the LUT lane or Staged, never a
    /// silent approximation.
    ///
    /// Decode LUTs depend only on the stored (weight-half) format, so
    /// they are built once per distinct weight format and shared across
    /// layers and activation halves.
    fn assign_packed(&mut self, net: &Network) {
        let mut luts: BTreeMap<Format, Arc<Vec<f32>>> = BTreeMap::new();
        let mut lut_for = |fmt: &Format| -> Arc<Vec<f32>> {
            luts.entry(*fmt)
                .or_insert_with(|| {
                    Arc::new(
                        PackedTensor::decode_table(fmt, LUT_MAX_WIDTH)
                            .expect("router admits LUT only for table-sized formats"),
                    )
                })
                .clone()
        };
        let mut plan = |lq: &mut LayerQ, upstream: &Option<Quantizer>| {
            let direct = !matches!(lq.staging, Staging::Store(_));
            let on_grid = *upstream == Some(lq.q);
            let pair = lq.pair;
            lq.packed = PackedPlan::for_layer(&pair, direct, on_grid, || lut_for(&pair.w));
        };
        // the engine quantizes the input once, onto the first named
        // layer's grid
        let mut current: Option<Quantizer> = Some(self.input);
        for (layer, entry) in net.layers.iter().zip(self.per_layer.iter_mut()) {
            match (layer, entry) {
                (Layer::Conv { .. } | Layer::Dense { .. }, LayerQuant::One(lq)) => {
                    plan(lq, &current);
                    current = Some(lq.q);
                }
                (Layer::Inception { .. }, LayerQuant::Branches(qs)) => {
                    // every branch reads the module input (the pool
                    // branch through a grid-preserving maxpool)
                    for lq in qs.iter_mut() {
                        plan(lq, &current);
                    }
                    current = match qs.split_first() {
                        Some((q0, rest)) if rest.iter().all(|lq| lq.q == q0.q) => Some(q0.q),
                        _ => None,
                    };
                }
                (Layer::GAvgPool, LayerQuant::One(lq)) => {
                    // unnamed quantized op: output lands on its
                    // inherited quantizer's grid
                    current = Some(lq.q);
                }
                // relu / maxpool / flatten preserve the grid
                _ => {}
            }
        }
    }

    /// Per named layer, the packed-execution lane the router assigned
    /// (`staged` / `int16` / `int32` / `lut`), in execution order —
    /// surfaced by `repro zoo-size` and the serving stats.
    pub fn packed_labels(&self, net: &Network) -> Vec<(String, &'static str)> {
        let mut out = Vec::new();
        for (layer, entry) in net.layers.iter().zip(&self.per_layer) {
            match (layer, entry) {
                (Layer::Conv { name, .. } | Layer::Dense { name, .. }, LayerQuant::One(lq)) => {
                    out.push((name.clone(), lq.packed.label()));
                }
                (Layer::Inception { .. }, LayerQuant::Branches(qs)) => {
                    for (br, lq) in layer.inception_branches().iter().zip(qs) {
                        if let Layer::Conv { name, .. } = br {
                            out.push((name.clone(), lq.packed.label()));
                        }
                    }
                }
                _ => {}
            }
        }
        out
    }
}

/// Reusable forward-pass executor (one per worker thread).
pub struct Engine {
    /// ping-pong activation buffers
    act_a: Vec<f32>,
    act_b: Vec<f32>,
    /// im2col patch buffer
    patches: Vec<f32>,
    /// quantized-weight staging buffer
    wq: Vec<f32>,
    /// per-layer output staging for inception concat
    branch_out: Vec<f32>,
    /// packed-kernel scratch (integer lanes, decoded weight tiles)
    exec: ExecScratch,
    /// intra-forward row parallelism for big staged GEMMs: workers the
    /// M dimension is split across (`0`/`1` = serial).  Rows are
    /// independent chains, so any split is bit-identical by
    /// construction (DESIGN.md §Perf).
    gemm_threads: usize,
    /// per-layer span collection (`obs` profiler; DESIGN.md
    /// §Observability).  `None` = profiling off: the hot path performs
    /// ONE `is_some` check per named layer and is otherwise untouched —
    /// no timestamps, no output scans, bit-identical forwards.
    prof: Option<Vec<LayerSpan>>,
}

/// Shape of the activation tensor flowing through the engine.
#[derive(Clone, Copy, Debug, PartialEq)]
enum ActShape {
    /// batch, height, width, channels
    Hwc(usize, usize, usize, usize),
    /// batch, features
    Flat(usize, usize),
}

impl ActShape {
    fn len(&self) -> usize {
        match *self {
            ActShape::Hwc(b, h, w, c) => b * h * w * c,
            ActShape::Flat(b, f) => b * f,
        }
    }
}

impl Default for Engine {
    fn default() -> Self {
        Self::new()
    }
}

impl Engine {
    pub fn new() -> Engine {
        Engine {
            act_a: Vec::new(),
            act_b: Vec::new(),
            patches: Vec::new(),
            wq: Vec::new(),
            branch_out: Vec::new(),
            exec: ExecScratch::default(),
            gemm_threads: 0,
            prof: None,
        }
    }

    /// Toggle per-layer span profiling (`SessionOptions.profile`,
    /// `repro eval --profile`).  Off is the default and costs nothing.
    pub fn set_profiling(&mut self, on: bool) {
        self.prof = if on { Some(Vec::new()) } else { None };
    }

    /// Drain the spans the last forward recorded (empty when profiling
    /// is off).  Callers wrap them into an
    /// [`crate::obs::ForwardProfile`] with their own end-to-end timer.
    pub fn take_spans(&mut self) -> Vec<LayerSpan> {
        self.prof.as_mut().map(std::mem::take).unwrap_or_default()
    }

    /// Configure intra-forward GEMM row parallelism (`0`/`1` = serial;
    /// the `--gemm-threads` flag).  Only staged-tier GEMMs with at
    /// least `GEMM_PAR_MIN_M` rows split — small GEMMs and the packed
    /// kernels (which own mutable scratch) stay serial.
    pub fn set_gemm_threads(&mut self, threads: usize) {
        self.gemm_threads = threads;
    }

    /// Run the network on a batch `x` of shape (B, H, W, C) under a
    /// resolved per-layer quantizer table; returns logits (B, classes).
    /// `store` is the shared [`WeightStore`] staged weights are read
    /// from (`None`, or a miss the budget cannot admit, falls back to
    /// the scratch staging pass — bit-identical by construction).
    pub fn forward(
        &mut self,
        net: &Network,
        x: &Tensor,
        table: &QuantTable,
        store: Option<&WeightStore>,
    ) -> Tensor {
        let t = self.forward_prefix(net, x, table, net.layers.len(), store);
        assert_eq!(
            t.shape().len(),
            2,
            "network must end with a dense layer (got shape {:?})",
            t.shape()
        );
        assert_eq!(t.shape()[1], net.classes);
        t
    }

    /// Run only the first `n_layers` layers; returns the intermediate
    /// activation tensor ((B,H,W,C) or (B,F)).  Used by the Fig 8
    /// accumulation study to tap a convolution's input.  Layer
    /// quantizers come from the table's full-network resolution, so a
    /// prefix run quantizes each executed layer exactly as the full
    /// forward would.
    pub fn forward_prefix(
        &mut self,
        net: &Network,
        x: &Tensor,
        table: &QuantTable,
        n_layers: usize,
        store: Option<&WeightStore>,
    ) -> Tensor {
        let shape = x.shape();
        assert_eq!(shape.len(), 4, "input must be (B, H, W, C)");
        assert_eq!(&shape[1..], &net.input, "input shape mismatch");
        assert_eq!(
            table.per_layer.len(),
            net.layers.len(),
            "quantizer table resolved against a different network"
        );
        let b = shape[0];
        let mut cur = ActShape::Hwc(b, net.input[0], net.input[1], net.input[2]);
        if let Some(spans) = &mut self.prof {
            spans.clear();
        }

        // stage input into act_a, quantized as the first GEMM's operand
        // (monomorphized q_slice via the dispatcher)
        self.act_a.clear();
        self.act_a.extend_from_slice(x.data());
        quantize_slice(&mut self.act_a, &table.input);

        for (layer, lq) in net.layers.iter().zip(&table.per_layer).take(n_layers) {
            cur = self.apply_layer(net, layer, cur, lq, store);
        }

        let (shape, n) = match cur {
            ActShape::Hwc(b, h, w, c) => (vec![b, h, w, c], b * h * w * c),
            ActShape::Flat(b, f) => (vec![b, f], b * f),
        };
        Tensor::new(shape, self.act_a[..n].to_vec()).unwrap()
    }

    /// Apply one layer reading from `act_a`, leaving the result in
    /// `act_a`.  `lq` is the layer's entry in the resolved quantizer
    /// table (per-branch for inception).
    fn apply_layer(
        &mut self,
        net: &Network,
        layer: &Layer,
        cur: ActShape,
        lq: &LayerQuant,
        store: Option<&WeightStore>,
    ) -> ActShape {
        match layer {
            Layer::Conv { .. } => {
                let LayerQuant::One(q) = lq else {
                    panic!("conv layer with branch quantizers");
                };
                let out = self.conv(net, layer, cur, q, store);
                std::mem::swap(&mut self.act_a, &mut self.act_b);
                out
            }
            Layer::Dense { name, in_dim, out_dim } => {
                let LayerQuant::One(lq) = lq else {
                    panic!("dense layer with branch quantizers");
                };
                let ActShape::Flat(b, f) = cur else {
                    panic!("dense after non-flat activation");
                };
                assert_eq!(f, *in_dim, "dense {name}: input dim mismatch");
                let w = net.weight(&format!("{name}.w"));
                let bias = net.weight(&format!("{name}.b"));
                // staged weights come from the store (lock-free when the
                // cached lease validates), the network itself
                // (identity-direct), or — on a miss the budget cannot
                // admit — the scratch staging fallback
                let cached = lq.staged_entry(store, w.data());
                let t0 = self.prof.as_ref().map(|_| Instant::now());
                resize(&mut self.act_b, b * out_dim);
                match (&lq.packed, &cached) {
                    // packed-domain execution: the MAC loop reads the
                    // store's bit-packed codes; bias is fused into the
                    // kernel epilogue (bit-exact to gemm_q + add_bias_q
                    // by the router's admission rules)
                    (PackedPlan::Int(op), Some(entry)) => {
                        with_packed_op!(op, o => gemm_packed_int(
                            &self.act_a[..b * f],
                            entry.packed(),
                            Some(bias.data()),
                            &mut self.act_b,
                            b,
                            *in_dim,
                            *out_dim,
                            o,
                            &mut self.exec,
                        ));
                    }
                    (PackedPlan::Lut(lut), Some(entry)) => {
                        with_quant_op!(&lq.q, op => gemm_packed_lut(
                            &self.act_a[..b * f],
                            entry.packed(),
                            lut,
                            Some(bias.data()),
                            &mut self.act_b,
                            b,
                            *in_dim,
                            *out_dim,
                            op,
                            &mut self.exec,
                        ));
                    }
                    // staged f32 tier: planned, or a packed layer whose
                    // store entry the budget could not admit.  Weights
                    // stage under the WEIGHT half; the chain below runs
                    // under the activation half.
                    _ => {
                        if cached.is_none() && !matches!(lq.staging, Staging::Direct) {
                            self.stage_quantized_weights(w.data(), &lq.wq);
                        }
                        let wq: &[f32] = match (&lq.staging, &cached) {
                            (Staging::Direct, _) => w.data(),
                            (_, Some(entry)) => entry.quantized(),
                            _ => &self.wq,
                        };
                        // one dispatch selects the layer's monomorphized
                        // kernels
                        with_quant_op!(&lq.q, op => {
                            gemm_q_rows(
                                &self.act_a[..b * f],
                                wq,
                                &mut self.act_b,
                                b,
                                *in_dim,
                                *out_dim,
                                op,
                                self.gemm_threads,
                            );
                            add_bias_q(&mut self.act_b, bias.data(), b, *out_dim, op);
                        });
                    }
                }
                if let Some(t0) = t0 {
                    let wall_s = t0.elapsed().as_secs_f64();
                    let lane = executed_lane(&lq.packed, cached.is_some());
                    let clamps = clamp_count(&self.act_b[..b * out_dim], &lq.q, &lq.pair.a);
                    if let Some(spans) = &mut self.prof {
                        spans.push(LayerSpan {
                            name: name.clone(),
                            lane: lane.to_string(),
                            wall_s,
                            macs: (b * in_dim * out_dim) as u64,
                            clamps,
                        });
                    }
                }
                std::mem::swap(&mut self.act_a, &mut self.act_b);
                ActShape::Flat(b, *out_dim)
            }
            Layer::Relu => {
                for v in self.act_a[..cur.len()].iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
                cur
            }
            Layer::MaxPool { k, stride, pad } => {
                let ActShape::Hwc(b, h, w, c) = cur else {
                    panic!("maxpool on flat activation");
                };
                let (oh, ow) = out_hw(h, w, *k, *stride, *pad);
                resize(&mut self.act_b, b * oh * ow * c);
                maxpool(
                    &self.act_a, &mut self.act_b, b, h, w, c, *k, *stride, *pad, oh, ow,
                );
                std::mem::swap(&mut self.act_a, &mut self.act_b);
                ActShape::Hwc(b, oh, ow, c)
            }
            Layer::Flatten => {
                let ActShape::Hwc(b, h, w, c) = cur else {
                    panic!("flatten on flat activation");
                };
                // NHWC row-major is already the flattened layout
                ActShape::Flat(b, h * w * c)
            }
            Layer::GAvgPool => {
                let ActShape::Hwc(b, h, w, c) = cur else {
                    panic!("gavgpool on flat activation");
                };
                // unnamed quantized op: runs in the inherited
                // downstream format (QuantTable docs)
                let LayerQuant::One(lq) = lq else {
                    panic!("gavgpool with branch quantizers");
                };
                resize(&mut self.act_b, b * c);
                with_quant_op!(&lq.q, op => {
                    gavgpool_q(&self.act_a, &mut self.act_b, b, h, w, c, op)
                });
                std::mem::swap(&mut self.act_a, &mut self.act_b);
                ActShape::Flat(b, c)
            }
            Layer::Inception { .. } => {
                let ActShape::Hwc(b, h, w, c) = cur else {
                    panic!("inception on flat activation");
                };
                let LayerQuant::Branches(qs) = lq else {
                    panic!("inception layer without branch quantizers");
                };
                let branches = layer.inception_branches();
                assert_eq!(qs.len(), branches.len(), "branch quantizer arity");
                let out_ch: usize = branches
                    .iter()
                    .map(|br| match br {
                        Layer::Conv { out_ch, .. } => *out_ch,
                        _ => 0,
                    })
                    .sum();
                // run each branch; concatenate along channels into branch_out
                resize(&mut self.branch_out, b * h * w * out_ch);
                let mut ch_off = 0;
                let mut saved_input: Vec<f32> = self.act_a[..b * h * w * c].to_vec();
                for (bi, br) in branches.iter().enumerate() {
                    // restore the module input for every branch after the first
                    if bi > 0 {
                        self.act_a[..b * h * w * c].copy_from_slice(&saved_input);
                    }
                    let is_proj = matches!(br, Layer::Conv { name, .. } if name.ends_with(".proj"));
                    let mut bshape = ActShape::Hwc(b, h, w, c);
                    if is_proj {
                        // pool branch: maxpool 3x3 s1 p1 first
                        let (oh, ow) = out_hw(h, w, 3, 1, 1);
                        resize(&mut self.act_b, b * oh * ow * c);
                        maxpool(&self.act_a, &mut self.act_b, b, h, w, c, 3, 1, 1, oh, ow);
                        std::mem::swap(&mut self.act_a, &mut self.act_b);
                        bshape = ActShape::Hwc(b, oh, ow, c);
                    }
                    let out = self.conv(net, br, bshape, &qs[bi], store);
                    let ActShape::Hwc(_, boh, bow, bc) = out else { unreachable!() };
                    assert_eq!((boh, bow), (h, w), "inception branches must preserve HxW");
                    // scatter branch channels into the concat buffer
                    for p in 0..b * h * w {
                        let src = &self.act_b[p * bc..(p + 1) * bc];
                        let dst = &mut self.branch_out[p * out_ch + ch_off..p * out_ch + ch_off + bc];
                        dst.copy_from_slice(src);
                    }
                    ch_off += bc;
                }
                saved_input.clear();
                std::mem::swap(&mut self.act_a, &mut self.branch_out);
                ActShape::Hwc(b, h, w, out_ch)
            }
        }
    }

    /// Conv via im2col + quantized GEMM.  Reads `act_a`, writes `act_b`
    /// (does NOT swap — callers decide).  Returns the output shape.
    fn conv(
        &mut self,
        net: &Network,
        layer: &Layer,
        cur: ActShape,
        lq: &LayerQ,
        store: Option<&WeightStore>,
    ) -> ActShape {
        let Layer::Conv { name, kh, kw, in_ch, out_ch, stride, pad } = layer else {
            panic!("conv() on non-conv layer");
        };
        let ActShape::Hwc(b, h, w, c) = cur else {
            panic!("conv on flat activation");
        };
        assert_eq!(c, *in_ch, "conv {name}: channel mismatch");
        let (oh, ow) = out_hw(h, w, *kh, *stride, *pad);
        let k_dim = kh * kw * in_ch;
        let m = b * oh * ow;

        resize(&mut self.patches, m * k_dim);
        im2col(
            &self.act_a, &mut self.patches, b, h, w, c, *kh, *kw, *stride, *pad, oh, ow,
        );

        let wt = net.weight(&format!("{name}.w"));
        let bdata = net.weight(&format!("{name}.b")).data();
        // staged weights by reference (store / identity-direct), with
        // scratch staging as the miss fallback — see the Dense arm
        let cached = lq.staged_entry(store, wt.data());
        let t0 = self.prof.as_ref().map(|_| Instant::now());
        resize(&mut self.act_b, m * out_ch);
        match (&lq.packed, &cached) {
            // packed-domain execution over the im2col patches — see the
            // Dense arm for the contract
            (PackedPlan::Int(op), Some(entry)) => {
                with_packed_op!(op, o => gemm_packed_int(
                    &self.patches,
                    entry.packed(),
                    Some(bdata),
                    &mut self.act_b,
                    m,
                    k_dim,
                    *out_ch,
                    o,
                    &mut self.exec,
                ));
            }
            (PackedPlan::Lut(lut), Some(entry)) => {
                with_quant_op!(&lq.q, op => gemm_packed_lut(
                    &self.patches,
                    entry.packed(),
                    lut,
                    Some(bdata),
                    &mut self.act_b,
                    m,
                    k_dim,
                    *out_ch,
                    op,
                    &mut self.exec,
                ));
            }
            _ => {
                if cached.is_none() && !matches!(lq.staging, Staging::Direct) {
                    // weight half stages the constant tensor; the MAC
                    // chain below dispatches on the activation half
                    self.stage_quantized_weights(wt.data(), &lq.wq);
                }
                let wq: &[f32] = match (&lq.staging, &cached) {
                    (Staging::Direct, _) => wt.data(),
                    (_, Some(entry)) => entry.quantized(),
                    _ => &self.wq,
                };
                // one dispatch selects the layer's monomorphized kernels
                with_quant_op!(&lq.q, op => {
                    gemm_q_rows(
                        &self.patches,
                        wq,
                        &mut self.act_b,
                        m,
                        k_dim,
                        *out_ch,
                        op,
                        self.gemm_threads,
                    );
                    add_bias_q(&mut self.act_b, bdata, m, *out_ch, op);
                });
            }
        }
        if let Some(t0) = t0 {
            let wall_s = t0.elapsed().as_secs_f64();
            let lane = executed_lane(&lq.packed, cached.is_some());
            let clamps = clamp_count(&self.act_b[..m * out_ch], &lq.q, &lq.pair.a);
            if let Some(spans) = &mut self.prof {
                spans.push(LayerSpan {
                    name: name.clone(),
                    lane: lane.to_string(),
                    wall_s,
                    macs: (m * k_dim * out_ch) as u64,
                    clamps,
                });
            }
        }
        ActShape::Hwc(b, oh, ow, *out_ch)
    }

    /// The store-miss fallback: quantize-and-copy into the scratch `wq`
    /// buffer — the pre-store staging pass, retained so a budget the
    /// store cannot admit an entry under degrades to correct
    /// (bit-identical) re-staging, never to an error.
    fn stage_quantized_weights(&mut self, w: &[f32], q: &Quantizer) {
        self.wq.clear();
        self.wq.extend_from_slice(w);
        quantize_slice(&mut self.wq, q);
    }
}

fn resize(buf: &mut Vec<f32>, n: usize) {
    buf.clear();
    buf.resize(n, 0.0);
}

/// The lane a layer ACTUALLY executed this forward: the router's
/// assignment when its store entry was available, the staged fallback
/// otherwise (a packed plan without its packed bytes degrades to the
/// staged tier — see the Dense arm).  With the store warm this is
/// exactly [`PackedPlan::label`], which `tests/obs_contract.rs` pins
/// against [`QuantTable::packed_labels`].
fn executed_lane(plan: &PackedPlan, staged_hit: bool) -> &'static str {
    if staged_hit {
        plan.label()
    } else {
        PackedPlan::Staged.label()
    }
}

/// Count output activations at or beyond the activation format's
/// representable magnitude — the per-forward generalization of
/// `numerics::trace::AccumTrace::first_saturation` (same threshold).
/// Identity-quantized outputs are exact f32 and never clamp.  Runs only
/// under the profiler (`Engine::set_profiling`), so forwards with
/// profiling off never touch it.
fn clamp_count(y: &[f32], q: &Quantizer, fmt: &Format) -> u64 {
    if q.is_identity() {
        return 0;
    }
    let max = fmt.max_value() as f32;
    y.iter().filter(|v| v.abs() >= max).count() as u64
}

fn out_hw(h: usize, w: usize, k: usize, stride: usize, pad: usize) -> (usize, usize) {
    ((h + 2 * pad - k) / stride + 1, (w + 2 * pad - k) / stride + 1)
}

/// NHWC im2col with zero padding; patch index ((ki*kw + kj)*C + c).
#[allow(clippy::too_many_arguments)]
fn im2col(
    x: &[f32],
    out: &mut [f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    kh: usize,
    kw: usize,
    stride: usize,
    pad: usize,
    oh: usize,
    ow: usize,
) {
    let k_dim = kh * kw * c;
    for bi in 0..b {
        let xb = &x[bi * h * w * c..(bi + 1) * h * w * c];
        for oy in 0..oh {
            for ox in 0..ow {
                let row = &mut out[((bi * oh + oy) * ow + ox) * k_dim..][..k_dim];
                for ki in 0..kh {
                    let iy = (oy * stride + ki) as isize - pad as isize;
                    for kj in 0..kw {
                        let ix = (ox * stride + kj) as isize - pad as isize;
                        let dst = &mut row[(ki * kw + kj) * c..][..c];
                        if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                            let src = &xb[(iy as usize * w + ix as usize) * c..][..c];
                            dst.copy_from_slice(src);
                        } else {
                            dst.fill(0.0);
                        }
                    }
                }
            }
        }
    }
}

/// Rows of A processed together per tile.  Each output element's MAC
/// chain is a serial dependence of ~the full quantizer latency per k
/// step; interleaving `GEMM_MR` independent rows inside the k loop keeps
/// that many chains in flight, which is where the blocked kernel beats
/// the naive one at the small-N GEMM shapes the seed networks produce
/// (conv out_ch 16..64, dense out_dim 10..512).
const GEMM_MR: usize = 8;
/// Output columns per tile: the out tile (`GEMM_MR * GEMM_NC` floats)
/// and one W row stay L1-resident across the whole k loop.
const GEMM_NC: usize = 64;
/// Fixed inner-lane width of the [`gemm_q`] n loop (divides `GEMM_NC`,
/// so full tiles have no remainder).  The lane loop advances `GEMM_LANES`
/// *independent* chains one k step in lockstep over plain arrays — the
/// array-of-lanes layout stable-Rust auto-vectorization needs; each
/// lane's op sequence is exactly the scalar `q(o + q(a*w))`, so bits
/// are untouched (DESIGN.md §Perf).
const GEMM_LANES: usize = 8;
/// Minimum M (GEMM rows) before [`gemm_q_rows`] splits across pool
/// workers — below this the queue/join overhead beats the win (the
/// seed nets' conv GEMMs at batch 32 are 3k–25k rows; dense layers
/// are `M = batch` and stay serial).
const GEMM_PAR_MIN_M: usize = 256;

/// Per-op-truncated GEMM: out[m][n] = chain_k q(acc + q(a[m][k] * w[k][n])).
/// Row-major A (M,K), W (K,N), out (M,N).
///
/// This is THE sweep hot path, so it is cache-blocked over M and N
/// (DESIGN.md §4) **and monomorphized over the quantization op** `Q`:
/// callers dispatch once per GEMM via [`crate::with_quant_op!`], so the
/// instantiation for `QFloat` / `QFixed` / `QIdentity` contains that
/// kind's arithmetic only — no per-MAC kind branch, no dead constants,
/// and an inner loop the compiler can autovectorize.  The old
/// `is_identity` runtime fast path is now just the `QIdentity`
/// instantiation: it keeps the flush-to-zero and ±inf-saturation steps
/// (normal operands can cancel into the subnormal window mid-chain), so
/// bit-exactness with the Pallas/PJRT contract holds unconditionally
/// (`single_fast_path_is_bitexact_even_off_normal_range`).
///
/// The k loop stays **strictly serial in increasing k per output
/// element** — that ordering is the bit-exactness contract (module
/// header; DESIGN.md §3) and the reason K is never tiled out of order.
/// Tiling M/N only regroups *independent* chains, so every
/// instantiation is bit-identical to the scalar [`gemm_q_naive`]
/// reference (property test below; ratio re-measured by the `hot_paths`
/// bench and recorded in the `BENCH_*.json` trajectory).
pub fn gemm_q<Q: QuantOp>(
    a: &[f32],
    w: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    q: &Q,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for n0 in (0..n).step_by(GEMM_NC) {
        let n1 = (n0 + GEMM_NC).min(n);
        for m0 in (0..m).step_by(GEMM_MR) {
            let m1 = (m0 + GEMM_MR).min(m);
            for mi in m0..m1 {
                out[mi * n + n0..mi * n + n1].fill(0.0);
            }
            for ki in 0..k {
                let wrow = &w[ki * n + n0..ki * n + n1];
                for mi in m0..m1 {
                    let av = a[mi * k + ki];
                    let orow = &mut out[mi * n + n0..mi * n + n1];
                    // array-of-lanes inner loop (`GEMM_LANES` chains per
                    // step over fixed-width arrays): same per-element op
                    // sequence as the scalar zip, restructured so the
                    // monomorphized, branch-minimal `q.q` bodies
                    // auto-vectorize on stable Rust
                    let mut oc = orow.chunks_exact_mut(GEMM_LANES);
                    let mut wc = wrow.chunks_exact(GEMM_LANES);
                    for (ol, wl) in (&mut oc).zip(&mut wc) {
                        let mut prod = [0f32; GEMM_LANES];
                        for j in 0..GEMM_LANES {
                            prod[j] = q.q(av * wl[j]);
                        }
                        for j in 0..GEMM_LANES {
                            ol[j] = q.q(ol[j] + prod[j]);
                        }
                    }
                    for (o, &wv) in oc.into_remainder().iter_mut().zip(wc.remainder()) {
                        *o = q.q(*o + q.q(av * wv));
                    }
                }
            }
        }
    }
}

/// [`gemm_q`] with optional intra-forward row parallelism.  Rows of A
/// are **independent** per-element k chains, so splitting M across
/// `coordinator::pool` workers regroups whole chains without touching
/// any chain's internal order — every split is bit-identical to the
/// serial call by construction (each output element still runs
/// `q(acc + q(a·w))` over increasing k from a zero accumulator;
/// DESIGN.md §Perf).  Serial for `threads <= 1` or below the
/// [`GEMM_PAR_MIN_M`] row floor; row chunks are `GEMM_MR`-aligned so
/// every worker's tile boundaries match the serial kernel's.
#[allow(clippy::too_many_arguments)]
fn gemm_q_rows<Q: QuantOp + Sync>(
    a: &[f32],
    w: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    q: &Q,
    threads: usize,
) {
    if threads <= 1 || m < GEMM_PAR_MIN_M {
        return gemm_q(a, w, out, m, k, n, q);
    }
    let rows_per = (((m + threads - 1) / threads) + GEMM_MR - 1) / GEMM_MR * GEMM_MR;
    crate::coordinator::pool::run_sliced(&mut out[..m * n], rows_per * n, threads, |start, chunk| {
        let r0 = start / n;
        let rows = chunk.len() / n;
        gemm_q(&a[r0 * k..(r0 + rows) * k], w, chunk, rows, k, n, q);
    });
}

/// The retained naive triple loop over the scalar [`Quantizer::q`]
/// reference — the readable baseline every monomorphized `gemm_q::<Q>`
/// instantiation is verified bit-exact against (same per-element k
/// chain; deliberately NOT generic, so it always exercises the
/// enum-dispatching scalar path).
pub fn gemm_q_naive(
    a: &[f32],
    w: &[f32],
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    q: &Quantizer,
) {
    debug_assert_eq!(a.len(), m * k);
    debug_assert_eq!(w.len(), k * n);
    debug_assert_eq!(out.len(), m * n);
    for mi in 0..m {
        let arow = &a[mi * k..(mi + 1) * k];
        let orow = &mut out[mi * n..(mi + 1) * n];
        orow.fill(0.0);
        for ki in 0..k {
            let av = arow[ki];
            let wrow = &w[ki * n..(ki + 1) * n];
            for ni in 0..n {
                orow[ni] = q.q(orow[ni] + q.q(av * wrow[ni]));
            }
        }
    }
}

/// One rounded bias add per output element: y = q(y + q(b)).
/// Monomorphized like [`gemm_q`] (dispatched together with it).
fn add_bias_q<Q: QuantOp>(y: &mut [f32], bias: &[f32], m: usize, n: usize, q: &Q) {
    debug_assert_eq!(bias.len(), n);
    // bias is quantized once (it is a stored parameter)
    let mut bq = [0f32; 512];
    assert!(n <= bq.len(), "bias wider than staging buffer");
    for (i, &b) in bias.iter().enumerate() {
        bq[i] = q.q(b);
    }
    for mi in 0..m {
        let row = &mut y[mi * n..(mi + 1) * n];
        for ni in 0..n {
            row[ni] = q.q(row[ni] + bq[ni]);
        }
    }
}

/// Max pooling with zero padding (activations are post-relu, so the
/// zero pad never wins spuriously in our networks; same choice as the
/// JAX side).
#[allow(clippy::too_many_arguments)]
fn maxpool(
    x: &[f32],
    out: &mut [f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    k: usize,
    stride: usize,
    pad: usize,
    oh: usize,
    ow: usize,
) {
    for bi in 0..b {
        let xb = &x[bi * h * w * c..(bi + 1) * h * w * c];
        for oy in 0..oh {
            for ox in 0..ow {
                let dst = &mut out[((bi * oh + oy) * ow + ox) * c..][..c];
                let mut first = true;
                for ki in 0..k {
                    let iy = (oy * stride + ki) as isize - pad as isize;
                    for kj in 0..k {
                        let ix = (ox * stride + kj) as isize - pad as isize;
                        let inside =
                            iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w;
                        if inside {
                            let src = &xb[(iy as usize * w + ix as usize) * c..][..c];
                            if first {
                                dst.copy_from_slice(src);
                            } else {
                                for ci in 0..c {
                                    if src[ci] > dst[ci] {
                                        dst[ci] = src[ci];
                                    }
                                }
                            }
                        } else if first {
                            dst.fill(0.0);
                        } else {
                            for v in dst.iter_mut() {
                                if 0.0 > *v {
                                    *v = 0.0;
                                }
                            }
                        }
                        first = false;
                    }
                }
            }
        }
    }
}

/// Global average pool with the serial per-add-rounded adder chain over
/// row-major spatial positions, then one rounded multiply by q(1/HW).
/// Monomorphized like [`gemm_q`].
fn gavgpool_q<Q: QuantOp>(
    x: &[f32],
    out: &mut [f32],
    b: usize,
    h: usize,
    w: usize,
    c: usize,
    q: &Q,
) {
    let hw = h * w;
    let inv = q.q(1.0 / hw as f32);
    for bi in 0..b {
        let xb = &x[bi * hw * c..(bi + 1) * hw * c];
        let dst = &mut out[bi * c..(bi + 1) * c];
        dst.fill(0.0);
        for p in 0..hw {
            let src = &xb[p * c..(p + 1) * c];
            for ci in 0..c {
                dst[ci] = q.q(dst[ci] + src[ci]);
            }
        }
        for v in dst.iter_mut() {
            *v = q.q(*v * inv);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Format;

    fn q_exact() -> Quantizer {
        Quantizer::new(&Format::SINGLE)
    }

    /// Run the monomorphized instantiation `q` selects — exactly the
    /// dispatch the engine's layers perform.
    fn gemm_dispatch(
        a: &[f32],
        w: &[f32],
        out: &mut [f32],
        m: usize,
        k: usize,
        n: usize,
        q: &Quantizer,
    ) {
        with_quant_op!(q, op => gemm_q(a, w, out, m, k, n, op));
    }

    #[test]
    fn gemm_q_exact_matches_serial_matmul() {
        let m = 3;
        let k = 5;
        let n = 4;
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.7).sin()).collect();
        let w: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.3).cos()).collect();
        let mut out = vec![0.0; m * n];
        gemm_dispatch(&a, &w, &mut out, m, k, n, &q_exact());
        for mi in 0..m {
            for ni in 0..n {
                let mut acc = 0.0f32;
                for ki in 0..k {
                    acc += a[mi * k + ki] * w[ki * n + ni];
                }
                assert_eq!(out[mi * n + ni], acc);
            }
        }
    }

    #[test]
    fn gemm_q_saturates_like_dot_q() {
        use crate::numerics::dot_q;
        let qz = Quantizer::new(&Format::fixed(4, 4));
        let k = 64;
        let a = vec![1.0f32; k];
        let w = vec![1.0f32; k];
        let mut out = vec![0.0; 1];
        gemm_dispatch(&a, &w, &mut out, 1, k, 1, &qz);
        assert_eq!(out[0], dot_q(&a, &w, &qz));
        assert_eq!(out[0], 16.0 - 1.0 / 16.0);
    }

    /// Deterministic ragged-tile check: shapes that straddle both the
    /// `GEMM_MR` and `GEMM_NC` boundaries must agree bitwise with the
    /// naive reference.
    #[test]
    fn blocked_matches_naive_on_ragged_tiles() {
        let (m, k, n) = (GEMM_MR + 1, 19, GEMM_NC + 3);
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.19).sin()).collect();
        let w: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.41).cos()).collect();
        for fmt in [Format::float(5, 5), Format::fixed(3, 6), Format::SINGLE] {
            let q = Quantizer::new(&fmt);
            let mut blocked = vec![0.0; m * n];
            let mut naive = vec![7.0; m * n]; // nonzero: fill must overwrite
            gemm_dispatch(&a, &w, &mut blocked, m, k, n, &q);
            gemm_q_naive(&a, &w, &mut naive, m, k, n, &q);
            for i in 0..m * n {
                assert_eq!(blocked[i].to_bits(), naive[i].to_bits(), "{fmt} elem {i}");
            }
        }
    }

    /// The `QIdentity` fast path keeps the flush/saturate steps, so it
    /// is bit-exact with the reference even when values *leave* the
    /// normal f32 range — a raw subnormal product, and the subtler case
    /// of two normal partial sums cancelling into the subnormal window,
    /// where a plain mul-add chain would silently diverge from the
    /// Pallas/PJRT contract.
    #[test]
    fn single_fast_path_is_bitexact_even_off_normal_range() {
        let q = Quantizer::new(&Format::SINGLE);
        assert!(q.is_identity(), "SINGLE must select the QIdentity instantiation");
        // subnormal product (1e-40 is a representable f32 subnormal)
        let (a, w) = (vec![1.0e-30f32], vec![1.0e-10f32]);
        let (mut fast, mut reference) = (vec![7.0f32], vec![7.0f32]);
        gemm_dispatch(&a, &w, &mut fast, 1, 1, 1, &q);
        gemm_q_naive(&a, &w, &mut reference, 1, 1, 1, &q);
        assert_eq!(reference[0], 0.0, "reference must flush the subnormal");
        assert_eq!(fast[0].to_bits(), reference[0].to_bits());
        // cancellation: normal acc + normal product -> subnormal sum
        let (a, w) = (vec![1.0f32, 1.0], vec![1.2e-38f32, -1.19e-38]);
        let (mut fast, mut reference) = (vec![7.0f32], vec![7.0f32]);
        gemm_dispatch(&a, &w, &mut fast, 1, 2, 1, &q);
        gemm_q_naive(&a, &w, &mut reference, 1, 2, 1, &q);
        assert_eq!(reference[0], 0.0, "cancellation result must flush");
        assert_eq!(fast[0].to_bits(), reference[0].to_bits());
        // normal-range chain: still bit-equal
        let (a, w) = (vec![f32::MIN_POSITIVE, -3.5], vec![2.0f32, 0.25]);
        let (mut fast, mut reference) = (vec![7.0f32], vec![7.0f32]);
        gemm_dispatch(&a, &w, &mut fast, 1, 2, 1, &q);
        gemm_q_naive(&a, &w, &mut reference, 1, 2, 1, &q);
        assert_eq!(fast[0].to_bits(), reference[0].to_bits());
    }

    /// The kernel-equivalence property test (ISSUE 1, extended by
    /// ISSUE 4): every monomorphized `gemm_q::<Q>` instantiation —
    /// reached through the same `with_quant_op!` dispatch the engine
    /// uses — is bit-exact against the retained naive reference over
    /// the scalar `Quantizer::q`, across random shapes and random
    /// float/fixed formats, including the `QIdentity`/`Format::SINGLE`
    /// fast path (the shared `arb_format` generator always draws it).
    /// The dynamic `gemm_q::<Quantizer>` fallback is pinned to the same
    /// bits while we're here.
    #[test]
    fn prop_monomorphized_gemm_bitexact_vs_scalar_naive() {
        use crate::testing::prop::{arb_format, run_prop};
        run_prop("mono_gemm_matches_scalar_naive", 60, |g| {
            let m = g.usize_in(1, 2 * GEMM_MR + 3);
            let k = g.usize_in(1, 48);
            let n = g.usize_in(1, GEMM_NC + 9);
            let fmt = arb_format(g);
            let q = Quantizer::new(&fmt);
            let a: Vec<f32> = (0..m * k).map(|_| g.f32_normal()).collect();
            let w: Vec<f32> = (0..k * n).map(|_| g.f32_normal()).collect();
            let mut blocked = vec![0.0; m * n];
            let mut naive = vec![0.0; m * n];
            let mut dynamic = vec![0.0; m * n];
            gemm_dispatch(&a, &w, &mut blocked, m, k, n, &q);
            gemm_q_naive(&a, &w, &mut naive, m, k, n, &q);
            gemm_q(&a, &w, &mut dynamic, m, k, n, &q); // Q = Quantizer fallback
            for i in 0..m * n {
                assert_eq!(
                    blocked[i].to_bits(),
                    naive[i].to_bits(),
                    "{fmt} m={m} k={k} n={n} elem {i}: {} vs {}",
                    blocked[i],
                    naive[i]
                );
                assert_eq!(
                    dynamic[i].to_bits(),
                    naive[i].to_bits(),
                    "{fmt} m={m} k={k} n={n} elem {i}: dynamic fallback diverged"
                );
            }
        });
    }

    /// Row-parallel GEMM is bit-identical to the serial kernel for any
    /// thread count: splitting M regroups whole (independent) chains,
    /// never the serial-k order inside one (ISSUE 8 tentpole b).
    #[test]
    fn row_parallel_gemm_is_bitexact_vs_serial() {
        let (m, k, n) = (GEMM_PAR_MIN_M + 11, 17, GEMM_NC + 5);
        let a: Vec<f32> = (0..m * k).map(|i| (i as f32 * 0.113).sin()).collect();
        let w: Vec<f32> = (0..k * n).map(|i| (i as f32 * 0.271).cos()).collect();
        for fmt in [Format::float(5, 5), Format::fixed(4, 6), Format::SINGLE] {
            let q = Quantizer::new(&fmt);
            let mut serial = vec![0.0; m * n];
            with_quant_op!(&q, op => gemm_q(&a, &w, &mut serial, m, k, n, op));
            for threads in [2, 3, 8] {
                let mut par = vec![7.0; m * n];
                with_quant_op!(&q, op => gemm_q_rows(&a, &w, &mut par, m, k, n, op, threads));
                for i in 0..m * n {
                    assert_eq!(
                        par[i].to_bits(),
                        serial[i].to_bits(),
                        "{fmt} threads={threads} elem {i}"
                    );
                }
            }
            // below the row floor the wrapper must stay serial (and
            // therefore trivially bit-identical)
            let small_m = GEMM_PAR_MIN_M - 1;
            let mut small_serial = vec![0.0; small_m * n];
            let mut small_par = vec![0.0; small_m * n];
            let sa = &a[..small_m * k];
            with_quant_op!(&q, op => gemm_q(sa, &w, &mut small_serial, small_m, k, n, op));
            with_quant_op!(&q, op => gemm_q_rows(sa, &w, &mut small_par, small_m, k, n, op, 4));
            assert_eq!(small_par, small_serial);
        }
    }

    #[test]
    fn im2col_identity_kernel() {
        // 1x1 kernel, no pad: patches == input
        let (b, h, w, c) = (1, 2, 2, 3);
        let x: Vec<f32> = (0..b * h * w * c).map(|i| i as f32).collect();
        let mut p = vec![0.0; b * h * w * c];
        im2col(&x, &mut p, b, h, w, c, 1, 1, 1, 0, 2, 2);
        assert_eq!(p, x);
    }

    #[test]
    fn im2col_padding_and_order() {
        // 1 channel 2x2 input, 3x3 kernel, pad 1: center patch sees all
        let x = vec![1.0f32, 2.0, 3.0, 4.0]; // (1,2,2,1)
        let mut p = vec![0.0; 4 * 9];
        im2col(&x, &mut p, 1, 2, 2, 1, 3, 3, 1, 1, 2, 2);
        // output position (0,0): kernel rows cover pad; patch index (ki*3+kj)
        let p00 = &p[0..9];
        assert_eq!(p00, &[0.0, 0.0, 0.0, 0.0, 1.0, 2.0, 0.0, 3.0, 4.0]);
        let p11 = &p[3 * 9..4 * 9];
        assert_eq!(p11, &[1.0, 2.0, 0.0, 3.0, 4.0, 0.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn maxpool_2x2() {
        // (1, 2, 2, 1) -> (1, 1, 1, 1)
        let x = vec![1.0f32, 5.0, 3.0, 2.0];
        let mut o = vec![0.0; 1];
        maxpool(&x, &mut o, 1, 2, 2, 1, 2, 2, 0, 1, 1);
        assert_eq!(o[0], 5.0);
    }

    #[test]
    fn gavgpool_exact_mean() {
        let x = vec![1.0f32, 2.0, 3.0, 4.0]; // (1,2,2,1)
        let mut o = vec![0.0; 1];
        gavgpool_q(&x, &mut o, 1, 2, 2, 1, &q_exact());
        assert_eq!(o[0], 2.5);
    }
}
