//! Pure-Rust customized-precision inference engine.
//!
//! This is the repository's equivalent of the paper's modified Caffe: a
//! forward pass in which **every arithmetic operation is immediately
//! re-quantized** to the customized format (§3.1).  It interprets the
//! same layer specs the JAX model zoo exports to `artifacts/meta.json`
//! and matches the Pallas-kernel HLO path BIT-exactly (proved by the
//! `pjrt_cross_check` test), which is what makes it safe to use as the
//! fast sweep engine while the PJRT path serves requests.
//!
//! The scratch-buffer `Engine` itself is crate-private: every consumer
//! — offline sweeps and the request path alike — executes through
//! [`crate::serving::Backend`] (the one-substrate guarantee, DESIGN.md
//! §Serving), so `serving::NativeBackend` is the only constructor of
//! engines outside this module.

mod engine;
mod layers;
mod network;

pub(crate) use engine::Engine;
pub use engine::{gemm_q, gemm_q_naive, QuantTable};
pub use layers::Layer;
pub use network::{Network, Zoo};
