//! Layer specs — the Rust mirror of `python/compile/model.py`'s
//! JSON-able layer dictionaries (parsed from `artifacts/meta.json`).

use anyhow::{bail, Result};

use crate::util::json::Json;

#[derive(Clone, Debug, PartialEq)]
pub enum Layer {
    Conv {
        name: String,
        kh: usize,
        kw: usize,
        in_ch: usize,
        out_ch: usize,
        stride: usize,
        pad: usize,
    },
    Dense {
        name: String,
        in_dim: usize,
        out_dim: usize,
    },
    Relu,
    MaxPool {
        k: usize,
        stride: usize,
        pad: usize,
    },
    Flatten,
    GAvgPool,
    /// Mini inception: 1x1, 3x3, 5x5 and maxpool(3,1,1)+1x1 branches,
    /// channel-concatenated in that order (model.py `_inception_convs`).
    Inception {
        name: String,
        in_ch: usize,
        c1: usize,
        c3: usize,
        c5: usize,
        cp: usize,
    },
}

impl Layer {
    pub fn from_json(j: &Json) -> Result<Layer> {
        let op = j
            .req("op")?
            .as_str()
            .ok_or_else(|| anyhow::anyhow!("layer op must be a string"))?;
        let geti = |key: &str| -> Result<usize> {
            j.req(key)?
                .as_usize()
                .ok_or_else(|| anyhow::anyhow!("layer field {key:?} must be a number"))
        };
        let gets = |key: &str| -> Result<String> {
            Ok(j.req(key)?
                .as_str()
                .ok_or_else(|| anyhow::anyhow!("layer field {key:?} must be a string"))?
                .to_string())
        };
        Ok(match op {
            "conv" => Layer::Conv {
                name: gets("name")?,
                kh: geti("kh")?,
                kw: geti("kw")?,
                in_ch: geti("in_ch")?,
                out_ch: geti("out_ch")?,
                stride: geti("stride")?,
                pad: geti("pad")?,
            },
            "dense" => Layer::Dense {
                name: gets("name")?,
                in_dim: geti("in_dim")?,
                out_dim: geti("out_dim")?,
            },
            "relu" => Layer::Relu,
            "maxpool" => Layer::MaxPool {
                k: geti("k")?,
                stride: geti("stride")?,
                pad: geti("pad")?,
            },
            "flatten" => Layer::Flatten,
            "gavgpool" => Layer::GAvgPool,
            "inception" => Layer::Inception {
                name: gets("name")?,
                in_ch: geti("in_ch")?,
                c1: geti("c1")?,
                c3: geti("c3")?,
                c5: geti("c5")?,
                cp: geti("cp")?,
            },
            other => bail!("unknown layer op {other:?}"),
        })
    }

    /// The four branch convolutions of an inception module, in concat
    /// order (matches model.py `_inception_convs`).
    pub fn inception_branches(&self) -> Vec<Layer> {
        let Layer::Inception { name, in_ch, c1, c3, c5, cp } = self else {
            panic!("inception_branches on non-inception layer");
        };
        let conv = |suffix: &str, k: usize, out: usize| Layer::Conv {
            name: format!("{name}.{suffix}"),
            kh: k,
            kw: k,
            in_ch: *in_ch,
            out_ch: out,
            stride: 1,
            pad: (k - 1) / 2,
        };
        vec![
            conv("1x1", 1, *c1),
            conv("3x3", 3, *c3),
            conv("5x5", 5, *c5),
            conv("proj", 1, *cp),
        ]
    }

    /// MAC-chain length (dot-product K) of this layer, if it has one.
    pub fn chain_len(&self) -> Option<usize> {
        match self {
            Layer::Conv { kh, kw, in_ch, .. } => Some(kh * kw * in_ch),
            Layer::Dense { in_dim, .. } => Some(*in_dim),
            Layer::Inception { .. } => self
                .inception_branches()
                .iter()
                .filter_map(|b| b.chain_len())
                .max(),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_conv_from_json() {
        let j = Json::parse(
            r#"{"op":"conv","name":"c1","kh":5,"kw":5,"in_ch":3,"out_ch":16,"stride":1,"pad":2}"#,
        )
        .unwrap();
        let l = Layer::from_json(&j).unwrap();
        assert_eq!(
            l,
            Layer::Conv {
                name: "c1".into(),
                kh: 5,
                kw: 5,
                in_ch: 3,
                out_ch: 16,
                stride: 1,
                pad: 2
            }
        );
        assert_eq!(l.chain_len(), Some(75));
    }

    #[test]
    fn parses_simple_ops() {
        assert_eq!(
            Layer::from_json(&Json::parse(r#"{"op":"relu"}"#).unwrap()).unwrap(),
            Layer::Relu
        );
        assert_eq!(
            Layer::from_json(&Json::parse(r#"{"op":"flatten"}"#).unwrap()).unwrap(),
            Layer::Flatten
        );
        assert!(Layer::from_json(&Json::parse(r#"{"op":"warp"}"#).unwrap()).is_err());
    }

    #[test]
    fn inception_branch_expansion() {
        let j = Json::parse(
            r#"{"op":"inception","name":"inc1","in_ch":16,"c1":8,"c3":16,"c5":8,"cp":8}"#,
        )
        .unwrap();
        let l = Layer::from_json(&j).unwrap();
        let b = l.inception_branches();
        assert_eq!(b.len(), 4);
        match &b[2] {
            Layer::Conv { name, kh, pad, out_ch, .. } => {
                assert_eq!(name, "inc1.5x5");
                assert_eq!((*kh, *pad, *out_ch), (5, 2, 8));
            }
            _ => panic!(),
        }
        assert_eq!(l.chain_len(), Some(5 * 5 * 16));
    }
}
