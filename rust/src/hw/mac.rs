//! Analytic delay/area/power model of a multiply-accumulate unit.
//!
//! # Model
//!
//! **Float MAC** `F(m, e)` (significand width s = m+1):
//! * significand multiplier — partial-product array reduced by a
//!   Wallace/Dadda tree: area ∝ s², delay ∝ log₂(s) CSA levels plus a
//!   final carry-propagate adder over 2s bits (∝ log₂(2s));
//! * exponent path — small adders: area ∝ e, delay ∝ log₂(e);
//! * alignment barrel shifter (mantissa alignment before the add, the
//!   step the paper calls out in Fig 3): area ∝ s·log₂(s), delay ∝ log₂(s);
//! * significand adder (width ≈ 2s + guard): delay ∝ log₂(2s+2);
//! * LZA + normalization shifter: area ∝ s·log₂(s), delay ∝ log₂(s);
//! * rounding incrementer + flags: constant.
//!
//! **Fixed MAC** `X(l, r)` (word width n = 1+l+r): n×n array multiplier
//! (area ∝ n², delay ∝ log₂ n + log₂ 2n) + 2n-wide saturating
//! accumulator (area ∝ n, delay: constant saturation mux).
//!
//! Power tracks switched capacitance ≈ area (activity factors cancel in
//! normalization).
//!
//! # Calibration
//!
//! Constants are fixed by normalizing the IEEE single-precision MAC
//! (m=23, e=8) to delay = area = power = 1 and checking the paper's
//! anchors (asserted in tests, tolerances ±25%):
//! * F(7,6): speedup ≈ 7.2×, energy savings ≈ 3.4×   (paper §4.2)
//! * F(8,6): speedup ≈ 5.7×, energy savings ≈ 3.0×   (paper §4.2)
//! * fixed ≥ ~40 bits is *slower* than the SP-float baseline (paper §1
//!   finding 3 — the GoogLeNet fixed-vs-float argument)

use crate::formats::Format;

/// Relative delay/area/power of one MAC unit (1.0 = IEEE-754 single).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MacCost {
    pub delay: f64,
    pub area: f64,
    pub power: f64,
}

// ---- gate-level building blocks (unit: one FO4-ish gate delay / one
// unit cell of area; absolute units cancel in normalization) ----------

fn log2(x: f64) -> f64 {
    x.max(1.0).log2()
}

/// Wallace-tree multiplier of two w-bit operands.
fn mult_delay(w: f64) -> f64 {
    // CSA tree depth (3:2 compressors) + final CPA over 2w bits
    1.0 + 1.5 * log2(w) + 1.0 * log2(2.0 * w)
}

fn mult_area(w: f64) -> f64 {
    w * w + 2.0 * w * log2(w) // PP array + reduction wiring/CPA
}

/// Logarithmic carry-lookahead adder of width w.
fn add_delay(w: f64) -> f64 {
    1.0 + log2(w)
}

fn add_area(w: f64) -> f64 {
    2.0 * w
}

/// Barrel shifter over w positions.
fn shift_delay(w: f64) -> f64 {
    log2(w)
}

fn shift_area(w: f64) -> f64 {
    w * log2(w)
}

const ROUND_DELAY: f64 = 2.0; // rounding incrementer + sticky logic
const FLOAT_FIXED_OVERHEAD_AREA: f64 = 48.0; // flags, sign, control
const SAT_DELAY: f64 = 1.5; // fixed-point saturation mux
const SAT_AREA_PER_BIT: f64 = 1.0;

fn float_raw(m: u32, e: u32) -> (f64, f64) {
    let s = (m + 1) as f64; // significand incl. hidden bit
    let ew = e as f64;
    // delays along the MAC critical path (Fig 3c): multiply -> align ->
    // add -> normalize -> round, plus the exponent compare feeding align
    let delay = mult_delay(s)
        + shift_delay(s).max(add_delay(ew)) // align vs exponent path overlap
        + add_delay(2.0 * s + 2.0)
        + shift_delay(s)
        + ROUND_DELAY;
    let area = mult_area(s)
        + 2.0 * shift_area(s)            // align + normalize shifters
        + add_area(2.0 * s + 2.0)
        + 3.0 * add_area(ew)             // exponent add/sub/compare
        + FLOAT_FIXED_OVERHEAD_AREA;
    (delay, area)
}

fn fixed_raw(total_bits: u32) -> (f64, f64) {
    let n = total_bits as f64;
    let delay = mult_delay(n) + add_delay(2.0 * n) + SAT_DELAY;
    let area = mult_area(n) + add_area(2.0 * n) + SAT_AREA_PER_BIT * 2.0 * n;
    (delay, area)
}

fn baseline() -> (f64, f64) {
    float_raw(23, 8)
}

/// Relative critical-path delay (1.0 = SP float MAC).
pub fn delay(fmt: &Format) -> f64 {
    let (base_d, _) = baseline();
    let d = match *fmt {
        Format::Float { mantissa, exponent } => float_raw(mantissa, exponent).0,
        Format::Fixed { .. } => fixed_raw(fmt.total_bits()).0,
    };
    d / base_d
}

/// Relative silicon area (1.0 = SP float MAC).
pub fn area(fmt: &Format) -> f64 {
    let (_, base_a) = baseline();
    let a = match *fmt {
        Format::Float { mantissa, exponent } => float_raw(mantissa, exponent).1,
        Format::Fixed { .. } => fixed_raw(fmt.total_bits()).1,
    };
    a / base_a
}

/// Relative power ≈ switched capacitance ≈ area.
pub fn power(fmt: &Format) -> f64 {
    area(fmt)
}

/// All three at once.
pub fn cost(fmt: &Format) -> MacCost {
    MacCost {
        delay: delay(fmt),
        area: area(fmt),
        power: power(fmt),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::speedup::{energy_savings, speedup};

    #[test]
    fn baseline_is_unity() {
        let f = Format::SINGLE;
        assert!((delay(&f) - 1.0).abs() < 1e-12);
        assert!((area(&f) - 1.0).abs() < 1e-12);
        assert!((power(&f) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn monotone_in_mantissa() {
        // Fig 4: delay and area rise monotonically with mantissa width
        let mut last_d = 0.0;
        let mut last_a = 0.0;
        for m in 1..=23 {
            let f = Format::float(m, 8);
            assert!(delay(&f) > last_d, "delay not monotone at m={m}");
            assert!(area(&f) > last_a, "area not monotone at m={m}");
            last_d = delay(&f);
            last_a = area(&f);
        }
    }

    #[test]
    fn paper_anchor_f7e6() {
        // §4.2: F(7,6) => ~7.2x speedup, ~3.4x energy savings
        let f = Format::float(7, 6);
        let s = speedup(&f);
        let e = energy_savings(&f);
        assert!((5.4..=9.0).contains(&s), "speedup {s}");
        assert!((2.5..=4.3).contains(&e), "energy {e}");
    }

    #[test]
    fn paper_anchor_f8e6() {
        // §4.2: F(8,6) => ~5.7x speedup, ~3.0x energy savings
        let f = Format::float(8, 6);
        let s = speedup(&f);
        let e = energy_savings(&f);
        assert!((4.3..=7.2).contains(&s), "speedup {s}");
        assert!((2.2..=3.8).contains(&e), "energy {e}");
        assert!(s < speedup(&Format::float(7, 6)));
    }

    #[test]
    fn paper_anchor_wide_fixed_loses_to_sp_float() {
        // §1 finding 3: fixed-point at >= ~40 bits is more expensive
        // than the SP float baseline
        let f40 = Format::fixed(20, 19); // 40 bits
        assert!(speedup(&f40) < 1.0, "fixed-40 speedup {}", speedup(&f40));
        let f48 = Format::fixed(24, 23);
        assert!(speedup(&f48) < speedup(&f40));
    }

    #[test]
    fn fixed_beats_float_at_iso_multiplier_width() {
        // §2.1: "floating-point computation units are substantially
        // larger, slower, and more complex than integer units" — at the
        // same significand/word width, the float unit pays for shifters,
        // exponent logic and rounding that the integer unit does not.
        for n in [8u32, 12, 16, 24] {
            let fx = Format::fixed(n / 2, n - 1 - n / 2); // n-bit word
            let fl = Format::float(n - 1, 5); // (n)-bit significand
            assert_eq!(fx.total_bits(), n);
            assert!(
                delay(&fx) < delay(&fl) && area(&fx) < area(&fl),
                "fixed should win at word width {n}"
            );
        }
    }

    #[test]
    fn exponent_bits_cost_little_area() {
        // mantissa dominates (Fig 4's message)
        let a6 = area(&Format::float(10, 6));
        let a8 = area(&Format::float(10, 8));
        assert!((a8 - a6) / a6 < 0.05);
    }
}
