//! Analytic delay/area/power model of a multiply-accumulate unit.
//!
//! # Model
//!
//! **Float MAC** `F(m, e)` (significand width s = m+1):
//! * significand multiplier — partial-product array reduced by a
//!   Wallace/Dadda tree: area ∝ s², delay ∝ log₂(s) CSA levels plus a
//!   final carry-propagate adder over 2s bits (∝ log₂(2s));
//! * exponent path — small adders: area ∝ e, delay ∝ log₂(e);
//! * alignment barrel shifter (mantissa alignment before the add, the
//!   step the paper calls out in Fig 3): area ∝ s·log₂(s), delay ∝ log₂(s);
//! * significand adder (width ≈ 2s + guard): delay ∝ log₂(2s+2);
//! * LZA + normalization shifter: area ∝ s·log₂(s), delay ∝ log₂(s);
//! * rounding incrementer + flags: constant.
//!
//! **Fixed MAC** `X(l, r)` (word width n = 1+l+r): n×n array multiplier
//! (area ∝ n², delay ∝ log₂ n + log₂ 2n) + 2n-wide saturating
//! accumulator (area ∝ n, delay: constant saturation mux).
//!
//! Power tracks switched capacitance ≈ area (activity factors cancel in
//! normalization).
//!
//! # Calibration
//!
//! Constants are fixed by normalizing the IEEE single-precision MAC
//! (m=23, e=8) to delay = area = power = 1 and checking the paper's
//! anchors (asserted in tests, tolerances ±25%):
//! * F(7,6): speedup ≈ 7.2×, energy savings ≈ 3.4×   (paper §4.2)
//! * F(8,6): speedup ≈ 5.7×, energy savings ≈ 3.0×   (paper §4.2)
//! * fixed ≥ ~40 bits is *slower* than the SP-float baseline (paper §1
//!   finding 3 — the GoogLeNet fixed-vs-float argument)

use crate::formats::Format;

/// Relative delay/area/power of one MAC unit (1.0 = IEEE-754 single).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct MacCost {
    pub delay: f64,
    pub area: f64,
    pub power: f64,
}

// ---- gate-level building blocks (unit: one FO4-ish gate delay / one
// unit cell of area; absolute units cancel in normalization) ----------

fn log2(x: f64) -> f64 {
    x.max(1.0).log2()
}

/// Wallace-tree multiplier of two w-bit operands.
fn mult_delay(w: f64) -> f64 {
    // CSA tree depth (3:2 compressors) + final CPA over 2w bits
    1.0 + 1.5 * log2(w) + 1.0 * log2(2.0 * w)
}

fn mult_area(w: f64) -> f64 {
    w * w + 2.0 * w * log2(w) // PP array + reduction wiring/CPA
}

/// Logarithmic carry-lookahead adder of width w.
fn add_delay(w: f64) -> f64 {
    1.0 + log2(w)
}

fn add_area(w: f64) -> f64 {
    2.0 * w
}

/// Barrel shifter over w positions.
fn shift_delay(w: f64) -> f64 {
    log2(w)
}

fn shift_area(w: f64) -> f64 {
    w * log2(w)
}

const ROUND_DELAY: f64 = 2.0; // rounding incrementer + sticky logic
const FLOAT_FIXED_OVERHEAD_AREA: f64 = 48.0; // flags, sign, control
const SAT_DELAY: f64 = 1.5; // fixed-point saturation mux
const SAT_AREA_PER_BIT: f64 = 1.0;

/// Multiplier operand width of one format: the significand (m+1, incl.
/// the hidden bit) for floats, the word width (1+l+r) for fixed point.
fn mult_width(fmt: &Format) -> f64 {
    match *fmt {
        Format::Float { mantissa, .. } => (mantissa + 1) as f64,
        Format::Fixed { .. } => fmt.total_bits() as f64,
    }
}

/// Raw (un-normalized) delay/area of a MAC whose multiplier takes a
/// `w`-format weight operand and an `a`-format activation operand, and
/// whose accumulator path runs in the **activation** format (the split
/// pair's MAC semantics: the product/accumulate grid is the
/// activations', the weight format only sizes its multiplier port).
///
/// The multiplier is priced at the geometric mean of the two operand
/// widths — an `s_w × s_a` partial-product array has `s_w · s_a` cells,
/// i.e. the area of a square `√(s_w·s_a)` multiplier, and the CSA tree
/// depth tracks the same effective width.  For a uniform pair the
/// geomean is EXACT (`sqrt(s·s) == s` in IEEE for these integer-valued
/// widths), so `pair_raw(f, f)` reproduces the pre-pair single-format
/// model bit-for-bit and every `BENCH_pr4_baseline.json` ratio stays
/// comparable.
fn pair_raw(w: &Format, a: &Format) -> (f64, f64) {
    let mw = (mult_width(w) * mult_width(a)).sqrt();
    match *a {
        Format::Float { mantissa, exponent } => {
            let s = (mantissa + 1) as f64; // significand incl. hidden bit
            let ew = exponent as f64;
            // delays along the MAC critical path (Fig 3c): multiply ->
            // align -> add -> normalize -> round, plus the exponent
            // compare feeding align
            let delay = mult_delay(mw)
                + shift_delay(s).max(add_delay(ew)) // align vs exponent path overlap
                + add_delay(2.0 * s + 2.0)
                + shift_delay(s)
                + ROUND_DELAY;
            let area = mult_area(mw)
                + 2.0 * shift_area(s)            // align + normalize shifters
                + add_area(2.0 * s + 2.0)
                + 3.0 * add_area(ew)             // exponent add/sub/compare
                + FLOAT_FIXED_OVERHEAD_AREA;
            (delay, area)
        }
        Format::Fixed { .. } => {
            let n = a.total_bits() as f64;
            let delay = mult_delay(mw) + add_delay(2.0 * n) + SAT_DELAY;
            let area = mult_area(mw) + add_area(2.0 * n) + SAT_AREA_PER_BIT * 2.0 * n;
            (delay, area)
        }
    }
}

fn baseline() -> (f64, f64) {
    pair_raw(&Format::SINGLE, &Format::SINGLE)
}

/// Relative critical-path delay (1.0 = SP float MAC).
pub fn delay(fmt: &Format) -> f64 {
    delay_pair(fmt, fmt)
}

/// Relative silicon area (1.0 = SP float MAC).
pub fn area(fmt: &Format) -> f64 {
    area_pair(fmt, fmt)
}

/// Relative power ≈ switched capacitance ≈ area.
pub fn power(fmt: &Format) -> f64 {
    area(fmt)
}

/// Relative critical-path delay of a split weight/activation MAC
/// (1.0 = SP float MAC; `delay_pair(f, f) == delay(f)` exactly).
pub fn delay_pair(w: &Format, a: &Format) -> f64 {
    let (base_d, _) = baseline();
    pair_raw(w, a).0 / base_d
}

/// Relative silicon area of a split weight/activation MAC
/// (`area_pair(f, f) == area(f)` exactly).
pub fn area_pair(w: &Format, a: &Format) -> f64 {
    let (_, base_a) = baseline();
    pair_raw(w, a).1 / base_a
}

/// Relative power of a split weight/activation MAC (≈ its area).
pub fn power_pair(w: &Format, a: &Format) -> f64 {
    area_pair(w, a)
}

/// All three at once.
pub fn cost(fmt: &Format) -> MacCost {
    cost_pair(fmt, fmt)
}

/// All three for a split weight/activation MAC.  Uniform pairs
/// reproduce [`cost`] exactly (asserted across the whole design grid in
/// tests), so single-format numbers are the `w == a` diagonal of this
/// model.
pub fn cost_pair(w: &Format, a: &Format) -> MacCost {
    MacCost {
        delay: delay_pair(w, a),
        area: area_pair(w, a),
        power: power_pair(w, a),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hw::speedup::{energy_savings, speedup};

    #[test]
    fn baseline_is_unity() {
        let f = Format::SINGLE;
        assert!((delay(&f) - 1.0).abs() < 1e-12);
        assert!((area(&f) - 1.0).abs() < 1e-12);
        assert!((power(&f) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn monotone_in_mantissa() {
        // Fig 4: delay and area rise monotonically with mantissa width
        let mut last_d = 0.0;
        let mut last_a = 0.0;
        for m in 1..=23 {
            let f = Format::float(m, 8);
            assert!(delay(&f) > last_d, "delay not monotone at m={m}");
            assert!(area(&f) > last_a, "area not monotone at m={m}");
            last_d = delay(&f);
            last_a = area(&f);
        }
    }

    #[test]
    fn paper_anchor_f7e6() {
        // §4.2: F(7,6) => ~7.2x speedup, ~3.4x energy savings
        let f = Format::float(7, 6);
        let s = speedup(&f);
        let e = energy_savings(&f);
        assert!((5.4..=9.0).contains(&s), "speedup {s}");
        assert!((2.5..=4.3).contains(&e), "energy {e}");
    }

    #[test]
    fn paper_anchor_f8e6() {
        // §4.2: F(8,6) => ~5.7x speedup, ~3.0x energy savings
        let f = Format::float(8, 6);
        let s = speedup(&f);
        let e = energy_savings(&f);
        assert!((4.3..=7.2).contains(&s), "speedup {s}");
        assert!((2.2..=3.8).contains(&e), "energy {e}");
        assert!(s < speedup(&Format::float(7, 6)));
    }

    #[test]
    fn paper_anchor_wide_fixed_loses_to_sp_float() {
        // §1 finding 3: fixed-point at >= ~40 bits is more expensive
        // than the SP float baseline
        let f40 = Format::fixed(20, 19); // 40 bits
        assert!(speedup(&f40) < 1.0, "fixed-40 speedup {}", speedup(&f40));
        let f48 = Format::fixed(24, 23);
        assert!(speedup(&f48) < speedup(&f40));
    }

    #[test]
    fn fixed_beats_float_at_iso_multiplier_width() {
        // §2.1: "floating-point computation units are substantially
        // larger, slower, and more complex than integer units" — at the
        // same significand/word width, the float unit pays for shifters,
        // exponent logic and rounding that the integer unit does not.
        for n in [8u32, 12, 16, 24] {
            let fx = Format::fixed(n / 2, n - 1 - n / 2); // n-bit word
            let fl = Format::float(n - 1, 5); // (n)-bit significand
            assert_eq!(fx.total_bits(), n);
            assert!(
                delay(&fx) < delay(&fl) && area(&fx) < area(&fl),
                "fixed should win at word width {n}"
            );
        }
    }

    #[test]
    fn exponent_bits_cost_little_area() {
        // mantissa dominates (Fig 4's message)
        let a6 = area(&Format::float(10, 6));
        let a8 = area(&Format::float(10, 8));
        assert!((a8 - a6) / a6 < 0.05);
    }

    /// The pair model's backward-compatibility anchor: a uniform pair
    /// reproduces the single-format cost EXACTLY (f64 equality, not a
    /// tolerance) across the entire design grid, so every pre-pair
    /// `BENCH_pr4_baseline.json` ratio stays comparable.
    #[test]
    fn uniform_pairs_reproduce_single_format_costs_exactly() {
        for f in crate::formats::design_space(1) {
            let single = cost(&f);
            let pair = cost_pair(&f, &f);
            assert_eq!(single.delay, pair.delay, "delay drifted for {}", f.id());
            assert_eq!(single.area, pair.area, "area drifted for {}", f.id());
            assert_eq!(single.power, pair.power, "power drifted for {}", f.id());
        }
    }

    /// With the activation half held fixed, narrowing the weight half
    /// shrinks the multiplier monotonically — the pair axis the search
    /// descends is well-ordered in the cost model.
    #[test]
    fn pair_cost_monotone_in_weight_width() {
        let a = Format::fixed(4, 4);
        let mut last_d = 0.0;
        let mut last_a = 0.0;
        for m in 1..=23u32 {
            let w = Format::float(m, 6);
            let c = cost_pair(&w, &a);
            assert!(c.delay > last_d, "pair delay not monotone at m={m}");
            assert!(c.area > last_a, "pair area not monotone at m={m}");
            last_d = c.delay;
            last_a = c.area;
        }
    }

    /// The ARM-paper operating point — float weights with fixed
    /// activations — is priced between the two uniform designs: the
    /// narrow fixed accumulator helps, the wider float multiplier port
    /// costs, and the result is finite and positive like every pair.
    #[test]
    fn split_pair_costs_are_finite_and_bracketed() {
        let w = Format::float(7, 6); // mult width 8
        let a = Format::fixed(3, 4); // word width 8
        let c = cost_pair(&w, &a);
        assert!(c.delay.is_finite() && c.delay > 0.0);
        assert!(c.area.is_finite() && c.area > 0.0);
        // same multiplier widths => the split pair prices exactly like
        // uniform fixed:l3r4 (the accumulator path is the a-half's)
        let uni = cost(&a);
        assert_eq!(c.delay, uni.delay);
        assert_eq!(c.area, uni.area);
        // a wider weight port than uniform-fixed costs more
        let wide = cost_pair(&Format::float(15, 6), &a);
        assert!(wide.delay > c.delay && wide.area > c.area);
    }
}
