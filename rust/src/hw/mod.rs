//! Customized-precision MAC hardware model (paper §2.3 / §3.2).
//!
//! The paper synthesized each candidate MAC unit with Synopsys Design
//! Compiler + PrimeTime on a commercial 28 nm process and consumed the
//! resulting *normalized* delay/area/power trends.  Neither tool nor PDK
//! is available offline, so [`mac`] provides the standard analytic
//! gate-level scaling laws (Wallace-tree multiplier, logarithmic carry
//! lookahead, barrel shifters), calibrated so that the paper's anchor
//! observations hold — see `mac.rs` for the calibration table.
//!
//! [`speedup`] implements Figure 5: with a fixed silicon area budget, a
//! smaller & faster unit wins twice — higher clock *and* more parallel
//! replicas — hence the paper's "quadratic improvement" in throughput.

pub mod mac;
pub mod speedup;

pub use mac::{area, cost_pair, delay, power, MacCost};
pub use speedup::{
    energy_savings, pair_energy_savings, pair_speedup, plan_energy_savings, plan_speedup, speedup,
    Efficiency,
};
