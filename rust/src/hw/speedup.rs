//! Figure 5: speedup & energy at a fixed area budget.
//!
//! With DNN-scale parallelism, a MAC that is `1/area` the size fits
//! `area_base/area` more replicas in the same silicon, and a shorter
//! critical path clocks `delay_base/delay` faster; total throughput
//! gain is the product (the paper's "quadratic improvement", §3.2).
//!
//! Energy per operation tracks switched capacitance (≈ area), plus a
//! fixed platform overhead (clock tree, SRAM, control) that narrow
//! units cannot shrink — calibrated so F(7,6) lands at the paper's
//! 3.4× energy savings while its speedup is 7.2×.

use crate::formats::{Format, FormatPair, ResolvedPlan};
use crate::hw::mac;
use crate::nn::Network;

/// Fraction of per-op energy that scales with MAC area; the remainder
/// is fixed platform overhead.  See module docs.
pub const ENERGY_AREA_FRACTION: f64 = 0.9;

/// Combined efficiency figures for one format.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Efficiency {
    pub speedup: f64,
    pub energy_savings: f64,
    pub delay: f64,
    pub area: f64,
}

/// Throughput gain over the SP-float baseline at equal silicon area:
/// `(1/delay) * (1/area)`.
pub fn speedup(fmt: &Format) -> f64 {
    let c = mac::cost(fmt);
    (1.0 / c.delay) * (1.0 / c.area)
}

/// Energy-per-op savings over the SP-float baseline.
pub fn energy_savings(fmt: &Format) -> f64 {
    let c = mac::cost(fmt);
    let rel_energy = ENERGY_AREA_FRACTION * c.power + (1.0 - ENERGY_AREA_FRACTION);
    1.0 / rel_energy
}

/// Throughput gain of a split weight/activation MAC over the SP-float
/// baseline — the same quadratic `(1/delay)·(1/area)` combination over
/// [`mac::cost_pair`].  A uniform pair reproduces [`speedup`] exactly
/// (the single-format numbers are the `w == a` diagonal).
pub fn pair_speedup(pair: &FormatPair) -> f64 {
    let c = mac::cost_pair(&pair.w, &pair.a);
    (1.0 / c.delay) * (1.0 / c.area)
}

/// Energy-per-op savings of a split weight/activation MAC over the
/// SP-float baseline; uniform pairs reproduce [`energy_savings`]
/// exactly.
pub fn pair_energy_savings(pair: &FormatPair) -> f64 {
    let c = mac::cost_pair(&pair.w, &pair.a);
    let rel_energy = ENERGY_AREA_FRACTION * c.power + (1.0 - ENERGY_AREA_FRACTION);
    1.0 / rel_energy
}

/// MAC-weighted throughput gain of a per-layer plan over the SP-float
/// baseline: layer `i` contributes its per-sample MAC count at its
/// format's [`speedup`]; the aggregate is total MACs over total
/// weighted time (harmonic composition — a wide, slow layer dominates
/// exactly as it would on hardware provisioned per layer).  A uniform
/// assignment reduces to `speedup(fmt)`.
///
/// Panics if `plan` was not resolved against `net` (a layer the network
/// has but the plan does not cover) — the same fail-loudly rule as the
/// engine's quantizer table, never a silently wrong estimate.
pub fn plan_speedup(net: &Network, plan: &ResolvedPlan) -> f64 {
    plan_harmonic(net, plan, pair_speedup)
}

/// MAC-weighted energy savings of a per-layer plan over the SP-float
/// baseline (same harmonic composition as [`plan_speedup`], over
/// [`energy_savings`]).  Panics on a plan/network mismatch, like
/// [`plan_speedup`].
pub fn plan_energy_savings(net: &Network, plan: &ResolvedPlan) -> f64 {
    plan_harmonic(net, plan, pair_energy_savings)
}

fn plan_harmonic(net: &Network, plan: &ResolvedPlan, gain: impl Fn(&FormatPair) -> f64) -> f64 {
    let macs = net.quantized_layer_macs();
    let total: f64 = macs.iter().map(|(_, m)| *m as f64).sum();
    if total == 0.0 {
        return 1.0;
    }
    let weighted: f64 = macs
        .iter()
        .map(|(name, m)| {
            let fmt = plan.format_for(name).unwrap_or_else(|| {
                panic!("plan was not resolved against {}: layer {name:?} unassigned", net.name)
            });
            let g = gain(&fmt);
            // a NaN/inf/zero gain would silently corrupt the whole
            // harmonic aggregate (and every plan_search ranking built
            // on it) — fail as loudly as the unresolved-plan case
            assert!(
                g.is_finite() && g > 0.0,
                "plan gain for layer {name:?} of {} is not finite-positive (got {g} for {})",
                net.name,
                fmt.id()
            );
            *m as f64 / g
        })
        .sum();
    total / weighted
}

pub fn efficiency(fmt: &Format) -> Efficiency {
    let c = mac::cost(fmt);
    Efficiency {
        speedup: speedup(fmt),
        energy_savings: energy_savings(fmt),
        delay: c.delay,
        area: c.area,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_is_one() {
        assert!((speedup(&Format::SINGLE) - 1.0).abs() < 1e-12);
        assert!((energy_savings(&Format::SINGLE) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn speedup_is_quadratic_combination() {
        let f = Format::float(7, 6);
        let c = mac::cost(&f);
        assert!((speedup(&f) - 1.0 / (c.delay * c.area)).abs() < 1e-12);
        // both factors contribute: speedup exceeds either alone
        assert!(speedup(&f) > 1.0 / c.delay);
        assert!(speedup(&f) > 1.0 / c.area);
    }

    #[test]
    fn narrower_is_never_slower_float() {
        // within a fixed exponent width, fewer mantissa bits => more speedup
        let mut last = 0.0;
        for m in (1..=23).rev() {
            let s = speedup(&Format::float(m, 6));
            assert!(s >= last * 0.9999, "m={m}: {s} < {last}");
            last = s;
        }
    }

    #[test]
    fn plan_speedup_is_mac_weighted() {
        use crate::formats::{Plan, PrecisionSpec};
        let net = crate::testing::fixtures::tiny_conv_network(4);
        // fixture MAC ledger: c1 = 4*4*3*3*1*2, fc = 8*3
        assert_eq!(
            net.quantized_layer_macs(),
            vec![("c1".to_string(), 288), ("fc".to_string(), 24)]
        );
        // a uniform assignment reduces to the format's own speedup
        let f = Format::float(7, 6);
        let uni = PrecisionSpec::Uniform(f).resolve(&net).unwrap();
        assert!((plan_speedup(&net, &uni) - speedup(&f)).abs() < 1e-9);
        // a mixed plan lands strictly between its formats' speedups
        let mixed = Plan::parse("plan:c1=float:m4e5,*=float:m10e6")
            .unwrap()
            .resolve(&net)
            .unwrap();
        let s = plan_speedup(&net, &mixed);
        let (lo, hi) = (speedup(&Format::float(10, 6)), speedup(&Format::float(4, 5)));
        assert!(s > lo && s < hi, "expected {lo} < {s} < {hi}");
        // hand-computed harmonic composition over the MAC ledger
        let want = 312.0 / (288.0 / hi + 24.0 / lo);
        assert!((s - want).abs() < 1e-9);
        // the energy aggregate composes the same way and reduces to the
        // format's own figure under a uniform assignment
        assert!((plan_energy_savings(&net, &uni) - energy_savings(&f)).abs() < 1e-9);
        let e = plan_energy_savings(&net, &mixed);
        let (elo, ehi) = (
            energy_savings(&Format::float(10, 6)),
            energy_savings(&Format::float(4, 5)),
        );
        assert!(e > elo && e < ehi, "expected {elo} < {e} < {ehi}");
    }

    /// A plan that was not resolved against the network must panic —
    /// never produce a silently wrong baseline-weighted estimate.
    #[test]
    #[should_panic(expected = "not resolved against")]
    fn plan_speedup_panics_on_network_mismatch() {
        use crate::formats::ResolvedPlan;
        let net = crate::testing::fixtures::tiny_conv_network(4);
        let foreign = ResolvedPlan {
            assignments: vec![("conv9".to_string(), FormatPair::uniform(Format::float(7, 6)))],
        };
        let _ = plan_speedup(&net, &foreign);
    }

    #[test]
    fn energy_savings_saturate() {
        // fixed platform overhead bounds energy savings at 1/(1-fraction)
        let tiny = Format::float(1, 2);
        assert!(energy_savings(&tiny) < 1.0 / (1.0 - ENERGY_AREA_FRACTION));
        assert!(energy_savings(&tiny) > 1.0);
    }

    /// Uniform pairs ARE the single-format numbers — exact f64
    /// equality, the backward-compatibility contract the pair model
    /// rides on.
    #[test]
    fn uniform_pair_gains_match_single_format_exactly() {
        for f in crate::formats::design_space(1) {
            let p = FormatPair::uniform(f);
            assert_eq!(pair_speedup(&p), speedup(&f), "speedup drifted for {}", f.id());
            assert_eq!(
                pair_energy_savings(&p),
                energy_savings(&f),
                "energy drifted for {}",
                f.id()
            );
        }
    }

    /// Satellite: pair speedup/energy are finite and positive across
    /// the WHOLE admissible format grid (every ordered pair of design
    /// points) — a NaN/inf anywhere would poison `plan_harmonic`'s
    /// aggregate, which now asserts against exactly that.
    #[test]
    fn pair_gains_are_finite_across_the_admissible_grid() {
        let grid = crate::formats::design_space(4); // 60 designs, 3600 pairs
        for w in &grid {
            for a in &grid {
                let p = FormatPair::split(*w, *a);
                let s = pair_speedup(&p);
                let e = pair_energy_savings(&p);
                assert!(s.is_finite() && s > 0.0, "speedup {s} for {}", p.id());
                assert!(e.is_finite() && e > 0.0, "energy {e} for {}", p.id());
            }
        }
    }

    /// A plan with a split pair aggregates through the pair gains: the
    /// ARM-paper shape (float weights, fixed activations) is priced as
    /// the pair model says, not as either half alone.
    #[test]
    fn plan_speedup_aggregates_split_pairs() {
        use crate::formats::Plan;
        let net = crate::testing::fixtures::tiny_conv_network(4);
        let plan = Plan::parse("plan:c1=w:float:m7e6+a:fixed:l4r8,*=float:m7e6")
            .unwrap()
            .resolve(&net)
            .unwrap();
        let s = plan_speedup(&net, &plan);
        let pair = FormatPair::split(Format::float(7, 6), Format::fixed(4, 8));
        let want = 312.0 / (288.0 / pair_speedup(&pair) + 24.0 / speedup(&Format::float(7, 6)));
        assert!((s - want).abs() < 1e-9, "expected {want}, got {s}");
        assert!(s.is_finite() && s > 0.0);
    }
}
