//! Figure 5: speedup & energy at a fixed area budget.
//!
//! With DNN-scale parallelism, a MAC that is `1/area` the size fits
//! `area_base/area` more replicas in the same silicon, and a shorter
//! critical path clocks `delay_base/delay` faster; total throughput
//! gain is the product (the paper's "quadratic improvement", §3.2).
//!
//! Energy per operation tracks switched capacitance (≈ area), plus a
//! fixed platform overhead (clock tree, SRAM, control) that narrow
//! units cannot shrink — calibrated so F(7,6) lands at the paper's
//! 3.4× energy savings while its speedup is 7.2×.

use crate::formats::Format;
use crate::hw::mac;

/// Fraction of per-op energy that scales with MAC area; the remainder
/// is fixed platform overhead.  See module docs.
pub const ENERGY_AREA_FRACTION: f64 = 0.9;

/// Combined efficiency figures for one format.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Efficiency {
    pub speedup: f64,
    pub energy_savings: f64,
    pub delay: f64,
    pub area: f64,
}

/// Throughput gain over the SP-float baseline at equal silicon area:
/// `(1/delay) * (1/area)`.
pub fn speedup(fmt: &Format) -> f64 {
    let c = mac::cost(fmt);
    (1.0 / c.delay) * (1.0 / c.area)
}

/// Energy-per-op savings over the SP-float baseline.
pub fn energy_savings(fmt: &Format) -> f64 {
    let c = mac::cost(fmt);
    let rel_energy = ENERGY_AREA_FRACTION * c.power + (1.0 - ENERGY_AREA_FRACTION);
    1.0 / rel_energy
}

pub fn efficiency(fmt: &Format) -> Efficiency {
    let c = mac::cost(fmt);
    Efficiency {
        speedup: speedup(fmt),
        energy_savings: energy_savings(fmt),
        delay: c.delay,
        area: c.area,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn baseline_is_one() {
        assert!((speedup(&Format::SINGLE) - 1.0).abs() < 1e-12);
        assert!((energy_savings(&Format::SINGLE) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn speedup_is_quadratic_combination() {
        let f = Format::float(7, 6);
        let c = mac::cost(&f);
        assert!((speedup(&f) - 1.0 / (c.delay * c.area)).abs() < 1e-12);
        // both factors contribute: speedup exceeds either alone
        assert!(speedup(&f) > 1.0 / c.delay);
        assert!(speedup(&f) > 1.0 / c.area);
    }

    #[test]
    fn narrower_is_never_slower_float() {
        // within a fixed exponent width, fewer mantissa bits => more speedup
        let mut last = 0.0;
        for m in (1..=23).rev() {
            let s = speedup(&Format::float(m, 6));
            assert!(s >= last * 0.9999, "m={m}: {s} < {last}");
            last = s;
        }
    }

    #[test]
    fn energy_savings_saturate() {
        // fixed platform overhead bounds energy savings at 1/(1-fraction)
        let tiny = Format::float(1, 2);
        assert!(energy_savings(&tiny) < 1.0 / (1.0 - ENERGY_AREA_FRACTION));
        assert!(energy_savings(&tiny) > 1.0);
    }
}
