//! `repro` — the precis command-line interface.
//!
//! Subcommands:
//!   info                         zoo summary (networks, params, chains)
//!   eval     --net N --format F  accuracy of one configuration
//!   sweep    --net N             design-space sweep (Fig 6 data)
//!   search   --net N             model-driven precision search (§3.3)
//!   plan     --net N             greedy per-layer mixed-precision search
//!   trace    --net N             accumulation trace (Fig 8 data)
//!   figure   <fig4..fig11>       regenerate one paper figure's series
//!   figures                      regenerate all figures into --out
//!   serve    --sessions K,...    multi-model gateway under closed-loop
//!                                load; K = net@format
//!   zoo-size <net> --format F    per-layer f32-vs-packed storage table
//!                                (DESIGN.md §Storage)
//!   bench    [--json PATH]       headless hot-path suite; --json writes
//!                                the machine-readable BENCH report
//!   bench-sweep --net N          quick sequential sweep timing
//!
//! Common flags: --artifacts DIR (default artifacts), --out DIR (default
//! results), --samples N, --workers W, --seed S, --stride K.

use std::path::PathBuf;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use precis::coordinator::cache::ResultCache;
use precis::coordinator::Coordinator;
use precis::eval::sweep::EvalOptions;
use precis::eval::{accuracy_with_store_exec, sweep_design_space};
use precis::figures;
use precis::formats::{self, Format, PrecisionSpec};
use precis::nn::Zoo;
use precis::search::{default_ladder, exhaustive_search, plan_search, search, PlanSearchSpec, SearchSpec};
use precis::serving::{
    drive_open_loop, split_session_specs, warm_up, ArrivalSchedule, BackendKind, ClosedLoop,
    Gateway, SessionOptions, SloTarget,
};
use precis::store::{human_bytes, parse_byte_size, WeightStore};
use precis::util::cli::Args;
use precis::util::timer::Timer;

fn main() {
    let raw: Vec<String> = std::env::args().skip(1).collect();
    if let Err(e) = run(&raw) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const USAGE: &str = "usage: repro <info|eval|sweep|search|plan|trace|figure|figures|serve|zoo-size|bench|bench-sweep> [flags]
  repro info
  repro eval   --net lenet5 --format float:m7e6|plan:... [--samples 128] [--backend native|pjrt]
               (a plan rule may split weight and activation formats:
                plan:conv1=w:float:m4e5+a:fixed:l4r8,*=float:m7e6 — single-format
                rules are sugar for w == a)
               [--weight-budget 8m]   (cap + report the pre-quantized weight store)
               [--packed-exec]        (execute from bit-packed codes where the router
                                       admits a layer; bit-identical, native only)
               [--profile]            (per-layer span profile of one forward: wall time,
                                       executed lane, MACs, clamped activations;
                                       native only — DESIGN.md §Observability)
  repro sweep  --net lenet5 [--samples 128] [--stride 1]
  repro search --net lenet5 [--target 0.99] [--refine 2] [--kind float|fixed|both]
  repro plan   <net> [--target 0.99] [--validate 4]
               [--ladder float:m23e8,float:m7e6,...]
               (greedy descent over BOTH axes: each layer's weight and activation
                half narrow independently; the table reports both per layer)
  repro trace  --net alexnet-mini [--sample 0]
  repro figure <fig4|fig5|fig6|fig7|fig8|fig9|fig10|fig11> [--net N]
  repro figures [--out results]
  repro serve  --sessions lenet5@float:m7e6,lenet5@plan:conv1=float:m4e5,*=fixed:l8r8
               [--requests 256] [--clients 8] [--wait-ms 5] [--backend native|pjrt|auto]
               [--weight-budget 8m]   (gateway-wide staged-weight byte budget)
               [--packed-exec]        (native sessions execute from packed codes)
               [--arrivals poisson:200rps | burst:20rps:400rps:100ms:0.25
                           | ramp:50rps:500rps:200ms]
                                      (open-loop trace-driven load, seeded by --seed;
                                       default is closed-loop --clients)
               [--slo 20ms:256]       (per-session p99 queue-latency budget [+ max
                                       queue depth]; excess load is shed with a typed
                                       error, never silently dropped)
               [--qos-slots 2]        (gateway-wide execution slots: sessions closest
                                       to SLO violation drain first)
               [--gemm-threads 4]     (row-parallelize large GEMMs inside each native
                                       forward; bit-identical at any setting, 0 = serial)
               [--profile]            (capture each session's latest per-layer span
                                       profile and print it after the drive)
               [--events-out events.jsonl]
                                      (JSON-lines structured event log: session
                                       open/close, sheds, store evict/reject, SLO burn
                                       alerts; DESIGN.md §Observability)
  repro zoo-size <net> --format float:m7e6|plan:...
               (per-layer f32 vs bit-packed bytes, MAC-weighted, plus the packed
                execution lane per layer; DESIGN.md §Storage, §Packed execution)
  repro bench  [--preset quick|full] [--tag T] [--json BENCH_T.json]
               (headless: no artifacts needed; includes packed_forward_over_f32
                sections vs hw::speedup predictions and obs_overhead sections
                pricing the metrics/profiling hot paths; compare files with
                .github/scripts/bench_compare.py)
  repro bench-sweep --net lenet5 [--stride 1]
common: --artifacts DIR --out DIR --samples N --workers W --seed S";

fn run(raw: &[String]) -> Result<()> {
    let args = Args::parse(raw, &["quiet", "packed-exec", "profile"])?;
    let Some(cmd) = args.positional().first().map(|s| s.as_str()) else {
        println!("{USAGE}");
        return Ok(());
    };

    let artifacts = PathBuf::from(args.get_or("artifacts", "artifacts"));
    let out_dir = PathBuf::from(args.get_or("out", "results"));
    let samples = args.get_usize("samples", 128)?;
    let workers = args.get_usize("workers", 0)?;
    let seed = args.get_usize("seed", 2018)? as u64;
    let stride = args.get_usize("stride", 1)?.max(1);
    let opts = EvalOptions { samples, batch: 32 };

    let load_coord = || -> Result<Coordinator> {
        let zoo = Zoo::load(&artifacts).context("loading artifacts")?;
        let cache = ResultCache::open(out_dir.join("cache.json"));
        let mut c = Coordinator::new(zoo, cache);
        if workers > 0 {
            c = c.with_workers(workers);
        }
        Ok(c)
    };

    match cmd {
        "info" => {
            let zoo = Zoo::load(&artifacts)?;
            println!("{:<16} {:>8} {:>10} {:>7} {:>6} {:>10}", "network", "params", "max_chain", "classes", "topk", "exact_acc");
            for net in zoo.by_size_desc() {
                println!(
                    "{:<16} {:>8} {:>10} {:>7} {:>6} {:>10.3}",
                    net.name, net.n_params, net.max_chain, net.classes, net.topk, net.eval_acc_exact
                );
            }
            println!("\ndesign space: {} formats ({} float, {} fixed)",
                formats::design_space(1).len(),
                formats::float_space().len(),
                formats::fixed_space().len());
        }
        "eval" => {
            let net_name = args.get("net").context("--net required")?;
            let spec = PrecisionSpec::parse(args.get("format").context("--format required")?)?;
            let zoo = Zoo::load(&artifacts)?;
            let net = zoo.network(net_name)?;
            let resolved = spec.resolve(&net)?;
            let t = Timer::start();
            // --weight-budget caps the pre-quantized weight store the
            // eval workers share, and reports its counters after
            let weight_budget = args.get("weight-budget").map(parse_byte_size).transpose()?;
            let packed_exec = args.has("packed-exec");
            let acc = match args.get_or("backend", "native") {
                "native" => {
                    let store = std::sync::Arc::new(WeightStore::from_budget(weight_budget));
                    let acc = accuracy_with_store_exec(&net, &spec, samples, &store, packed_exec)?;
                    if weight_budget.is_some() || packed_exec {
                        eprintln!("# weight store: {}", store.stats().render());
                    }
                    if packed_exec {
                        let table = precis::nn::QuantTable::resolve_for(&net, &spec, true)?;
                        let lanes: Vec<String> = table
                            .packed_labels(&net)
                            .into_iter()
                            .map(|(name, lane)| format!("{name}={lane}"))
                            .collect();
                        eprintln!("# packed exec lanes: {}", lanes.join(", "));
                    }
                    // --profile: one extra profiled forward over the
                    // warm store (the accuracy pass above staged it),
                    // reporting per-layer wall/lane/MACs/clamps
                    if args.has("profile") {
                        use precis::serving::{Backend, NativeBackend};
                        let mut b = NativeBackend::with_store(net.clone(), store.clone())
                            .with_packed_exec(packed_exec)
                            .with_profiling(true);
                        let n = samples.min(net.eval_len()).min(32).max(1);
                        let x = net.eval_x.slice_rows(0, n);
                        b.run_spec(&x, &spec)?;
                        if let Some(p) = Backend::take_profile(&mut b) {
                            println!("{}", p.render());
                        }
                    }
                    acc
                }
                // the AOT executables take one fmt vector: any spec
                // that resolves uniform runs on PJRT
                "pjrt" => {
                    if weight_budget.is_some() {
                        eprintln!(
                            "(--weight-budget applies to the native engine's weight store \
                             only; PJRT holds weights on-device — flag ignored)"
                        );
                    }
                    if packed_exec {
                        eprintln!(
                            "(--packed-exec applies to the native engine only; PJRT holds \
                             weights on-device — flag ignored)"
                        );
                    }
                    if args.has("profile") {
                        eprintln!(
                            "(--profile applies to the native engine only — flag ignored)"
                        );
                    }
                    let fmt = spec.resolved_uniform(&net)?;
                    pjrt_eval(&net, &artifacts, &fmt, samples, zoo.batch)?
                }
                b => bail!("unknown backend {b:?}"),
            };
            // uniform specs report the format's own figures; plans the
            // MAC-weighted aggregates
            let (speedup, energy) = match spec.uniform_format() {
                Some(fmt) => (precis::hw::speedup(&fmt), precis::hw::energy_savings(&fmt)),
                None => (
                    precis::hw::plan_speedup(&net, &resolved),
                    precis::hw::plan_energy_savings(&net, &resolved),
                ),
            };
            println!(
                "{net_name} @ {}: top-{} = {:.4}  (speedup {:.2}x, energy {:.2}x, {} samples, {:.1}s)",
                spec.id(),
                net.topk,
                acc,
                speedup,
                energy,
                samples.min(net.eval_len()),
                t.elapsed_s()
            );
        }
        "sweep" => {
            let net_name = args.get("net").context("--net required")?;
            let coord = load_coord()?;
            let t = Timer::start();
            let table = figures::fig6(&coord, net_name, &opts, stride)?;
            print!("{}", table.to_tsv());
            eprintln!("# sweep of {} configs in {:.1}s", table.rows.len(), t.elapsed_s());
        }
        "search" => {
            let net_name = args.get("net").context("--net required")?;
            let target = args.get_f64("target", 0.99)?;
            let refine = args.get_usize("refine", 2)?;
            let kind = args.get_or("kind", "both");
            let coord = load_coord()?;
            let net = coord.zoo.network(net_name)?;
            let space: Vec<Format> = match kind {
                "float" => formats::float_space(),
                "fixed" => formats::fixed_space(),
                "both" => formats::design_space(1),
                k => bail!("unknown --kind {k:?}"),
            };
            let model = figures::cross_validated_model(&coord, net_name, &opts, seed)?;
            let spec = SearchSpec { formats: space, target, refine_samples: refine, opts, seed };
            let t = Timer::start();
            let out = search(&net, &spec, &model)?;
            let (ex, _) = exhaustive_search(&net, &spec)?;
            coord.cache.flush()?;
            println!("model search : {:?} speedup {:.2}x measured_na {:.4} ({} sample-forwards)",
                out.chosen.map(|c| c.id()), out.speedup, out.measured_norm_acc, out.sample_forwards);
            println!("exhaustive   : {:?} speedup {:.2}x measured_na {:.4} ({} sample-forwards)",
                ex.chosen.map(|c| c.id()), ex.speedup, ex.measured_norm_acc, ex.sample_forwards);
            println!("search-cost reduction: {:.0}x  ({:.1}s total)",
                ex.sample_forwards as f64 / out.sample_forwards.max(1) as f64, t.elapsed_s());
        }
        "plan" => {
            // greedy per-layer mixed-precision search (DESIGN.md §Mixed
            // precision): probe-ranked descent, survivors validated
            let net_name = args
                .get("net")
                .or_else(|| args.positional().get(1).map(|s| s.as_str()))
                .context("--net (or a positional network name) required")?;
            let target = args.get_f64("target", 0.99)?;
            let validate = args.get_usize("validate", 4)?;
            let ladder: Vec<Format> = match args.get("ladder") {
                Some(list) => list
                    .split(',')
                    .map(|s| Format::parse(s.trim()))
                    .collect::<Result<_>>()?,
                None => default_ladder(),
            };
            let coord = load_coord()?;
            let net = coord.zoo.network(net_name)?;
            let model = figures::cross_validated_model(&coord, net_name, &opts, seed)?;
            let spec = PlanSearchSpec {
                ladder,
                target,
                max_validations: validate.max(1),
                opts,
                seed,
            };
            let t = Timer::start();
            let out = plan_search(&net, &spec, &model)?;
            coord.cache.flush()?;

            // 2-axis table: the weight and activation halves narrow
            // independently, so each gets its own column; speedup is
            // the pair's (uniform pairs = the single-format figure)
            println!(
                "{:<16} {:>14} {:>14} {:>10} {:>10}",
                "layer", "weights", "activations", "macs", "speedup"
            );
            let resolved = out.plan.resolve(&net)?;
            for (name, macs) in net.quantized_layer_macs() {
                let pair = resolved.format_for(&name).expect("resolved plan covers every layer");
                println!(
                    "{name:<16} {:>14} {:>14} {macs:>10} {:>9.2}x",
                    pair.w.id(),
                    pair.a.id(),
                    precis::hw::pair_speedup(&pair)
                );
            }
            println!("\nchosen plan  : {}", out.plan.id());
            println!("serve it as  : {net_name}@{}", out.plan.id());
            println!(
                "accuracy     : predicted {:.4}, measured {:.4} (target {:.2})",
                out.predicted_norm_acc, out.measured_norm_acc, target
            );
            println!("hw speedup   : {:.2}x (MAC-weighted over the plan)", out.speedup);
            println!(
                "search cost  : {} probe plans + {} validations vs {} exhaustive per-layer plans ({:.1}s)",
                out.plans_probed, out.validations_spent, out.exhaustive_plans, t.elapsed_s()
            );
        }
        "trace" => {
            let net_name = args.get_or("net", "alexnet-mini");
            let sample = args.get_usize("sample", 0)?;
            let zoo = Zoo::load(&artifacts)?;
            let net = zoo.network(net_name)?;
            let table = figures::fig8(&net, sample)?;
            print!("{}", table.to_tsv());
        }
        "figure" => {
            let which = args
                .positional()
                .get(1)
                .context("figure id required (fig4..fig11)")?
                .clone();
            let table = one_figure(&which, &args, &opts, seed, stride, load_coord)?;
            print!("{}", table.to_tsv());
        }
        "figures" => {
            let coord = load_coord()?;
            let t = Timer::start();
            let mut tables: Vec<figures::Table> = vec![figures::fig4(), figures::fig5()];
            for name in coord.zoo.names().iter().map(|s| s.to_string()).collect::<Vec<_>>() {
                eprintln!("# fig6 sweep: {name}");
                tables.push(figures::fig6(&coord, &name, &opts, stride)?);
            }
            eprintln!("# fig7 heatmap");
            tables.push(figures::fig7(&coord, "alexnet-mini", &opts)?);
            eprintln!("# fig8 trace");
            tables.push(figures::fig8(&coord.zoo.network("alexnet-mini")?, 0)?);
            eprintln!("# fig9 model");
            let (t9, model) = figures::fig9(&coord, &opts, seed)?;
            eprintln!("#   fit: na = {:.4} * r2 + {:.4} (r = {:.4}, n = {})",
                model.a, model.b, model.fit_r, model.n_points);
            tables.push(t9);
            eprintln!("# fig10 search validation");
            let mut probes = figures::ProbeMemo::new();
            tables.push(figures::fig10(&coord, &opts, &[0.95, 0.99, 0.999], seed, &mut probes)?);
            eprintln!("# fig11 final speedups");
            tables.push(figures::fig11(&coord, &opts, seed, &mut probes)?);
            for table in &tables {
                let p = table.write_to(&out_dir)?;
                eprintln!("wrote {}", p.display());
            }
            coord.cache.flush()?;
            eprintln!("# all figures in {:.1}s", t.elapsed_s());
        }
        "serve" => {
            let specs = args
                .get("sessions")
                .context("--sessions net@format[,net@format...] required")?
                .to_string();
            let n_requests = args.get_usize("requests", 256)?;
            let n_clients = args.get_usize("clients", 8)?.max(1);
            let wait_ms = args.get_usize("wait-ms", 5)?;
            let kind = BackendKind::parse(args.get_or("backend", "native"))?;
            // ONE weight store serves every session the gateway hosts
            // (sessions share staged weights by resolved format)
            let weight_budget = args.get("weight-budget").map(parse_byte_size).transpose()?;
            if weight_budget.is_some() && kind == BackendKind::Pjrt {
                eprintln!(
                    "(--weight-budget applies to native sessions only; PJRT holds weights \
                     on-device — the cap will sit unused)"
                );
            }
            let packed_exec = args.has("packed-exec");
            if packed_exec && kind == BackendKind::Pjrt {
                eprintln!(
                    "(--packed-exec applies to native sessions only; PJRT holds weights \
                     on-device — flag ignored)"
                );
            }
            // QoS: an SLO makes every opened session shed (typed, loud)
            // instead of queueing without bound; --qos-slots bounds
            // concurrent batch executions gateway-wide, granted by SLO
            // headroom (DESIGN.md §Serving QoS)
            let slo = args.get("slo").map(SloTarget::parse).transpose()?;
            let qos_slots = args.get_usize("qos-slots", 0)?;
            // intra-forward GEMM row parallelism (native engine only;
            // bit-identical at any thread count — DESIGN.md §Perf)
            let gemm_threads = args.get_usize("gemm-threads", 0)?;
            if gemm_threads > 1 && kind == BackendKind::Pjrt {
                eprintln!(
                    "(--gemm-threads applies to native sessions only; PJRT executables \
                     schedule their own kernels — flag ignored)"
                );
            }
            // open-loop trace-driven load: requests fire at schedule
            // time regardless of completions (the only mode where an
            // SLO has anything to shed); seeded for reproducibility
            let arrivals = args
                .get("arrivals")
                .map(|s| ArrivalSchedule::parse(s, seed))
                .transpose()?;
            // --events-out: stream typed lifecycle/shed/store/alert
            // records as JSON lines (DESIGN.md §Observability)
            let events_path = args.get("events-out").map(|s| s.to_string());
            let events = events_path
                .as_deref()
                .map(|p| {
                    precis::obs::EventSink::to_file(std::path::Path::new(p))
                        .map(std::sync::Arc::new)
                })
                .transpose()?;
            let zoo = Zoo::load(&artifacts)?;
            let mut gateway = Gateway::new(zoo, kind).with_options(SessionOptions {
                batch: 0, // artifact batch size
                max_wait: Duration::from_millis(wait_ms as u64),
                weight_budget,
                packed_exec,
                slo,
                qos_slots,
                gemm_threads,
                profile: args.has("profile"),
            });
            if let Some(sink) = &events {
                gateway = gateway.with_events(sink.clone());
            }
            let mut keys = Vec::new();
            for spec in split_session_specs(&specs) {
                keys.push(gateway.open_spec(&spec)?);
            }
            let mode = match &arrivals {
                Some(sched) => format!("open-loop {sched}"),
                None => format!("{n_clients} closed-loop clients"),
            };
            println!(
                "gateway: {} session(s) [{}], backend {}, {mode}, {n_requests} requests{}",
                keys.len(),
                keys.iter().map(|k| k.to_string()).collect::<Vec<_>>().join(", "),
                kind.as_str(),
                match slo {
                    Some(s) => format!(", slo {s}"),
                    None => String::new(),
                }
            );

            // one warm-up request per session proves each backend end
            // to end before the measured load
            warm_up(&gateway, &keys)?;

            let report = match &arrivals {
                Some(sched) => drive_open_loop(&gateway, &keys, sched, n_requests),
                None => ClosedLoop::new(n_clients).drive(&gateway, &keys, n_requests),
            };

            // per-key offered/served/shed/latency table, then the live
            // gateway stats snapshot (the gateway is still serving here
            // — telemetry is not a shutdown-only artifact)
            println!("\n{}", report.render(&keys));
            println!("{}", gateway.stats().render());
            // --profile: each session's latest per-layer span profile
            if args.has("profile") {
                for key in &keys {
                    if let Some(p) = gateway.session(key).and_then(|s| s.last_profile()) {
                        println!("profile {key}:\n{}", p.render());
                    }
                }
            }
            println!(
                "throughput: {:.1} served/s over {} session(s) ({:.2}s wall; \
                 {} offered = {} served + {} shed + {} failed)",
                report.served.len() as f64 / report.wall_s.max(1e-9),
                keys.len(),
                report.wall_s,
                report.offered,
                report.served.len(),
                report.shed(),
                report.failed()
            );
            anyhow::ensure!(
                report.is_balanced(),
                "drive accounting is unbalanced: {} offered != {} served + {} shed + {} failed",
                report.offered,
                report.served.len(),
                report.shed(),
                report.failed()
            );
            let fin = gateway.shutdown();
            println!("served {} requests in {} batches total", fin.total_requests(), fin.total_batches());
            // dropping the last sink Arc joins the writer, so the log
            // file is complete before we report it
            if let (Some(sink), Some(path)) = (events, events_path) {
                let (emitted, dropped) = (sink.emitted(), sink.dropped());
                drop(sink);
                println!("events: {emitted} emitted ({dropped} dropped) -> {path}");
            }
        }
        "zoo-size" => {
            // per-layer storage footprint: f32 carrier vs the packed
            // narrow-width encoding, MAC-weighted (DESIGN.md §Storage)
            let net_name = args
                .get("net")
                .or_else(|| args.positional().get(1).map(|s| s.as_str()))
                .context("--net (or a positional network name) required")?;
            let spec = PrecisionSpec::parse(
                args.get("format")
                    .context("--format float:m7e6 | plan:... required")?,
            )?;
            let zoo = Zoo::load(&artifacts)?;
            let net = zoo.network(net_name)?;
            let rows = precis::store::zoo_size(&net, &spec)?;
            // the packed-execution lane the router would assign each
            // layer under --packed-exec (DESIGN.md §Packed execution)
            let lanes: std::collections::BTreeMap<String, &'static str> =
                precis::nn::QuantTable::resolve_for(&net, &spec, true)?
                    .packed_labels(&net)
                    .into_iter()
                    .collect();
            println!(
                "{:<16} {:>14} {:>10} {:>8} {:>10} {:>10} {:>7} {:>9} {:>7}",
                "layer", "format", "macs", "params", "f32", "packed", "ratio", "mac-spdup", "exec"
            );
            let (mut tp, mut tf, mut tpk, mut tmacs) = (0usize, 0usize, 0usize, 0usize);
            let mut weighted_bits = 0f64;
            for r in &rows {
                println!(
                    "{:<16} {:>14} {:>10} {:>8} {:>10} {:>10} {:>6.2}x {:>8.2}x {:>7}",
                    r.layer,
                    r.pair.id(),
                    r.macs,
                    r.params,
                    human_bytes(r.f32_bytes),
                    human_bytes(r.packed_bytes),
                    r.f32_bytes as f64 / r.packed_bytes.max(1) as f64,
                    r.mac_speedup,
                    lanes.get(&r.layer).copied().unwrap_or("-"),
                );
                tp += r.params;
                tf += r.f32_bytes;
                tpk += r.packed_bytes;
                tmacs += r.macs;
                weighted_bits += r.macs as f64 * r.bits_per_value as f64;
            }
            let resolved = spec.resolve(&net)?;
            println!(
                "\ntotal: {} params, {} f32 -> {} packed ({:.2}x compression)",
                tp,
                human_bytes(tf),
                human_bytes(tpk),
                tf as f64 / tpk.max(1) as f64,
            );
            println!(
                "MAC-weighted width {:.1} bits/value; hw speedup {:.2}x, energy {:.2}x (paper Fig 5 framing)",
                weighted_bits / tmacs.max(1) as f64,
                precis::hw::plan_speedup(&net, &resolved),
                precis::hw::plan_energy_savings(&net, &resolved),
            );
        }
        "bench" => {
            // the headless hot-path suite + machine-readable report
            // (the perf-regression pipeline; DESIGN.md §Perf)
            let preset = args.get_or("preset", "quick");
            let quick = match preset {
                "quick" => true,
                "full" => false,
                p => bail!("unknown --preset {p:?} (quick|full)"),
            };
            let tag = args.get_or("tag", preset);
            let t = Timer::start();
            let report = precis::bench_harness::suite::hot_paths_report(tag, quick);
            eprintln!("\n# hot_paths suite ({preset}) in {:.1}s", t.elapsed_s());
            if let Some(path) = args.get("json") {
                report.save(std::path::Path::new(path))?;
                println!(
                    "wrote {path} ({} results, {} ratios; diff two files with \
                     .github/scripts/bench_compare.py)",
                    report.results.len(),
                    report.ratios.len()
                );
            }
        }
        "bench-sweep" => {
            // quick sequential sweep timing (perf work; listed in USAGE)
            let net_name = args.get("net").context("--net required")?;
            let zoo = Zoo::load(&artifacts)?;
            let net = zoo.network(net_name)?;
            let space = formats::design_space(stride);
            let t = Timer::start();
            let res = sweep_design_space(&net, &space, &opts)?;
            println!("{} configs in {:.2}s ({:.2} cfg/s)",
                res.len(), t.elapsed_s(), res.len() as f64 / t.elapsed_s());
        }
        other => {
            bail!("unknown command {other:?}\n{USAGE}");
        }
    }
    Ok(())
}

/// `eval --backend pjrt`: run the AOT HLO artifact through the PJRT
/// runtime (`pjrt` feature; DESIGN.md §5).
#[cfg(feature = "pjrt")]
fn pjrt_eval(
    net: &std::sync::Arc<precis::nn::Network>,
    artifacts: &std::path::Path,
    fmt: &Format,
    samples: usize,
    batch: usize,
) -> Result<f64> {
    let rt = precis::runtime::Runtime::cpu()?;
    let kind = if fmt.is_float() { "float" } else { "fixed" };
    let model = rt.load_network(net, artifacts, kind, batch)?;
    let (logits, labels) = model.run_eval(samples, fmt)?;
    Ok(precis::eval::topk_accuracy(&logits, &labels, net.classes, net.topk))
}

/// Native-only builds: fail with a pointer at the feature instead of a
/// missing symbol.
#[cfg(not(feature = "pjrt"))]
fn pjrt_eval(
    _net: &std::sync::Arc<precis::nn::Network>,
    _artifacts: &std::path::Path,
    _fmt: &Format,
    _samples: usize,
    _batch: usize,
) -> Result<f64> {
    bail!("this build has no PJRT runtime; rebuild with `--features pjrt` (DESIGN.md §5)")
}

fn one_figure(
    which: &str,
    args: &Args,
    opts: &EvalOptions,
    seed: u64,
    stride: usize,
    load_coord: impl Fn() -> Result<Coordinator>,
) -> Result<figures::Table> {
    Ok(match which {
        "fig4" => figures::fig4(),
        "fig5" => figures::fig5(),
        "fig6" => {
            let net = args.get("net").context("--net required for fig6")?;
            figures::fig6(&load_coord()?, net, opts, stride)?
        }
        "fig7" => figures::fig7(&load_coord()?, args.get_or("net", "alexnet-mini"), opts)?,
        "fig8" => {
            let coord = load_coord()?;
            let net = coord.zoo.network(args.get_or("net", "alexnet-mini"))?;
            figures::fig8(&net, args.get_usize("sample", 0)?)?
        }
        "fig9" => figures::fig9(&load_coord()?, opts, seed)?.0,
        "fig10" => {
            figures::fig10(&load_coord()?, opts, &[0.95, 0.99, 0.999], seed, &mut figures::ProbeMemo::new())?
        }
        "fig11" => figures::fig11(&load_coord()?, opts, seed, &mut figures::ProbeMemo::new())?,
        other => bail!("unknown figure {other:?} (fig4..fig11)"),
    })
}
