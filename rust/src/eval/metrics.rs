//! Classification metrics.
//!
//! Tie handling is normative and matches `python/compile/train.py`'s
//! `topk_accuracy` (numpy stable argsort of the negated logits): among
//! equal logits the *lower class index* ranks first.

/// Number of samples whose label is within the top-k logits.
pub fn topk_hits(logits: &[f32], labels: &[i32], classes: usize, k: usize) -> usize {
    assert_eq!(logits.len(), labels.len() * classes);
    let mut hits = 0;
    let mut idx: Vec<usize> = Vec::with_capacity(classes);
    for (s, &label) in labels.iter().enumerate() {
        let row = &logits[s * classes..(s + 1) * classes];
        idx.clear();
        idx.extend(0..classes);
        // descending by value, ascending by index for ties (stable sort
        // over an already-ascending index list)
        idx.sort_by(|&a, &b| row[b].partial_cmp(&row[a]).unwrap_or(std::cmp::Ordering::Equal));
        if idx[..k.min(classes)].contains(&(label as usize)) {
            hits += 1;
        }
    }
    hits
}

/// Top-k accuracy in [0, 1].
pub fn topk_accuracy(logits: &[f32], labels: &[i32], classes: usize, k: usize) -> f64 {
    if labels.is_empty() {
        return 0.0;
    }
    topk_hits(logits, labels, classes, k) as f64 / labels.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn top1_basic() {
        let logits = [0.1f32, 0.9, 0.0, /**/ 0.8, 0.1, 0.1];
        assert_eq!(topk_accuracy(&logits, &[1, 0], 3, 1), 1.0);
        assert_eq!(topk_accuracy(&logits, &[0, 0], 3, 1), 0.5);
    }

    #[test]
    fn top5_catches_lower_ranks() {
        let mut logits = vec![0.0f32; 10];
        for (i, v) in logits.iter_mut().enumerate() {
            *v = -(i as f32); // class 0 best, 9 worst
        }
        assert_eq!(topk_accuracy(&logits, &[4], 10, 5), 1.0);
        assert_eq!(topk_accuracy(&logits, &[5], 10, 5), 0.0);
    }

    #[test]
    fn ties_break_to_lower_index() {
        // all-equal logits (e.g. a fully saturated network): top-1 is class 0
        let logits = vec![7.0f32; 4];
        assert_eq!(topk_accuracy(&logits, &[0], 4, 1), 1.0);
        assert_eq!(topk_accuracy(&logits, &[3], 4, 1), 0.0);
        // top-2 covers classes {0, 1}
        assert_eq!(topk_accuracy(&logits, &[1], 4, 2), 1.0);
        assert_eq!(topk_accuracy(&logits, &[2], 4, 2), 0.0);
    }

    #[test]
    fn k_larger_than_classes_is_always_hit() {
        let logits = vec![1.0f32, 2.0];
        assert_eq!(topk_accuracy(&logits, &[0], 2, 5), 1.0);
    }

    #[test]
    fn empty_is_zero() {
        assert_eq!(topk_accuracy(&[], &[], 3, 1), 0.0);
    }
}
