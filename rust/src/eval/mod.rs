//! Accuracy metrics and the design-space evaluation driver (§3.1, §4.2).

pub mod metrics;
pub mod sweep;

pub use metrics::{topk_accuracy, topk_hits};
pub use sweep::{
    accuracy, accuracy_with_store, accuracy_with_store_exec, eval_config, forward_eval_parallel,
    forward_eval_parallel_exec, forward_eval_parallel_in, sweep_design_space, ConfigResult,
    EvalOptions,
};
