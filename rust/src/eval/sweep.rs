//! Design-space evaluation: run a network under one or many customized
//! precision configurations and measure accuracy + last-layer activations.
//!
//! Every forward pass here executes through [`Backend`] — the same
//! substrate the request path ([`crate::serving::Session`]) runs on —
//! so offline sweep numbers and served responses are the same function
//! by construction (DESIGN.md §Serving).  [`crate::coordinator`]
//! parallelizes this sequential core across worker threads and caches
//! results.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::pool::{default_workers, run_indexed};
use crate::eval::metrics::topk_accuracy;
use crate::formats::{Format, PrecisionSpec};
use crate::hw;
use crate::nn::Network;
use crate::serving::{Backend, NativeBackend};
use crate::store::WeightStore;
use crate::tensor::Tensor;

/// Evaluation options shared by sweeps and the search.
#[derive(Clone, Copy, Debug)]
pub struct EvalOptions {
    /// number of eval samples (clamped to the eval set size)
    pub samples: usize,
    /// batch size for the native engine
    pub batch: usize,
}

impl Default for EvalOptions {
    fn default() -> Self {
        EvalOptions { samples: 128, batch: 32 }
    }
}

/// Result of evaluating one (network, format) configuration.
#[derive(Clone, Debug)]
pub struct ConfigResult {
    pub format: Format,
    /// top-k accuracy on the evaluated subset
    pub accuracy: f64,
    /// accuracy normalized to the exact baseline on the same subset
    pub normalized_accuracy: f64,
    /// hardware speedup over the SP-float baseline
    pub speedup: f64,
    /// hardware energy savings over the SP-float baseline
    pub energy_savings: f64,
}

/// Run a batch of `b <= fixed_batch` samples through a backend that
/// may be compiled at a static batch size: pad with zero samples up to
/// that size and truncate the logits back to `b`.  Zero padding cannot
/// perturb live rows — per-sample computation is independent
/// (DESIGN.md §3) — so the result is bit-identical to an unconstrained
/// backend's.  No-op pass-through for unconstrained backends.
fn run_padded(backend: &mut dyn Backend, x: &Tensor, spec: &PrecisionSpec) -> Result<Tensor> {
    let b = x.shape()[0];
    let Some(fb) = backend.fixed_batch().filter(|&fb| fb != b) else {
        return backend.run_spec(x, spec);
    };
    anyhow::ensure!(
        b < fb,
        "batch of {b} exceeds the backend's fixed batch size {fb}"
    );
    let mut shape = x.shape().to_vec();
    let px: usize = shape[1..].iter().product();
    shape[0] = fb;
    let mut data = x.data().to_vec();
    data.resize(fb * px, 0.0);
    let out = backend.run_spec(&Tensor::new(shape, data)?, spec)?;
    let classes = out.shape()[1];
    Tensor::new(vec![b, classes], out.data()[..b * classes].to_vec())
}

/// Forward the first `opts.samples` eval inputs through `backend`;
/// returns (logits, labels).  `spec` is anything convertible to a
/// [`PrecisionSpec`] — a `&Format` (the legacy single-format calls
/// compile unchanged), a per-layer `Plan`, or a `&PrecisionSpec`.
/// `opts.batch` is clamped to at least 1 (a zero batch would not
/// advance) and overridden by the backend's [`Backend::fixed_batch`]
/// when it has one, with the ragged tail zero-padded — so the same
/// driver runs on native AND PJRT backends.
pub fn forward_eval(
    backend: &mut dyn Backend,
    spec: impl Into<PrecisionSpec>,
    opts: &EvalOptions,
) -> Result<(Vec<f32>, Vec<i32>)> {
    let spec: PrecisionSpec = spec.into();
    let net = backend.network().clone();
    let n = opts.samples.min(net.eval_len()).max(1);
    let batch = backend.fixed_batch().unwrap_or_else(|| opts.batch.max(1));
    let classes = net.classes;
    let mut logits = Vec::with_capacity(n * classes);
    let mut lo = 0;
    while lo < n {
        let hi = (lo + batch).min(n);
        let xb = net.eval_x.slice_rows(lo, hi);
        let out = run_padded(backend, &xb, &spec)?;
        logits.extend_from_slice(out.data());
        lo = hi;
    }
    Ok((logits, net.eval_y[..n].to_vec()))
}

/// Batch-parallel [`forward_eval`]: the same batches, fanned out over
/// [`run_indexed`] with one scratch-buffer [`NativeBackend`] per worker
/// (DESIGN.md §7).  Per-sample computation is identical regardless of
/// which worker runs a batch, so the logits are bit-identical to the
/// sequential driver — only wall-clock changes.  This is what keeps a
/// design-space sweep saturating all cores even when it has fewer
/// formats in flight than the machine has cores (e.g. the baseline
/// evaluation every sweep starts with, or a single-config `eval`).
pub fn forward_eval_parallel(
    net: &Arc<Network>,
    spec: impl Into<PrecisionSpec>,
    opts: &EvalOptions,
    workers: usize,
) -> Result<(Vec<f32>, Vec<i32>)> {
    let store = Arc::new(WeightStore::default());
    forward_eval_parallel_in(net, spec, opts, workers, &store)
}

/// [`forward_eval_parallel`] staging from a caller-supplied
/// [`WeightStore`]: every worker's backend shares the store, so each
/// layer's weights are quantized ONCE for the whole pool instead of
/// once per worker (DESIGN.md §Storage) — and `repro eval
/// --weight-budget` can cap and report the staging memory.
pub fn forward_eval_parallel_in(
    net: &Arc<Network>,
    spec: impl Into<PrecisionSpec>,
    opts: &EvalOptions,
    workers: usize,
    store: &Arc<WeightStore>,
) -> Result<(Vec<f32>, Vec<i32>)> {
    forward_eval_parallel_exec(net, spec, opts, workers, store, false)
}

/// [`forward_eval_parallel_in`] with packed-domain execution opt-in
/// (`repro eval --packed-exec`; DESIGN.md §Packed execution): every
/// worker's backend runs admitted layers straight from the store's
/// bit-packed codes.  Bit-identical to the staged path by the packed
/// contract — only memory traffic changes.
pub fn forward_eval_parallel_exec(
    net: &Arc<Network>,
    spec: impl Into<PrecisionSpec>,
    opts: &EvalOptions,
    workers: usize,
    store: &Arc<WeightStore>,
    packed_exec: bool,
) -> Result<(Vec<f32>, Vec<i32>)> {
    let spec: PrecisionSpec = spec.into();
    let n = opts.samples.min(net.eval_len()).max(1);
    // same clamp as forward_eval, so both paths use identical batching
    let batch = opts.batch.max(1);
    let jobs: Vec<(usize, usize)> = (0..n)
        .step_by(batch)
        .map(|lo| (lo, (lo + batch).min(n)))
        .collect();
    if workers <= 1 || jobs.len() <= 1 {
        let mut backend =
            NativeBackend::with_store(net.clone(), store.clone()).with_packed_exec(packed_exec);
        return forward_eval(&mut backend, &spec, opts);
    }
    let spec = &spec;
    let chunks = run_indexed(
        &jobs,
        workers,
        || NativeBackend::with_store(net.clone(), store.clone()).with_packed_exec(packed_exec),
        |backend, &(lo, hi)| -> Result<Vec<f32>> {
            let xb = net.eval_x.slice_rows(lo, hi);
            Ok(backend.run_spec(&xb, spec)?.into_data())
        },
    );
    let mut logits = Vec::with_capacity(n * net.classes);
    for chunk in chunks {
        logits.extend_from_slice(&chunk?);
    }
    Ok((logits, net.eval_y[..n].to_vec()))
}

/// Forward specific eval indices (the search's 10-input probe, §3.3).
/// Chunked and zero-padded to the backend's [`Backend::fixed_batch`]
/// when it has one, like [`forward_eval`].  Accepts plans like every
/// eval driver.
pub fn forward_indices(
    backend: &mut dyn Backend,
    spec: impl Into<PrecisionSpec>,
    indices: &[usize],
) -> Result<Vec<f32>> {
    let spec: PrecisionSpec = spec.into();
    let net = backend.network().clone();
    let [h, w, c] = net.input;
    let px = h * w * c;
    let chunk = backend.fixed_batch().unwrap_or(indices.len()).max(1);
    let mut out = Vec::with_capacity(indices.len() * net.classes);
    for idx in indices.chunks(chunk) {
        let mut xdata = Vec::with_capacity(idx.len() * px);
        for &i in idx {
            xdata.extend_from_slice(&net.eval_x.data()[i * px..(i + 1) * px]);
        }
        let x = Tensor::new(vec![idx.len(), h, w, c], xdata)?;
        out.extend_from_slice(run_padded(backend, &x, &spec)?.data());
    }
    Ok(out)
}

/// Top-k accuracy of one configuration (uniform format or plan) on the
/// eval subset, with the batches spread over all cores (bit-identical
/// to the sequential path).
pub fn accuracy(
    net: &Arc<Network>,
    spec: impl Into<PrecisionSpec>,
    samples: usize,
) -> Result<f64> {
    let opts = EvalOptions { samples, ..Default::default() };
    let (logits, labels) = forward_eval_parallel(net, spec, &opts, default_workers())?;
    Ok(topk_accuracy(&logits, &labels, net.classes, net.topk))
}

/// [`accuracy`] staging from a caller-supplied (budgeted) weight store
/// — the `repro eval --weight-budget` path, which reports the store's
/// counters after the run.
pub fn accuracy_with_store(
    net: &Arc<Network>,
    spec: impl Into<PrecisionSpec>,
    samples: usize,
    store: &Arc<WeightStore>,
) -> Result<f64> {
    accuracy_with_store_exec(net, spec, samples, store, false)
}

/// [`accuracy_with_store`] with packed-domain execution opt-in — the
/// `repro eval --packed-exec` driver.  The accuracy is identical by the
/// packed bit-exactness contract; the flag exists so the store counters
/// (and wall-clock) reflect packed execution.
pub fn accuracy_with_store_exec(
    net: &Arc<Network>,
    spec: impl Into<PrecisionSpec>,
    samples: usize,
    store: &Arc<WeightStore>,
    packed_exec: bool,
) -> Result<f64> {
    let opts = EvalOptions { samples, ..Default::default() };
    let (logits, labels) =
        forward_eval_parallel_exec(net, spec, &opts, default_workers(), store, packed_exec)?;
    Ok(topk_accuracy(&logits, &labels, net.classes, net.topk))
}

/// Evaluate one configuration fully (accuracy + hardware efficiency).
/// `baseline_acc` is the exact-format accuracy on the *same* subset.
pub fn eval_config(
    backend: &mut dyn Backend,
    fmt: &Format,
    baseline_acc: f64,
    opts: &EvalOptions,
) -> Result<ConfigResult> {
    let (logits, labels) = forward_eval(backend, fmt, opts)?;
    let net = backend.network();
    let acc = topk_accuracy(&logits, &labels, net.classes, net.topk);
    let eff = hw::speedup::efficiency(fmt);
    Ok(ConfigResult {
        format: *fmt,
        accuracy: acc,
        normalized_accuracy: if baseline_acc > 0.0 { acc / baseline_acc } else { 0.0 },
        speedup: eff.speedup,
        energy_savings: eff.energy_savings,
    })
}

/// Sequentially sweep a set of formats (the coordinator parallelizes
/// this; sequential version kept for tests and small runs).
pub fn sweep_design_space(
    net: &Arc<Network>,
    formats: &[Format],
    opts: &EvalOptions,
) -> Result<Vec<ConfigResult>> {
    let mut backend = NativeBackend::new(net.clone());
    let (logits, labels) = forward_eval(&mut backend, &Format::SINGLE, opts)?;
    let baseline = topk_accuracy(&logits, &labels, net.classes, net.topk);
    formats
        .iter()
        .map(|f| eval_config(&mut backend, f, baseline, opts))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::fixtures::tiny_network;

    /// A native backend constrained to a static batch size, modelling
    /// the AOT/PJRT executables (which reject any other batch shape).
    struct FixedBatch(NativeBackend, usize);

    impl Backend for FixedBatch {
        fn run_spec(&mut self, x: &Tensor, spec: &PrecisionSpec) -> Result<Tensor> {
            anyhow::ensure!(
                x.shape()[0] == self.1,
                "batch {} != fixed batch {}",
                x.shape()[0],
                self.1
            );
            self.0.run_spec(x, spec)
        }

        fn network(&self) -> &Arc<Network> {
            self.0.network()
        }

        fn label(&self) -> &'static str {
            "fixed-native"
        }

        fn fixed_batch(&self) -> Option<usize> {
            Some(self.1)
        }
    }

    /// The eval drivers must serve a fixed-batch backend (chunk +
    /// zero-pad ragged tails) and produce logits bit-identical to an
    /// unconstrained backend's — the guarantee that lets PJRT run the
    /// same offline code paths as the native engine.
    #[test]
    fn fixed_batch_backend_is_bit_identical_on_ragged_tails() {
        let net = tiny_network(10);
        let fmt = Format::float(7, 6);
        let opts = EvalOptions { samples: 10, batch: 4 };
        let (free, labels_a) =
            forward_eval(&mut NativeBackend::new(net.clone()), &fmt, &opts).unwrap();
        let (fixed, labels_b) =
            forward_eval(&mut FixedBatch(NativeBackend::new(net.clone()), 4), &fmt, &opts)
                .unwrap();
        assert_eq!(labels_a, labels_b);
        assert_eq!(free.len(), fixed.len());
        for i in 0..free.len() {
            assert_eq!(free[i].to_bits(), fixed[i].to_bits(), "logit {i}");
        }

        // the probe path chunks + pads too
        let idx = [0usize, 3, 7, 9, 1];
        let a = forward_indices(&mut NativeBackend::new(net.clone()), &fmt, &idx).unwrap();
        let b =
            forward_indices(&mut FixedBatch(NativeBackend::new(net.clone()), 4), &fmt, &idx)
                .unwrap();
        assert_eq!(a.len(), b.len());
        for i in 0..a.len() {
            assert_eq!(a[i].to_bits(), b[i].to_bits(), "probe logit {i}");
        }

        // an over-size batch is a clean error, not a silent truncation
        let x = net.eval_x.slice_rows(0, 6);
        let spec = PrecisionSpec::from(fmt);
        assert!(run_padded(&mut FixedBatch(NativeBackend::new(net.clone()), 4), &x, &spec)
            .is_err());
    }
}
