//! Design-space evaluation: run a network under one or many customized
//! precision configurations and measure accuracy + last-layer activations.
//!
//! This is the sequential core; [`crate::coordinator`] parallelizes it
//! across worker threads and caches results.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::pool::{default_workers, run_indexed};
use crate::eval::metrics::topk_accuracy;
use crate::formats::Format;
use crate::hw;
use crate::nn::{Engine, Network};
use crate::tensor::Tensor;

/// Evaluation options shared by sweeps and the search.
#[derive(Clone, Copy, Debug)]
pub struct EvalOptions {
    /// number of eval samples (clamped to the eval set size)
    pub samples: usize,
    /// batch size for the native engine
    pub batch: usize,
}

impl Default for EvalOptions {
    fn default() -> Self {
        EvalOptions { samples: 128, batch: 32 }
    }
}

/// Result of evaluating one (network, format) configuration.
#[derive(Clone, Debug)]
pub struct ConfigResult {
    pub format: Format,
    /// top-k accuracy on the evaluated subset
    pub accuracy: f64,
    /// accuracy normalized to the exact baseline on the same subset
    pub normalized_accuracy: f64,
    /// hardware speedup over the SP-float baseline
    pub speedup: f64,
    /// hardware energy savings over the SP-float baseline
    pub energy_savings: f64,
}

/// Forward the first `opts.samples` eval inputs; returns (logits, labels).
/// `opts.batch` is clamped to at least 1 (a zero batch would not advance).
pub fn forward_eval(
    engine: &mut Engine,
    net: &Network,
    fmt: &Format,
    opts: &EvalOptions,
) -> (Vec<f32>, Vec<i32>) {
    let n = opts.samples.min(net.eval_len()).max(1);
    let batch = opts.batch.max(1);
    let classes = net.classes;
    let mut logits = Vec::with_capacity(n * classes);
    let mut lo = 0;
    while lo < n {
        let hi = (lo + batch).min(n);
        let xb = net.eval_x.slice_rows(lo, hi);
        let out = engine.forward(net, &xb, fmt);
        logits.extend_from_slice(out.data());
        lo = hi;
    }
    (logits, net.eval_y[..n].to_vec())
}

/// Batch-parallel [`forward_eval`]: the same batches, fanned out over
/// [`run_indexed`] with one scratch-buffer [`Engine`] per worker
/// (DESIGN.md §7).  Per-sample computation is identical regardless of
/// which worker runs a batch, so the logits are bit-identical to the
/// sequential driver — only wall-clock changes.  This is what keeps a
/// design-space sweep saturating all cores even when it has fewer
/// formats in flight than the machine has cores (e.g. the baseline
/// evaluation every sweep starts with, or a single-config `eval`).
pub fn forward_eval_parallel(
    net: &Network,
    fmt: &Format,
    opts: &EvalOptions,
    workers: usize,
) -> (Vec<f32>, Vec<i32>) {
    let n = opts.samples.min(net.eval_len()).max(1);
    // same clamp as forward_eval, so both paths use identical batching
    let batch = opts.batch.max(1);
    let jobs: Vec<(usize, usize)> = (0..n)
        .step_by(batch)
        .map(|lo| (lo, (lo + batch).min(n)))
        .collect();
    if workers <= 1 || jobs.len() <= 1 {
        let mut engine = Engine::new();
        return forward_eval(&mut engine, net, fmt, opts);
    }
    let chunks = run_indexed(&jobs, workers, Engine::new, |engine, &(lo, hi)| {
        let xb = net.eval_x.slice_rows(lo, hi);
        engine.forward(net, &xb, fmt).into_data()
    });
    let mut logits = Vec::with_capacity(n * net.classes);
    for chunk in chunks {
        logits.extend_from_slice(&chunk);
    }
    (logits, net.eval_y[..n].to_vec())
}

/// Forward specific eval indices (the search's 10-input probe, §3.3).
pub fn forward_indices(
    engine: &mut Engine,
    net: &Network,
    fmt: &Format,
    indices: &[usize],
) -> Vec<f32> {
    let [h, w, c] = net.input;
    let px = h * w * c;
    let mut xdata = Vec::with_capacity(indices.len() * px);
    for &i in indices {
        xdata.extend_from_slice(&net.eval_x.data()[i * px..(i + 1) * px]);
    }
    let x = Tensor::new(vec![indices.len(), h, w, c], xdata).unwrap();
    engine.forward(net, &x, fmt).into_data()
}

/// Top-k accuracy of one configuration on the eval subset, with the
/// batches spread over all cores (bit-identical to the sequential path).
pub fn accuracy(net: &Network, fmt: &Format, samples: usize) -> Result<f64> {
    let opts = EvalOptions { samples, ..Default::default() };
    let (logits, labels) = forward_eval_parallel(net, fmt, &opts, default_workers());
    Ok(topk_accuracy(&logits, &labels, net.classes, net.topk))
}

/// Evaluate one configuration fully (accuracy + hardware efficiency).
/// `baseline_acc` is the exact-format accuracy on the *same* subset.
pub fn eval_config(
    engine: &mut Engine,
    net: &Network,
    fmt: &Format,
    baseline_acc: f64,
    opts: &EvalOptions,
) -> ConfigResult {
    let (logits, labels) = forward_eval(engine, net, fmt, opts);
    let acc = topk_accuracy(&logits, &labels, net.classes, net.topk);
    let eff = hw::speedup::efficiency(fmt);
    ConfigResult {
        format: *fmt,
        accuracy: acc,
        normalized_accuracy: if baseline_acc > 0.0 { acc / baseline_acc } else { 0.0 },
        speedup: eff.speedup,
        energy_savings: eff.energy_savings,
    }
}

/// Sequentially sweep a set of formats (the coordinator parallelizes
/// this; sequential version kept for tests and small runs).
pub fn sweep_design_space(
    net: &Arc<Network>,
    formats: &[Format],
    opts: &EvalOptions,
) -> Vec<ConfigResult> {
    let mut engine = Engine::new();
    let (logits, labels) = forward_eval(&mut engine, net, &Format::SINGLE, opts);
    let baseline = topk_accuracy(&logits, &labels, net.classes, net.topk);
    formats
        .iter()
        .map(|f| eval_config(&mut engine, net, f, baseline, opts))
        .collect()
}
