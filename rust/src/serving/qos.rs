//! SLO-driven quality-of-service: admission control + priority scheduling.
//!
//! The Gateway serves many sessions from one process; under heavy traffic
//! the PR 2/5/6 telemetry (queue-latency percentiles, store counters)
//! must become *control inputs* (ROADMAP item 4).  This module is that
//! control layer, in two halves:
//!
//! * [`QosGate`] — per-session admission control.  A session opened with
//!   an [`SloTarget`] (p99 queue-latency budget + max queue depth) sheds
//!   new work with a typed, loud [`ShedError`] the moment its queue
//!   exceeds the depth bound or its sliding-window p99 exceeds the
//!   budget.  Reject-don't-collapse: every offered request is either
//!   served bit-exactly or refused visibly — never silently dropped —
//!   so `served + shed == offered` holds exactly (DESIGN.md §Serving
//!   QoS).  Sessions without an SLO are never shed (byte-for-byte the
//!   pre-QoS behavior).
//!
//! * [`QosScheduler`] — cross-session priority scheduling.  When the
//!   gateway models limited compute (`SessionOptions::qos_slots > 0`),
//!   each dispatcher acquires an execution [`Permit`] before running a
//!   batch.  Grants go to the waiter with the least SLO *headroom*
//!   (closest to violating its budget first); best-effort sessions
//!   (no SLO) have infinite headroom but a starvation floor guarantees
//!   they still progress: a waiter passed over [`STARVATION_FLOOR`]
//!   times is granted next regardless of headroom.
//!
//! The decision logic is pure and unit-tested ([`QosGate::admit`],
//! `pick`); the wiring lives in `serving::session` / `serving::gateway`.

use std::fmt;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::{bail, Result};

use crate::obs::{Counter, Registry};
use crate::serving::session::SessionKey;

/// Default queue-depth bound when an SLO names only a latency budget.
pub const DEFAULT_SLO_DEPTH: usize = 256;

/// Grants a waiter is passed over before it is scheduled unconditionally.
pub const STARVATION_FLOOR: u64 = 4;

// ---------------------------------------------------------------------------
// SLO target
// ---------------------------------------------------------------------------

/// A per-session service-level objective: sliding-window p99 queue-latency
/// budget plus a hard queue-depth bound (the shedding inputs).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SloTarget {
    /// p99 queue-latency budget in milliseconds (sliding window,
    /// `SessionStats::p99_queue_ms`).
    pub p99_ms: f64,
    /// Maximum admitted-but-uncompleted requests before depth shedding.
    pub max_depth: usize,
}

impl SloTarget {
    /// Validated constructor: the budget must be a positive finite number
    /// of milliseconds and the depth bound at least 1.
    pub fn new(p99_ms: f64, max_depth: usize) -> Result<SloTarget> {
        if !p99_ms.is_finite() || p99_ms <= 0.0 {
            bail!("slo p99 budget must be a positive number of ms, got {p99_ms}");
        }
        if max_depth == 0 {
            bail!("slo max queue depth must be >= 1");
        }
        Ok(SloTarget { p99_ms, max_depth })
    }

    /// Parse the CLI spelling: `"<budget>ms"` or `"<budget>ms:<depth>"`,
    /// e.g. `20ms` (depth defaults to [`DEFAULT_SLO_DEPTH`]) or `5ms:64`.
    pub fn parse(s: &str) -> Result<SloTarget> {
        let (budget, depth) = match s.split_once(':') {
            Some((b, d)) => (b, Some(d)),
            None => (s, None),
        };
        let Some(ms) = budget.strip_suffix("ms") else {
            bail!("bad SLO '{s}': expected '<budget>ms[:<depth>]', e.g. 20ms or 5ms:64");
        };
        let p99_ms: f64 = ms
            .parse()
            .map_err(|_| anyhow::anyhow!("bad SLO '{s}': '{ms}' is not a number of ms"))?;
        let max_depth = match depth {
            Some(d) => d
                .parse()
                .map_err(|_| anyhow::anyhow!("bad SLO '{s}': '{d}' is not a queue depth"))?,
            None => DEFAULT_SLO_DEPTH,
        };
        SloTarget::new(p99_ms, max_depth)
    }
}

impl fmt::Display for SloTarget {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}ms:{}", self.p99_ms, self.max_depth)
    }
}

// ---------------------------------------------------------------------------
// Typed shed error
// ---------------------------------------------------------------------------

/// Why a request was shed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// The session's queue depth reached `SloTarget::max_depth`.
    Depth,
    /// The session's sliding-window p99 queue latency exceeded
    /// `SloTarget::p99_ms` (only enforced while a backlog exists, so a
    /// drained session always recovers — see [`QosGate::admit`]).
    Latency,
    /// No session is routed for the key (closed or never opened); the
    /// open-loop driver records unrouted fires as sheds so
    /// `served + shed == offered` holds exactly under churn.
    Closed,
}

impl ShedReason {
    pub fn as_str(&self) -> &'static str {
        match self {
            ShedReason::Depth => "depth",
            ShedReason::Latency => "latency",
            ShedReason::Closed => "closed",
        }
    }
}

/// Typed, loud rejection: admission control refused a request.
///
/// Carried as the `anyhow` error of `Session::infer_async` (and the
/// typed `Session::submit`), so callers distinguish shedding from real
/// failures with `err.downcast_ref::<ShedError>()`.
#[derive(Clone, Debug)]
pub struct ShedError {
    /// Which session shed.
    pub key: SessionKey,
    /// Which bound tripped.
    pub reason: ShedReason,
    /// Queue depth observed at the decision.
    pub depth: usize,
    /// Sliding-window p99 queue latency (ms) observed at the decision.
    pub p99_ms: f64,
    /// The violated target (`None` for [`ShedReason::Closed`], which is
    /// routing state, not an SLO decision).
    pub slo: Option<SloTarget>,
}

impl ShedError {
    /// Shed record for a request fired at a key with no routed session.
    pub fn closed(key: SessionKey) -> ShedError {
        ShedError {
            key,
            reason: ShedReason::Closed,
            depth: 0,
            p99_ms: 0.0,
            slo: None,
        }
    }
}

impl fmt::Display for ShedError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.slo {
            Some(slo) => write!(
                f,
                "session {} shed request ({}): queue depth {}, window p99 {:.3}ms, slo {}",
                self.key,
                self.reason.as_str(),
                self.depth,
                self.p99_ms,
                slo
            ),
            None => write!(f, "session {} shed request (closed): no session routed", self.key),
        }
    }
}

impl std::error::Error for ShedError {}

// ---------------------------------------------------------------------------
// Admission gate
// ---------------------------------------------------------------------------

/// Per-session admission control state.  Shared (`Arc`) between the
/// submitting side (`Session::submit` calls [`QosGate::admit`]) and the
/// dispatcher (which completes requests and publishes the window p99).
///
/// Depth accounting is exact: `admit` increments with a compare-and-swap
/// loop that refuses to exceed `max_depth`, and the dispatcher decrements
/// *before* replies are delivered, so `depth == admitted - completed`
/// never over-counts a request the caller has already seen answered.
#[derive(Debug)]
pub struct QosGate {
    key: SessionKey,
    slo: Option<SloTarget>,
    /// Admitted-but-uncompleted requests (queued + in the running batch).
    depth: AtomicUsize,
    /// Shed counters as `obs` cells so the gateway's registry can adopt
    /// the SAME atomics the stats path reads (DESIGN.md §Observability).
    shed_depth: Arc<Counter>,
    shed_latency: Arc<Counter>,
    /// Latest sliding-window p99 queue latency, as `f64::to_bits`.
    p99_bits: AtomicU64,
}

impl QosGate {
    pub fn new(key: SessionKey, slo: Option<SloTarget>) -> QosGate {
        QosGate {
            key,
            slo,
            depth: AtomicUsize::new(0),
            shed_depth: Arc::new(Counter::new()),
            shed_latency: Arc::new(Counter::new()),
            p99_bits: AtomicU64::new(0.0f64.to_bits()),
        }
    }

    /// Adopt this gate's shed counters into `reg` under
    /// `session/<key>/shed_*` names — the registry reads the same cells
    /// [`QosGate::shed_depth`]/[`QosGate::shed_latency`] count into.
    pub fn register_into(&self, reg: &Registry) {
        reg.adopt_counter(&format!("session/{}/shed_depth", self.key), &self.shed_depth);
        reg.adopt_counter(&format!("session/{}/shed_latency", self.key), &self.shed_latency);
    }

    pub fn slo(&self) -> Option<SloTarget> {
        self.slo
    }

    /// Admit or shed one request.  Decision table (DESIGN.md §Serving QoS):
    ///
    /// | SLO  | window p99 > budget   | depth < max_depth | outcome         |
    /// |------|-----------------------|-------------------|-----------------|
    /// | none | —                     | —                 | admit           |
    /// | set  | yes, and depth > 0    | —                 | shed (latency)  |
    /// | set  | no, or depth == 0     | yes               | admit           |
    /// | set  | no, or depth == 0     | no                | shed (depth)    |
    ///
    /// The latency bound only sheds while a backlog exists (`depth > 0`):
    /// the window percentile is history, and once the queue has fully
    /// drained the next request cannot inherit the old wait — without the
    /// backlog condition a session would stay wedged shut long after
    /// recovering.
    pub fn admit(&self) -> Result<(), ShedError> {
        let Some(slo) = self.slo else {
            // Best-effort session: never shed, but still track depth so
            // the stats table shows backlog.
            self.depth.fetch_add(1, Ordering::AcqRel);
            return Ok(());
        };
        let p99_ms = self.window_p99_ms();
        if p99_ms > slo.p99_ms {
            let depth = self.depth.load(Ordering::Acquire);
            if depth > 0 {
                self.shed_latency.incr();
                return Err(ShedError {
                    key: self.key.clone(),
                    reason: ShedReason::Latency,
                    depth,
                    p99_ms,
                    slo: Some(slo),
                });
            }
        }
        // Compare-and-increment: depth never exceeds max_depth, even with
        // concurrent submitters racing.
        match self
            .depth
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |d| {
                (d < slo.max_depth).then_some(d + 1)
            }) {
            Ok(_) => Ok(()),
            Err(depth) => {
                self.shed_depth.incr();
                Err(ShedError {
                    key: self.key.clone(),
                    reason: ShedReason::Depth,
                    depth,
                    p99_ms,
                    slo: Some(slo),
                })
            }
        }
    }

    /// Mark `n` admitted requests complete (replied or withdrawn).
    pub(crate) fn on_completed(&self, n: usize) {
        // Saturating: a stray extra decrement must not wrap the gate open.
        let _ = self
            .depth
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |d| {
                Some(d.saturating_sub(n))
            });
    }

    /// Publish the dispatcher's sliding-window p99 queue latency (ms).
    pub(crate) fn record_p99_ms(&self, p99_ms: f64) {
        self.p99_bits.store(p99_ms.to_bits(), Ordering::Release);
    }

    /// Current admitted-but-uncompleted request count.
    pub fn depth(&self) -> usize {
        self.depth.load(Ordering::Acquire)
    }

    /// Latest published sliding-window p99 queue latency (ms).
    pub fn window_p99_ms(&self) -> f64 {
        f64::from_bits(self.p99_bits.load(Ordering::Acquire))
    }

    pub fn shed_depth(&self) -> u64 {
        self.shed_depth.get()
    }

    pub fn shed_latency(&self) -> u64 {
        self.shed_latency.get()
    }

    /// Total requests shed by this gate.
    pub fn shed_total(&self) -> u64 {
        self.shed_depth() + self.shed_latency()
    }

    /// SLO headroom in `(-inf, 1]`: the min of the latency margin
    /// `(budget - p99) / budget` and the depth margin
    /// `1 - depth / max_depth`.  `<= 0` means at/over the bound;
    /// best-effort sessions report `f64::INFINITY` (always last pick,
    /// modulo the starvation floor).
    pub fn headroom(&self) -> f64 {
        let Some(slo) = self.slo else {
            return f64::INFINITY;
        };
        let lat = (slo.p99_ms - self.window_p99_ms()) / slo.p99_ms;
        let dep = 1.0 - self.depth() as f64 / slo.max_depth as f64;
        lat.min(dep)
    }
}

// ---------------------------------------------------------------------------
// Priority scheduler
// ---------------------------------------------------------------------------

/// Cross-session execution-permit scheduler.
///
/// Models limited compute: at most `slots` batches run concurrently
/// gateway-wide.  Dispatchers call [`QosScheduler::acquire`] before
/// `Backend::run_spec`; the returned [`Permit`] releases the slot on
/// drop.  Among waiting dispatchers the grant goes to the one whose
/// [`QosGate::headroom`] is smallest (closest to violating its SLO),
/// except that any waiter already passed over [`STARVATION_FLOOR`] times
/// is granted first (oldest such waiter wins) so best-effort sessions
/// cannot starve.
///
/// With `SessionOptions::qos_slots == 0` (the default) no scheduler is
/// built and dispatch order is exactly the pre-QoS behavior.
#[derive(Debug)]
pub struct QosScheduler {
    slots: usize,
    state: Mutex<SchedState>,
    cv: Condvar,
}

#[derive(Debug, Default)]
struct SchedState {
    in_flight: usize,
    next_ticket: u64,
    waiters: Vec<Waiter>,
}

#[derive(Debug)]
struct Waiter {
    ticket: u64,
    gate: Arc<QosGate>,
    passed_over: u64,
}

impl QosScheduler {
    /// `slots` is the number of concurrent batch executions permitted.
    pub fn new(slots: usize) -> Arc<QosScheduler> {
        assert!(slots >= 1, "QosScheduler needs at least one slot");
        Arc::new(QosScheduler {
            slots,
            state: Mutex::new(SchedState::default()),
            cv: Condvar::new(),
        })
    }

    pub fn slots(&self) -> usize {
        self.slots
    }

    /// Number of dispatchers currently waiting for a slot.
    pub fn waiting(&self) -> usize {
        self.lock().waiters.len()
    }

    /// Block until this gate's dispatcher is granted an execution slot.
    pub fn acquire(self: &Arc<Self>, gate: &Arc<QosGate>) -> Permit {
        let mut st = self.lock();
        let ticket = st.next_ticket;
        st.next_ticket += 1;
        st.waiters.push(Waiter {
            ticket,
            gate: gate.clone(),
            passed_over: 0,
        });
        loop {
            if st.in_flight < self.slots {
                let ranked: Vec<(u64, f64, u64)> = st
                    .waiters
                    .iter()
                    .map(|w| (w.ticket, w.gate.headroom(), w.passed_over))
                    .collect();
                let idx = pick(&ranked).expect("acquire: at least this waiter is queued");
                if st.waiters[idx].ticket == ticket {
                    st.waiters.swap_remove(idx);
                    st.in_flight += 1;
                    // Everyone left behind was passed over by this grant.
                    for w in &mut st.waiters {
                        w.passed_over += 1;
                    }
                    return Permit {
                        sched: self.clone(),
                    };
                }
                // A different waiter is next in line; wake it and wait.
                self.cv.notify_all();
            }
            st = self
                .cv
                .wait(st)
                .unwrap_or_else(std::sync::PoisonError::into_inner);
        }
    }

    fn release(&self) {
        let mut st = self.lock();
        st.in_flight = st.in_flight.saturating_sub(1);
        drop(st);
        self.cv.notify_all();
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, SchedState> {
        self.state
            .lock()
            .unwrap_or_else(std::sync::PoisonError::into_inner)
    }
}

/// An execution slot; releases (and wakes waiters) on drop.
#[derive(Debug)]
pub struct Permit {
    sched: Arc<QosScheduler>,
}

impl Drop for Permit {
    fn drop(&mut self) {
        self.sched.release();
    }
}

/// Pure selection policy over `(ticket, headroom, passed_over)` waiters:
/// the oldest waiter at/over the starvation floor wins; otherwise the
/// waiter with the least headroom (ties to the oldest ticket).
fn pick(waiters: &[(u64, f64, u64)]) -> Option<usize> {
    if waiters.is_empty() {
        return None;
    }
    let starved = waiters
        .iter()
        .enumerate()
        .filter(|(_, w)| w.2 >= STARVATION_FLOOR)
        .min_by_key(|(_, w)| w.0);
    if let Some((i, _)) = starved {
        return Some(i);
    }
    waiters
        .iter()
        .enumerate()
        .min_by(|(_, a), (_, b)| a.1.total_cmp(&b.1).then(a.0.cmp(&b.0)))
        .map(|(i, _)| i)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::PrecisionSpec;
    use std::sync::atomic::AtomicUsize;
    use std::time::Duration;

    fn key(name: &str) -> SessionKey {
        SessionKey {
            net: name.to_string(),
            spec: "float:m7e6".parse::<PrecisionSpec>().unwrap(),
        }
    }

    // -- SloTarget ----------------------------------------------------------

    #[test]
    fn slo_parse_accepts_budget_and_depth() {
        let s = SloTarget::parse("20ms").unwrap();
        assert_eq!(s.p99_ms, 20.0);
        assert_eq!(s.max_depth, DEFAULT_SLO_DEPTH);

        let s = SloTarget::parse("5ms:64").unwrap();
        assert_eq!(s.p99_ms, 5.0);
        assert_eq!(s.max_depth, 64);

        let s = SloTarget::parse("0.5ms:8").unwrap();
        assert_eq!(s.p99_ms, 0.5);
        assert_eq!(s.max_depth, 8);
    }

    #[test]
    fn slo_parse_rejects_malformed() {
        for bad in ["", "20", "20s", "ms", "xms", "20ms:", "20ms:x", "20ms:0", "-3ms", "0ms"] {
            assert!(SloTarget::parse(bad).is_err(), "'{bad}' should not parse");
        }
    }

    #[test]
    fn slo_display_round_trips() {
        let s = SloTarget::parse("5ms:64").unwrap();
        assert_eq!(SloTarget::parse(&s.to_string()).unwrap(), s);
    }

    // -- QosGate ------------------------------------------------------------

    #[test]
    fn gate_without_slo_always_admits_and_tracks_depth() {
        let g = QosGate::new(key("a"), None);
        for _ in 0..1000 {
            g.admit().unwrap();
        }
        assert_eq!(g.depth(), 1000);
        assert_eq!(g.shed_total(), 0);
        g.on_completed(1000);
        assert_eq!(g.depth(), 0);
    }

    #[test]
    fn gate_sheds_on_depth_bound_and_recovers() {
        let g = QosGate::new(key("a"), Some(SloTarget::new(50.0, 4).unwrap()));
        for _ in 0..4 {
            g.admit().unwrap();
        }
        let err = g.admit().unwrap_err();
        assert_eq!(err.reason, ShedReason::Depth);
        assert_eq!(err.depth, 4);
        assert_eq!(g.shed_depth(), 1);
        assert_eq!(g.depth(), 4);

        g.on_completed(2);
        assert_eq!(g.depth(), 2);
        g.admit().unwrap();
        g.admit().unwrap();
        assert_eq!(g.admit().unwrap_err().reason, ShedReason::Depth);
    }

    #[test]
    fn gate_depth_bound_is_exact_under_contention() {
        let g = Arc::new(QosGate::new(key("a"), Some(SloTarget::new(50.0, 16).unwrap())));
        let admitted = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..8 {
                let g = g.clone();
                let admitted = admitted.clone();
                s.spawn(move || {
                    for _ in 0..100 {
                        if g.admit().is_ok() {
                            admitted.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                });
            }
        });
        // Exactly max_depth admissions succeed; every other attempt is a
        // counted depth shed, and the books balance.
        assert_eq!(admitted.load(Ordering::Relaxed), 16);
        assert_eq!(g.depth(), 16);
        assert_eq!(g.shed_depth(), 800 - 16);
    }

    #[test]
    fn gate_latency_shed_requires_backlog() {
        let g = QosGate::new(key("a"), Some(SloTarget::new(5.0, 64).unwrap()));
        g.record_p99_ms(12.0);
        // Over budget but fully drained: the next request cannot inherit
        // the historical wait, so it is admitted (recovery rule).
        g.admit().unwrap();
        // Now a backlog exists and the window is still over budget: shed.
        let err = g.admit().unwrap_err();
        assert_eq!(err.reason, ShedReason::Latency);
        assert_eq!(err.p99_ms, 12.0);
        assert_eq!(g.shed_latency(), 1);
        // Window recovers: admission resumes even with the backlog.
        g.record_p99_ms(1.0);
        g.admit().unwrap();
        assert_eq!(g.depth(), 2);
    }

    #[test]
    fn shed_error_downcasts_through_anyhow() {
        let g = QosGate::new(key("a"), Some(SloTarget::new(50.0, 1).unwrap()));
        g.admit().unwrap();
        let err = anyhow::Error::new(g.admit().unwrap_err());
        let shed = err.downcast_ref::<ShedError>().expect("typed shed");
        assert_eq!(shed.reason, ShedReason::Depth);
        assert_eq!(shed.key, key("a"));
    }

    #[test]
    fn headroom_orders_sessions_by_slo_pressure() {
        let best_effort = QosGate::new(key("be"), None);
        assert_eq!(best_effort.headroom(), f64::INFINITY);

        let g = QosGate::new(key("a"), Some(SloTarget::new(10.0, 10).unwrap()));
        assert_eq!(g.headroom(), 1.0);
        g.record_p99_ms(5.0); // latency margin 0.5, depth margin 1.0
        assert_eq!(g.headroom(), 0.5);
        for _ in 0..8 {
            g.admit().unwrap(); // depth margin 0.2 < latency margin
        }
        assert!((g.headroom() - 0.2).abs() < 1e-12);
        g.record_p99_ms(20.0); // over budget: headroom goes negative
        assert!(g.headroom() < 0.0);
    }

    // -- pick() policy ------------------------------------------------------

    #[test]
    fn pick_prefers_least_headroom_then_oldest() {
        assert_eq!(pick(&[]), None);
        // (ticket, headroom, passed_over)
        let w = [(0, 0.9, 0), (1, 0.1, 0), (2, 0.5, 0)];
        assert_eq!(pick(&w), Some(1));
        // Tie on headroom: oldest ticket wins.
        let w = [(7, 0.3, 0), (3, 0.3, 0)];
        assert_eq!(pick(&w), Some(1));
    }

    #[test]
    fn pick_starvation_floor_overrides_headroom() {
        // The best-effort waiter (infinite headroom) has been passed over
        // STARVATION_FLOOR times: it goes first despite an SLO waiter
        // being near violation.
        let w = [
            (0, f64::INFINITY, STARVATION_FLOOR),
            (1, 0.01, 0),
            (2, f64::INFINITY, STARVATION_FLOOR + 2),
        ];
        // Oldest starved waiter wins (ticket 0).
        assert_eq!(pick(&w), Some(0));
        // Below the floor, headroom rules.
        let w = [(0, f64::INFINITY, STARVATION_FLOOR - 1), (1, 0.01, 0)];
        assert_eq!(pick(&w), Some(1));
    }

    // -- QosScheduler -------------------------------------------------------

    #[test]
    fn scheduler_enforces_slot_bound() {
        let sched = QosScheduler::new(1);
        let gate = Arc::new(QosGate::new(key("a"), None));
        let running = Arc::new(AtomicUsize::new(0));
        let peak = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for _ in 0..4 {
                let sched = sched.clone();
                let gate = gate.clone();
                let running = running.clone();
                let peak = peak.clone();
                s.spawn(move || {
                    for _ in 0..10 {
                        let permit = sched.acquire(&gate);
                        let now = running.fetch_add(1, Ordering::SeqCst) + 1;
                        peak.fetch_max(now, Ordering::SeqCst);
                        std::thread::sleep(Duration::from_micros(50));
                        running.fetch_sub(1, Ordering::SeqCst);
                        drop(permit);
                    }
                });
            }
        });
        assert_eq!(peak.load(Ordering::SeqCst), 1, "slot bound violated");
        assert_eq!(sched.waiting(), 0);
    }

    #[test]
    fn scheduler_grants_all_waiters_no_deadlock() {
        let sched = QosScheduler::new(2);
        let tight = Arc::new(QosGate::new(
            key("tight"),
            Some(SloTarget::new(1.0, 2).unwrap()),
        ));
        let be = Arc::new(QosGate::new(key("be"), None));
        let done = Arc::new(AtomicUsize::new(0));
        std::thread::scope(|s| {
            for i in 0..6 {
                let sched = sched.clone();
                let gate = if i % 2 == 0 { tight.clone() } else { be.clone() };
                let done = done.clone();
                s.spawn(move || {
                    for _ in 0..20 {
                        let _permit = sched.acquire(&gate);
                        std::thread::sleep(Duration::from_micros(20));
                        done.fetch_add(1, Ordering::SeqCst);
                    }
                });
            }
        });
        // Starvation floor + release wakeups: every acquisition completes.
        assert_eq!(done.load(Ordering::SeqCst), 120);
    }
}
