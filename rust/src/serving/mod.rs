//! The unified execution API: sessions, backends, and the multi-model
//! serving gateway.
//!
//! The paper's pitch is serving production DNNs under customized
//! precision; comparing formats fairly requires **one execution
//! substrate with swappable precision**.  This module is that
//! substrate's front door:
//!
//! * [`Backend`] — the object-safe batch executor every code path runs
//!   through: the native engine ([`NativeBackend`]) or the AOT/PJRT
//!   executable (`PjrtBackend`, `pjrt` feature).  The offline drivers
//!   (`eval`, `search`, the sweep coordinator) execute through the same
//!   trait as the request path, so sweep numbers and served responses
//!   are the same function by construction (bit-identity is
//!   integration-tested).
//! * [`Session`] — one hosted `(network, precision spec)` pair, where
//!   the spec is a uniform format or a per-layer mixed-precision plan
//!   (`net@plan:...` keys; uniform plans are bit-identical to the
//!   single-format session they spell out — DESIGN.md §Mixed
//!   precision): [`Session::open`] → [`Session::infer`] /
//!   [`Session::run_batch`] / [`Session::stats`].  Single-sample
//!   requests are dynamically batched to the execution batch size with
//!   a bounded queueing delay.
//! * [`Gateway`] — N concurrent sessions keyed by `(network, spec)`
//!   with per-key routing, hot add/remove, and live aggregate
//!   telemetry ([`GatewayStats`] — requests, batches, padded slots,
//!   p50/p99 queue latency, queue depth, shed counts, and shared
//!   weight-store counters per session).  All native sessions of one
//!   gateway stage weights from ONE [`crate::store::WeightStore`], so
//!   sessions whose specs resolve a layer to the same format share its
//!   pre-quantized tensor (`--weight-budget`; DESIGN.md §Storage).
//! * **QoS** ([`SloTarget`], [`QosGate`], [`QosScheduler`]) — the
//!   control layer over that telemetry (DESIGN.md §Serving QoS): a
//!   session opened with an SLO (p99 queue-latency budget + max queue
//!   depth, `--slo`) sheds excess load with a typed, loud
//!   [`ShedError`] instead of queueing without bound, and a gateway
//!   with `--qos-slots` drains sessions by SLO headroom
//!   (closest-to-violation first, with a starvation floor).  The
//!   open-loop trace-driven load generator ([`ArrivalSchedule`],
//!   [`drive_open_loop`]) fires requests at schedule time regardless
//!   of completions — the only drive mode where shedding and queue
//!   growth are observable — and accounts every offered request
//!   exactly once (`served + shed == offered`).
//! * **Observability** ([`crate::obs`], DESIGN.md §Observability) — a
//!   gateway registers its store and sessions into one lock-free
//!   metrics [`crate::obs::Registry`] ([`Gateway::registry`]), streams
//!   typed lifecycle/shed/store/alert events into an
//!   [`crate::obs::EventSink`] ([`Gateway::with_events`],
//!   `--events-out`), evaluates per-session SLO burn rates on the
//!   stats path (the `burn` column of [`GatewayStats::render`]), and
//!   captures per-layer forward profiles when a session is opened with
//!   [`SessionOptions::profile`] (`--profile`).
//!
//! ```no_run
//! use precis::formats::Format;
//! use precis::nn::Zoo;
//! use precis::serving::{BackendKind, Gateway};
//!
//! let zoo = Zoo::load("artifacts").unwrap();
//! let gw = Gateway::new(zoo, BackendKind::Native);
//! let lenet = gw.open("lenet5", Format::parse("float:m7e6").unwrap()).unwrap();
//! let alex = gw.open("alexnet-mini", Format::parse("fixed:l8r8").unwrap()).unwrap();
//! let sample = vec![0.0; 28 * 28]; // one lenet5 input
//! let logits = gw.infer(&lenet, sample).unwrap();
//! println!("{logits:?}\n{}", gw.stats().render());
//! # let _ = alex;
//! ```

mod backend;
mod gateway;
mod loadgen;
mod qos;
mod session;

#[cfg(feature = "pjrt")]
pub use backend::PjrtBackend;
pub use backend::{Backend, BackendFactory, BackendKind, NativeBackend};
pub use gateway::{Gateway, GatewayStats};
pub use loadgen::{
    drive_closed_loop, drive_open_loop, warm_up, ArrivalSchedule, ArrivalShape, ClosedLoop,
    DriveFailure, DriveReport, FailureKind, ServedRequest,
};
pub use qos::{
    QosGate, QosScheduler, ShedError, ShedReason, SloTarget, DEFAULT_SLO_DEPTH, STARVATION_FLOOR,
};
pub use session::{
    QUEUE_LAT_WINDOW, Session, SessionKey, SessionOptions, SessionStats, SubmitError,
    split_session_specs,
};
