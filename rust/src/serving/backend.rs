//! The pluggable execution backend behind every [`crate::serving::Session`].
//!
//! [`Backend`] is the **one execution substrate** of the crate: the
//! offline drivers (`eval`, `search`, the sweep coordinator) and the
//! online request path (`Session` / `Gateway`) all run batches through
//! this trait, so comparing numeric formats never compares two
//! different forward passes (DESIGN.md §Serving).
//!
//! Construction is unified behind [`BackendKind`] + the session
//! factory: PJRT handles are not `Send` (the xla crate wraps raw
//! pointers in `Rc`), so a [`BackendFactory`] — which *is* `Send` — is
//! what crosses threads, and the backend itself is built on the
//! session's dispatcher thread and never leaves it.  That used to be a
//! public contortion of the old `InferenceServer::spawn`; it is now an
//! implementation detail.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{bail, Result};

use crate::formats::Format;
use crate::nn::{Engine, Network};
use crate::tensor::Tensor;

/// Anything that can run a batch (B, H, W, C) -> (B, classes) under a
/// customized-precision format.  Object-safe; see the module docs for
/// the one-substrate guarantee.
pub trait Backend {
    /// Execute one batch of inputs, returning the logits.
    fn run_batch(&mut self, x: &Tensor, fmt: &Format) -> Result<Tensor>;

    /// The network this backend executes.
    fn network(&self) -> &Arc<Network>;

    /// Short telemetry label (`"native"` / `"pjrt"`).
    fn label(&self) -> &'static str;

    /// The only batch size this backend can execute, when constrained
    /// (the AOT/PJRT executables are compiled at a static batch size);
    /// `None` means any batch works.  Drivers pad partial batches with
    /// zero samples up to this size and truncate the logits — zero
    /// padding cannot perturb live rows, since per-sample computation
    /// is independent (DESIGN.md §3).
    fn fixed_batch(&self) -> Option<usize> {
        None
    }
}

/// Builds a backend **on the thread that calls it** (the session
/// dispatcher).  The factory is `Send` even when the backend it builds
/// is not.
pub type BackendFactory = Box<dyn FnOnce() -> Result<Box<dyn Backend>> + Send + 'static>;

/// Which execution backend a [`crate::serving::Session`] should open.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// The pure-Rust engine — always available, bit-exact with the
    /// Pallas/PJRT path by contract (DESIGN.md §3).
    Native,
    /// The AOT/PJRT executable (`pjrt` feature + artifacts required).
    /// The backend is built lazily on the session's dispatcher thread,
    /// so `open` itself succeeds and an unavailable runtime surfaces
    /// as a hard `backend init failed` error on every request — never
    /// as a silent native fallback.  Drivers send one warm-up request
    /// per session ([`crate::serving::warm_up`]) to fail fast.
    Pjrt,
    /// PJRT when it can be brought up, otherwise the native engine.
    Auto,
}

impl BackendKind {
    /// Parse the CLI spelling (`native` / `pjrt` / `auto`).
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "native" => Ok(BackendKind::Native),
            "pjrt" => Ok(BackendKind::Pjrt),
            "auto" => Ok(BackendKind::Auto),
            other => bail!("unknown backend {other:?} (native|pjrt|auto)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
            BackendKind::Auto => "auto",
        }
    }
}

/// The native-engine backend: one scratch-buffer engine bound to one
/// network (zero heap allocations per forward after warm-up).
pub struct NativeBackend {
    net: Arc<Network>,
    engine: Engine,
}

impl NativeBackend {
    pub fn new(net: Arc<Network>) -> NativeBackend {
        NativeBackend { net, engine: Engine::new() }
    }

    /// Run only the first `n_layers` layers and return the intermediate
    /// activation — the Fig 8 accumulation study taps a convolution's
    /// input this way.  Native-only: the AOT artifacts expose logits,
    /// not intermediate activations.
    pub fn forward_prefix(&mut self, x: &Tensor, fmt: &Format, n_layers: usize) -> Tensor {
        self.engine.forward_prefix(&self.net, x, fmt, n_layers)
    }
}

impl Backend for NativeBackend {
    fn run_batch(&mut self, x: &Tensor, fmt: &Format) -> Result<Tensor> {
        Ok(self.engine.forward(&self.net, x, fmt))
    }

    fn network(&self) -> &Arc<Network> {
        &self.net
    }

    fn label(&self) -> &'static str {
        "native"
    }
}

/// The PJRT backend: the AOT artifact executable (`pjrt` feature only;
/// DESIGN.md §5).  Built by the session factory on the dispatcher
/// thread — it cannot cross threads.
#[cfg(feature = "pjrt")]
pub struct PjrtBackend {
    pub model: crate::runtime::LoadedModel,
}

#[cfg(feature = "pjrt")]
impl Backend for PjrtBackend {
    fn run_batch(&mut self, x: &Tensor, fmt: &Format) -> Result<Tensor> {
        self.model.run_batch(x, fmt)
    }

    fn network(&self) -> &Arc<Network> {
        &self.model.net
    }

    fn label(&self) -> &'static str {
        "pjrt"
    }

    fn fixed_batch(&self) -> Option<usize> {
        Some(self.model.batch)
    }
}

/// Bring up the PJRT backend for `(net, fmt)` at the artifact batch
/// size, or fail with a pointer at the feature / the missing artifact.
#[cfg(feature = "pjrt")]
fn pjrt_backend(
    net: &Arc<Network>,
    dir: &Path,
    batch: usize,
    fmt: &Format,
) -> Result<Box<dyn Backend>> {
    let kind = if fmt.is_float() { "float" } else { "fixed" };
    let hlo = net.hlo_path(dir, kind)?;
    anyhow::ensure!(hlo.exists(), "missing HLO artifact {}", hlo.display());
    let rt = crate::runtime::Runtime::cpu()?;
    let model = rt.load_network(net, dir, kind, batch)?;
    Ok(Box::new(PjrtBackend { model }))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backend(
    _net: &Arc<Network>,
    _dir: &Path,
    _batch: usize,
    _fmt: &Format,
) -> Result<Box<dyn Backend>> {
    bail!("this build has no PJRT runtime; rebuild with `--features pjrt` (DESIGN.md §5)")
}

/// The unified construction path: a `Send` factory that resolves
/// `kind` on the dispatcher thread.  `Auto` degrades to the native
/// engine with a note on stderr; `Pjrt` makes unavailability a hard
/// error so a silent native run can never be mislabeled as pjrt.
pub(crate) fn make_factory(
    net: Arc<Network>,
    dir: PathBuf,
    batch: usize,
    fmt: Format,
    kind: BackendKind,
) -> BackendFactory {
    Box::new(move || match kind {
        BackendKind::Native => Ok(Box::new(NativeBackend::new(net)) as Box<dyn Backend>),
        BackendKind::Pjrt => pjrt_backend(&net, &dir, batch, &fmt),
        BackendKind::Auto => match pjrt_backend(&net, &dir, batch, &fmt) {
            Ok(b) => Ok(b),
            Err(e) => {
                eprintln!(
                    "(PJRT unavailable for {} — serving on the native engine: {e:#})",
                    net.name
                );
                Ok(Box::new(NativeBackend::new(net)) as Box<dyn Backend>)
            }
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parse_roundtrip() {
        for kind in [BackendKind::Native, BackendKind::Pjrt, BackendKind::Auto] {
            assert_eq!(BackendKind::parse(kind.as_str()).unwrap(), kind);
        }
        assert!(BackendKind::parse("cuda").is_err());
    }

    #[test]
    fn native_backend_runs_the_tiny_network() {
        let net = crate::testing::fixtures::tiny_network(8);
        let mut b = NativeBackend::new(net.clone());
        let x = net.eval_x.slice_rows(0, 4);
        let out = b.run_batch(&x, &Format::SINGLE).unwrap();
        assert_eq!(out.shape(), &[4, net.classes]);
        assert_eq!(b.label(), "native");
        assert_eq!(b.network().name, net.name);
    }
}
