//! The pluggable execution backend behind every [`crate::serving::Session`].
//!
//! [`Backend`] is the **one execution substrate** of the crate: the
//! offline drivers (`eval`, `search`, the sweep coordinator) and the
//! online request path (`Session` / `Gateway`) all run batches through
//! this trait, so comparing numeric formats never compares two
//! different forward passes (DESIGN.md §Serving).
//!
//! Construction is unified behind [`BackendKind`] + the session
//! factory: PJRT handles are not `Send` (the xla crate wraps raw
//! pointers in `Rc`), so a [`BackendFactory`] — which *is* `Send` — is
//! what crosses threads, and the backend itself is built on the
//! session's dispatcher thread and never leaves it.  That used to be a
//! public contortion of the old `InferenceServer::spawn`; it is now an
//! implementation detail.

use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use anyhow::{bail, Result};

use crate::formats::{Format, PrecisionSpec};
use crate::nn::{Engine, Network, QuantTable};
use crate::obs::ForwardProfile;
use crate::store::{StoreStats, WeightStore};
use crate::tensor::Tensor;

/// Anything that can run a batch (B, H, W, C) -> (B, classes) under a
/// precision spec — a uniform customized format or a per-layer plan.
/// Object-safe; see the module docs for the one-substrate guarantee.
pub trait Backend {
    /// Execute one batch of inputs under `spec`, returning the logits.
    /// Single-format implementations (PJRT) accept any spec that
    /// resolves uniform and reject genuinely mixed plans with an `Err`.
    fn run_spec(&mut self, x: &Tensor, spec: &PrecisionSpec) -> Result<Tensor>;

    /// Convenience: [`Backend::run_spec`] under a uniform format (the
    /// paper's single-format setting; bit-identical to passing
    /// `PrecisionSpec::Uniform(*fmt)`).
    fn run_batch(&mut self, x: &Tensor, fmt: &Format) -> Result<Tensor> {
        self.run_spec(x, &PrecisionSpec::Uniform(*fmt))
    }

    /// The network this backend executes.
    fn network(&self) -> &Arc<Network>;

    /// Short telemetry label (`"native"` / `"pjrt"`).
    fn label(&self) -> &'static str;

    /// The only batch size this backend can execute, when constrained
    /// (the AOT/PJRT executables are compiled at a static batch size);
    /// `None` means any batch works.  Drivers pad partial batches with
    /// zero samples up to this size and truncate the logits — zero
    /// padding cannot perturb live rows, since per-sample computation
    /// is independent (DESIGN.md §3).
    fn fixed_batch(&self) -> Option<usize> {
        None
    }

    /// Counter snapshot of the weight store this backend stages from
    /// (DESIGN.md §Storage); `None` for backends that do not stage
    /// weights host-side (the AOT/PJRT executables hold weights
    /// on-device).
    fn store_stats(&self) -> Option<StoreStats> {
        None
    }

    /// Toggle per-layer span profiling for subsequent forwards
    /// (`SessionOptions.profile`; DESIGN.md §Observability).  Default is
    /// a no-op: backends without a profiler stay unprofiled and return
    /// `None` from [`Backend::take_profile`].
    fn set_profiling(&mut self, _on: bool) {}

    /// The [`ForwardProfile`] of the most recent profiled forward, if
    /// profiling is on and a forward has run since the last take.
    fn take_profile(&mut self) -> Option<ForwardProfile> {
        None
    }
}

/// Builds a backend **on the thread that calls it** (the session
/// dispatcher).  The factory is `Send` even when the backend it builds
/// is not.
pub type BackendFactory = Box<dyn FnOnce() -> Result<Box<dyn Backend>> + Send + 'static>;

/// Which execution backend a [`crate::serving::Session`] should open.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BackendKind {
    /// The pure-Rust engine — always available, bit-exact with the
    /// Pallas/PJRT path by contract (DESIGN.md §3).
    Native,
    /// The AOT/PJRT executable (`pjrt` feature + artifacts required).
    /// The backend is built lazily on the session's dispatcher thread,
    /// so `open` itself succeeds and an unavailable runtime surfaces
    /// as a hard `backend init failed` error on every request — never
    /// as a silent native fallback.  Drivers send one warm-up request
    /// per session ([`crate::serving::warm_up`]) to fail fast.
    Pjrt,
    /// PJRT when it can be brought up, otherwise the native engine.
    Auto,
}

impl BackendKind {
    /// Parse the CLI spelling (`native` / `pjrt` / `auto`).
    pub fn parse(s: &str) -> Result<BackendKind> {
        match s {
            "native" => Ok(BackendKind::Native),
            "pjrt" => Ok(BackendKind::Pjrt),
            "auto" => Ok(BackendKind::Auto),
            other => bail!("unknown backend {other:?} (native|pjrt|auto)"),
        }
    }

    pub fn as_str(&self) -> &'static str {
        match self {
            BackendKind::Native => "native",
            BackendKind::Pjrt => "pjrt",
            BackendKind::Auto => "auto",
        }
    }
}

/// The native-engine backend: one scratch-buffer engine bound to one
/// network (zero heap allocations per forward after warm-up).  The
/// per-layer quantizer table for the active spec is memoized — resolved
/// once when the spec changes, reused across every batch after — so
/// both sweeps (many batches per format) and plan execution stay off
/// the allocator on the hot path.  Each table entry is a thin
/// [`crate::numerics::Quantizer`] dispatcher, so every layer the engine
/// runs under this backend executes the monomorphized `gemm_q::<Q>` /
/// `q_slice::<Q>` instantiation for its format's kind (DESIGN.md
/// §Perf) — format resolution, memoization, and kernel selection all
/// happen off the per-MAC path.
pub struct NativeBackend {
    net: Arc<Network>,
    engine: Engine,
    /// memoized (spec, resolved quantizer table) for the last spec run
    table: Option<(PrecisionSpec, QuantTable)>,
    /// pre-quantized weight store, shared with every other backend the
    /// gateway (or a parallel eval driver) built over the same zoo —
    /// entries are keyed by resolved format, so sessions share them
    /// (DESIGN.md §Storage)
    store: Arc<WeightStore>,
    /// run packed-domain kernels where the router admits them
    /// (DESIGN.md §Packed execution); off = the staged f32 tier, the
    /// pre-existing behaviour.  Bit-identical either way — the flag
    /// trades weight-memory traffic, never numerics.
    packed_exec: bool,
    /// per-layer span profiling (`obs`); off by default and free when
    /// off — `run_spec` takes no timestamps and the engine records no
    /// spans
    profiling: bool,
    /// the profile of the last profiled forward, until taken
    last_profile: Option<ForwardProfile>,
}

impl NativeBackend {
    /// A backend with its own default-budget store
    /// ([`crate::store::DEFAULT_WEIGHT_BUDGET`]); use
    /// [`NativeBackend::with_store`] to share one across backends.
    pub fn new(net: Arc<Network>) -> NativeBackend {
        Self::with_store(net, Arc::new(WeightStore::default()))
    }

    /// A backend staging from a shared [`WeightStore`].
    pub fn with_store(net: Arc<Network>, store: Arc<WeightStore>) -> NativeBackend {
        NativeBackend {
            net,
            engine: Engine::new(),
            table: None,
            store,
            packed_exec: false,
            profiling: false,
            last_profile: None,
        }
    }

    /// Builder: enable per-layer span profiling (`repro eval --profile`
    /// builds its profiled backend this way).
    pub fn with_profiling(mut self, on: bool) -> NativeBackend {
        Backend::set_profiling(&mut self, on);
        self
    }

    /// Builder: enable (or disable) packed-domain execution for every
    /// spec this backend runs.  Invalidates the memoized table — the
    /// packed router runs at resolve time.
    pub fn with_packed_exec(mut self, packed_exec: bool) -> NativeBackend {
        if self.packed_exec != packed_exec {
            self.table = None;
        }
        self.packed_exec = packed_exec;
        self
    }

    /// Builder: row-parallelize large staged-tier GEMMs across up to
    /// `threads` pool workers (`0` or `1` = serial, the default).
    /// Bit-identical at any setting: workers own disjoint row ranges
    /// and every output element's serial-k chain runs unchanged on
    /// exactly one worker (DESIGN.md §Perf).
    pub fn with_gemm_threads(mut self, threads: usize) -> NativeBackend {
        self.engine.set_gemm_threads(threads);
        self
    }

    /// Whether this backend executes from packed codes where admitted.
    pub fn packed_exec(&self) -> bool {
        self.packed_exec
    }

    /// The weight store this backend stages from.
    pub fn store(&self) -> &Arc<WeightStore> {
        &self.store
    }

    /// Resolve (or reuse) the quantizer table for `spec`.
    fn ensure_table(&mut self, spec: &PrecisionSpec) -> Result<()> {
        let stale = match &self.table {
            Some((cached, _)) => cached != spec,
            None => true,
        };
        if stale {
            let table = QuantTable::resolve_for(&self.net, spec, self.packed_exec)?;
            self.table = Some((spec.clone(), table));
        }
        Ok(())
    }

    /// Run only the first `n_layers` layers and return the intermediate
    /// activation — the Fig 8 accumulation study taps a convolution's
    /// input this way.  Native-only: the AOT artifacts expose logits,
    /// not intermediate activations.
    pub fn forward_prefix(&mut self, x: &Tensor, fmt: &Format, n_layers: usize) -> Tensor {
        let table = QuantTable::uniform_for(&self.net, fmt);
        self.engine
            .forward_prefix(&self.net, x, &table, n_layers, Some(&self.store))
    }
}

impl Backend for NativeBackend {
    fn run_spec(&mut self, x: &Tensor, spec: &PrecisionSpec) -> Result<Tensor> {
        self.ensure_table(spec)?;
        let (_, table) = self.table.as_ref().expect("table resolved above");
        if !self.profiling {
            return Ok(self.engine.forward(&self.net, x, table, Some(&self.store)));
        }
        let t0 = Instant::now();
        let out = self.engine.forward(&self.net, x, table, Some(&self.store));
        self.last_profile = Some(ForwardProfile {
            layers: self.engine.take_spans(),
            total_s: t0.elapsed().as_secs_f64(),
            batch: x.shape()[0],
        });
        Ok(out)
    }

    fn network(&self) -> &Arc<Network> {
        &self.net
    }

    fn label(&self) -> &'static str {
        "native"
    }

    fn store_stats(&self) -> Option<StoreStats> {
        Some(self.store.stats())
    }

    fn set_profiling(&mut self, on: bool) {
        self.profiling = on;
        self.engine.set_profiling(on);
        if !on {
            self.last_profile = None;
        }
    }

    fn take_profile(&mut self) -> Option<ForwardProfile> {
        self.last_profile.take()
    }
}

/// The PJRT backend: the AOT artifact executable (`pjrt` feature only;
/// DESIGN.md §5).  Built by the session factory on the dispatcher
/// thread — it cannot cross threads.
#[cfg(feature = "pjrt")]
pub struct PjrtBackend {
    pub model: crate::runtime::LoadedModel,
}

#[cfg(feature = "pjrt")]
impl Backend for PjrtBackend {
    fn run_spec(&mut self, x: &Tensor, spec: &PrecisionSpec) -> Result<Tensor> {
        // the AOT executables take ONE runtime fmt vector: any spec
        // that resolves uniform runs; a mixed plan is a clean error
        let fmt = spec.resolved_uniform(&self.model.net)?;
        self.model.run_batch(x, &fmt)
    }

    fn network(&self) -> &Arc<Network> {
        &self.model.net
    }

    fn label(&self) -> &'static str {
        "pjrt"
    }

    fn fixed_batch(&self) -> Option<usize> {
        Some(self.model.batch)
    }
}

/// Bring up the PJRT backend for `(net, fmt)` at the artifact batch
/// size, or fail with a pointer at the feature / the missing artifact.
#[cfg(feature = "pjrt")]
fn pjrt_backend(
    net: &Arc<Network>,
    dir: &Path,
    batch: usize,
    spec: &PrecisionSpec,
) -> Result<Box<dyn Backend>> {
    // per-layer plans need the native engine unless they resolve
    // uniform (one executable serves one runtime fmt vector)
    let fmt = spec.resolved_uniform(net)?;
    let kind = if fmt.is_float() { "float" } else { "fixed" };
    let hlo = net.hlo_path(dir, kind)?;
    anyhow::ensure!(hlo.exists(), "missing HLO artifact {}", hlo.display());
    let rt = crate::runtime::Runtime::cpu()?;
    let model = rt.load_network(net, dir, kind, batch)?;
    Ok(Box::new(PjrtBackend { model }))
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_backend(
    _net: &Arc<Network>,
    _dir: &Path,
    _batch: usize,
    _spec: &PrecisionSpec,
) -> Result<Box<dyn Backend>> {
    bail!("this build has no PJRT runtime; rebuild with `--features pjrt` (DESIGN.md §5)")
}

/// The unified construction path: a `Send` factory that resolves
/// `kind` on the dispatcher thread.  `Auto` degrades to the native
/// engine with a note on stderr (including for mixed per-layer plans,
/// which only the native engine executes); `Pjrt` makes unavailability
/// a hard error so a silent native run can never be mislabeled as pjrt.
/// Native backends stage weights from `store` — the gateway passes one
/// shared store so its sessions share entries by resolved format.
pub(crate) fn make_factory(
    net: Arc<Network>,
    dir: PathBuf,
    batch: usize,
    spec: PrecisionSpec,
    kind: BackendKind,
    store: Arc<WeightStore>,
    packed_exec: bool,
    gemm_threads: usize,
) -> BackendFactory {
    // packed execution is a native-engine concept: the AOT executables
    // hold weights on-device in their own layout, so the flag only
    // shapes native backends (the serve CLI notes this for --backend
    // pjrt)
    Box::new(move || match kind {
        BackendKind::Native => Ok(Box::new(
            NativeBackend::with_store(net, store)
                .with_packed_exec(packed_exec)
                .with_gemm_threads(gemm_threads),
        ) as Box<dyn Backend>),
        BackendKind::Pjrt => pjrt_backend(&net, &dir, batch, &spec),
        BackendKind::Auto => match pjrt_backend(&net, &dir, batch, &spec) {
            Ok(b) => Ok(b),
            Err(e) => {
                eprintln!(
                    "(PJRT unavailable for {} — serving on the native engine: {e:#})",
                    net.name
                );
                Ok(Box::new(
                    NativeBackend::with_store(net, store)
                        .with_packed_exec(packed_exec)
                        .with_gemm_threads(gemm_threads),
                ) as Box<dyn Backend>)
            }
        },
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parse_roundtrip() {
        for kind in [BackendKind::Native, BackendKind::Pjrt, BackendKind::Auto] {
            assert_eq!(BackendKind::parse(kind.as_str()).unwrap(), kind);
        }
        assert!(BackendKind::parse("cuda").is_err());
    }

    #[test]
    fn native_backend_runs_the_tiny_network() {
        let net = crate::testing::fixtures::tiny_network(8);
        let mut b = NativeBackend::new(net.clone());
        let x = net.eval_x.slice_rows(0, 4);
        let out = b.run_batch(&x, &Format::SINGLE).unwrap();
        assert_eq!(out.shape(), &[4, net.classes]);
        assert_eq!(b.label(), "native");
        assert_eq!(b.network().name, net.name);
    }

    /// The engine stages weights through the backend's store: the first
    /// forward misses once per quantized layer, a warm forward only
    /// hits (zero weight-quantization work), and `Format::SINGLE` over
    /// clean weights bypasses the store entirely (identity-direct
    /// borrow — the ISSUE 5 `QIdentity` staging fix).
    #[test]
    fn native_backend_stages_weights_through_the_store() {
        let net = crate::testing::fixtures::tiny_conv_network(4);
        let mut b = NativeBackend::new(net.clone());
        let x = net.eval_x.slice_rows(0, 4);
        let fmt = Format::fixed(8, 8);
        b.run_batch(&x, &fmt).unwrap();
        let s = b.store_stats().expect("native backends have a store");
        assert_eq!((s.hits, s.misses, s.entries), (0, 2, 2), "c1 and fc staged once");
        b.run_batch(&x, &fmt).unwrap();
        let s = b.store_stats().unwrap();
        assert_eq!(s.misses, 2, "a warm forward quantizes no weights");
        assert_eq!(s.hits, 2);
        // switching specs adds entries only for newly resolved formats
        b.run_batch(&x, &Format::float(7, 6)).unwrap();
        assert_eq!(b.store_stats().unwrap().entries, 4);

        // the SINGLE fast path borrows the network's weights directly:
        // no store traffic, no copies, and still the exact logits
        let mut ident = NativeBackend::new(net.clone());
        ident.run_batch(&x, &Format::SINGLE).unwrap();
        let s = ident.store_stats().unwrap();
        assert_eq!((s.hits, s.misses, s.entries, s.bytes), (0, 0, 0, 0));
    }

    /// The uniform-plan anchor (ISSUE 3 satellite): for random formats
    /// across both representation kinds, running `plan:*=<fmt>` is
    /// bit-identical to running `<fmt>` directly — through the conv AND
    /// dense paths of the fixture network.
    #[test]
    fn prop_uniform_plan_forward_is_bit_identical_to_single_format() {
        use crate::formats::{Plan, PrecisionSpec};
        use crate::testing::prop::run_prop;
        let net = crate::testing::fixtures::tiny_conv_network(6);
        let x = net.eval_x.slice_rows(0, 6);
        run_prop("uniform_plan_bitexact", 40, |g| {
            let fmt = if g.bool() {
                Format::float(g.usize_in(0, 23) as u32, g.usize_in(1, 8) as u32)
            } else {
                Format::fixed(g.usize_in(0, 16) as u32, g.usize_in(0, 16) as u32)
            };
            let via_fmt = NativeBackend::new(net.clone()).run_batch(&x, &fmt).unwrap();
            let via_plan = NativeBackend::new(net.clone())
                .run_spec(&x, &PrecisionSpec::from(Plan::uniform(fmt)))
                .unwrap();
            // an explicit all-layers plan with one format is the same
            // assignment spelled differently — also bit-identical
            let explicit = Plan::explicit(
                net.quantized_layer_names().into_iter().map(|n| (n, fmt)).collect(),
            )
            .unwrap();
            let via_explicit = NativeBackend::new(net.clone())
                .run_spec(&x, &PrecisionSpec::from(explicit))
                .unwrap();
            for i in 0..via_fmt.data().len() {
                assert_eq!(
                    via_fmt.data()[i].to_bits(),
                    via_plan.data()[i].to_bits(),
                    "{fmt} wildcard-plan logit {i}"
                );
                assert_eq!(
                    via_fmt.data()[i].to_bits(),
                    via_explicit.data()[i].to_bits(),
                    "{fmt} explicit-plan logit {i}"
                );
            }
        });
    }

    /// A genuinely mixed plan routes different quantizers to different
    /// layers: narrowing ONLY the dense layer must change the logits
    /// relative to uniform-exact, and differ from narrowing only the
    /// conv layer.
    #[test]
    fn mixed_plan_routes_formats_per_layer() {
        use crate::formats::PrecisionSpec;
        let net = crate::testing::fixtures::tiny_conv_network(6);
        let x = net.eval_x.slice_rows(0, 6);
        let run = |spec: &str| -> Vec<f32> {
            NativeBackend::new(net.clone())
                .run_spec(&x, &PrecisionSpec::parse(spec).unwrap())
                .unwrap()
                .into_data()
        };
        let exact = run("float:m23e8");
        let narrow_fc = run("plan:fc=fixed:l0r2,*=float:m23e8");
        let narrow_c1 = run("plan:c1=fixed:l0r2,*=float:m23e8");
        assert_ne!(exact, narrow_fc, "narrowing fc must perturb the logits");
        assert_ne!(exact, narrow_c1, "narrowing c1 must perturb the logits");
        assert_ne!(narrow_fc, narrow_c1, "the two single-layer plans must differ");
        // a plan that fails validation surfaces as Err, not a panic
        let mut b = NativeBackend::new(net.clone());
        let bad = PrecisionSpec::parse("plan:conv9=float:m7e6,*=fixed:l8r8").unwrap();
        assert!(b.run_spec(&x, &bad).is_err());
        // ...and the backend recovers: the next valid spec still runs
        assert!(b.run_batch(&x, &Format::SINGLE).is_ok());
    }
}
