//! A `Session` = one hosted `(network, precision spec)` pair — a
//! uniform format or a per-layer plan — with its own dynamic-batching
//! dispatcher.
//!
//! Single-sample requests are queued; the dispatcher thread flushes a
//! batch when either the execution batch size is reached or the oldest
//! queued request exceeds `max_wait` (classic dynamic batching, as in
//! vLLM-style routers).  The backend is built **on the dispatcher
//! thread** by a [`BackendFactory`] and never crosses a thread boundary
//! (PJRT handles are not `Send` — `serving::backend` module docs).
//!
//! Telemetry is **live**: the dispatcher folds every flushed batch into
//! a shared stats cell, so [`Session::stats`] (and the gateway's
//! aggregate view) can be read at any time, not only at shutdown.

use std::fmt;
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex, OnceLock, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::formats::PrecisionSpec;
use crate::nn::{Network, Zoo};
use crate::obs::{Event, EventSink, ForwardProfile, Histogram, Registry};
use crate::serving::backend::{make_factory, BackendFactory, BackendKind};
use crate::serving::qos::{QosGate, QosScheduler, ShedError, SloTarget};
use crate::store::{StoreStats, WeightStore};
use crate::tensor::Tensor;

/// Identity of one hosted session: the `(network, precision spec)`
/// pair the gateway routes by.  Spelled `net@spec`, e.g.
/// `lenet5@float:m7e6` or `lenet5@plan:conv1=float:m4e5,*=fixed:l8r8`.
#[derive(Clone, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub struct SessionKey {
    pub net: String,
    pub spec: PrecisionSpec,
}

impl SessionKey {
    pub fn new(net: &str, spec: impl Into<PrecisionSpec>) -> SessionKey {
        SessionKey { net: net.to_string(), spec: spec.into() }
    }

    /// Parse the `net@format` / `net@plan:...` spelling used by
    /// `repro serve --sessions`.
    pub fn parse(s: &str) -> Result<SessionKey> {
        let (net, spec) = s.split_once('@').ok_or_else(|| {
            anyhow!("session {s:?}: expected net@format or net@plan:... (e.g. lenet5@float:m7e6)")
        })?;
        Ok(SessionKey { net: net.to_string(), spec: PrecisionSpec::parse(spec)? })
    }
}

impl fmt::Display for SessionKey {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}@{}", self.net, self.spec.id())
    }
}

/// Split a comma-separated `--sessions` list into individual `net@spec`
/// strings.  Plan specs contain commas themselves
/// (`net@plan:a=...,b=...`), so a comma only starts a new spec when the
/// following segment contains `@` (every session spec does); other
/// segments re-attach to the spec before them.
pub fn split_session_specs(s: &str) -> Vec<String> {
    let mut out: Vec<String> = Vec::new();
    for seg in s.split(',') {
        match out.last_mut() {
            Some(last) if !seg.contains('@') => {
                last.push(',');
                last.push_str(seg.trim());
            }
            _ => out.push(seg.trim().to_string()),
        }
    }
    out
}

/// Aggregate serving telemetry for one session, accumulated over every
/// batch its dispatcher has flushed since open (it is lifetime-total,
/// not per-batch).  Queue-latency percentiles are computed over a
/// sliding window of the most recent [`QUEUE_LAT_WINDOW`] requests.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SessionStats {
    /// resolved backend label ("native"/"pjrt"; empty until the
    /// factory has run)
    pub backend: String,
    /// single-sample requests answered (or failed)
    pub requests: u64,
    /// batches flushed to the backend
    pub batches: u64,
    /// dead slots padded into partially-full batches — nonzero only
    /// for statically-batched backends (PJRT); native sessions execute
    /// the live rows as-is
    pub padded_slots: u64,
    /// median time a request waited in the batching queue
    pub p50_queue_ms: f64,
    /// 99th-percentile batching-queue wait
    pub p99_queue_ms: f64,
    /// weight-store counters of the backend this session executes on
    /// (snapshotted after every flushed batch; `None` for backends
    /// without a host-side store, e.g. PJRT).  Gateway sessions share
    /// ONE store per zoo, so every session reports the same shared
    /// totals (DESIGN.md §Storage).
    pub store: Option<StoreStats>,
    /// whether this session was opened with packed-domain execution
    /// (`SessionOptions::packed_exec`; DESIGN.md §Packed execution)
    pub packed_exec: bool,
    /// requests refused by admission control (`SessionOptions::slo`;
    /// DESIGN.md §Serving QoS).  Always 0 without an SLO.
    pub shed: u64,
    /// admitted-but-uncompleted requests right now (queued + in the
    /// running batch) — the depth-shedding input, visible live
    pub depth: usize,
    /// slow-window SLO error-budget burn rate computed by the gateway's
    /// [`crate::obs::BurnMeter`] (DESIGN.md §Observability).  1.0 means
    /// the shed fraction exactly consumes the budget; 0 for standalone
    /// sessions and sessions that have never shed.
    pub burn: f64,
    /// whether the burn-rate alert is firing (fast AND slow windows
    /// both over budget); only a gateway sets this
    pub alerting: bool,
}

/// Sliding-window size for the queue-latency percentiles.
pub const QUEUE_LAT_WINDOW: usize = 4096;

/// Shared between the dispatcher (writer) and any stats reader.
#[derive(Default)]
struct StatsCell {
    backend: &'static str,
    requests: u64,
    batches: u64,
    padded_slots: u64,
    store: Option<StoreStats>,
    queue_lat_s: Vec<f64>,
    lat_next: usize,
    /// registry view of the queue-latency stream
    /// ([`Session::register_obs`]); `None` until registered.  Recording
    /// happens inside the per-batch stats lock the dispatcher already
    /// holds, so registration adds no new synchronization.
    hist: Option<Arc<Histogram>>,
}

impl StatsCell {
    fn push_lat(&mut self, secs: f64) {
        if let Some(h) = &self.hist {
            h.record(secs);
        }
        if self.queue_lat_s.len() < QUEUE_LAT_WINDOW {
            self.queue_lat_s.push(secs);
        } else {
            self.queue_lat_s[self.lat_next] = secs;
            self.lat_next = (self.lat_next + 1) % QUEUE_LAT_WINDOW;
        }
    }

    /// Copy the raw fields out — a cheap memcpy-style clone, so the
    /// lock (which the dispatcher takes for every flushed batch) is
    /// held only briefly; the percentile sort happens in
    /// [`Session::stats`] *after* the lock is released.
    fn raw(&self) -> (SessionStats, Vec<f64>) {
        (
            SessionStats {
                backend: self.backend.to_string(),
                requests: self.requests,
                batches: self.batches,
                padded_slots: self.padded_slots,
                p50_queue_ms: 0.0,
                p99_queue_ms: 0.0,
                store: self.store,
                packed_exec: false, // the Session overrides from its options
                shed: 0,            // the Session overrides from its gate
                depth: 0,           // the Session overrides from its gate
                burn: 0.0,          // a Gateway overrides from its meter
                alerting: false,    // a Gateway overrides from its meter
            },
            self.queue_lat_s.clone(),
        )
    }
}

struct Request {
    /// one sample, H*W*C values
    pixels: Vec<f32>,
    reply: Sender<Result<Vec<f32>>>,
    enqueued: Instant,
}

/// The (p50, p99) of a queue-latency window, in milliseconds, computed
/// by nearest-rank over the sorted window: index `(n-1) * q`, rounded to
/// the nearest integer (truncating here biased the window p99 — the
/// value the QoS gate sheds on — optimistically by up to one rank; the
/// `bench_harness::percentile` fix, applied to the serving window too).
/// An empty window reports `(0.0, 0.0)` — never NaN.  `total_cmp` makes
/// the sort panic-free for any float input.
fn window_percentiles_ms(mut lats_s: Vec<f64>) -> (f64, f64) {
    lats_s.sort_by(|a, b| a.total_cmp(b));
    let pct = |q: f64| -> f64 {
        if lats_s.is_empty() {
            0.0
        } else {
            lats_s[((lats_s.len() - 1) as f64 * q).round() as usize] * 1e3
        }
    };
    (pct(0.5), pct(0.99))
}

/// Tuning knobs for [`Session::open_with`].
#[derive(Clone, Copy, Debug)]
pub struct SessionOptions {
    /// execution batch size; 0 means "the artifact batch size from the
    /// zoo" (the only size the PJRT executables accept)
    pub batch: usize,
    /// how long the oldest queued request may wait before a partial
    /// batch is flushed
    pub max_wait: Duration,
    /// byte budget of the pre-quantized weight store (`--weight-budget`;
    /// DESIGN.md §Storage).  `None` = the store default
    /// ([`crate::store::DEFAULT_WEIGHT_BUDGET`]); `Some(0)` disables
    /// caching (every forward re-stages).  A gateway builds ONE store
    /// from this for all its sessions; a standalone
    /// [`Session::open_with`] gets its own.
    pub weight_budget: Option<usize>,
    /// execute from the store's bit-packed codes where the packed
    /// router admits a layer (`--packed-exec`; DESIGN.md §Packed
    /// execution).  Bit-identical to staged execution by contract;
    /// native backends only (PJRT executables hold weights on-device).
    pub packed_exec: bool,
    /// per-session service-level objective (`--slo`; DESIGN.md §Serving
    /// QoS): p99 queue-latency budget + max queue depth.  With an SLO
    /// set, submissions are admission-controlled and shed with a typed
    /// [`ShedError`] when a bound trips; `None` (the default) never
    /// sheds — byte-for-byte the pre-QoS behavior.
    pub slo: Option<SloTarget>,
    /// gateway-wide execution slots for SLO-priority scheduling
    /// (`--qos-slots`).  Consumed by [`crate::serving::Gateway`] when it
    /// builds its [`QosScheduler`]; 0 (the default) disables the
    /// scheduler entirely and dispatchers run unthrottled as before.
    /// Ignored by standalone sessions.
    pub qos_slots: usize,
    /// row-parallelize large staged-tier GEMMs across this many pool
    /// workers inside each forward (`--gemm-threads`; DESIGN.md §Perf).
    /// 0 or 1 (the default) = serial.  Bit-identical at any setting;
    /// native backends only.
    pub gemm_threads: usize,
    /// per-forward span profiling (`--profile`; DESIGN.md
    /// §Observability): the backend records per-layer wall time,
    /// executed lane, MACs, and clamped activations into a
    /// [`ForwardProfile`] readable via [`Session::last_profile`].
    /// Off (the default) the engine takes no timestamps and forwards
    /// are bit-identical to a build without the profiler.
    pub profile: bool,
}

impl Default for SessionOptions {
    fn default() -> Self {
        SessionOptions {
            batch: 0,
            max_wait: Duration::from_millis(5),
            weight_budget: None,
            packed_exec: false,
            slo: None,
            qos_slots: 0,
            gemm_threads: 0,
            profile: false,
        }
    }
}

impl SessionOptions {
    /// Build the weight store these options describe.
    pub(crate) fn build_store(&self) -> Arc<WeightStore> {
        Arc::new(WeightStore::from_budget(self.weight_budget))
    }
}

/// Handle for one live `(network, format)` execution session.
///
/// Cheap to share behind an `Arc`: every method takes `&self`.
/// Dropping the last handle shuts the dispatcher down after it drains
/// the requests already queued.
pub struct Session {
    key: SessionKey,
    net: Arc<Network>,
    tx: Sender<Request>,
    worker: Option<JoinHandle<()>>,
    input_len: usize,
    classes: usize,
    stats: Arc<Mutex<StatsCell>>,
    /// admission-control state, shared with the dispatcher (which
    /// completes requests and publishes the window p99)
    gate: Arc<QosGate>,
    /// whether this session was opened with packed-domain execution
    /// (false for [`Session::with_factory`] — custom factories decide
    /// their backend's configuration themselves)
    packed_exec: bool,
    /// latest [`ForwardProfile`] the dispatcher captured; `None` unless
    /// opened with `SessionOptions::profile` (the mutex is only ever
    /// touched when profiling is on, so the off path stays lock-free)
    profile: Option<Arc<Mutex<Option<ForwardProfile>>>>,
    /// structured event log ([`Session::set_events`]); shed events are
    /// emitted from `submit` on the caller thread with one atomic
    /// pointer load when unset
    events: OnceLock<Arc<EventSink>>,
}

/// Typed submission failure from [`Session::submit`]: shed by admission
/// control, session down, or malformed input.  `infer_async` carries the
/// same values as `anyhow` errors (a shed converts to the bare
/// [`ShedError`] so `downcast_ref::<ShedError>()` works on either path).
#[derive(Debug)]
pub enum SubmitError {
    /// Admission control refused the request (reject-don't-collapse).
    Shed(ShedError),
    /// The dispatcher has retired; no requests can be queued.
    Down { key: SessionKey },
    /// Wrong pixel count for the session's network.
    BadInput { key: SessionKey, expected: usize, got: usize },
}

impl fmt::Display for SubmitError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SubmitError::Shed(e) => e.fmt(f),
            SubmitError::Down { key } => write!(f, "session {key} is down"),
            SubmitError::BadInput { key, expected, got } => {
                write!(f, "{key}: expected {expected} pixels, got {got}")
            }
        }
    }
}

impl std::error::Error for SubmitError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            SubmitError::Shed(e) => Some(e),
            _ => None,
        }
    }
}

impl SubmitError {
    /// Convert for the `anyhow`-surface methods.  A shed becomes the
    /// bare [`ShedError`] (not wrapped), so callers can downcast it from
    /// the `anyhow::Error` directly; the messages of the other variants
    /// are unchanged from the pre-QoS `infer_async`.
    pub fn into_anyhow(self) -> anyhow::Error {
        match self {
            SubmitError::Shed(e) => anyhow::Error::new(e),
            other => anyhow::Error::new(other),
        }
    }
}

impl Session {
    /// Open a session on `zoo`'s network `net` under `spec` (a uniform
    /// [`crate::formats::Format`] or a per-layer plan), executing on
    /// `kind`, with default batching options.
    pub fn open(
        zoo: &Zoo,
        net: &str,
        spec: impl Into<PrecisionSpec>,
        kind: BackendKind,
    ) -> Result<Session> {
        Self::open_with(zoo, net, spec, kind, SessionOptions::default())
    }

    /// [`Session::open`] with explicit batching options (the session
    /// gets its own weight store sized by `opts.weight_budget`).
    pub fn open_with(
        zoo: &Zoo,
        net: &str,
        spec: impl Into<PrecisionSpec>,
        kind: BackendKind,
        opts: SessionOptions,
    ) -> Result<Session> {
        let store = opts.build_store();
        Self::open_in(zoo, net, spec, kind, opts, store)
    }

    /// [`Session::open_with`] staging from a caller-shared
    /// [`WeightStore`] — how a [`crate::serving::Gateway`] makes all
    /// its sessions share pre-quantized weights by resolved format
    /// (DESIGN.md §Storage).
    pub fn open_in(
        zoo: &Zoo,
        net: &str,
        spec: impl Into<PrecisionSpec>,
        kind: BackendKind,
        opts: SessionOptions,
        store: Arc<WeightStore>,
    ) -> Result<Session> {
        Self::open_qos(zoo, net, spec, kind, opts, store, None)
    }

    /// [`Session::open_in`] under a gateway-wide [`QosScheduler`]: the
    /// dispatcher acquires an execution permit before every batch, so
    /// sessions closest to violating their SLO drain first
    /// (DESIGN.md §Serving QoS).
    #[allow(clippy::too_many_arguments)]
    pub fn open_qos(
        zoo: &Zoo,
        net: &str,
        spec: impl Into<PrecisionSpec>,
        kind: BackendKind,
        opts: SessionOptions,
        store: Arc<WeightStore>,
        scheduler: Option<Arc<QosScheduler>>,
    ) -> Result<Session> {
        let spec: PrecisionSpec = spec.into();
        let network = zoo.network(net)?;
        // fail malformed plans at open time, not on the first request
        spec.resolve(&network)?;
        let batch = if opts.batch == 0 { zoo.batch } else { opts.batch };
        let factory = make_factory(
            network.clone(),
            zoo.dir.clone(),
            batch,
            spec.clone(),
            kind,
            store,
            opts.packed_exec,
            opts.gemm_threads,
        );
        let resolved = SessionOptions { batch, ..opts };
        let mut session = Self::with_factory_qos(network, spec, resolved, scheduler, factory);
        session.packed_exec = opts.packed_exec;
        Ok(session)
    }

    /// Advanced constructor: run on a caller-supplied backend factory
    /// (custom backends, fault-injection tests).  The factory executes
    /// on the dispatcher thread; if it fails, every queued and future
    /// request receives the construction error.
    pub fn with_factory(
        net: Arc<Network>,
        spec: impl Into<PrecisionSpec>,
        batch: usize,
        max_wait: Duration,
        factory: BackendFactory,
    ) -> Session {
        let opts = SessionOptions { batch, max_wait, ..SessionOptions::default() };
        Self::with_factory_qos(net, spec, opts, None, factory)
    }

    /// [`Session::with_factory`] with full [`SessionOptions`] (SLO
    /// admission control) and an optional shared [`QosScheduler`]
    /// (priority execution permits).  `opts.batch` must already be
    /// resolved (>= 1); `opts.weight_budget` is not consulted here —
    /// the factory owns backend construction.
    pub fn with_factory_qos(
        net: Arc<Network>,
        spec: impl Into<PrecisionSpec>,
        opts: SessionOptions,
        scheduler: Option<Arc<QosScheduler>>,
        factory: BackendFactory,
    ) -> Session {
        assert!(opts.batch >= 1, "session batch size must be >= 1");
        let spec: PrecisionSpec = spec.into();
        let (tx, rx): (Sender<Request>, Receiver<Request>) = channel();
        let [h, w, c] = net.input;
        let classes = net.classes;
        let stats = Arc::new(Mutex::new(StatsCell::default()));
        let key = SessionKey::new(&net.name, spec.clone());
        let gate = Arc::new(QosGate::new(key.clone(), opts.slo));
        let profile = opts.profile.then(|| Arc::new(Mutex::new(None)));

        let worker = {
            let net = net.clone();
            let stats = stats.clone();
            let gate = gate.clone();
            let profile = profile.clone();
            let batch = opts.batch;
            let max_wait = opts.max_wait;
            std::thread::spawn(move || {
                dispatch(net, spec, batch, max_wait, factory, rx, stats, gate, scheduler, profile)
            })
        };

        Session {
            key,
            net,
            tx,
            worker: Some(worker),
            input_len: h * w * c,
            classes,
            stats,
            gate,
            packed_exec: false,
            profile,
            events: OnceLock::new(),
        }
    }

    /// Annotate a [`Session::with_factory`] session whose custom
    /// factory builds packed-exec backends, so the serving stats
    /// ([`SessionStats::packed_exec`], the gateway `exec` column)
    /// report the lane truthfully.  [`Session::open_in`] sets this
    /// from its [`SessionOptions`] automatically.
    pub fn with_packed_exec(mut self, packed_exec: bool) -> Session {
        self.packed_exec = packed_exec;
        self
    }

    /// The `(network, precision spec)` pair this session serves.
    pub fn key(&self) -> &SessionKey {
        &self.key
    }

    /// The network this session serves.
    pub fn network(&self) -> &Arc<Network> {
        &self.net
    }

    /// Submit one sample; blocks until its logits come back.
    pub fn infer(&self, pixels: Vec<f32>) -> Result<Vec<f32>> {
        self.infer_async(pixels)?
            .recv()
            .map_err(|_| anyhow!("session {} dropped the request", self.key))?
    }

    /// Async-style submit: returns a receiver for the logits.  With an
    /// SLO configured, consults the admission gate first; a shed comes
    /// back as a downcastable [`ShedError`].
    pub fn infer_async(&self, pixels: Vec<f32>) -> Result<Receiver<Result<Vec<f32>>>> {
        self.submit(pixels).map_err(SubmitError::into_anyhow)
    }

    /// Typed submit: like [`Session::infer_async`] but the failure is a
    /// [`SubmitError`] the caller can match on without string parsing —
    /// the loadgen drivers aggregate sheds/downs per request from this.
    pub fn submit(&self, pixels: Vec<f32>) -> Result<Receiver<Result<Vec<f32>>>, SubmitError> {
        if pixels.len() != self.input_len {
            return Err(SubmitError::BadInput {
                key: self.key.clone(),
                expected: self.input_len,
                got: pixels.len(),
            });
        }
        if let Err(shed) = self.gate.admit() {
            if let Some(sink) = self.events.get() {
                sink.emit(Event::Shed {
                    key: self.key.to_string(),
                    reason: shed.reason.as_str(),
                    depth: shed.depth,
                });
            }
            return Err(SubmitError::Shed(shed));
        }
        let (rtx, rrx) = channel();
        if self
            .tx
            .send(Request { pixels, reply: rtx, enqueued: Instant::now() })
            .is_err()
        {
            // withdrawn: the request never reached the queue, so it must
            // not count against the depth bound
            self.gate.on_completed(1);
            return Err(SubmitError::Down { key: self.key.clone() });
        }
        Ok(rrx)
    }

    /// The session's admission-control gate (live shed counters, queue
    /// depth, published window p99).
    pub fn qos_gate(&self) -> &Arc<QosGate> {
        &self.gate
    }

    /// Register this session's counters and queue-latency histogram
    /// into an [`crate::obs::Registry`], under
    /// `session/<key>/{shed_depth, shed_latency, queue_latency}`.
    /// The registry shares the SAME atomic cells the session already
    /// mutates — registration creates views, not copies, so the hot
    /// path gains no extra synchronization (DESIGN.md §Observability).
    pub fn register_obs(&self, reg: &Registry) {
        self.gate.register_into(reg);
        let hist = reg.histogram(&format!("session/{}/queue_latency", self.key));
        self.stats
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .hist = Some(hist);
    }

    /// Attach a structured event log; shed events flow into it from
    /// `submit`.  Set-once: later calls are ignored, so the emit path
    /// can read the sink with a single atomic load.
    pub fn set_events(&self, sink: Arc<EventSink>) {
        let _ = self.events.set(sink);
    }

    /// The most recent [`ForwardProfile`] the dispatcher captured.
    /// Always `None` unless the session was opened with
    /// [`SessionOptions::profile`] set; otherwise `None` only until the
    /// first batch completes.
    pub fn last_profile(&self) -> Option<ForwardProfile> {
        self.profile
            .as_ref()
            .and_then(|cell| cell.lock().unwrap_or_else(PoisonError::into_inner).clone())
    }

    /// Run a whole (B, H, W, C) tensor through the request path and
    /// reassemble the logits (B, classes).  Each row travels the same
    /// queue as [`Session::infer`] — per-sample computation is
    /// independent, so the result is bit-identical to a direct
    /// backend batch of any grouping.
    pub fn run_batch(&self, x: &Tensor) -> Result<Tensor> {
        let shape = x.shape();
        anyhow::ensure!(shape.len() == 4, "{}: input must be (B, H, W, C)", self.key);
        let b = shape[0];
        let px: usize = shape[1..].iter().product();
        anyhow::ensure!(
            px == self.input_len,
            "{}: expected {} pixels per sample, got {px}",
            self.key,
            self.input_len
        );
        let mut pending = Vec::with_capacity(b);
        for i in 0..b {
            let pixels = x.data()[i * px..(i + 1) * px].to_vec();
            pending.push(self.infer_async(pixels)?);
        }
        let mut out = Vec::with_capacity(b * self.classes);
        for rx in pending {
            let row = rx
                .recv()
                .map_err(|_| anyhow!("session {} dropped the request", self.key))??;
            out.extend_from_slice(&row);
        }
        Tensor::new(vec![b, self.classes], out)
    }

    /// Live telemetry snapshot (available any time, not only at
    /// shutdown).
    pub fn stats(&self) -> SessionStats {
        let (mut stats, lats) = self
            .stats
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .raw();
        let (p50, p99) = window_percentiles_ms(lats);
        stats.p50_queue_ms = p50;
        stats.p99_queue_ms = p99;
        stats.packed_exec = self.packed_exec;
        stats.shed = self.gate.shed_total();
        stats.depth = self.gate.depth();
        stats
    }

    /// Shut down: stop accepting requests, drain the queue, join the
    /// dispatcher, and return the final telemetry.
    pub fn shutdown(mut self) -> SessionStats {
        self.disconnect_and_join();
        self.stats()
    }

    fn disconnect_and_join(&mut self) {
        // swap in a dead sender so the dispatcher sees disconnection
        // once the already-queued requests are drained
        let (dead_tx, _) = channel();
        self.tx = dead_tx;
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}

impl Drop for Session {
    fn drop(&mut self) {
        self.disconnect_and_join();
    }
}

/// The dispatcher loop: build the backend, then batch-and-flush until
/// every sender is gone and the queue is drained.
///
/// QoS contract: the gate's depth is decremented (`on_completed`)
/// *before* replies are delivered on every path — success, batch
/// failure, bad tensor, init failure — so a caller that has seen its
/// answer can immediately resubmit without phantom backlog, and
/// `depth == admitted - completed` holds exactly.
#[allow(clippy::too_many_arguments)]
fn dispatch(
    net: Arc<Network>,
    spec: PrecisionSpec,
    batch: usize,
    max_wait: Duration,
    factory: BackendFactory,
    rx: Receiver<Request>,
    stats: Arc<Mutex<StatsCell>>,
    gate: Arc<QosGate>,
    scheduler: Option<Arc<QosScheduler>>,
    profile: Option<Arc<Mutex<Option<ForwardProfile>>>>,
) {
    let mut backend = match factory() {
        Ok(mut b) => {
            if profile.is_some() {
                b.set_profiling(true);
            }
            let mut s = stats.lock().unwrap_or_else(PoisonError::into_inner);
            s.backend = b.label();
            drop(s);
            b
        }
        Err(e) => {
            // fail every queued and future request with the
            // construction error, then retire
            while let Ok(r) = rx.recv() {
                gate.on_completed(1);
                let _ = r.reply.send(Err(anyhow!("backend init failed: {e}")));
            }
            return;
        }
    };
    let [h, w, c] = net.input;
    let input_len = h * w * c;
    let classes = net.classes;
    let mut queue: Vec<Request> = Vec::with_capacity(batch);
    loop {
        if queue.is_empty() {
            match rx.recv() {
                Ok(r) => queue.push(r),
                Err(_) => break, // all senders gone: shut down
            }
        }
        // drain whatever already queued up while the previous batch was
        // executing (closed-loop clients resubmit during compute, so
        // the backlog is usually here) ...
        while queue.len() < batch {
            match rx.try_recv() {
                Ok(r) => queue.push(r),
                Err(_) => break,
            }
        }
        // ... then accumulate until full or the oldest request exceeds
        // its batching window
        while queue.len() < batch {
            let age = queue[0].enqueued.elapsed();
            if age >= max_wait {
                break;
            }
            match rx.recv_timeout(max_wait - age) {
                Ok(r) => queue.push(r),
                Err(_) => break,
            }
        }

        let live = queue.len();
        // only a statically-batched backend (PJRT executables) needs
        // dead slots; the native engine executes the live rows as-is,
        // so sparse traffic never pays for a full-batch forward
        let rows = backend.fixed_batch().unwrap_or(live).max(live);
        let mut xdata = Vec::with_capacity(rows * input_len);
        for r in &queue {
            xdata.extend_from_slice(&r.pixels);
        }
        xdata.resize(rows * input_len, 0.0); // pad dead slots (if any)
        let window = {
            let mut s = stats.lock().unwrap_or_else(PoisonError::into_inner);
            s.requests += live as u64;
            s.batches += 1;
            s.padded_slots += (rows - live) as u64;
            for r in &queue {
                s.push_lat(r.enqueued.elapsed().as_secs_f64());
            }
            // snapshot the window for admission decisions (sorted after
            // the lock is dropped; only priced when an SLO consumes it)
            gate.slo().map(|_| s.queue_lat_s.clone())
        };
        if let Some(lats) = window {
            let (_, p99) = window_percentiles_ms(lats);
            gate.record_p99_ms(p99);
        }

        let x = match Tensor::new(vec![rows, h, w, c], xdata) {
            Ok(t) => t,
            Err(e) => {
                let msg = format!("{e}");
                gate.on_completed(live);
                for r in queue.drain(..) {
                    let _ = r.reply.send(Err(anyhow!("bad batch: {msg}")));
                }
                continue;
            }
        };

        let result = {
            // under priority scheduling, wait for an execution slot —
            // granted by SLO headroom, not FIFO (DESIGN.md §Serving QoS)
            let _permit = scheduler.as_ref().map(|s| s.acquire(&gate));
            backend.run_spec(&x, &spec)
        };
        gate.on_completed(live);
        // publish the batch's span profile (profiling sessions only;
        // the cell is absent — not merely empty — when profiling is off)
        if let Some(cell) = &profile {
            if let Some(p) = backend.take_profile() {
                *cell.lock().unwrap_or_else(PoisonError::into_inner) = Some(p);
            }
        }
        match result {
            Ok(out) => {
                for (i, r) in queue.drain(..).enumerate() {
                    let row = out.data()[i * classes..(i + 1) * classes].to_vec();
                    let _ = r.reply.send(Ok(row));
                }
            }
            Err(e) => {
                let msg = format!("{e}");
                for r in queue.drain(..) {
                    let _ = r.reply.send(Err(anyhow!("batch failed: {msg}")));
                }
            }
        }
        // store counters move during run_spec (weight staging happens
        // inside the forward), so the snapshot follows the batch
        if let Some(st) = backend.store_stats() {
            stats.lock().unwrap_or_else(PoisonError::into_inner).store = Some(st);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Format;
    use crate::serving::backend::{Backend, NativeBackend};
    use crate::testing::fixtures::tiny_network;

    fn native_session(net: &Arc<Network>, fmt: Format, batch: usize) -> Session {
        let n = net.clone();
        Session::with_factory(
            net.clone(),
            fmt,
            batch,
            Duration::from_millis(5),
            Box::new(move || Ok(Box::new(NativeBackend::new(n)) as Box<dyn Backend>)),
        )
    }

    #[test]
    fn key_parse_display_roundtrip() {
        let k = SessionKey::parse("lenet5@float:m7e6").unwrap();
        assert_eq!(k.net, "lenet5");
        assert_eq!(k.spec, PrecisionSpec::Uniform(Format::float(7, 6)));
        assert_eq!(SessionKey::parse(&k.to_string()).unwrap(), k);
        assert!(SessionKey::parse("lenet5").is_err());
        assert!(SessionKey::parse("lenet5@decimal:x1y2").is_err());
    }

    #[test]
    fn key_parses_plan_specs() {
        let k = SessionKey::parse("lenet5@plan:conv1=float:m4e5,*=fixed:l8r8").unwrap();
        assert_eq!(k.net, "lenet5");
        assert_eq!(k.spec.uniform_format(), None);
        assert_eq!(k.to_string(), "lenet5@plan:conv1=float:m4e5,*=fixed:l8r8");
        assert_eq!(SessionKey::parse(&k.to_string()).unwrap(), k);
        // the PR 2 out-of-range regression, through plan syntax
        assert!(SessionKey::parse("lenet5@plan:*=fixed:l100r100").is_err());
        assert!(SessionKey::parse("lenet5@plan:conv1=float:m99e9,*=fixed:l8r8").is_err());
    }

    /// Split-precision pair specs (ISSUE 9): the `w:…+a:…` spelling
    /// rides through SessionKey parse ⇄ Display unchanged, and
    /// malformed halves surface as clean errors.
    #[test]
    fn key_parses_split_pair_specs() {
        let s = "lenet5@plan:conv1=w:float:m4e5+a:fixed:l4r8,*=float:m7e6";
        let k = SessionKey::parse(s).unwrap();
        assert_eq!(k.net, "lenet5");
        assert_eq!(k.spec.uniform_format(), None);
        assert_eq!(k.to_string(), s);
        assert_eq!(SessionKey::parse(&k.to_string()).unwrap(), k);
        // a lone half, a missing half, and an out-of-range half all err
        assert!(SessionKey::parse("lenet5@plan:conv1=w:float:m4e5").is_err());
        assert!(SessionKey::parse("lenet5@plan:conv1=w:float:m4e5+").is_err());
        assert!(SessionKey::parse("lenet5@plan:conv1=w:float:m4e5+a:fixed:l100r100").is_err());
    }

    #[test]
    fn split_session_specs_handles_plan_commas() {
        assert_eq!(
            split_session_specs("lenet5@float:m7e6, alexnet-mini@fixed:l8r8"),
            vec!["lenet5@float:m7e6", "alexnet-mini@fixed:l8r8"]
        );
        assert_eq!(
            split_session_specs(
                "lenet5@plan:conv1=float:m4e5,*=fixed:l8r8,alexnet-mini@fixed:l8r8"
            ),
            vec!["lenet5@plan:conv1=float:m4e5,*=fixed:l8r8", "alexnet-mini@fixed:l8r8"]
        );
        // every split piece parses as a session key
        for spec in split_session_specs("a@plan:x=float:m7e6,*=float:m4e5,b@fixed:l8r8") {
            assert!(SessionKey::parse(&spec).is_ok(), "{spec}");
        }
        // a malformed leading segment stays its own (unparsable) spec
        assert_eq!(split_session_specs("oops,a@float:m7e6"), vec!["oops", "a@float:m7e6"]);
    }

    /// `--sessions` splitting with `+`-bearing pair rules (ISSUE 9):
    /// pair halves contain no `@`, so the comma re-attach logic keeps a
    /// split-precision plan spec in one piece next to other sessions.
    #[test]
    fn split_session_specs_handles_pair_rules() {
        assert_eq!(
            split_session_specs(
                "a@plan:c1=w:float:m4e5+a:fixed:l4r8,*=float:m7e6,b@fixed:l8r8"
            ),
            vec!["a@plan:c1=w:float:m4e5+a:fixed:l4r8,*=float:m7e6", "b@fixed:l8r8"]
        );
        // pair rules on BOTH sessions, in either order
        let both = split_session_specs(
            "b@fixed:l8r8,a@plan:c1=w:fixed:l8r8+a:float:m4e5,fc=w:float:m7e6+a:fixed:l4r8"
        );
        assert_eq!(
            both,
            vec![
                "b@fixed:l8r8",
                "a@plan:c1=w:fixed:l8r8+a:float:m4e5,fc=w:float:m7e6+a:fixed:l4r8"
            ]
        );
        for spec in both {
            assert!(SessionKey::parse(&spec).is_ok(), "{spec}");
        }
    }

    /// SessionKey Display ⇄ parse round-trips for random valid keys
    /// (uniform and plan specs alike).
    #[test]
    fn prop_session_key_roundtrip() {
        use crate::formats::{FormatPair, Plan};
        use crate::testing::prop::run_prop;
        run_prop("session_key_roundtrip", 200, |g| {
            let mut fmt = |g: &mut crate::testing::prop::Gen| {
                if g.bool() {
                    Format::float(g.usize_in(0, 23) as u32, g.usize_in(1, 8) as u32)
                } else {
                    Format::fixed(g.usize_in(0, 64) as u32, g.usize_in(0, 64) as u32)
                }
            };
            let net = ["lenet5", "alexnet-mini", "vgg-mini"][g.usize_in(0, 2)];
            let key = match g.usize_in(0, 2) {
                0 => SessionKey::new(net, fmt(g)),
                1 => {
                    let mut pairs = vec![("conv1".to_string(), fmt(g))];
                    if g.bool() {
                        pairs.push(("fc1".to_string(), fmt(g)));
                    }
                    SessionKey::new(net, Plan::explicit(pairs).unwrap())
                }
                // split (w, a) pairs — some collapse to uniform sugar,
                // which must round-trip through the BARE spelling
                _ => {
                    let pair = FormatPair::split(fmt(g), fmt(g));
                    let plan = Plan::explicit_pairs(vec![("conv1".to_string(), pair)]).unwrap();
                    SessionKey::new(net, plan)
                }
            };
            assert_eq!(SessionKey::parse(&key.to_string()).unwrap(), key);
        });
    }

    /// The request path must agree bitwise with a direct backend batch,
    /// including across padded partial batches.
    #[test]
    fn session_is_bit_identical_to_direct_backend() {
        let net = tiny_network(10);
        let fmt = Format::float(7, 6);
        let session = native_session(&net, fmt, 4); // 10 samples -> ragged batching
        let x = net.eval_x.slice_rows(0, 10);

        let via_session = session.run_batch(&x).unwrap();
        let direct = NativeBackend::new(net.clone()).run_batch(&x, &fmt).unwrap();
        assert_eq!(via_session.shape(), direct.shape());
        for (i, (a, b)) in via_session.data().iter().zip(direct.data()).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "logit {i}");
        }

        let st = session.shutdown();
        assert_eq!(st.backend, "native");
        assert_eq!(st.requests, 10);
        assert!(st.batches >= 3);
    }

    #[test]
    fn session_rejects_malformed_input() {
        let net = tiny_network(4);
        let session = native_session(&net, Format::SINGLE, 2);
        assert!(session.infer(vec![0.0; 3]).is_err());
        let bad = Tensor::new(vec![1, 2, 2], vec![0.0; 4]).unwrap();
        assert!(session.run_batch(&bad).is_err());
    }

    /// A failing factory must propagate its error to every queued
    /// request instead of hanging or dropping them.
    #[test]
    fn backend_init_failure_fails_every_queued_request() {
        let net = tiny_network(6);
        let gate = Arc::new(std::sync::Barrier::new(2));
        let gate2 = gate.clone();
        let session = Session::with_factory(
            net.clone(),
            Format::SINGLE,
            4,
            Duration::from_millis(50),
            Box::new(move || {
                // hold construction until the requests are queued, so
                // the error provably reaches *queued* requests
                gate2.wait();
                Err(anyhow!("induced init failure"))
            }),
        );
        let px = net.input.iter().product::<usize>();
        let pending: Vec<_> = (0..5)
            .map(|i| {
                session
                    .infer_async(net.eval_x.data()[i * px..(i + 1) * px].to_vec())
                    .unwrap()
            })
            .collect();
        gate.wait();
        for rx in pending {
            let got = rx.recv().expect("reply channel must stay open");
            let e = got.expect_err("request must fail");
            assert!(e.to_string().contains("induced init failure"), "{e}");
        }
        // a request submitted after the failure also gets the error
        let late = session.infer(net.eval_x.data()[..px].to_vec());
        assert!(late.is_err());
    }

    /// Dropping/shutting the session with requests in flight must still
    /// answer every request (the dispatcher drains before retiring).
    #[test]
    fn shutdown_answers_requests_in_flight() {
        let net = tiny_network(8);
        let fmt = Format::fixed(8, 8);
        let session = native_session(&net, fmt, 4);
        let px = net.input.iter().product::<usize>();
        let pending: Vec<_> = (0..7)
            .map(|i| {
                session
                    .infer_async(net.eval_x.data()[i * px..(i + 1) * px].to_vec())
                    .unwrap()
            })
            .collect();
        let stats = session.shutdown(); // requests still queued here
        assert_eq!(stats.requests, 7, "every in-flight request must be served");
        let direct = NativeBackend::new(net.clone())
            .run_batch(&net.eval_x.slice_rows(0, 7), &fmt)
            .unwrap();
        for (i, rx) in pending.into_iter().enumerate() {
            let got = rx.recv().unwrap().unwrap();
            let want = &direct.data()[i * net.classes..(i + 1) * net.classes];
            assert_eq!(got.as_slice(), want, "request {i}");
        }
    }

    /// Satellite (ISSUE 3): exact quantile values from synthetic queue
    /// latencies through the real sliding-window path — deterministic,
    /// no timing involved.
    #[test]
    fn stats_window_percentiles_are_exact() {
        // 1..=100 ms, pushed in scrambled order: nearest-rank indices
        // round((n-1)*0.5) = round(49.5) = 50 and round((n-1)*0.99) =
        // round(98.01) = 98 pick exactly 51 and 99 ms
        let mut cell = StatsCell::default();
        for i in (1..=100u32).rev() {
            cell.push_lat(i as f64 * 1e-3);
        }
        let (_, lats) = cell.raw();
        assert_eq!(lats.len(), 100);
        let (p50, p99) = window_percentiles_ms(lats);
        assert_eq!(p50, 51.0);
        assert_eq!(p99, 99.0);

        // single-element window: both percentiles are that element
        let (p50, p99) = window_percentiles_ms(vec![0.007]);
        assert_eq!((p50, p99), (7.0, 7.0));

        // empty window: zeros, never NaN and never a panic
        let (p50, p99) = window_percentiles_ms(Vec::new());
        assert_eq!((p50, p99), (0.0, 0.0));
        assert!(!p50.is_nan() && !p99.is_nan());
        let empty = StatsCell::default();
        let (stats, lats) = empty.raw();
        assert!(lats.is_empty());
        assert_eq!(stats.requests, 0);
    }

    /// Window eviction: past `QUEUE_LAT_WINDOW` entries the ring
    /// overwrites the OLDEST samples, so percentiles reflect only the
    /// most recent window.
    #[test]
    fn stats_window_evicts_oldest_beyond_capacity() {
        let mut cell = StatsCell::default();
        // fill the window with a constant 1 ms...
        for _ in 0..QUEUE_LAT_WINDOW {
            cell.push_lat(1e-3);
        }
        // ...then push 8 late 100 ms outliers: they must displace the
        // first 8 slots (ring order), leaving the window length capped
        for _ in 0..8 {
            cell.push_lat(100e-3);
        }
        let (_, lats) = cell.raw();
        assert_eq!(lats.len(), QUEUE_LAT_WINDOW, "window length stays capped");
        assert_eq!(lats.iter().filter(|&&v| v == 100e-3).count(), 8);
        for (i, &v) in lats.iter().take(8).enumerate() {
            assert_eq!(v, 100e-3, "slot {i} must hold an evicting sample");
        }
        let (p50, p99) = window_percentiles_ms(lats);
        assert_eq!(p50, 1.0, "8/4096 outliers cannot move the median");
        assert_eq!(p99, 1.0, "p99 rank (round(4095*0.99)=4054) is below the outliers");
        // wrap-around continues cyclically
        for _ in 0..QUEUE_LAT_WINDOW {
            cell.push_lat(2e-3);
        }
        let (_, lats) = cell.raw();
        assert!(lats.iter().all(|&v| v == 2e-3), "a full extra pass rewrites every slot");
    }

    /// ISSUE 7 tentpole: with an SLO, admission sheds at the *exact*
    /// depth bound with a typed [`ShedError`], recovers after
    /// completions, and the books balance (served + shed == offered).
    /// Deterministic: the backend is gated on a token channel, so the
    /// test controls exactly when depth drains — no timing assumptions.
    #[test]
    fn slo_session_sheds_at_depth_bound_and_recovers() {
        use crate::serving::qos::ShedReason;

        struct GatedBackend {
            inner: NativeBackend,
            tokens: Receiver<()>,
        }
        impl Backend for GatedBackend {
            fn run_spec(&mut self, x: &Tensor, spec: &PrecisionSpec) -> Result<Tensor> {
                // one token per batch; the test holds the sender
                let _ = self.tokens.recv();
                self.inner.run_spec(x, spec)
            }
            fn network(&self) -> &Arc<Network> {
                self.inner.network()
            }
            fn label(&self) -> &'static str {
                "native"
            }
        }

        let net = tiny_network(8);
        let (token_tx, token_rx) = channel::<()>();
        let opts = SessionOptions {
            batch: 1,
            max_wait: Duration::from_millis(0),
            slo: Some(SloTarget::new(1000.0, 3).unwrap()),
            ..SessionOptions::default()
        };
        let n = net.clone();
        let session = Session::with_factory_qos(
            net.clone(),
            Format::SINGLE,
            opts,
            None,
            Box::new(move || {
                Ok(Box::new(GatedBackend { inner: NativeBackend::new(n), tokens: token_rx })
                    as Box<dyn Backend>)
            }),
        );
        let px = net.input.iter().product::<usize>();
        let sample = || net.eval_x.data()[..px].to_vec();

        // Admit exactly max_depth = 3 (first blocks in the backend, the
        // rest queue), then the 4th is shed with a typed error.
        let pending: Vec<_> = (0..3).map(|_| session.submit(sample()).unwrap()).collect();
        let err = session.submit(sample()).unwrap_err();
        match &err {
            SubmitError::Shed(shed) => {
                assert_eq!(shed.reason, ShedReason::Depth);
                assert_eq!(shed.depth, 3);
                assert_eq!(shed.key, *session.key());
            }
            other => panic!("expected a depth shed, got {other}"),
        }
        // ...and the anyhow surface downcasts to the same type
        let err = session.infer_async(sample()).unwrap_err();
        let shed = err.downcast_ref::<ShedError>().expect("typed shed via anyhow");
        assert_eq!(shed.reason, ShedReason::Depth);
        let mid = session.stats();
        assert_eq!(mid.shed, 2);
        assert_eq!(mid.depth, 3);

        // Release the backend: every admitted request completes...
        for _ in 0..3 {
            token_tx.send(()).unwrap();
        }
        let served: Vec<Vec<f32>> =
            pending.into_iter().map(|rx| rx.recv().unwrap().unwrap()).collect();
        // ...bit-identical to a direct backend run (sheds never perturb
        // served results)
        let direct = NativeBackend::new(net.clone())
            .run_batch(&net.eval_x.slice_rows(0, 1), &Format::SINGLE)
            .unwrap();
        for logits in &served {
            assert_eq!(logits.as_slice(), direct.data());
        }

        // ...and admission recovers: depth drained back below the bound.
        token_tx.send(()).unwrap();
        let rx = session.submit(sample()).expect("gate must reopen after drain");
        rx.recv().unwrap().unwrap();

        // Books balance: offered = 6, served = 4, shed = 2.
        let st = session.shutdown();
        assert_eq!(st.requests, 4);
        assert_eq!(st.shed, 2);
        assert_eq!(st.requests + st.shed, 6);
        assert_eq!(st.depth, 0);
    }

    /// Without an SLO the gate is wide open: no request is ever shed and
    /// `SessionStats::{shed, depth}` stay zero at rest — the pre-QoS
    /// behavior, byte for byte.
    #[test]
    fn no_slo_session_never_sheds() {
        let net = tiny_network(8);
        let session = native_session(&net, Format::SINGLE, 4);
        let px = net.input.iter().product::<usize>();
        for i in 0..8 {
            session.infer(net.eval_x.data()[i * px..(i + 1) * px].to_vec()).unwrap();
        }
        let st = session.shutdown();
        assert_eq!(st.requests, 8);
        assert_eq!(st.shed, 0);
        assert_eq!(st.depth, 0);
    }

    /// ISSUE 10 tentpole: a session opened with `profile` captures a
    /// per-layer span profile after each batch, readable live; without
    /// the flag the accessor is always `None` (the profile cell does
    /// not even exist, so the off path takes no lock).
    #[test]
    fn profiled_session_reports_layer_spans() {
        let net = tiny_network(4);
        let n = net.clone();
        let opts =
            SessionOptions { batch: 2, profile: true, ..SessionOptions::default() };
        let session = Session::with_factory_qos(
            net.clone(),
            Format::fixed(8, 8),
            opts,
            None,
            Box::new(move || Ok(Box::new(NativeBackend::new(n)) as Box<dyn Backend>)),
        );
        let px = net.input.iter().product::<usize>();
        assert!(session.last_profile().is_none(), "no batch has run yet");
        session.infer(net.eval_x.data()[..px].to_vec()).unwrap();
        let p = session.last_profile().expect("profile after the first batch");
        assert_eq!(p.batch, 1, "native partial flush executes 1 live row");
        assert_eq!(p.layers.len(), 1, "the fixture has one named layer");
        assert_eq!(p.layers[0].name, "fc");
        assert_eq!(p.layers[0].lane, "staged", "no packed exec: staged lane");
        assert_eq!(p.layers[0].macs, (px * net.classes) as u64);
        assert!(p.total_s > 0.0);

        // a plain session never allocates the profile cell
        let plain = native_session(&net, Format::fixed(8, 8), 2);
        plain.infer(net.eval_x.data()[..px].to_vec()).unwrap();
        assert!(plain.last_profile().is_none());
    }

    /// ISSUE 10 tentpole: `register_obs` shares the session's gate
    /// counters and queue-latency stream with a metrics registry —
    /// the same atomic cells, not copies, visible live.
    #[test]
    fn register_obs_shares_gate_counters_and_latency_histogram() {
        let reg = Registry::new();
        let net = tiny_network(4);
        let session = native_session(&net, Format::SINGLE, 2);
        session.register_obs(&reg);
        let px = net.input.iter().product::<usize>();
        for i in 0..4 {
            session.infer(net.eval_x.data()[i * px..(i + 1) * px].to_vec()).unwrap();
        }
        let key = session.key().to_string();
        let h = reg.histogram(&format!("session/{key}/queue_latency"));
        assert_eq!(h.count(), 4, "every request's queue latency is recorded");
        assert_eq!(reg.counter_value(&format!("session/{key}/shed_depth")), Some(0));
        assert_eq!(reg.counter_value(&format!("session/{key}/shed_latency")), Some(0));
    }

    /// ISSUE 10 tentpole: shed refusals flow into the structured event
    /// log as typed `shed` records carrying the reason and the queue
    /// depth observed at refusal time.
    #[test]
    fn sheds_are_logged_to_the_event_sink() {
        use crate::obs::EventSink;
        use crate::util::json::Json;

        struct GatedBackend {
            inner: NativeBackend,
            tokens: Receiver<()>,
        }
        impl Backend for GatedBackend {
            fn run_spec(&mut self, x: &Tensor, spec: &PrecisionSpec) -> Result<Tensor> {
                let _ = self.tokens.recv();
                self.inner.run_spec(x, spec)
            }
            fn network(&self) -> &Arc<Network> {
                self.inner.network()
            }
            fn label(&self) -> &'static str {
                "native"
            }
        }

        let net = tiny_network(4);
        let (token_tx, token_rx) = channel::<()>();
        let opts = SessionOptions {
            batch: 1,
            max_wait: Duration::from_millis(0),
            slo: Some(SloTarget::new(1000.0, 1).unwrap()),
            ..SessionOptions::default()
        };
        let n = net.clone();
        let session = Session::with_factory_qos(
            net.clone(),
            Format::SINGLE,
            opts,
            None,
            Box::new(move || {
                Ok(Box::new(GatedBackend { inner: NativeBackend::new(n), tokens: token_rx })
                    as Box<dyn Backend>)
            }),
        );
        let (sink, captured) = EventSink::capture();
        session.set_events(Arc::new(sink));

        let px = net.input.iter().product::<usize>();
        let sample = || net.eval_x.data()[..px].to_vec();
        let pending = session.submit(sample()).unwrap(); // fills depth bound 1
        let err = session.submit(sample()).unwrap_err(); // refused -> event
        assert!(matches!(err, SubmitError::Shed(_)), "{err}");
        token_tx.send(()).unwrap();
        pending.recv().unwrap().unwrap();
        let key = session.key().to_string();
        drop(session); // drops the sink's last Arc; the writer drains

        let lines = captured.lines();
        assert_eq!(lines.len(), 1, "exactly the one shed is logged");
        assert_eq!(lines[0].get("kind").and_then(Json::as_str), Some("shed"));
        assert_eq!(lines[0].get("reason").and_then(Json::as_str), Some("depth"));
        assert_eq!(lines[0].get("depth").and_then(Json::as_f64), Some(1.0));
        assert_eq!(lines[0].get("key").and_then(Json::as_str), Some(key.as_str()));
    }

    #[test]
    fn stats_are_live_not_only_at_shutdown() {
        let net = tiny_network(4);
        let session = native_session(&net, Format::SINGLE, 2);
        let px = net.input.iter().product::<usize>();
        assert_eq!(session.stats().requests, 0);
        session.infer(net.eval_x.data()[..px].to_vec()).unwrap();
        let mid = session.stats();
        assert_eq!(mid.requests, 1);
        assert_eq!(mid.batches, 1);
        // the native backend has no fixed batch, so the partial flush
        // executes 1 live row with no dead padding
        assert_eq!(mid.padded_slots, 0);
        assert!(mid.p99_queue_ms >= mid.p50_queue_ms);
        assert_eq!(mid.backend, "native");
        // native sessions surface their weight-store counters live
        // (SINGLE over clean weights borrows directly: all zeros)
        let st = mid.store.expect("native sessions report store stats");
        assert_eq!((st.hits, st.misses, st.entries), (0, 0, 0));
    }
}
