//! Closed-loop load generation over a [`Gateway`] — shared by the
//! `repro serve` subcommand and the `serve` example so the two drivers
//! cannot drift.

use std::time::Instant;

use anyhow::{anyhow, Result};

use crate::serving::{Gateway, SessionKey};

/// One served request: (key index into the driven key list, eval-sample
/// index, end-to-end latency in seconds, logits).
pub type ServedRequest = (usize, usize, f64, Vec<f32>);

/// Send one request per session, outside any measurement window: it
/// proves each backend end to end (`Auto` resolves its fallback here —
/// the PJRT client + compile happen lazily on that session's
/// dispatcher thread) and absorbs cold-start latency symmetrically, so
/// native and pjrt telemetry stay comparable.
pub fn warm_up(gateway: &Gateway, keys: &[SessionKey]) -> Result<()> {
    for key in keys {
        let net = gateway
            .session(key)
            .ok_or_else(|| anyhow!("gateway hosts no session {key}"))?
            .network()
            .clone();
        let px: usize = net.input.iter().product();
        gateway.infer(key, net.eval_x.data()[..px].to_vec())?;
    }
    Ok(())
}

/// Drive `n_requests` through the gateway from `n_clients` closed-loop
/// client threads, round-robining by session key: request `i` goes to
/// `keys[i % keys.len()]` with eval sample `(i / keys.len()) %
/// eval_len`, so every key receives an identical, deterministic sample
/// stream regardless of client count.  Returns one record per request;
/// callers aggregate what they need (latency percentiles, accuracy, or
/// nothing).  Panics if a session vanishes or a request fails
/// mid-drive — load-generator semantics, not server semantics.
pub fn drive_closed_loop(
    gateway: &Gateway,
    keys: &[SessionKey],
    n_requests: usize,
    n_clients: usize,
) -> Vec<ServedRequest> {
    assert!(!keys.is_empty(), "drive_closed_loop needs at least one session key");
    let n_clients = n_clients.max(1);
    let mut served: Vec<ServedRequest> = Vec::with_capacity(n_requests);
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        for cid in 0..n_clients {
            handles.push(scope.spawn(move || {
                let mut out = Vec::new();
                let mut i = cid;
                while i < n_requests {
                    let ki = i % keys.len();
                    let session = gateway.session(&keys[ki]).expect("session vanished");
                    let net = session.network();
                    let px: usize = net.input.iter().product();
                    let sample = (i / keys.len()) % net.eval_len();
                    let pixels = net.eval_x.data()[sample * px..(sample + 1) * px].to_vec();
                    let t = Instant::now();
                    let logits = session.infer(pixels).expect("inference failed");
                    out.push((ki, sample, t.elapsed().as_secs_f64(), logits));
                    i += n_clients;
                }
                out
            }));
        }
        for h in handles {
            served.extend(h.join().unwrap());
        }
    });
    served
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    use crate::formats::Format;
    use crate::serving::backend::{Backend, NativeBackend};
    use crate::serving::Session;
    use crate::testing::fixtures::tiny_network;

    #[test]
    fn drives_every_request_exactly_once_across_keys() {
        let gw = Gateway::empty();
        let mut keys = Vec::new();
        for fmt in [Format::float(7, 6), Format::fixed(8, 8)] {
            let net = tiny_network(8);
            let n = net.clone();
            keys.push(gw.adopt(Session::with_factory(
                net,
                fmt,
                4,
                Duration::from_millis(3),
                Box::new(move || Ok(Box::new(NativeBackend::new(n)) as Box<dyn Backend>)),
            )));
        }
        warm_up(&gw, &keys).unwrap();
        let served = drive_closed_loop(&gw, &keys, 24, 3);
        assert_eq!(served.len(), 24);
        for ki in 0..keys.len() {
            let mut samples: Vec<usize> = served
                .iter()
                .filter(|(k, _, _, _)| *k == ki)
                .map(|(_, s, _, _)| *s)
                .collect();
            samples.sort_unstable();
            // 12 requests per key over an 8-sample eval set wrap around
            let want: Vec<usize> = (0..12).map(|i| i % 8).collect();
            let mut want_sorted = want;
            want_sorted.sort_unstable();
            assert_eq!(samples, want_sorted);
        }
        // warm-up (1/key) + 12/key driven requests
        let stats = gw.shutdown();
        assert_eq!(stats.total_requests(), 2 * (12 + 1));
    }

    #[test]
    fn warm_up_surfaces_missing_sessions() {
        let gw = Gateway::empty();
        let key = SessionKey::new("ghost", Format::SINGLE);
        assert!(warm_up(&gw, std::slice::from_ref(&key)).is_err());
    }
}
