//! Load generation over a [`Gateway`] — shared by the `repro serve`
//! subcommand and the `serve` example so the two drivers cannot drift.
//!
//! Two drive modes (DESIGN.md §Serving QoS):
//!
//! * **Closed loop** ([`ClosedLoop`], [`drive_closed_loop`]) — N client
//!   threads, each firing its next request only after the previous one
//!   answers.  Offered load self-throttles to the service rate, so a
//!   closed-loop drive can never observe queue growth or shedding; it
//!   measures latency under a bounded concurrency.
//! * **Open loop** ([`ArrivalSchedule`], [`drive_open_loop`]) — requests
//!   fire at their scheduled arrival time *regardless of completions*,
//!   the way real traffic arrives.  This is the only mode where an SLO
//!   gate has anything to shed, and the driver accounts every offered
//!   request exactly once: `served + shed + failed == offered`
//!   ([`DriveReport`]), with sheds kept as typed [`ShedError`] records —
//!   reject-don't-collapse, never silently dropped.
//!
//! Both modes route request `i` to `keys[i % keys.len()]` with eval
//! sample `(i / keys.len()) % eval_len`, so every key receives an
//! identical, deterministic sample stream regardless of client count or
//! arrival shape — which is what lets the chaos tests assert bit-exact
//! logits against a direct backend reference.

use std::fmt;
use std::sync::mpsc::{channel, Receiver};
use std::time::Instant;

use anyhow::{anyhow, bail, Result};

use crate::serving::qos::ShedError;
use crate::serving::session::SubmitError;
use crate::serving::{Gateway, SessionKey};
use crate::util::rng::Pcg32;
use crate::util::table::Columns;

/// One served request: (key index into the driven key list, eval-sample
/// index, end-to-end latency in seconds, logits).
pub type ServedRequest = (usize, usize, f64, Vec<f32>);

// ---------------------------------------------------------------------------
// Arrival schedules
// ---------------------------------------------------------------------------

/// The rate profile of an open-loop arrival process.  All three shapes
/// are driven by one non-homogeneous Poisson sampler
/// ([`ArrivalSchedule::times`]); the shape only supplies the
/// instantaneous rate `λ(t)`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ArrivalShape {
    /// Constant-rate Poisson arrivals: `poisson:200rps`.
    Poisson { rps: f64 },
    /// On/off bursts: `burst:<base>rps:<peak>rps:<period>ms:<duty>` —
    /// each period opens at `peak` for its first `duty` fraction, then
    /// falls back to `base`.  `burst:20rps:400rps:100ms:0.25`.
    Burst { base_rps: f64, peak_rps: f64, period_ms: f64, duty: f64 },
    /// Diurnal-style sawtooth ramp: `ramp:<lo>rps:<hi>rps:<period>ms` —
    /// the rate climbs linearly from `lo` to `hi` over each period,
    /// then resets.  `ramp:50rps:500rps:200ms`.
    Ramp { lo_rps: f64, hi_rps: f64, period_ms: f64 },
}

fn check_rate(what: &str, rps: f64) -> Result<()> {
    if !rps.is_finite() || rps <= 0.0 {
        bail!("{what} must be a positive request rate, got {rps}");
    }
    Ok(())
}

fn check_period(period_ms: f64) -> Result<()> {
    if !period_ms.is_finite() || period_ms <= 0.0 {
        bail!("arrival period must be a positive number of ms, got {period_ms}");
    }
    Ok(())
}

impl ArrivalShape {
    fn validate(&self) -> Result<()> {
        match *self {
            ArrivalShape::Poisson { rps } => check_rate("poisson rate", rps),
            ArrivalShape::Burst { base_rps, peak_rps, period_ms, duty } => {
                check_rate("burst base rate", base_rps)?;
                check_rate("burst peak rate", peak_rps)?;
                check_period(period_ms)?;
                if !duty.is_finite() || duty <= 0.0 || duty >= 1.0 {
                    bail!("burst duty must be a fraction in (0, 1), got {duty}");
                }
                Ok(())
            }
            ArrivalShape::Ramp { lo_rps, hi_rps, period_ms } => {
                check_rate("ramp low rate", lo_rps)?;
                check_rate("ramp high rate", hi_rps)?;
                check_period(period_ms)
            }
        }
    }

    /// The maximum instantaneous rate — the thinning envelope `λmax`.
    fn peak_rate(&self) -> f64 {
        match *self {
            ArrivalShape::Poisson { rps } => rps,
            ArrivalShape::Burst { base_rps, peak_rps, .. } => base_rps.max(peak_rps),
            ArrivalShape::Ramp { lo_rps, hi_rps, .. } => lo_rps.max(hi_rps),
        }
    }

    /// Instantaneous rate `λ(t)` at `t` seconds into the trace.
    fn rate_at(&self, t_s: f64) -> f64 {
        match *self {
            ArrivalShape::Poisson { rps } => rps,
            ArrivalShape::Burst { base_rps, peak_rps, period_ms, duty } => {
                let phase = (t_s * 1e3) % period_ms;
                if phase < duty * period_ms {
                    peak_rps
                } else {
                    base_rps
                }
            }
            ArrivalShape::Ramp { lo_rps, hi_rps, period_ms } => {
                let phase = (t_s * 1e3) % period_ms;
                lo_rps + (hi_rps - lo_rps) * (phase / period_ms)
            }
        }
    }
}

fn parse_rate(what: &str, s: &str) -> Result<f64> {
    let Some(num) = s.strip_suffix("rps") else {
        bail!("bad {what} '{s}': expected '<rate>rps', e.g. 200rps");
    };
    num.parse::<f64>()
        .map_err(|_| anyhow!("bad {what} '{s}': '{num}' is not a number"))
}

fn parse_period(s: &str) -> Result<f64> {
    let Some(num) = s.strip_suffix("ms") else {
        bail!("bad arrival period '{s}': expected '<period>ms', e.g. 100ms");
    };
    num.parse::<f64>()
        .map_err(|_| anyhow!("bad arrival period '{s}': '{num}' is not a number"))
}

/// A seeded, reproducible open-loop arrival trace: the shape plus the
/// PRNG seed.  The trace is a pure timestamp stream —
/// [`ArrivalSchedule::times`] does no sleeping and touches no clock, so
/// the same `(shape, seed)` always yields the bit-identical schedule
/// (the chaos tests depend on this).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ArrivalSchedule {
    pub shape: ArrivalShape,
    pub seed: u64,
}

impl ArrivalSchedule {
    /// Validated constructor.
    pub fn new(shape: ArrivalShape, seed: u64) -> Result<ArrivalSchedule> {
        shape.validate()?;
        Ok(ArrivalSchedule { shape, seed })
    }

    /// Parse the CLI spelling (`--arrivals`):
    /// `poisson:200rps`, `burst:20rps:400rps:100ms:0.25`,
    /// `ramp:50rps:500rps:200ms`.
    pub fn parse(s: &str, seed: u64) -> Result<ArrivalSchedule> {
        let parts: Vec<&str> = s.split(':').collect();
        let shape = match parts.as_slice() {
            ["poisson", rate] => ArrivalShape::Poisson { rps: parse_rate("poisson rate", rate)? },
            ["burst", base, peak, period, duty] => ArrivalShape::Burst {
                base_rps: parse_rate("burst base rate", base)?,
                peak_rps: parse_rate("burst peak rate", peak)?,
                period_ms: parse_period(period)?,
                duty: duty
                    .parse::<f64>()
                    .map_err(|_| anyhow!("bad burst duty '{duty}': not a number"))?,
            },
            ["ramp", lo, hi, period] => ArrivalShape::Ramp {
                lo_rps: parse_rate("ramp low rate", lo)?,
                hi_rps: parse_rate("ramp high rate", hi)?,
                period_ms: parse_period(period)?,
            },
            _ => bail!(
                "bad arrival schedule '{s}': expected poisson:<rate>rps, \
                 burst:<base>rps:<peak>rps:<period>ms:<duty>, or \
                 ramp:<lo>rps:<hi>rps:<period>ms"
            ),
        };
        ArrivalSchedule::new(shape, seed)
    }

    /// The first `n` arrival timestamps, in seconds from trace start,
    /// strictly increasing.  Non-homogeneous Poisson sampling by
    /// Lewis–Shedler thinning: candidate gaps are exponential at the
    /// envelope rate `λmax`, and each candidate survives with
    /// probability `λ(t)/λmax`.  Pure function of `(shape, seed)`.
    pub fn times(&self, n: usize) -> Vec<f64> {
        let mut rng = Pcg32::seeded(self.seed);
        let lmax = self.shape.peak_rate();
        let mut t = 0.0f64;
        let mut out = Vec::with_capacity(n);
        while out.len() < n {
            // exponential gap at λmax; 1 - u keeps ln away from zero
            t += -(1.0 - rng.uniform_f64()).ln() / lmax;
            if rng.uniform_f64() * lmax < self.shape.rate_at(t) {
                out.push(t);
            }
        }
        out
    }
}

impl fmt::Display for ArrivalSchedule {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.shape {
            ArrivalShape::Poisson { rps } => write!(f, "poisson:{rps}rps"),
            ArrivalShape::Burst { base_rps, peak_rps, period_ms, duty } => {
                write!(f, "burst:{base_rps}rps:{peak_rps}rps:{period_ms}ms:{duty}")
            }
            ArrivalShape::Ramp { lo_rps, hi_rps, period_ms } => {
                write!(f, "ramp:{lo_rps}rps:{hi_rps}rps:{period_ms}ms")
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Drive reports
// ---------------------------------------------------------------------------

/// Why one offered request was not served.
#[derive(Clone, Debug)]
pub enum FailureKind {
    /// Refused by admission control (or fired at a closed/vanished
    /// session — [`ShedReason::Closed`]).  Counted as `shed`.
    Shed(ShedError),
    /// The request was admitted but execution failed (backend error,
    /// dropped reply channel).  Counted as `failed`, never as shed.
    Failed(String),
}

/// One unserved request: which offered request it was and why.
#[derive(Clone, Debug)]
pub struct DriveFailure {
    /// Global request index in the offered stream (`i`-th fire).
    pub index: usize,
    /// The session key the request was routed to.
    pub key: SessionKey,
    /// Shed or failed.
    pub kind: FailureKind,
}

/// Everything a drive observed, with exact accounting:
/// `served.len() + shed() + failed() == offered` always
/// ([`DriveReport::is_balanced`] — the chaos test's core invariant).
#[derive(Debug, Default)]
pub struct DriveReport {
    /// Requests the driver fired (counted at the fire site, not derived).
    pub offered: u64,
    /// Successfully answered requests, with latencies and logits.
    pub served: Vec<ServedRequest>,
    /// Typed per-request records for everything not served.
    pub failures: Vec<DriveFailure>,
    /// Wall-clock duration of the drive, seconds.
    pub wall_s: f64,
}

impl DriveReport {
    /// Requests refused by admission control (plus closed-key fires).
    pub fn shed(&self) -> u64 {
        self.failures
            .iter()
            .filter(|f| matches!(f.kind, FailureKind::Shed(_)))
            .count() as u64
    }

    /// Requests admitted but not answered successfully.
    pub fn failed(&self) -> u64 {
        self.failures
            .iter()
            .filter(|f| matches!(f.kind, FailureKind::Failed(_)))
            .count() as u64
    }

    /// The accounting invariant: every offered request is either served,
    /// shed, or failed — exactly once, nothing silently dropped.
    pub fn is_balanced(&self) -> bool {
        self.served.len() as u64 + self.shed() + self.failed() == self.offered
    }

    /// Render the per-key offered/served/shed/latency table shared by
    /// `repro serve` and the `serve` example, built on the shared
    /// [`Columns`] row builder (golden-pinned by `render_golden_table`).
    /// `keys` must be the key list the drive ran over (key indices in
    /// `served` index into it).
    pub fn render(&self, keys: &[SessionKey]) -> String {
        let cols = Columns::new(&[44, 8, 8, 8, 8, 9, 9]);
        let mut out = cols.row(&[
            "session", "offered", "served", "shed", "failed", "p50 ms", "p99 ms",
        ]);
        out.push('\n');
        for (ki, key) in keys.iter().enumerate() {
            let mut lats: Vec<f64> = self
                .served
                .iter()
                .filter(|(k, _, _, _)| *k == ki)
                .map(|(_, _, lat, _)| *lat)
                .collect();
            let served = lats.len() as u64;
            let mut shed = 0u64;
            let mut failed = 0u64;
            for f in self.failures.iter().filter(|f| f.key == *key) {
                match f.kind {
                    FailureKind::Shed(_) => shed += 1,
                    FailureKind::Failed(_) => failed += 1,
                }
            }
            lats.sort_by(|a, b| a.total_cmp(b));
            let pct = |q: f64| -> f64 {
                if lats.is_empty() {
                    0.0
                } else {
                    // nearest rank, matching bench_harness::percentile
                    lats[((lats.len() - 1) as f64 * q).round() as usize] * 1e3
                }
            };
            out.push_str(&cols.row(&[
                key.to_string(),
                (served + shed + failed).to_string(),
                served.to_string(),
                shed.to_string(),
                failed.to_string(),
                format!("{:.3}", pct(0.5)),
                format!("{:.3}", pct(0.99)),
            ]));
            out.push('\n');
        }
        out.push_str(&cols.row(&[
            "total".to_string(),
            self.offered.to_string(),
            self.served.len().to_string(),
            self.shed().to_string(),
            self.failed().to_string(),
        ]));
        out.push_str(&format!(
            "   ({:.2}s wall{})\n",
            self.wall_s,
            if self.is_balanced() { "" } else { "; UNBALANCED" }
        ));
        out
    }
}

// ---------------------------------------------------------------------------
// Warm-up
// ---------------------------------------------------------------------------

/// Send one request per session, outside any measurement window: it
/// proves each backend end to end (`Auto` resolves its fallback here —
/// the PJRT client + compile happen lazily on that session's
/// dispatcher thread) and absorbs cold-start latency symmetrically, so
/// native and pjrt telemetry stay comparable.
pub fn warm_up(gateway: &Gateway, keys: &[SessionKey]) -> Result<()> {
    for key in keys {
        let net = gateway
            .session(key)
            .ok_or_else(|| anyhow!("gateway hosts no session {key}"))?
            .network()
            .clone();
        let px: usize = net.input.iter().product();
        gateway.infer(key, net.eval_x.data()[..px].to_vec())?;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// Closed-loop driver
// ---------------------------------------------------------------------------

/// Closed-loop drive configuration: `clients` threads, each firing its
/// next request only after the previous one answers.
///
/// [`ClosedLoop::new`] records every per-request failure as a typed
/// [`DriveFailure`] the caller aggregates; [`ClosedLoop::strict`] keeps
/// the historical load-generator semantics — panic the moment a session
/// vanishes or a request fails mid-drive — which the benches rely on to
/// fail fast instead of producing a report with holes in it.
#[derive(Clone, Copy, Debug)]
pub struct ClosedLoop {
    clients: usize,
    strict: bool,
}

impl ClosedLoop {
    /// Record failures as typed per-request records (never panics).
    pub fn new(clients: usize) -> ClosedLoop {
        ClosedLoop { clients: clients.max(1), strict: false }
    }

    /// Panic on a vanished session or failed request (bench semantics).
    pub fn strict(clients: usize) -> ClosedLoop {
        ClosedLoop { clients: clients.max(1), strict: true }
    }

    /// Drive `n_requests` through the gateway, round-robining by key:
    /// request `i` goes to `keys[i % keys.len()]` with eval sample
    /// `(i / keys.len()) % eval_len`.
    pub fn drive(
        &self,
        gateway: &Gateway,
        keys: &[SessionKey],
        n_requests: usize,
    ) -> DriveReport {
        assert!(!keys.is_empty(), "closed-loop drive needs at least one session key");
        let start = Instant::now();
        let strict = self.strict;
        let mut served: Vec<ServedRequest> = Vec::with_capacity(n_requests);
        let mut failures: Vec<DriveFailure> = Vec::new();
        std::thread::scope(|scope| {
            let mut handles = Vec::new();
            for cid in 0..self.clients {
                handles.push(scope.spawn(move || {
                    let mut out = Vec::new();
                    let mut bad = Vec::new();
                    let mut i = cid;
                    while i < n_requests {
                        let ki = i % keys.len();
                        match drive_one(gateway, &keys[ki], i, keys.len(), strict) {
                            Ok(rec) => out.push(rec),
                            Err(kind) => {
                                bad.push(DriveFailure { index: i, key: keys[ki].clone(), kind })
                            }
                        }
                        i += self.clients;
                    }
                    (out, bad)
                }));
            }
            for h in handles {
                let (out, bad) = h.join().unwrap();
                served.extend(out);
                failures.extend(bad);
            }
        });
        DriveReport {
            offered: n_requests as u64,
            served,
            failures,
            wall_s: start.elapsed().as_secs_f64(),
        }
    }
}

/// Fire one closed-loop request and wait for its answer.  In strict
/// mode the historical panic messages are preserved verbatim.
fn drive_one(
    gateway: &Gateway,
    key: &SessionKey,
    i: usize,
    n_keys: usize,
    strict: bool,
) -> Result<ServedRequest, FailureKind> {
    let Some(session) = gateway.session(key) else {
        if strict {
            panic!("session vanished");
        }
        return Err(FailureKind::Shed(ShedError::closed(key.clone())));
    };
    let net = session.network();
    let px: usize = net.input.iter().product();
    let sample = (i / n_keys) % net.eval_len();
    let pixels = net.eval_x.data()[sample * px..(sample + 1) * px].to_vec();
    let t = Instant::now();
    let reply = match session.submit(pixels) {
        Ok(rx) => rx.recv(),
        Err(SubmitError::Shed(e)) => {
            if strict {
                panic!("inference failed");
            }
            return Err(FailureKind::Shed(e));
        }
        Err(SubmitError::Down { key }) => {
            if strict {
                panic!("inference failed");
            }
            return Err(FailureKind::Shed(ShedError::closed(key)));
        }
        Err(e @ SubmitError::BadInput { .. }) => {
            if strict {
                panic!("inference failed");
            }
            return Err(FailureKind::Failed(e.to_string()));
        }
    };
    match reply {
        Ok(Ok(logits)) => Ok((i % n_keys, sample, t.elapsed().as_secs_f64(), logits)),
        Ok(Err(e)) => {
            if strict {
                panic!("inference failed");
            }
            Err(FailureKind::Failed(e.to_string()))
        }
        Err(_) => {
            // the session shut down mid-request without answering —
            // churn, not a backend failure
            if strict {
                panic!("inference failed");
            }
            Err(FailureKind::Shed(ShedError::closed(key.clone())))
        }
    }
}

/// Historical entry point: strict closed-loop drive returning only the
/// served records.  Panics if a session vanishes or a request fails
/// mid-drive — load-generator semantics, not server semantics; use
/// [`ClosedLoop::new`] for typed per-request failures instead.
pub fn drive_closed_loop(
    gateway: &Gateway,
    keys: &[SessionKey],
    n_requests: usize,
    n_clients: usize,
) -> Vec<ServedRequest> {
    ClosedLoop::strict(n_clients).drive(gateway, keys, n_requests).served
}

// ---------------------------------------------------------------------------
// Open-loop driver
// ---------------------------------------------------------------------------

/// Drive `n_requests` through the gateway **open loop**: request `i`
/// fires at `schedule.times(n)[i]` seconds after the drive starts,
/// whether or not earlier requests have completed — so offered load does
/// not self-throttle to the service rate, queues genuinely grow, and the
/// SLO gate has something to shed.
///
/// Routing and sample selection match the closed-loop driver (request
/// `i` → `keys[i % keys.len()]`, sample `(i / keys.len()) % eval_len`).
/// Every fire is accounted exactly once in the returned [`DriveReport`]:
/// answered requests land in `served`, admission-control rejections and
/// closed-key fires are `shed`, execution errors are `failed` —
/// `served + shed + failed == offered` always, even while sessions are
/// hot-opened and closed mid-drive (the chaos-lane contract).
///
/// One collector thread per key receives in-flight replies in firing
/// order (per-session replies are FIFO), so the firing thread never
/// blocks on completions.
pub fn drive_open_loop(
    gateway: &Gateway,
    keys: &[SessionKey],
    schedule: &ArrivalSchedule,
    n_requests: usize,
) -> DriveReport {
    assert!(!keys.is_empty(), "open-loop drive needs at least one session key");
    type InFlight = (usize, usize, Instant, Receiver<Result<Vec<f32>>>);

    let times = schedule.times(n_requests);
    let start = Instant::now();
    let mut offered = 0u64;
    let mut served: Vec<ServedRequest> = Vec::with_capacity(n_requests);
    let mut failures: Vec<DriveFailure> = Vec::new();

    std::thread::scope(|scope| {
        let mut txs = Vec::with_capacity(keys.len());
        let mut collectors = Vec::with_capacity(keys.len());
        for (ki, key) in keys.iter().enumerate() {
            let (tx, rx) = channel::<InFlight>();
            txs.push(tx);
            collectors.push(scope.spawn(move || {
                let mut out: Vec<ServedRequest> = Vec::new();
                let mut bad: Vec<DriveFailure> = Vec::new();
                while let Ok((i, sample, fired, reply)) = rx.recv() {
                    match reply.recv() {
                        Ok(Ok(logits)) => {
                            out.push((ki, sample, fired.elapsed().as_secs_f64(), logits))
                        }
                        Ok(Err(e)) => bad.push(DriveFailure {
                            index: i,
                            key: key.clone(),
                            kind: FailureKind::Failed(e.to_string()),
                        }),
                        // shut down mid-request without an answer: churn
                        Err(_) => bad.push(DriveFailure {
                            index: i,
                            key: key.clone(),
                            kind: FailureKind::Shed(ShedError::closed(key.clone())),
                        }),
                    }
                }
                (out, bad)
            }));
        }

        for (i, &t) in times.iter().enumerate() {
            let deadline = start + std::time::Duration::from_secs_f64(t);
            let now = Instant::now();
            if deadline > now {
                std::thread::sleep(deadline - now);
            }
            let ki = i % keys.len();
            offered += 1;
            let Some(session) = gateway.session(&keys[ki]) else {
                failures.push(DriveFailure {
                    index: i,
                    key: keys[ki].clone(),
                    kind: FailureKind::Shed(ShedError::closed(keys[ki].clone())),
                });
                continue;
            };
            let net = session.network();
            let px: usize = net.input.iter().product();
            let sample = (i / keys.len()) % net.eval_len();
            let pixels = net.eval_x.data()[sample * px..(sample + 1) * px].to_vec();
            let fired = Instant::now();
            match session.submit(pixels) {
                Ok(rx) => {
                    // a send can only fail if the collector is gone,
                    // which cannot happen while txs is alive
                    let _ = txs[ki].send((i, sample, fired, rx));
                }
                Err(SubmitError::Shed(e)) => failures.push(DriveFailure {
                    index: i,
                    key: keys[ki].clone(),
                    kind: FailureKind::Shed(e),
                }),
                Err(SubmitError::Down { key }) => failures.push(DriveFailure {
                    index: i,
                    key: keys[ki].clone(),
                    kind: FailureKind::Shed(ShedError::closed(key)),
                }),
                Err(e @ SubmitError::BadInput { .. }) => failures.push(DriveFailure {
                    index: i,
                    key: keys[ki].clone(),
                    kind: FailureKind::Failed(e.to_string()),
                }),
            }
        }
        drop(txs); // collectors drain their in-flight queues and retire
        for h in collectors {
            let (out, bad) = h.join().unwrap();
            served.extend(out);
            failures.extend(bad);
        }
    });

    DriveReport { offered, served, failures, wall_s: start.elapsed().as_secs_f64() }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    use crate::formats::Format;
    use crate::serving::backend::{Backend, NativeBackend};
    use crate::serving::qos::ShedReason;
    use crate::serving::Session;
    use crate::testing::fixtures::tiny_network;

    /// ISSUE 10 satellite: `DriveReport::render` is pinned as a golden
    /// string through the shared [`Columns`] builder, like
    /// `GatewayStats::render` — the two CLI tables share one geometry
    /// implementation and can no longer drift independently.
    #[test]
    fn render_golden_table() {
        let keys = vec![SessionKey::new("lenet5", Format::fixed(8, 8))];
        let report = DriveReport {
            offered: 3,
            served: vec![
                (0, 0, 0.001, vec![]),
                (0, 1, 0.002, vec![]),
                (0, 2, 0.004, vec![]),
            ],
            failures: vec![],
            wall_s: 1.5,
        };
        assert!(report.is_balanced());
        let header = "session".to_string()
            + &" ".repeat(39)
            + "offered   served     shed   failed    p50 ms    p99 ms";
        let row = "lenet5@fixed:l8r8".to_string()
            + &" ".repeat(35)
            + "3"
            + &" ".repeat(8)
            + "3"
            + &" ".repeat(8)
            + "0"
            + &" ".repeat(8)
            + "0     2.000     4.000";
        let total = "total".to_string()
            + &" ".repeat(47)
            + "3"
            + &" ".repeat(8)
            + "3"
            + &" ".repeat(8)
            + "0"
            + &" ".repeat(8)
            + "0   (1.50s wall)";
        assert_eq!(report.render(&keys), format!("{header}\n{row}\n{total}\n"));
    }

    // -- ArrivalSchedule: pure timestamp-stream properties (no sleeping) ----

    #[test]
    fn schedule_is_deterministic_under_seed() {
        let sched = ArrivalSchedule::parse("poisson:200rps", 42).unwrap();
        let a: Vec<u64> = sched.times(256).iter().map(|t| t.to_bits()).collect();
        let b: Vec<u64> = sched.times(256).iter().map(|t| t.to_bits()).collect();
        assert_eq!(a, b, "same (shape, seed) must be bit-identical");
        let other = ArrivalSchedule::parse("poisson:200rps", 43).unwrap();
        let c: Vec<u64> = other.times(256).iter().map(|t| t.to_bits()).collect();
        assert_ne!(a, c, "a different seed must yield a different trace");
    }

    #[test]
    fn schedule_times_are_strictly_increasing_and_positive() {
        for spec in ["poisson:500rps", "burst:20rps:400rps:100ms:0.25", "ramp:50rps:500rps:200ms"]
        {
            let times = ArrivalSchedule::parse(spec, 7).unwrap().times(512);
            assert_eq!(times.len(), 512);
            assert!(times[0] > 0.0, "{spec}");
            for w in times.windows(2) {
                assert!(w[1] > w[0], "{spec}: arrivals must be strictly increasing");
            }
        }
    }

    #[test]
    fn poisson_mean_rate_is_within_tolerance() {
        let n = 4000;
        let times = ArrivalSchedule::parse("poisson:200rps", 2018).unwrap().times(n);
        // n arrivals at 200 rps should span ~20 s of trace time
        let span = times[n - 1];
        let expect = n as f64 / 200.0;
        assert!(
            (span - expect).abs() / expect < 0.1,
            "trace span {span:.2}s vs expected {expect:.2}s"
        );
    }

    #[test]
    fn burst_concentrates_arrivals_in_the_duty_window() {
        // peak 1000 rps for the first half of each 1000 ms period, base
        // 10 rps for the rest: ~99% of arrivals land in the duty window
        let sched = ArrivalSchedule::parse("burst:10rps:1000rps:1000ms:0.5", 5).unwrap();
        let times = sched.times(2000);
        let in_burst =
            times.iter().filter(|&&t| (t * 1e3) % 1000.0 < 500.0).count() as f64;
        let frac = in_burst / times.len() as f64;
        assert!(frac > 0.9, "burst fraction {frac:.3} too low");
    }

    #[test]
    fn ramp_skews_arrivals_toward_the_high_end() {
        // lo 10 rps -> hi 1000 rps sawtooth: the second half of each
        // period (mean rate 752.5) must collect ~3x the arrivals of the
        // first half (mean rate 257.5)
        let sched = ArrivalSchedule::parse("ramp:10rps:1000rps:500ms", 9).unwrap();
        let times = sched.times(4000);
        let late =
            times.iter().filter(|&&t| (t * 1e3) % 500.0 >= 250.0).count() as f64;
        let early = times.len() as f64 - late;
        assert!(late > 2.0 * early, "late {late} vs early {early}");
    }

    #[test]
    fn schedule_parse_accepts_and_rejects() {
        assert_eq!(
            ArrivalSchedule::parse("poisson:200rps", 1).unwrap().shape,
            ArrivalShape::Poisson { rps: 200.0 }
        );
        assert_eq!(
            ArrivalSchedule::parse("burst:20rps:400rps:100ms:0.25", 1).unwrap().shape,
            ArrivalShape::Burst {
                base_rps: 20.0,
                peak_rps: 400.0,
                period_ms: 100.0,
                duty: 0.25
            }
        );
        assert_eq!(
            ArrivalSchedule::parse("ramp:50rps:500rps:200ms", 1).unwrap().shape,
            ArrivalShape::Ramp { lo_rps: 50.0, hi_rps: 500.0, period_ms: 200.0 }
        );
        for bad in [
            "",
            "poisson",
            "poisson:200",          // missing rps suffix
            "poisson:xrps",         // not a number
            "poisson:0rps",         // zero rate
            "poisson:-5rps",        // negative rate
            "burst:20rps:400rps",   // missing period + duty
            "burst:20rps:400rps:100ms:1.5", // duty out of (0,1)
            "burst:20rps:400rps:0ms:0.5",   // zero period
            "ramp:50rps:500rps",    // missing period
            "ramp:50rps:500rps:200", // missing ms suffix
            "sine:50rps:500rps:200ms", // unknown shape
        ] {
            assert!(ArrivalSchedule::parse(bad, 1).is_err(), "'{bad}' should not parse");
        }
    }

    #[test]
    fn schedule_display_round_trips() {
        for spec in ["poisson:200rps", "burst:20rps:400rps:100ms:0.25", "ramp:50rps:500rps:200ms"]
        {
            let sched = ArrivalSchedule::parse(spec, 11).unwrap();
            let again = ArrivalSchedule::parse(&sched.to_string(), 11).unwrap();
            assert_eq!(sched, again, "{spec}");
        }
    }

    // -- drivers over a fixture gateway -------------------------------------

    fn fixture_gateway(n_keys: usize) -> (Gateway, Vec<SessionKey>) {
        let gw = Gateway::empty();
        let mut keys = Vec::new();
        let fmts = [Format::float(7, 6), Format::fixed(8, 8)];
        for fmt in fmts.iter().take(n_keys) {
            let net = tiny_network(8);
            let n = net.clone();
            keys.push(gw.adopt(Session::with_factory(
                net,
                *fmt,
                4,
                Duration::from_millis(3),
                Box::new(move || Ok(Box::new(NativeBackend::new(n)) as Box<dyn Backend>)),
            )));
        }
        (gw, keys)
    }

    #[test]
    fn drives_every_request_exactly_once_across_keys() {
        let (gw, keys) = fixture_gateway(2);
        warm_up(&gw, &keys).unwrap();
        let served = drive_closed_loop(&gw, &keys, 24, 3);
        assert_eq!(served.len(), 24);
        for ki in 0..keys.len() {
            let mut samples: Vec<usize> = served
                .iter()
                .filter(|(k, _, _, _)| *k == ki)
                .map(|(_, s, _, _)| *s)
                .collect();
            samples.sort_unstable();
            // 12 requests per key over an 8-sample eval set wrap around
            let want: Vec<usize> = (0..12).map(|i| i % 8).collect();
            let mut want_sorted = want;
            want_sorted.sort_unstable();
            assert_eq!(samples, want_sorted);
        }
        // warm-up (1/key) + 12/key driven requests
        let stats = gw.shutdown();
        assert_eq!(stats.total_requests(), 2 * (12 + 1));
    }

    #[test]
    fn warm_up_surfaces_missing_sessions() {
        let gw = Gateway::empty();
        let key = SessionKey::new("ghost", Format::SINGLE);
        assert!(warm_up(&gw, std::slice::from_ref(&key)).is_err());
    }

    /// Satellite (ISSUE 1): the non-strict closed loop records a
    /// vanished session as typed per-request sheds — no panic, exact
    /// accounting.
    #[test]
    fn closed_loop_records_vanished_sessions_instead_of_panicking() {
        let gw = Gateway::empty();
        let ghost = vec![SessionKey::new("ghost", Format::SINGLE)];
        let report = ClosedLoop::new(3).drive(&gw, &ghost, 12);
        assert_eq!(report.offered, 12);
        assert!(report.served.is_empty());
        assert_eq!(report.shed(), 12);
        assert_eq!(report.failed(), 0);
        assert!(report.is_balanced());
        for f in &report.failures {
            match &f.kind {
                FailureKind::Shed(e) => assert_eq!(e.reason, ShedReason::Closed),
                other => panic!("expected a closed shed, got {other:?}"),
            }
        }
    }

    #[test]
    fn closed_loop_report_balances_on_a_healthy_gateway() {
        let (gw, keys) = fixture_gateway(2);
        let report = ClosedLoop::new(2).drive(&gw, &keys, 16);
        assert_eq!(report.offered, 16);
        assert_eq!(report.served.len(), 16);
        assert_eq!(report.shed() + report.failed(), 0);
        assert!(report.is_balanced());
        // the render table lists every key and the totals line
        let table = report.render(&keys);
        for key in &keys {
            assert!(table.contains(&key.to_string()), "{table}");
        }
        assert!(table.contains("total"));
        assert!(!table.contains("UNBALANCED"), "{table}");
    }

    #[test]
    fn open_loop_serves_everything_under_light_load() {
        let (gw, keys) = fixture_gateway(2);
        // 20k rps over 32 requests: ~1.6 ms of schedule, served easily
        let sched = ArrivalSchedule::parse("poisson:20000rps", 13).unwrap();
        let report = drive_open_loop(&gw, &keys, &sched, 32);
        assert_eq!(report.offered, 32);
        assert_eq!(report.served.len(), 32);
        assert!(report.is_balanced());
        // sample streams match the closed-loop routing contract
        for ki in 0..keys.len() {
            let mut samples: Vec<usize> = report
                .served
                .iter()
                .filter(|(k, _, _, _)| *k == ki)
                .map(|(_, s, _, _)| *s)
                .collect();
            samples.sort_unstable();
            let mut want: Vec<usize> = (0..16).map(|i| i % 8).collect();
            want.sort_unstable();
            assert_eq!(samples, want);
        }
    }

    /// Fires at a key with no routed session are counted as Closed
    /// sheds, keeping the books exact — the churn-chaos foundation.
    #[test]
    fn open_loop_counts_unrouted_fires_as_closed_sheds() {
        let gw = Gateway::empty();
        let ghost = vec![SessionKey::new("ghost", Format::SINGLE)];
        let sched = ArrivalSchedule::parse("poisson:50000rps", 3).unwrap();
        let report = drive_open_loop(&gw, &ghost, &sched, 20);
        assert_eq!(report.offered, 20);
        assert!(report.served.is_empty());
        assert_eq!(report.shed(), 20);
        assert!(report.is_balanced());
        for f in &report.failures {
            match &f.kind {
                FailureKind::Shed(e) => assert_eq!(e.reason, ShedReason::Closed),
                other => panic!("expected a closed shed, got {other:?}"),
            }
        }
        let table = report.render(&ghost);
        assert!(!table.contains("UNBALANCED"), "{table}");
    }
}
