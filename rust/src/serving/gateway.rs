//! The multi-model, multi-format serving gateway.
//!
//! A [`Gateway`] hosts N concurrent [`Session`]s keyed by
//! `(network, precision spec)` and routes single-sample requests by
//! [`SessionKey`].  Each session runs its own dynamic-batching
//! dispatcher, so one process serves e.g. `lenet5@float:m7e6`, a
//! per-layer `lenet5@plan:conv1=float:m4e5,*=fixed:l8r8`, and
//! `alexnet-mini@fixed:l8r8` simultaneously; sessions can be added and
//! removed while traffic is flowing (a sweep can be served live).
//!
//! This replaces the old single-pair `InferenceServer`: what used to be
//! one `(network, format)` hard-wired to one dispatcher thread is now a
//! routing table of sessions sharing one aggregate telemetry view
//! ([`GatewayStats`]).

use std::collections::btree_map::Entry;
use std::collections::BTreeMap;
use std::sync::{Arc, OnceLock, PoisonError, RwLock};

use anyhow::{anyhow, Result};

use crate::formats::PrecisionSpec;
use crate::nn::Zoo;
use crate::obs::{BurnConfig, BurnMeter, Event, EventSink, Registry};
use crate::serving::backend::BackendKind;
use crate::serving::qos::{QosScheduler, SloTarget};
use crate::serving::session::{Session, SessionKey, SessionOptions, SessionStats};
use crate::store::{StoreStats, WeightStore};
use crate::util::table::Columns;

/// Aggregate serving telemetry: one [`SessionStats`] per hosted
/// session, keyed and sorted by [`SessionKey`].  Like the per-session
/// stats it is accumulated over each session's whole lifetime and can
/// be snapshotted live at any point.
#[derive(Clone, Debug, Default)]
pub struct GatewayStats {
    pub sessions: Vec<(SessionKey, SessionStats)>,
    /// LIVE snapshot of the gateway-owned shared store, taken at the
    /// moment [`Gateway::stats`] / [`Gateway::shutdown`] ran — unlike
    /// the per-session copies (which are as of each session's last
    /// flushed batch).  `None` when that store saw no traffic (all
    /// PJRT, or adopted sessions staging from their own stores).
    pub store: Option<StoreStats>,
}

impl GatewayStats {
    /// Requests answered across every session.
    pub fn total_requests(&self) -> u64 {
        self.sessions.iter().map(|(_, s)| s.requests).sum()
    }

    /// Requests shed by admission control across every session
    /// (DESIGN.md §Serving QoS).
    pub fn total_shed(&self) -> u64 {
        self.sessions.iter().map(|(_, s)| s.shed).sum()
    }

    /// Batches flushed across every session.
    pub fn total_batches(&self) -> u64 {
        self.sessions.iter().map(|(_, s)| s.batches).sum()
    }

    /// The shared weight-store counters: the gateway-level live
    /// snapshot when there is one, otherwise the first session's
    /// last-batch copy (sessions adopted with a custom factory stage
    /// from their own store, which only they can report).
    pub fn store(&self) -> Option<StoreStats> {
        self.store
            .or_else(|| self.sessions.iter().find_map(|(_, s)| s.store))
    }

    /// Fixed-width table for CLI/reporting output, built on the shared
    /// [`Columns`] row builder.  The `store h/m` column shows the
    /// shared store's hit/miss totals as seen at each session's last
    /// flushed batch; the footer line is [`GatewayStats::store`] (live
    /// at snapshot time for gateway-opened sessions).  The trailing
    /// `burn` column is the slow-window SLO error-budget burn multiple
    /// (`-` until something is shed, `!`-suffixed while the burn alert
    /// fires — DESIGN.md §Observability).
    pub fn render(&self) -> String {
        let cols = Columns::new(&[32, 8, 6, 9, 8, 9, 7, 10, 10, 6, 6, 12, 7]);
        let mut out = cols.row(&[
            "session",
            "backend",
            "exec",
            "requests",
            "batches",
            "req/batch",
            "padded",
            "p50_queue",
            "p99_queue",
            "depth",
            "shed",
            "store h/m",
            "burn",
        ]);
        out.push('\n');
        for (key, s) in &self.sessions {
            let slots = s.requests + s.padded_slots;
            let store = match &s.store {
                Some(st) => format!("{}/{}", st.hits, st.misses),
                None => "-".to_string(),
            };
            let burn = if s.shed == 0 && !s.alerting {
                "-".to_string()
            } else {
                format!("{:.1}x{}", s.burn, if s.alerting { "!" } else { "" })
            };
            out.push_str(&cols.row(&[
                key.to_string(),
                s.backend.clone(),
                (if s.packed_exec { "packed" } else { "staged" }).to_string(),
                s.requests.to_string(),
                s.batches.to_string(),
                format!("{:.1}", s.requests as f64 / s.batches.max(1) as f64),
                format!("{:.1}%", 100.0 * s.padded_slots as f64 / slots.max(1) as f64),
                format!("{:.2}ms", s.p50_queue_ms),
                format!("{:.2}ms", s.p99_queue_ms),
                s.depth.to_string(),
                s.shed.to_string(),
                store,
                burn,
            ]));
            out.push('\n');
        }
        if let Some(st) = self.store() {
            out.push_str(&format!("weight store: {}\n", st.render()));
        }
        out
    }
}

/// The multi-session router.  All methods take `&self`; the gateway is
/// shared freely across client threads.
pub struct Gateway {
    zoo: Option<Zoo>,
    kind: BackendKind,
    opts: SessionOptions,
    /// ONE pre-quantized weight store shared by every session this
    /// gateway opens: entries are keyed by `(net, layer, resolved
    /// format)`, so sessions with overlapping resolved formats share
    /// staged weights (DESIGN.md §Storage)
    store: Arc<WeightStore>,
    /// ONE execution-permit scheduler shared by every session this
    /// gateway opens, when `opts.qos_slots > 0`: batches execute in
    /// SLO-headroom order instead of free-running (DESIGN.md §Serving
    /// QoS).  `None` (the default) leaves dispatchers unthrottled.
    sched: Option<Arc<QosScheduler>>,
    /// ONE metrics registry shared by everything this gateway hosts:
    /// the store and every session register their existing atomic
    /// cells into it at open time, so the registry is a VIEW over the
    /// counters the stats surfaces already read, not a mirror
    /// (DESIGN.md §Observability)
    registry: Arc<Registry>,
    /// structured event log ([`Gateway::with_events`]); fanned out to
    /// the store and every session, which each hold their own `Arc`
    events: OnceLock<Arc<EventSink>>,
    /// per-session SLO error-budget burn tracking, evaluated on the
    /// stats path (never on a forward)
    burn: BurnMeter,
    sessions: RwLock<BTreeMap<SessionKey, Arc<Session>>>,
}

impl Gateway {
    /// A gateway over a model zoo; sessions opened through it execute
    /// on `kind` backends.
    pub fn new(zoo: Zoo, kind: BackendKind) -> Gateway {
        let opts = SessionOptions::default();
        let store = opts.build_store();
        let registry = Arc::new(Registry::new());
        store.register_into(&registry);
        Gateway {
            zoo: Some(zoo),
            kind,
            store,
            sched: build_scheduler(&opts),
            opts,
            registry,
            events: OnceLock::new(),
            burn: BurnMeter::new(BurnConfig::default()),
            sessions: RwLock::new(BTreeMap::new()),
        }
    }

    /// A gateway with no zoo: only [`Gateway::adopt`]ed sessions can be
    /// hosted (custom backends, tests).
    pub fn empty() -> Gateway {
        let opts = SessionOptions::default();
        let store = opts.build_store();
        let registry = Arc::new(Registry::new());
        store.register_into(&registry);
        Gateway {
            zoo: None,
            kind: BackendKind::Native,
            store,
            sched: build_scheduler(&opts),
            opts,
            registry,
            events: OnceLock::new(),
            burn: BurnMeter::new(BurnConfig::default()),
            sessions: RwLock::new(BTreeMap::new()),
        }
    }

    /// Set the batching options used by subsequently opened sessions.
    /// Rebuilds the shared weight store from `opts.weight_budget`
    /// (`--weight-budget`), the priority scheduler from `opts.qos_slots`
    /// (`--qos-slots`), and the metrics registry (so the registry's
    /// `store/*` names track the NEW store's cells) — call it before
    /// opening sessions.
    pub fn with_options(mut self, opts: SessionOptions) -> Gateway {
        self.opts = opts;
        self.store = opts.build_store();
        self.sched = build_scheduler(&opts);
        self.registry = Arc::new(Registry::new());
        self.store.register_into(&self.registry);
        self
    }

    /// Attach a structured event log (`--events-out`): session
    /// open/close, sheds, store evict/reject, SLO state transitions and
    /// burn alerts all flow into `sink`.  Set-once — call before
    /// opening sessions; a second call is ignored.
    pub fn with_events(self, sink: Arc<EventSink>) -> Gateway {
        if self.events.set(sink.clone()).is_ok() {
            self.store.set_events(sink.clone());
            for session in self.read_lock().values() {
                session.set_events(sink.clone());
            }
        }
        self
    }

    /// The gateway-wide metrics registry: live named views over the
    /// store's and every hosted session's counters and latency
    /// histograms.
    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }

    /// The gateway-wide priority scheduler, when `qos_slots > 0`.
    /// Sessions adopted from custom factories can share it via
    /// [`Session::with_factory_qos`].
    pub fn scheduler(&self) -> Option<&Arc<QosScheduler>> {
        self.sched.as_ref()
    }

    /// The zoo this gateway serves from (None for [`Gateway::empty`]).
    pub fn zoo(&self) -> Option<&Zoo> {
        self.zoo.as_ref()
    }

    /// The gateway-wide weight store its native sessions stage from.
    pub fn store(&self) -> &Arc<WeightStore> {
        &self.store
    }

    /// Hot-add a session for `(net, spec)` — a uniform [`crate::formats::Format`]
    /// or a per-layer [`crate::formats::Plan`].  Idempotent: opening a
    /// key that is already hosted returns it unchanged.
    pub fn open(&self, net: &str, spec: impl Into<PrecisionSpec>) -> Result<SessionKey> {
        self.open_slo(net, spec, self.opts.slo)
    }

    /// [`Gateway::open`] with a per-session SLO override: `slo` replaces
    /// the gateway-default `SessionOptions::slo` for this session only,
    /// so one gateway can host latency-guaranteed and best-effort
    /// sessions side by side (DESIGN.md §Serving QoS).
    pub fn open_slo(
        &self,
        net: &str,
        spec: impl Into<PrecisionSpec>,
        slo: Option<SloTarget>,
    ) -> Result<SessionKey> {
        let spec: PrecisionSpec = spec.into();
        let key = SessionKey::new(net, spec.clone());
        if self.session(&key).is_some() {
            return Ok(key);
        }
        let zoo = self
            .zoo
            .as_ref()
            .ok_or_else(|| anyhow!("gateway has no zoo; use adopt() for custom sessions"))?;
        let opts = SessionOptions { slo, ..self.opts };
        let session = Session::open_qos(
            zoo,
            net,
            spec,
            self.kind,
            opts,
            self.store.clone(),
            self.sched.clone(),
        )?;
        let mut map = self.write_lock();
        // on a lost race with a concurrent open, keep the incumbent —
        // but release the routing lock BEFORE dropping the duplicate,
        // since its Drop joins a dispatcher thread
        let mut duplicate = None;
        match map.entry(key.clone()) {
            Entry::Vacant(v) => {
                let session = v.insert(Arc::new(session));
                session.register_obs(&self.registry);
                if let Some(sink) = self.events.get() {
                    session.set_events(sink.clone());
                    sink.emit(Event::SessionOpen { key: key.to_string() });
                }
            }
            Entry::Occupied(_) => duplicate = Some(session),
        }
        drop(map);
        drop(duplicate);
        Ok(key)
    }

    /// [`Gateway::open`] for the `net@format` / `net@plan:...` CLI
    /// spelling.
    pub fn open_spec(&self, spec: &str) -> Result<SessionKey> {
        let key = SessionKey::parse(spec)?;
        self.open(&key.net, key.spec.clone())
    }

    /// Hot-add a pre-built session (custom factory / no zoo).  An
    /// existing session under the same key is replaced and retires
    /// once its in-flight requests drain.
    pub fn adopt(&self, session: Session) -> SessionKey {
        let key = session.key().clone();
        session.register_obs(&self.registry);
        if let Some(sink) = self.events.get() {
            session.set_events(sink.clone());
            sink.emit(Event::SessionOpen { key: key.to_string() });
        }
        // bind the displaced session so the write-guard temporary is
        // released before the old session drops (its Drop may join a
        // dispatcher draining in-flight requests)
        let displaced = self.write_lock().insert(key.clone(), Arc::new(session));
        if let (Some(d), Some(sink)) = (&displaced, self.events.get()) {
            sink.emit(Event::SessionClose { key: key.to_string(), requests: d.stats().requests });
        }
        drop(displaced);
        key
    }

    /// Hot-remove: stop routing to `key` and return the session's final
    /// telemetry (None if it was not hosted).  In-flight requests are
    /// still answered — the dispatcher drains its queue before
    /// retiring, and clients holding the session directly keep it
    /// alive until they drop it.
    pub fn close(&self, key: &SessionKey) -> Option<SessionStats> {
        let session = self.write_lock().remove(key)?;
        let stats = match Arc::try_unwrap(session) {
            Ok(s) => s.shutdown(),
            // other holders remain: snapshot now, they drain it later
            Err(arc) => arc.stats(),
        };
        if let Some(sink) = self.events.get() {
            sink.emit(Event::SessionClose { key: key.to_string(), requests: stats.requests });
        }
        self.burn.forget(&key.to_string());
        Some(stats)
    }

    /// The hosted session for `key`, if any.
    pub fn session(&self, key: &SessionKey) -> Option<Arc<Session>> {
        self.read_lock().get(key).cloned()
    }

    /// Every hosted key, sorted.
    pub fn keys(&self) -> Vec<SessionKey> {
        self.read_lock().keys().cloned().collect()
    }

    /// Route one request to the session for `key` and wait for its
    /// logits.
    pub fn infer(&self, key: &SessionKey, pixels: Vec<f32>) -> Result<Vec<f32>> {
        let session = self
            .session(key)
            .ok_or_else(|| anyhow!("gateway hosts no session {key}"))?;
        session.infer(pixels)
    }

    /// Live aggregate telemetry across every hosted session, plus a
    /// live snapshot of the gateway-owned weight store.
    pub fn stats(&self) -> GatewayStats {
        let mut sessions: Vec<(SessionKey, SessionStats)> = self
            .read_lock()
            .iter()
            .map(|(k, s)| (k.clone(), s.stats()))
            .collect();
        for (key, stats) in &mut sessions {
            observe_burn(&self.burn, self.events.get(), key, stats);
        }
        GatewayStats { sessions, store: live_store_snapshot(&self.store) }
    }

    /// Shut every session down and return the aggregate telemetry.
    /// Sessions whose only holder is the gateway are joined after
    /// draining their queued requests; for a session some client still
    /// holds an `Arc` to, the stats are a live snapshot and the
    /// dispatcher retires only when that last holder drops it (same
    /// caveat as [`Gateway::close`]).
    pub fn shutdown(self) -> GatewayStats {
        let map = self
            .sessions
            .into_inner()
            .unwrap_or_else(PoisonError::into_inner);
        let mut sessions = Vec::with_capacity(map.len());
        for (key, session) in map {
            let mut stats = match Arc::try_unwrap(session) {
                Ok(s) => s.shutdown(),
                Err(arc) => arc.stats(),
            };
            observe_burn(&self.burn, self.events.get(), &key, &mut stats);
            if let Some(sink) = self.events.get() {
                sink.emit(Event::SessionClose { key: key.to_string(), requests: stats.requests });
            }
            sessions.push((key, stats));
        }
        // final store snapshot AFTER every owned session drained
        GatewayStats { sessions, store: live_store_snapshot(&self.store) }
    }

    fn read_lock(&self) -> std::sync::RwLockReadGuard<'_, BTreeMap<SessionKey, Arc<Session>>> {
        self.sessions.read().unwrap_or_else(PoisonError::into_inner)
    }

    fn write_lock(&self) -> std::sync::RwLockWriteGuard<'_, BTreeMap<SessionKey, Arc<Session>>> {
        self.sessions.write().unwrap_or_else(PoisonError::into_inner)
    }
}

/// The execution-permit scheduler `opts` describe: `qos_slots > 0`
/// bounds gateway-wide concurrent batch executions (granted by SLO
/// headroom — DESIGN.md §Serving QoS); 0 (the default) means no
/// scheduler and free-running dispatchers, the pre-QoS behavior.
fn build_scheduler(opts: &SessionOptions) -> Option<Arc<QosScheduler>> {
    (opts.qos_slots > 0).then(|| QosScheduler::new(opts.qos_slots))
}

/// Fill one session's burn-rate fields from the meter and emit SLO
/// state transitions / alerts into the event log.  Runs on the stats
/// path only; the inputs are the same lifetime shed/served counters
/// `DriveReport` books against, so an alert's totals reconcile exactly
/// with the driver's ledger (`tests/obs_contract.rs`).
fn observe_burn(
    burn: &BurnMeter,
    events: Option<&Arc<EventSink>>,
    key: &SessionKey,
    stats: &mut SessionStats,
) {
    let label = key.to_string();
    let was = burn.was_burning(&label);
    let reading = burn.check(&label, stats.shed, stats.requests);
    stats.burn = reading.slow;
    stats.alerting = reading.alerting;
    if let Some(sink) = events {
        if reading.alerting != was {
            let (from, to) =
                if reading.alerting { ("ok", "burning") } else { ("burning", "ok") };
            sink.emit(Event::SloState { key: label.clone(), from, to });
        }
        if reading.alerting {
            sink.emit(Event::Alert {
                key: label,
                fast: reading.fast,
                slow: reading.slow,
                shed: reading.shed,
                served: reading.served,
            });
        }
    }
}

/// `Some(stats)` iff the store has seen any staging traffic — keeps
/// [`GatewayStats::store`] falling back to per-session snapshots for
/// gateways whose own store is unused (adopted custom sessions).
fn live_store_snapshot(store: &WeightStore) -> Option<StoreStats> {
    let s = store.stats();
    (s.hits + s.misses + s.rejected > 0).then_some(s)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    use crate::formats::Format;
    use crate::serving::backend::{Backend, NativeBackend};
    use crate::testing::fixtures::tiny_network;

    fn adopt_native(gw: &Gateway, fmt: Format, batch: usize) -> SessionKey {
        let net = tiny_network(8);
        let n = net.clone();
        gw.adopt(Session::with_factory(
            net,
            fmt,
            batch,
            Duration::from_millis(3),
            Box::new(move || Ok(Box::new(NativeBackend::new(n)) as Box<dyn Backend>)),
        ))
    }

    /// Concurrent clients across two sessions: every response must be
    /// bit-identical to the matching direct backend run.
    #[test]
    fn routes_concurrent_clients_across_two_sessions() {
        let gw = Gateway::empty();
        let (f1, f2) = (Format::float(7, 6), Format::fixed(8, 8));
        let k1 = adopt_native(&gw, f1, 4);
        let k2 = adopt_native(&gw, f2, 4);
        assert_eq!(gw.keys(), vec![k1.clone(), k2.clone()]);

        let net = tiny_network(8);
        let px = net.input.iter().product::<usize>();
        let direct = |fmt: &Format| {
            NativeBackend::new(net.clone())
                .run_batch(&net.eval_x.slice_rows(0, 8), fmt)
                .unwrap()
        };
        let want1 = direct(&f1);
        let want2 = direct(&f2);

        std::thread::scope(|scope| {
            for (key, want) in [(&k1, &want1), (&k2, &want2)] {
                for client in 0..3usize {
                    let gw = &gw;
                    let net = &net;
                    scope.spawn(move || {
                        let mut i = client;
                        while i < 8 {
                            let pixels = net.eval_x.data()[i * px..(i + 1) * px].to_vec();
                            let got = gw.infer(key, pixels).unwrap();
                            let row = &want.data()[i * net.classes..(i + 1) * net.classes];
                            assert_eq!(got.as_slice(), row, "{key} sample {i}");
                            i += 3;
                        }
                    });
                }
            }
        });

        let stats = gw.shutdown();
        assert_eq!(stats.sessions.len(), 2);
        assert_eq!(stats.total_requests(), 16);
        for (_, s) in &stats.sessions {
            assert_eq!(s.backend, "native");
            assert!(s.batches >= 2);
        }
    }

    #[test]
    fn hot_remove_stops_routing_but_spares_the_other_session() {
        let gw = Gateway::empty();
        let k1 = adopt_native(&gw, Format::float(7, 6), 2);
        let k2 = adopt_native(&gw, Format::SINGLE, 2);
        let net = tiny_network(8);
        let px = net.input.iter().product::<usize>();
        let pixels = net.eval_x.data()[..px].to_vec();

        gw.infer(&k1, pixels.clone()).unwrap();
        let closed = gw.close(&k1).expect("k1 was hosted");
        assert_eq!(closed.requests, 1);
        assert!(gw.infer(&k1, pixels.clone()).is_err(), "closed key must not route");
        assert!(gw.close(&k1).is_none(), "double close");
        gw.infer(&k2, pixels).unwrap();
        assert_eq!(gw.keys(), vec![k2.clone()]);
        let stats = gw.shutdown();
        assert_eq!(stats.sessions.len(), 1);
        assert_eq!(stats.sessions[0].0, k2);
    }

    #[test]
    fn open_requires_a_zoo_and_render_formats_stats() {
        let gw = Gateway::empty();
        assert!(gw.open("lenet5", Format::SINGLE).is_err());
        let k = adopt_native(&gw, Format::SINGLE, 2);
        let table = gw.stats().render();
        assert!(table.contains(&k.to_string()), "{table}");
        assert_eq!(gw.stats().total_batches(), 0);
    }

    /// Satellite (ISSUE 7): the stats table surfaces the shedding
    /// inputs — live queue depth and shed totals — next to the latency
    /// percentiles operators already read, and `total_shed` aggregates
    /// across sessions.
    #[test]
    fn render_includes_depth_and_shed_columns() {
        let mk = |requests, shed, depth| SessionStats {
            backend: "native".to_string(),
            requests,
            shed,
            depth,
            ..SessionStats::default()
        };
        let stats = GatewayStats {
            sessions: vec![
                (SessionKey::new("a", Format::SINGLE), mk(10, 3, 7)),
                (SessionKey::new("b", Format::float(7, 6)), mk(20, 4, 0)),
            ],
            store: None,
        };
        let table = stats.render();
        let header = table.lines().next().unwrap();
        assert!(header.contains("depth"), "{header}");
        assert!(header.contains("shed"), "{header}");
        // column order in every row matches the header: depth then shed
        let row_a = table.lines().nth(1).unwrap();
        let d = row_a.find(" 7 ").expect("depth value rendered");
        let s = row_a.rfind(" 3").expect("shed value rendered");
        assert!(d < s, "depth before shed: {row_a}");
        assert_eq!(stats.total_shed(), 7);
        assert_eq!(stats.total_requests(), 30);
    }

    /// ISSUE 10 satellite: `GatewayStats::render` is pinned as a golden
    /// string through the shared [`Columns`] builder — header and data
    /// rows can never drift apart again, and the new trailing `burn`
    /// column renders the alert marker.
    #[test]
    fn render_golden_table() {
        let stats = GatewayStats {
            sessions: vec![(
                SessionKey::new("lenet5", Format::fixed(8, 8)),
                SessionStats {
                    backend: "native".to_string(),
                    requests: 100,
                    batches: 25,
                    p50_queue_ms: 1.0,
                    p99_queue_ms: 2.5,
                    depth: 2,
                    shed: 5,
                    burn: 4.8,
                    alerting: true,
                    ..SessionStats::default()
                },
            )],
            store: None,
        };
        let header = "session".to_string()
            + &" ".repeat(27)
            + "backend   exec  requests  batches req/batch  padded  p50_queue  \
               p99_queue  depth   shed    store h/m    burn";
        let row = "lenet5@fixed:l8r8".to_string()
            + &" ".repeat(18)
            + "native staged"
            + &" ".repeat(7)
            + "100"
            + &" ".repeat(7)
            + "25"
            + &" ".repeat(7)
            + "4.0    0.0%     1.00ms     2.50ms      2      5"
            + &" ".repeat(12)
            + "-   4.8x!";
        assert_eq!(stats.render(), format!("{header}\n{row}\n"));
    }

    /// ISSUE 10 tentpole: the gateway's event log records the session
    /// lifecycle — adopt emits `session_open`, shutdown emits
    /// `session_close` carrying the lifetime request count — and the
    /// gateway registry holds live views of the store and session
    /// counters.
    #[test]
    fn event_log_records_session_lifecycle() {
        use crate::obs::EventSink;
        use crate::util::json::Json;

        let (sink, captured) = EventSink::capture();
        let gw = Gateway::empty().with_events(Arc::new(sink));
        assert_eq!(gw.registry().counter_value("store/hits"), Some(0));
        let key = adopt_native(&gw, Format::SINGLE, 2);
        assert_eq!(
            gw.registry().counter_value(&format!("session/{key}/shed_depth")),
            Some(0),
            "adopt registers the session's gate counters"
        );
        let net = tiny_network(8);
        let px = net.input.iter().product::<usize>();
        gw.infer(&key, net.eval_x.data()[..px].to_vec()).unwrap();
        gw.shutdown(); // drops every Arc of the sink; the writer drains

        let lines = captured.lines();
        let kinds: Vec<&str> =
            lines.iter().filter_map(|l| l.get("kind").and_then(Json::as_str)).collect();
        assert_eq!(kinds, vec!["session_open", "session_close"]);
        assert_eq!(lines[0].get("key").and_then(Json::as_str), Some(key.to_string().as_str()));
        assert_eq!(lines[1].get("requests").and_then(Json::as_f64), Some(1.0));
    }

    /// ROADMAP item 4: sustained overload flips a session to `burning`
    /// (state transition + alert whose books carry the exact shed and
    /// served counters), and recovery flips it back to `ok`.
    #[test]
    fn observe_burn_emits_transitions_and_alerts() {
        use crate::obs::EventSink;
        use crate::util::json::Json;

        let burn = BurnMeter::new(BurnConfig { budget: 0.01, min_offered: 10 });
        let (sink, captured) = EventSink::capture();
        let sink = Arc::new(sink);
        let key = SessionKey::new("a", Format::SINGLE);

        // 70 shed of 100 offered at a 1% budget: 70x burn on both windows
        let mut hot = SessionStats { requests: 30, shed: 70, ..SessionStats::default() };
        observe_burn(&burn, Some(&sink), &key, &mut hot);
        assert!(hot.alerting, "overload must alert");
        assert!(hot.burn >= 1.0, "slow window over budget: {}", hot.burn);

        // 10k clean requests later: fast window clean, slow diluted
        let mut cool =
            SessionStats { requests: 10_030, shed: 70, ..SessionStats::default() };
        observe_burn(&burn, Some(&sink), &key, &mut cool);
        assert!(!cool.alerting, "recovery must clear the alert");
        drop(sink);

        let lines = captured.lines();
        let kinds: Vec<&str> =
            lines.iter().filter_map(|l| l.get("kind").and_then(Json::as_str)).collect();
        assert_eq!(kinds, vec!["slo_state", "alert", "slo_state"]);
        assert_eq!(lines[0].get("to").and_then(Json::as_str), Some("burning"));
        assert_eq!(lines[1].get("shed").and_then(Json::as_f64), Some(70.0));
        assert_eq!(lines[1].get("served").and_then(Json::as_f64), Some(30.0));
        assert_eq!(lines[2].get("to").and_then(Json::as_str), Some("ok"));
    }

    /// `qos_slots` builds ONE scheduler shared by everything the
    /// gateway opens; 0 (the default) leaves dispatchers unthrottled.
    #[test]
    fn qos_slots_option_builds_the_scheduler() {
        let gw = Gateway::empty();
        assert!(gw.scheduler().is_none(), "default: no scheduler");
        let gw = Gateway::empty()
            .with_options(SessionOptions { qos_slots: 3, ..SessionOptions::default() });
        let sched = gw.scheduler().expect("qos_slots > 0 builds a scheduler");
        assert_eq!(sched.slots(), 3);
        assert_eq!(sched.waiting(), 0);
    }
}
