//! L3 coordination: worker pool, parallel design-space sweeps, result
//! cache, and a batching inference server.
//!
//! The paper's workload is *sweep-shaped* (hundreds of (network, format)
//! evaluations feeding the search and every figure), so the coordinator
//! is organized around a work-stealing job pool with per-worker engine
//! reuse and a persistent result cache keyed by
//! (network, format, samples).  The [`server`] submodule provides the
//! request-path façade: single-sample requests are dynamically batched
//! to the artifact batch size and dispatched to a pluggable runner
//! (native engine or PJRT executable).

pub mod cache;
pub mod pool;
pub mod server;
mod sweep;

pub use sweep::{sweep_formats, Coordinator};
