//! L3 sweep coordination: worker pool, parallel design-space sweeps,
//! and the persistent result cache.
//!
//! The paper's workload is *sweep-shaped* (hundreds of (network, format)
//! evaluations feeding the search and every figure), so the coordinator
//! is organized around a work-stealing job pool with one
//! [`crate::serving::NativeBackend`] per worker and a persistent result
//! cache keyed by (network, format, samples).  The request path lives
//! in [`crate::serving`]: the old single-pair `coordinator::server`
//! façade was replaced by the multi-session `serving::Gateway`, which
//! executes through the same [`crate::serving::Backend`] substrate as
//! the sweeps here.

pub mod cache;
pub mod pool;
mod sweep;

pub use sweep::{sweep_formats, Coordinator};
