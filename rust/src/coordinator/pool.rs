//! Work-stealing worker pool on std threads (tokio/rayon are not in the
//! offline crate set — DESIGN.md §6).
//!
//! Jobs are indexed; workers claim indices with an atomic counter and
//! send `(index, result)` down an mpsc channel, so results come back in
//! job order regardless of completion order.  Each worker owns a
//! `state` value created by `init` (the sweep uses this for its
//! scratch-buffer [`crate::serving::NativeBackend`]).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

/// Run `jobs.len()` tasks over `workers` threads.  `init()` runs once
/// per worker; `f(state, job)` per job.  Results are returned in job
/// order.  Panics in jobs propagate (fail fast).
pub fn run_indexed<J, R, S>(
    jobs: &[J],
    workers: usize,
    init: impl Fn() -> S + Sync,
    f: impl Fn(&mut S, &J) -> R + Sync,
) -> Vec<R>
where
    J: Sync,
    R: Send,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, R)>();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let next = &next;
            let f = &f;
            let init = &init;
            scope.spawn(move || {
                let mut state = init();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = f(&mut state, &jobs[i]);
                    if tx.send((i, r)).is_err() {
                        break;
                    }
                }
            });
        }
        drop(tx);

        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter()
            .map(|r| r.expect("worker panicked before completing its job"))
            .collect()
    })
}

/// Run `f` over disjoint consecutive chunks of `out` (each `chunk_len`
/// elements; the last one ragged) on up to `workers` scoped threads.
/// `f(start, chunk)` receives the chunk's element offset into `out`.
///
/// This is the mutable-output counterpart of [`run_indexed`] — the
/// engine's intra-forward GEMM row parallelism hands each worker a
/// disjoint `&mut` row range of the output (`nn::engine::gemm_q_rows`).
/// Chunks are claimed from a shared queue; `workers <= 1` (or a single
/// chunk) degenerates to a plain serial loop with no threads spawned.
/// Panics in workers propagate when the scope joins (fail fast).
pub fn run_sliced<T: Send>(
    out: &mut [T],
    chunk_len: usize,
    workers: usize,
    f: impl Fn(usize, &mut [T]) + Sync,
) {
    assert!(chunk_len > 0, "chunk_len must be positive");
    if out.is_empty() {
        return;
    }
    let mut chunks: Vec<(usize, &mut [T])> = Vec::new();
    let mut start = 0;
    for c in out.chunks_mut(chunk_len) {
        let len = c.len();
        chunks.push((start, c));
        start += len;
    }
    let workers = workers.clamp(1, chunks.len());
    if workers <= 1 {
        for (s, c) in chunks {
            f(s, c);
        }
        return;
    }
    let queue = std::sync::Mutex::new(chunks);
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let queue = &queue;
            let f = &f;
            scope.spawn(move || loop {
                let item = queue
                    .lock()
                    .unwrap_or_else(std::sync::PoisonError::into_inner)
                    .pop();
                match item {
                    Some((s, c)) => f(s, c),
                    None => break,
                }
            });
        }
    });
}

/// Default worker count: one per available core.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::run_prop;

    #[test]
    fn preserves_job_order() {
        let jobs: Vec<usize> = (0..100).collect();
        let out = run_indexed(&jobs, 8, || (), |_, &j| j * 2);
        assert_eq!(out, (0..100).map(|j| j * 2).collect::<Vec<_>>());
    }

    #[test]
    fn per_worker_state_is_isolated() {
        // each worker counts its own jobs; totals must sum to n
        use std::sync::atomic::{AtomicUsize, Ordering};
        static TOTAL: AtomicUsize = AtomicUsize::new(0);
        struct Counter(usize);
        impl Drop for Counter {
            fn drop(&mut self) {
                TOTAL.fetch_add(self.0, Ordering::SeqCst);
            }
        }
        let jobs: Vec<u32> = (0..57).collect();
        let _ = run_indexed(&jobs, 4, || Counter(0), |s, _| s.0 += 1);
        assert_eq!(TOTAL.load(Ordering::SeqCst), 57);
    }

    #[test]
    fn empty_and_single() {
        let out: Vec<u32> = run_indexed(&[] as &[u32], 4, || (), |_, &j| j);
        assert!(out.is_empty());
        let out = run_indexed(&[9u32], 16, || (), |_, &j| j + 1);
        assert_eq!(out, vec![10]);
    }

    #[test]
    fn sliced_covers_every_element_exactly_once() {
        // write chunk-start+offset into every element: full coverage
        // with disjoint writes means every element holds its own index
        let mut out = vec![0usize; 103];
        run_sliced(&mut out, 10, 4, |start, chunk| {
            for (i, v) in chunk.iter_mut().enumerate() {
                *v += start + i + 1;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i + 1, "element {i} written other than exactly once");
        }
        // serial path (workers = 1) and the empty slice
        let mut one = vec![0usize; 7];
        run_sliced(&mut one, 3, 1, |start, chunk| chunk[0] = start);
        assert_eq!((one[0], one[3], one[6]), (0, 3, 6));
        let empty: &mut [usize] = &mut [];
        run_sliced(empty, 5, 8, |_, _| panic!("no chunks on empty input"));
    }

    #[test]
    fn prop_sliced_matches_serial_for_any_geometry() {
        run_prop("sliced_matches_serial", 30, |g| {
            let n = g.usize_in(0, 200);
            let chunk = g.usize_in(1, 40);
            let workers = g.usize_in(1, 9);
            let mut par = vec![0u64; n];
            let mut seq = vec![0u64; n];
            run_sliced(&mut par, chunk, workers, |start, c| {
                for (i, v) in c.iter_mut().enumerate() {
                    *v = ((start + i) as u64) * 31 + 7;
                }
            });
            for (i, v) in seq.iter_mut().enumerate() {
                *v = (i as u64) * 31 + 7;
            }
            assert_eq!(par, seq);
        });
    }

    #[test]
    fn prop_matches_sequential_map() {
        run_prop("pool_matches_map", 30, |g| {
            let n = g.usize_in(0, 64);
            let jobs: Vec<i64> = (0..n).map(|_| g.int_in(-1000, 1000)).collect();
            let workers = g.usize_in(1, 9);
            let par = run_indexed(&jobs, workers, || (), |_, &j| j * j - 3);
            let seq: Vec<i64> = jobs.iter().map(|&j| j * j - 3).collect();
            assert_eq!(par, seq);
        });
    }
}
