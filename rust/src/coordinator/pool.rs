//! Work-stealing worker pool on std threads (tokio/rayon are not in the
//! offline crate set — DESIGN.md §6).
//!
//! Jobs are indexed; workers claim indices with an atomic counter and
//! send `(index, result)` down an mpsc channel, so results come back in
//! job order regardless of completion order.  Each worker owns a
//! `state` value created by `init` (the sweep uses this for its
//! scratch-buffer [`crate::serving::NativeBackend`]).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

/// Run `jobs.len()` tasks over `workers` threads.  `init()` runs once
/// per worker; `f(state, job)` per job.  Results are returned in job
/// order.  Panics in jobs propagate (fail fast).
pub fn run_indexed<J, R, S>(
    jobs: &[J],
    workers: usize,
    init: impl Fn() -> S + Sync,
    f: impl Fn(&mut S, &J) -> R + Sync,
) -> Vec<R>
where
    J: Sync,
    R: Send,
{
    let n = jobs.len();
    if n == 0 {
        return Vec::new();
    }
    let workers = workers.clamp(1, n);
    let next = AtomicUsize::new(0);
    let (tx, rx) = mpsc::channel::<(usize, R)>();

    std::thread::scope(|scope| {
        for _ in 0..workers {
            let tx = tx.clone();
            let next = &next;
            let f = &f;
            let init = &init;
            scope.spawn(move || {
                let mut state = init();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let r = f(&mut state, &jobs[i]);
                    if tx.send((i, r)).is_err() {
                        break;
                    }
                }
            });
        }
        drop(tx);

        let mut out: Vec<Option<R>> = (0..n).map(|_| None).collect();
        for (i, r) in rx {
            out[i] = Some(r);
        }
        out.into_iter()
            .map(|r| r.expect("worker panicked before completing its job"))
            .collect()
    })
}

/// Default worker count: one per available core.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::run_prop;

    #[test]
    fn preserves_job_order() {
        let jobs: Vec<usize> = (0..100).collect();
        let out = run_indexed(&jobs, 8, || (), |_, &j| j * 2);
        assert_eq!(out, (0..100).map(|j| j * 2).collect::<Vec<_>>());
    }

    #[test]
    fn per_worker_state_is_isolated() {
        // each worker counts its own jobs; totals must sum to n
        use std::sync::atomic::{AtomicUsize, Ordering};
        static TOTAL: AtomicUsize = AtomicUsize::new(0);
        struct Counter(usize);
        impl Drop for Counter {
            fn drop(&mut self) {
                TOTAL.fetch_add(self.0, Ordering::SeqCst);
            }
        }
        let jobs: Vec<u32> = (0..57).collect();
        let _ = run_indexed(&jobs, 4, || Counter(0), |s, _| s.0 += 1);
        assert_eq!(TOTAL.load(Ordering::SeqCst), 57);
    }

    #[test]
    fn empty_and_single() {
        let out: Vec<u32> = run_indexed(&[] as &[u32], 4, || (), |_, &j| j);
        assert!(out.is_empty());
        let out = run_indexed(&[9u32], 16, || (), |_, &j| j + 1);
        assert_eq!(out, vec![10]);
    }

    #[test]
    fn prop_matches_sequential_map() {
        run_prop("pool_matches_map", 30, |g| {
            let n = g.usize_in(0, 64);
            let jobs: Vec<i64> = (0..n).map(|_| g.int_in(-1000, 1000)).collect();
            let workers = g.usize_in(1, 9);
            let par = run_indexed(&jobs, workers, || (), |_, &j| j * j - 3);
            let seq: Vec<i64> = jobs.iter().map(|&j| j * j - 3).collect();
            assert_eq!(par, seq);
        });
    }
}
