//! The parallel sweep coordinator.

use std::sync::Arc;

use anyhow::Result;

use crate::coordinator::cache::{CachedAccuracy, ResultCache};
use crate::coordinator::pool::{default_workers, run_indexed};
use crate::eval::metrics::topk_accuracy;
use crate::eval::sweep::{forward_eval, forward_eval_parallel, ConfigResult, EvalOptions};
use crate::formats::Format;
use crate::hw;
use crate::nn::{Network, Zoo};
use crate::serving::NativeBackend;

/// Parallel sweep of `formats` over one network, with caching.
///
/// Two levels of parallelism, both through the same pool
/// (DESIGN.md §7): the formats fan out over `workers` with one
/// [`NativeBackend`] per worker, and the baseline evaluation that gates
/// the sweep — a single config, which format-level fan-out alone would
/// run on one core — fans its *batches* out instead.
pub fn sweep_formats(
    net: &Arc<Network>,
    formats: &[Format],
    opts: &EvalOptions,
    workers: usize,
    cache: &ResultCache,
) -> Result<Vec<ConfigResult>> {
    let samples = opts.samples.min(net.eval_len());

    // baseline accuracy on the identical subset (cached like any config)
    let baseline = cached_accuracy(net, &Format::SINGLE, opts, cache, 1.0, workers)?.accuracy;

    let jobs: Vec<Format> = formats.to_vec();
    let results = run_indexed(
        &jobs,
        workers,
        || NativeBackend::new(net.clone()),
        |backend, fmt| -> Result<(Format, CachedAccuracy)> {
            if let Some(hit) = cache.get(&net.name, &fmt.id(), samples) {
                return Ok((*fmt, hit));
            }
            let (logits, labels) = forward_eval(backend, fmt, opts)?;
            let acc = topk_accuracy(&logits, &labels, net.classes, net.topk);
            let na = if baseline > 0.0 { acc / baseline } else { 0.0 };
            let v = CachedAccuracy { accuracy: acc, normalized_accuracy: na };
            cache.put(&net.name, &fmt.id(), samples, v);
            Ok((*fmt, v))
        },
    );

    let mut out = Vec::with_capacity(results.len());
    for r in results {
        let (fmt, v) = r?;
        let eff = hw::speedup::efficiency(&fmt);
        out.push(ConfigResult {
            format: fmt,
            accuracy: v.accuracy,
            normalized_accuracy: v.normalized_accuracy,
            speedup: eff.speedup,
            energy_savings: eff.energy_savings,
        });
    }
    Ok(out)
}

fn cached_accuracy(
    net: &Arc<Network>,
    fmt: &Format,
    opts: &EvalOptions,
    cache: &ResultCache,
    na: f64,
    workers: usize,
) -> Result<CachedAccuracy> {
    let samples = opts.samples.min(net.eval_len());
    if let Some(hit) = cache.get(&net.name, &fmt.id(), samples) {
        return Ok(hit);
    }
    let (logits, labels) = forward_eval_parallel(net, fmt, opts, workers)?;
    let acc = topk_accuracy(&logits, &labels, net.classes, net.topk);
    let v = CachedAccuracy { accuracy: acc, normalized_accuracy: na };
    cache.put(&net.name, &fmt.id(), samples, v);
    Ok(v)
}

/// High-level façade over a zoo: owns the cache and worker settings.
pub struct Coordinator {
    pub zoo: Zoo,
    pub workers: usize,
    pub cache: ResultCache,
}

impl Coordinator {
    pub fn new(zoo: Zoo, cache: ResultCache) -> Coordinator {
        Coordinator { zoo, workers: default_workers(), cache }
    }

    pub fn with_workers(mut self, workers: usize) -> Coordinator {
        self.workers = workers.max(1);
        self
    }

    /// Sweep one network across `formats`.
    pub fn sweep(
        &self,
        net_name: &str,
        formats: &[Format],
        opts: &EvalOptions,
    ) -> Result<Vec<ConfigResult>> {
        let net = self.zoo.network(net_name)?;
        let out = sweep_formats(&net, formats, opts, self.workers, &self.cache)?;
        self.cache.flush()?;
        Ok(out)
    }
}
