//! Dynamic-batching inference server (the request-path façade).
//!
//! Single-sample requests are queued; the dispatcher thread flushes a
//! batch when either the artifact batch size is reached or the oldest
//! queued request exceeds `max_wait` (classic dynamic batching, as in
//! vLLM-style routers).  The execution backend is pluggable via
//! [`BatchRunner`] — the PJRT executable on the request path, or the
//! native engine (tests, quickstart).
//!
//! PJRT handles are not `Send` (the xla crate wraps raw pointers in
//! `Rc`), so the server takes a *factory*: the backend is constructed on
//! the dispatcher thread itself and never crosses a thread boundary.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::formats::Format;
use crate::nn::{Engine, Network};
use crate::tensor::Tensor;

/// Anything that can run a fixed-size batch (B, H, W, C) -> (B, classes).
pub trait BatchRunner {
    fn run(&mut self, x: &Tensor, fmt: &Format) -> Result<Tensor>;
}

/// Native-engine backend.
pub struct NativeRunner {
    pub net: Arc<Network>,
    engine: Engine,
}

impl NativeRunner {
    pub fn new(net: Arc<Network>) -> NativeRunner {
        NativeRunner { net, engine: Engine::new() }
    }
}

impl BatchRunner for NativeRunner {
    fn run(&mut self, x: &Tensor, fmt: &Format) -> Result<Tensor> {
        Ok(self.engine.forward(&self.net, x, fmt))
    }
}

/// PJRT backend (the AOT artifact executable; `pjrt` feature only —
/// builds without it fall back to [`NativeRunner`], DESIGN.md §5).
/// Construct it inside the server's factory closure — it cannot cross
/// threads.
#[cfg(feature = "pjrt")]
pub struct PjrtRunner {
    pub model: crate::runtime::LoadedModel,
}

#[cfg(feature = "pjrt")]
impl BatchRunner for PjrtRunner {
    fn run(&mut self, x: &Tensor, fmt: &Format) -> Result<Tensor> {
        self.model.run_batch(x, fmt)
    }
}

struct Request {
    /// one sample, H*W*C values
    pixels: Vec<f32>,
    reply: Sender<Result<Vec<f32>>>,
    enqueued: Instant,
}

/// Per-batch telemetry, folded into [`ServerStats`].
#[derive(Clone, Copy, Debug, Default)]
pub struct ServerStats {
    pub requests: u64,
    pub batches: u64,
    pub padded_slots: u64,
}

/// Handle for submitting requests; dropping it shuts the server down.
pub struct InferenceServer {
    tx: Sender<Request>,
    worker: Option<JoinHandle<ServerStats>>,
    input_len: usize,
}

impl InferenceServer {
    /// Spawn the dispatcher.  `factory` builds the backend **on the
    /// dispatcher thread**; `batch` is the fixed execution batch size.
    pub fn spawn<R, F>(
        net: Arc<Network>,
        batch: usize,
        fmt: Format,
        max_wait: Duration,
        factory: F,
    ) -> InferenceServer
    where
        R: BatchRunner,
        F: FnOnce() -> Result<R> + Send + 'static,
    {
        let (tx, rx): (Sender<Request>, Receiver<Request>) = channel();
        let [h, w, c] = net.input;
        let input_len = h * w * c;
        let classes = net.classes;

        let worker = std::thread::spawn(move || -> ServerStats {
            let mut stats = ServerStats::default();
            let mut runner = match factory() {
                Ok(r) => r,
                Err(e) => {
                    // fail every request with the construction error
                    while let Ok(r) = rx.recv() {
                        let _ = r.reply.send(Err(anyhow!("backend init failed: {e}")));
                    }
                    return stats;
                }
            };
            let mut queue: Vec<Request> = Vec::with_capacity(batch);
            loop {
                if queue.is_empty() {
                    match rx.recv() {
                        Ok(r) => queue.push(r),
                        Err(_) => break, // all senders gone: shut down
                    }
                }
                // drain whatever already queued up while the previous
                // batch was executing (closed-loop clients resubmit
                // during compute, so the backlog is usually here) ...
                while queue.len() < batch {
                    match rx.try_recv() {
                        Ok(r) => queue.push(r),
                        Err(_) => break,
                    }
                }
                // ... then accumulate until full or the oldest request
                // exceeds its batching window
                while queue.len() < batch {
                    let age = queue[0].enqueued.elapsed();
                    if age >= max_wait {
                        break;
                    }
                    match rx.recv_timeout(max_wait - age) {
                        Ok(r) => queue.push(r),
                        Err(_) => break,
                    }
                }

                let live = queue.len();
                let mut xdata = Vec::with_capacity(batch * input_len);
                for r in &queue {
                    xdata.extend_from_slice(&r.pixels);
                }
                xdata.resize(batch * input_len, 0.0); // pad dead slots
                stats.requests += live as u64;
                stats.batches += 1;
                stats.padded_slots += (batch - live) as u64;

                let x = match Tensor::new(vec![batch, h, w, c], xdata) {
                    Ok(t) => t,
                    Err(e) => {
                        let msg = format!("{e}");
                        for r in queue.drain(..) {
                            let _ = r.reply.send(Err(anyhow!("bad batch: {msg}")));
                        }
                        continue;
                    }
                };

                match runner.run(&x, &fmt) {
                    Ok(out) => {
                        for (i, r) in queue.drain(..).enumerate() {
                            let row = out.data()[i * classes..(i + 1) * classes].to_vec();
                            let _ = r.reply.send(Ok(row));
                        }
                    }
                    Err(e) => {
                        let msg = format!("{e}");
                        for r in queue.drain(..) {
                            let _ = r.reply.send(Err(anyhow!("batch failed: {msg}")));
                        }
                    }
                }
            }
            stats
        });

        InferenceServer { tx, worker: Some(worker), input_len }
    }

    /// Convenience: native-engine server.
    pub fn native(net: Arc<Network>, batch: usize, fmt: Format, max_wait: Duration) -> InferenceServer {
        let net2 = net.clone();
        Self::spawn(net, batch, fmt, max_wait, move || Ok(NativeRunner::new(net2)))
    }

    /// Submit one sample; blocks until its logits come back.
    pub fn infer(&self, pixels: Vec<f32>) -> Result<Vec<f32>> {
        self.infer_async(pixels)?
            .recv()
            .map_err(|_| anyhow!("server dropped the request"))?
    }

    /// Async-style submit: returns a receiver for the logits.
    pub fn infer_async(&self, pixels: Vec<f32>) -> Result<Receiver<Result<Vec<f32>>>> {
        if pixels.len() != self.input_len {
            anyhow::bail!("expected {} pixels, got {}", self.input_len, pixels.len());
        }
        let (rtx, rrx) = channel();
        self.tx
            .send(Request { pixels, reply: rtx, enqueued: Instant::now() })
            .map_err(|_| anyhow!("server is down"))?;
        Ok(rrx)
    }

    /// Shut down and return the dispatcher's telemetry.
    pub fn shutdown(mut self) -> ServerStats {
        let (dead_tx, _) = channel();
        self.tx = dead_tx;
        self.worker
            .take()
            .map(|h| h.join().unwrap_or_default())
            .unwrap_or_default()
    }
}

impl Drop for InferenceServer {
    fn drop(&mut self) {
        let (dead_tx, _) = channel();
        self.tx = dead_tx;
        if let Some(h) = self.worker.take() {
            let _ = h.join();
        }
    }
}
