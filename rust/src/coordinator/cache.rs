//! Persistent sweep-result cache.
//!
//! Keyed by `(network, format id, samples)`; stores (accuracy,
//! normalized accuracy).  Hardware numbers are analytic and never
//! cached.  The figure harness re-runs are near-instant once the sweep
//! has been paid for.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::Result;

use crate::util::json::Json;

#[derive(Clone, Copy, Debug, PartialEq)]
pub struct CachedAccuracy {
    pub accuracy: f64,
    pub normalized_accuracy: f64,
}

pub struct ResultCache {
    path: Option<PathBuf>,
    map: Mutex<BTreeMap<String, CachedAccuracy>>,
    dirty: Mutex<bool>,
}

fn key(net: &str, fmt_id: &str, samples: usize) -> String {
    format!("{net}|{fmt_id}|{samples}")
}

impl ResultCache {
    /// In-memory cache (tests).
    pub fn ephemeral() -> ResultCache {
        ResultCache {
            path: None,
            map: Mutex::new(BTreeMap::new()),
            dirty: Mutex::new(false),
        }
    }

    /// Load (or start) a cache backed by a JSON file.
    pub fn open(path: impl AsRef<Path>) -> ResultCache {
        let path = path.as_ref().to_path_buf();
        let mut map = BTreeMap::new();
        if let Ok(text) = std::fs::read_to_string(&path) {
            if let Ok(j) = Json::parse(&text) {
                if let Some(obj) = j.as_obj() {
                    for (k, v) in obj {
                        let (Some(acc), Some(na)) = (
                            v.get("acc").and_then(Json::as_f64),
                            v.get("na").and_then(Json::as_f64),
                        ) else {
                            continue;
                        };
                        map.insert(k.clone(), CachedAccuracy { accuracy: acc, normalized_accuracy: na });
                    }
                }
            }
        }
        ResultCache {
            path: Some(path),
            map: Mutex::new(map),
            dirty: Mutex::new(false),
        }
    }

    pub fn get(&self, net: &str, fmt_id: &str, samples: usize) -> Option<CachedAccuracy> {
        self.map.lock().unwrap().get(&key(net, fmt_id, samples)).copied()
    }

    pub fn put(&self, net: &str, fmt_id: &str, samples: usize, v: CachedAccuracy) {
        self.map.lock().unwrap().insert(key(net, fmt_id, samples), v);
        *self.dirty.lock().unwrap() = true;
    }

    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Write back to disk if dirty (no-op for ephemeral caches).
    pub fn flush(&self) -> Result<()> {
        let Some(path) = &self.path else { return Ok(()) };
        if !*self.dirty.lock().unwrap() {
            return Ok(());
        }
        let map = self.map.lock().unwrap();
        let obj: BTreeMap<String, Json> = map
            .iter()
            .map(|(k, v)| {
                (
                    k.clone(),
                    Json::obj(vec![
                        ("acc", Json::num(v.accuracy)),
                        ("na", Json::num(v.normalized_accuracy)),
                    ]),
                )
            })
            .collect();
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, Json::Obj(obj).to_string())?;
        *self.dirty.lock().unwrap() = false;
        Ok(())
    }
}

impl Drop for ResultCache {
    fn drop(&mut self) {
        let _ = self.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_roundtrip() {
        let c = ResultCache::ephemeral();
        assert!(c.get("net", "float:m7e6", 128).is_none());
        let v = CachedAccuracy { accuracy: 0.9, normalized_accuracy: 0.97 };
        c.put("net", "float:m7e6", 128, v);
        assert_eq!(c.get("net", "float:m7e6", 128), Some(v));
        // different samples => different key
        assert!(c.get("net", "float:m7e6", 64).is_none());
    }

    #[test]
    fn persists_across_open() {
        let p = std::env::temp_dir().join("precis_cache_test.json");
        std::fs::remove_file(&p).ok();
        {
            let c = ResultCache::open(&p);
            c.put("a", "fixed:l8r8", 32, CachedAccuracy { accuracy: 0.5, normalized_accuracy: 0.55 });
            c.flush().unwrap();
        }
        let c2 = ResultCache::open(&p);
        let v = c2.get("a", "fixed:l8r8", 32).unwrap();
        assert_eq!(v.accuracy, 0.5);
        assert_eq!(v.normalized_accuracy, 0.55);
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn corrupt_file_is_ignored() {
        let p = std::env::temp_dir().join("precis_cache_corrupt.json");
        std::fs::write(&p, "not json at all").unwrap();
        let c = ResultCache::open(&p);
        assert!(c.is_empty());
        std::fs::remove_file(&p).ok();
    }
}
