//! Observability: metrics registry, forward profiling, structured
//! events, and SLO burn-rate alerts (ISSUE 10, ROADMAP item 4).
//!
//! Four pieces, one contract:
//!
//! * [`Registry`] — named [`Counter`]s, [`Gauge`]s, and log-scale
//!   latency [`Histogram`]s on relaxed atomics.  Subsystems that
//!   already own counters (weight store, QoS gates) *adopt* their
//!   existing cells into the registry, so stats snapshots and the
//!   registry read the same atomics.
//! * [`ForwardProfile`] / [`LayerSpan`] — per-layer wall time, executed
//!   lane, MACs, and clamp counts for one profiled forward
//!   (`SessionOptions.profile`, `repro eval --profile`).
//! * [`EventSink`] / [`Event`] — bounded MPSC JSON-lines log
//!   (`--events-out events.jsonl`) of session, store, shed, and SLO
//!   lifecycle records.
//! * [`BurnMeter`] — fast/slow-window error-budget burn from the
//!   shed/served books; feeds `Alert` events and the
//!   `GatewayStats::render` burn column.
//!
//! The contract (pinned by `tests/obs_contract.rs` and the
//! `obs_overhead/*` bench section): **zero overhead when off, lock-free
//! when on**.  Profiling off is byte-identical to a build without this
//! module; with the registry live, warm forwards still take no lock
//! (`tests/store_contract.rs`).

pub mod burn;
pub mod events;
pub mod profile;
pub mod registry;

pub use burn::{BurnConfig, BurnMeter, BurnReading};
pub use events::{Captured, Event, EventSink};
pub use profile::{ForwardProfile, LayerSpan};
pub use registry::{Counter, Gauge, Histogram, MetricValue, Registry};
