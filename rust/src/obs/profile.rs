//! Per-forward span profiling: per-layer wall time, executed lane,
//! MAC count, and an opt-in activation clamp/saturation counter — the
//! runtime generalization of `numerics::trace::AccumTrace`'s
//! `first_saturation` probe, applied to live traffic instead of a
//! single traced dot product.
//!
//! The profiler is strictly opt-in (`SessionOptions.profile`,
//! `repro eval --profile`).  When off, the engine takes no timestamps,
//! runs no saturation scans, and produces bit-identical outputs to a
//! build without this module (pinned by `tests/obs_contract.rs`).

use crate::util::json::Json;
use crate::util::table::Columns;
use crate::util::timer::human;

/// One executed layer inside a profiled forward.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerSpan {
    /// layer name from the network spec (e.g. `"c1"`, `"fc"`)
    pub name: String,
    /// executed lane label: `"staged"`, `"int16"`, `"int32"`, or
    /// `"lut"` (the `PackedPlan::label` vocabulary)
    pub lane: String,
    /// wall time spent inside the layer's kernel dispatch
    pub wall_s: f64,
    /// multiply-accumulates issued: `m * k * n` of the layer's GEMM
    /// (convolutions count their im2col-equivalent GEMM)
    pub macs: u64,
    /// output activations at or beyond the activation format's
    /// representable magnitude — 0 when the layer output is f32-exact
    pub clamps: u64,
}

/// The aggregate of one profiled forward.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ForwardProfile {
    pub layers: Vec<LayerSpan>,
    /// end-to-end wall time of the forward (covers layer spans plus
    /// inter-layer glue; per-layer times sum to ~this)
    pub total_s: f64,
    /// batch size the forward executed with
    pub batch: usize,
}

impl ForwardProfile {
    /// Sum of per-layer wall times (≤ `total_s` up to glue and timer
    /// granularity).
    pub fn layers_total_s(&self) -> f64 {
        self.layers.iter().map(|l| l.wall_s).sum()
    }

    pub fn total_macs(&self) -> u64 {
        self.layers.iter().map(|l| l.macs).sum()
    }

    pub fn total_clamps(&self) -> u64 {
        self.layers.iter().map(|l| l.clamps).sum()
    }

    /// Per-layer table: name, lane, wall, share of layer time, MACs,
    /// effective GMAC/s, clamped activations.
    pub fn render(&self) -> String {
        let cols = Columns::new(&[16, 8, 10, 7, 12, 9, 8]);
        let mut out = String::new();
        out.push_str(&cols.row(&["layer", "lane", "wall", "share", "macs", "gmac/s", "clamps"]));
        out.push('\n');
        let span_total = self.layers_total_s();
        for l in &self.layers {
            let share = if span_total > 0.0 { 100.0 * l.wall_s / span_total } else { 0.0 };
            let gmacs = if l.wall_s > 0.0 { l.macs as f64 / l.wall_s / 1e9 } else { 0.0 };
            out.push_str(&cols.row(&[
                l.name.clone(),
                l.lane.clone(),
                human(l.wall_s),
                format!("{share:.1}%"),
                l.macs.to_string(),
                format!("{gmacs:.2}"),
                l.clamps.to_string(),
            ]));
            out.push('\n');
        }
        out.push_str(&format!(
            "forward total: {} (layers {}, batch {}, {} MACs, {} clamped)\n",
            human(self.total_s),
            human(span_total),
            self.batch,
            self.total_macs(),
            self.total_clamps(),
        ));
        out
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("batch", Json::num(self.batch as f64)),
            ("total_s", Json::num(self.total_s)),
            (
                "layers",
                Json::arr(
                    self.layers
                        .iter()
                        .map(|l| {
                            Json::obj(vec![
                                ("name", Json::str(&l.name)),
                                ("lane", Json::str(&l.lane)),
                                ("wall_s", Json::num(l.wall_s)),
                                ("macs", Json::num(l.macs as f64)),
                                ("clamps", Json::num(l.clamps as f64)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture() -> ForwardProfile {
        ForwardProfile {
            layers: vec![
                LayerSpan {
                    name: "c1".into(),
                    lane: "int16".into(),
                    wall_s: 3e-3,
                    macs: 1_000_000,
                    clamps: 2,
                },
                LayerSpan {
                    name: "fc".into(),
                    lane: "staged".into(),
                    wall_s: 1e-3,
                    macs: 250_000,
                    clamps: 0,
                },
            ],
            total_s: 4.2e-3,
            batch: 8,
        }
    }

    #[test]
    fn totals_aggregate_over_layers() {
        let p = fixture();
        assert!((p.layers_total_s() - 4e-3).abs() < 1e-12);
        assert_eq!(p.total_macs(), 1_250_000);
        assert_eq!(p.total_clamps(), 2);
    }

    #[test]
    fn render_lists_layers_lanes_and_totals() {
        let r = fixture().render();
        assert!(r.contains("layer"), "header:\n{r}");
        assert!(r.contains("int16") && r.contains("staged"), "lanes:\n{r}");
        assert!(r.contains("75.0%"), "c1 holds 3/4 of layer time:\n{r}");
        assert!(r.contains("batch 8"), "totals:\n{r}");
        assert!(r.contains("2 clamped"), "clamp total:\n{r}");
    }

    #[test]
    fn json_roundtrips_through_util_json() {
        let doc = fixture().to_json().to_string();
        let parsed = Json::parse(&doc).expect("valid json");
        let layers = parsed.get("layers").and_then(Json::as_arr).expect("layers");
        assert_eq!(layers.len(), 2);
        assert_eq!(layers[0].get("lane").and_then(Json::as_str), Some("int16"));
        assert_eq!(layers[1].get("name").and_then(Json::as_str), Some("fc"));
        assert_eq!(parsed.get("batch").and_then(Json::as_f64), Some(8.0));
    }
}
