//! The lock-free metrics registry: named counters, gauges, and
//! fixed-bucket log-scale latency histograms.
//!
//! Hot-path contract (DESIGN.md §Observability): every mutation —
//! [`Counter::add`], [`Gauge::set`], [`Histogram::record`] — is a
//! handful of `Relaxed` atomic operations on pre-allocated cells.  The
//! registry's own map IS behind a mutex, but it is touched only at
//! registration and snapshot time: callers prefetch `Arc` handles once
//! (session open, store construction) and the serving hot path never
//! sees the lock.  [`Registry::adopt_counter`] lets a subsystem that
//! already owns its counters (the weight store, the QoS gates) register
//! the SAME cells instead of mirroring them, so the stats surfaces and
//! the registry are views over one set of atomics by construction.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, PoisonError};

/// A monotonically increasing relaxed-atomic counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub const fn new() -> Counter {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    #[inline]
    pub fn incr(&self) {
        self.add(1);
    }

    #[inline]
    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// A last-value-wins f64 cell (stored as bits, like
/// `QosGate::record_p99_ms`).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub const fn new() -> Gauge {
        Gauge(AtomicU64::new(0))
    }

    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    #[inline]
    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Sub-buckets per power-of-two octave.  8 keeps any bucket's relative
/// width at 1/8 of its lower bound — a p99 read off the histogram is
/// within ~12.5% of the exact order statistic by construction.
pub const HIST_SUB_BUCKETS: u64 = 8;
/// Octaves covered above the 1ns floor: 2^40 ns ≈ 18 minutes, far past
/// any latency this system reports; beyond that is one overflow bucket.
pub const HIST_OCTAVES: usize = 40;
/// Total buckets: the `< 1ns` floor bucket, `HIST_OCTAVES * 8` log-scale
/// buckets, and the overflow bucket.
pub const HIST_BUCKETS: usize = 1 + HIST_OCTAVES * HIST_SUB_BUCKETS as usize + 1;

/// Fixed-bucket log-scale latency histogram over seconds.
///
/// Values are mapped to whole nanoseconds, then to `(octave, sub)`
/// where `octave = floor(log2(ns))` and the octave is split into
/// [`HIST_SUB_BUCKETS`] linear sub-buckets (the HdrHistogram layout).
/// The index math is pure integer arithmetic, so bucket boundaries are
/// EXACT — `bounds_s(bucket_index(v))` always brackets `v` — and a
/// merge is bucket-wise count addition (associative and commutative).
#[derive(Debug)]
pub struct Histogram {
    buckets: [AtomicU64; HIST_BUCKETS],
    count: Counter,
    /// total recorded time in whole nanoseconds (throughput/mean views)
    sum_ns: Counter,
}

impl Default for Histogram {
    fn default() -> Self {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: std::array::from_fn(|_| AtomicU64::new(0)),
            count: Counter::new(),
            sum_ns: Counter::new(),
        }
    }

    /// The bucket index for a duration in seconds.  Non-finite and
    /// negative inputs land in the floor bucket (they carry no
    /// duration; see `util::timer::human`).
    pub fn bucket_index(seconds: f64) -> usize {
        if !seconds.is_finite() || seconds <= 0.0 {
            return 0;
        }
        let ns = (seconds * 1e9) as u64;
        if ns == 0 {
            return 0;
        }
        let octave = 63 - ns.leading_zeros() as u64;
        if octave >= HIST_OCTAVES as u64 {
            return HIST_BUCKETS - 1;
        }
        // linear split of [2^octave, 2^(octave+1)) into 8 sub-buckets
        let sub = ((ns - (1u64 << octave)) * HIST_SUB_BUCKETS) >> octave;
        1 + (octave * HIST_SUB_BUCKETS + sub) as usize
    }

    /// The `[lo, hi)` bounds of bucket `i`, in seconds.
    pub fn bounds_s(i: usize) -> (f64, f64) {
        if i == 0 {
            return (0.0, 1e-9);
        }
        if i >= HIST_BUCKETS - 1 {
            return ((1u64 << HIST_OCTAVES) as f64 * 1e-9, f64::INFINITY);
        }
        let k = (i - 1) as u64;
        let (octave, sub) = (k / HIST_SUB_BUCKETS, k % HIST_SUB_BUCKETS);
        let base = (1u64 << octave) as f64;
        let step = base / HIST_SUB_BUCKETS as f64;
        let lo = base + sub as f64 * step;
        ((lo) * 1e-9, (lo + step) * 1e-9)
    }

    #[inline]
    pub fn record(&self, seconds: f64) {
        self.buckets[Self::bucket_index(seconds)].fetch_add(1, Ordering::Relaxed);
        self.count.incr();
        if seconds.is_finite() && seconds > 0.0 {
            self.sum_ns.add((seconds * 1e9) as u64);
        }
    }

    pub fn count(&self) -> u64 {
        self.count.get()
    }

    /// Mean recorded duration in seconds (0 when empty).
    pub fn mean_s(&self) -> f64 {
        let n = self.count();
        if n == 0 {
            0.0
        } else {
            self.sum_ns.get() as f64 * 1e-9 / n as f64
        }
    }

    /// Nearest-rank quantile over the bucket counts: the midpoint of
    /// the bucket holding the element at rank `round((count-1) * q)` —
    /// the same rank rule as [`crate::bench_harness::percentile`], so
    /// the two agree within one bucket width on any sample set.
    pub fn quantile(&self, q: f64) -> f64 {
        let n = self.count();
        if n == 0 {
            return 0.0;
        }
        let rank = ((n - 1) as f64 * q.clamp(0.0, 1.0)).round() as u64;
        let mut seen = 0u64;
        for i in 0..HIST_BUCKETS {
            seen += self.buckets[i].load(Ordering::Relaxed);
            if seen > rank {
                let (lo, hi) = Self::bounds_s(i);
                // the overflow bucket has no finite midpoint
                return if hi.is_finite() { (lo + hi) / 2.0 } else { lo };
            }
        }
        // counts raced upward between count() and the scan: the last
        // populated bucket is still the right answer
        Self::bounds_s(HIST_BUCKETS - 1).0
    }

    /// Fold another histogram's counts into this one (bucket-wise
    /// addition — associative, commutative, identity = empty).
    pub fn merge_from(&self, other: &Histogram) {
        for i in 0..HIST_BUCKETS {
            let n = other.buckets[i].load(Ordering::Relaxed);
            if n > 0 {
                self.buckets[i].fetch_add(n, Ordering::Relaxed);
            }
        }
        self.count.add(other.count());
        self.sum_ns.add(other.sum_ns.get());
    }

    /// Raw bucket counts (tests, exporters).
    pub fn bucket_counts(&self) -> Vec<u64> {
        self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect()
    }
}

/// A point-in-time reading of one metric.
#[derive(Clone, Debug, PartialEq)]
pub enum MetricValue {
    Counter(u64),
    Gauge(f64),
    /// (count, mean_s, p50_s, p99_s)
    Histogram { count: u64, mean_s: f64, p50_s: f64, p99_s: f64 },
}

#[derive(Default)]
struct Inner {
    counters: BTreeMap<String, Arc<Counter>>,
    gauges: BTreeMap<String, Arc<Gauge>>,
    histograms: BTreeMap<String, Arc<Histogram>>,
}

/// The named-metric registry.  Registration and snapshots lock; the
/// returned `Arc` handles never do.
#[derive(Default)]
pub struct Registry {
    inner: Mutex<Inner>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Get-or-create: idempotent by name, so re-registration under the
    /// same name hands back the SAME cell.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.lock().counters.entry(name.to_string()).or_default().clone()
    }

    /// Register an EXISTING counter cell under `name` — the adoption
    /// path for subsystems that already own their atomics (store,
    /// gates).  If the name is taken the incumbent wins and is
    /// returned, keeping adoption idempotent.
    pub fn adopt_counter(&self, name: &str, cell: &Arc<Counter>) -> Arc<Counter> {
        self.lock().counters.entry(name.to_string()).or_insert_with(|| cell.clone()).clone()
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        self.lock().gauges.entry(name.to_string()).or_default().clone()
    }

    pub fn histogram(&self, name: &str) -> Arc<Histogram> {
        self.lock().histograms.entry(name.to_string()).or_default().clone()
    }

    /// Read every registered metric, sorted by name.
    pub fn snapshot(&self) -> Vec<(String, MetricValue)> {
        let g = self.lock();
        let mut out: Vec<(String, MetricValue)> = Vec::new();
        for (k, c) in &g.counters {
            out.push((k.clone(), MetricValue::Counter(c.get())));
        }
        for (k, v) in &g.gauges {
            out.push((k.clone(), MetricValue::Gauge(v.get())));
        }
        for (k, h) in &g.histograms {
            out.push((
                k.clone(),
                MetricValue::Histogram {
                    count: h.count(),
                    mean_s: h.mean_s(),
                    p50_s: h.quantile(0.5),
                    p99_s: h.quantile(0.99),
                },
            ));
        }
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// The counter's current value, if registered (stats views).
    pub fn counter_value(&self, name: &str) -> Option<u64> {
        self.lock().counters.get(name).map(|c| c.get())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::bench_harness::percentile;

    #[test]
    fn counters_and_gauges_read_back() {
        let reg = Registry::new();
        let c = reg.counter("store/hits");
        c.add(3);
        c.incr();
        assert_eq!(reg.counter("store/hits").get(), 4, "same cell by name");
        assert_eq!(reg.counter_value("store/hits"), Some(4));
        assert_eq!(reg.counter_value("absent"), None);
        let g = reg.gauge("qos/p99_ms");
        g.set(12.5);
        assert_eq!(reg.gauge("qos/p99_ms").get(), 12.5);
    }

    #[test]
    fn adopt_counter_shares_the_cell_and_is_idempotent() {
        let reg = Registry::new();
        let owned = Arc::new(Counter::new());
        let adopted = reg.adopt_counter("store/misses", &owned);
        assert!(Arc::ptr_eq(&owned, &adopted));
        owned.add(7);
        assert_eq!(reg.counter_value("store/misses"), Some(7), "one set of atomics");
        // a second adoption (or a plain counter() lookup) keeps the
        // incumbent cell
        let other = Arc::new(Counter::new());
        assert!(Arc::ptr_eq(&reg.adopt_counter("store/misses", &other), &owned));
        assert!(Arc::ptr_eq(&reg.counter("store/misses"), &owned));
    }

    /// ISSUE 10 satellite: bucket-boundary exactness.  For every bucket
    /// the returned bounds bracket exactly the values that map to it —
    /// checked at and adjacent to each boundary in integer nanoseconds.
    #[test]
    fn histogram_bucket_boundaries_are_exact() {
        for i in 1..HIST_BUCKETS - 1 {
            let (lo, hi) = Histogram::bounds_s(i);
            let (lo_ns, hi_ns) = (lo * 1e9, hi * 1e9);
            // the lower bound is IN the bucket, one ns below is not
            assert_eq!(Histogram::bucket_index(lo_ns * 1e-9), i, "lo of {i}");
            assert_eq!(
                Histogram::bucket_index((lo_ns - 1.0) * 1e-9),
                i - 1,
                "lo-1ns of {i} (lo = {lo_ns}ns)"
            );
            // the upper bound is the NEXT bucket's lower bound
            assert_eq!(Histogram::bucket_index(hi_ns * 1e-9), i + 1, "hi of {i}");
        }
        // floor and overflow
        assert_eq!(Histogram::bucket_index(0.0), 0);
        assert_eq!(Histogram::bucket_index(-1.0), 0);
        assert_eq!(Histogram::bucket_index(f64::NAN), 0);
        assert_eq!(Histogram::bucket_index(0.4e-9), 0);
        assert_eq!(Histogram::bucket_index(1e9), HIST_BUCKETS - 1);
        let (lo, hi) = Histogram::bounds_s(HIST_BUCKETS - 1);
        assert_eq!(lo, (1u64 << HIST_OCTAVES) as f64 * 1e-9);
        assert!(hi.is_infinite());
    }

    /// ISSUE 10 satellite: merge associativity — (a ⊕ b) ⊕ c and
    /// a ⊕ (b ⊕ c) produce identical bucket counts, sums, and counts.
    #[test]
    fn histogram_merge_is_associative() {
        let seqs: [&[f64]; 3] = [
            &[1e-6, 2e-6, 3e-3],
            &[5e-9, 0.5, 0.25, 1e-4],
            &[2e-3, 2e-3, 7.0],
        ];
        let fill = |vals: &[f64]| {
            let h = Histogram::new();
            for &v in vals {
                h.record(v);
            }
            h
        };
        let left = fill(&[]);
        let ab = fill(&[]);
        ab.merge_from(&fill(seqs[0]));
        ab.merge_from(&fill(seqs[1]));
        left.merge_from(&ab);
        left.merge_from(&fill(seqs[2]));

        let right = fill(&[]);
        let bc = fill(&[]);
        bc.merge_from(&fill(seqs[1]));
        bc.merge_from(&fill(seqs[2]));
        right.merge_from(&fill(seqs[0]));
        right.merge_from(&bc);

        assert_eq!(left.bucket_counts(), right.bucket_counts());
        assert_eq!(left.count(), right.count());
        assert_eq!(left.mean_s(), right.mean_s());
    }

    /// ISSUE 10 satellite: histogram-derived p50/p99 agree with
    /// `bench_harness::percentile`'s nearest-rank statistic within one
    /// bucket width, on synthetic sequences spanning several octaves.
    #[test]
    fn histogram_quantiles_agree_with_nearest_rank_within_a_bucket() {
        let sequences: Vec<Vec<f64>> = vec![
            (1..=200).map(|i| i as f64 * 1e-4).collect(),
            (1..=50).map(|i| 1e-6 * 1.3f64.powi(i)).collect(),
            vec![3e-3; 100],
            (1..=10).map(|i| i as f64 * 1e-2).collect(),
        ];
        for seq in sequences {
            let h = Histogram::new();
            for &v in &seq {
                h.record(v);
            }
            let mut sorted = seq.clone();
            sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
            for q in [0.5, 0.99] {
                let exact = percentile(&sorted, q);
                let approx = h.quantile(q);
                let (lo, hi) = Histogram::bounds_s(Histogram::bucket_index(exact));
                let width = hi - lo;
                assert!(
                    (approx - exact).abs() <= width,
                    "q={q}: |{approx} - {exact}| > bucket width {width} (n={})",
                    seq.len()
                );
            }
            assert_eq!(h.count(), seq.len() as u64);
        }
    }

    #[test]
    fn snapshot_lists_every_metric_sorted() {
        let reg = Registry::new();
        reg.counter("b/count").add(2);
        reg.gauge("a/gauge").set(1.5);
        let h = reg.histogram("c/lat");
        h.record(1e-3);
        let snap = reg.snapshot();
        let names: Vec<&str> = snap.iter().map(|(n, _)| n.as_str()).collect();
        assert_eq!(names, vec!["a/gauge", "b/count", "c/lat"]);
        match &snap[2].1 {
            MetricValue::Histogram { count, p50_s, .. } => {
                assert_eq!(*count, 1);
                let (lo, hi) = Histogram::bounds_s(Histogram::bucket_index(1e-3));
                assert!(*p50_s >= lo && *p50_s <= hi);
            }
            v => panic!("expected a histogram, got {v:?}"),
        }
    }
}
