//! SLO error-budget burn-rate computation (ROADMAP item 4).
//!
//! The error budget is the fraction of offered requests a session is
//! ALLOWED to shed (`BurnConfig::budget`, default 1%).  Burn rate is
//! actual shed fraction divided by that budget, computed over two
//! windows in the multi-window style of SRE burn alerts:
//!
//! * **slow** — cumulative over the session's lifetime counters:
//!   `(shed / offered) / budget`.
//! * **fast** — over the delta since the previous [`BurnMeter::check`]
//!   call (the meter keeps per-session `(shed, offered)` snapshots), so
//!   a fresh overload spikes the fast window immediately while the slow
//!   window confirms it is sustained.
//!
//! An [`Alert`](super::Event::Alert) fires only when BOTH windows are
//! at or above 1.0 — fast alone is a blip, slow alone is old news.  The
//! inputs are the same shed/served counters `DriveReport` books against,
//! so an alert's totals reconcile exactly with the driver's ledger
//! (pinned by `tests/obs_contract.rs`).

use std::collections::BTreeMap;
use std::sync::{Mutex, PoisonError};

/// Burn-rate policy knobs.
#[derive(Clone, Copy, Debug)]
pub struct BurnConfig {
    /// allowed shed fraction of offered traffic (the error budget)
    pub budget: f64,
    /// minimum offered requests in a window before burn is meaningful —
    /// avoids a 1-of-2 shed reading as a 50x burn
    pub min_offered: u64,
}

impl Default for BurnConfig {
    fn default() -> Self {
        BurnConfig { budget: 0.01, min_offered: 20 }
    }
}

/// One burn evaluation for one session.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct BurnReading {
    /// delta-window burn multiple (1.0 = exactly on budget)
    pub fast: f64,
    /// lifetime burn multiple
    pub slow: f64,
    /// cumulative shed count the reading was computed from
    pub shed: u64,
    /// cumulative served count the reading was computed from
    pub served: u64,
    /// both windows at or over budget
    pub alerting: bool,
}

#[derive(Clone, Copy, Default)]
struct SessionWindow {
    shed: u64,
    offered: u64,
    burning: bool,
}

/// Tracks per-session shed/offered snapshots between stat polls and
/// turns counter deltas into burn readings.  Locking is confined to
/// `check`, which runs on the stats path (`Gateway::stats`), never on
/// a forward.
#[derive(Default)]
pub struct BurnMeter {
    cfg: BurnConfig,
    windows: Mutex<BTreeMap<String, SessionWindow>>,
}

impl BurnMeter {
    pub fn new(cfg: BurnConfig) -> BurnMeter {
        BurnMeter { cfg, windows: Mutex::new(BTreeMap::new()) }
    }

    pub fn config(&self) -> BurnConfig {
        self.cfg
    }

    fn burn(&self, shed: u64, offered: u64) -> f64 {
        if offered < self.cfg.min_offered.max(1) {
            return 0.0;
        }
        (shed as f64 / offered as f64) / self.cfg.budget
    }

    /// Evaluate one session from its cumulative counters.  `served` and
    /// `shed` must be lifetime totals (the same books `DriveReport`
    /// keeps); offered = served + shed.
    pub fn check(&self, session: &str, shed: u64, served: u64) -> BurnReading {
        let offered = shed + served;
        let slow = self.burn(shed, offered);
        let mut windows = self.windows.lock().unwrap_or_else(PoisonError::into_inner);
        let prev = windows.entry(session.to_string()).or_default();
        // counters are monotonic per session; a smaller value means the
        // session was replaced — restart the window
        let (d_shed, d_offered) = if shed >= prev.shed && offered >= prev.offered {
            (shed - prev.shed, offered - prev.offered)
        } else {
            (shed, offered)
        };
        let fast = self.burn(d_shed, d_offered);
        let alerting = fast >= 1.0 && slow >= 1.0;
        prev.shed = shed;
        prev.offered = offered;
        prev.burning = alerting;
        BurnReading { fast, slow, shed, served, alerting }
    }

    /// Whether the previous `check` left this session in the burning
    /// state (drives `SloState` transition events).
    pub fn was_burning(&self, session: &str) -> bool {
        self.windows
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .get(session)
            .map(|w| w.burning)
            .unwrap_or(false)
    }

    /// Forget a closed session's window.
    pub fn forget(&self, session: &str) {
        self.windows.lock().unwrap_or_else(PoisonError::into_inner).remove(session);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn under_budget_reads_below_one() {
        let m = BurnMeter::new(BurnConfig { budget: 0.01, min_offered: 10 });
        // 1 shed in 1000 offered at a 1% budget: burn 0.1x
        let r = m.check("s", 1, 999);
        assert!((r.slow - 0.1).abs() < 1e-12, "slow {}", r.slow);
        assert!((r.fast - 0.1).abs() < 1e-12, "fast {}", r.fast);
        assert!(!r.alerting);
    }

    #[test]
    fn sustained_overload_alerts_on_both_windows() {
        let m = BurnMeter::new(BurnConfig { budget: 0.01, min_offered: 10 });
        // 100 shed of 400 offered: shed fraction 25%, burn 25x
        let r = m.check("s", 100, 300);
        assert!(r.fast >= 1.0 && r.slow >= 1.0);
        assert!(r.alerting);
        assert_eq!((r.shed, r.served), (100, 300));
        assert!(m.was_burning("s"));
    }

    #[test]
    fn recovery_clears_the_fast_window_first() {
        let m = BurnMeter::new(BurnConfig { budget: 0.01, min_offered: 10 });
        assert!(m.check("s", 50, 50).alerting, "overload poll");
        // next poll: 400 more requests, none shed — fast window clean,
        // slow window still over budget from history
        let r = m.check("s", 50, 450);
        assert_eq!(r.fast, 0.0);
        assert!(r.slow >= 1.0);
        assert!(!r.alerting, "one clean window is enough to stop alerting");
        assert!(!m.was_burning("s"));
    }

    #[test]
    fn tiny_windows_do_not_alert() {
        let m = BurnMeter::new(BurnConfig::default());
        // 1 of 2 shed is a 50% fraction but far below min_offered
        let r = m.check("s", 1, 1);
        assert_eq!(r.fast, 0.0);
        assert_eq!(r.slow, 0.0);
        assert!(!r.alerting);
    }

    #[test]
    fn sessions_are_tracked_independently_and_forgettable() {
        let m = BurnMeter::new(BurnConfig { budget: 0.01, min_offered: 10 });
        m.check("a", 100, 0);
        let r = m.check("b", 0, 100);
        assert!(!r.alerting);
        assert!(m.was_burning("a") && !m.was_burning("b"));
        m.forget("a");
        assert!(!m.was_burning("a"));
        // a replaced session (counters reset) restarts the window
        let r = m.check("b", 5, 45);
        assert!(r.fast >= 1.0, "delta window sees the 5-of-{} shed burst", 50);
    }
}
