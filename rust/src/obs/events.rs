//! Structured event log: a bounded MPSC JSON-lines sink.
//!
//! Producers (`Gateway`, `Session`, `WeightStore`) call
//! [`EventSink::emit`] from serving hot paths, so the send side is
//! lock-free: a sequence-number `fetch_add` plus an `std::sync::mpsc`
//! `try_send` into a bounded channel.  When the channel is full or the
//! writer is gone the event is counted in `dropped` and discarded —
//! telemetry must never block a forward.  A single writer thread
//! serializes each event through `util::json` (no new deps) and writes
//! one object per line, flushing whenever the queue momentarily drains
//! so a tailing reader sees near-real-time output.  Dropping the last
//! `Arc<EventSink>` closes the channel and joins the writer, so the
//! file is complete on shutdown.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, SyncSender, TryRecvError};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{Context, Result};

use super::Counter;
use crate::util::json::Json;

/// Bounded queue depth: enough for a burst of sheds during overload,
/// small enough that a stuck disk cannot hold gigabytes of events.
const QUEUE_DEPTH: usize = 4096;

/// One typed record in the event log.
#[derive(Clone, Debug, PartialEq)]
pub enum Event {
    /// a session was opened or adopted into the gateway
    SessionOpen { key: String },
    /// a session was closed or shut down; `requests` is its lifetime total
    SessionClose { key: String, requests: u64 },
    /// the weight store evicted an entry to make room
    StoreEvict { key: String, bytes: usize },
    /// the weight store refused an entry that cannot fit
    StoreReject { key: String, bytes: usize },
    /// QoS admission shed a request (`reason`: "depth" or "latency")
    Shed { key: String, reason: &'static str, depth: usize },
    /// SLO burn state transition (`"ok"` ⇄ `"burning"`)
    SloState { key: String, from: &'static str, to: &'static str },
    /// burn-rate alert: both windows are over budget
    Alert { key: String, fast: f64, slow: f64, shed: u64, served: u64 },
}

impl Event {
    /// `kind` discriminator used in the JSON encoding.
    pub fn kind(&self) -> &'static str {
        match self {
            Event::SessionOpen { .. } => "session_open",
            Event::SessionClose { .. } => "session_close",
            Event::StoreEvict { .. } => "store_evict",
            Event::StoreReject { .. } => "store_reject",
            Event::Shed { .. } => "shed",
            Event::SloState { .. } => "slo_state",
            Event::Alert { .. } => "alert",
        }
    }

    fn to_json(&self, seq: u64, t_s: f64) -> Json {
        let mut pairs = vec![
            ("seq", Json::num(seq as f64)),
            ("t_s", Json::num(t_s)),
            ("kind", Json::str(self.kind())),
        ];
        match self {
            Event::SessionOpen { key } => pairs.push(("key", Json::str(key))),
            Event::SessionClose { key, requests } => {
                pairs.push(("key", Json::str(key)));
                pairs.push(("requests", Json::num(*requests as f64)));
            }
            Event::StoreEvict { key, bytes } | Event::StoreReject { key, bytes } => {
                pairs.push(("key", Json::str(key)));
                pairs.push(("bytes", Json::num(*bytes as f64)));
            }
            Event::Shed { key, reason, depth } => {
                pairs.push(("key", Json::str(key)));
                pairs.push(("reason", Json::str(reason)));
                pairs.push(("depth", Json::num(*depth as f64)));
            }
            Event::SloState { key, from, to } => {
                pairs.push(("key", Json::str(key)));
                pairs.push(("from", Json::str(from)));
                pairs.push(("to", Json::str(to)));
            }
            Event::Alert { key, fast, slow, shed, served } => {
                pairs.push(("key", Json::str(key)));
                pairs.push(("fast", Json::num(*fast)));
                pairs.push(("slow", Json::num(*slow)));
                pairs.push(("shed", Json::num(*shed as f64)));
                pairs.push(("served", Json::num(*served as f64)));
            }
        }
        Json::obj(pairs)
    }
}

struct Stamped {
    seq: u64,
    t_s: f64,
    event: Event,
}

/// In-memory capture target for tests (`EventSink::capture`).
#[derive(Clone, Default)]
pub struct Captured(Arc<Mutex<Vec<u8>>>);

impl Captured {
    /// The captured bytes as a string (call after the sink is dropped
    /// for a complete log).
    pub fn text(&self) -> String {
        let buf = self.0.lock().unwrap_or_else(PoisonError::into_inner);
        String::from_utf8_lossy(&buf).into_owned()
    }

    /// Parsed JSON lines (panics on malformed output — test-only).
    pub fn lines(&self) -> Vec<Json> {
        self.text()
            .lines()
            .filter(|l| !l.trim().is_empty())
            .map(|l| Json::parse(l).expect("event line is valid JSON"))
            .collect()
    }
}

impl Write for Captured {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap_or_else(PoisonError::into_inner).extend_from_slice(buf);
        Ok(buf.len())
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

/// The bounded JSON-lines event sink.  Cheap to share as
/// `Arc<EventSink>`; `emit` never blocks and never locks.
pub struct EventSink {
    tx: Option<SyncSender<Stamped>>,
    seq: AtomicU64,
    dropped: Counter,
    start: Instant,
    worker: Mutex<Option<JoinHandle<()>>>,
}

impl EventSink {
    fn spawn(out: Box<dyn Write + Send>) -> EventSink {
        let (tx, rx) = sync_channel::<Stamped>(QUEUE_DEPTH);
        let worker = std::thread::Builder::new()
            .name("obs-events".into())
            .spawn(move || writer_loop(rx, out))
            .expect("spawn event writer");
        EventSink {
            tx: Some(tx),
            seq: AtomicU64::new(0),
            dropped: Counter::new(),
            start: Instant::now(),
            worker: Mutex::new(Some(worker)),
        }
    }

    /// Sink writing JSON lines to `path` (truncates an existing file).
    pub fn to_file(path: &Path) -> Result<EventSink> {
        let f = File::create(path).with_context(|| format!("create {}", path.display()))?;
        Ok(EventSink::spawn(Box::new(BufWriter::new(f))))
    }

    /// Sink writing into an in-memory buffer — returns the sink and the
    /// capture handle (tests and the events smoke lane).
    pub fn capture() -> (EventSink, Captured) {
        let cap = Captured::default();
        (EventSink::spawn(Box::new(cap.clone())), cap)
    }

    /// Enqueue one event.  Non-blocking: a full queue or a dead writer
    /// increments `dropped` instead of stalling the caller.
    pub fn emit(&self, event: Event) {
        let seq = self.seq.fetch_add(1, Ordering::Relaxed);
        let t_s = self.start.elapsed().as_secs_f64();
        if let Some(tx) = &self.tx {
            if tx.try_send(Stamped { seq, t_s, event }).is_err() {
                self.dropped.incr();
            }
        }
    }

    /// Events discarded because the queue was full.
    pub fn dropped(&self) -> u64 {
        self.dropped.get()
    }

    /// Events accepted for serialization so far.
    pub fn emitted(&self) -> u64 {
        self.seq.load(Ordering::Relaxed) - self.dropped()
    }
}

impl Drop for EventSink {
    fn drop(&mut self) {
        // closing the channel ends the writer loop; joining it
        // guarantees the file is flushed and complete
        self.tx = None;
        let handle = self.worker.lock().unwrap_or_else(PoisonError::into_inner).take();
        if let Some(h) = handle {
            let _ = h.join();
        }
    }
}

fn writer_loop(rx: Receiver<Stamped>, mut out: Box<dyn Write + Send>) {
    loop {
        // drain eagerly; when the queue momentarily empties, flush so a
        // tailing reader sees the log in near real time
        let msg = match rx.try_recv() {
            Ok(m) => m,
            Err(TryRecvError::Empty) => {
                let _ = out.flush();
                match rx.recv() {
                    Ok(m) => m,
                    Err(_) => break,
                }
            }
            Err(TryRecvError::Disconnected) => break,
        };
        let line = msg.event.to_json(msg.seq, msg.t_s).to_string();
        if writeln!(out, "{line}").is_err() {
            // sink is broken (disk full, pipe closed): keep draining so
            // producers don't fill the queue, but stop writing
            for _ in rx.iter() {}
            break;
        }
    }
    let _ = out.flush();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_serialize_with_seq_kind_and_payload() {
        let (sink, cap) = EventSink::capture();
        sink.emit(Event::SessionOpen { key: "mlp@int8".into() });
        sink.emit(Event::Shed { key: "mlp@int8".into(), reason: "depth", depth: 9 });
        sink.emit(Event::SessionClose { key: "mlp@int8".into(), requests: 41 });
        drop(sink);

        let lines = cap.lines();
        assert_eq!(lines.len(), 3);
        let kinds: Vec<&str> =
            lines.iter().map(|l| l.get("kind").and_then(Json::as_str).unwrap()).collect();
        assert_eq!(kinds, vec!["session_open", "shed", "session_close"]);
        // seq strictly increasing from 0
        for (i, l) in lines.iter().enumerate() {
            assert_eq!(l.get("seq").and_then(Json::as_f64), Some(i as f64));
            assert!(l.get("t_s").and_then(Json::as_f64).unwrap() >= 0.0);
        }
        assert_eq!(lines[1].get("reason").and_then(Json::as_str), Some("depth"));
        assert_eq!(lines[1].get("depth").and_then(Json::as_f64), Some(9.0));
        assert_eq!(lines[2].get("requests").and_then(Json::as_f64), Some(41.0));
    }

    #[test]
    fn alert_and_store_events_carry_their_books() {
        let (sink, cap) = EventSink::capture();
        sink.emit(Event::StoreEvict { key: "mlp@int8/int8".into(), bytes: 1024 });
        sink.emit(Event::StoreReject { key: "big@f32".into(), bytes: 1 << 30 });
        sink.emit(Event::SloState { key: "mlp@int8".into(), from: "ok", to: "burning" });
        sink.emit(Event::Alert {
            key: "mlp@int8".into(),
            fast: 2.5,
            slow: 1.5,
            shed: 10,
            served: 90,
        });
        drop(sink);

        let lines = cap.lines();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0].get("bytes").and_then(Json::as_f64), Some(1024.0));
        assert_eq!(lines[2].get("to").and_then(Json::as_str), Some("burning"));
        let alert = &lines[3];
        assert_eq!(alert.get("kind").and_then(Json::as_str), Some("alert"));
        assert_eq!(alert.get("fast").and_then(Json::as_f64), Some(2.5));
        assert_eq!(alert.get("shed").and_then(Json::as_f64), Some(10.0));
        assert_eq!(alert.get("served").and_then(Json::as_f64), Some(90.0));
    }

    #[test]
    fn file_sink_is_complete_after_drop() {
        let dir = std::env::temp_dir().join("precis_obs_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("events.jsonl");
        let sink = EventSink::to_file(&path).unwrap();
        for i in 0..100 {
            sink.emit(Event::Shed { key: format!("s{}", i % 3), reason: "latency", depth: i });
        }
        assert_eq!(sink.emitted(), 100);
        assert_eq!(sink.dropped(), 0);
        drop(sink);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().filter(|l| !l.trim().is_empty()).collect();
        assert_eq!(lines.len(), 100, "every event lands exactly once");
        for l in lines {
            Json::parse(l).expect("valid JSON line");
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn emit_from_many_threads_keeps_unique_seqs() {
        let (sink, cap) = EventSink::capture();
        let sink = Arc::new(sink);
        let mut handles = Vec::new();
        for t in 0..4 {
            let s = Arc::clone(&sink);
            handles.push(std::thread::spawn(move || {
                for i in 0..50 {
                    s.emit(Event::Shed { key: format!("t{t}"), reason: "depth", depth: i });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        drop(sink);
        let lines = cap.lines();
        assert_eq!(lines.len(), 200);
        let mut seqs: Vec<u64> =
            lines.iter().map(|l| l.get("seq").and_then(Json::as_f64).unwrap() as u64).collect();
        seqs.sort_unstable();
        let want: Vec<u64> = (0..200).collect();
        assert_eq!(seqs, want, "every seq assigned exactly once");
    }
}
