//! In-repo micro-benchmark framework (criterion is not in the offline
//! crate set — DESIGN.md §6).  Used by the `[[bench]]` targets with
//! `harness = false`, and by `repro bench` for the machine-readable
//! perf-regression pipeline (DESIGN.md §Perf).
//!
//! Protocol per benchmark: warm up for `warmup_iters`, then run timed
//! batches until `min_time` elapses (at least `min_batches`, at most
//! [`MAX_BATCHES`]), and report median / p10 / p90 per-iteration time
//! plus derived throughput.  The statistics ([`percentile`],
//! [`summarize`]) and the stopping rule ([`Bench::keep_sampling`]) are
//! plain functions over synthetic-testable inputs, so the harness
//! itself is unit-tested without timing anything.
//!
//! [`BenchResult`] and [`BenchReport`] serialize to/from the crate's
//! mini-JSON: `repro bench --json BENCH_<tag>.json` writes a report the
//! checked-in `.github/scripts/bench_compare.py` diffs against a
//! baseline with a noise-tolerant threshold — that pair is the repo's
//! perf-regression harness and the source of the `BENCH_*.json`
//! trajectory.

pub mod suite;

use std::collections::BTreeMap;
use std::hint::black_box;
use std::path::Path;
use std::time::Instant;

use anyhow::{anyhow, bail, Context, Result};

use crate::util::json::Json;

/// Hard cap on timed batches per benchmark — bounds runaway cases where
/// `min_time` never elapses cheaply.
pub const MAX_BATCHES: usize = 10_000;

/// Schema tag `bench_compare.py` validates strictly before comparing.
pub const BENCH_SCHEMA: &str = "precis-bench/1";

#[derive(Clone, Debug, PartialEq)]
pub struct BenchResult {
    pub name: String,
    /// seconds per iteration
    pub median: f64,
    pub p10: f64,
    pub p90: f64,
    pub iters_per_batch: u64,
    pub batches: usize,
}

impl BenchResult {
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.median
    }

    /// The machine-readable form consumed by `bench_compare.py`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(&self.name)),
            ("median_s", Json::num(self.median)),
            ("p10_s", Json::num(self.p10)),
            ("p90_s", Json::num(self.p90)),
            ("iters_per_batch", Json::num(self.iters_per_batch as f64)),
            ("batches", Json::num(self.batches as f64)),
        ])
    }

    /// Parse one result object.  Malformed input (missing keys, wrong
    /// types, non-finite or negative timings) is `Err` — never a panic.
    pub fn from_json(j: &Json) -> Result<BenchResult> {
        let name = j
            .req("name")?
            .as_str()
            .ok_or_else(|| anyhow!("result name is not a string"))?
            .to_string();
        let num = |key: &str| -> Result<f64> {
            let v = j
                .req(key)?
                .as_f64()
                .ok_or_else(|| anyhow!("result {name:?}: {key} is not a number"))?;
            if !v.is_finite() || v < 0.0 {
                bail!("result {name:?}: {key} = {v} is not a finite non-negative number");
            }
            Ok(v)
        };
        Ok(BenchResult {
            median: num("median_s")?,
            p10: num("p10_s")?,
            p90: num("p90_s")?,
            iters_per_batch: num("iters_per_batch")? as u64,
            batches: num("batches")? as usize,
            name,
        })
    }
}

/// Exact order statistic the harness reports: the element at the
/// nearest rank, index `round((len - 1) * q)`, of the sorted samples
/// (no interpolation — a reported time is always one that was
/// measured).  Flooring here biased quantiles low by up to one full
/// rank — p99 of a 10-sample window truncated rank 8.91 down to sample
/// 8 (the p89 statistic), and every even-length median picked the lower
/// middle element — an optimistic skew on exactly the tail values the
/// regression gates care about (ISSUE 8).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of an empty sample set");
    sorted[((sorted.len() - 1) as f64 * q).round() as usize]
}

/// Reduce raw per-iteration batch timings to a [`BenchResult`] —
/// the selection logic of [`Bench::run`], separated so tests can feed
/// synthetic timing sequences.
pub fn summarize(name: &str, mut samples: Vec<f64>, iters_per_batch: u64) -> BenchResult {
    assert!(!samples.is_empty(), "summarize needs at least one batch");
    samples.sort_by(|a, b| a.partial_cmp(b).expect("timings are finite"));
    BenchResult {
        name: name.to_string(),
        median: percentile(&samples, 0.5),
        p10: percentile(&samples, 0.1),
        p90: percentile(&samples, 0.9),
        iters_per_batch,
        batches: samples.len(),
    }
}

pub struct Bench {
    pub warmup_iters: u64,
    pub min_batches: usize,
    pub min_time_s: f64,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup_iters: 3,
            min_batches: 10,
            min_time_s: 0.5,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn quick() -> Bench {
        Bench { warmup_iters: 1, min_batches: 5, min_time_s: 0.1, ..Default::default() }
    }

    /// The stopping rule: sample another batch while the batch floor or
    /// the time floor is unmet, and the [`MAX_BATCHES`] cap is not hit.
    pub fn keep_sampling(&self, batches: usize, elapsed_s: f64) -> bool {
        batches < MAX_BATCHES && (batches < self.min_batches || elapsed_s < self.min_time_s)
    }

    /// Time `f` (one logical iteration per call).
    pub fn run<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> BenchResult {
        // warmup + calibrate iterations per batch to ~10ms
        let t0 = Instant::now();
        for _ in 0..self.warmup_iters.max(1) {
            black_box(f());
        }
        let per_iter = t0.elapsed().as_secs_f64() / self.warmup_iters.max(1) as f64;
        let iters = ((0.01 / per_iter.max(1e-9)) as u64).clamp(1, 1_000_000);

        let mut samples = Vec::new();
        let bench_start = Instant::now();
        while self.keep_sampling(samples.len(), bench_start.elapsed().as_secs_f64()) {
            let t = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            samples.push(t.elapsed().as_secs_f64() / iters as f64);
        }
        let r = summarize(name, samples, iters);
        println!(
            "{:<44} {:>12}/iter   (p10 {:>10}, p90 {:>10}, {} x {} iters)",
            r.name,
            crate::util::timer::human(r.median),
            crate::util::timer::human(r.p10),
            crate::util::timer::human(r.p90),
            r.batches,
            r.iters_per_batch,
        );
        self.results.push(r.clone());
        r
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Consume the harness, yielding everything it measured (what a
    /// [`BenchReport`] is assembled from).
    pub fn into_results(self) -> Vec<BenchResult> {
        self.results
    }
}

/// One `BENCH_*.json` file: a tagged set of results plus the derived
/// speedup ratios the acceptance gates read (blocked-vs-naive GEMM,
/// uniform-vs-mixed-plan forward, ...).  Strictly schema-tagged so a
/// comparison between incompatible files fails loudly.
#[derive(Clone, Debug, PartialEq)]
pub struct BenchReport {
    pub tag: String,
    /// `"quick"` or `"full"` — which suite preset produced it.
    pub preset: String,
    pub results: Vec<BenchResult>,
    /// named speedup ratios (dimensionless, > 1.0 means the first-named
    /// side is faster), e.g. `gemm_blocked_over_naive/<shape>/<fmt>`
    pub ratios: BTreeMap<String, f64>,
}

impl BenchReport {
    pub fn new(tag: &str, preset: &str) -> BenchReport {
        BenchReport {
            tag: tag.to_string(),
            preset: preset.to_string(),
            results: Vec::new(),
            ratios: BTreeMap::new(),
        }
    }

    /// Record a derived speedup ratio.
    pub fn ratio(&mut self, name: &str, value: f64) {
        self.ratios.insert(name.to_string(), value);
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("schema", Json::str(BENCH_SCHEMA)),
            ("tag", Json::str(&self.tag)),
            ("preset", Json::str(&self.preset)),
            ("results", Json::arr(self.results.iter().map(|r| r.to_json()))),
            (
                "ratios",
                Json::Obj(self.ratios.iter().map(|(k, v)| (k.clone(), Json::num(*v))).collect()),
            ),
        ])
    }

    /// Parse a report object; any structural defect is `Err`.
    pub fn from_json(j: &Json) -> Result<BenchReport> {
        let schema = j.req("schema")?.as_str().unwrap_or_default();
        if schema != BENCH_SCHEMA {
            bail!("unsupported bench schema {schema:?} (want {BENCH_SCHEMA:?})");
        }
        let field = |key: &str| -> Result<String> {
            Ok(j.req(key)?
                .as_str()
                .ok_or_else(|| anyhow!("{key} is not a string"))?
                .to_string())
        };
        let results = j
            .req("results")?
            .as_arr()
            .ok_or_else(|| anyhow!("results is not an array"))?
            .iter()
            .map(BenchResult::from_json)
            .collect::<Result<Vec<_>>>()?;
        let mut ratios = BTreeMap::new();
        for (k, v) in j
            .req("ratios")?
            .as_obj()
            .ok_or_else(|| anyhow!("ratios is not an object"))?
        {
            let r = v.as_f64().ok_or_else(|| anyhow!("ratio {k:?} is not a number"))?;
            if !r.is_finite() {
                bail!("ratio {k:?} = {r} is not finite");
            }
            ratios.insert(k.clone(), r);
        }
        Ok(BenchReport { tag: field("tag")?, preset: field("preset")?, results, ratios })
    }

    /// Parse a whole `BENCH_*.json` text.  Malformed JSON and schema
    /// violations are `Err`, never a panic.
    pub fn parse(text: &str) -> Result<BenchReport> {
        let j = Json::parse(text).context("BENCH json does not parse")?;
        BenchReport::from_json(&j)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        std::fs::write(path, format!("{}\n", self.to_json()))
            .with_context(|| format!("writing {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<BenchReport> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        BenchReport::parse(&text)
    }
}

/// Print a section header in bench output.
pub fn section(title: &str) {
    println!("\n== {title} {}", "=".repeat(66usize.saturating_sub(title.len())));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bench { warmup_iters: 1, min_batches: 3, min_time_s: 0.01, results: vec![] };
        let r = b.run("spin", || {
            let mut s = 0u64;
            for i in 0..100 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.median > 0.0);
        assert!(r.p10 <= r.median && r.median <= r.p90 + 1e-12);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn throughput_derivation() {
        let r = BenchResult {
            name: "x".into(),
            median: 0.002,
            p10: 0.001,
            p90: 0.003,
            iters_per_batch: 1,
            batches: 1,
        };
        assert!((r.throughput(10.0) - 5000.0).abs() < 1e-9);
    }

    /// Exact selection (ISSUE 4 satellite, rank rule fixed by ISSUE 8):
    /// on a known synthetic timing sequence, median/p10/p90 are the
    /// exact elements at indices `round((len-1)*q)` of the sorted
    /// sequence — nearest rank, no interpolation.
    #[test]
    fn summarize_selects_exact_order_statistics() {
        // 5 samples, shuffled: sorted = [1, 2, 3, 4, 5] (ms)
        let r = summarize("synthetic", vec![0.005, 0.001, 0.004, 0.002, 0.003], 7);
        assert_eq!(r.median, 0.003); // idx round(4 * 0.5) = 2
        assert_eq!(r.p10, 0.001); // idx round(4 * 0.1) = 0
        assert_eq!(r.p90, 0.005); // idx round(4 * 0.9) = round(3.6) = 4
        assert_eq!(r.iters_per_batch, 7);
        assert_eq!(r.batches, 5);

        // 10 samples 1..=10: median idx round(4.5) = 5 -> 6, p10 idx
        // round(0.9) = 1 -> 2, p90 idx round(8.1) = 8 -> 9 (the old
        // floor rule picked 5 / 1 / 9 — low-biased on two of three)
        let seq: Vec<f64> = (1..=10).rev().map(|i| i as f64).collect();
        let r = summarize("synthetic10", seq, 1);
        assert_eq!(r.median, 6.0);
        assert_eq!(r.p10, 2.0);
        assert_eq!(r.p90, 9.0);

        // a single sample is every statistic
        let r = summarize("one", vec![0.25], 1);
        assert_eq!((r.p10, r.median, r.p90), (0.25, 0.25, 0.25));
    }

    /// ISSUE 8 satellite: the exact cases the floor rule got wrong —
    /// even-length windows (median must be the upper middle element,
    /// nearest rank) and q = 0.99 tails over window sizes where
    /// truncation dropped a full rank.
    #[test]
    fn percentile_even_windows_and_p99_are_nearest_rank() {
        // even-length window: median rank (3 * 0.5) = 1.5 rounds UP to
        // index 2 (floor silently picked the lower middle, index 1)
        let four = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&four, 0.5), 3.0);
        assert_eq!(percentile(&four, 0.99), 4.0); // round(2.97) = 3

        // p99 of a 10-sample window: rank 8.91 -> 9 (the max); the old
        // floor returned index 8 — the p89 order statistic
        let ten: Vec<f64> = (1..=10).map(|i| i as f64).collect();
        assert_eq!(percentile(&ten, 0.99), 10.0);

        // the QoS-window shape: 200 samples, p99 rank 199 * 0.99 =
        // 197.01 -> 197, the 198th smallest — and p50 rank 99.5 rounds
        // to 100 (value 101), not down to 99
        let two_hundred: Vec<f64> = (1..=200).map(|i| i as f64).collect();
        assert_eq!(percentile(&two_hundred, 0.99), 198.0);
        assert_eq!(percentile(&two_hundred, 0.5), 101.0);

        // boundary quantiles stay exact selections at any length
        assert_eq!(percentile(&ten, 0.0), 1.0);
        assert_eq!(percentile(&ten, 1.0), 10.0);
    }

    /// The stopping rule in isolation: batch floor OR time floor keeps
    /// sampling, both met stops, and the hard cap always stops.
    #[test]
    fn keep_sampling_stopping_rule() {
        let b = Bench { min_batches: 5, min_time_s: 0.5, ..Bench::default() };
        assert!(b.keep_sampling(0, 0.0), "must take at least one batch");
        assert!(b.keep_sampling(4, 100.0), "batch floor unmet: keep going despite time");
        assert!(b.keep_sampling(5, 0.49), "time floor unmet: keep going despite batches");
        assert!(!b.keep_sampling(5, 0.5), "both floors met: stop");
        assert!(!b.keep_sampling(17, 2.0), "well past both floors: stop");
        // the hard cap is exact: one more batch is allowed at
        // MAX_BATCHES - 1, none at MAX_BATCHES
        assert!(b.keep_sampling(MAX_BATCHES - 1, 0.0), "one below the cap still samples");
        assert!(!b.keep_sampling(MAX_BATCHES, 0.0), "hard cap dominates the time floor");
    }

    #[test]
    fn bench_result_json_roundtrip_is_exact() {
        let r = BenchResult {
            name: "gemm_q/32x400x120/float:m7e6".into(),
            median: 2.537e-5,
            p10: 2.4e-5,
            p90: 3.1e-5,
            iters_per_batch: 394,
            batches: 21,
        };
        let parsed = Json::parse(&r.to_json().to_string()).unwrap();
        let back = BenchResult::from_json(&parsed).unwrap();
        // f64 Display round-trips exactly, so the timings survive bitwise
        assert_eq!(back, r);
        assert_eq!(back.median.to_bits(), r.median.to_bits());
    }

    #[test]
    fn bench_report_json_roundtrip() {
        let mut rep = BenchReport::new("unit", "quick");
        rep.results.push(BenchResult {
            name: "a".into(),
            median: 0.5,
            p10: 0.25,
            p90: 0.75,
            iters_per_batch: 2,
            batches: 3,
        });
        rep.ratio("gemm_blocked_over_naive/1x2x3/float:m7e6", 2.25);
        let back = BenchReport::parse(&rep.to_json().to_string()).unwrap();
        assert_eq!(back, rep);
    }

    /// Malformed input is `Err`, never a panic (ISSUE 4 satellite).
    #[test]
    fn malformed_bench_json_is_err_not_panic() {
        // not JSON at all
        assert!(BenchReport::parse("]]]").is_err());
        assert!(BenchReport::parse("").is_err());
        // valid JSON, wrong shape
        assert!(BenchReport::parse("[1, 2, 3]").is_err());
        assert!(BenchReport::parse(r#"{"schema": "precis-bench/1"}"#).is_err());
        // wrong schema tag
        assert!(BenchReport::parse(
            r#"{"schema": "other/9", "tag": "t", "preset": "quick", "results": [], "ratios": {}}"#
        )
        .is_err());
        // result entries with missing keys / wrong types / bad values
        let r = |body: &str| {
            BenchReport::parse(&format!(
                r#"{{"schema": "precis-bench/1", "tag": "t", "preset": "quick",
                     "results": [{body}], "ratios": {{}}}}"#
            ))
        };
        assert!(r(r#"{"name": "x"}"#).is_err(), "missing timing keys");
        assert!(r(r#"{"name": 3, "median_s": 1, "p10_s": 1, "p90_s": 1,
                      "iters_per_batch": 1, "batches": 1}"#)
            .is_err());
        assert!(r(r#"{"name": "x", "median_s": -1, "p10_s": 1, "p90_s": 1,
                      "iters_per_batch": 1, "batches": 1}"#)
            .is_err());
        assert!(r(r#"{"name": "x", "median_s": 1, "p10_s": 1, "p90_s": 1,
                      "iters_per_batch": 1, "batches": 1}"#)
            .is_ok());
        // a non-numeric ratio
        assert!(BenchReport::parse(
            r#"{"schema": "precis-bench/1", "tag": "t", "preset": "quick",
                "results": [], "ratios": {"r": "fast"}}"#
        )
        .is_err());
    }

    #[test]
    fn report_save_load_roundtrip() {
        let dir = std::env::temp_dir().join("precis_bench_harness_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_unit.json");
        let mut rep = BenchReport::new("unit", "full");
        rep.ratio("x", 1.5);
        rep.save(&path).unwrap();
        assert_eq!(BenchReport::load(&path).unwrap(), rep);
        std::fs::remove_file(&path).ok();
    }
}
