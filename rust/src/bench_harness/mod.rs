//! In-repo micro-benchmark framework (criterion is not in the offline
//! crate set — DESIGN.md §6).  Used by the `[[bench]]` targets with
//! `harness = false`.
//!
//! Protocol per benchmark: warm up for `warmup_iters`, then run timed
//! batches until `min_time` elapses (at least `min_batches`), and report
//! median / p10 / p90 per-iteration time plus derived throughput.

use std::hint::black_box;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    /// seconds per iteration
    pub median: f64,
    pub p10: f64,
    pub p90: f64,
    pub iters_per_batch: u64,
    pub batches: usize,
}

impl BenchResult {
    pub fn throughput(&self, units_per_iter: f64) -> f64 {
        units_per_iter / self.median
    }
}

pub struct Bench {
    pub warmup_iters: u64,
    pub min_batches: usize,
    pub min_time_s: f64,
    results: Vec<BenchResult>,
}

impl Default for Bench {
    fn default() -> Self {
        Bench {
            warmup_iters: 3,
            min_batches: 10,
            min_time_s: 0.5,
            results: Vec::new(),
        }
    }
}

impl Bench {
    pub fn quick() -> Bench {
        Bench { warmup_iters: 1, min_batches: 5, min_time_s: 0.1, ..Default::default() }
    }

    /// Time `f` (one logical iteration per call).
    pub fn run<R>(&mut self, name: &str, mut f: impl FnMut() -> R) -> BenchResult {
        // warmup + calibrate iterations per batch to ~10ms
        let t0 = Instant::now();
        for _ in 0..self.warmup_iters.max(1) {
            black_box(f());
        }
        let per_iter = t0.elapsed().as_secs_f64() / self.warmup_iters.max(1) as f64;
        let iters = ((0.01 / per_iter.max(1e-9)) as u64).clamp(1, 1_000_000);

        let mut samples = Vec::new();
        let bench_start = Instant::now();
        while samples.len() < self.min_batches
            || bench_start.elapsed().as_secs_f64() < self.min_time_s
        {
            let t = Instant::now();
            for _ in 0..iters {
                black_box(f());
            }
            samples.push(t.elapsed().as_secs_f64() / iters as f64);
            if samples.len() > 10_000 {
                break;
            }
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let pick = |q: f64| samples[((samples.len() - 1) as f64 * q) as usize];
        let r = BenchResult {
            name: name.to_string(),
            median: pick(0.5),
            p10: pick(0.1),
            p90: pick(0.9),
            iters_per_batch: iters,
            batches: samples.len(),
        };
        println!(
            "{:<44} {:>12}/iter   (p10 {:>10}, p90 {:>10}, {} x {} iters)",
            r.name,
            crate::util::timer::human(r.median),
            crate::util::timer::human(r.p10),
            crate::util::timer::human(r.p90),
            r.batches,
            r.iters_per_batch,
        );
        self.results.push(r.clone());
        r
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Print a section header in bench output.
pub fn section(title: &str) {
    println!("\n== {title} {}", "=".repeat(66usize.saturating_sub(title.len())));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bench { warmup_iters: 1, min_batches: 3, min_time_s: 0.01, results: vec![] };
        let r = b.run("spin", || {
            let mut s = 0u64;
            for i in 0..100 {
                s = s.wrapping_add(i * i);
            }
            s
        });
        assert!(r.median > 0.0);
        assert!(r.p10 <= r.median && r.median <= r.p90 + 1e-12);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn throughput_derivation() {
        let r = BenchResult {
            name: "x".into(),
            median: 0.002,
            p10: 0.001,
            p90: 0.003,
            iters_per_batch: 1,
            batches: 1,
        };
        assert!((r.throughput(10.0) - 5000.0).abs() < 1e-9);
    }
}
