//! The headless `hot_paths` benchmark suite — the artifact-free half of
//! `benches/hot_paths.rs`, shared with the `repro bench` subcommand so
//! the interactive bench and the perf-regression pipeline can never
//! measure different code (DESIGN.md §Perf).
//!
//! Every section runs on a fresh clone with no `artifacts/`: the
//! quantizer and GEMM kernels use synthetic operands at seed-net
//! shapes, and the forward sections use the deterministic
//! `testing::fixtures::tiny_conv_network`.  Results and the derived
//! speedup ratios are collected into a [`BenchReport`] for
//! `BENCH_*.json` / `bench_compare.py`.

use std::sync::Arc;

use crate::bench_harness::{section, Bench, BenchReport, BenchResult};
use crate::formats::{Format, PrecisionSpec};
use crate::obs::{Counter, Histogram};
use crate::nn::{gemm_q, gemm_q_naive};
use crate::numerics::{dot_q, quantize_slice, PackedOp, Quantizer};
use crate::serving::{Backend, NativeBackend};
use crate::store::{
    gemm_packed_int, gemm_packed_int_scalar, ExecScratch, PackedTensor, StoreKey, WeightStore,
};
use crate::testing::fixtures::tiny_conv_network;
use crate::util::rng::Pcg32;
use crate::{with_packed_op, with_quant_op};

/// GEMM shapes of the seed networks' conv (im2col) and dense layers at
/// batch 32: (M, K, N) = (b*oh*ow, kh*kw*cin, cout) / (b, in, out).
pub const GEMM_SHAPES: [(usize, usize, usize); 4] = [
    (25088, 25, 20), // lenet5 conv1 at batch 32: 5x5x1 -> 20
    (32, 400, 120),  // lenet5 dense1 at batch 32: 400 -> 120
    (6272, 147, 24), // cifarnet conv1 at batch 32: 7x7x3 -> 24
    (3200, 432, 48), // alexnet-mini conv2 at batch 32: 3x3x48 -> 48
];

/// The three kernel kinds under test: a customized float, a customized
/// fixed, and the `QIdentity` exact baseline.
fn formats_under_test() -> [Format; 3] {
    [Format::float(7, 6), Format::fixed(8, 8), Format::SINGLE]
}

fn randv(n: usize, seed: u64) -> Vec<f32> {
    let mut r = Pcg32::seeded(seed);
    (0..n).map(|_| r.normal()).collect()
}

fn ratio(num: &BenchResult, den: &BenchResult) -> f64 {
    num.median / den.median
}

/// Run the headless hot-path suite and assemble the machine-readable
/// report.  `quick` trades coverage (2 GEMM shapes instead of 4) and
/// per-bench time floors for wall-clock — it is the CI perf-smoke
/// preset; `full` is the `make bench-json` trajectory preset.
pub fn hot_paths_report(tag: &str, quick: bool) -> BenchReport {
    let mut bench = if quick { Bench::quick() } else { Bench::default() };
    let mut report = BenchReport::new(tag, if quick { "quick" } else { "full" });
    let shapes = if quick { &GEMM_SHAPES[..2] } else { &GEMM_SHAPES[..] };
    run_suite(&mut bench, &mut report, 4096, &[256, 1000], shapes, 32);
    report
}

/// The suite body, parameterized over problem sizes so the structural
/// unit test and `tests/obs_contract.rs` can run it at trivial sizes
/// (names and ratio families are identical either way; only the
/// dimension strings differ).
pub fn run_suite(
    bench: &mut Bench,
    report: &mut BenchReport,
    slice_len: usize,
    dot_ks: &[usize],
    gemm_shapes: &[(usize, usize, usize)],
    fwd_batch: usize,
) {
    section("q_slice: monomorphized kernel vs scalar enum-dispatch reference");
    let xs = randv(slice_len, 1);
    let mut buf = xs.clone();
    for fmt in formats_under_test() {
        let q = Quantizer::new(&fmt);
        let mono = bench.run(&format!("q_slice/{slice_len}/{}", fmt.id()), || {
            buf.copy_from_slice(&xs);
            quantize_slice(&mut buf, &q);
            buf[0]
        });
        let scalar = bench.run(&format!("q_slice_scalar/{slice_len}/{}", fmt.id()), || {
            buf.copy_from_slice(&xs);
            for v in buf.iter_mut() {
                *v = q.q(*v);
            }
            buf[0]
        });
        report.ratio(&format!("q_slice_mono_over_scalar/{}", fmt.id()), ratio(&scalar, &mono));
        println!(
            "    -> mono {:.0} Melem/s, scalar {:.0} Melem/s: {:.2}x",
            mono.throughput(slice_len as f64) / 1e6,
            scalar.throughput(slice_len as f64) / 1e6,
            ratio(&scalar, &mono),
        );
    }

    section("dot_q (per-op-rounded MAC chain, scalar reference)");
    for &k in dot_ks {
        let a = randv(k, 2);
        let w = randv(k, 3);
        for fmt in [Format::float(7, 6), Format::fixed(8, 8)] {
            let q = Quantizer::new(&fmt);
            let r = bench.run(&format!("dot_q/K={k}/{}", fmt.id()), || dot_q(&a, &w, &q));
            println!("    -> {:.1} Mmac/s", r.throughput(k as f64) / 1e6);
        }
    }

    section("gemm_q: monomorphized blocked kernel vs scalar naive reference");
    for &(m, k, n) in gemm_shapes {
        let a = randv(m * k, 4);
        let w = randv(k * n, 5);
        let mut out = vec![0.0f32; m * n];
        let macs = (m * k * n) as f64;
        for fmt in formats_under_test() {
            let q = Quantizer::new(&fmt);
            let blocked = bench.run(&format!("gemm_q/{m}x{k}x{n}/{}", fmt.id()), || {
                with_quant_op!(&q, op => gemm_q(&a, &w, &mut out, m, k, n, op));
                out[0]
            });
            let naive = bench.run(&format!("gemm_q_naive/{m}x{k}x{n}/{}", fmt.id()), || {
                gemm_q_naive(&a, &w, &mut out, m, k, n, &q);
                out[0]
            });
            report.ratio(
                &format!("gemm_blocked_over_naive/{m}x{k}x{n}/{}", fmt.id()),
                ratio(&naive, &blocked),
            );
            println!(
                "    -> blocked {:.1} Mmac/s, naive {:.1} Mmac/s: {:.2}x",
                blocked.throughput(macs) / 1e6,
                naive.throughput(macs) / 1e6,
                ratio(&naive, &blocked),
            );
        }
    }

    section("fixture forward: uniform format vs mixed per-layer plan (no artifacts)");
    let net = tiny_conv_network(fwd_batch);
    let x = net.eval_x.slice_rows(0, fwd_batch);
    let uniform = PrecisionSpec::parse("float:m7e6").expect("uniform spec parses");
    let mixed = PrecisionSpec::parse("plan:c1=fixed:l8r8,*=float:m7e6").expect("plan parses");
    let mut backend = NativeBackend::new(net.clone());
    let u = bench.run(&format!("forward/tiny-conv/uniform/batch{fwd_batch}"), || {
        backend.run_spec(&x, &uniform).expect("fixture forward").data()[0]
    });
    let p = bench.run(&format!("forward_plan/tiny-conv/mixed/batch{fwd_batch}"), || {
        backend.run_spec(&x, &mixed).expect("fixture plan forward").data()[0]
    });
    // the memoized quantizer table means a mixed plan must cost what a
    // uniform format costs (≈1.0x) — drift here is a plans regression
    report.ratio("plan_uniform_over_mixed/tiny-conv", ratio(&u, &p));
    println!("    -> uniform/mixed ratio {:.2}x (contract: ~1.0x)", ratio(&u, &p));

    // ISSUE 5 acceptance: the store removes the per-forward weight
    // quantization term.  `cached` stages once and then reads by
    // reference; `restaged` runs with a disabled store (budget 0), i.e.
    // the pre-store per-forward quantize-and-copy path.
    section("weight store: warm cached forward vs per-forward re-staging");
    let narrow = PrecisionSpec::parse("fixed:l8r8").expect("spec parses");
    let mut cached_backend =
        NativeBackend::with_store(net.clone(), Arc::new(WeightStore::unbounded()));
    cached_backend.run_spec(&x, &narrow).expect("warm-up forward");
    let warm_misses = cached_backend.store_stats().expect("native store").misses;
    let cached = bench.run(&format!("forward_cached/tiny-conv/batch{fwd_batch}"), || {
        cached_backend.run_spec(&x, &narrow).expect("cached forward").data()[0]
    });
    assert_eq!(
        cached_backend.store_stats().expect("native store").misses,
        warm_misses,
        "a warm store must do zero weight-quantization work"
    );
    let mut restaged_backend =
        NativeBackend::with_store(net.clone(), Arc::new(WeightStore::with_budget(0)));
    let restaged = bench.run(&format!("forward_restaged/tiny-conv/batch{fwd_batch}"), || {
        restaged_backend.run_spec(&x, &narrow).expect("restaged forward").data()[0]
    });
    report.ratio("forward_restaged_over_cached/tiny-conv", ratio(&restaged, &cached));
    println!(
        "    -> restaged/cached ratio {:.2}x (store removes the staging term)",
        ratio(&restaged, &cached)
    );

    // the packed storage tier: encode/decode throughput + the
    // compression each format achieves over the f32 carrier
    section("packed codec: pack / unpack vs the f32 carrier");
    let ws = randv(slice_len, 6);
    let mut decoded = Vec::new();
    for fmt in formats_under_test() {
        let packed = PackedTensor::pack(&ws, &fmt);
        bench.run(&format!("pack/{slice_len}/{}", fmt.id()), || {
            PackedTensor::pack(&ws, &fmt).packed_bytes()
        });
        let un = bench.run(&format!("unpack/{slice_len}/{}", fmt.id()), || {
            packed.unpack_into(&mut decoded);
            decoded[0]
        });
        let compression = packed.f32_bytes() as f64 / packed.packed_bytes().max(1) as f64;
        report.ratio(&format!("packed_compression/{}", fmt.id()), compression);
        println!(
            "    -> {} bits/value, {:.2}x compression, decode {:.0} Melem/s",
            packed.width(),
            compression,
            un.throughput(slice_len as f64) / 1e6,
        );
    }

    // ISSUE 6: the packed execution tier.  For each router lane, warm a
    // staged backend and a packed one on the same store-backed fixture,
    // assert the logits are bit-identical (the non-negotiable packed
    // contract), and report the measured packed/staged ratio next to
    // the hardware model's prediction so the trajectory records how
    // much of `hw::speedup` a software integer/LUT kernel realizes.
    section("packed exec: forward from bit-packed codes vs staged-f32 tier");
    for fmt in [Format::fixed(3, 3), Format::fixed(4, 4), Format::fixed(8, 8), Format::float(7, 6)]
    {
        let id = fmt.id();
        let spec = PrecisionSpec::parse(&id).expect("packed spec parses");
        let mut staged = NativeBackend::with_store(net.clone(), Arc::new(WeightStore::unbounded()));
        let mut packed = NativeBackend::with_store(net.clone(), Arc::new(WeightStore::unbounded()))
            .with_packed_exec(true);
        let want = staged.run_spec(&x, &spec).expect("staged warm-up forward");
        let got = packed.run_spec(&x, &spec).expect("packed warm-up forward");
        assert_eq!(
            want.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            got.data().iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            "packed forward must be bit-identical to the staged tier ({id})"
        );
        let s = bench.run(&format!("forward_staged/tiny-conv/{id}/batch{fwd_batch}"), || {
            staged.run_spec(&x, &spec).expect("staged forward").data()[0]
        });
        let p = bench.run(&format!("forward_packed/tiny-conv/{id}/batch{fwd_batch}"), || {
            packed.run_spec(&x, &spec).expect("packed forward").data()[0]
        });
        let predicted = crate::hw::speedup(&fmt);
        report.ratio(&format!("packed_forward_over_f32/{id}"), ratio(&s, &p));
        report.ratio(&format!("hw_speedup_predicted/{id}"), predicted);
        println!(
            "    -> packed/staged {:.2}x measured, {:.2}x predicted by the MAC model",
            ratio(&s, &p),
            predicted,
        );
    }

    // ISSUE 9: split weight/activation precision.  A warm split-pair
    // forward runs the SAME staged chain as its activation half run
    // uniformly (the weight half only changes what was staged, which is
    // warm here), so the ratio's contract is ~1.0x — drift is a
    // pair-resolution regression.  Result names follow
    // `forward_split/<w>+<a>` so the trajectory keys on the pair.
    section("split precision: (w, a) pair forward vs the activation half alone");
    for (pair, act) in [
        ("w:fixed:l8r8+a:float:m7e6", "float:m7e6"),
        ("w:float:m4e5+a:fixed:l4r8", "fixed:l4r8"),
    ] {
        let split_spec =
            PrecisionSpec::parse(&format!("plan:*={pair}")).expect("split pair spec parses");
        let act_spec = PrecisionSpec::parse(act).expect("activation half parses");
        let mut split_backend =
            NativeBackend::with_store(net.clone(), Arc::new(WeightStore::unbounded()));
        let mut act_backend =
            NativeBackend::with_store(net.clone(), Arc::new(WeightStore::unbounded()));
        split_backend.run_spec(&x, &split_spec).expect("split warm-up forward");
        act_backend.run_spec(&x, &act_spec).expect("uniform warm-up forward");
        let s = bench.run(&format!("forward_split/tiny-conv/{pair}/batch{fwd_batch}"), || {
            split_backend.run_spec(&x, &split_spec).expect("split forward").data()[0]
        });
        let u = bench.run(&format!("forward_act_uniform/tiny-conv/{act}/batch{fwd_batch}"), || {
            act_backend.run_spec(&x, &act_spec).expect("uniform forward").data()[0]
        });
        report.ratio(&format!("split_over_activation_uniform/{pair}"), ratio(&s, &u));
        println!(
            "    -> split/uniform ratio {:.2}x (contract: ~1.0x; pair {pair})",
            ratio(&s, &u)
        );
    }

    // ISSUE 8 tentpole (a): the lock-free warm path.  One resident
    // entry; the locked side re-runs `prepare` per read (mutex + map
    // lookup — the pre-PR-8 per-layer warm cost), the lock-free side
    // validates a lease with one atomic epoch load.  The correctness
    // half rides along: the lock-acquisition counter must not move
    // across the lock-free timing loop.
    section("warm store reads: lock-free lease validation vs locked prepare");
    let store = WeightStore::unbounded();
    let key = StoreKey::new("bench", "fc", Format::fixed(8, 8));
    let weights = randv(slice_len, 7);
    let lease = store.prepare_lease(&key, &weights).expect("unbounded store admits");
    let locked = bench.run(&format!("warm_locked_prepare/{slice_len}"), || {
        store.prepare(&key, &weights).expect("resident entry").bytes()
    });
    let locks_before = store.lock_acquisitions();
    let lockfree = bench.run(&format!("warm_lockfree_hit/{slice_len}"), || {
        store.hit_if_current(&lease).expect("lease stays current").bytes()
    });
    assert_eq!(
        store.lock_acquisitions(),
        locks_before,
        "a lock-free warm read must not acquire the store mutex"
    );
    report.ratio("warm_lockfree_over_locked", ratio(&locked, &lockfree));
    println!("    -> locked/lock-free ratio {:.2}x", ratio(&locked, &lockfree));

    // ISSUE 8 tentpole (b): the lane-chunked gemm_q against the scalar
    // per-element chain (gemm_q_naive computes the identical serial-k
    // semantics with no blocking and no lanes), one ratio per kernel
    // kind at the widest shape in this run
    section("gemm SIMD: lane-chunked kernel vs scalar per-element chain");
    {
        let &(m, k, n) = gemm_shapes.last().expect("at least one GEMM shape");
        let a = randv(m * k, 8);
        let w = randv(k * n, 9);
        let mut out = vec![0.0f32; m * n];
        let macs = (m * k * n) as f64;
        for fmt in formats_under_test() {
            let q = Quantizer::new(&fmt);
            let simd = bench.run(&format!("gemm_simd/{m}x{k}x{n}/{}", fmt.id()), || {
                with_quant_op!(&q, op => gemm_q(&a, &w, &mut out, m, k, n, op));
                out[0]
            });
            let scalar = bench.run(&format!("gemm_scalar/{m}x{k}x{n}/{}", fmt.id()), || {
                gemm_q_naive(&a, &w, &mut out, m, k, n, &q);
                out[0]
            });
            report.ratio(&format!("gemm_simd_over_scalar/{}", fmt.id()), ratio(&scalar, &simd));
            println!(
                "    -> simd {:.1} Mmac/s, scalar {:.1} Mmac/s: {:.2}x",
                simd.throughput(macs) / 1e6,
                scalar.throughput(macs) / 1e6,
                ratio(&scalar, &simd),
            );
        }
    }

    // ...and the packed integer MAC lanes (PR 6) against their untiled
    // scalar reference — one ratio per accumulator width
    section("packed int MAC: lane-chunked integer kernel vs scalar reference");
    {
        let &(m, k, n) = gemm_shapes.last().expect("at least one GEMM shape");
        let macs = (m * k * n) as f64;
        for (lane, fmt) in [("int16", Format::fixed(3, 3)), ("int32", Format::fixed(6, 6))] {
            let q = Quantizer::new(&fmt);
            let mut a = randv(m * k, 10);
            quantize_slice(&mut a, &q); // the integer lane's on-grid premise
            let packed = PackedTensor::pack(&randv(k * n, 11), &fmt);
            let op = PackedOp::for_format(&fmt).expect("fixed l+r<=12 has an integer op");
            let mut scratch = ExecScratch::default();
            let mut out = vec![0.0f32; m * n];
            let simd = bench.run(&format!("packed_int_simd/{m}x{k}x{n}/{lane}"), || {
                with_packed_op!(&op, o => gemm_packed_int(
                    &a, &packed, None, &mut out, m, k, n, o, &mut scratch,
                ));
                out[0]
            });
            let scalar = bench.run(&format!("packed_int_scalar/{m}x{k}x{n}/{lane}"), || {
                with_packed_op!(&op, o => gemm_packed_int_scalar(
                    &a, &packed, None, &mut out, m, k, n, o, &mut scratch,
                ));
                out[0]
            });
            report.ratio(&format!("packed_int_simd_over_scalar/{lane}"), ratio(&scalar, &simd));
            println!(
                "    -> simd {:.1} Mmac/s, scalar {:.1} Mmac/s: {:.2}x",
                simd.throughput(macs) / 1e6,
                scalar.throughput(macs) / 1e6,
                ratio(&scalar, &simd),
            );
        }
    }

    // ISSUE 10 tentpole: the observability hot paths.  The registry
    // primitives must price like bare relaxed atomics, and a profiled
    // forward must cost within noise of a plain one — the
    // `obs_profile_overhead/tiny-conv` ratio is the zero-overhead
    // contract's regression gate (contract: ~1.0x; the span clock is
    // two `Instant::now` calls per layer against a whole-layer GEMM).
    section("obs overhead: metric primitives + profiled vs plain forward");
    {
        let counter = Counter::new();
        let c = bench.run("obs_overhead/counter_add", || {
            counter.add(1);
            counter.get()
        });
        let hist = Histogram::new();
        let mut tick = 0u64;
        let h = bench.run("obs_overhead/histogram_record", || {
            tick += 1;
            hist.record((tick % 1024) as f64 * 1e-6);
            hist.count()
        });
        println!(
            "    -> counter {:.0} Mops/s, histogram {:.0} Mops/s",
            c.throughput(1.0) / 1e6,
            h.throughput(1.0) / 1e6,
        );
        let spec = PrecisionSpec::parse("fixed:l8r8").expect("spec parses");
        let mut plain = NativeBackend::with_store(net.clone(), Arc::new(WeightStore::unbounded()));
        let mut profiled =
            NativeBackend::with_store(net.clone(), Arc::new(WeightStore::unbounded()))
                .with_profiling(true);
        plain.run_spec(&x, &spec).expect("plain warm-up forward");
        profiled.run_spec(&x, &spec).expect("profiled warm-up forward");
        let fp = bench.run(&format!("obs_overhead/forward_plain/batch{fwd_batch}"), || {
            plain.run_spec(&x, &spec).expect("plain forward").data()[0]
        });
        let fq = bench.run(&format!("obs_overhead/forward_profiled/batch{fwd_batch}"), || {
            profiled.run_spec(&x, &spec).expect("profiled forward").data()[0]
        });
        report.ratio("obs_profile_overhead/tiny-conv", ratio(&fp, &fq));
        println!("    -> profiled/plain ratio {:.2}x (contract: ~1.0x)", ratio(&fp, &fq));
    }

    report.results.extend_from_slice(bench.results());
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The suite is the regression harness's data source: its report
    /// must always carry the result names and the ratio families that
    /// `bench_compare.py` and the acceptance gates read, and must
    /// round-trip the JSON schema.  Run at trivial problem sizes with
    /// the smallest stopping rule, so this stays fast under the debug
    /// tier-1 `cargo test`.
    #[test]
    fn suite_report_has_the_gated_sections_and_roundtrips() {
        let mut bench = Bench { warmup_iters: 1, min_batches: 2, min_time_s: 0.0, ..Bench::quick() };
        let mut report = BenchReport::new("unit-test", "quick");
        run_suite(&mut bench, &mut report, 64, &[16], &[(10, 7, 9), (3, 5, 4)], 4);

        assert!(report.results.len() >= 10, "suspiciously few results");
        assert!(
            report.ratios.keys().any(|k| k.starts_with("gemm_blocked_over_naive/")),
            "missing blocked-vs-naive ratios"
        );
        assert!(
            report.ratios.contains_key("plan_uniform_over_mixed/tiny-conv"),
            "missing mixed-plan ratio"
        );
        assert!(
            report.ratios.keys().any(|k| k.starts_with("q_slice_mono_over_scalar/")),
            "missing q_slice ratios"
        );
        // the ISSUE 5 sections: cached-vs-restaged forward + the packed
        // codec (bench_compare tolerates their absence in older
        // baselines — missing-section is a warning, not a failure)
        assert!(
            report.ratios.contains_key("forward_restaged_over_cached/tiny-conv"),
            "missing store cached-vs-restaged ratio"
        );
        assert!(
            report.ratios.keys().any(|k| k.starts_with("packed_compression/")),
            "missing packed-compression ratios"
        );
        // the ISSUE 6 sections: packed-domain forward vs the staged
        // tier, plus the hardware model's prediction for each format
        // (also tolerated as missing-section notes in older baselines)
        for fam in ["packed_forward_over_f32/", "hw_speedup_predicted/"] {
            let n = report.ratios.keys().filter(|k| k.starts_with(fam)).count();
            assert!(n >= 4, "expected >=4 {fam} ratios, got {n}");
        }
        // the ISSUE 9 section: split-pair forwards vs the activation
        // half (warn-only missing-section in older baselines)
        assert_eq!(
            report.ratios.keys().filter(|k| k.starts_with("split_over_activation_uniform/")).count(),
            2,
            "one split-pair ratio per benchmarked pair"
        );
        // the ISSUE 8 sections: lock-free warm reads + the two SIMD
        // ratio families (also warn-only in older baselines)
        assert!(
            report.ratios.contains_key("warm_lockfree_over_locked"),
            "missing lock-free warm-path ratio"
        );
        assert_eq!(
            report.ratios.keys().filter(|k| k.starts_with("gemm_simd_over_scalar/")).count(),
            3,
            "one gemm SIMD ratio per kernel kind"
        );
        for lane in ["int16", "int32"] {
            assert!(
                report.ratios.contains_key(&format!("packed_int_simd_over_scalar/{lane}")),
                "missing packed int SIMD ratio for {lane}"
            );
        }
        // the ISSUE 10 section: metrics/profiling hot-path pricing (the
        // zero-overhead contract's regression gate; warn-only in older
        // baselines)
        assert!(
            report.ratios.contains_key("obs_profile_overhead/tiny-conv"),
            "missing profiled-vs-plain forward ratio"
        );
        for name in [
            "forward_cached/",
            "forward_restaged/",
            "pack/",
            "unpack/",
            "forward_staged/",
            "forward_packed/",
            "forward_split/",
            "forward_act_uniform/",
            "warm_locked_prepare/",
            "warm_lockfree_hit/",
            "gemm_simd/",
            "gemm_scalar/",
            "packed_int_simd/",
            "packed_int_scalar/",
            "obs_overhead/counter_add",
            "obs_overhead/histogram_record",
            "obs_overhead/forward_plain/",
            "obs_overhead/forward_profiled/",
        ] {
            assert!(
                report.results.iter().any(|r| r.name.starts_with(name)),
                "missing {name} results"
            );
        }
        for (k, v) in &report.ratios {
            assert!(v.is_finite() && *v > 0.0, "ratio {k} = {v}");
        }
        // every result name is unique (bench_compare keys on them)
        let mut names: Vec<&str> = report.results.iter().map(|r| r.name.as_str()).collect();
        names.sort_unstable();
        let before = names.len();
        names.dedup();
        assert_eq!(names.len(), before, "duplicate bench names");

        let back = BenchReport::parse(&report.to_json().to_string()).unwrap();
        assert_eq!(back, report);
    }
}
