//! Per-layer mixed-precision plans — the `PrecisionPlan` subsystem.
//!
//! The paper assigns ONE custom format to the whole network (§2.2);
//! related work (Lai et al., arXiv:1703.03073) shows per-layer format
//! choices recover accuracy at narrower widths.  A [`Plan`] is an
//! ordered list of `layer=format` rules with an optional `*` wildcard
//! default, spelled
//!
//! ```text
//! plan:conv1=float:m4e5,conv2=fixed:l2r12,*=float:m7e6
//! ```
//!
//! Rules apply **first-match-wins** in written order; a rule after the
//! wildcard would be unreachable and is rejected at parse time, as are
//! duplicate patterns.  `Plan::parse` ⇄ `Display` round-trip exactly.
//!
//! # The second axis: weight/activation format pairs
//!
//! The ARM inference paper (float weights, fixed activations) shows the
//! best operating points pair *different* representations per operand,
//! so a rule's right-hand side is a [`FormatPair`] — a weight format
//! and an activation format:
//!
//! ```text
//! plan:conv1=w:float:m4e5+a:fixed:l4r8,*=float:m7e6
//! ```
//!
//! A single-format rule is **sugar for `w == a`**: every pre-existing
//! spec string parses, displays and resolves byte-identically, and a
//! uniform pair executes the identical code path a single format does.
//! A split pair stages weights through the `w:` half and runs the MAC
//! chain (input staging, products, accumulation, bias, pooling) under
//! the `a:` half (DESIGN.md §Mixed precision).  Both half orders parse
//! (`w:…+a:…` and `a:…+w:…`); the canonical [`FormatPair::id`] spelling
//! is `w:` first, collapsing to the bare format id when the halves are
//! equal.
//!
//! [`PrecisionSpec`] is the execution-facing sum of both worlds — a
//! single [`Format`] (the paper's setting, and the bit-exactness
//! anchor: a uniform plan executes the identical per-layer quantizer
//! table a single format does) or a per-layer [`Plan`].  Every
//! execution driver ([`crate::serving::Backend`], `eval::forward_eval`,
//! the sweep/search runners) accepts a `PrecisionSpec`.
//!
//! Resolution ([`PrecisionSpec::resolve`] / [`Plan::resolve`]) validates
//! a plan against a [`Network`]'s named quantized layers (conv / dense;
//! inception modules contribute their four branch convolutions) and
//! produces the [`ResolvedPlan`] assignment the engine's quantizer
//! table is built from.  Validation is total: every quantized layer
//! must be covered, and every non-wildcard rule must bind a real layer
//! (typos fail loudly, never silently fall through).

use std::fmt;

use anyhow::{anyhow, bail, Result};

use crate::formats::Format;
use crate::nn::Network;

/// A per-layer `(weight format, activation format)` assignment — the
/// second precision axis (module docs).  `w == a` is the paper's
/// single-format setting and spells/parses as the bare format id;
/// split pairs spell `w:<fmt>+a:<fmt>`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct FormatPair {
    /// The format weights are staged (and stored/packed) under.
    pub w: Format,
    /// The format the MAC chain and activations run under.
    pub a: Format,
}

impl FormatPair {
    /// The `w == a` pair — the single-format sugar's meaning.
    pub const fn uniform(fmt: Format) -> FormatPair {
        FormatPair { w: fmt, a: fmt }
    }

    /// An explicit weight/activation split.
    pub const fn split(w: Format, a: Format) -> FormatPair {
        FormatPair { w, a }
    }

    /// `true` when the two halves differ (a genuinely mixed pair).
    pub fn is_split(&self) -> bool {
        self.w != self.a
    }

    /// `Some(fmt)` when both halves are the same format — the
    /// single-format view uniform pairs collapse to.
    pub fn uniform_format(&self) -> Option<Format> {
        (self.w == self.a).then_some(self.w)
    }

    /// Stable identifier, also the parse form: the bare format id when
    /// `w == a` (so single-format spellings survive byte-identically),
    /// else `w:<fmt>+a:<fmt>`.
    pub fn id(&self) -> String {
        if self.w == self.a {
            self.w.id()
        } else {
            format!("w:{}+a:{}", self.w.id(), self.a.id())
        }
    }

    /// Parse a bare format id (sugar for `w == a`) or a
    /// `w:<fmt>+a:<fmt>` pair (either half order).  A lone half —
    /// `w:float:m4e5` with no `+`, or a `+` with a missing/duplicate
    /// half — is a dedicated `Err`, never a panic.
    pub fn parse(s: &str) -> Result<FormatPair> {
        if !s.contains('+') && !s.starts_with("w:") && !s.starts_with("a:") {
            return Ok(FormatPair::uniform(Format::parse(s)?));
        }
        if !s.contains('+') {
            bail!(
                "format pair {s:?}: lone {:?} half — a split pair needs both halves \
                 (`w:<format>+a:<format>`)",
                &s[..2]
            );
        }
        let halves: Vec<&str> = s.split('+').collect();
        if halves.len() != 2 {
            bail!(
                "format pair {s:?}: expected exactly one `+` separating a `w:` and an `a:` half"
            );
        }
        let mut w = None;
        let mut a = None;
        for half in halves {
            if half.is_empty() {
                bail!("format pair {s:?}: empty half (write `w:<format>+a:<format>`)");
            }
            if let Some(rest) = half.strip_prefix("w:") {
                if w.is_some() {
                    bail!("format pair {s:?}: duplicate `w:` half");
                }
                if rest.is_empty() {
                    bail!("format pair {s:?}: `w:` half names no format");
                }
                w = Some(Format::parse(rest)?);
            } else if let Some(rest) = half.strip_prefix("a:") {
                if a.is_some() {
                    bail!("format pair {s:?}: duplicate `a:` half");
                }
                if rest.is_empty() {
                    bail!("format pair {s:?}: `a:` half names no format");
                }
                a = Some(Format::parse(rest)?);
            } else {
                bail!("format pair {s:?}: half {half:?} must start with `w:` or `a:`");
            }
        }
        match (w, a) {
            (Some(w), Some(a)) => Ok(FormatPair { w, a }),
            (Some(_), None) => bail!("format pair {s:?}: missing the `a:` half"),
            (None, _) => bail!("format pair {s:?}: missing the `w:` half"),
        }
    }
}

impl fmt::Display for FormatPair {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.id())
    }
}

impl From<Format> for FormatPair {
    fn from(f: Format) -> FormatPair {
        FormatPair::uniform(f)
    }
}

/// One `pattern=pair` rule: `pattern` is an exact layer name or the
/// wildcard `*`.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
struct PlanRule {
    pattern: String,
    fmt: FormatPair,
}

/// An ordered per-layer format assignment (see the module docs for the
/// syntax and matching semantics).
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct Plan {
    rules: Vec<PlanRule>,
}

impl Plan {
    /// The plan that assigns `fmt` to every layer: `plan:*=<fmt>`.
    /// Executing it is bit-identical to executing `fmt` directly (the
    /// uniform-plan anchor; property-tested in `serving::backend`).
    pub fn uniform(fmt: Format) -> Plan {
        Plan {
            rules: vec![PlanRule { pattern: "*".to_string(), fmt: FormatPair::uniform(fmt) }],
        }
    }

    /// A plan with one explicit single-format rule per (layer, format)
    /// pair, in order (`w == a` sugar).  Errs on duplicate layer names.
    pub fn explicit(pairs: Vec<(String, Format)>) -> Result<Plan> {
        Plan::explicit_pairs(
            pairs.into_iter().map(|(n, f)| (n, FormatPair::uniform(f))).collect(),
        )
    }

    /// A plan with one explicit rule per (layer, [`FormatPair`]), in
    /// order — the 2-axis generalization [`crate::search`] builds its
    /// candidates through.  Errs on duplicate layer names.
    pub fn explicit_pairs(pairs: Vec<(String, FormatPair)>) -> Result<Plan> {
        let rules = pairs
            .into_iter()
            .map(|(pattern, fmt)| PlanRule { pattern, fmt })
            .collect();
        Plan::validated(rules)
    }

    fn validated(rules: Vec<PlanRule>) -> Result<Plan> {
        if rules.is_empty() {
            bail!("plan has no rules");
        }
        for (i, r) in rules.iter().enumerate() {
            if r.pattern.is_empty() {
                bail!("plan rule {i}: empty layer pattern");
            }
            if r.pattern != "*" && r.pattern.contains(['*', '=', ',', '@', ':', '+']) {
                bail!("plan rule {i}: invalid layer pattern {:?}", r.pattern);
            }
            if rules[..i].iter().any(|p| p.pattern == r.pattern) {
                bail!("plan rule {i}: duplicate pattern {:?}", r.pattern);
            }
            if i + 1 < rules.len() && r.pattern == "*" {
                bail!("plan rule {i}: rules after the `*` wildcard are unreachable");
            }
        }
        Ok(Plan { rules })
    }

    /// Parse the `plan:layer=format[,layer=format...]` spelling, where
    /// each format is a bare id or a `w:<fmt>+a:<fmt>` pair.  Every
    /// format goes through the range-checked [`Format::parse`], so an
    /// out-of-range format (e.g. `fixed:l100r100`) is an `Err` here
    /// too, never a constructor panic.  An empty body, an empty rule
    /// between commas, and a trailing comma each get a dedicated error
    /// naming the position.
    pub fn parse(s: &str) -> Result<Plan> {
        let body = s
            .strip_prefix("plan:")
            .ok_or_else(|| anyhow!("plan {s:?}: expected `plan:layer=format,...`"))?;
        if body.is_empty() {
            bail!("plan {s:?}: empty plan body (write `plan:layer=format,...`)");
        }
        let parts: Vec<&str> = body.split(',').collect();
        let mut rules = Vec::new();
        for (i, part) in parts.iter().enumerate() {
            if part.is_empty() {
                if i + 1 == parts.len() {
                    bail!("plan {s:?}: trailing comma after rule {}", i.saturating_sub(1));
                }
                bail!("plan {s:?}: empty rule at position {i} (consecutive commas)");
            }
            let (pattern, fmt) = part
                .split_once('=')
                .ok_or_else(|| anyhow!("plan {s:?}: rule {part:?} is not `layer=format`"))?;
            rules.push(PlanRule {
                pattern: pattern.to_string(),
                fmt: FormatPair::parse(fmt)?,
            });
        }
        Plan::validated(rules)
    }

    /// Stable identifier; identical to the [`Display`](fmt::Display)
    /// form and accepted back by [`Plan::parse`].
    pub fn id(&self) -> String {
        self.to_string()
    }

    /// The format pair the first matching rule assigns to `layer`, if
    /// any.
    pub fn format_for(&self, layer: &str) -> Option<FormatPair> {
        self.rules
            .iter()
            .find(|r| r.pattern == layer || r.pattern == "*")
            .map(|r| r.fmt)
    }

    /// `Some(fmt)` when this plan is the single-wildcard uniform shape
    /// (the [`Plan::uniform`] constructor's output) with `w == a`.
    pub fn uniform_format(&self) -> Option<Format> {
        match self.rules.as_slice() {
            [r] if r.pattern == "*" => r.fmt.uniform_format(),
            _ => None,
        }
    }

    /// Validate this plan against `net`'s named quantized layers and
    /// return the per-layer assignment.  Errors when a quantized layer
    /// is left unassigned, or when a non-wildcard rule names no layer
    /// of the network.
    pub fn resolve(&self, net: &Network) -> Result<ResolvedPlan> {
        let names = net.quantized_layer_names();
        if names.is_empty() {
            bail!("{}: network has no quantized layers to plan", net.name);
        }
        let mut assignments = Vec::with_capacity(names.len());
        for name in &names {
            let fmt = self.format_for(name).ok_or_else(|| {
                anyhow!(
                    "plan {self} leaves layer {name:?} of {} unassigned (add `*=<format>` as a default)",
                    net.name
                )
            })?;
            assignments.push((name.clone(), fmt));
        }
        for r in &self.rules {
            if r.pattern != "*" && !names.iter().any(|n| *n == r.pattern) {
                bail!(
                    "plan rule {:?} matches no quantized layer of {} (layers: {})",
                    r.pattern,
                    net.name,
                    names.join(", ")
                );
            }
        }
        Ok(ResolvedPlan { assignments })
    }
}

impl fmt::Display for Plan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "plan:")?;
        for (i, r) in self.rules.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{}={}", r.pattern, r.fmt.id())?;
        }
        Ok(())
    }
}

/// A plan resolved against one network: the format pair of every named
/// quantized layer, in execution order.  This is what the engine's
/// per-layer quantizer table and [`crate::hw::plan_speedup`] consume.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ResolvedPlan {
    /// `(layer name, format pair)` per quantized layer, in network
    /// order.
    pub assignments: Vec<(String, FormatPair)>,
}

impl ResolvedPlan {
    /// The assigned format pair of `layer`, if it is a quantized layer.
    pub fn format_for(&self, layer: &str) -> Option<FormatPair> {
        self.assignments
            .iter()
            .find(|(n, _)| n == layer)
            .map(|(_, f)| *f)
    }

    /// `Some(fmt)` when every layer resolved to the same `w == a`
    /// format — the gate for single-format backends (the AOT/PJRT
    /// executables take one runtime `fmt` vector).  A split pair
    /// anywhere disqualifies the plan.
    pub fn uniform(&self) -> Option<Format> {
        let (_, first) = self.assignments.first()?;
        let fmt = first.uniform_format()?;
        self.assignments
            .iter()
            .all(|(_, f)| *f == *first)
            .then_some(fmt)
    }
}

impl fmt::Display for ResolvedPlan {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, (name, fmt)) in self.assignments.iter().enumerate() {
            if i > 0 {
                write!(f, ",")?;
            }
            write!(f, "{name}={}", fmt.id())?;
        }
        Ok(())
    }
}

/// What a session / driver executes under: one format for every layer
/// (the paper's §2.2 setting) or a per-layer [`Plan`].  The parse
/// spelling is either a bare format id (`float:m7e6`) or the
/// `plan:...` syntax, so existing `net@format` session keys and CLI
/// flags keep their meaning unchanged.  Weight/activation split pairs
/// are expressed through plan rules (`plan:*=w:<fmt>+a:<fmt>` for a
/// network-wide split).
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum PrecisionSpec {
    /// One format for the whole network.
    Uniform(Format),
    /// A per-layer plan (native engine only, unless it resolves
    /// uniform).
    PerLayer(Plan),
}

impl PrecisionSpec {
    /// Parse a bare format id or a `plan:...` string.
    pub fn parse(s: &str) -> Result<PrecisionSpec> {
        if s.starts_with("plan:") {
            Ok(PrecisionSpec::PerLayer(Plan::parse(s)?))
        } else {
            Ok(PrecisionSpec::Uniform(Format::parse(s)?))
        }
    }

    /// Stable identifier in the parse spelling (`float:m7e6` /
    /// `plan:...`); also the [`Display`](fmt::Display) form.
    pub fn id(&self) -> String {
        match self {
            PrecisionSpec::Uniform(f) => f.id(),
            PrecisionSpec::PerLayer(p) => p.id(),
        }
    }

    /// Resolve to a per-layer assignment on `net`.  Uniform specs
    /// resolve to every quantized layer (and never fail); plans
    /// validate per [`Plan::resolve`].
    pub fn resolve(&self, net: &Network) -> Result<ResolvedPlan> {
        match self {
            PrecisionSpec::Uniform(f) => Ok(ResolvedPlan {
                assignments: net
                    .quantized_layer_names()
                    .into_iter()
                    .map(|n| (n, FormatPair::uniform(*f)))
                    .collect(),
            }),
            PrecisionSpec::PerLayer(p) => p.resolve(net),
        }
    }

    /// The single format this spec runs under on `net`, for backends
    /// that take one runtime format vector (PJRT).  Uniform specs pass
    /// through unresolved; a plan qualifies iff its resolved assignment
    /// is uniform (every layer the same `w == a` format).
    pub fn resolved_uniform(&self, net: &Network) -> Result<Format> {
        match self {
            PrecisionSpec::Uniform(f) => Ok(*f),
            PrecisionSpec::PerLayer(p) => p.resolve(net)?.uniform().ok_or_else(|| {
                anyhow!(
                    "{}: per-layer plan is not uniform — single-format backends (PJRT) cannot \
                     execute it; use the native engine",
                    self.id()
                )
            }),
        }
    }

    /// `Some(fmt)` for specs that are syntactically uniform (a bare
    /// format, or the single-wildcard `w == a` plan) without needing a
    /// network.
    pub fn uniform_format(&self) -> Option<Format> {
        match self {
            PrecisionSpec::Uniform(f) => Some(*f),
            PrecisionSpec::PerLayer(p) => p.uniform_format(),
        }
    }
}

impl fmt::Display for PrecisionSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            PrecisionSpec::Uniform(x) => write!(f, "{}", x.id()),
            PrecisionSpec::PerLayer(p) => write!(f, "{p}"),
        }
    }
}

impl From<Format> for PrecisionSpec {
    fn from(f: Format) -> PrecisionSpec {
        PrecisionSpec::Uniform(f)
    }
}

impl From<&Format> for PrecisionSpec {
    fn from(f: &Format) -> PrecisionSpec {
        PrecisionSpec::Uniform(*f)
    }
}

impl From<Plan> for PrecisionSpec {
    fn from(p: Plan) -> PrecisionSpec {
        PrecisionSpec::PerLayer(p)
    }
}

impl From<&Plan> for PrecisionSpec {
    fn from(p: &Plan) -> PrecisionSpec {
        PrecisionSpec::PerLayer(p.clone())
    }
}

impl From<&PrecisionSpec> for PrecisionSpec {
    fn from(s: &PrecisionSpec) -> PrecisionSpec {
        s.clone()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::fixtures::{tiny_conv_network, tiny_network};
    use crate::testing::prop::{run_prop, Gen};

    fn upair(f: Format) -> FormatPair {
        FormatPair::uniform(f)
    }

    #[test]
    fn uniform_plan_shape_and_id() {
        let p = Plan::uniform(Format::float(7, 6));
        assert_eq!(p.id(), "plan:*=float:m7e6");
        assert_eq!(p.uniform_format(), Some(Format::float(7, 6)));
        assert_eq!(p.format_for("anything"), Some(upair(Format::float(7, 6))));
        assert_eq!(Plan::parse(&p.id()).unwrap(), p);
    }

    #[test]
    fn parse_display_roundtrip_explicit() {
        let s = "plan:conv1=float:m4e5,conv2=fixed:l2r12,*=float:m7e6";
        let p = Plan::parse(s).unwrap();
        assert_eq!(p.to_string(), s);
        assert_eq!(Plan::parse(&p.to_string()).unwrap(), p);
        assert_eq!(p.format_for("conv1"), Some(upair(Format::float(4, 5))));
        assert_eq!(p.format_for("conv2"), Some(upair(Format::fixed(2, 12))));
        // first-match-wins: unknown names fall to the wildcard
        assert_eq!(p.format_for("fc9"), Some(upair(Format::float(7, 6))));
        assert_eq!(p.uniform_format(), None);
    }

    /// The tentpole grammar: `w:<fmt>+a:<fmt>` rules parse in either
    /// half order, display canonically (`w:` first), and collapse to
    /// the single-format spelling when the halves are equal.
    #[test]
    fn parse_display_roundtrip_split_pairs() {
        let s = "plan:conv1=w:float:m4e5+a:fixed:l4r8,*=float:m7e6";
        let p = Plan::parse(s).unwrap();
        assert_eq!(p.to_string(), s);
        assert_eq!(Plan::parse(&p.to_string()).unwrap(), p);
        assert_eq!(
            p.format_for("conv1"),
            Some(FormatPair::split(Format::float(4, 5), Format::fixed(4, 8)))
        );
        let pair = p.format_for("conv1").unwrap();
        assert!(pair.is_split());
        assert_eq!(pair.uniform_format(), None);
        // the wildcard sugar is a uniform pair
        assert_eq!(p.format_for("fc"), Some(upair(Format::float(7, 6))));

        // either half order parses; the id is canonical (`w:` first)
        let swapped = Plan::parse("plan:conv1=a:fixed:l4r8+w:float:m4e5,*=float:m7e6").unwrap();
        assert_eq!(swapped, p);
        assert_eq!(swapped.to_string(), s);

        // equal halves collapse to the bare-format spelling
        let collapsed = Plan::parse("plan:*=w:float:m7e6+a:float:m7e6").unwrap();
        assert_eq!(collapsed, Plan::uniform(Format::float(7, 6)));
        assert_eq!(collapsed.to_string(), "plan:*=float:m7e6");
        assert_eq!(collapsed.uniform_format(), Some(Format::float(7, 6)));
        // a genuinely split wildcard is NOT a uniform format
        let split = Plan::parse("plan:*=w:float:m7e6+a:fixed:l4r8").unwrap();
        assert_eq!(split.uniform_format(), None);
    }

    #[test]
    fn format_pair_parse_and_id() {
        // bare ids stay the w==a sugar, byte-identically
        let u = FormatPair::parse("float:m7e6").unwrap();
        assert_eq!(u, upair(Format::float(7, 6)));
        assert_eq!(u.id(), "float:m7e6");
        // split pairs round-trip through the canonical id
        let s = FormatPair::split(Format::fixed(8, 8), Format::float(4, 5));
        assert_eq!(s.id(), "w:fixed:l8r8+a:float:m4e5");
        assert_eq!(FormatPair::parse(&s.id()).unwrap(), s);
        assert_eq!(FormatPair::parse("a:float:m4e5+w:fixed:l8r8").unwrap(), s);
    }

    /// Satellite: malformed pair halves are dedicated errors, never the
    /// generic rule error and never a panic.
    #[test]
    fn pair_parse_rejects_malformed_halves() {
        for bad in [
            "w:float:m4e5",                  // lone half, no '+'
            "a:fixed:l4r8",                  // lone half, no '+'
            "w:float:m4e5+",                 // empty second half
            "+a:fixed:l4r8",                 // empty first half
            "a:+w:float:m4e5",               // 'a:' half names no format
            "w:+a:fixed:l4r8",               // 'w:' half names no format
            "w:float:m4e5+w:float:m7e6",     // duplicate 'w:' halves
            "a:fixed:l4r8+a:fixed:l2r2",     // duplicate 'a:' halves
            "w:float:m4e5+fixed:l4r8",       // unprefixed second half
            "w:float:m4e5+a:fixed:l4r8+a:fixed:l2r2", // three halves
            "w:float:m99e9+a:fixed:l4r8",    // out-of-range w half
            "w:float:m4e5+a:fixed:l100r100", // out-of-range a half
            "+",
            "w:+a:",
        ] {
            assert!(FormatPair::parse(bad).is_err(), "accepted {bad:?}");
            assert!(Plan::parse(&format!("plan:*={bad}")).is_err(), "plan accepted {bad:?}");
        }
    }

    #[test]
    fn parse_rejects_malformed() {
        for bad in [
            "",
            "plan:",
            "plan",
            "conv1=float:m4e5",             // missing plan: prefix
            "plan:conv1",                   // no '='
            "plan:=float:m7e6",             // empty pattern
            "plan:conv1=decimal:x1y2",      // bad format
            "plan:conv1=float:m99e9",       // out-of-range format
            "plan:a=float:m7e6,a=fixed:l8r8", // duplicate pattern
            "plan:*=float:m7e6,a=fixed:l8r8", // unreachable after wildcard
            "plan:a*b=float:m7e6",          // '*' inside a name
            "plan:a=float:m7e6,",           // trailing empty rule
            "plan:a+b=float:m7e6",          // '+' inside a name
        ] {
            assert!(Plan::parse(bad).is_err(), "accepted {bad:?}");
        }
    }

    /// Satellite: the empty-body / trailing-comma / empty-rule shapes
    /// get dedicated errors naming the position, not the generic
    /// ``rule "" is not `layer=format` `` fall-through.
    #[test]
    fn parse_empty_and_trailing_rules_get_dedicated_errors() {
        let err = |s: &str| Plan::parse(s).unwrap_err().to_string();
        assert!(err("plan:").contains("empty plan body"), "{}", err("plan:"));
        let trailing = err("plan:a=float:m7e6,");
        assert!(trailing.contains("trailing comma after rule 0"), "{trailing}");
        let trailing2 = err("plan:a=float:m7e6,b=fixed:l8r8,");
        assert!(trailing2.contains("trailing comma after rule 1"), "{trailing2}");
        let between = err("plan:a=float:m7e6,,b=fixed:l8r8");
        assert!(between.contains("empty rule at position 1"), "{between}");
        // none of them fall through to the generic rule error
        for s in ["plan:", "plan:a=float:m7e6,", "plan:a=float:m7e6,,b=fixed:l8r8"] {
            assert!(!err(s).contains("is not `layer=format`"), "{}", err(s));
        }
    }

    /// Regression mirroring the PR 2 `fixed:l100r100` case for plan
    /// syntax: an out-of-range format inside a plan (or a plan session
    /// spec) must be `Err`, never an assert panic in `Format::fixed`.
    #[test]
    fn plan_rejects_out_of_range_fixed_format() {
        assert!(Plan::parse("plan:*=fixed:l100r100").is_err());
        assert!(Plan::parse("plan:c1=fixed:l100r100,*=float:m7e6").is_err());
        assert!(PrecisionSpec::parse("plan:*=fixed:l65r0").is_err());
        assert!(PrecisionSpec::parse("plan:*=w:fixed:l65r0+a:float:m7e6").is_err());
        // the full accepted constructor range still parses
        assert_eq!(
            Plan::parse("plan:*=fixed:l64r64").unwrap().uniform_format(),
            Some(Format::fixed(64, 64))
        );
    }

    #[test]
    fn spec_parse_dispatches_on_prefix() {
        assert_eq!(
            PrecisionSpec::parse("float:m7e6").unwrap(),
            PrecisionSpec::Uniform(Format::float(7, 6))
        );
        let s = PrecisionSpec::parse("plan:*=fixed:l8r8").unwrap();
        assert_eq!(s, PrecisionSpec::PerLayer(Plan::uniform(Format::fixed(8, 8))));
        assert_eq!(s.uniform_format(), Some(Format::fixed(8, 8)));
        // a uniform plan stays a plan through parse (faithful round-trip)
        assert_eq!(PrecisionSpec::parse(&s.id()).unwrap(), s);
        assert!(PrecisionSpec::parse("warp:x1y2").is_err());
        // a bare spec is never a pair — splits live inside plan rules
        assert!(PrecisionSpec::parse("w:float:m7e6+a:fixed:l8r8").is_err());
    }

    /// Tentpole acceptance: every pre-existing single-format spec
    /// string parses, displays, and resolves byte-identically to the
    /// pre-pair grammar (the `w == a` sugar is invisible end to end).
    #[test]
    fn single_format_specs_are_byte_identical_sugar() {
        let net = tiny_conv_network(4); // quantized layers: c1, fc
        for s in [
            "float:m7e6",
            "fixed:l8r8",
            "float:m23e8",
            "plan:*=float:m7e6",
            "plan:c1=float:m4e5,*=fixed:l8r8",
            "plan:c1=float:m4e5,fc=fixed:l2r12",
        ] {
            let spec = PrecisionSpec::parse(s).unwrap();
            assert_eq!(spec.id(), s, "display drifted for {s:?}");
            assert_eq!(spec.to_string(), s);
            let resolved = spec.resolve(&net).unwrap();
            for (name, pair) in &resolved.assignments {
                assert_eq!(
                    pair.uniform_format().map(|f| f.id()),
                    Some(pair.id()),
                    "layer {name} of {s:?} resolved to a split pair"
                );
            }
        }
        // the pinned pre-pair resolved Display shape survives
        let r = PrecisionSpec::parse("plan:c1=float:m4e5,*=fixed:l8r8")
            .unwrap()
            .resolve(&net)
            .unwrap();
        assert_eq!(r.to_string(), "c1=float:m4e5,fc=fixed:l8r8");
    }

    #[test]
    fn resolve_covers_and_validates_layers() {
        let net = tiny_conv_network(4); // quantized layers: c1, fc
        assert_eq!(net.quantized_layer_names(), vec!["c1", "fc"]);

        let p = Plan::parse("plan:c1=float:m4e5,*=fixed:l8r8").unwrap();
        let r = p.resolve(&net).unwrap();
        assert_eq!(
            r.assignments,
            vec![
                ("c1".to_string(), upair(Format::float(4, 5))),
                ("fc".to_string(), upair(Format::fixed(8, 8))),
            ]
        );
        assert_eq!(r.uniform(), None);
        assert_eq!(r.format_for("fc"), Some(upair(Format::fixed(8, 8))));
        assert_eq!(r.to_string(), "c1=float:m4e5,fc=fixed:l8r8");

        // uncovered layer: error (no wildcard)
        assert!(Plan::parse("plan:c1=float:m4e5").unwrap().resolve(&net).is_err());
        // rule naming no real layer: error (typo protection)
        assert!(Plan::parse("plan:conv9=float:m4e5,*=fixed:l8r8")
            .unwrap()
            .resolve(&net)
            .is_err());

        // explicit all-layers plan with equal formats resolves uniform
        let q = Plan::parse("plan:c1=float:m7e6,fc=float:m7e6").unwrap();
        assert_eq!(q.resolve(&net).unwrap().uniform(), Some(Format::float(7, 6)));
        // ...and the PJRT gate accepts exactly that shape
        let spec = PrecisionSpec::PerLayer(q);
        assert_eq!(spec.resolved_uniform(&net).unwrap(), Format::float(7, 6));
        let mixed = PrecisionSpec::parse("plan:c1=float:m4e5,*=fixed:l8r8").unwrap();
        assert!(mixed.resolved_uniform(&net).is_err());
        // a split pair is not PJRT-expressible even when both layers
        // carry the identical pair
        let split = PrecisionSpec::parse("plan:*=w:float:m7e6+a:fixed:l8r8").unwrap();
        let rs = split.resolve(&net).unwrap();
        assert_eq!(rs.uniform(), None);
        assert!(split.resolved_uniform(&net).is_err());
        assert_eq!(
            rs.to_string(),
            "c1=w:float:m7e6+a:fixed:l8r8,fc=w:float:m7e6+a:fixed:l8r8"
        );
    }

    #[test]
    fn uniform_spec_resolves_on_any_network() {
        let net = tiny_network(4); // dense-only fixture
        let spec = PrecisionSpec::Uniform(Format::fixed(4, 4));
        let r = spec.resolve(&net).unwrap();
        assert_eq!(r.assignments, vec![("fc".to_string(), upair(Format::fixed(4, 4)))]);
        assert_eq!(r.uniform(), Some(Format::fixed(4, 4)));
    }

    fn arb_format(g: &mut Gen) -> Format {
        if g.bool() {
            Format::float(g.usize_in(0, 23) as u32, g.usize_in(1, 8) as u32)
        } else {
            Format::fixed(g.usize_in(0, 64) as u32, g.usize_in(0, 64) as u32)
        }
    }

    fn arb_pair(g: &mut Gen) -> FormatPair {
        if g.bool() {
            FormatPair::uniform(arb_format(g))
        } else {
            FormatPair::split(arb_format(g), arb_format(g))
        }
    }

    /// Plan (and PrecisionSpec) Display ⇄ parse round-trips for random
    /// valid rule lists over the whole constructor-valid format range,
    /// including split weight/activation pairs.
    #[test]
    fn prop_plan_roundtrip() {
        const NAMES: [&str; 6] = ["conv1", "conv2", "inc1.1x1", "inc1.proj", "fc1", "fc2"];
        run_prop("plan_roundtrip", 200, |g| {
            let n = g.usize_in(1, NAMES.len());
            let mut pool: Vec<&str> = NAMES.to_vec();
            let mut rules = Vec::new();
            for _ in 0..n {
                let i = g.usize_in(0, pool.len() - 1);
                rules.push((pool.swap_remove(i).to_string(), arb_pair(g)));
            }
            let mut plan = Plan::explicit_pairs(rules).unwrap();
            if g.bool() {
                // append a wildcard default
                let mut with_star = plan
                    .rules
                    .iter()
                    .map(|r| (r.pattern.clone(), r.fmt))
                    .collect::<Vec<_>>();
                with_star.push(("*".to_string(), arb_pair(g)));
                plan = Plan::explicit_pairs(with_star).unwrap();
            }
            assert_eq!(Plan::parse(&plan.id()).unwrap(), plan);
            let spec = PrecisionSpec::PerLayer(plan.clone());
            assert_eq!(PrecisionSpec::parse(&spec.id()).unwrap(), spec);
        });
    }

    /// Format Display is the human form, `id()` the parse form; the
    /// parse form round-trips for every constructor-valid format and
    /// format pair.
    #[test]
    fn prop_format_id_roundtrip() {
        run_prop("format_id_roundtrip", 300, |g| {
            let f = arb_format(g);
            assert_eq!(Format::parse(&f.id()).unwrap(), f);
            let spec = PrecisionSpec::Uniform(f);
            assert_eq!(PrecisionSpec::parse(&spec.id()).unwrap(), spec);
            let pair = arb_pair(g);
            assert_eq!(FormatPair::parse(&pair.id()).unwrap(), pair);
        });
    }

    /// Malformed plan strings must return `Err` — never panic — for
    /// arbitrary mutations of valid plans and for random garbage,
    /// including the `w:…+a:…` pair grammar.
    #[test]
    fn prop_malformed_plans_err_not_panic() {
        const CHARS: [char; 16] =
            ['p', 'l', 'a', 'n', ':', '=', ',', '*', 'm', 'e', 'r', '1', '@', '.', 'w', '+'];
        run_prop("malformed_plan_err", 300, |g| {
            let len = g.usize_in(0, 40);
            let s: String = (0..len).map(|_| *g.choose(&CHARS)).collect();
            // must return (Ok or Err), not panic
            let _ = Plan::parse(&s);
            let _ = PrecisionSpec::parse(&s);
            let _ = FormatPair::parse(&s);
            // mutated valid plan: truncate at a random byte boundary
            let valid = "plan:conv1=w:float:m4e5+a:fixed:l4r8,conv2=fixed:l2r12,*=float:m7e6";
            let cut = g.usize_in(0, valid.len());
            let _ = Plan::parse(&valid[..cut]);
        });
    }
}
