//! The customized-precision design space (paper §2.2).
//!
//! A [`Format`] is either a custom float `F(m, e)` (sign + m-bit mantissa
//! with hidden leading 1 + e-bit exponent, bias `2^(e-1)-1`) or a custom
//! fixed point `X(l, r)` (sign + l integer bits + r fractional bits,
//! sign-magnitude, symmetric saturation).  Semantics are normative in
//! `python/compile/kernels/qformat.py` and mirrored bit-exactly by
//! [`crate::numerics`].
//!
//! [`design_space`] enumerates the grid the paper sweeps (~240 designs,
//! matching the paper's "hundreds of designs ... 340" scale), and
//! [`Format::runtime_params`] produces the 4-float descriptor consumed by
//! the AOT HLO artifacts.
//!
//! The [`plan`] submodule generalizes the single-format setting to
//! per-layer mixed precision: a [`Plan`] assigns a [`FormatPair`]
//! (weight format + activation format; single-format rules are sugar
//! for `w == a`) per named layer, and [`PrecisionSpec`] (uniform
//! format | plan) is what every execution driver accepts (DESIGN.md
//! §Mixed precision).

pub mod plan;

pub use plan::{FormatPair, Plan, PrecisionSpec, ResolvedPlan};

use std::fmt;

use anyhow::{anyhow, bail, Context, Result};

/// Largest finite f32 — the carrier clamp for e=8 float formats
/// (see qformat.py: the simulated format cannot exceed its carrier).
pub const F32_MAX: f64 = 3.402_823_466_385_288_6e38;

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Format {
    /// Custom float: mantissa bits (0..=23), exponent bits (1..=8).
    Float { mantissa: u32, exponent: u32 },
    /// Custom fixed: integer bits and fractional bits (excluding sign).
    Fixed { int_bits: u32, frac_bits: u32 },
}

impl Format {
    /// Trusted-input constructor: panics out of range.  Untrusted input
    /// (CLI flags, session specs, plan rules) must come through
    /// [`Format::try_float`] / [`Format::parse`] instead, which return
    /// `Err` — every parse path in the crate does, so the asserts here
    /// are unreachable from parsed input.
    pub fn float(mantissa: u32, exponent: u32) -> Format {
        Format::try_float(mantissa, exponent)
            .expect("Format::float out of range (use try_float for untrusted input)")
    }

    /// See [`Format::float`] for the trusted/untrusted split.
    pub fn fixed(int_bits: u32, frac_bits: u32) -> Format {
        Format::try_fixed(int_bits, frac_bits)
            .expect("Format::fixed out of range (use try_fixed for untrusted input)")
    }

    /// Range-checked [`Format::float`]: `Err` instead of a panic, the
    /// single place the float range is enforced.
    pub fn try_float(mantissa: u32, exponent: u32) -> Result<Format> {
        if mantissa > 23 || !(1..=8).contains(&exponent) {
            bail!("float format out of range: m{mantissa}e{exponent} (m<=23, 1<=e<=8)");
        }
        Ok(Format::Float { mantissa, exponent })
    }

    /// Range-checked [`Format::fixed`]: `Err` instead of a panic, the
    /// single place the fixed range is enforced.
    pub fn try_fixed(int_bits: u32, frac_bits: u32) -> Result<Format> {
        if int_bits > 64 || frac_bits > 64 {
            bail!("fixed format out of range: l{int_bits}r{frac_bits} (l<=64, r<=64)");
        }
        Ok(Format::Fixed { int_bits, frac_bits })
    }

    /// IEEE-754 single precision (the paper's baseline, 1x speedup).
    pub const SINGLE: Format = Format::Float { mantissa: 23, exponent: 8 };

    pub fn is_float(&self) -> bool {
        matches!(self, Format::Float { .. })
    }

    /// Total storage bits incl. sign.
    pub fn total_bits(&self) -> u32 {
        match *self {
            Format::Float { mantissa, exponent } => 1 + mantissa + exponent,
            Format::Fixed { int_bits, frac_bits } => 1 + int_bits + frac_bits,
        }
    }

    /// Exponent bias `2^(e-1) - 1`.
    pub fn bias(&self) -> i32 {
        match *self {
            Format::Float { exponent, .. } => (1i32 << (exponent - 1)) - 1,
            _ => 0,
        }
    }

    /// Smallest positive normal value (floats; f32-carrier clamped).
    pub fn min_normal(&self) -> f64 {
        match *self {
            Format::Float { .. } => {
                let emin = -self.bias();
                2.0f64.powi(emin.max(-126))
            }
            Format::Fixed { frac_bits, .. } => 2.0f64.powi(-(frac_bits as i32)),
        }
    }

    /// Largest representable magnitude (f32-carrier clamped for floats).
    pub fn max_value(&self) -> f64 {
        match *self {
            Format::Float { mantissa, exponent } => {
                let emax = (1i32 << exponent) - 1 - self.bias();
                let v = (2.0 - 2.0f64.powi(-(mantissa as i32))) * 2.0f64.powi(emax);
                v.min(F32_MAX)
            }
            Format::Fixed { int_bits, frac_bits } => {
                2.0f64.powi(int_bits as i32) - 2.0f64.powi(-(frac_bits as i32))
            }
        }
    }

    /// The runtime `fmt[4]` descriptor fed to the HLO artifacts and the
    /// native engine (layout documented in qformat.py).
    pub fn runtime_params(&self) -> [f32; 4] {
        match *self {
            Format::Float { mantissa, .. } => [
                (23 - mantissa) as f32,
                self.min_normal() as f32,
                self.max_value() as f32,
                0.0,
            ],
            Format::Fixed { frac_bits, .. } => {
                let scale = 2.0f64.powi(frac_bits as i32);
                [scale as f32, (1.0 / scale) as f32, self.max_value() as f32, 0.0]
            }
        }
    }

    /// Stable identifier, also the parse format: `float:m7e6` / `fixed:l8r8`.
    pub fn id(&self) -> String {
        match *self {
            Format::Float { mantissa, exponent } => format!("float:m{mantissa}e{exponent}"),
            Format::Fixed { int_bits, frac_bits } => format!("fixed:l{int_bits}r{frac_bits}"),
        }
    }

    pub fn parse(s: &str) -> Result<Format> {
        let (kind, rest) = s
            .split_once(':')
            .ok_or_else(|| anyhow!("format {s:?}: expected kind:params"))?;
        let grab = |txt: &str, a: char, b: Option<char>| -> Result<(u32, u32)> {
            let txt = txt
                .strip_prefix(a)
                .ok_or_else(|| anyhow!("format {s:?}: expected {a}..."))?;
            let bpos = match b {
                Some(bc) => txt
                    .find(bc)
                    .ok_or_else(|| anyhow!("format {s:?}: expected ...{bc}..."))?,
                None => txt.len(),
            };
            let first: u32 = txt[..bpos].parse().map_err(|_| anyhow!("bad number in {s:?}"))?;
            let second: u32 = txt[bpos + 1..].parse().map_err(|_| anyhow!("bad number in {s:?}"))?;
            Ok((first, second))
        };
        match kind {
            "float" => {
                let (m, e) = grab(rest, 'm', Some('e'))?;
                Format::try_float(m, e).with_context(|| format!("format {s:?}"))
            }
            "fixed" => {
                let (l, r) = grab(rest, 'l', Some('r'))?;
                // the range-checked constructor makes out-of-range
                // untrusted input (CLI flags, session specs, plan
                // rules) an Err instead of a `Format::fixed` assert
                Format::try_fixed(l, r).with_context(|| format!("format {s:?}"))
            }
            _ => bail!("format {s:?}: unknown kind {kind:?}"),
        }
    }
}

impl fmt::Display for Format {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            Format::Float { mantissa, exponent } => write!(f, "FL m{mantissa} e{exponent}"),
            Format::Fixed { int_bits, frac_bits } => write!(f, "FI l{int_bits} r{frac_bits}"),
        }
    }
}

/// The sweep grid: every float `m in 1..=20 x e in 2..=8` plus every
/// fixed `l, r in {0, 2, 4, .., 18}` — 240 designs, comparable to the
/// paper's 340.  `stride` thins the grid uniformly (for quick runs).
pub fn design_space(stride: usize) -> Vec<Format> {
    let mut out = Vec::new();
    for e in 2..=8u32 {
        for m in 1..=20u32 {
            out.push(Format::float(m, e));
        }
    }
    for l in (0..=18u32).step_by(2) {
        for r in (0..=18u32).step_by(2) {
            out.push(Format::fixed(l, r));
        }
    }
    if stride > 1 {
        out = out.into_iter().step_by(stride).collect();
    }
    out
}

/// Only the float half of the space (Fig 10 top row).
pub fn float_space() -> Vec<Format> {
    design_space(1).into_iter().filter(|f| f.is_float()).collect()
}

/// Only the fixed half of the space (Fig 10 bottom row).
pub fn fixed_space() -> Vec<Format> {
    design_space(1).into_iter().filter(|f| !f.is_float()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_precision_properties() {
        let f = Format::SINGLE;
        assert_eq!(f.total_bits(), 32);
        assert_eq!(f.bias(), 127);
        assert_eq!(f.min_normal(), 2.0f64.powi(-126));
        assert!((f.max_value() - F32_MAX).abs() < 1e30);
    }

    #[test]
    fn fixed_16bit_center() {
        // paper §4.3: 16-bit, radix point centered => saturates near 256
        let f = Format::fixed(8, 8);
        assert_eq!(f.total_bits(), 17);
        assert!((f.max_value() - (256.0 - 1.0 / 256.0)).abs() < 1e-12);
    }

    #[test]
    fn runtime_params_float() {
        let p = Format::float(7, 6).runtime_params();
        assert_eq!(p[0], 16.0);
        assert_eq!(p[1] as f64, Format::float(7, 6).min_normal());
        assert_eq!(p[2] as f64, Format::float(7, 6).max_value() as f32 as f64);
    }

    #[test]
    fn runtime_params_fixed() {
        let p = Format::fixed(4, 4).runtime_params();
        assert_eq!(p[0], 16.0);
        assert_eq!(p[1], 1.0 / 16.0);
        assert_eq!(p[2], 16.0 - 1.0 / 16.0);
    }

    #[test]
    fn id_parse_roundtrip() {
        for f in design_space(1) {
            assert_eq!(Format::parse(&f.id()).unwrap(), f);
        }
    }

    #[test]
    fn parse_rejects_bad() {
        assert!(Format::parse("float:m24e8").is_err());
        assert!(Format::parse("float:m5e0").is_err());
        assert!(Format::parse("decimal:x1y2").is_err());
        assert!(Format::parse("float").is_err());
        assert!(Format::parse("fixed:l2q3").is_err());
    }

    /// Regression: out-of-range fixed formats must return `Err`, not
    /// panic in the `Format::fixed` constructor assert.
    #[test]
    fn parse_rejects_out_of_range_fixed() {
        assert!(Format::parse("fixed:l100r100").is_err());
        assert!(Format::parse("fixed:l65r0").is_err());
        assert!(Format::parse("fixed:l0r65").is_err());
        // the constructor's full accepted range still parses
        assert_eq!(Format::parse("fixed:l64r64").unwrap(), Format::fixed(64, 64));
    }

    #[test]
    fn design_space_size_and_split() {
        let all = design_space(1);
        assert_eq!(all.len(), 20 * 7 + 10 * 10);
        assert_eq!(float_space().len(), 140);
        assert_eq!(fixed_space().len(), 100);
        let thin = design_space(4);
        assert_eq!(thin.len(), all.len().div_ceil(4));
    }

    #[test]
    fn e8_carrier_clamp() {
        let f = Format::float(7, 8);
        assert!(f.max_value() <= F32_MAX);
        assert!(f.min_normal() >= 2.0f64.powi(-126));
    }
}
