//! Minimal dense f32/i32 tensors + the `.prt` container reader.
//!
//! Deliberately tiny: row-major contiguous storage, shape bookkeeping,
//! and the handful of view ops the inference engine needs.  Not a
//! general ndarray — the engine's hot loops index raw slices directly.

pub mod io;

use anyhow::{bail, Result};

/// Row-major dense f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != data.len() {
            bail!("shape {:?} wants {} elements, got {}", shape, n, data.len());
        }
        Ok(Tensor { shape, data })
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn from_fn(shape: Vec<usize>, mut f: impl FnMut(usize) -> f32) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor { shape, data: (0..n).map(&mut f).collect() }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    /// Reshape without copying (must preserve element count).
    pub fn reshape(mut self, shape: Vec<usize>) -> Result<Tensor> {
        let n: usize = shape.iter().product();
        if n != self.data.len() {
            bail!("reshape {:?} -> {:?}: element count mismatch", self.shape, shape);
        }
        self.shape = shape;
        Ok(self)
    }

    /// Row `i` of a rank-2 tensor.
    pub fn row(&self, i: usize) -> &[f32] {
        assert_eq!(self.shape.len(), 2);
        let w = self.shape[1];
        &self.data[i * w..(i + 1) * w]
    }

    /// Slice of the leading axis: rows `[lo, hi)` of the flattened
    /// [d0, rest...] view (used for batching the eval set).
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Tensor {
        assert!(!self.shape.is_empty());
        let rest: usize = self.shape[1..].iter().product();
        let mut shape = self.shape.clone();
        shape[0] = hi - lo;
        Tensor {
            shape,
            data: self.data[lo * rest..hi * rest].to_vec(),
        }
    }
}

/// Row-major dense i32 tensor (labels).
#[derive(Clone, Debug, PartialEq)]
pub struct TensorI32 {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn new_checks_element_count() {
        assert!(Tensor::new(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn reshape_and_rows() {
        let t = Tensor::from_fn(vec![2, 3], |i| i as f32);
        assert_eq!(t.row(1), &[3.0, 4.0, 5.0]);
        let r = t.reshape(vec![3, 2]).unwrap();
        assert_eq!(r.row(2), &[4.0, 5.0]);
        assert!(r.clone().reshape(vec![7]).is_err());
    }

    #[test]
    fn slice_rows_takes_leading_axis() {
        let t = Tensor::from_fn(vec![4, 2, 2], |i| i as f32);
        let s = t.slice_rows(1, 3);
        assert_eq!(s.shape(), &[2, 2, 2]);
        assert_eq!(s.data()[0], 4.0);
        assert_eq!(s.len(), 8);
    }
}
