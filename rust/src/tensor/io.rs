//! `.prt` tensor-container reader (format defined in
//! `python/compile/io_prt.py`; written once at `make artifacts`).
//!
//! All fields are little-endian; decoding is hand-rolled over
//! `from_le_bytes` because `byteorder` is not in the offline crate set
//! (DESIGN.md §6).

use std::collections::BTreeMap;
use std::fmt;
use std::fs::File;
use std::io::{BufReader, Read, Seek};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{Tensor, TensorI32};

pub const MAGIC: u32 = 0x5052_5431; // "PRT1"

/// Typed header-validation failure: names the offending tensor and the
/// reason, so zoo loading can report WHICH entry of a corrupt container
/// broke (and tests can downcast instead of string-matching).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MalformedTensor {
    pub tensor: String,
    pub reason: String,
}

impl fmt::Display for MalformedTensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "malformed tensor {:?}: {}", self.tensor, self.reason)
    }
}

impl std::error::Error for MalformedTensor {}

fn malformed(tensor: &str, reason: String) -> anyhow::Error {
    anyhow::Error::new(MalformedTensor { tensor: tensor.to_string(), reason })
}

/// Everything a `.prt` file can hold.
#[derive(Clone, Debug)]
pub enum AnyTensor {
    F32(Tensor),
    I32(TensorI32),
}

/// Ordered contents of a container (order matters: the HLO weight
/// parameter order is the file order).
pub struct Container {
    pub entries: Vec<(String, AnyTensor)>,
    index: BTreeMap<String, usize>,
}

impl Container {
    pub fn get(&self, name: &str) -> Option<&AnyTensor> {
        self.index.get(name).map(|&i| &self.entries[i].1)
    }

    pub fn f32(&self, name: &str) -> Result<&Tensor> {
        match self.get(name) {
            Some(AnyTensor::F32(t)) => Ok(t),
            Some(AnyTensor::I32(_)) => bail!("tensor {name:?} is i32, expected f32"),
            None => bail!("tensor {name:?} not in container"),
        }
    }

    pub fn i32(&self, name: &str) -> Result<&TensorI32> {
        match self.get(name) {
            Some(AnyTensor::I32(t)) => Ok(t),
            Some(AnyTensor::F32(_)) => bail!("tensor {name:?} is f32, expected i32"),
            None => bail!("tensor {name:?} not in container"),
        }
    }

    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|(n, _)| n.as_str()).collect()
    }
}

// ---- little-endian primitives ---------------------------------------

fn read_bytes<const N: usize>(r: &mut impl Read) -> Result<[u8; N]> {
    let mut buf = [0u8; N];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

fn read_u8(r: &mut impl Read) -> Result<u8> {
    Ok(read_bytes::<1>(r)?[0])
}

fn read_u16(r: &mut impl Read) -> Result<u16> {
    Ok(u16::from_le_bytes(read_bytes(r)?))
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    Ok(u32::from_le_bytes(read_bytes(r)?))
}

/// Bulk-read `n` little-endian 4-byte values through `decode`.
fn read_vec4<T>(r: &mut impl Read, n: usize, decode: fn([u8; 4]) -> T) -> Result<Vec<T>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| decode([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Read a `.prt` container.
///
/// Every size field in the header is UNTRUSTED: the element count is
/// computed with `checked_mul` over the dims and bounded against the
/// bytes actually remaining in the file BEFORE any payload buffer is
/// allocated, so a corrupt or truncated container surfaces as a
/// [`MalformedTensor`] error naming the entry — never as an abort on a
/// multi-gigabyte preallocation or a debug overflow panic.
pub fn read_container(path: &Path) -> Result<Container> {
    let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let file_len = f
        .metadata()
        .with_context(|| format!("stat {}", path.display()))?
        .len();
    let mut r = BufReader::new(f);

    let magic = read_u32(&mut r)?;
    if magic != MAGIC {
        bail!("{}: bad magic {magic:#x} (want {MAGIC:#x})", path.display());
    }
    let count = read_u32(&mut r)? as usize;
    // each entry costs ≥ 4 header bytes, so a count the file cannot
    // possibly hold is rejected before `with_capacity` trusts it
    if count as u64 > file_len / 4 {
        bail!(
            "{}: header claims {count} tensors but the file is only {file_len} bytes",
            path.display()
        );
    }
    let mut entries = Vec::with_capacity(count);
    let mut index = BTreeMap::new();

    for _ in 0..count {
        let name_len = read_u16(&mut r)? as usize;
        let mut name_bytes = vec![0u8; name_len];
        r.read_exact(&mut name_bytes)?;
        let name = String::from_utf8(name_bytes).context("tensor name not utf-8")?;

        let dtype = read_u8(&mut r)?;
        let ndim = read_u8(&mut r)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(&mut r)? as usize);
        }
        let n = shape
            .iter()
            .try_fold(1usize, |acc, &d| acc.checked_mul(d))
            .ok_or_else(|| {
                malformed(&name, format!("shape {shape:?} overflows the element count"))
            })
            .with_context(|| format!("in {}", path.display()))?
            .max(1);
        let payload = n.checked_mul(4).ok_or_else(|| {
            malformed(&name, format!("{n} elements overflow the byte count"))
        })?;
        let remaining = file_len.saturating_sub(r.stream_position()?);
        if payload as u64 > remaining {
            return Err(malformed(
                &name,
                format!(
                    "header claims {n} elements ({payload} bytes) but only \
                     {remaining} bytes remain"
                ),
            ))
            .with_context(|| format!("in {}", path.display()));
        }

        let t = match dtype {
            0 => AnyTensor::F32(Tensor::new(shape, read_vec4(&mut r, n, f32::from_le_bytes)?)?),
            1 => AnyTensor::I32(TensorI32 {
                shape,
                data: read_vec4(&mut r, n, i32::from_le_bytes)?,
            }),
            d => bail!("{}: unknown dtype {d} for {name:?}", path.display()),
        };
        index.insert(name.clone(), entries.len());
        entries.push((name, t));
    }
    Ok(Container { entries, index })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    /// Hand-roll a container matching io_prt.py's layout.
    fn write_test_container(path: &Path) {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend(MAGIC.to_le_bytes());
        buf.extend(2u32.to_le_bytes());
        // f32 tensor "a" of shape (2, 2)
        buf.extend(1u16.to_le_bytes());
        buf.push(b'a');
        buf.push(0); // dtype f32
        buf.push(2); // ndim
        buf.extend(2u32.to_le_bytes());
        buf.extend(2u32.to_le_bytes());
        for v in [1.0f32, 2.0, 3.0, 4.5] {
            buf.extend(v.to_le_bytes());
        }
        // i32 tensor "y" of shape (3,)
        buf.extend(1u16.to_le_bytes());
        buf.push(b'y');
        buf.push(1); // dtype i32
        buf.push(1); // ndim
        buf.extend(3u32.to_le_bytes());
        for v in [7i32, -1, 0] {
            buf.extend(v.to_le_bytes());
        }
        File::create(path).unwrap().write_all(&buf).unwrap();
    }

    #[test]
    fn reads_both_dtypes_in_order() {
        let p = std::env::temp_dir().join("precis_test_container.prt");
        write_test_container(&p);
        let c = read_container(&p).unwrap();
        assert_eq!(c.names(), vec!["a", "y"]);
        let a = c.f32("a").unwrap();
        assert_eq!(a.shape(), &[2, 2]);
        assert_eq!(a.data(), &[1.0, 2.0, 3.0, 4.5]);
        let y = c.i32("y").unwrap();
        assert_eq!(y.data, vec![7, -1, 0]);
        assert!(c.f32("y").is_err());
        assert!(c.i32("a").is_err());
        assert!(c.f32("zz").is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let p = std::env::temp_dir().join("precis_test_badmagic.prt");
        File::create(&p).unwrap().write_all(&[0u8; 16]).unwrap();
        assert!(read_container(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    /// Malformed-header matrix (ISSUE 9 satellite): every corrupt size
    /// field errs BEFORE the payload allocation, with the typed
    /// [`MalformedTensor`] naming the offending entry.
    #[test]
    fn malformed_headers_err_before_allocating() {
        let entry_header = |name: u8, ndim: u8, dims: &[u32]| {
            let mut buf: Vec<u8> = Vec::new();
            buf.extend(MAGIC.to_le_bytes());
            buf.extend(1u32.to_le_bytes());
            buf.extend(1u16.to_le_bytes());
            buf.push(name);
            buf.push(0); // dtype f32
            buf.push(ndim);
            for &d in dims {
                buf.extend(d.to_le_bytes());
            }
            buf
        };
        let write = |tag: &str, buf: &[u8]| {
            let p = std::env::temp_dir().join(format!("precis_test_{tag}.prt"));
            File::create(&p).unwrap().write_all(buf).unwrap();
            p
        };

        // oversized count: claims ~1e9 elements (4 GB) in a tiny file —
        // must be rejected by the length bound, not attempted
        let p = write("oversized", &entry_header(b'a', 1, &[1_000_000_000]));
        let err = read_container(&p).unwrap_err();
        let m = err.downcast_ref::<MalformedTensor>().expect("typed error");
        assert_eq!(m.tensor, "a");
        assert!(m.reason.contains("1000000000 elements"), "{m}");
        std::fs::remove_file(&p).ok();

        // dim overflow: the shape product exceeds usize — checked_mul
        // catches it instead of wrapping to a small bogus count
        let p = write("dimoverflow", &entry_header(b'b', 3, &[u32::MAX, u32::MAX, u32::MAX]));
        let err = read_container(&p).unwrap_err();
        let m = err.downcast_ref::<MalformedTensor>().expect("typed error");
        assert_eq!(m.tensor, "b");
        assert!(m.reason.contains("overflows"), "{m}");
        std::fs::remove_file(&p).ok();

        // shape/count mismatch: shape says 2x3 but the payload holds 4
        // values — the next entry's header then reads into the payload
        // bytes and the container must err, not misparse
        let mut buf = entry_header(b'c', 2, &[2, 3]);
        buf[4..8].copy_from_slice(&2u32.to_le_bytes()); // claim 2 entries
        for v in [1.0f32, 2.0, 3.0, 4.0] {
            buf.extend(v.to_le_bytes());
        }
        let p = write("mismatch", &buf);
        assert!(read_container(&p).is_err());
        std::fs::remove_file(&p).ok();

        // entry-count bomb: a count no file this size could hold is
        // rejected before `Vec::with_capacity` trusts it
        let mut buf: Vec<u8> = Vec::new();
        buf.extend(MAGIC.to_le_bytes());
        buf.extend(u32::MAX.to_le_bytes());
        let p = write("countbomb", &buf);
        let err = read_container(&p).unwrap_err();
        assert!(err.to_string().contains("claims 4294967295 tensors"), "{err}");
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn truncated_payload_is_an_error_not_a_panic() {
        let p = std::env::temp_dir().join("precis_test_truncated.prt");
        let mut buf: Vec<u8> = Vec::new();
        buf.extend(MAGIC.to_le_bytes());
        buf.extend(1u32.to_le_bytes());
        buf.extend(1u16.to_le_bytes());
        buf.push(b'a');
        buf.push(0); // dtype f32
        buf.push(1); // ndim
        buf.extend(8u32.to_le_bytes()); // claims 8 elements...
        buf.extend(1.0f32.to_le_bytes()); // ...delivers one
        File::create(&p).unwrap().write_all(&buf).unwrap();
        assert!(read_container(&p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
