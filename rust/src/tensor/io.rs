//! `.prt` tensor-container reader (format defined in
//! `python/compile/io_prt.py`; written once at `make artifacts`).
//!
//! All fields are little-endian; decoding is hand-rolled over
//! `from_le_bytes` because `byteorder` is not in the offline crate set
//! (DESIGN.md §6).

use std::collections::BTreeMap;
use std::fs::File;
use std::io::{BufReader, Read};
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::{Tensor, TensorI32};

pub const MAGIC: u32 = 0x5052_5431; // "PRT1"

/// Everything a `.prt` file can hold.
#[derive(Clone, Debug)]
pub enum AnyTensor {
    F32(Tensor),
    I32(TensorI32),
}

/// Ordered contents of a container (order matters: the HLO weight
/// parameter order is the file order).
pub struct Container {
    pub entries: Vec<(String, AnyTensor)>,
    index: BTreeMap<String, usize>,
}

impl Container {
    pub fn get(&self, name: &str) -> Option<&AnyTensor> {
        self.index.get(name).map(|&i| &self.entries[i].1)
    }

    pub fn f32(&self, name: &str) -> Result<&Tensor> {
        match self.get(name) {
            Some(AnyTensor::F32(t)) => Ok(t),
            Some(AnyTensor::I32(_)) => bail!("tensor {name:?} is i32, expected f32"),
            None => bail!("tensor {name:?} not in container"),
        }
    }

    pub fn i32(&self, name: &str) -> Result<&TensorI32> {
        match self.get(name) {
            Some(AnyTensor::I32(t)) => Ok(t),
            Some(AnyTensor::F32(_)) => bail!("tensor {name:?} is f32, expected i32"),
            None => bail!("tensor {name:?} not in container"),
        }
    }

    pub fn names(&self) -> Vec<&str> {
        self.entries.iter().map(|(n, _)| n.as_str()).collect()
    }
}

// ---- little-endian primitives ---------------------------------------

fn read_bytes<const N: usize>(r: &mut impl Read) -> Result<[u8; N]> {
    let mut buf = [0u8; N];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

fn read_u8(r: &mut impl Read) -> Result<u8> {
    Ok(read_bytes::<1>(r)?[0])
}

fn read_u16(r: &mut impl Read) -> Result<u16> {
    Ok(u16::from_le_bytes(read_bytes(r)?))
}

fn read_u32(r: &mut impl Read) -> Result<u32> {
    Ok(u32::from_le_bytes(read_bytes(r)?))
}

/// Bulk-read `n` little-endian 4-byte values through `decode`.
fn read_vec4<T>(r: &mut impl Read, n: usize, decode: fn([u8; 4]) -> T) -> Result<Vec<T>> {
    let mut bytes = vec![0u8; n * 4];
    r.read_exact(&mut bytes)?;
    Ok(bytes
        .chunks_exact(4)
        .map(|c| decode([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// Read a `.prt` container.
pub fn read_container(path: &Path) -> Result<Container> {
    let f = File::open(path).with_context(|| format!("open {}", path.display()))?;
    let mut r = BufReader::new(f);

    let magic = read_u32(&mut r)?;
    if magic != MAGIC {
        bail!("{}: bad magic {magic:#x} (want {MAGIC:#x})", path.display());
    }
    let count = read_u32(&mut r)? as usize;
    let mut entries = Vec::with_capacity(count);
    let mut index = BTreeMap::new();

    for _ in 0..count {
        let name_len = read_u16(&mut r)? as usize;
        let mut name_bytes = vec![0u8; name_len];
        r.read_exact(&mut name_bytes)?;
        let name = String::from_utf8(name_bytes).context("tensor name not utf-8")?;

        let dtype = read_u8(&mut r)?;
        let ndim = read_u8(&mut r)? as usize;
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(read_u32(&mut r)? as usize);
        }
        let n: usize = shape.iter().product::<usize>().max(1);
        let n = if ndim == 0 { 1 } else { n };

        let t = match dtype {
            0 => AnyTensor::F32(Tensor::new(shape, read_vec4(&mut r, n, f32::from_le_bytes)?)?),
            1 => AnyTensor::I32(TensorI32 {
                shape,
                data: read_vec4(&mut r, n, i32::from_le_bytes)?,
            }),
            d => bail!("{}: unknown dtype {d} for {name:?}", path.display()),
        };
        index.insert(name.clone(), entries.len());
        entries.push((name, t));
    }
    Ok(Container { entries, index })
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    /// Hand-roll a container matching io_prt.py's layout.
    fn write_test_container(path: &Path) {
        let mut buf: Vec<u8> = Vec::new();
        buf.extend(MAGIC.to_le_bytes());
        buf.extend(2u32.to_le_bytes());
        // f32 tensor "a" of shape (2, 2)
        buf.extend(1u16.to_le_bytes());
        buf.push(b'a');
        buf.push(0); // dtype f32
        buf.push(2); // ndim
        buf.extend(2u32.to_le_bytes());
        buf.extend(2u32.to_le_bytes());
        for v in [1.0f32, 2.0, 3.0, 4.5] {
            buf.extend(v.to_le_bytes());
        }
        // i32 tensor "y" of shape (3,)
        buf.extend(1u16.to_le_bytes());
        buf.push(b'y');
        buf.push(1); // dtype i32
        buf.push(1); // ndim
        buf.extend(3u32.to_le_bytes());
        for v in [7i32, -1, 0] {
            buf.extend(v.to_le_bytes());
        }
        File::create(path).unwrap().write_all(&buf).unwrap();
    }

    #[test]
    fn reads_both_dtypes_in_order() {
        let p = std::env::temp_dir().join("precis_test_container.prt");
        write_test_container(&p);
        let c = read_container(&p).unwrap();
        assert_eq!(c.names(), vec!["a", "y"]);
        let a = c.f32("a").unwrap();
        assert_eq!(a.shape(), &[2, 2]);
        assert_eq!(a.data(), &[1.0, 2.0, 3.0, 4.5]);
        let y = c.i32("y").unwrap();
        assert_eq!(y.data, vec![7, -1, 0]);
        assert!(c.f32("y").is_err());
        assert!(c.i32("a").is_err());
        assert!(c.f32("zz").is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn rejects_bad_magic() {
        let p = std::env::temp_dir().join("precis_test_badmagic.prt");
        File::create(&p).unwrap().write_all(&[0u8; 16]).unwrap();
        assert!(read_container(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    #[test]
    fn truncated_payload_is_an_error_not_a_panic() {
        let p = std::env::temp_dir().join("precis_test_truncated.prt");
        let mut buf: Vec<u8> = Vec::new();
        buf.extend(MAGIC.to_le_bytes());
        buf.extend(1u32.to_le_bytes());
        buf.extend(1u16.to_le_bytes());
        buf.push(b'a');
        buf.push(0); // dtype f32
        buf.push(1); // ndim
        buf.extend(8u32.to_le_bytes()); // claims 8 elements...
        buf.extend(1.0f32.to_le_bytes()); // ...delivers one
        File::create(&p).unwrap().write_all(&buf).unwrap();
        assert!(read_container(&p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
