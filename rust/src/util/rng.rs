//! Deterministic PRNGs: SplitMix64 (seeding) and PCG32 (streams).
//!
//! Used for workload generation, sampling eval subsets, and the
//! property-testing framework.  Not cryptographic; chosen for quality,
//! tiny state and exact reproducibility across runs.

/// SplitMix64 — Steele et al., used to expand a single u64 seed.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG32 (XSH-RR variant) — O'Neill 2014.
#[derive(Clone, Debug)]
pub struct Pcg32 {
    state: u64,
    inc: u64,
}

impl Pcg32 {
    /// A distinct `stream` yields an independent sequence for the same seed.
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut rng = Self {
            state: 0,
            inc: (stream << 1) | 1,
        };
        rng.state = sm.next_u64();
        rng.next_u32();
        rng
    }

    pub fn seeded(seed: u64) -> Self {
        Self::new(seed, 0xda3e_39cb_94b9_5bdb)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` without modulo bias (Lemire rejection).
    pub fn below(&mut self, bound: u32) -> u32 {
        assert!(bound > 0);
        loop {
            let x = self.next_u32() as u64;
            let m = x * bound as u64;
            let lo = m as u32;
            if lo >= bound || lo >= (u32::MAX - bound + 1) % bound {
                return (m >> 32) as u32;
            }
        }
    }

    /// Uniform f32 in `[0, 1)` with 24 bits of randomness.
    #[inline]
    pub fn uniform(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Uniform f64 in `[0, 1)` with 53 bits of randomness — enough
    /// resolution for exponential inter-arrival sampling
    /// (`serving::ArrivalSchedule`), where the f32 variant's 2^-24 grid
    /// would visibly quantize short gaps at high request rates.
    #[inline]
    pub fn uniform_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[lo, hi)`.
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.uniform() * (hi - lo)
    }

    /// Standard normal via Box–Muller (one value per call; simple > fast).
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.uniform();
            if u1 > 0.0 {
                let u2 = self.uniform();
                let r = (-2.0 * (u1 as f64).ln()).sqrt();
                let th = 2.0 * std::f64::consts::PI * u2 as f64;
                return (r * th.cos()) as f32;
            }
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u32 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `0..n` (k <= n).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        self.shuffle(&mut idx);
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_reference_values() {
        // reference sequence for seed 0 (matches the published algorithm)
        let mut sm = SplitMix64::new(0);
        let v: Vec<u64> = (0..3).map(|_| sm.next_u64()).collect();
        assert_eq!(v[0], 0xE220_A839_7B1D_CDAF);
        assert_eq!(v[1], 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(v[2], 0x06C4_5D18_8009_454F);
    }

    #[test]
    fn pcg_determinism_and_streams() {
        let a: Vec<u32> = {
            let mut r = Pcg32::new(42, 1);
            (0..8).map(|_| r.next_u32()).collect()
        };
        let b: Vec<u32> = {
            let mut r = Pcg32::new(42, 1);
            (0..8).map(|_| r.next_u32()).collect()
        };
        let c: Vec<u32> = {
            let mut r = Pcg32::new(42, 2);
            (0..8).map(|_| r.next_u32()).collect()
        };
        assert_eq!(a, b);
        assert_ne!(a, c);
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg32::seeded(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Pcg32::seeded(3);
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let v = r.uniform();
            assert!((0.0..1.0).contains(&v));
            sum += v as f64;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn uniform_f64_bounds_mean_and_determinism() {
        let mut r = Pcg32::seeded(17);
        let n = 20_000;
        let mut sum = 0.0f64;
        for _ in 0..n {
            let v = r.uniform_f64();
            assert!((0.0..1.0).contains(&v));
            sum += v;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
        // bit-exact under the same seed (the ArrivalSchedule contract
        // inherits this)
        let a: Vec<u64> = {
            let mut r = Pcg32::seeded(23);
            (0..8).map(|_| r.uniform_f64().to_bits()).collect()
        };
        let b: Vec<u64> = {
            let mut r = Pcg32::seeded(23);
            (0..8).map(|_| r.uniform_f64().to_bits()).collect()
        };
        assert_eq!(a, b);
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg32::seeded(11);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Pcg32::seeded(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Pcg32::seeded(9);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut u = s.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), 20);
    }
}
