//! Wall-clock timing helpers for coordinator metrics and benches.

use std::time::Instant;

/// Simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }
}

/// Format a duration in adaptive units.
pub fn human(seconds: f64) -> String {
    if seconds < 1e-6 {
        format!("{:.1}ns", seconds * 1e9)
    } else if seconds < 1e-3 {
        format!("{:.1}µs", seconds * 1e6)
    } else if seconds < 1.0 {
        format!("{:.1}ms", seconds * 1e3)
    } else if seconds < 120.0 {
        format!("{seconds:.2}s")
    } else {
        format!("{:.1}min", seconds / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_advances() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_ms() >= 4.0);
    }

    #[test]
    fn human_units() {
        assert!(human(3e-9).ends_with("ns"));
        assert!(human(3e-5).ends_with("µs"));
        assert!(human(3e-2).ends_with("ms"));
        assert!(human(3.0).ends_with('s'));
        assert!(human(300.0).ends_with("min"));
    }
}
