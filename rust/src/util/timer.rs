//! Wall-clock timing helpers for coordinator metrics and benches.

use std::time::Instant;

/// Simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed_s(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed_s() * 1e3
    }
}

/// Format a duration in adaptive units.  Negative and non-finite
/// inputs render as a typed `"n/a"` — they can reach here when diffing
/// timestamps against the open-loop driver's absolute deadlines, and
/// `"-3000000.0µs"` or `"NaNns"` in a report is worse than admitting
/// the value carries no duration.
pub fn human(seconds: f64) -> String {
    if !seconds.is_finite() || seconds < 0.0 {
        "n/a".to_string()
    } else if seconds < 1e-6 {
        format!("{:.1}ns", seconds * 1e9)
    } else if seconds < 1e-3 {
        format!("{:.1}µs", seconds * 1e6)
    } else if seconds < 1.0 {
        format!("{:.1}ms", seconds * 1e3)
    } else if seconds < 120.0 {
        format!("{seconds:.2}s")
    } else {
        format!("{:.1}min", seconds / 60.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_advances() {
        let t = Timer::start();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert!(t.elapsed_ms() >= 4.0);
    }

    #[test]
    fn human_units() {
        assert!(human(3e-9).ends_with("ns"));
        assert!(human(3e-5).ends_with("µs"));
        assert!(human(3e-2).ends_with("ms"));
        assert!(human(3.0).ends_with('s'));
        assert!(human(300.0).ends_with("min"));
    }

    /// ISSUE 10 satellite: the case matrix for inputs that are not
    /// durations — negative diffs and non-finite values render as a
    /// typed "n/a", never unit-suffixed nonsense; zero and denormal
    /// positives still take the normal unit ladder.
    #[test]
    fn human_non_durations_are_na() {
        for (input, want) in [
            (-3.0, "n/a"),
            (-1e-9, "n/a"),
            (f64::NEG_INFINITY, "n/a"),
            (f64::INFINITY, "n/a"),
            (f64::NAN, "n/a"),
        ] {
            assert_eq!(human(input), want, "human({input})");
        }
        assert_eq!(human(0.0), "0.0ns");
        assert_eq!(human(-0.0), "0.0ns", "negative zero is a zero duration");
        assert!(human(f64::MIN_POSITIVE).ends_with("ns"));
    }
}
