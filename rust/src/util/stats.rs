//! Small statistics helpers shared by search/ and figures/: mean,
//! Pearson correlation, coefficient of determination (R²) and ordinary
//! least squares for the paper's linear accuracy model (§3.3, Fig 9).

/// Arithmetic mean; 0.0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        0.0
    } else {
        xs.iter().sum::<f64>() / xs.len() as f64
    }
}

/// Pearson product-moment correlation coefficient.
pub fn pearson(xs: &[f64], ys: &[f64]) -> f64 {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n < 2 {
        return 0.0;
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    let mut syy = 0.0;
    for i in 0..n {
        let dx = xs[i] - mx;
        let dy = ys[i] - my;
        sxy += dx * dy;
        sxx += dx * dx;
        syy += dy * dy;
    }
    if sxx == 0.0 || syy == 0.0 {
        return 0.0;
    }
    sxy / (sxx * syy).sqrt()
}

/// Linear coefficient of determination between paired observations —
/// the paper's similarity metric over last-layer activations (§3.3):
/// the square of the Pearson correlation of (exact, quantized) pairs.
pub fn r_squared(exact: &[f64], quant: &[f64]) -> f64 {
    let r = pearson(exact, quant);
    r * r
}

/// Ordinary least squares y ≈ a·x + b.  Returns (a, b).
pub fn ols(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len();
    if n == 0 {
        return (0.0, 0.0);
    }
    let mx = mean(xs);
    let my = mean(ys);
    let mut sxy = 0.0;
    let mut sxx = 0.0;
    for i in 0..n {
        sxy += (xs[i] - mx) * (ys[i] - my);
        sxx += (xs[i] - mx) * (xs[i] - mx);
    }
    if sxx == 0.0 {
        return (0.0, my);
    }
    let a = sxy / sxx;
    (a, my - a * mx)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_basic() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(mean(&[2.0, 4.0]), 3.0);
    }

    #[test]
    fn pearson_perfect_and_anti() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y: Vec<f64> = x.iter().map(|v| 2.0 * v + 1.0).collect();
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let z: Vec<f64> = x.iter().map(|v| -v).collect();
        assert!((pearson(&x, &z) + 1.0).abs() < 1e-12);
    }

    #[test]
    fn pearson_degenerate() {
        assert_eq!(pearson(&[1.0, 1.0, 1.0], &[1.0, 2.0, 3.0]), 0.0);
        assert_eq!(pearson(&[1.0], &[2.0]), 0.0);
    }

    #[test]
    fn r2_of_noisy_line_is_high() {
        let x: Vec<f64> = (0..100).map(|i| i as f64).collect();
        let y: Vec<f64> = x.iter().enumerate()
            .map(|(i, v)| 3.0 * v + if i % 2 == 0 { 0.5 } else { -0.5 })
            .collect();
        assert!(r_squared(&x, &y) > 0.999);
    }

    #[test]
    fn ols_recovers_line() {
        let x: Vec<f64> = (0..50).map(|i| i as f64 / 10.0).collect();
        let y: Vec<f64> = x.iter().map(|v| -1.5 * v + 4.0).collect();
        let (a, b) = ols(&x, &y);
        assert!((a + 1.5).abs() < 1e-12);
        assert!((b - 4.0).abs() < 1e-12);
    }

    #[test]
    fn ols_degenerate_x() {
        let (a, b) = ols(&[2.0, 2.0], &[1.0, 3.0]);
        assert_eq!(a, 0.0);
        assert_eq!(b, 2.0);
    }
}
