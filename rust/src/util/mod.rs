//! Offline-build substrates: PRNG, mini-JSON, CLI parsing, timing.
//!
//! The vendored crate set excludes `rand`, `serde`, `clap` and friends
//! (DESIGN.md §6), so these are small, fully tested from-scratch
//! implementations sized exactly to this repository's needs.

pub mod cli;
pub mod json;
pub mod rng;
pub mod stats;
pub mod table;
pub mod timer;
