//! Minimal JSON reader/writer (serde is not in the offline crate set).
//!
//! Supports exactly what `artifacts/meta.json` and the results files
//! need: objects, arrays, strings (with \u escapes), f64 numbers, bools,
//! null.  The parser is a straightforward recursive-descent over bytes;
//! the writer escapes control characters and round-trips every value
//! this crate produces.

use std::collections::BTreeMap;
use std::fmt;

use anyhow::{anyhow, bail, Result};

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- accessors ---------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required fields.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key).ok_or_else(|| anyhow!("missing key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    // ---- constructors for the writer side ----------------------------
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    pub fn str(s: &str) -> Json {
        Json::Str(s.to_string())
    }

    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.pos != p.bytes.len() {
            bail!("trailing data at byte {}", p.pos);
        }
        Ok(v)
    }
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Result<u8> {
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("unexpected end of input"))
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!(
                "expected {:?} at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek()? as char
            );
        }
        self.pos += 1;
        Ok(())
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.number(),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(s.as_bytes()) {
            self.pos += s.len();
            Ok(v)
        } else {
            bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                c => bail!("expected ',' or '}}' at byte {}, found {:?}", self.pos, c as char),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                c => bail!("expected ',' or ']' at byte {}, found {:?}", self.pos, c as char),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let b = self.peek()?;
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek()?;
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| anyhow!("bad \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.pos += 4;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| anyhow!("bad codepoint {code:#x}"))?,
                            );
                        }
                        _ => bail!("bad escape {:?}", e as char),
                    }
                }
                _ => {
                    // byte-accurate UTF-8 pass-through: back up and take the char
                    self.pos -= 1;
                    let s = std::str::from_utf8(&self.bytes[self.pos..])?;
                    let c = s.chars().next().ok_or_else(|| anyhow!("eof in string"))?;
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>().map_err(|e| anyhow!("bad number {s:?}: {e}"))?))
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\r' => write!(f, "\\r")?,
                        '\t' => write!(f, "\\t")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("3.5").unwrap(), Json::Num(3.5));
        assert_eq!(Json::parse("-2e3").unwrap(), Json::Num(-2000.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64().unwrap(), 2.0);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn parses_unicode_escape() {
        let j = Json::parse(r#""é中""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "é中");
    }

    #[test]
    fn parses_utf8_passthrough() {
        let j = Json::parse(r#"{"k": "naïve — ok"}"#).unwrap();
        assert_eq!(j.get("k").unwrap().as_str().unwrap(), "naïve — ok");
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("xyz").is_err());
        assert!(Json::parse("{} trailing").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"a":[1,2.5,"s"],"b":{"c":true,"d":null},"e":"q\"uote"}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn req_errors_on_missing() {
        let j = Json::parse(r#"{"a": 1}"#).unwrap();
        assert!(j.req("a").is_ok());
        assert!(j.req("zz").is_err());
    }
}
