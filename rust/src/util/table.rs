//! Shared fixed-width row builder for the CLI stat tables.
//!
//! `DriveReport::render` and `GatewayStats::render` used to build their
//! headers and rows from SEPARATE `format!` strings, and the two
//! drifted once already (ISSUE 10 satellite).  [`Columns`] is the one
//! place the widths live: the first column is left-aligned (it carries
//! the row key), every other column is right-aligned, cells are
//! single-space separated, and callers pre-format numeric cells (the
//! builder never decides precision — only geometry).

/// Column geometry for one table: a width per column.
#[derive(Clone, Debug)]
pub struct Columns {
    widths: Vec<usize>,
}

impl Columns {
    pub fn new(widths: &[usize]) -> Columns {
        assert!(!widths.is_empty(), "a table needs at least one column");
        Columns { widths: widths.to_vec() }
    }

    /// Render one row (no trailing newline).  Fewer cells than columns
    /// renders a prefix row (the totals line of `DriveReport` appends
    /// free text after its first columns); more cells than columns is a
    /// caller bug.
    pub fn row<S: AsRef<str>>(&self, cells: &[S]) -> String {
        assert!(
            cells.len() <= self.widths.len(),
            "{} cells for {} columns",
            cells.len(),
            self.widths.len()
        );
        let mut out = String::new();
        for (i, cell) in cells.iter().enumerate() {
            if i > 0 {
                out.push(' ');
            }
            let (c, w) = (cell.as_ref(), self.widths[i]);
            if i == 0 {
                out.push_str(&format!("{c:<w$}"));
            } else {
                out.push_str(&format!("{c:>w$}"));
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn first_column_left_rest_right() {
        let cols = Columns::new(&[6, 4, 5]);
        assert_eq!(cols.row(&["key", "12", "3.40"]), "key      12  3.40");
    }

    #[test]
    fn oversized_cells_widen_without_truncation() {
        let cols = Columns::new(&[3, 2]);
        assert_eq!(cols.row(&["longkey", "12345"]), "longkey 12345");
    }

    #[test]
    fn prefix_rows_render_only_the_given_cells() {
        let cols = Columns::new(&[4, 3, 3]);
        assert_eq!(cols.row(&["tot", "10"]), "tot   10");
    }

    #[test]
    fn header_and_row_share_the_geometry() {
        // the regression this type exists to prevent: header and data
        // rows built from the same widths can never drift
        let cols = Columns::new(&[8, 6]);
        let header = cols.row(&["session", "shed"]);
        let row = cols.row(&["a@f", "3"]);
        assert_eq!(header.len(), row.len());
        assert_eq!(header.find("shed").map(|i| i + 4), row.find('3').map(|i| i + 1));
    }
}
