//! Tiny CLI argument parser (clap is not in the offline crate set).
//!
//! Model: `repro <subcommand> [--flag value]... [--switch]... [positional]...`
//! Flags can be declared with defaults; unknown flags are an error so
//! typos fail loudly.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

#[derive(Debug, Default)]
pub struct Args {
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse raw arguments.  `known_switches` are boolean flags that take
    /// no value; everything else starting with `--` consumes one value.
    pub fn parse(raw: &[String], known_switches: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        while i < raw.len() {
            let a = &raw[i];
            if let Some(name) = a.strip_prefix("--") {
                if known_switches.contains(&name) {
                    out.switches.push(name.to_string());
                } else {
                    let v = raw
                        .get(i + 1)
                        .ok_or_else(|| anyhow!("flag --{name} needs a value"))?;
                    out.flags.insert(name.to_string(), v.clone());
                    i += 1;
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        Ok(out)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow!("--{name} expects an integer, got {s:?}")),
        }
    }

    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(s) => s
                .parse()
                .map_err(|_| anyhow!("--{name} expects a number, got {s:?}")),
        }
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Error if any flag outside `allowed` was passed (typo guard).
    pub fn check_known(&self, allowed: &[&str]) -> Result<()> {
        for k in self.flags.keys() {
            if !allowed.contains(&k.as_str()) {
                bail!("unknown flag --{k} (allowed: {})", allowed.join(", "));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_flags_switches_positional() {
        let a = Args::parse(&s(&["fig6", "--net", "lenet5", "--verbose", "extra"]), &["verbose"])
            .unwrap();
        assert_eq!(a.positional(), &["fig6", "extra"]);
        assert_eq!(a.get("net"), Some("lenet5"));
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
    }

    #[test]
    fn typed_getters() {
        let a = Args::parse(&s(&["--n", "42", "--x", "1.5"]), &[]).unwrap();
        assert_eq!(a.get_usize("n", 0).unwrap(), 42);
        assert_eq!(a.get_f64("x", 0.0).unwrap(), 1.5);
        assert_eq!(a.get_usize("missing", 7).unwrap(), 7);
        assert!(a.get_usize("x", 0).is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&s(&["--net"]), &[]).is_err());
    }

    #[test]
    fn unknown_flag_guard() {
        let a = Args::parse(&s(&["--good", "1", "--bad", "2"]), &[]).unwrap();
        assert!(a.check_known(&["good"]).is_err());
        assert!(a.check_known(&["good", "bad"]).is_ok());
    }
}
