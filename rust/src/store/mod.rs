//! `precis::store` — the pre-quantized, bit-packed weight store.
//!
//! Weights are constant per `(network, layer, resolved format)`, yet
//! the engine used to re-copy and re-quantize every layer's full weight
//! tensor on **every** forward.  A [`WeightStore`] prepares that work
//! once: each entry holds the layer's weights quantized to f32 for the
//! kernel path *and* a bit-packed narrow-width [`PackedTensor`] whose
//! decode is bit-exact to [`crate::numerics::quantize_slice`]
//! (DESIGN.md §Storage).  After the first forward under a spec, the
//! engine reads staged weights by reference — zero weight-quantization
//! work per request, which the store's counters prove and
//! `bench_harness::suite` quantifies (cached-vs-restaged forward).
//!
//! # Keying & sharing
//!
//! Entries are keyed by [`StoreKey`] — `(network, layer, resolved
//! Format)`, *not* by precision spec: two gateway sessions serving
//! `lenet5@float:m4e5` and `lenet5@plan:conv1=float:m4e5,...` share
//! every layer whose resolved format matches.  One store is shared by
//! all sessions a [`crate::serving::Gateway`] hosts over the same zoo.
//!
//! # Budget & eviction
//!
//! The store holds at most `budget` bytes (each entry priced as its
//! quantized-f32 bytes plus its packed bytes); admission is checked
//! *before* building an entry, and inserting past the budget evicts
//! least-recently-used entries.  A `prepare` the budget cannot admit
//! returns `None` and the engine falls back to its scratch staging
//! buffer — eviction degrades to correct (bit-identical) re-staging,
//! never to an error.  `Some(0)` is the "disabled" budget (the bench
//! suite's re-staging baseline); `None` is unbounded.
//!
//! `Format::SINGLE` layers whose weights the identity quantizer leaves
//! bit-identical never reach the store at all — the engine borrows the
//! network's tensor directly (no copy, no store bytes; see
//! `nn::QuantTable`).

mod exec;
mod footprint;
mod packed;

pub use exec::{
    gemm_packed_int, gemm_packed_lut, route, ExecScratch, HasLanes, PackedPlan, Route,
    LUT_MAX_WIDTH,
};
pub use footprint::{zoo_size, FootprintRow};
pub use packed::PackedTensor;

use std::collections::HashMap;
use std::sync::{Arc, Mutex, PoisonError};

use anyhow::{bail, Result};

use crate::formats::Format;
use crate::numerics::{quantize_slice, Quantizer};

/// Default byte budget for stores nobody configured (e.g. a bare
/// `NativeBackend::new`): generous for every zoo network while keeping
/// a 240-format design-space sweep from pinning one staged copy per
/// format it ever visited.
pub const DEFAULT_WEIGHT_BUDGET: usize = 64 << 20;

/// Identity of one staged weight tensor: the layer's weights under one
/// **resolved** format.  Specs that resolve a layer to the same format
/// share its entry (module docs).
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StoreKey {
    pub net: String,
    pub layer: String,
    pub fmt: Format,
}

impl StoreKey {
    pub fn new(net: &str, layer: &str, fmt: Format) -> StoreKey {
        StoreKey { net: net.to_string(), layer: layer.to_string(), fmt }
    }
}

/// One staged weight tensor: the quantized f32 data the kernels read,
/// plus the bit-packed narrow-width encoding.
pub struct StoreEntry {
    quantized: Vec<f32>,
    packed: PackedTensor,
}

impl StoreEntry {
    fn build(fmt: &Format, weights: &[f32]) -> StoreEntry {
        // the SAME quantize_slice call the engine's scratch staging
        // runs — bit-identity between store hits and misses is by
        // construction, not by test alone
        let mut quantized = weights.to_vec();
        quantize_slice(&mut quantized, &Quantizer::new(fmt));
        let packed = PackedTensor::pack_quantized(&quantized, fmt);
        StoreEntry { quantized, packed }
    }

    /// The kernel-ready quantized weights (what `gemm_q` consumes).
    pub fn quantized(&self) -> &[f32] {
        &self.quantized
    }

    /// The narrow-width encoding (storage tier; decodes bit-exactly to
    /// [`StoreEntry::quantized`]).
    pub fn packed(&self) -> &PackedTensor {
        &self.packed
    }

    /// Budget price of this entry.
    pub fn bytes(&self) -> usize {
        Self::bytes_for(self.quantized.len(), self.packed.fmt())
    }

    /// Budget price of a would-be entry — exact, without building it.
    pub fn bytes_for(len: usize, fmt: &Format) -> usize {
        len * 4 + PackedTensor::packed_bytes_for(len, fmt)
    }
}

/// Counter snapshot of a [`WeightStore`] (all lifetime-total except the
/// `entries`/`bytes` gauges).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StoreStats {
    /// prepares served from a resident entry
    pub hits: u64,
    /// prepares that had to build (and admit) an entry
    pub misses: u64,
    /// entries displaced by the LRU policy
    pub evictions: u64,
    /// prepares refused because the entry alone exceeds the budget
    /// (the caller re-stages into scratch — correct, just uncached)
    pub rejected: u64,
    /// resident entries
    pub entries: usize,
    /// resident bytes (quantized f32 + packed, summed over entries)
    pub bytes: usize,
    /// resident packed bytes alone (the narrow storage tier)
    pub packed_bytes: usize,
    /// configured budget (`None` = unbounded)
    pub budget: Option<usize>,
}

impl StoreStats {
    /// One-line human rendering for CLI stats tables.
    pub fn render(&self) -> String {
        format!(
            "{} hits, {} misses, {} evictions, {} rejected; {} entries, {} resident ({} packed), budget {}",
            self.hits,
            self.misses,
            self.evictions,
            self.rejected,
            self.entries,
            human_bytes(self.bytes),
            human_bytes(self.packed_bytes),
            match self.budget {
                Some(b) => human_bytes(b),
                None => "unbounded".to_string(),
            },
        )
    }
}

struct Slot {
    entry: Arc<StoreEntry>,
    last_used: u64,
}

struct Inner {
    budget: Option<usize>,
    tick: u64,
    entries: HashMap<StoreKey, Slot>,
    bytes: usize,
    packed_bytes: usize,
    hits: u64,
    misses: u64,
    evictions: u64,
    rejected: u64,
}

/// The shared weight store (module docs).  All methods take `&self`;
/// clone the surrounding `Arc` to share it across sessions/threads.
pub struct WeightStore {
    inner: Mutex<Inner>,
}

impl Default for WeightStore {
    fn default() -> Self {
        WeightStore::with_budget(DEFAULT_WEIGHT_BUDGET)
    }
}

impl WeightStore {
    /// A store capped at `budget` bytes.  `0` disables caching entirely
    /// (every `prepare` returns `None`; the re-staging baseline).
    pub fn with_budget(budget: usize) -> WeightStore {
        WeightStore {
            inner: Mutex::new(Inner {
                budget: Some(budget),
                tick: 0,
                entries: HashMap::new(),
                bytes: 0,
                packed_bytes: 0,
                hits: 0,
                misses: 0,
                evictions: 0,
                rejected: 0,
            }),
        }
    }

    /// A store with no byte budget.
    pub fn unbounded() -> WeightStore {
        let store = WeightStore::with_budget(0);
        store.lock().budget = None;
        store
    }

    /// The CLI `--weight-budget` shape: `Some(b)` →
    /// [`WeightStore::with_budget`], `None` (flag absent) → the
    /// [`DEFAULT_WEIGHT_BUDGET`] default.  Unbounded stores are only
    /// ever explicit ([`WeightStore::unbounded`]).
    pub fn from_budget(budget: Option<usize>) -> WeightStore {
        match budget {
            Some(b) => WeightStore::with_budget(b),
            None => WeightStore::default(),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// The staged entry for `key`, building it from `weights` on a
    /// miss.  `None` means the budget cannot admit the entry (priced
    /// before building) — the caller must re-stage into scratch, which
    /// is bit-identical by construction.
    pub fn prepare(&self, key: &StoreKey, weights: &[f32]) -> Option<Arc<StoreEntry>> {
        let tick = {
            let mut g = self.lock();
            g.tick += 1;
            let tick = g.tick;
            if let Some(slot) = g.entries.get_mut(key) {
                slot.last_used = tick;
                let entry = slot.entry.clone();
                g.hits += 1;
                return Some(entry);
            }
            let price = StoreEntry::bytes_for(weights.len(), &key.fmt);
            if let Some(b) = g.budget {
                if price > b {
                    g.rejected += 1;
                    return None;
                }
            }
            g.misses += 1;
            tick
        };
        // build OUTSIDE the lock: quantization + packing of a large
        // tensor must not stall other sessions' hits
        let entry = Arc::new(StoreEntry::build(&key.fmt, weights));
        let mut g = self.lock();
        if let Some(slot) = g.entries.get_mut(key) {
            // lost a race with a concurrent builder — adopt the
            // incumbent (identical bits by construction)
            slot.last_used = slot.last_used.max(tick);
            return Some(slot.entry.clone());
        }
        g.bytes += entry.bytes();
        g.packed_bytes += entry.packed.packed_bytes();
        g.entries
            .insert(key.clone(), Slot { entry: entry.clone(), last_used: tick });
        while g.budget.is_some_and(|b| g.bytes > b) && g.entries.len() > 1 {
            let lru = g
                .entries
                .iter()
                .min_by_key(|(_, s)| s.last_used)
                .map(|(k, _)| k.clone())
                .expect("non-empty map has an LRU entry");
            let slot = g.entries.remove(&lru).expect("key came from the map");
            g.bytes -= slot.entry.bytes();
            g.packed_bytes -= slot.entry.packed.packed_bytes();
            g.evictions += 1;
        }
        Some(entry)
    }

    /// Counter snapshot (cheap: copies a few words under the lock).
    pub fn stats(&self) -> StoreStats {
        let g = self.lock();
        StoreStats {
            hits: g.hits,
            misses: g.misses,
            evictions: g.evictions,
            rejected: g.rejected,
            entries: g.entries.len(),
            bytes: g.bytes,
            packed_bytes: g.packed_bytes,
            budget: g.budget,
        }
    }

    /// Drop every entry (counters keep their lifetime totals).
    pub fn clear(&self) {
        let mut g = self.lock();
        g.entries.clear();
        g.bytes = 0;
        g.packed_bytes = 0;
    }
}

/// `"8m"` / `"512k"` / `"1g"` / plain bytes → bytes (the
/// `--weight-budget` flag grammar; case-insensitive suffix).
pub fn parse_byte_size(s: &str) -> Result<usize> {
    let t = s.trim();
    if t.is_empty() {
        bail!("empty byte size");
    }
    let (num, mult) = match t.chars().next_back().unwrap().to_ascii_lowercase() {
        'k' => (&t[..t.len() - 1], 1usize << 10),
        'm' => (&t[..t.len() - 1], 1usize << 20),
        'g' => (&t[..t.len() - 1], 1usize << 30),
        _ => (t, 1usize),
    };
    let n: usize = num
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("bad byte size {s:?} (want e.g. 65536, 512k, 8m, 1g)"))?;
    n.checked_mul(mult)
        .ok_or_else(|| anyhow::anyhow!("byte size {s:?} overflows"))
}

/// Compact byte rendering for stats tables.
pub fn human_bytes(b: usize) -> String {
    if b >= 1 << 30 {
        format!("{:.2}GiB", b as f64 / (1u64 << 30) as f64)
    } else if b >= 1 << 20 {
        format!("{:.2}MiB", b as f64 / (1 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.1}KiB", b as f64 / (1 << 10) as f64)
    } else {
        format!("{b}B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(layer: &str, fmt: Format) -> StoreKey {
        StoreKey::new("unit-net", layer, fmt)
    }

    #[test]
    fn hit_miss_and_bit_identity_to_quantize_slice() {
        let store = WeightStore::unbounded();
        let fmt = Format::fixed(4, 4);
        let w: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).sin() * 7.0).collect();
        let k = key("c1", fmt);

        let a = store.prepare(&k, &w).expect("unbounded store admits");
        let s = store.stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 1, 1));
        assert_eq!(s.bytes, StoreEntry::bytes_for(w.len(), &fmt));
        assert_eq!(s.budget, None);

        let mut want = w.clone();
        quantize_slice(&mut want, &Quantizer::new(&fmt));
        assert_eq!(a.quantized(), want.as_slice());
        // the packed tier decodes to the same bits
        assert_eq!(a.packed().unpack(), want);

        let b = store.prepare(&k, &w).expect("hit");
        assert!(Arc::ptr_eq(&a, &b), "a hit returns the SAME staged entry");
        let s = store.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));

        // a different resolved format is a different entry
        store.prepare(&key("c1", Format::float(7, 6)), &w).unwrap();
        assert_eq!(store.stats().entries, 2);
    }

    #[test]
    fn lru_eviction_under_a_tight_budget() {
        let fmt = Format::fixed(8, 8);
        let w: Vec<f32> = (0..32).map(|i| i as f32 * 0.25).collect();
        let one = StoreEntry::bytes_for(w.len(), &fmt);
        // room for two entries, not three
        let store = WeightStore::with_budget(2 * one);

        store.prepare(&key("a", fmt), &w).unwrap();
        store.prepare(&key("b", fmt), &w).unwrap();
        assert_eq!(store.stats().entries, 2);
        // touch `a` so `b` is the LRU victim
        store.prepare(&key("a", fmt), &w).unwrap();
        store.prepare(&key("c", fmt), &w).unwrap();

        let s = store.stats();
        assert_eq!(s.entries, 2);
        assert_eq!(s.evictions, 1);
        assert!(s.bytes <= 2 * one);
        // `b` was evicted: preparing it again is a miss that evicts the
        // new LRU (`a`); `a` and `c` patterns confirm recency ordering
        store.prepare(&key("b", fmt), &w).unwrap();
        let s = store.stats();
        assert_eq!(s.evictions, 2);
        assert_eq!(s.misses, 4, "a, b, c, then b again");
        assert_eq!(s.hits, 1, "only the explicit re-touch of `a` hit");
    }

    #[test]
    fn oversized_entries_are_rejected_not_inserted() {
        let fmt = Format::float(7, 6);
        let w = vec![1.0f32; 128];
        let store = WeightStore::with_budget(StoreEntry::bytes_for(w.len(), &fmt) - 1);
        assert!(store.prepare(&key("big", fmt), &w).is_none());
        let s = store.stats();
        assert_eq!((s.rejected, s.misses, s.entries, s.bytes), (1, 0, 0, 0));

        // budget 0 = disabled: everything is rejected
        let disabled = WeightStore::with_budget(0);
        assert!(disabled.prepare(&key("any", fmt), &w).is_none());
        assert_eq!(disabled.stats().rejected, 1);
    }

    #[test]
    fn clear_drops_entries_but_keeps_counters() {
        let store = WeightStore::unbounded();
        let fmt = Format::fixed(2, 2);
        store.prepare(&key("a", fmt), &[1.0, 2.0]).unwrap();
        store.prepare(&key("a", fmt), &[1.0, 2.0]).unwrap();
        store.clear();
        let s = store.stats();
        assert_eq!((s.entries, s.bytes, s.packed_bytes), (0, 0, 0));
        assert_eq!((s.hits, s.misses), (1, 1));
        // re-preparing after clear rebuilds
        store.prepare(&key("a", fmt), &[1.0, 2.0]).unwrap();
        assert_eq!(store.stats().misses, 2);
    }

    #[test]
    fn parse_byte_size_grammar() {
        assert_eq!(parse_byte_size("65536").unwrap(), 65536);
        assert_eq!(parse_byte_size("512k").unwrap(), 512 << 10);
        assert_eq!(parse_byte_size("8m").unwrap(), 8 << 20);
        assert_eq!(parse_byte_size("2G").unwrap(), 2 << 30);
        assert_eq!(parse_byte_size(" 16 m ").unwrap(), 16 << 20);
        assert_eq!(parse_byte_size("0").unwrap(), 0);
        for bad in ["", "m", "12q", "-4", "1.5m", "99999999999999999999"] {
            assert!(parse_byte_size(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn human_bytes_rendering() {
        assert_eq!(human_bytes(64), "64B");
        assert_eq!(human_bytes(2048), "2.0KiB");
        assert_eq!(human_bytes(3 << 20), "3.00MiB");
        assert_eq!(human_bytes(5 << 30), "5.00GiB");
    }
}
