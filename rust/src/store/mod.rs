//! `precis::store` — the pre-quantized, bit-packed weight store.
//!
//! Weights are constant per `(network, layer, resolved format)`, yet
//! the engine used to re-copy and re-quantize every layer's full weight
//! tensor on **every** forward.  A [`WeightStore`] prepares that work
//! once: each entry holds the layer's weights quantized to f32 for the
//! kernel path *and* a bit-packed narrow-width [`PackedTensor`] whose
//! decode is bit-exact to [`crate::numerics::quantize_slice`]
//! (DESIGN.md §Storage).  After the first forward under a spec, the
//! engine reads staged weights by reference — zero weight-quantization
//! work per request, which the store's counters prove and
//! `bench_harness::suite` quantifies (cached-vs-restaged forward).
//!
//! # Keying & sharing
//!
//! Entries are keyed by [`StoreKey`] — `(network, layer, resolved
//! Format)`, *not* by precision spec: two gateway sessions serving
//! `lenet5@float:m4e5` and `lenet5@plan:conv1=float:m4e5,...` share
//! every layer whose resolved format matches.  One store is shared by
//! all sessions a [`crate::serving::Gateway`] hosts over the same zoo.
//!
//! # Budget & eviction
//!
//! The store holds at most `budget` bytes (each entry priced as its
//! quantized-f32 bytes plus its packed bytes); admission is checked
//! *before* building an entry, and inserting past the budget evicts
//! least-recently-used entries.  A `prepare` the budget cannot admit
//! returns `None` and the engine falls back to its scratch staging
//! buffer — eviction degrades to correct (bit-identical) re-staging,
//! never to an error.  `Some(0)` is the "disabled" budget (the bench
//! suite's re-staging baseline); `None` is unbounded.
//!
//! `Format::SINGLE` layers whose weights the identity quantizer leaves
//! bit-identical never reach the store at all — the engine borrows the
//! network's tensor directly (no copy, no store bytes; see
//! `nn::QuantTable`).
//!
//! # Lock-free warm path
//!
//! A [`WeightStore::prepare_lease`] miss (or cold hit) goes through the
//! store mutex as before, but returns a [`Lease`]: the entry `Arc` plus
//! the slot's per-key epoch and the epoch value observed at issue time.
//! The engine caches the lease inside its resolved `QuantTable`; every
//! subsequent warm forward revalidates with
//! [`WeightStore::hit_if_current`] — one `Acquire` load, zero mutex
//! acquisitions.  Eviction and [`WeightStore::clear`] bump the epoch
//! (`Release`), so stale leases fail validation and fall back to the
//! locked path, which is always correct (entries are immutable and
//! rebuilt bit-identically).  DESIGN.md §Storage has the full
//! load/validate/fallback/invalidate protocol table.

mod exec;
mod footprint;
mod packed;

pub use exec::{
    gemm_packed_int, gemm_packed_int_scalar, gemm_packed_lut, route, route_pair, ExecScratch,
    HasLanes, PackedPlan, Route, LUT_MAX_WIDTH,
};
pub use footprint::{zoo_size, FootprintRow};
pub use packed::PackedTensor;

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, PoisonError};

use anyhow::{bail, Result};

use crate::formats::Format;
use crate::numerics::{quantize_slice, Quantizer};
use crate::obs::{Counter, Event, EventSink, Registry};

/// Default byte budget for stores nobody configured (e.g. a bare
/// `NativeBackend::new`): generous for every zoo network while keeping
/// a 240-format design-space sweep from pinning one staged copy per
/// format it ever visited.
pub const DEFAULT_WEIGHT_BUDGET: usize = 64 << 20;

/// Identity of one staged weight tensor: the layer's weights under one
/// **resolved** format.  Specs that resolve a layer to the same format
/// share its entry (module docs).
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct StoreKey {
    pub net: String,
    pub layer: String,
    pub fmt: Format,
}

impl StoreKey {
    pub fn new(net: &str, layer: &str, fmt: Format) -> StoreKey {
        StoreKey { net: net.to_string(), layer: layer.to_string(), fmt }
    }
}

/// One staged weight tensor: the quantized f32 data the kernels read,
/// plus the bit-packed narrow-width encoding.
pub struct StoreEntry {
    quantized: Vec<f32>,
    packed: PackedTensor,
}

impl StoreEntry {
    fn build(fmt: &Format, weights: &[f32]) -> StoreEntry {
        // the SAME quantize_slice call the engine's scratch staging
        // runs — bit-identity between store hits and misses is by
        // construction, not by test alone
        let mut quantized = weights.to_vec();
        quantize_slice(&mut quantized, &Quantizer::new(fmt));
        let packed = PackedTensor::pack_quantized(&quantized, fmt);
        StoreEntry { quantized, packed }
    }

    /// The kernel-ready quantized weights (what `gemm_q` consumes).
    pub fn quantized(&self) -> &[f32] {
        &self.quantized
    }

    /// The narrow-width encoding (storage tier; decodes bit-exactly to
    /// [`StoreEntry::quantized`]).
    pub fn packed(&self) -> &PackedTensor {
        &self.packed
    }

    /// Budget price of this entry.
    pub fn bytes(&self) -> usize {
        Self::bytes_for(self.quantized.len(), self.packed.fmt())
    }

    /// Budget price of a would-be entry — exact, without building it.
    pub fn bytes_for(len: usize, fmt: &Format) -> usize {
        len * 4 + PackedTensor::packed_bytes_for(len, fmt)
    }
}

/// Counter snapshot of a [`WeightStore`] (all lifetime-total except the
/// `entries`/`bytes` gauges).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StoreStats {
    /// prepares served from a resident entry
    pub hits: u64,
    /// prepares that had to build (and admit) an entry
    pub misses: u64,
    /// entries displaced by the LRU policy
    pub evictions: u64,
    /// prepares refused because the entry alone exceeds the budget
    /// (the caller re-stages into scratch — correct, just uncached)
    pub rejected: u64,
    /// lost-race adopts: a concurrent prepare built a duplicate entry
    /// but was served the incumbent — counted in `hits`, not `misses`,
    /// so `hits + misses + rejected` always equals total prepares
    pub races: u64,
    /// resident entries
    pub entries: usize,
    /// resident bytes (quantized f32 + packed, summed over entries)
    pub bytes: usize,
    /// resident packed bytes alone (the narrow storage tier)
    pub packed_bytes: usize,
    /// configured budget (`None` = unbounded)
    pub budget: Option<usize>,
}

impl StoreStats {
    /// One-line human rendering for CLI stats tables.
    pub fn render(&self) -> String {
        format!(
            "{} hits, {} misses, {} evictions, {} rejected, {} races; {} entries, {} resident ({} packed), budget {}",
            self.hits,
            self.misses,
            self.evictions,
            self.rejected,
            self.races,
            self.entries,
            human_bytes(self.bytes),
            human_bytes(self.packed_bytes),
            match self.budget {
                Some(b) => human_bytes(b),
                None => "unbounded".to_string(),
            },
        )
    }
}

struct Slot {
    entry: Arc<StoreEntry>,
    /// Per-key epoch published to [`Lease`] holders: bumped (`Release`)
    /// when this slot is evicted or cleared, so every outstanding lease
    /// on it goes stale with one atomic store.  A re-inserted key gets
    /// a FRESH epoch cell, so leases from a previous residency can
    /// never revalidate by accident.
    epoch: Arc<AtomicU64>,
    last_used: u64,
}

/// An epoch-validated claim on a staged entry — the lock-free warm
/// path (module docs, DESIGN.md §Storage).  The engine caches the
/// lease inside its resolved `QuantTable`; while the slot's epoch still
/// equals the value observed at issue time,
/// [`WeightStore::hit_if_current`] serves the entry with a single
/// atomic load and **no mutex**.  Eviction and [`WeightStore::clear`]
/// bump the epoch, so stale leases fall back to the locked
/// [`WeightStore::prepare_lease`] path.
#[derive(Clone)]
pub struct Lease {
    entry: Arc<StoreEntry>,
    epoch: Arc<AtomicU64>,
    seen: u64,
}

impl Lease {
    /// The staged entry this lease was issued against.  Readable even
    /// when stale — entries are immutable, staleness only means the
    /// store has since evicted the slot (the engine re-prepares so the
    /// store's accounting stays truthful).
    pub fn entry(&self) -> &Arc<StoreEntry> {
        &self.entry
    }
}

struct Inner {
    budget: Option<usize>,
    tick: u64,
    entries: HashMap<StoreKey, Slot>,
    bytes: usize,
    packed_bytes: usize,
    // lifetime counters as obs cells: mutated only under this mutex (so
    // their relative ordering is exactly the old plain-u64 behaviour)
    // but adoptable into an `obs::Registry`, which then reads the SAME
    // atomics `stats()` snapshots — one set of books, two views
    misses: Arc<Counter>,
    evictions: Arc<Counter>,
    rejected: Arc<Counter>,
    races: Arc<Counter>,
}

/// The shared weight store (module docs).  All methods take `&self`;
/// clone the surrounding `Arc` to share it across sessions/threads.
pub struct WeightStore {
    inner: Mutex<Inner>,
    /// prepares served from a resident entry (locked hit, lost-race
    /// adopt, or lock-free lease validation) — atomic so the warm path
    /// can count hits without touching the mutex
    hits: Arc<Counter>,
    /// data-path mutex acquisitions; [`WeightStore::stats`] reads do
    /// not count.  The store-contract concurrency tests assert this
    /// stays flat across warm forwards — the "zero locks when warm"
    /// proof counter.
    lock_acquisitions: Arc<Counter>,
    /// structured event sink for evict/reject records (`obs::events`).
    /// Set-once and read lock-free; unset costs one pointer check per
    /// eviction/rejection — never per warm forward.
    events: OnceLock<Arc<EventSink>>,
}

impl Default for WeightStore {
    fn default() -> Self {
        WeightStore::with_budget(DEFAULT_WEIGHT_BUDGET)
    }
}

impl WeightStore {
    /// A store capped at `budget` bytes.  `0` disables caching entirely
    /// (every `prepare` returns `None`; the re-staging baseline).
    pub fn with_budget(budget: usize) -> WeightStore {
        WeightStore {
            inner: Mutex::new(Inner {
                budget: Some(budget),
                tick: 0,
                entries: HashMap::new(),
                bytes: 0,
                packed_bytes: 0,
                misses: Arc::new(Counter::new()),
                evictions: Arc::new(Counter::new()),
                rejected: Arc::new(Counter::new()),
                races: Arc::new(Counter::new()),
            }),
            hits: Arc::new(Counter::new()),
            lock_acquisitions: Arc::new(Counter::new()),
            events: OnceLock::new(),
        }
    }

    /// Adopt this store's counters into `reg` under `store/*` names.
    /// The registry then reads the SAME cells every mutation touches —
    /// [`WeightStore::stats`] and a registry snapshot can never
    /// disagree.  Adoption locks once (registration time); the data
    /// path is untouched, so warm forwards stay lock-free with the
    /// registry live (tests/store_contract.rs).
    pub fn register_into(&self, reg: &Registry) {
        reg.adopt_counter("store/hits", &self.hits);
        reg.adopt_counter("store/lock_acquisitions", &self.lock_acquisitions);
        let g = self.lock_raw();
        reg.adopt_counter("store/misses", &g.misses);
        reg.adopt_counter("store/evictions", &g.evictions);
        reg.adopt_counter("store/rejected", &g.rejected);
        reg.adopt_counter("store/races", &g.races);
    }

    /// Wire the structured event sink (evict/reject records).  Set-once:
    /// later calls are ignored, matching the gateway's one-sink model.
    pub fn set_events(&self, sink: Arc<EventSink>) {
        let _ = self.events.set(sink);
    }

    /// A store with no byte budget.
    pub fn unbounded() -> WeightStore {
        let store = WeightStore::with_budget(0);
        store.lock_raw().budget = None;
        store
    }

    /// The CLI `--weight-budget` shape: `Some(b)` →
    /// [`WeightStore::with_budget`], `None` (flag absent) → the
    /// [`DEFAULT_WEIGHT_BUDGET`] default.  Unbounded stores are only
    /// ever explicit ([`WeightStore::unbounded`]).
    pub fn from_budget(budget: Option<usize>) -> WeightStore {
        match budget {
            Some(b) => WeightStore::with_budget(b),
            None => WeightStore::default(),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.lock_acquisitions.incr();
        self.lock_raw()
    }

    /// The mutex without the data-path acquisition counter — for
    /// [`WeightStore::stats`] and construction, so the counter measures
    /// exactly what forwards pay.
    fn lock_raw(&self) -> std::sync::MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    fn lease_for(&self, slot: &Slot) -> Lease {
        self.hits.incr();
        Lease {
            entry: slot.entry.clone(),
            seen: slot.epoch.load(Ordering::Acquire),
            epoch: slot.epoch.clone(),
        }
    }

    /// The staged entry for `key` as an epoch-validated [`Lease`],
    /// building it from `weights` on a miss.  This is the LOCKED slow
    /// path; callers cache the lease and serve warm forwards through
    /// [`WeightStore::hit_if_current`].  `None` means the budget cannot
    /// admit the entry (priced before building) — the caller must
    /// re-stage into scratch, which is bit-identical by construction.
    pub fn prepare_lease(&self, key: &StoreKey, weights: &[f32]) -> Option<Lease> {
        let tick = {
            let mut g = self.lock();
            g.tick += 1;
            let tick = g.tick;
            if let Some(slot) = g.entries.get_mut(key) {
                slot.last_used = tick;
                return Some(self.lease_for(slot));
            }
            let price = StoreEntry::bytes_for(weights.len(), &key.fmt);
            if let Some(b) = g.budget {
                if price > b {
                    g.rejected.incr();
                    if let Some(sink) = self.events.get() {
                        sink.emit(Event::StoreReject { key: key_label(key), bytes: price });
                    }
                    return None;
                }
            }
            tick
        };
        // build OUTSIDE the lock: quantization + packing of a large
        // tensor must not stall other sessions' hits
        let entry = Arc::new(StoreEntry::build(&key.fmt, weights));
        let mut g = self.lock();
        if let Some(slot) = g.entries.get_mut(key) {
            // lost a race with a concurrent builder — adopt the
            // incumbent (identical bits by construction).  Serving a
            // resident entry is a HIT; `races` records the duplicate
            // build, so hit/miss totals balance per prepare even under
            // contention.
            slot.last_used = slot.last_used.max(tick);
            g.races.incr();
            return Some(self.lease_for(slot));
        }
        // the insert is what makes it a miss — counted here, not before
        // the build, so a lost race cannot count a miss AND a hit
        g.misses.incr();
        g.bytes += entry.bytes();
        g.packed_bytes += entry.packed.packed_bytes();
        let epoch = Arc::new(AtomicU64::new(0));
        g.entries.insert(
            key.clone(),
            Slot { entry: entry.clone(), epoch: epoch.clone(), last_used: tick },
        );
        while g.budget.is_some_and(|b| g.bytes > b) && g.entries.len() > 1 {
            let lru = g
                .entries
                .iter()
                .min_by_key(|(_, s)| s.last_used)
                .map(|(k, _)| k.clone())
                .expect("non-empty map has an LRU entry");
            let slot = g.entries.remove(&lru).expect("key came from the map");
            slot.epoch.fetch_add(1, Ordering::Release);
            g.bytes -= slot.entry.bytes();
            g.packed_bytes -= slot.entry.packed.packed_bytes();
            g.evictions.incr();
            if let Some(sink) = self.events.get() {
                sink.emit(Event::StoreEvict { key: key_label(&lru), bytes: slot.entry.bytes() });
            }
        }
        Some(Lease { entry, epoch, seen: 0 })
    }

    /// [`WeightStore::prepare_lease`] without the lease — for callers
    /// that re-resolve tables per call (eval/search) and cannot cache.
    pub fn prepare(&self, key: &StoreKey, weights: &[f32]) -> Option<Arc<StoreEntry>> {
        self.prepare_lease(key, weights).map(|l| l.entry)
    }

    /// The lock-free warm path: if `lease` is still current (one
    /// `Acquire` load of the slot's epoch — no mutex), count a hit and
    /// return its entry.  `None` means the slot was evicted or cleared
    /// since the lease was issued; re-prepare through the locked path.
    pub fn hit_if_current(&self, lease: &Lease) -> Option<Arc<StoreEntry>> {
        if lease.epoch.load(Ordering::Acquire) == lease.seen {
            self.hits.incr();
            Some(lease.entry.clone())
        } else {
            None
        }
    }

    /// Lifetime count of data-path mutex acquisitions.  Does not lock;
    /// a warm multi-session run must leave this flat
    /// (tests/store_contract.rs).
    pub fn lock_acquisitions(&self) -> u64 {
        self.lock_acquisitions.get()
    }

    /// Counter snapshot (cheap: copies a few words under the lock; not
    /// counted as a data-path acquisition).
    pub fn stats(&self) -> StoreStats {
        let g = self.lock_raw();
        StoreStats {
            hits: self.hits.get(),
            misses: g.misses.get(),
            evictions: g.evictions.get(),
            rejected: g.rejected.get(),
            races: g.races.get(),
            entries: g.entries.len(),
            bytes: g.bytes,
            packed_bytes: g.packed_bytes,
            budget: g.budget,
        }
    }

    /// Drop every entry (counters keep their lifetime totals).  Every
    /// outstanding [`Lease`] is invalidated by bumping its slot's epoch
    /// before the slot is dropped.
    pub fn clear(&self) {
        let mut g = self.lock();
        for slot in g.entries.values() {
            slot.epoch.fetch_add(1, Ordering::Release);
        }
        g.entries.clear();
        g.bytes = 0;
        g.packed_bytes = 0;
    }
}

/// Event-log spelling of a [`StoreKey`]: `net/layer@fmt`.
fn key_label(key: &StoreKey) -> String {
    format!("{}/{}@{}", key.net, key.layer, key.fmt)
}

/// `"8m"` / `"512k"` / `"1g"` / plain bytes → bytes (the
/// `--weight-budget` flag grammar; case-insensitive suffix).
pub fn parse_byte_size(s: &str) -> Result<usize> {
    let t = s.trim();
    if t.is_empty() {
        bail!("empty byte size");
    }
    // Split off the final CHARACTER, not the final byte: a multi-byte
    // final char (e.g. "8µ") must fall through to the plain-number
    // parse and come back as a typed Err — never a mid-UTF-8 slice.
    let last = t.chars().next_back().expect("non-empty after trim");
    let (num, mult) = match last.to_ascii_lowercase() {
        'k' => (&t[..t.len() - last.len_utf8()], 1usize << 10),
        'm' => (&t[..t.len() - last.len_utf8()], 1usize << 20),
        'g' => (&t[..t.len() - last.len_utf8()], 1usize << 30),
        _ => (t, 1usize),
    };
    let n: usize = num
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("bad byte size {s:?} (want e.g. 65536, 512k, 8m, 1g)"))?;
    n.checked_mul(mult)
        .ok_or_else(|| anyhow::anyhow!("byte size {s:?} overflows"))
}

/// Compact byte rendering for stats tables.
pub fn human_bytes(b: usize) -> String {
    if b >= 1 << 30 {
        format!("{:.2}GiB", b as f64 / (1u64 << 30) as f64)
    } else if b >= 1 << 20 {
        format!("{:.2}MiB", b as f64 / (1 << 20) as f64)
    } else if b >= 1 << 10 {
        format!("{:.1}KiB", b as f64 / (1 << 10) as f64)
    } else {
        format!("{b}B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn key(layer: &str, fmt: Format) -> StoreKey {
        StoreKey::new("unit-net", layer, fmt)
    }

    #[test]
    fn hit_miss_and_bit_identity_to_quantize_slice() {
        let store = WeightStore::unbounded();
        let fmt = Format::fixed(4, 4);
        let w: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).sin() * 7.0).collect();
        let k = key("c1", fmt);

        let a = store.prepare(&k, &w).expect("unbounded store admits");
        let s = store.stats();
        assert_eq!((s.hits, s.misses, s.entries), (0, 1, 1));
        assert_eq!(s.bytes, StoreEntry::bytes_for(w.len(), &fmt));
        assert_eq!(s.budget, None);

        let mut want = w.clone();
        quantize_slice(&mut want, &Quantizer::new(&fmt));
        assert_eq!(a.quantized(), want.as_slice());
        // the packed tier decodes to the same bits
        assert_eq!(a.packed().unpack(), want);

        let b = store.prepare(&k, &w).expect("hit");
        assert!(Arc::ptr_eq(&a, &b), "a hit returns the SAME staged entry");
        let s = store.stats();
        assert_eq!((s.hits, s.misses, s.entries), (1, 1, 1));

        // a different resolved format is a different entry
        store.prepare(&key("c1", Format::float(7, 6)), &w).unwrap();
        assert_eq!(store.stats().entries, 2);
    }

    #[test]
    fn lru_eviction_under_a_tight_budget() {
        let fmt = Format::fixed(8, 8);
        let w: Vec<f32> = (0..32).map(|i| i as f32 * 0.25).collect();
        let one = StoreEntry::bytes_for(w.len(), &fmt);
        // room for two entries, not three
        let store = WeightStore::with_budget(2 * one);

        store.prepare(&key("a", fmt), &w).unwrap();
        store.prepare(&key("b", fmt), &w).unwrap();
        assert_eq!(store.stats().entries, 2);
        // touch `a` so `b` is the LRU victim
        store.prepare(&key("a", fmt), &w).unwrap();
        store.prepare(&key("c", fmt), &w).unwrap();

        let s = store.stats();
        assert_eq!(s.entries, 2);
        assert_eq!(s.evictions, 1);
        assert!(s.bytes <= 2 * one);
        // `b` was evicted: preparing it again is a miss that evicts the
        // new LRU (`a`); `a` and `c` patterns confirm recency ordering
        store.prepare(&key("b", fmt), &w).unwrap();
        let s = store.stats();
        assert_eq!(s.evictions, 2);
        assert_eq!(s.misses, 4, "a, b, c, then b again");
        assert_eq!(s.hits, 1, "only the explicit re-touch of `a` hit");
    }

    #[test]
    fn oversized_entries_are_rejected_not_inserted() {
        let fmt = Format::float(7, 6);
        let w = vec![1.0f32; 128];
        let store = WeightStore::with_budget(StoreEntry::bytes_for(w.len(), &fmt) - 1);
        assert!(store.prepare(&key("big", fmt), &w).is_none());
        let s = store.stats();
        assert_eq!((s.rejected, s.misses, s.entries, s.bytes), (1, 0, 0, 0));

        // budget 0 = disabled: everything is rejected
        let disabled = WeightStore::with_budget(0);
        assert!(disabled.prepare(&key("any", fmt), &w).is_none());
        assert_eq!(disabled.stats().rejected, 1);
    }

    #[test]
    fn clear_drops_entries_but_keeps_counters() {
        let store = WeightStore::unbounded();
        let fmt = Format::fixed(2, 2);
        store.prepare(&key("a", fmt), &[1.0, 2.0]).unwrap();
        store.prepare(&key("a", fmt), &[1.0, 2.0]).unwrap();
        store.clear();
        let s = store.stats();
        assert_eq!((s.entries, s.bytes, s.packed_bytes), (0, 0, 0));
        assert_eq!((s.hits, s.misses), (1, 1));
        // re-preparing after clear rebuilds
        store.prepare(&key("a", fmt), &[1.0, 2.0]).unwrap();
        assert_eq!(store.stats().misses, 2);
    }

    /// The lock-free warm path in isolation: a current lease validates
    /// with zero mutex acquisitions and still counts hits; `clear()`
    /// invalidates it and the locked fallback rebuilds bit-identically.
    #[test]
    fn lease_warm_path_is_lockfree_until_invalidated() {
        let store = WeightStore::unbounded();
        let fmt = Format::fixed(4, 4);
        let w: Vec<f32> = (0..32).map(|i| i as f32 * 0.5 - 8.0).collect();
        let lease = store.prepare_lease(&key("c1", fmt), &w).expect("unbounded store admits");

        let locks = store.lock_acquisitions();
        for _ in 0..5 {
            let e = store.hit_if_current(&lease).expect("current lease validates");
            assert!(Arc::ptr_eq(&e, lease.entry()), "validation serves the leased entry");
        }
        assert_eq!(store.lock_acquisitions(), locks, "warm validation takes no mutex");
        assert_eq!(store.stats().hits, 5, "lock-free validations still count as hits");

        // clear() bumps the epoch: the lease goes stale and the caller
        // falls back to the locked path, which rebuilds bit-identically
        store.clear();
        assert!(store.hit_if_current(&lease).is_none(), "cleared slot invalidates the lease");
        let fresh = store.prepare_lease(&key("c1", fmt), &w).expect("re-admitted");
        assert_eq!(fresh.entry().quantized(), lease.entry().quantized());
        assert_eq!(store.stats().misses, 2, "the stale fallback is a real (locked) miss");
    }

    /// Eviction invalidates outstanding leases, and a key that re-enters
    /// the store gets a FRESH epoch cell — an old lease can never
    /// revalidate against the new residency.
    #[test]
    fn eviction_invalidates_leases_and_reinsert_gets_a_fresh_epoch() {
        let fmt = Format::fixed(8, 8);
        let w: Vec<f32> = (0..32).map(|i| i as f32 * 0.25).collect();
        let one = StoreEntry::bytes_for(w.len(), &fmt);
        let store = WeightStore::with_budget(2 * one);

        let la = store.prepare_lease(&key("a", fmt), &w).unwrap();
        store.prepare_lease(&key("b", fmt), &w).unwrap();
        store.prepare_lease(&key("b", fmt), &w).unwrap(); // touch b: a is the LRU victim
        store.prepare_lease(&key("c", fmt), &w).unwrap(); // evicts a
        assert_eq!(store.stats().evictions, 1);
        assert!(store.hit_if_current(&la).is_none(), "evicted slot invalidates the lease");

        let la2 = store.prepare_lease(&key("a", fmt), &w).unwrap();
        assert!(store.hit_if_current(&la).is_none(), "old lease stays stale after re-insert");
        assert!(store.hit_if_current(&la2).is_some(), "the new residency's lease is current");
    }

    /// ISSUE 10: the registry adopts the store's OWN counter cells —
    /// `stats()` and the registry can never disagree — and evictions /
    /// rejections land in the structured event log with their byte
    /// prices.
    #[test]
    fn registry_adoption_and_events_share_the_books() {
        use crate::obs::{EventSink, Registry};
        use crate::util::json::Json;

        let fmt = Format::fixed(8, 8);
        let w: Vec<f32> = (0..32).map(|i| i as f32 * 0.25).collect();
        let one = StoreEntry::bytes_for(w.len(), &fmt);
        let store = WeightStore::with_budget(2 * one);
        let reg = Registry::new();
        store.register_into(&reg);
        let (sink, cap) = EventSink::capture();
        store.set_events(Arc::new(sink));

        store.prepare(&key("a", fmt), &w).unwrap();
        store.prepare(&key("b", fmt), &w).unwrap();
        store.prepare(&key("a", fmt), &w).unwrap(); // touch a: b is LRU
        store.prepare(&key("c", fmt), &w).unwrap(); // evicts b
        let big = vec![1.0f32; 4096];
        assert!(store.prepare(&key("big", fmt), &big).is_none(), "over budget");

        let s = store.stats();
        assert_eq!((s.misses, s.evictions, s.rejected, s.hits), (3, 1, 1, 1));
        for (name, want) in [
            ("store/hits", s.hits),
            ("store/misses", s.misses),
            ("store/evictions", s.evictions),
            ("store/rejected", s.rejected),
            ("store/races", s.races),
            ("store/lock_acquisitions", store.lock_acquisitions()),
        ] {
            assert_eq!(reg.counter_value(name), Some(want), "{name}");
        }

        drop(store); // joins the sink's writer: the capture is complete
        let lines = cap.lines();
        assert_eq!(lines.len(), 2, "one evict + one reject:\n{}", cap.text());
        assert_eq!(lines[0].get("kind").and_then(Json::as_str), Some("store_evict"));
        assert_eq!(lines[0].get("key").and_then(Json::as_str), Some("unit-net/b@FI l8 r8"));
        assert_eq!(lines[0].get("bytes").and_then(Json::as_f64), Some(one as f64));
        assert_eq!(lines[1].get("kind").and_then(Json::as_str), Some("store_reject"));
        assert_eq!(
            lines[1].get("bytes").and_then(Json::as_f64),
            Some(StoreEntry::bytes_for(big.len(), &fmt) as f64)
        );
    }

    #[test]
    fn parse_byte_size_grammar() {
        assert_eq!(parse_byte_size("65536").unwrap(), 65536);
        assert_eq!(parse_byte_size("512k").unwrap(), 512 << 10);
        assert_eq!(parse_byte_size("8m").unwrap(), 8 << 20);
        assert_eq!(parse_byte_size("2G").unwrap(), 2 << 30);
        assert_eq!(parse_byte_size(" 16 m ").unwrap(), 16 << 20);
        assert_eq!(parse_byte_size("0").unwrap(), 0);
        for bad in ["", "m", "12q", "-4", "1.5m", "99999999999999999999"] {
            assert!(parse_byte_size(bad).is_err(), "accepted {bad:?}");
        }
        // multi-byte final characters must come back as a typed Err,
        // never a mid-UTF-8 slice panic (ISSUE 8 satellite)
        for bad in ["8µ", "µ", "16µ", "…", "8µb", "8\u{03bc}"] {
            assert!(parse_byte_size(bad).is_err(), "accepted {bad:?}");
        }
    }

    #[test]
    fn human_bytes_rendering() {
        assert_eq!(human_bytes(64), "64B");
        assert_eq!(human_bytes(2048), "2.0KiB");
        assert_eq!(human_bytes(3 << 20), "3.00MiB");
        assert_eq!(human_bytes(5 << 30), "5.00GiB");
    }
}
