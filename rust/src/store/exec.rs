//! Packed-domain execution: GEMM kernels that compute directly from
//! [`PackedTensor`] codes (DESIGN.md §Packed execution).
//!
//! PR 5's store realized the paper's *storage* claim; this module makes
//! the narrow representation the **execution** representation too: the
//! weight stream a kernel reads from memory is the packed bitstream
//! itself, cutting weight-memory traffic by the bit-width ratio the
//! analytical `hw::speedup` model prices (PAPER.md §4,
//! `bench_harness::suite` measures the realized ratio).
//!
//! Two strategies, selected statically per layer by [`route`]:
//!
//! * **Integer MAC chain** ([`gemm_packed_int`]) — fixed formats with
//!   `l + r ≤ 12` whose *activations are on the same grid* run the
//!   whole serial-k chain in i16/i32 grid units with one rescale per
//!   output element; bit-exactness is by the bounds derived in
//!   [`crate::numerics::PackedOp`]'s module docs.
//! * **Decode-LUT MAC** ([`gemm_packed_lut`]) — any format whose code
//!   space is LUT-sized (`width ≤ `[`LUT_MAX_WIDTH`]) decodes each
//!   weight code through a per-format table fused into the f32 MAC
//!   loop; bit-exactness is by the codec contract (`decode ≡
//!   quantize_slice`, pinned by the golden vectors).
//!
//! Everything else — raw-carrier formats, `Format::SINGLE`/direct
//! layers, integer-eligible layers whose upstream activations are NOT
//! on the grid — routes to [`Route::Staged`], the pre-existing f32
//! tier.  **Bit-exactness versus that staged path is the non-negotiable
//! contract**: the router never lets a format that cannot reproduce the
//! serial-k f32 chain reach a packed kernel (`tests/packed_exec.rs`
//! pins the decisions).

use std::sync::Arc;

use crate::formats::{Format, FormatPair};
use crate::numerics::{AccInt, PackedOp, QFixedInt, QuantOp};
use crate::store::PackedTensor;

/// Mirror of the engine's blocking (nn::engine `GEMM_MR`/`GEMM_NC`):
/// the packed kernels tile identically so their per-element serial-k
/// chains — the only order that matters for bit-exactness — line up
/// with `gemm_q`'s, and their cache behaviour is comparable in the
/// bench suite.
const GEMM_MR: usize = 8;
const GEMM_NC: usize = 64;

/// Fixed lane width for the integer MAC inner loop (mirrors the
/// engine's `GEMM_LANES`): the accumulate loop is expressed over
/// `chunks_exact` blocks of this many outputs through a local
/// array-of-lanes, which the optimizer can keep in vector registers —
/// i16 lanes pack 16-wide in a 256-bit register, i32 lanes 8-wide.
/// Divides `GEMM_NC`, so full tiles see no remainder loop.  Per-element
/// op order (`product` then clamped `accumulate`, serial in k) is
/// untouched: lanes are independent output elements.
const INT_LANES: usize = 8;

/// Cap on LUT code width: `2^18` f32 entries = 1 MiB per table — wide
/// enough for the paper's headline `fixed:l8r8` (width 18) while
/// keeping tables L2-resident.
pub const LUT_MAX_WIDTH: u32 = 18;

/// Where one layer's GEMM executes.  Chosen statically at resolve time
/// ([`route`]); formats that cannot meet the bit-exactness contract on
/// a packed lane are routed to [`Route::Staged`], never approximated.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Route {
    /// Integer MAC chain, i16 lanes (`l + r ≤ 7`, on-grid upstream).
    Int16,
    /// Integer MAC chain, i32 lanes (`l + r ≤ 12`, on-grid upstream).
    Int32,
    /// Per-format decode LUT fused into the f32 MAC loop.
    Lut,
    /// The kernel-ready f32 tier (the pre-existing staged path).
    Staged,
}

/// The static router.  `direct` is the engine's identity-staging fast
/// path (`Format::SINGLE` over clean weights — no packed tier exists);
/// `upstream_on_grid` certifies every activation entering the layer is
/// an output of the layer's own quantizer (same grid), the premise the
/// integer chain's exactness proof needs.  Off-grid activations still
/// execute packed — through the LUT lane, whose proof needs nothing
/// from the activations.
pub fn route(fmt: &Format, direct: bool, upstream_on_grid: bool) -> Route {
    if direct {
        return Route::Staged;
    }
    if upstream_on_grid {
        if let Some(op) = PackedOp::for_format(fmt) {
            return match op {
                PackedOp::I16(_) => Route::Int16,
                PackedOp::I32(_) => Route::Int32,
            };
        }
    }
    if PackedTensor::bits_per_value(fmt) <= LUT_MAX_WIDTH {
        Route::Lut
    } else {
        Route::Staged // raw carrier / wider than any feasible LUT
    }
}

/// The router over a weight/activation [`FormatPair`].  A uniform pair
/// is exactly [`route`] (the single-format diagonal).  A split pair can
/// NEVER take an integer lane: the integer chain's exactness proof
/// needs activations staged on the *weight* grid, and a split pair
/// breaks that grid by construction — those layers pin to the LUT lane
/// (whose proof is activation-agnostic: weight codes decode through the
/// `w`-half's table, the f32 MAC chain runs under the `a`-half's
/// quantizer) when the weight code space fits, else to
/// [`Route::Staged`].  Never a silent approximation.
pub fn route_pair(pair: &FormatPair, direct: bool, upstream_on_grid: bool) -> Route {
    if let Some(fmt) = pair.uniform_format() {
        return route(&fmt, direct, upstream_on_grid);
    }
    if direct {
        return Route::Staged;
    }
    if PackedTensor::bits_per_value(&pair.w) <= LUT_MAX_WIDTH {
        Route::Lut
    } else {
        Route::Staged // raw-carrier weight half: no packed tier to read
    }
}

/// One layer's resolved execution strategy — the router's decision plus
/// the artifacts the kernel needs (the integer op, or the decode
/// table).  Carried per quantized layer by `nn::QuantTable` when packed
/// execution is enabled; [`PackedPlan::Staged`] is both the default and
/// the dynamic fallback when the store cannot supply the packed tier.
#[derive(Clone, Debug, Default)]
pub enum PackedPlan {
    /// Execute from the kernel-ready f32 tier.
    #[default]
    Staged,
    /// Integer MAC chain on the packed codes.
    Int(PackedOp),
    /// Decode-LUT MAC on the packed codes.
    Lut(Arc<Vec<f32>>),
}

impl PackedPlan {
    /// Build the plan [`route_pair`] picks for one layer.  `lut`
    /// supplies (and memoizes) the decode table for the **weight** half
    /// when the LUT lane is chosen — tables depend only on the stored
    /// (weight) format, so callers share them across layers and across
    /// activation halves.
    pub fn for_layer(
        pair: &FormatPair,
        direct: bool,
        upstream_on_grid: bool,
        lut: impl FnOnce() -> Arc<Vec<f32>>,
    ) -> PackedPlan {
        match route_pair(pair, direct, upstream_on_grid) {
            Route::Staged => PackedPlan::Staged,
            Route::Int16 | Route::Int32 => {
                // integer routes only exist on the uniform diagonal, so
                // the weight half IS the (single) layer format here
                PackedPlan::Int(PackedOp::for_format(&pair.w).expect("router checked the format"))
            }
            Route::Lut => PackedPlan::Lut(lut()),
        }
    }

    /// Stats/CLI label (`staged` / `int16` / `int32` / `lut`).
    pub fn label(&self) -> &'static str {
        match self {
            PackedPlan::Staged => "staged",
            PackedPlan::Int(op) => op.label(),
            PackedPlan::Lut(_) => "lut",
        }
    }

    pub fn is_staged(&self) -> bool {
        matches!(self, PackedPlan::Staged)
    }
}

/// Integer-lane scratch for one accumulator width.
#[derive(Default)]
pub struct IntLanes<A> {
    /// staged activation grid integers (m × k)
    a: Vec<A>,
    /// decoded weight integers for the current n-tile (k × nw)
    wblk: Vec<A>,
    /// staged bias grid integers (n)
    bias: Vec<A>,
}

/// Reusable scratch for the packed kernels — owned by the engine so a
/// warm forward allocates nothing (the `act_a`/`wq` discipline).
#[derive(Default)]
pub struct ExecScratch {
    i16: IntLanes<i16>,
    i32: IntLanes<i32>,
    /// decoded f32 weights for the current n-tile (k × nw) — LUT lane
    wblk_f: Vec<f32>,
    /// quantized bias (n) — LUT lane epilogue (`add_bias_q` semantics)
    bias_f: Vec<f32>,
}

/// Selects the scratch lane matching an accumulator width — the
/// `ExecScratch` end of [`AccInt`] (kept here so `numerics` stays
/// independent of the store).
pub trait HasLanes: AccInt {
    fn lanes(s: &mut ExecScratch) -> &mut IntLanes<Self>
    where
        Self: Sized;
}

impl HasLanes for i16 {
    fn lanes(s: &mut ExecScratch) -> &mut IntLanes<i16> {
        &mut s.i16
    }
}

impl HasLanes for i32 {
    fn lanes(s: &mut ExecScratch) -> &mut IntLanes<i32> {
        &mut s.i32
    }
}

/// The integer MAC kernel: `out[m × n] = a[m × k] · w[k × n]` (+ bias)
/// computed entirely in grid units from the packed bitstream, one
/// rescale per output element.  Bit-exact to `gemm_q` + `add_bias_q`
/// over the same operands **when** `a` is on the format's grid and
/// `l + r ≤ 12` — the router's premises ([`route`]); the arithmetic
/// stays in `A` throughout, so debug builds verify the width bounds.
///
/// `a` values of exactly zero skip their inner loop: `clamp(acc + 0) ==
/// acc` is an identity in grid units (`|acc| ≤ M` is an invariant), so
/// the skip is bit-free — unlike in the f32 chain, where proving
/// `q(acc + q(av·wv))` inert requires reasoning about signed zeros.
pub fn gemm_packed_int<A: HasLanes>(
    a: &[f32],
    w: &PackedTensor,
    bias: Option<&[f32]>,
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    op: &QFixedInt<A>,
    scratch: &mut ExecScratch,
) {
    debug_assert_eq!(w.len(), k * n, "packed weight shape");
    debug_assert!(a.len() >= m * k && out.len() >= m * n);
    let lanes = A::lanes(scratch);
    // stage activations to grid integers once per call (exact: the
    // router guarantees they are outputs of this layer's quantizer)
    lanes.a.clear();
    lanes.a.extend(a[..m * k].iter().map(|&x| op.stage(x)));
    lanes.bias.clear();
    if let Some(b) = bias {
        // add_bias_q's "quantize the bias once" staging, in grid units
        lanes.bias.extend(b[..n].iter().map(|&x| op.stage_rounded(x)));
    }
    for n0 in (0..n).step_by(GEMM_NC) {
        let nw = GEMM_NC.min(n - n0);
        // decode this k × nw code block once; this bitstream read is
        // the kernel's only weight-memory traffic
        lanes.wblk.clear();
        for ki in 0..k {
            let row = ki * n + n0;
            lanes
                .wblk
                .extend((row..row + nw).map(|i| A::from_i64(w.fixed_int_at(i))));
        }
        for m0 in (0..m).step_by(GEMM_MR) {
            let mh = GEMM_MR.min(m - m0);
            let mut acc = [[A::ZERO; GEMM_NC]; GEMM_MR];
            for ki in 0..k {
                let wrow = &lanes.wblk[ki * nw..ki * nw + nw];
                for (mi, arow) in acc.iter_mut().enumerate().take(mh) {
                    let av = lanes.a[(m0 + mi) * k + ki];
                    if av == A::ZERO {
                        continue; // exact: clamp(acc + 0) == acc
                    }
                    // array-of-lanes accumulate: same per-element op
                    // sequence, expressed in fixed-width blocks the
                    // optimizer vectorizes (lanes are independent
                    // output elements; k stays serial per element)
                    let mut oc = arow[..nw].chunks_exact_mut(INT_LANES);
                    let mut wc = wrow.chunks_exact(INT_LANES);
                    for (ol, wl) in (&mut oc).zip(&mut wc) {
                        let mut prod = [A::ZERO; INT_LANES];
                        for j in 0..INT_LANES {
                            prod[j] = op.product(av, wl[j]);
                        }
                        for j in 0..INT_LANES {
                            ol[j] = op.accumulate(ol[j], prod[j]);
                        }
                    }
                    for (o, &wv) in oc.into_remainder().iter_mut().zip(wc.remainder()) {
                        *o = op.accumulate(*o, op.product(av, wv));
                    }
                }
            }
            for mi in 0..mh {
                let off = (m0 + mi) * n + n0;
                for (j, o) in out[off..off + nw].iter_mut().enumerate() {
                    let mut v = acc[mi][j];
                    if !lanes.bias.is_empty() {
                        v = op.accumulate(v, lanes.bias[n0 + j]);
                    }
                    *o = op.finish(v);
                }
            }
        }
    }
}

/// Scalar reference for [`gemm_packed_int`]: the identical grid-unit
/// serial-k chain, one output element at a time — no tiling, no lane
/// chunking, weights decoded on every access.  Exists as the
/// denominator of the `packed_int_simd_over_scalar/<lane>` bench ratio
/// and as the differential oracle for the lane-chunked kernel; the
/// engine never calls it.
#[allow(clippy::too_many_arguments)]
pub fn gemm_packed_int_scalar<A: HasLanes>(
    a: &[f32],
    w: &PackedTensor,
    bias: Option<&[f32]>,
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    op: &QFixedInt<A>,
    scratch: &mut ExecScratch,
) {
    debug_assert_eq!(w.len(), k * n, "packed weight shape");
    debug_assert!(a.len() >= m * k && out.len() >= m * n);
    let lanes = A::lanes(scratch);
    lanes.a.clear();
    lanes.a.extend(a[..m * k].iter().map(|&x| op.stage(x)));
    lanes.bias.clear();
    if let Some(b) = bias {
        lanes.bias.extend(b[..n].iter().map(|&x| op.stage_rounded(x)));
    }
    for mi in 0..m {
        for ni in 0..n {
            let mut acc = A::ZERO;
            for ki in 0..k {
                let av = lanes.a[mi * k + ki];
                if av == A::ZERO {
                    continue; // exact: clamp(acc + 0) == acc
                }
                let wv = A::from_i64(w.fixed_int_at(ki * n + ni));
                acc = op.accumulate(acc, op.product(av, wv));
            }
            if !lanes.bias.is_empty() {
                acc = op.accumulate(acc, lanes.bias[ni]);
            }
            out[mi * n + ni] = op.finish(acc);
        }
    }
}

/// The decode-LUT kernel: the same blocked serial-k f32 chain as
/// `gemm_q` + `add_bias_q`, but each weight is read as its narrow code
/// and decoded through `lut` (`lut[code]` is bit-exact to the staged
/// f32 weight by the codec contract) — so the result is bit-identical
/// to the staged path for ANY format and ANY activations, on-grid or
/// not.  No zero-skip here: the f32 chain's signed-zero algebra is kept
/// exactly as `gemm_q` runs it.
pub fn gemm_packed_lut<Q: QuantOp>(
    a: &[f32],
    w: &PackedTensor,
    lut: &[f32],
    bias: Option<&[f32]>,
    out: &mut [f32],
    m: usize,
    k: usize,
    n: usize,
    q: &Q,
    scratch: &mut ExecScratch,
) {
    debug_assert_eq!(w.len(), k * n, "packed weight shape");
    debug_assert!(a.len() >= m * k && out.len() >= m * n);
    debug_assert_eq!(lut.len(), 1usize << w.width(), "LUT covers the code space");
    scratch.bias_f.clear();
    if let Some(b) = bias {
        scratch.bias_f.extend(b[..n].iter().map(|&x| q.q(x)));
    }
    for n0 in (0..n).step_by(GEMM_NC) {
        let nw = GEMM_NC.min(n - n0);
        scratch.wblk_f.clear();
        for ki in 0..k {
            let row = ki * n + n0;
            scratch
                .wblk_f
                .extend((row..row + nw).map(|i| lut[w.code_at(i) as usize]));
        }
        for m0 in (0..m).step_by(GEMM_MR) {
            let mh = GEMM_MR.min(m - m0);
            for mi in 0..mh {
                let off = (m0 + mi) * n + n0;
                out[off..off + nw].fill(0.0);
            }
            for ki in 0..k {
                let wrow = &scratch.wblk_f[ki * nw..ki * nw + nw];
                for mi in 0..mh {
                    let av = a[(m0 + mi) * k + ki];
                    let off = (m0 + mi) * n + n0;
                    for (o, &wv) in out[off..off + nw].iter_mut().zip(wrow) {
                        *o = q.q(*o + q.q(av * wv));
                    }
                }
            }
            if !scratch.bias_f.is_empty() {
                for mi in 0..mh {
                    let off = (m0 + mi) * n + n0;
                    for (j, o) in out[off..off + nw].iter_mut().enumerate() {
                        *o = q.q(*o + scratch.bias_f[n0 + j]);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::{quantize_slice, Quantizer};
    use crate::testing::prop::{arb_format, run_prop, Gen};
    use crate::with_packed_op;

    /// The staged-f32 reference chain the kernels must reproduce:
    /// serial increasing-k `q(acc + q(a·w))` per output element, then
    /// the `add_bias_q` step — `gemm_q`'s pinned semantics.
    fn reference(
        a: &[f32],
        wq: &[f32],
        bias: Option<&[f32]>,
        m: usize,
        k: usize,
        n: usize,
        q: &Quantizer,
    ) -> Vec<f32> {
        let mut out = vec![0.0f32; m * n];
        for mi in 0..m {
            for ni in 0..n {
                let mut acc = 0.0f32;
                for ki in 0..k {
                    acc = q.q(acc + q.q(a[mi * k + ki] * wq[ki * n + ni]));
                }
                if let Some(b) = bias {
                    acc = q.q(acc + q.q(b[ni]));
                }
                out[mi * n + ni] = acc;
            }
        }
        out
    }

    fn assert_bits(got: &[f32], want: &[f32], ctx: &str) {
        assert_eq!(got.len(), want.len(), "{ctx}");
        for i in 0..want.len() {
            assert_eq!(
                got[i].to_bits(),
                want[i].to_bits(),
                "{ctx} elem {i}: {} vs {}",
                got[i],
                want[i]
            );
        }
    }

    #[test]
    fn router_decision_table() {
        use Route::*;
        for (fmt, direct, upstream, want) in [
            // integer lanes: fixed, on-grid upstream, l + r thresholds
            ("fixed:l0r2", false, true, Int16),
            ("fixed:l1r3", false, true, Int16),
            ("fixed:l3r3", false, true, Int16),
            ("fixed:l4r4", false, true, Int32),
            ("fixed:l12r0", false, true, Int32),
            // off-grid upstream: integer premise fails → LUT
            ("fixed:l0r2", false, false, Lut),
            ("fixed:l4r4", false, false, Lut),
            // t > 12 never integer; width ≤ 18 → LUT either way
            ("fixed:l8r8", false, true, Lut),
            ("fixed:l12r2", false, true, Lut),
            ("fixed:l2r12", false, false, Lut),
            // floats: LUT when the code space fits
            ("float:m0e5", false, true, Lut),
            ("float:m7e6", false, false, Lut),
            ("float:m10e3", false, true, Lut),
            // statically staged: raw carrier, 32-bit codes, direct
            ("float:m23e8", false, true, Staged),
            ("fixed:l16r16", false, true, Staged),
            ("fixed:l30r30", false, false, Staged),
            ("float:m7e6", true, true, Staged),
            ("float:m23e8", true, true, Staged),
        ] {
            let f = Format::parse(fmt).unwrap();
            let got = route(&f, direct, upstream);
            assert_eq!(got, want, "{fmt} direct={direct} upstream={upstream}");
        }
    }

    /// The split-pair router: mixed (w, a) pairs may NEVER take an
    /// integer lane, even when both halves alone are integer-eligible —
    /// they pin to LUT (weight codes fit) or Staged (raw carrier),
    /// never a silent approximation.  Uniform pairs reproduce the
    /// single-format table above exactly.
    #[test]
    fn router_split_pair_decision_table() {
        use Route::*;
        for (spec, direct, upstream, want) in [
            // both halves integer-eligible alone — still never Int
            ("w:fixed:l1r3+a:fixed:l2r2", false, true, Lut),
            ("w:fixed:l4r4+a:fixed:l1r3", false, true, Lut),
            // mixed-kind pairs: routed by the weight half's code width
            ("w:float:m7e6+a:fixed:l4r8", false, true, Lut),
            ("w:fixed:l8r8+a:float:m7e6", false, false, Lut),
            ("w:float:m4e5+a:float:m10e6", false, true, Lut),
            // raw-carrier weight half: no packed tier to read
            ("w:float:m23e8+a:fixed:l4r4", false, true, Staged),
            ("w:fixed:l16r16+a:float:m7e6", false, true, Staged),
            // a LUT-sized weight half with a raw-carrier ACTIVATION half
            // is fine — only the weight half is read from codes
            ("w:fixed:l4r4+a:float:m23e8", false, true, Lut),
            // direct always wins
            ("w:float:m4e5+a:fixed:l4r8", true, true, Staged),
        ] {
            let p = FormatPair::parse(spec).unwrap();
            let got = route_pair(&p, direct, upstream);
            assert_eq!(got, want, "{spec} direct={direct} upstream={upstream}");
        }
        // the uniform diagonal IS `route` — every single-format decision
        // is unchanged when spelled as a pair
        for fmt in crate::formats::design_space(3) {
            for direct in [false, true] {
                for upstream in [false, true] {
                    assert_eq!(
                        route_pair(&FormatPair::uniform(fmt), direct, upstream),
                        route(&fmt, direct, upstream),
                        "{} direct={direct} upstream={upstream}",
                        fmt.id()
                    );
                }
            }
        }
    }

    #[test]
    fn plan_labels_follow_routes() {
        let lut = |p: &FormatPair| {
            let w = p.w;
            move || Arc::new(PackedTensor::decode_table(&w, LUT_MAX_WIDTH).unwrap())
        };
        for (spec, upstream, want) in [
            ("fixed:l1r3", true, "int16"),
            ("fixed:l4r4", true, "int32"),
            ("fixed:l8r8", true, "lut"),
            ("float:m7e6", true, "lut"),
            ("float:m23e8", true, "staged"),
            ("fixed:l16r16", true, "staged"),
            // split pairs: integer-eligible halves still land on lut
            ("w:fixed:l1r3+a:fixed:l2r2", true, "lut"),
            ("w:float:m7e6+a:fixed:l4r8", true, "lut"),
            ("w:float:m23e8+a:fixed:l4r4", true, "staged"),
        ] {
            let p = FormatPair::parse(spec).unwrap();
            let plan = PackedPlan::for_layer(&p, false, upstream, lut(&p));
            assert_eq!(plan.label(), want, "{spec}");
        }
        let single = FormatPair::uniform(Format::SINGLE);
        assert!(PackedPlan::for_layer(&single, true, true, || unreachable!()).is_staged());
    }

    /// Both kernels against the serial-k reference across random
    /// shapes, formats, and operand distributions — ragged tiles
    /// included (m, n, k straddle the 8/64 blocking).
    #[test]
    fn prop_packed_kernels_bitexact_vs_reference() {
        run_prop("packed_kernels_vs_reference", 120, |g| {
            let fmt = arb_format(g);
            let q = Quantizer::new(&fmt);
            let (m, k, n) = (g.usize_in(1, 17), g.usize_in(1, 40), g.usize_in(1, 70));
            // activations ON the grid (the integer lane's premise); the
            // LUT lane must hold for off-grid too — exercised at the
            // engine level, where inputs are staged by a DIFFERENT
            // layer's quantizer
            let mut a: Vec<f32> = (0..m * k).map(|_| g.f32_normal() * 4.0).collect();
            quantize_slice(&mut a, &q);
            let wraw: Vec<f32> = (0..k * n).map(|_| g.f32_normal() * 2.0).collect();
            let bias: Vec<f32> = (0..n).map(|_| g.f32_normal()).collect();
            let packed = PackedTensor::pack(&wraw, &fmt);
            let mut wq = wraw.clone();
            quantize_slice(&mut wq, &q);
            let want = reference(&a, &wq, Some(&bias), m, k, n, &q);

            let mut scratch = ExecScratch::default();
            let mut out = vec![0.0f32; m * n];
            match route(&fmt, false, true) {
                Route::Int16 | Route::Int32 => {
                    let op = PackedOp::for_format(&fmt).unwrap();
                    with_packed_op!(&op, o => gemm_packed_int(
                        &a, &packed, Some(&bias), &mut out, m, k, n, o, &mut scratch,
                    ));
                    assert_bits(&out, &want, &format!("{} int", fmt.id()));
                    // the untiled scalar reference must agree bit-for-bit
                    // with both the f32 chain and the lane-chunked kernel
                    let mut out_s = vec![0.0f32; m * n];
                    with_packed_op!(&op, o => gemm_packed_int_scalar(
                        &a, &packed, Some(&bias), &mut out_s, m, k, n, o, &mut scratch,
                    ));
                    assert_bits(&out_s, &want, &format!("{} int scalar", fmt.id()));
                }
                Route::Lut => {}
                Route::Staged => return, // raw carrier: no packed lane
            }
            // every LUT-sized format also runs the LUT lane
            if let Some(lut) = PackedTensor::decode_table(&fmt, LUT_MAX_WIDTH) {
                let mut out = vec![0.0f32; m * n];
                gemm_packed_lut(
                    &a, &packed, &lut, Some(&bias), &mut out, m, k, n, &q, &mut scratch,
                );
                assert_bits(&out, &want, &format!("{} lut", fmt.id()));
            }
        });
    }

    /// The zero-skip is exact: activation rows dominated by ±0.0
    /// (including -0.0, which survives relu) change nothing.
    #[test]
    fn int_kernel_zero_skip_is_exact() {
        let fmt = Format::fixed(4, 4);
        let q = Quantizer::new(&fmt);
        let (m, k, n) = (3, 9, 5);
        let mut a = vec![0.0f32; m * k];
        a[4] = -0.0;
        a[9] = 1.5;
        a[20] = -0.0625;
        let wraw: Vec<f32> = (0..k * n).map(|i| ((i * 7 % 13) as f32 - 6.0) * 0.75).collect();
        let packed = PackedTensor::pack(&wraw, &fmt);
        let mut wq = wraw.clone();
        quantize_slice(&mut wq, &q);
        let want = reference(&a, &wq, None, m, k, n, &q);
        let op = PackedOp::for_format(&fmt).unwrap();
        let mut out = vec![0.0f32; m * n];
        with_packed_op!(&op, o => gemm_packed_int(
            &a, &packed, None, &mut out, m, k, n, o, &mut ExecScratch::default(),
        ));
        assert_bits(&out, &want, "zero-skip");
    }

    /// Saturation pressure at both lane boundaries: all-max operands
    /// drive every intermediate to its peak (the debug-build overflow
    /// proof) and the chain must still match the f32 reference exactly.
    #[test]
    fn int_kernel_worst_case_magnitude_at_lane_boundaries() {
        for (l, r) in [(7u32, 0u32), (0, 7), (6, 6), (0, 12), (12, 0)] {
            let fmt = Format::fixed(l, r);
            let q = Quantizer::new(&fmt);
            let max = q.q(f32::MAX);
            let (m, k, n) = (2, 130, 3);
            let a = vec![max; m * k];
            let wraw: Vec<f32> = (0..k * n)
                .map(|i| if i % 2 == 0 { max } else { -max })
                .collect();
            let packed = PackedTensor::pack(&wraw, &fmt);
            let mut wq = wraw.clone();
            quantize_slice(&mut wq, &q);
            let bias = vec![max; n];
            let want = reference(&a, &wq, Some(&bias), m, k, n, &q);
            let op = PackedOp::for_format(&fmt).unwrap();
            let mut out = vec![0.0f32; m * n];
            with_packed_op!(&op, o => gemm_packed_int(
                &a, &packed, Some(&bias), &mut out, m, k, n, o, &mut ExecScratch::default(),
            ));
            assert_bits(&out, &want, &format!("fixed:l{l}r{r} worst case"));
        }
    }

    /// LUT lane with OFF-grid activations (a coarser upstream grid than
    /// the layer's own): the integer premise fails here, the LUT proof
    /// does not need it.
    #[test]
    fn lut_kernel_handles_off_grid_activations() {
        let fmt = Format::float(4, 4);
        let q = Quantizer::new(&fmt);
        let (m, k, n) = (4, 11, 6);
        // raw, unquantized activations — deliberately off every grid
        let a: Vec<f32> = (0..m * k).map(|i| ((i as f32) * 0.731).sin() * 3.3).collect();
        let wraw: Vec<f32> = (0..k * n).map(|i| ((i as f32) * 0.517).cos()).collect();
        let packed = PackedTensor::pack(&wraw, &fmt);
        let mut wq = wraw.clone();
        quantize_slice(&mut wq, &q);
        let bias: Vec<f32> = (0..n).map(|i| i as f32 * 0.21 - 0.5).collect();
        let want = reference(&a, &wq, Some(&bias), m, k, n, &q);
        let lut = PackedTensor::decode_table(&fmt, LUT_MAX_WIDTH).unwrap();
        let mut out = vec![0.0f32; m * n];
        gemm_packed_lut(
            &a, &packed, &lut, Some(&bias), &mut out, m, k, n, &q, &mut ExecScratch::default(),
        );
        assert_bits(&out, &want, "off-grid lut");
    }

    /// Scratch reuse across calls of different shapes leaves no stale
    /// state behind (the engine holds ONE ExecScratch across layers).
    #[test]
    fn scratch_reuse_across_shapes_and_lanes() {
        let mut scratch = ExecScratch::default();
        let mut g = Gen::new(0xacc, 1.0);
        for case in 0..12 {
            let fmt = if case % 2 == 0 {
                Format::fixed(3, 3)
            } else {
                Format::fixed(5, 5)
            };
            let q = Quantizer::new(&fmt);
            let (m, k, n) = (g.usize_in(1, 9), g.usize_in(1, 30), g.usize_in(1, 80));
            let mut a: Vec<f32> = (0..m * k).map(|_| g.f32_normal() * 3.0).collect();
            quantize_slice(&mut a, &q);
            let wraw: Vec<f32> = (0..k * n).map(|_| g.f32_normal()).collect();
            let packed = PackedTensor::pack(&wraw, &fmt);
            let mut wq = wraw.clone();
            quantize_slice(&mut wq, &q);
            let want = reference(&a, &wq, None, m, k, n, &q);
            let op = PackedOp::for_format(&fmt).unwrap();
            let mut out = vec![0.0f32; m * n];
            with_packed_op!(&op, o => gemm_packed_int(
                &a, &packed, None, &mut out, m, k, n, o, &mut scratch,
            ));
            assert_bits(&out, &want, &format!("reuse case {case}"));
            // interleave a LUT call over the same scratch
            let lut = PackedTensor::decode_table(&fmt, LUT_MAX_WIDTH).unwrap();
            let mut out2 = vec![0.0f32; m * n];
            gemm_packed_lut(&a, &packed, &lut, None, &mut out2, m, k, n, &q, &mut scratch);
            assert_bits(&out2, &want, &format!("reuse lut case {case}"));
        }
    }
}
