//! `PackedTensor` — the bit-packed narrow-width weight encoding.
//!
//! The paper's efficiency argument is about *storage*, not only MAC
//! cost: a custom-width value occupies `total_bits()` bits, so moving
//! weights at the format's own width cuts memory traffic
//! proportionally (PAPER.md §4).  This module makes that claim concrete
//! in software: a [`PackedTensor`] holds one quantized tensor as a
//! contiguous bitstream of fixed-width codes whose decode is **bit-exact
//! to [`quantize_slice`]** — pinned against the normative `qformat.py`
//! by replaying the 470 golden vectors through the codec
//! (`rust/tests/golden_quant.rs`) and property-tested across the whole
//! design surface.
//!
//! # Code layouts (DESIGN.md §Storage)
//!
//! Every value becomes one unsigned `width`-bit code; the three layouts
//! are selected per [`Format`]:
//!
//! * **Float `F(m, e)`** — `width = 1 + ebits + m`, fields (MSB→LSB)
//!   `sign | exponent-code | mantissa`.  The exponent code enumerates
//!   the format's *f32-reachable* exponents `E ∈ [emin, emax]`
//!   (carrier-clamped, so `e = 8` spans only `[-126, 127]`):
//!   code `0` is zero, code `E - emin + 1` a normal value, and the top
//!   code `SAT = span + 1` the saturation value `max_value()` — needed
//!   because the carrier-clamped `max` of an `e = 8` format is
//!   `f32::MAX`, whose 23-bit mantissa does not fit in `m` bits.
//!   `ebits` is the bit-length of `SAT`, so `width ≤ 32` always
//!   (`float:m23e8` packs at exactly the carrier's 32 bits).
//! * **Fixed `X(l, r)`, `l + r + 2 < 32`** — `width = l + r + 2`
//!   two's-complement codes of the scaled integer `k = y · 2^r`
//!   (`|k| ≤ 2^(l+r)`: the `+2` covers the sign and the carry the
//!   f32 carrier's 24-bit mantissa can round `2^(l+r) - 1` up to).
//!   The unused most-negative code `-2^(width-1)` is the `-0.0`
//!   sentinel — quantization preserves the sign of zero
//!   (`q(-0.25) = -0.0` under `X(l, 1)`), and two's complement has no
//!   negative zero of its own.
//! * **Raw carrier** — formats at least as wide as the carrier
//!   (`l + r + 2 ≥ 32`) store the f32 bits verbatim at `width = 32`:
//!   packing *wider* than the carrier would expand the tensor, and the
//!   carrier already is the exact storage of the quantized value.
//!
//! # Bitstream
//!
//! Code `i` occupies bits `[i·width, (i+1)·width)` of a little-endian
//! bitstream over `u64` words: bit `b` lives in `words[b / 64]` at bit
//! position `b % 64`, and codes are written LSB-first (a code may
//! straddle two words).  The layout is pinned by
//! `packed_layout_is_stable` below.
//!
//! Packing is defined over **finite** inputs (network weights; the
//! quantizers map every finite input to a finite grid point).  NaN is
//! not representable in any code space and is rejected by a
//! `debug_assert` in [`PackedTensor::pack`].

use crate::formats::Format;
use crate::numerics::{quantize_slice, Quantizer};

/// One quantized tensor, stored as fixed-width codes in a contiguous
/// `u64` bitstream (see the module docs for the code layouts).
#[derive(Clone, Debug, PartialEq)]
pub struct PackedTensor {
    fmt: Format,
    len: usize,
    width: u32,
    words: Vec<u64>,
}

/// The per-format code layout, resolved once per pack/unpack.
enum Codec {
    /// sign | exponent-code | m-bit mantissa (see module docs)
    Float { emin: i32, sat: u32, ebits: u32, m: u32, max_bits: u32 },
    /// two's-complement `y · 2^r` with a `-0.0` sentinel
    Fixed { width: u32, scale: f64, inv_scale: f64 },
    /// the f32 carrier bits verbatim (width 32)
    Raw,
}

impl Codec {
    fn of(fmt: &Format) -> Codec {
        match *fmt {
            Format::Float { mantissa, exponent } => {
                let bias = fmt.bias();
                let emin = (-bias).max(-126);
                let emax = ((1i32 << exponent) - 1 - bias).min(127);
                let sat = (emax - emin + 2) as u32; // span + 1
                let ebits = 32 - sat.leading_zeros();
                Codec::Float {
                    emin,
                    sat,
                    ebits,
                    m: mantissa,
                    max_bits: (fmt.max_value() as f32).to_bits(),
                }
            }
            Format::Fixed { int_bits, frac_bits } => {
                let width = 2 + int_bits + frac_bits;
                if width >= 32 {
                    Codec::Raw
                } else {
                    let scale = 2.0f64.powi(frac_bits as i32);
                    Codec::Fixed { width, scale, inv_scale: 1.0 / scale }
                }
            }
        }
    }

    fn width(&self) -> u32 {
        match *self {
            Codec::Float { ebits, m, .. } => 1 + ebits + m,
            Codec::Fixed { width, .. } => width,
            Codec::Raw => 32,
        }
    }

    /// Encode one value that is already on the format's grid (an output
    /// of the format's quantizer).
    fn encode(&self, y: f32) -> u64 {
        match *self {
            Codec::Float { emin, sat, ebits, m, max_bits } => {
                let bits = y.to_bits();
                let sign = (bits >> 31) as u64;
                let mag = bits & 0x7FFF_FFFF;
                let (ecode, mant) = if mag == 0 {
                    (0u64, 0u64)
                } else if mag == max_bits {
                    // the saturation value — under an e=8 carrier clamp
                    // its mantissa is wider than m bits, so it gets the
                    // dedicated top code
                    (sat as u64, 0u64)
                } else {
                    let e = (mag >> 23) as i32 - 127;
                    // emax = emin + span - 1 = emin + sat - 2
                    debug_assert!(
                        e >= emin && e <= emin + sat as i32 - 2,
                        "exponent {e} outside the format range"
                    );
                    let mant23 = (mag & 0x7F_FFFF) as u64;
                    debug_assert_eq!(
                        mant23 & ((1u64 << (23 - m)) - 1),
                        0,
                        "mantissa carries sub-grid bits"
                    );
                    ((e - emin + 1) as u64, mant23 >> (23 - m))
                };
                (sign << (ebits + m)) | (ecode << m) | mant
            }
            Codec::Fixed { width, scale, .. } => {
                if y == 0.0 {
                    return if y.is_sign_negative() { 1u64 << (width - 1) } else { 0 };
                }
                // y = k·2^-r exactly, so this recovers the integer k
                // exactly in f64 (no rounding for width < 32)
                let k = (y as f64 * scale).round() as i64;
                debug_assert!(k.unsigned_abs() <= 1u64 << (width - 2), "code {k} out of range");
                (k as u64) & ((1u64 << width) - 1)
            }
            Codec::Raw => y.to_bits() as u64,
        }
    }

    fn decode(&self, code: u64) -> f32 {
        match *self {
            Codec::Float { emin, sat, ebits, m, max_bits } => {
                let sign = ((code >> (ebits + m)) & 1) as u32;
                let ecode = ((code >> m) & ((1u64 << ebits) - 1)) as u32;
                let mant = (code & ((1u64 << m) - 1)) as u32;
                let mag = if ecode == 0 {
                    0
                } else if ecode == sat {
                    max_bits
                } else {
                    let e = emin + ecode as i32 - 1;
                    (((e + 127) as u32) << 23) | (mant << (23 - m))
                };
                f32::from_bits((sign << 31) | mag)
            }
            Codec::Fixed { width, inv_scale, .. } => {
                let sign_bit = 1u64 << (width - 1);
                if code == sign_bit {
                    return -0.0;
                }
                let k = if code & sign_bit != 0 {
                    (code | !((1u64 << width) - 1)) as i64 // sign-extend
                } else {
                    code as i64
                };
                (k as f64 * inv_scale) as f32
            }
            Codec::Raw => f32::from_bits(code as u32),
        }
    }
}

impl PackedTensor {
    /// Storage bits per value under `fmt` (the module-docs layout).
    pub fn bits_per_value(fmt: &Format) -> u32 {
        Codec::of(fmt).width()
    }

    /// Exact packed size of a `len`-value tensor under `fmt`, in bytes
    /// (`⌈len · width / 8⌉`) — computable without packing, which is how
    /// the store's admission check prices an entry before building it.
    pub fn packed_bytes_for(len: usize, fmt: &Format) -> usize {
        (len * Self::bits_per_value(fmt) as usize).div_ceil(8)
    }

    /// Quantize `data` under `fmt` and pack the result — one
    /// [`quantize_slice`] (the identical op the engine's staging path
    /// runs) followed by the encode pass.
    pub fn pack(data: &[f32], fmt: &Format) -> PackedTensor {
        let mut q = data.to_vec();
        quantize_slice(&mut q, &Quantizer::new(fmt));
        Self::pack_quantized(&q, fmt)
    }

    /// Pack values that are **already** on `fmt`'s grid (outputs of the
    /// format's quantizer — [`PackedTensor::pack`] quantizes for you).
    pub fn pack_quantized(values: &[f32], fmt: &Format) -> PackedTensor {
        let codec = Codec::of(fmt);
        let width = codec.width();
        let mut words = vec![0u64; (values.len() * width as usize).div_ceil(64)];
        for (i, &v) in values.iter().enumerate() {
            debug_assert!(!v.is_nan(), "NaN is not packable (module docs)");
            let code = codec.encode(v);
            let bit = i * width as usize;
            let (w, off) = (bit / 64, (bit % 64) as u32);
            words[w] |= code << off;
            if off + width > 64 {
                words[w + 1] |= code >> (64 - off);
            }
        }
        PackedTensor { fmt: *fmt, len: values.len(), width, words }
    }

    /// Decode into `out` (cleared first).  Bit-exact to running
    /// [`quantize_slice`] over the tensor [`PackedTensor::pack`] was
    /// given.
    pub fn unpack_into(&self, out: &mut Vec<f32>) {
        let codec = Codec::of(&self.fmt);
        let width = self.width;
        let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
        out.clear();
        out.reserve(self.len);
        for i in 0..self.len {
            let bit = i * width as usize;
            let (w, off) = (bit / 64, (bit % 64) as u32);
            let mut code = self.words[w] >> off;
            if off + width > 64 {
                code |= self.words[w + 1] << (64 - off);
            }
            out.push(codec.decode(code & mask));
        }
    }

    /// Decode into a fresh vector (see [`PackedTensor::unpack_into`]).
    pub fn unpack(&self) -> Vec<f32> {
        let mut out = Vec::new();
        self.unpack_into(&mut out);
        out
    }

    /// The raw `width`-bit code of element `i` (the module-docs
    /// bitstream layout) — the read the packed-domain kernels fuse into
    /// their MAC loops (store::exec): the weight stream they pull from
    /// memory is this bitstream, not the f32 tier.
    #[inline]
    pub fn code_at(&self, i: usize) -> u64 {
        debug_assert!(i < self.len);
        let width = self.width;
        let mask = if width == 64 { u64::MAX } else { (1u64 << width) - 1 };
        let bit = i * width as usize;
        let (w, off) = (bit / 64, (bit % 64) as u32);
        let mut code = self.words[w] >> off;
        if off + width > 64 {
            code |= self.words[w + 1] << (64 - off);
        }
        code & mask
    }

    /// Fixed-codec tensors only: element `i` as its two's-complement
    /// grid integer `k = y · 2^r` (the `-0.0` sentinel is numerically
    /// 0).  The packed-int execution lane streams weights through this.
    #[inline]
    pub fn fixed_int_at(&self, i: usize) -> i64 {
        debug_assert!(
            matches!(Codec::of(&self.fmt), Codec::Fixed { .. }),
            "fixed_int_at on a {} tensor",
            self.fmt.id()
        );
        let code = self.code_at(i);
        let width = self.width;
        let sign_bit = 1u64 << (width - 1);
        if code & sign_bit == 0 {
            code as i64
        } else if code == sign_bit {
            0 // the -0.0 sentinel: numerically zero
        } else {
            (code | !((1u64 << width) - 1)) as i64 // sign-extend
        }
    }

    /// The full `code → value` decode table for `fmt`, when the code
    /// space is LUT-sized (`width ≤ max_width`, and not the raw-carrier
    /// layout, whose 2^32 codes never are): `table[code]` is bit-exact
    /// to [`PackedTensor::unpack`] of that code by construction.  Codes
    /// the encoder never emits decode to unspecified (harmless,
    /// unreachable) values.
    pub fn decode_table(fmt: &Format, max_width: u32) -> Option<Vec<f32>> {
        let codec = Codec::of(fmt);
        if matches!(codec, Codec::Raw) || codec.width() > max_width {
            return None;
        }
        Some((0u64..1u64 << codec.width()).map(|c| codec.decode(c)).collect())
    }

    pub fn fmt(&self) -> &Format {
        &self.fmt
    }

    /// Number of encoded values.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bits per code in this tensor's layout.
    pub fn width(&self) -> u32 {
        self.width
    }

    /// Packed storage footprint in bytes (`⌈len · width / 8⌉`).
    pub fn packed_bytes(&self) -> usize {
        (self.len * self.width as usize).div_ceil(8)
    }

    /// The f32-carrier footprint the packing is measured against.
    pub fn f32_bytes(&self) -> usize {
        self.len * 4
    }

    /// The raw bitstream words (layout in the module docs).
    pub fn words(&self) -> &[u64] {
        &self.words
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::prop::{arb_format, run_prop, Gen};

    fn roundtrip_matches_quantize(data: &[f32], fmt: &Format) {
        let mut want = data.to_vec();
        quantize_slice(&mut want, &Quantizer::new(fmt));
        let packed = PackedTensor::pack(data, fmt);
        assert_eq!(packed.len(), data.len());
        let got = packed.unpack();
        for i in 0..want.len() {
            assert_eq!(
                got[i].to_bits(),
                want[i].to_bits(),
                "{} elem {i}: decode {} vs quantize {}",
                fmt.id(),
                got[i],
                want[i]
            );
        }
    }

    /// The width table of the golden-vector formats — pins the layout
    /// rules (float `1 + ebits + m`, fixed `l + r + 2`, raw ≥ 32).
    #[test]
    fn bits_per_value_layout_table() {
        for (fmt, width) in [
            ("fixed:l0r2", 4),
            ("fixed:l1r3", 6),
            ("fixed:l4r4", 10),
            ("fixed:l8r8", 18),
            ("fixed:l12r2", 16),
            ("fixed:l2r12", 16),
            ("float:m0e5", 7),
            ("float:m1e2", 5),
            ("float:m2e8", 11),
            ("float:m4e4", 10),
            ("float:m7e6", 15),
            ("float:m10e3", 15),
            ("float:m23e8", 32),
            // formats as wide as the carrier fall back to raw f32 codes
            ("fixed:l16r16", 32),
            ("fixed:l64r64", 32),
        ] {
            let f = Format::parse(fmt).unwrap();
            assert_eq!(PackedTensor::bits_per_value(&f), width, "{fmt}");
        }
    }

    /// The documented bitstream layout, pinned word-for-word: three
    /// `fixed:l1r3` codes (width 6) at their LSB-first positions.
    #[test]
    fn packed_layout_is_stable() {
        let fmt = Format::fixed(1, 3);
        // q is exact on these grid points: codes 4, -4 (two's compl.
        // 0b111100 = 60), 8
        let p = PackedTensor::pack(&[0.5, -0.5, 1.0], &fmt);
        assert_eq!(p.width(), 6);
        assert_eq!(p.packed_bytes(), 3); // ceil(18 / 8)
        assert_eq!(p.words(), &[4 | (60 << 6) | (8 << 12)]);
        assert_eq!(p.unpack(), vec![0.5, -0.5, 1.0]);
    }

    /// Codes straddling u64 word boundaries decode intact.
    #[test]
    fn codes_straddle_word_boundaries() {
        // width 18: value 3 occupies bits 54..72 — across words 0 and 1
        let fmt = Format::fixed(8, 8);
        let vals: Vec<f32> = (0..11).map(|i| i as f32 * 1.5 - 8.0).collect();
        let p = PackedTensor::pack(&vals, &fmt);
        assert_eq!(p.width(), 18);
        assert_eq!(p.words().len(), 4); // ceil(198 / 64)
        roundtrip_matches_quantize(&vals, &fmt);
    }

    /// Negative zero survives both code spaces: the fixed sentinel and
    /// the float sign bit.
    #[test]
    fn negative_zero_roundtrips() {
        for fmt in [Format::fixed(4, 4), Format::float(7, 6), Format::SINGLE] {
            let p = PackedTensor::pack(&[-0.0, 0.0, -0.25e-30], &fmt);
            let got = p.unpack();
            assert_eq!(got[0].to_bits(), (-0.0f32).to_bits(), "{fmt}");
            assert_eq!(got[1].to_bits(), 0.0f32.to_bits(), "{fmt}");
        }
        // a negative value that quantizes to zero keeps its sign under
        // the float path (sign * 0.0) — the sentinel case in fixed form
        let q = Quantizer::new(&Format::fixed(4, 1));
        assert_eq!(q.q(-0.25).to_bits(), (-0.0f32).to_bits());
        roundtrip_matches_quantize(&[-0.25], &Format::fixed(4, 1));
    }

    /// Saturation values (incl. the carrier-clamped `e = 8` max whose
    /// mantissa is wider than `m`) take the dedicated SAT code.
    #[test]
    fn saturation_and_flush_roundtrip() {
        for fmt in [
            Format::float(4, 4),
            Format::float(2, 8), // carrier-clamped: max = f32::MAX
            Format::float(23, 8),
            Format::fixed(4, 4),
            Format::fixed(8, 8),
        ] {
            let vals = [
                1.0e38,
                -1.0e38,
                f32::INFINITY,
                f32::NEG_INFINITY,
                1.0e-40, // carrier subnormal: flushes (floats) / rounds (fixeds)
                fmt.max_value() as f32,
                -(fmt.max_value() as f32),
                fmt.min_normal() as f32,
            ];
            roundtrip_matches_quantize(&vals, &fmt);
        }
    }

    #[test]
    fn empty_and_single_value_tensors() {
        let fmt = Format::float(7, 6);
        let p = PackedTensor::pack(&[], &fmt);
        assert!(p.is_empty());
        assert_eq!(p.packed_bytes(), 0);
        assert_eq!(p.unpack(), Vec::<f32>::new());
        roundtrip_matches_quantize(&[3.14159], &fmt);
    }

    /// An arbitrary format across the *whole* constructor range — the
    /// shared `arb_format` plus wide fixeds, so the raw-carrier
    /// fallback (`l + r + 2 ≥ 32`) is always exercised too.
    fn arb_format_wide(g: &mut Gen) -> Format {
        if g.usize_in(0, 3) == 0 {
            Format::fixed(g.usize_in(0, 64) as u32, g.usize_in(0, 64) as u32)
        } else {
            arb_format(g)
        }
    }

    /// The tentpole property (ISSUE 5): pack → unpack is bit-identical
    /// to `quantize_slice` across random shapes and formats, including
    /// `QIdentity`/`Format::SINGLE` (always drawn by `arb_format`) and
    /// the raw-carrier fixed fallback.
    #[test]
    fn prop_pack_unpack_bitexact_vs_quantize_slice() {
        run_prop("pack_unpack_vs_quantize_slice", 200, |g| {
            let fmt = arb_format_wide(g);
            let n = g.usize_in(0, 96);
            let vals: Vec<f32> = (0..n)
                .map(|_| {
                    let mag = g.f32_in(0.0, 1.0) * 2.0f32.powi(g.int_in(-40, 38) as i32);
                    if g.bool() {
                        -mag
                    } else {
                        mag
                    }
                })
                .collect();
            roundtrip_matches_quantize(&vals, &fmt);
        });
    }

    /// `code_at` + `decode_table` reproduce `unpack` bit-exactly — the
    /// LUT execution lane's contract (store::exec reads the bitstream
    /// through exactly this pair).
    #[test]
    fn prop_code_at_through_decode_table_matches_unpack() {
        run_prop("code_at_lut_vs_unpack", 150, |g| {
            let fmt = arb_format(g);
            if PackedTensor::bits_per_value(&fmt) > 18 {
                assert!(PackedTensor::decode_table(&fmt, 18).is_none(), "{}", fmt.id());
                return;
            }
            let lut = PackedTensor::decode_table(&fmt, 18).unwrap();
            assert_eq!(lut.len(), 1 << PackedTensor::bits_per_value(&fmt));
            let vals: Vec<f32> = (0..g.usize_in(1, 64))
                .map(|_| g.f32_normal() * 2.0f32.powi(g.int_in(-20, 20) as i32))
                .collect();
            let p = PackedTensor::pack(&vals, &fmt);
            let want = p.unpack();
            for i in 0..p.len() {
                let got = lut[p.code_at(i) as usize];
                assert_eq!(
                    got.to_bits(),
                    want[i].to_bits(),
                    "{} elem {i}: lut {got} vs unpack {}",
                    fmt.id(),
                    want[i]
                );
            }
        });
    }

    /// `fixed_int_at` is the decoded value in grid units, with the
    /// `-0.0` sentinel mapped to numeric 0 — what the integer MAC lane
    /// streams.
    #[test]
    fn fixed_int_at_recovers_grid_integers() {
        let fmt = Format::fixed(4, 4); // grid k/16, M = 255
        let vals = [0.5f32, -0.5, 15.9375, -15.9375, 0.0, -0.0, -0.01];
        let p = PackedTensor::pack(&vals, &fmt);
        let want: Vec<i64> = vec![8, -8, 255, -255, 0, 0, 0]; // q(-0.01) = -0.0
        for (i, &w) in want.iter().enumerate() {
            assert_eq!(p.fixed_int_at(i), w, "elem {i}");
        }
        // every decoded grid integer rescales to the unpacked value
        let unpacked = p.unpack();
        for i in 0..p.len() {
            let v = (p.fixed_int_at(i) as f32) / 16.0;
            assert_eq!(v.to_bits(), (unpacked[i] + 0.0).to_bits(), "elem {i}");
        }
    }

    /// The raw-carrier layout has no LUT (2^32 codes), and the width
    /// cap is honoured.
    #[test]
    fn decode_table_bounds() {
        assert!(PackedTensor::decode_table(&Format::fixed(16, 16), 18).is_none());
        assert!(PackedTensor::decode_table(&Format::float(23, 8), 18).is_none());
        assert!(PackedTensor::decode_table(&Format::fixed(8, 8), 17).is_none());
        let lut = PackedTensor::decode_table(&Format::fixed(8, 8), 18).unwrap();
        assert_eq!(lut.len(), 1 << 18);
    }

    /// Packing already-quantized data is idempotent with packing raw
    /// data (quantizers are idempotent), and `packed_bytes_for` prices
    /// exactly what `pack` builds.
    #[test]
    fn prop_pack_quantized_and_size_estimate_agree() {
        run_prop("pack_quantized_agrees", 120, |g| {
            let fmt = arb_format_wide(g);
            let q = Quantizer::new(&fmt);
            let vals: Vec<f32> = (0..g.usize_in(1, 48)).map(|_| g.f32_normal() * 8.0).collect();
            let mut quantized = vals.clone();
            quantize_slice(&mut quantized, &q);
            let a = PackedTensor::pack(&vals, &fmt);
            let b = PackedTensor::pack_quantized(&quantized, &fmt);
            assert_eq!(a, b, "{}", fmt.id());
            assert_eq!(a.packed_bytes(), PackedTensor::packed_bytes_for(vals.len(), &fmt));
            // every decoded value is a fixed point of the quantizer
            for v in a.unpack() {
                assert_eq!(q.q(v).to_bits(), v.to_bits(), "{} value {v}", fmt.id());
            }
        });
    }
}
