//! Per-layer storage-footprint accounting — the data behind
//! `repro zoo-size`.
//!
//! The paper frames custom widths as a *memory* win as much as a MAC
//! win (PAPER.md §4): an `X(8, 8)` weight occupies 17 bits, not 32.
//! [`zoo_size`] prices one network under a resolved precision spec —
//! f32 carrier bytes vs the [`PackedTensor`] layout's packed bytes per
//! layer — alongside each layer's MAC count and the [`crate::hw`] MAC
//! speedup, so the table mirrors the paper's footprint framing: wide
//! layers dominate both the byte total and the MAC-weighted speedup.

use anyhow::Result;

use crate::formats::{FormatPair, PrecisionSpec};
use crate::nn::Network;
use crate::store::PackedTensor;

/// One quantized layer's storage and compute footprint under its
/// resolved weight/activation pair.  Storage columns follow the
/// **weight** half alone — that is what the store packs; activations
/// are transient — while `mac_speedup` prices the full pair through
/// the two-operand MAC model.
#[derive(Clone, Debug, PartialEq)]
pub struct FootprintRow {
    pub layer: String,
    pub pair: FormatPair,
    /// per-sample MACs (the weighting `hw::plan_speedup` uses)
    pub macs: usize,
    /// weight + bias parameter count
    pub params: usize,
    /// f32-carrier storage of those parameters
    pub f32_bytes: usize,
    /// packed code width under the weight half (DESIGN.md §Storage)
    pub bits_per_value: u32,
    /// packed storage of those parameters
    pub packed_bytes: usize,
    /// the pair's MAC-level hardware speedup (paper Fig 5; uniform
    /// pairs are the single-format numbers exactly)
    pub mac_speedup: f64,
}

/// Price every quantized layer of `net` under `spec` (validated like
/// every execution path — typos and uncovered layers are `Err`).  Rows
/// come back in execution order.
pub fn zoo_size(net: &Network, spec: &PrecisionSpec) -> Result<Vec<FootprintRow>> {
    let resolved = spec.resolve(net)?;
    let macs = net.quantized_layer_macs();
    debug_assert_eq!(macs.len(), resolved.assignments.len());
    let rows = resolved
        .assignments
        .iter()
        .zip(&macs)
        .map(|((name, pair), (mac_name, macs))| {
            debug_assert_eq!(name, mac_name);
            let params = net.weight(&format!("{name}.w")).data().len()
                + net.weight(&format!("{name}.b")).data().len();
            FootprintRow {
                layer: name.clone(),
                pair: *pair,
                macs: *macs,
                params,
                f32_bytes: params * 4,
                bits_per_value: PackedTensor::bits_per_value(&pair.w),
                packed_bytes: PackedTensor::packed_bytes_for(params, &pair.w),
                mac_speedup: crate::hw::pair_speedup(pair),
            }
        })
        .collect();
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Format;
    use crate::testing::fixtures::tiny_conv_network;

    #[test]
    fn footprint_of_the_fixture_under_a_mixed_plan() {
        let net = tiny_conv_network(4); // c1: 3x3x1x2 + 2 = 20; fc: 8x3 + 3 = 27
        let spec = PrecisionSpec::parse("plan:c1=fixed:l8r8,*=float:m7e6").unwrap();
        let rows = zoo_size(&net, &spec).unwrap();
        assert_eq!(rows.len(), 2);

        assert_eq!(rows[0].layer, "c1");
        assert_eq!(rows[0].params, 20);
        assert_eq!(rows[0].f32_bytes, 80);
        assert_eq!(rows[0].bits_per_value, 18); // l + r + 2
        assert_eq!(rows[0].packed_bytes, 45); // ceil(20 * 18 / 8)

        assert_eq!(rows[1].layer, "fc");
        assert_eq!(rows[1].params, 27);
        assert_eq!(rows[1].bits_per_value, 15); // 1 + ebits(7) + m(7)
        assert_eq!(rows[1].packed_bytes, 51); // ceil(27 * 15 / 8)

        // MAC counts line up with the network's own accounting, so the
        // hw weighting in the CLI table matches plan_speedup's
        let macs = net.quantized_layer_macs();
        assert_eq!(rows[0].macs, macs[0].1);
        assert_eq!(rows[1].macs, macs[1].1);
        for r in &rows {
            assert!(r.mac_speedup > 0.0);
            assert!(r.packed_bytes < r.f32_bytes, "{}: narrow formats must compress", r.layer);
        }

        // validation is total, like every execution path
        assert!(zoo_size(&net, &PrecisionSpec::parse("plan:typo=fixed:l8r8").unwrap()).is_err());
    }

    /// Split pairs: the storage columns price the WEIGHT half only
    /// (identical bytes to the same weight format under any activation
    /// half), while the speedup column prices the full pair.
    #[test]
    fn split_pair_rows_price_weight_half_storage() {
        let net = tiny_conv_network(4);
        let split =
            PrecisionSpec::parse("plan:c1=w:fixed:l8r8+a:float:m4e5,*=float:m7e6").unwrap();
        let uniform_w = PrecisionSpec::parse("plan:c1=fixed:l8r8,*=float:m7e6").unwrap();
        let srows = zoo_size(&net, &split).unwrap();
        let urows = zoo_size(&net, &uniform_w).unwrap();
        assert_eq!(srows[0].bits_per_value, urows[0].bits_per_value);
        assert_eq!(srows[0].packed_bytes, urows[0].packed_bytes);
        assert_eq!(srows[0].pair.id(), "w:fixed:l8r8+a:float:m4e5");
        let pair = FormatPair::split(Format::fixed(8, 8), Format::float(4, 5));
        assert_eq!(srows[0].mac_speedup, crate::hw::pair_speedup(&pair));
        assert_ne!(srows[0].mac_speedup, urows[0].mac_speedup);
    }

    #[test]
    fn baseline_format_packs_at_carrier_width() {
        let net = tiny_conv_network(4);
        let rows = zoo_size(&net, &PrecisionSpec::Uniform(Format::SINGLE)).unwrap();
        for r in rows {
            assert_eq!(r.bits_per_value, 32);
            assert_eq!(r.packed_bytes, r.f32_bytes);
        }
    }
}
