//! In-repo testing substrates (proptest is not in the offline crate set —
//! DESIGN.md §6).

pub mod prop;
