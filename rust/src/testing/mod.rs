//! In-repo testing substrates (proptest is not in the offline crate set —
//! DESIGN.md §6).

pub mod fixtures;
pub mod prop;

/// Truthiness rule for the `PRECIS_REQUIRE_*` strict-mode env vars used
/// by the artifact-dependent test suites: set and neither empty nor
/// `"0"`.  Shared so all test binaries promote skips to failures under
/// exactly the same condition.
pub fn strict_env(var: &str) -> bool {
    std::env::var(var).map(|v| !v.is_empty() && v != "0").unwrap_or(false)
}
