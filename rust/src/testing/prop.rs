//! Seeded property-testing mini-framework (the offline substitute for
//! `proptest`; DESIGN.md §6).
//!
//! Model: a property is a closure over a [`Gen`]; [`run_prop`] executes
//! it for N seeded cases.  On failure it re-runs a *shrinking* pass —
//! re-executing the property with truncated size budgets — and always
//! prints the failing case's seed, so a regression can be replayed with
//! [`run_prop_seeded`].  Deliberately value-agnostic: shrinking reduces
//! the generator's size budget (which generators consult for lengths and
//! magnitudes) rather than structurally shrinking values; this keeps the
//! framework ~150 lines while still converging on small counterexamples.

use std::panic::{catch_unwind, AssertUnwindSafe};

use crate::formats::Format;
use crate::util::rng::Pcg32;

/// Random-value source handed to properties.
pub struct Gen {
    rng: Pcg32,
    /// size budget in [0.0, 1.0]; generators scale ranges by it
    pub size: f64,
}

impl Gen {
    pub fn new(seed: u64, size: f64) -> Gen {
        Gen { rng: Pcg32::seeded(seed), size }
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    /// Inclusive integer range, scaled down by the size budget when shrinking.
    pub fn int_in(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        let eff = ((span as f64 * self.size).ceil() as u64).clamp(1, span);
        lo + (self.rng.next_u64() % eff) as i64
    }

    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.int_in(lo as i64, hi as i64) as usize
    }

    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        self.rng.range(lo, lo + (hi - lo) * self.size as f32)
    }

    pub fn f32_normal(&mut self) -> f32 {
        self.rng.normal() * self.size as f32
    }

    /// Vector with length in [min_len, max_len] (size-scaled).
    pub fn vec_f32(&mut self, min_len: usize, max_len: usize) -> Vec<f32> {
        let n = self.usize_in(min_len, max_len);
        (0..n).map(|_| self.f32_normal()).collect()
    }

    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u32) as usize]
    }
}

/// Arbitrary [`Format`] across the whole design surface — both
/// representation kinds plus an explicit `Format::SINGLE` arm, so
/// properties over quantized kernels always exercise the
/// `QIdentity` fast path too (the shared generator the kernel
/// bit-identity suites use; ISSUE 4).
pub fn arb_format(g: &mut Gen) -> Format {
    match g.usize_in(0, 3) {
        0 => Format::SINGLE,
        1 => Format::float(g.usize_in(0, 23) as u32, g.usize_in(1, 8) as u32),
        _ => Format::fixed(g.usize_in(0, 16) as u32, g.usize_in(0, 16) as u32),
    }
}

/// Run `cases` seeded executions of `prop`.  Panics (failing the test)
/// with the seed of the smallest failing case found.
pub fn run_prop<F: Fn(&mut Gen)>(name: &str, cases: u32, prop: F) {
    // fixed base seed for reproducibility; override via PRECIS_PROP_SEED
    let base: u64 = std::env::var("PRECIS_PROP_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5eed_0000);
    for i in 0..cases {
        let seed = base.wrapping_add(i as u64);
        if let Err(msg) = try_case(&prop, seed, 1.0) {
            // shrink: retry the same seed with smaller size budgets and
            // report the smallest budget that still fails
            let mut fail_size = 1.0;
            let mut fail_msg = msg;
            for &size in &[0.02, 0.05, 0.1, 0.25, 0.5] {
                if let Err(m) = try_case(&prop, seed, size) {
                    fail_size = size;
                    fail_msg = m;
                    break;
                }
            }
            panic!(
                "property {name:?} failed (seed={seed:#x}, size={fail_size}): {fail_msg}\n\
                 replay with run_prop_seeded({name:?}, {seed:#x}, {fail_size}, ...)"
            );
        }
    }
}

/// Replay a single case (for regression pinning).
pub fn run_prop_seeded<F: Fn(&mut Gen)>(name: &str, seed: u64, size: f64, prop: F) {
    if let Err(msg) = try_case(&prop, seed, size) {
        panic!("property {name:?} failed (seed={seed:#x}, size={size}): {msg}");
    }
}

fn try_case<F: Fn(&mut Gen)>(prop: &F, seed: u64, size: f64) -> Result<(), String> {
    let result = catch_unwind(AssertUnwindSafe(|| {
        let mut g = Gen::new(seed, size);
        prop(&mut g);
    }));
    match result {
        Ok(()) => Ok(()),
        Err(e) => {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".to_string());
            Err(msg)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        run_prop("tautology", 100, |g| {
            let v = g.vec_f32(0, 16);
            assert!(v.len() <= 16);
        });
    }

    #[test]
    fn failing_property_reports_seed() {
        let r = catch_unwind(AssertUnwindSafe(|| {
            run_prop("always_false", 10, |g| {
                let x = g.int_in(0, 100);
                assert!(x < 0, "x={x} is not negative");
            });
        }));
        let msg = match r {
            Err(e) => e.downcast_ref::<String>().cloned().unwrap_or_default(),
            Ok(()) => panic!("property should have failed"),
        };
        assert!(msg.contains("seed="), "missing seed in: {msg}");
        assert!(msg.contains("always_false"));
    }

    #[test]
    fn generators_respect_ranges() {
        run_prop("ranges", 200, |g| {
            let i = g.int_in(-5, 5);
            assert!((-5..=5).contains(&i));
            let u = g.usize_in(3, 9);
            assert!((3..=9).contains(&u));
            let f = g.f32_in(1.0, 2.0);
            assert!((1.0..=2.0).contains(&f));
        });
    }

    #[test]
    fn arb_format_covers_all_kinds_and_parses() {
        let (mut single, mut float, mut fixed) = (0, 0, 0);
        for seed in 0..200 {
            let mut g = Gen::new(seed, 1.0);
            let f = arb_format(&mut g);
            // always a valid, parseable point of the design surface
            assert_eq!(Format::parse(&f.id()).unwrap(), f);
            if f == Format::SINGLE {
                single += 1;
            } else if f.is_float() {
                float += 1;
            } else {
                fixed += 1;
            }
        }
        assert!(single > 0, "SINGLE arm never drawn");
        assert!(float > 0, "float arm never drawn");
        assert!(fixed > 0, "fixed arm never drawn");
    }

    #[test]
    fn seeded_replay_is_deterministic() {
        let mut a = Gen::new(99, 1.0);
        let mut b = Gen::new(99, 1.0);
        for _ in 0..50 {
            assert_eq!(a.int_in(0, 1000), b.int_in(0, 1000));
        }
    }
}
