//! Deterministic in-memory test fixtures.
//!
//! The integration suites exercise the real artifact zoo (and skip
//! without it); these fixtures give the serving layer a network that
//! exists on every fresh clone, so the session/gateway contracts
//! (bit-identity, error propagation, drain-on-shutdown) are verified
//! by tier-1 `cargo test` unconditionally.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::formats::Format;
use crate::nn::{Layer, Network};
use crate::serving::{Backend, NativeBackend};
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

/// A tiny fully-deterministic network: (2, 2, 1) input → flatten →
/// dense(4 → 3), with `eval_n` synthetic eval samples whose labels are
/// the network's own exact-format argmax — so baseline accuracy is
/// exactly 1.0 and format-degradation behaviour is observable.  Two
/// calls with the same `eval_n` produce bit-identical networks, so
/// fixtures built independently (e.g. one inside a session, one as the
/// reference) are comparable at 0 ulp.
pub fn tiny_network(eval_n: usize) -> Arc<Network> {
    let mut rng = Pcg32::seeded(0x7e57_f1f7);
    let in_dim = 4;
    let classes = 3;

    let w = Tensor::new(
        vec![in_dim, classes],
        (0..in_dim * classes).map(|_| rng.normal()).collect(),
    )
    .unwrap();
    let b = Tensor::new(vec![classes], (0..classes).map(|_| rng.normal() * 0.1).collect()).unwrap();
    let eval_x = Tensor::new(
        vec![eval_n, 2, 2, 1],
        (0..eval_n * in_dim).map(|_| rng.normal()).collect(),
    )
    .unwrap();

    let mut weights = BTreeMap::new();
    weights.insert("fc.w".to_string(), w);
    weights.insert("fc.b".to_string(), b);

    let mut net = Arc::new(Network {
        name: "tiny-fixture".to_string(),
        input: [2, 2, 1],
        classes,
        topk: 1,
        layers: vec![
            Layer::Flatten,
            Layer::Dense { name: "fc".to_string(), in_dim, out_dim: classes },
        ],
        weight_order: vec!["fc.w".to_string(), "fc.b".to_string()],
        weights,
        eval_x,
        eval_y: vec![0; eval_n],
        eval_acc_exact: 1.0,
        hlo_files: BTreeMap::new(),
        n_params: in_dim * classes + classes,
        max_chain: in_dim,
    });

    // label every sample with the exact forward pass's argmax, run
    // through the same serving substrate everything else uses
    let logits = NativeBackend::new(net.clone())
        .run_batch(&net.eval_x.slice_rows(0, eval_n), &Format::SINGLE)
        .unwrap();
    let labels = (0..eval_n)
        .map(|i| {
            let row = &logits.data()[i * classes..(i + 1) * classes];
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(c, _)| c as i32)
                .unwrap()
        })
        .collect();
    Arc::get_mut(&mut net).expect("backend dropped; sole owner").eval_y = labels;
    net
}

/// A tiny two-GEMM network exercising the conv path: (4, 4, 1) input →
/// conv `c1` (3x3, pad 1, 2 channels) → relu → maxpool 2x2 → flatten →
/// dense `fc` (8 → 3).  Having two named quantized layers (`c1`, `fc`)
/// makes it the fixture for per-layer mixed-precision plans; like
/// [`tiny_network`] it is fully deterministic and self-labeled with the
/// exact forward's argmax (baseline accuracy exactly 1.0).
pub fn tiny_conv_network(eval_n: usize) -> Arc<Network> {
    let mut rng = Pcg32::seeded(0x7e57_c0ff);
    let (h, w, cin) = (4usize, 4usize, 1usize);
    let (kh, kw, cout) = (3usize, 3usize, 2usize);
    let classes = 3usize;
    let flat = (h / 2) * (w / 2) * cout; // after maxpool k2 s2

    let c1_w = Tensor::new(
        vec![kh, kw, cin, cout],
        (0..kh * kw * cin * cout).map(|_| rng.normal() * 0.5).collect(),
    )
    .unwrap();
    let c1_b = Tensor::new(vec![cout], (0..cout).map(|_| rng.normal() * 0.1).collect()).unwrap();
    let fc_w = Tensor::new(
        vec![flat, classes],
        (0..flat * classes).map(|_| rng.normal() * 0.5).collect(),
    )
    .unwrap();
    let fc_b = Tensor::new(vec![classes], (0..classes).map(|_| rng.normal() * 0.1).collect()).unwrap();
    let eval_x = Tensor::new(
        vec![eval_n, h, w, cin],
        (0..eval_n * h * w * cin).map(|_| rng.normal()).collect(),
    )
    .unwrap();

    let mut weights = BTreeMap::new();
    weights.insert("c1.w".to_string(), c1_w);
    weights.insert("c1.b".to_string(), c1_b);
    weights.insert("fc.w".to_string(), fc_w);
    weights.insert("fc.b".to_string(), fc_b);

    let weight_order: Vec<String> =
        ["c1.w", "c1.b", "fc.w", "fc.b"].iter().map(|s| s.to_string()).collect();
    let n_params = kh * kw * cin * cout + cout + flat * classes + classes;

    let mut net = Arc::new(Network {
        name: "tiny-conv-fixture".to_string(),
        input: [h, w, cin],
        classes,
        topk: 1,
        layers: vec![
            Layer::Conv {
                name: "c1".to_string(),
                kh,
                kw,
                in_ch: cin,
                out_ch: cout,
                stride: 1,
                pad: 1,
            },
            Layer::Relu,
            Layer::MaxPool { k: 2, stride: 2, pad: 0 },
            Layer::Flatten,
            Layer::Dense { name: "fc".to_string(), in_dim: flat, out_dim: classes },
        ],
        weight_order,
        weights,
        eval_x,
        eval_y: vec![0; eval_n],
        eval_acc_exact: 1.0,
        hlo_files: BTreeMap::new(),
        n_params,
        max_chain: kh * kw * cin,
    });

    let logits = NativeBackend::new(net.clone())
        .run_batch(&net.eval_x.slice_rows(0, eval_n), &Format::SINGLE)
        .unwrap();
    let labels = (0..eval_n)
        .map(|i| {
            let row = &logits.data()[i * classes..(i + 1) * classes];
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(c, _)| c as i32)
                .unwrap()
        })
        .collect();
    Arc::get_mut(&mut net).expect("backend dropped; sole owner").eval_y = labels;
    net
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_conv_network_is_deterministic_and_self_labeled() {
        let a = tiny_conv_network(8);
        let b = tiny_conv_network(8);
        assert_eq!(a.eval_x.data(), b.eval_x.data());
        assert_eq!(a.eval_y, b.eval_y);
        assert_eq!(a.quantized_layer_names(), vec!["c1", "fc"]);
        // self-labeling: exact-format accuracy is exactly 1.0
        let logits = NativeBackend::new(a.clone())
            .run_batch(&a.eval_x.slice_rows(0, 8), &Format::SINGLE)
            .unwrap();
        let acc = crate::eval::topk_accuracy(logits.data(), &a.eval_y, a.classes, 1);
        assert_eq!(acc, 1.0);
    }

    #[test]
    fn tiny_network_is_deterministic() {
        let a = tiny_network(6);
        let b = tiny_network(6);
        assert_eq!(a.eval_x.data(), b.eval_x.data());
        assert_eq!(
            a.weight("fc.w").data(),
            b.weight("fc.w").data()
        );
        assert_eq!(a.eval_len(), 6);
    }
}
