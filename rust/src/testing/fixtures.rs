//! Deterministic in-memory test fixtures.
//!
//! The integration suites exercise the real artifact zoo (and skip
//! without it); these fixtures give the serving layer a network that
//! exists on every fresh clone, so the session/gateway contracts
//! (bit-identity, error propagation, drain-on-shutdown) are verified
//! by tier-1 `cargo test` unconditionally.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::formats::Format;
use crate::nn::{Layer, Network};
use crate::serving::{Backend, NativeBackend};
use crate::tensor::Tensor;
use crate::util::rng::Pcg32;

/// A tiny fully-deterministic network: (2, 2, 1) input → flatten →
/// dense(4 → 3), with `eval_n` synthetic eval samples whose labels are
/// the network's own exact-format argmax — so baseline accuracy is
/// exactly 1.0 and format-degradation behaviour is observable.  Two
/// calls with the same `eval_n` produce bit-identical networks, so
/// fixtures built independently (e.g. one inside a session, one as the
/// reference) are comparable at 0 ulp.
pub fn tiny_network(eval_n: usize) -> Arc<Network> {
    let mut rng = Pcg32::seeded(0x7e57_f1f7);
    let in_dim = 4;
    let classes = 3;

    let w = Tensor::new(
        vec![in_dim, classes],
        (0..in_dim * classes).map(|_| rng.normal()).collect(),
    )
    .unwrap();
    let b = Tensor::new(vec![classes], (0..classes).map(|_| rng.normal() * 0.1).collect()).unwrap();
    let eval_x = Tensor::new(
        vec![eval_n, 2, 2, 1],
        (0..eval_n * in_dim).map(|_| rng.normal()).collect(),
    )
    .unwrap();

    let mut weights = BTreeMap::new();
    weights.insert("fc.w".to_string(), w);
    weights.insert("fc.b".to_string(), b);

    let mut net = Arc::new(Network {
        name: "tiny-fixture".to_string(),
        input: [2, 2, 1],
        classes,
        topk: 1,
        layers: vec![
            Layer::Flatten,
            Layer::Dense { name: "fc".to_string(), in_dim, out_dim: classes },
        ],
        weight_order: vec!["fc.w".to_string(), "fc.b".to_string()],
        weights,
        eval_x,
        eval_y: vec![0; eval_n],
        eval_acc_exact: 1.0,
        hlo_files: BTreeMap::new(),
        n_params: in_dim * classes + classes,
        max_chain: in_dim,
    });

    // label every sample with the exact forward pass's argmax, run
    // through the same serving substrate everything else uses
    let logits = NativeBackend::new(net.clone())
        .run_batch(&net.eval_x.slice_rows(0, eval_n), &Format::SINGLE)
        .unwrap();
    let labels = (0..eval_n)
        .map(|i| {
            let row = &logits.data()[i * classes..(i + 1) * classes];
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(c, _)| c as i32)
                .unwrap()
        })
        .collect();
    Arc::get_mut(&mut net).expect("backend dropped; sole owner").eval_y = labels;
    net
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_network_is_deterministic() {
        let a = tiny_network(6);
        let b = tiny_network(6);
        assert_eq!(a.eval_x.data(), b.eval_x.data());
        assert_eq!(
            a.weight("fc.w").data(),
            b.weight("fc.w").data()
        );
        assert_eq!(a.eval_len(), 6);
    }
}
