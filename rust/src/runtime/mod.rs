//! PJRT runtime: load `artifacts/*.hlo.txt`, compile once, execute many.
//!
//! This is the request-path executor for the AOT-lowered JAX/Pallas
//! artifacts (see `python/compile/aot.py` for the artifact signature).
//! HLO **text** is the interchange format — the xla_extension 0.5.1
//! bundled with the published `xla` crate rejects jax>=0.5's 64-bit
//! instruction-id protos, while its text parser reassigns ids.
//!
//! # The `pjrt` feature
//!
//! The PJRT bindings are not part of the offline crate set (DESIGN.md
//! §6), so the whole executor is gated behind the `pjrt` cargo feature
//! (DESIGN.md §5):
//!
//! * **default build** — this module exports only [`AVAILABLE`]
//!   (`false`); everything that would need a PJRT executable falls back
//!   to the native engine ([`crate::serving::NativeBackend`]), which is
//!   bit-exact with the Pallas kernels by contract (DESIGN.md §3).
//! * **`--features pjrt`** — compiles the executor in this module
//!   against the `xla` dependency (and the
//!   `serving::PjrtBackend` adapter the session factory builds on the
//!   dispatcher thread).  Out of the box that dependency is
//!   the in-repo `rust/xla-stub` placeholder, which type-checks the
//!   path but fails fast at runtime; point it at a real PJRT binding
//!   crate to execute the artifacts.

#[cfg(feature = "pjrt")]
mod pjrt;

#[cfg(feature = "pjrt")]
pub use pjrt::{LoadedModel, ModelCache, Runtime};

/// True when this build carries the PJRT-backed runtime (`pjrt` feature).
pub const AVAILABLE: bool = cfg!(feature = "pjrt");
