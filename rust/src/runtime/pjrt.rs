//! The PJRT-backed executor (compiled only with the `pjrt` feature —
//! see the module docs in `runtime/mod.rs` and DESIGN.md §5).
//!
//! One [`LoadedModel`] = one compiled executable per (network, kind);
//! the format descriptor is a runtime input, so the whole design space
//! runs on a single executable with zero recompiles.

use std::collections::BTreeMap;
use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::formats::Format;
use crate::nn::Network;
use crate::tensor::Tensor;

/// Shared PJRT CPU client (one per process).
pub struct Runtime {
    client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Runtime { client })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    pub fn load_hlo(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client
            .compile(&comp)
            .map_err(|e| anyhow!("compile {}: {e}", path.display()))
    }

    /// Load a network's artifact for one representation kind and bind
    /// its weights.
    pub fn load_network(
        &self,
        net: &Arc<Network>,
        artifacts_dir: &Path,
        kind: &str,
        batch: usize,
    ) -> Result<LoadedModel> {
        let path = net.hlo_path(artifacts_dir, kind)?;
        let exe = self
            .load_hlo(&path)
            .with_context(|| format!("loading {} ({kind})", net.name))?;
        Ok(LoadedModel {
            net: net.clone(),
            kind: kind.to_string(),
            batch,
            exe,
        })
    }
}

/// A compiled (network, kind) executable with weight binding.
pub struct LoadedModel {
    pub net: Arc<Network>,
    pub kind: String,
    pub batch: usize,
    exe: xla::PjRtLoadedExecutable,
}

impl LoadedModel {
    /// Check the format kind matches this executable.
    fn check_kind(&self, fmt: &Format) -> Result<()> {
        let want_float = self.kind == "float";
        if fmt.is_float() != want_float {
            bail!(
                "format {fmt} fed to a {} executable of {}",
                self.kind,
                self.net.name
            );
        }
        Ok(())
    }

    /// Execute one batch.  `x` must be (batch, H, W, C) with the static
    /// artifact batch size; returns logits (batch, classes).
    pub fn run_batch(&self, x: &Tensor, fmt: &Format) -> Result<Tensor> {
        self.check_kind(fmt)?;
        let [h, w, c] = self.net.input;
        if x.shape() != [self.batch, h, w, c] {
            bail!(
                "{}: batch shape {:?} != expected {:?}",
                self.net.name,
                x.shape(),
                [self.batch, h, w, c]
            );
        }

        let dims: Vec<i64> = x.shape().iter().map(|&d| d as i64).collect();
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(2 + self.net.weight_order.len());
        inputs.push(
            xla::Literal::vec1(x.data())
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape input: {e}"))?,
        );
        let params = fmt.runtime_params();
        inputs.push(xla::Literal::vec1(&params));
        for wname in &self.net.weight_order {
            let t = self.net.weight(wname);
            let wdims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
            inputs.push(
                xla::Literal::vec1(t.data())
                    .reshape(&wdims)
                    .map_err(|e| anyhow!("reshape weight {wname}: {e}"))?,
            );
        }

        let result = self
            .exe
            .execute::<xla::Literal>(&inputs)
            .map_err(|e| anyhow!("execute {}: {e}", self.net.name))?;
        let lit = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch result: {e}"))?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple
        let out = lit.to_tuple1().map_err(|e| anyhow!("untuple: {e}"))?;
        let values = out.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e}"))?;
        Tensor::new(vec![self.batch, self.net.classes], values)
    }

    /// Run `n` eval samples (padding the tail batch), returning logits
    /// (n, classes) and the matching labels.
    pub fn run_eval(&self, n: usize, fmt: &Format) -> Result<(Vec<f32>, Vec<i32>)> {
        let n = n.min(self.net.eval_len()).max(1);
        let [h, w, c] = self.net.input;
        let px = h * w * c;
        let classes = self.net.classes;
        let mut logits = Vec::with_capacity(n * classes);
        let mut lo = 0;
        while lo < n {
            let hi = (lo + self.batch).min(n);
            // pad the final partial batch by repeating the last sample
            let mut xdata = Vec::with_capacity(self.batch * px);
            xdata.extend_from_slice(&self.net.eval_x.data()[lo * px..hi * px]);
            while xdata.len() < self.batch * px {
                let last = &self.net.eval_x.data()[(hi - 1) * px..hi * px];
                xdata.extend_from_slice(last);
            }
            let x = Tensor::new(vec![self.batch, h, w, c], xdata)?;
            let out = self.run_batch(&x, fmt)?;
            logits.extend_from_slice(&out.data()[..(hi - lo) * classes]);
            lo = hi;
        }
        Ok((logits, self.net.eval_y[..n].to_vec()))
    }
}

/// Cache of compiled executables keyed by (network, kind).
pub struct ModelCache {
    runtime: Runtime,
    artifacts_dir: std::path::PathBuf,
    batch: usize,
    models: BTreeMap<(String, String), Arc<LoadedModel>>,
}

impl ModelCache {
    pub fn new(runtime: Runtime, artifacts_dir: impl AsRef<Path>, batch: usize) -> ModelCache {
        ModelCache {
            runtime,
            artifacts_dir: artifacts_dir.as_ref().to_path_buf(),
            batch,
            models: BTreeMap::new(),
        }
    }

    pub fn get(&mut self, net: &Arc<Network>, kind: &str) -> Result<Arc<LoadedModel>> {
        let key = (net.name.clone(), kind.to_string());
        if let Some(m) = self.models.get(&key) {
            return Ok(m.clone());
        }
        let m = Arc::new(
            self.runtime
                .load_network(net, &self.artifacts_dir, kind, self.batch)?,
        );
        self.models.insert(key, m.clone());
        Ok(m)
    }
}
