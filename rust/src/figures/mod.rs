//! Regeneration of every figure in the paper's evaluation (Figs 4-11).
//!
//! Each `figN` function computes the same data series the paper plots
//! and returns it as a TSV table (`Table`): headers + rows.  The CLI
//! (`repro figure <id>`) prints them and `repro figures` writes all of
//! them under `results/`.  EXPERIMENTS.md records the paper-vs-measured
//! comparison for each.

use std::path::Path;
use std::sync::Arc;

use anyhow::{anyhow, Result};

use crate::coordinator::Coordinator;
use crate::eval::sweep::EvalOptions;
use crate::eval::ConfigResult;
use crate::formats::{self, Format};
use crate::hw;
use crate::nn::Network;
use crate::numerics::trace::{trace_accumulation, trace_exact};
use crate::search::{
    collect_model_points_cached, predictions_from_r2s, probe_r2s, select_candidates,
    AccuracyModel,
};
use crate::serving::NativeBackend;

/// Memo of probe R²s per network (model-independent, so fig10 and
/// fig11 share one probe pass per network over the full design space).
pub type ProbeMemo = std::collections::BTreeMap<String, Vec<(Format, f64)>>;

fn memo_probe_r2s<'a>(
    memo: &'a mut ProbeMemo,
    net: &Arc<Network>,
    seed: u64,
) -> Result<&'a [(Format, f64)]> {
    use std::collections::btree_map::Entry;
    let slot = match memo.entry(net.name.clone()) {
        Entry::Occupied(e) => e.into_mut(),
        Entry::Vacant(v) => v.insert(probe_r2s(net, &formats::design_space(1), seed)?),
    };
    Ok(slot)
}

/// A printable/storable data table.
#[derive(Clone, Debug)]
pub struct Table {
    pub name: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(name: &str, headers: &[&str]) -> Table {
        Table {
            name: name.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn push(&mut self, row: Vec<String>) {
        assert_eq!(row.len(), self.headers.len(), "row arity mismatch in {}", self.name);
        self.rows.push(row);
    }

    pub fn to_tsv(&self) -> String {
        let mut s = String::new();
        s.push_str(&self.headers.join("\t"));
        s.push('\n');
        for r in &self.rows {
            s.push_str(&r.join("\t"));
            s.push('\n');
        }
        s
    }

    pub fn write_to(&self, dir: &Path) -> Result<std::path::PathBuf> {
        std::fs::create_dir_all(dir)?;
        let path = dir.join(format!("{}.tsv", self.name));
        std::fs::write(&path, self.to_tsv())?;
        Ok(path)
    }
}

fn f(v: f64) -> String {
    format!("{v:.6}")
}

// ---------------------------------------------------------------------
// Fig 4: MAC delay & area vs mantissa width (hardware model only)

pub fn fig4() -> Table {
    let mut t = Table::new("fig4_mac_delay_area", &["mantissa_bits", "delay_norm", "area_norm"]);
    for m in 1..=23u32 {
        let fmt = Format::float(m, 8);
        t.push(vec![m.to_string(), f(hw::delay(&fmt)), f(hw::area(&fmt))]);
    }
    t
}

// ---------------------------------------------------------------------
// Fig 5: the fixed-area speedup composition (frequency x parallelism)

pub fn fig5() -> Table {
    let mut t = Table::new(
        "fig5_speedup_composition",
        &["format", "total_bits", "delay_norm", "area_norm", "freq_gain", "parallel_gain", "speedup"],
    );
    for fmt in [
        Format::SINGLE,
        Format::float(16, 8),
        Format::float(10, 6),
        Format::float(7, 6),
        Format::float(4, 5),
        Format::fixed(16, 15),
        Format::fixed(8, 8),
        Format::fixed(4, 4),
    ] {
        let c = hw::mac::cost(&fmt);
        t.push(vec![
            fmt.id(),
            fmt.total_bits().to_string(),
            f(c.delay),
            f(c.area),
            f(1.0 / c.delay),
            f(1.0 / c.area),
            f(hw::speedup(&fmt)),
        ]);
    }
    t
}

// ---------------------------------------------------------------------
// Fig 6: accuracy vs speedup scatter per network (the core sweep)

pub fn fig6(coord: &Coordinator, net_name: &str, opts: &EvalOptions, stride: usize) -> Result<Table> {
    let space = formats::design_space(stride);
    let results = coord.sweep(net_name, &space, opts)?;
    let mut t = Table::new(
        &format!("fig6_design_space_{net_name}"),
        &["format", "kind", "total_bits", "speedup", "accuracy", "normalized_accuracy"],
    );
    for r in &results {
        t.push(vec![
            r.format.id(),
            if r.format.is_float() { "float".into() } else { "fixed".into() },
            r.format.total_bits().to_string(),
            f(r.speedup),
            f(r.accuracy),
            f(r.normalized_accuracy),
        ]);
    }
    Ok(t)
}

// ---------------------------------------------------------------------
// Fig 7: speedup & energy heatmaps over bit allocations, with the
// <1%-loss region marked for alexnet-mini

pub fn fig7(coord: &Coordinator, net_name: &str, opts: &EvalOptions) -> Result<Table> {
    let space = formats::design_space(1);
    let results = coord.sweep(net_name, &space, opts)?;
    let mut t = Table::new(
        &format!("fig7_heatmap_{net_name}"),
        &["kind", "x_bits", "y_bits", "speedup", "energy_savings", "acceptable"],
    );
    for r in &results {
        let (kind, x, y) = match r.format {
            Format::Float { mantissa, exponent } => ("float", mantissa, exponent),
            Format::Fixed { int_bits, frac_bits } => ("fixed", int_bits, frac_bits),
        };
        t.push(vec![
            kind.to_string(),
            x.to_string(),
            y.to_string(),
            f(r.speedup),
            f(r.energy_savings),
            (r.normalized_accuracy >= 0.99).to_string(),
        ]);
    }
    Ok(t)
}

// ---------------------------------------------------------------------
// Fig 8: serialized accumulation of one neuron under several formats

/// The formats the paper plots in Fig 8, adapted to this testbed's
/// dynamic range.  Two adaptations (DESIGN.md §1): (a) FL m2/e14 is out
/// of the f32 carrier's exponent range — FL m2 e8 preserves the
/// illustrated phenomenon (excessive rounding once the sum is large);
/// (b) the paper's AlexNet neuron accumulates into the hundreds, where
/// X(8,8) saturates at 255 — our mini-net sums peak at a few units, so
/// the "radix point too high" saturation case is X(1,14) (16 bits like
/// the paper's, saturating at 2.0), keeping the same story at our scale.
pub fn fig8_formats() -> Vec<Format> {
    vec![
        Format::fixed(8, 8),   // FI 16-bit, radix centered: tracks well here
        Format::fixed(1, 14),  // FI 16-bit, saturates mid-chain (paper's green line)
        Format::float(10, 4),  // FL m10 e4
        Format::float(2, 8),   // FL m2: excessive rounding (paper: m2 e14)
        Format::float(8, 6),   // FL m8 e6: the accurate/fast pick
    ]
}

/// Extract one neuron's MAC chain: the im2col row feeding the first
/// conv-layer-with-max-chain of `net` at the center output position of
/// eval input `sample`, paired with the weight column of out-channel 0.
pub fn neuron_chain(net: &Arc<Network>, sample: usize) -> Result<(Vec<f32>, Vec<f32>)> {
    // find the deepest conv layer (paper uses AlexNet's third conv)
    let conv_idx = net
        .layers
        .iter()
        .enumerate()
        .filter(|(_, l)| matches!(l, crate::nn::Layer::Conv { .. }))
        .map(|(i, _)| i)
        .next_back()
        .ok_or_else(|| anyhow!("{} has no conv layer", net.name))?;
    let crate::nn::Layer::Conv { name, kh, kw, in_ch, out_ch, stride, pad } =
        net.layers[conv_idx].clone()
    else {
        unreachable!()
    };

    // input activations of that conv under the exact format, tapped
    // through the serving substrate's native backend
    let mut backend = NativeBackend::new(net.clone());
    let x = net.eval_x.slice_rows(sample, sample + 1);
    let act = backend.forward_prefix(&x, &Format::SINGLE, conv_idx);
    let shape = act.shape().to_vec();
    let (h, w, c) = (shape[1], shape[2], shape[3]);
    assert_eq!(c, in_ch);

    // im2col row at the center output position
    let oy = ((h + 2 * pad - kh) / stride + 1) / 2;
    let ox = ((w + 2 * pad - kw) / stride + 1) / 2;
    let mut inputs = Vec::with_capacity(kh * kw * c);
    for ki in 0..kh {
        for kj in 0..kw {
            let iy = (oy * stride + ki) as isize - pad as isize;
            let ix = (ox * stride + kj) as isize - pad as isize;
            for ci in 0..c {
                let v = if iy >= 0 && (iy as usize) < h && ix >= 0 && (ix as usize) < w {
                    act.data()[((iy as usize * w) + ix as usize) * c + ci]
                } else {
                    0.0
                };
                inputs.push(v);
            }
        }
    }

    // weight column for out-channel 0: w[kh, kw, cin, cout] row-major
    let wt = net.weight(&format!("{name}.w"));
    let mut weights = Vec::with_capacity(kh * kw * c);
    for i in 0..kh * kw * c {
        weights.push(wt.data()[i * out_ch]);
    }
    Ok((weights, inputs))
}

pub fn fig8(net: &Arc<Network>, sample: usize) -> Result<Table> {
    let (weights, inputs) = neuron_chain(net, sample)?;
    let fmts = fig8_formats();
    let mut headers: Vec<String> = vec!["step".into(), "exact".into()];
    headers.extend(fmts.iter().map(|f| f.id()));
    let mut t = Table {
        name: format!("fig8_accumulation_{}", net.name),
        headers,
        rows: Vec::new(),
    };
    let exact = trace_exact(&weights, &inputs);
    let traces: Vec<_> = fmts
        .iter()
        .map(|fm| trace_accumulation(&weights, &inputs, fm))
        .collect();
    for step in 0..exact.len() {
        let mut row = vec![step.to_string(), f(exact[step] as f64)];
        row.extend(traces.iter().map(|tr| f(tr.running[step] as f64)));
        t.rows.push(row);
    }
    Ok(t)
}

// ---------------------------------------------------------------------
// Fig 9: the linear correlation-accuracy model

/// The paper builds the Fig 9 model from AlexNet + CIFARNET + LeNet-5.
pub const MODEL_NETS: [&str; 3] = ["alexnet-mini", "cifarnet", "lenet5"];

pub fn fig9(coord: &Coordinator, opts: &EvalOptions, seed: u64) -> Result<(Table, AccuracyModel)> {
    let mut points = Vec::new();
    let mut t = Table::new(
        "fig9_correlation_model",
        &["network", "format", "r2", "normalized_accuracy"],
    );
    let space = formats::design_space(1);
    for name in MODEL_NETS {
        let net = coord.zoo.network(name)?;
        for (fmt, p) in
            collect_model_points_cached(&net, &space, opts, seed, Some(&coord.cache))?
        {
            t.push(vec![name.to_string(), fmt.id(), f(p.r2), f(p.normalized_accuracy)]);
            points.push(p);
        }
    }
    coord.cache.flush()?;
    let model = AccuracyModel::fit(&points);
    Ok((t, model))
}

// ---------------------------------------------------------------------
// Fig 10: search validation (exhaustive vs model + N samples)

pub fn fig10(
    coord: &Coordinator,
    opts: &EvalOptions,
    targets: &[f64],
    seed: u64,
    probes: &mut ProbeMemo,
) -> Result<Table> {
    let mut t = Table::new(
        "fig10_search_validation",
        &["network", "kind", "target", "method", "chosen", "speedup", "measured_norm_acc", "sample_forwards"],
    );
    for net_name in coord.zoo.names().iter().map(|s| s.to_string()).collect::<Vec<_>>() {
        let net = coord.zoo.network(&net_name)?;
        let samples = opts.samples.min(net.eval_len());
        // cross-validated model: fit on the OTHER model networks (§4.4)
        let model = cross_validated_model(coord, &net_name, opts, seed)?;
        let all_r2s: Vec<(Format, f64)> = memo_probe_r2s(probes, &net, seed)?.to_vec();
        for kind in ["float", "fixed"] {
            let r2s: Vec<(Format, f64)> = all_r2s
                .iter()
                .copied()
                .filter(|(fm, _)| fm.is_float() == (kind == "float"))
                .collect();
            let space: Vec<Format> = r2s.iter().map(|(fm, _)| *fm).collect();
            // one memoized probe pass + one (cached) accuracy table per (net, kind)
            let cands = predictions_from_r2s(&r2s, &model);
            let table = coord.sweep(&net_name, &space, opts)?;
            let na_of = |fm: &Format| -> f64 {
                table
                    .iter()
                    .find(|r| r.format == *fm)
                    .map(|r| r.normalized_accuracy)
                    .unwrap_or(0.0)
            };
            let probe_cost = (space.len() + 1) * crate::search::PROBE_INPUTS;

            for &target in targets {
                // exhaustive: fastest config whose measured na clears
                let best = table
                    .iter()
                    .filter(|r| r.normalized_accuracy >= target)
                    .max_by(|a, b| a.speedup.partial_cmp(&b.speedup).unwrap());
                t.push(vec![
                    net_name.clone(),
                    kind.into(),
                    format!("{target}"),
                    "exhaustive".into(),
                    best.map(|r| r.format.id()).unwrap_or_else(|| "-".into()),
                    f(best.map(|r| r.speedup).unwrap_or(0.0)),
                    f(best.map(|r| r.normalized_accuracy).unwrap_or(0.0)),
                    ((space.len() + 1) * samples).to_string(),
                ]);
                // model + N refinement evaluations
                for refine in [0usize, 1, 2] {
                    let mut evals = 0usize;
                    let sel = select_candidates(&cands, target, refine, |fm| {
                        evals += 1;
                        na_of(fm)
                    });
                    let (chosen, na) = match sel {
                        Some((idx, _, _)) => {
                            let c = cands[idx].0;
                            (Some(c), na_of(&c))
                        }
                        None => (None, 0.0),
                    };
                    t.push(vec![
                        net_name.clone(),
                        kind.into(),
                        format!("{target}"),
                        format!("model+{refine}"),
                        chosen.map(|c| c.id()).unwrap_or_else(|| "-".into()),
                        f(chosen.map(|c| hw::speedup(&c)).unwrap_or(0.0)),
                        f(na),
                        (probe_cost + (evals + 1) * samples).to_string(),
                    ]);
                }
            }
        }
    }
    coord.cache.flush()?;
    Ok(t)
}

/// Fit the accuracy model on the Fig 9 reference networks, excluding
/// `exclude` (the paper's cross-validation protocol).
pub fn cross_validated_model(
    coord: &Coordinator,
    exclude: &str,
    opts: &EvalOptions,
    seed: u64,
) -> Result<AccuracyModel> {
    let space = formats::design_space(1);
    let mut points = Vec::new();
    for name in MODEL_NETS.iter().filter(|n| **n != exclude) {
        let net = coord.zoo.network(name)?;
        points.extend(
            collect_model_points_cached(&net, &space, opts, seed, Some(&coord.cache))?
                .into_iter()
                .map(|(_, p)| p),
        );
    }
    Ok(AccuracyModel::fit(&points))
}

// ---------------------------------------------------------------------
// Fig 11: final speedups at 99% target with 2 refinement samples

pub fn fig11(
    coord: &Coordinator,
    opts: &EvalOptions,
    seed: u64,
    probes: &mut ProbeMemo,
) -> Result<Table> {
    let mut t = Table::new(
        "fig11_final_speedup",
        &["network", "params", "chosen", "speedup", "measured_norm_acc"],
    );
    let mut speedups = Vec::new();
    for net in coord.zoo.by_size_desc() {
        let model = cross_validated_model(coord, &net.name, opts, seed)?;
        let cands = predictions_from_r2s(memo_probe_r2s(probes, &net, seed)?, &model);
        // refinement evaluations come from the (cached) accuracy table
        let table = coord.sweep(&net.name, &formats::design_space(1), opts)?;
        let na_of = |fm: &Format| -> f64 {
            table
                .iter()
                .find(|r| r.format == *fm)
                .map(|r| r.normalized_accuracy)
                .unwrap_or(0.0)
        };
        let sel = select_candidates(&cands, 0.99, 2, |fm| na_of(fm));
        if let Some((idx, _, _)) = sel {
            let chosen = cands[idx].0;
            let speedup = hw::speedup(&chosen);
            speedups.push(speedup);
            t.push(vec![
                net.name.clone(),
                net.n_params.to_string(),
                chosen.id(),
                f(speedup),
                f(na_of(&chosen)),
            ]);
        }
    }
    let gmean = if speedups.is_empty() {
        0.0
    } else {
        (speedups.iter().map(|s| s.ln()).sum::<f64>() / speedups.len() as f64).exp()
    };
    let amean = speedups.iter().sum::<f64>() / speedups.len().max(1) as f64;
    t.push(vec![
        "MEAN(arith)".into(),
        "-".into(),
        "-".into(),
        f(amean),
        "-".into(),
    ]);
    t.push(vec!["MEAN(geo)".into(), "-".into(), "-".into(), f(gmean), "-".into()]);
    Ok(t)
}

/// Helper for examples: summarize a sweep's Pareto frontier.
pub fn pareto(results: &[ConfigResult], target_na: f64) -> Option<&ConfigResult> {
    results
        .iter()
        .filter(|r| r.normalized_accuracy >= target_na)
        .max_by(|a, b| a.speedup.partial_cmp(&b.speedup).unwrap())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_is_monotone_and_normalized() {
        let t = fig4();
        assert_eq!(t.rows.len(), 23);
        let last = t.rows.last().unwrap();
        assert_eq!(last[0], "23");
        assert!((last[1].parse::<f64>().unwrap() - 1.0).abs() < 1e-9);
        assert!((last[2].parse::<f64>().unwrap() - 1.0).abs() < 1e-9);
        let mut prev = 0.0;
        for r in &t.rows {
            let d: f64 = r[1].parse().unwrap();
            assert!(d > prev);
            prev = d;
        }
    }

    #[test]
    fn fig5_baseline_row_is_unity() {
        let t = fig5();
        let base = &t.rows[0];
        assert_eq!(base[0], Format::SINGLE.id());
        assert!((base[6].parse::<f64>().unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn table_tsv_shape() {
        let mut t = Table::new("x", &["a", "b"]);
        t.push(vec!["1".into(), "2".into()]);
        let tsv = t.to_tsv();
        assert_eq!(tsv, "a\tb\n1\t2\n");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn table_rejects_misshapen_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.push(vec!["1".into()]);
    }
}
