//! Integer-domain fixed-point ops for packed-domain execution
//! (DESIGN.md §Packed execution).
//!
//! A fixed format `X(l, r)` quantizes onto the uniform grid `k · 2^-r`,
//! `|k| ≤ M = 2^(l+r) - 1`.  When **both** operands of every MAC are on
//! that grid, the staged-f32 chain `q(acc + q(a·w))` is an exact
//! computation over grid integers — so it can run as an integer MAC
//! chain on the tensor's packed two's-complement codes directly, with
//! ONE rescale (`· 2^-r`) per output element:
//!
//! * product: `q(f32(a·w)) ≡ clamp(rte_shr(i·j, r), ±M)` — exact while
//!   `i·j` is exactly representable in the f32 carrier, i.e. `M² < 2^24`
//!   ⇒ **`l + r ≤ 12`** ([`I32_MAX_TOTAL_BITS`]).  Beyond that the f32
//!   product rounds before the grid rounding (double rounding) and the
//!   chains genuinely diverge (e.g. `X(0,13)`: `4091·4915 = 20107265`
//!   rescales to 2455 directly but 2454 through f32).
//! * sum: `q(f32(acc + p)) ≡ clamp(acc + p, ±M)` — both addends are
//!   clamped to `±M`, so the sum magnitude `≤ 2M < 2^24` is exact, and
//!   `rte` of an on-grid value is the identity.
//! * `l + r ≤ 7` ([`I16_MAX_TOTAL_BITS`]) additionally bounds every
//!   intermediate (`|i·j| ≤ M² = 16129 < 2^15`) inside **i16**, so the
//!   whole chain runs in 16-bit lanes — debug-build overflow checks
//!   genuinely prove the bound.
//!
//! Clamp/round commute at the saturation boundary because `rte` is
//! monotone and the `M + 0.5` tie resolves to the even `M + 1` (`M` is
//! odd for `l + r ≥ 1`), which clamps back to `M` — identical to
//! clamping first.  The `-0.0` grid point is integer `0` on every path.
//!
//! [`PackedOp`] is the [`Quantizer`]-shaped dispatcher: built once per
//! format (when the format qualifies), it selects which monomorphized
//! `store::exec::gemm_packed_int::<A>` instantiation a kernel call runs
//! via [`with_packed_op!`](crate::with_packed_op) — the same
//! dispatch-once pattern as [`with_quant_op!`](crate::with_quant_op).
//!
//! [`Quantizer`]: crate::numerics::Quantizer

use crate::formats::Format;

/// `l + r` bound for the i16 accumulator lane: every product and
/// clamped sum fits 16 bits (`M² = 16129 < 2^15`).
pub const I16_MAX_TOTAL_BITS: u32 = 7;

/// `l + r` bound for integer execution at all: raw products must be
/// exactly representable in the f32 carrier (`M² < 2^24`), or the
/// staged chain's product rounding cannot be reproduced.
pub const I32_MAX_TOTAL_BITS: u32 = 12;

/// An accumulator integer for the packed MAC chain (i16 or i32).  The
/// arithmetic runs IN this type — no silent widening — so debug-build
/// overflow checks prove the width bounds the module docs derive.
pub trait AccInt: Copy + PartialEq + std::fmt::Debug + 'static {
    const ZERO: Self;
    /// Narrow from a decoded code (caller guarantees range).
    fn from_i64(v: i64) -> Self;
    /// Saturating f32 → integer conversion (`as`-cast semantics).
    fn from_f32(v: f32) -> Self;
    fn to_f32(self) -> f32;
    fn mul(self, rhs: Self) -> Self;
    fn add(self, rhs: Self) -> Self;
    /// Round-half-even of `self / 2^r` (exact rational RHE).
    fn rte_shr(self, r: u32) -> Self;
    /// Clamp into `[-m, m]`.
    fn clamp_mag(self, m: Self) -> Self;
}

macro_rules! impl_acc_int {
    ($t:ty) => {
        impl AccInt for $t {
            const ZERO: Self = 0;

            #[inline(always)]
            fn from_i64(v: i64) -> Self {
                debug_assert!(
                    <$t>::try_from(v).is_ok(),
                    "code {v} exceeds the accumulator width"
                );
                v as $t
            }

            #[inline(always)]
            fn from_f32(v: f32) -> Self {
                v as $t
            }

            #[inline(always)]
            fn to_f32(self) -> f32 {
                self as f32
            }

            #[inline(always)]
            fn mul(self, rhs: Self) -> Self {
                self * rhs
            }

            #[inline(always)]
            fn add(self, rhs: Self) -> Self {
                self + rhs
            }

            #[inline(always)]
            fn rte_shr(self, r: u32) -> Self {
                if r == 0 {
                    return self;
                }
                // arithmetic shift floors; the masked remainder is the
                // non-negative fractional part in grid units
                let down = self >> r;
                let rem = self & ((1 << r) - 1);
                let half = 1 << (r - 1);
                down + (rem > half || (rem == half && (down & 1) == 1)) as $t
            }

            #[inline(always)]
            fn clamp_mag(self, m: Self) -> Self {
                self.clamp(-m, m)
            }
        }
    };
}

impl_acc_int!(i16);
impl_acc_int!(i32);

/// The integer-domain counterpart of [`crate::numerics::QFixed`]: the
/// fixed format's grid constants in accumulator units.  `A` is the lane
/// width ([`PackedOp::for_format`] picks it from `l + r`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QFixedInt<A> {
    /// fractional shift `r` — the one rescale per product
    r: u32,
    /// grid bound `M = 2^(l+r) - 1` in grid units
    max: A,
    /// `2^r` (exact): stages on-grid f32 values to grid integers
    scale: f32,
    /// `2^-r` (exact): the final rescale per output element
    inv_scale: f32,
}

impl<A: AccInt> QFixedInt<A> {
    /// Stage a value that is ON the grid (an output of the format's own
    /// quantizer — the router's upstream condition) to grid units.
    #[inline(always)]
    pub fn stage(&self, x: f32) -> A {
        // x = k·2^-r exactly, so the scaling recovers k exactly
        A::from_f32(x * self.scale)
    }

    /// Stage a possibly OFF-grid value (a raw bias) to grid units:
    /// `clamp(rte(x·2^r), ±M)` — bit-equivalent to staging `q(x)`
    /// (clamp/round commute; module docs).
    #[inline(always)]
    pub fn stage_rounded(&self, x: f32) -> A {
        A::from_f32((x * self.scale).round_ties_even()).clamp_mag(self.max)
    }

    /// One product in grid units: `q(f32(a·w))` as integers.
    #[inline(always)]
    pub fn product(&self, a: A, w: A) -> A {
        a.mul(w).rte_shr(self.r).clamp_mag(self.max)
    }

    /// One accumulate in grid units: `q(f32(acc + p))` as integers.
    #[inline(always)]
    pub fn accumulate(&self, acc: A, p: A) -> A {
        acc.add(p).clamp_mag(self.max)
    }

    /// Back to the f32 carrier — exact (`|acc| ≤ M < 2^24`, then a
    /// power-of-two rescale).
    #[inline(always)]
    pub fn finish(&self, acc: A) -> f32 {
        acc.to_f32() * self.inv_scale
    }
}

/// The thin dispatcher over the integer-lane instantiations — the
/// [`Quantizer`](crate::numerics::Quantizer) counterpart for
/// packed-domain execution.  [`PackedOp::for_format`] is the width
/// bound in type form: formats it returns `None` for CANNOT run the
/// integer chain bit-exactly and must route elsewhere (store::exec).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum PackedOp {
    /// `l + r ≤ 7`: the whole chain fits 16-bit lanes.
    I16(QFixedInt<i16>),
    /// `7 < l + r ≤ 12`: products exact in f32, chain fits i32.
    I32(QFixedInt<i32>),
}

impl PackedOp {
    /// The integer op for `fmt`, if the format's chain is bit-exactly
    /// representable as integer MACs (fixed, `l + r ≤ 12`).  Floats and
    /// wider fixeds return `None` — they route to LUT or staged-f32.
    pub fn for_format(fmt: &Format) -> Option<PackedOp> {
        let Format::Fixed { int_bits, frac_bits } = *fmt else {
            return None;
        };
        let t = int_bits + frac_bits;
        if t > I32_MAX_TOTAL_BITS {
            return None; // f32 product rounding is not reproducible
        }
        let r = frac_bits;
        let scale = 2.0f32.powi(r as i32);
        let max = (1i64 << t) - 1;
        Some(if t <= I16_MAX_TOTAL_BITS {
            PackedOp::I16(QFixedInt {
                r,
                max: max as i16,
                scale,
                inv_scale: 1.0 / scale,
            })
        } else {
            PackedOp::I32(QFixedInt {
                r,
                max: max as i32,
                scale,
                inv_scale: 1.0 / scale,
            })
        })
    }

    /// Stats/CLI label of the selected lane.
    pub fn label(&self) -> &'static str {
        match self {
            PackedOp::I16(_) => "int16",
            PackedOp::I32(_) => "int32",
        }
    }
}

/// Select the monomorphized integer-lane instantiation:
/// `with_packed_op!(p, op => body)` binds `op` to the variant's
/// [`QFixedInt`] (`&QFixedInt<i16>` / `&QFixedInt<i32>`) and runs
/// `body` once — the [`with_quant_op!`](crate::with_quant_op) pattern
/// for the packed-int kernels.  `p` must be a `&PackedOp`.
#[macro_export]
macro_rules! with_packed_op {
    ($p:expr, $op:ident => $body:expr) => {
        match $p {
            $crate::numerics::PackedOp::I16($op) => $body,
            $crate::numerics::PackedOp::I32($op) => $body,
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::numerics::Quantizer;
    use crate::testing::prop::{run_prop, Gen};

    #[test]
    fn for_format_width_bounds() {
        // lane selection is exactly the l + r thresholds
        for (l, r, want) in [
            (0u32, 2u32, Some("int16")),
            (3, 3, Some("int16")),
            (0, 7, Some("int16")),
            (7, 0, Some("int16")),
            (4, 4, Some("int32")),
            (8, 4, Some("int32")),
            (6, 6, Some("int32")),
            (0, 12, Some("int32")),
            (12, 0, Some("int32")),
            (6, 7, None), // t = 13: double rounding becomes possible
            (8, 8, None),
            (16, 16, None),
        ] {
            let got = PackedOp::for_format(&Format::fixed(l, r)).map(|p| p.label());
            assert_eq!(got, want, "fixed:l{l}r{r}");
        }
        // floats and the exact baseline never take the integer lane
        assert!(PackedOp::for_format(&Format::float(7, 6)).is_none());
        assert!(PackedOp::for_format(&Format::SINGLE).is_none());
    }

    /// `rte_shr` against an independent exact reference: f64 division is
    /// exact for these magnitudes, and f64 `round_ties_even` IS rational
    /// round-half-even.
    #[test]
    fn prop_rte_shr_is_round_half_even() {
        run_prop("rte_shr_rhe", 500, |g| {
            let r = g.usize_in(0, 12) as u32;
            let p = g.int_in(-(1 << 24), 1 << 24) as i32;
            let want = ((p as f64) / 2f64.powi(r as i32)).round_ties_even() as i32;
            assert_eq!(p.rte_shr(r), want, "p={p} r={r}");
            let p16 = g.int_in(-(1 << 14), 1 << 14) as i16;
            let r16 = g.usize_in(0, 7) as u32;
            let want16 = ((p16 as f64) / 2f64.powi(r16 as i32)).round_ties_even() as i16;
            assert_eq!(p16.rte_shr(r16), want16, "p={p16} r={r16}");
        });
    }

    /// The product/accumulate/finish ops against the scalar f32
    /// reference chain, through the real dispatch — on-grid operands
    /// drawn across every `(l, r)` regime both lanes cover.
    #[test]
    fn prop_integer_ops_match_f32_reference_chain() {
        run_prop("packed_int_vs_f32_chain", 400, |g| {
            let l = g.usize_in(0, 12) as u32;
            let r = g.usize_in(0, 12 - l as usize) as u32;
            let fmt = Format::fixed(l, r);
            let q = Quantizer::new(&fmt);
            let p = PackedOp::for_format(&fmt).expect("l + r <= 12 qualifies");
            let mx = 2.0f32.powi(l as i32) * 1.5;
            let k = g.usize_in(1, 24);
            let a: Vec<f32> = (0..k).map(|_| q.q(g.f32_in(-mx, mx))).collect();
            let w: Vec<f32> = (0..k).map(|_| q.q(g.f32_in(-mx, mx))).collect();
            let bias = g.f32_in(-mx, mx);

            // f32 reference: the gemm serial-k chain + add_bias_q step
            let mut want = 0.0f32;
            for i in 0..k {
                want = q.q(want + q.q(a[i] * w[i]));
            }
            want = q.q(want + q.q(bias));

            let got = crate::with_packed_op!(&p, op => {
                let mut acc = AccInt::ZERO;
                for i in 0..k {
                    acc = op.accumulate(acc, op.product(op.stage(a[i]), op.stage(w[i])));
                }
                op.finish(op.accumulate(acc, op.stage_rounded(bias)))
            });
            assert_eq!(
                got.to_bits(),
                want.to_bits(),
                "{}: int chain {got} vs f32 chain {want}",
                fmt.id()
            );
        });
    }

    /// Worst-case magnitudes at both lane boundaries: all-max operand
    /// vectors drive every intermediate to its peak — debug-build
    /// overflow checks fail loudly here if the width bounds were wrong.
    #[test]
    fn worst_case_magnitudes_stay_in_lane_at_the_boundaries() {
        for (l, r) in [(7u32, 0u32), (0, 7), (4, 3), (12, 0), (0, 12), (6, 6)] {
            let fmt = Format::fixed(l, r);
            let q = Quantizer::new(&fmt);
            let p = PackedOp::for_format(&fmt).unwrap();
            let max = q.q(f32::MAX); // the format's max grid point
            for k in [1usize, 2, 64, 300] {
                for sign in [1.0f32, -1.0] {
                    let a = vec![max; k];
                    let w = vec![sign * max; k];
                    let mut want = 0.0f32;
                    for i in 0..k {
                        want = q.q(want + q.q(a[i] * w[i]));
                    }
                    let got = crate::with_packed_op!(&p, op => {
                        let mut acc = AccInt::ZERO;
                        for i in 0..k {
                            acc = op.accumulate(
                                acc,
                                op.product(op.stage(a[i]), op.stage(w[i])),
                            );
                        }
                        op.finish(acc)
                    });
                    assert_eq!(
                        got.to_bits(),
                        want.to_bits(),
                        "fixed:l{l}r{r} k={k} sign={sign}"
                    );
                }
            }
        }
    }

    /// Signed zero: `-0.0` grid points stage to integer 0 and the chain
    /// finishes at `+0.0`, exactly like the f32 chain (whose
    /// accumulator never goes negative-zero: `+0 + -0 = +0`).
    #[test]
    fn negative_zero_stages_to_integer_zero() {
        let fmt = Format::fixed(4, 4);
        let q = Quantizer::new(&fmt);
        let p = PackedOp::for_format(&fmt).unwrap();
        crate::with_packed_op!(&p, op => {
            assert_eq!(op.stage(-0.0), 0);
            assert_eq!(op.stage_rounded(-0.03), 0, "q(-0.03) = -0.0 is integer 0");
            assert_eq!(q.q(-0.03).to_bits(), (-0.0f32).to_bits());
            let acc = op.accumulate(AccInt::ZERO, op.product(op.stage(-0.0), op.stage(1.0)));
            assert_eq!(op.finish(acc).to_bits(), 0.0f32.to_bits());
        });
    }
}
