//! The branch-light f32-carrier quantizer (hot path of the entire sweep).
//!
//! Float path: integer round-half-even on the raw f32 bits — adding the
//! tie-adjusted `half` into the mantissa field lets the carry propagate
//! into the exponent, which is exactly normalized rounding.  Overflow
//! saturates to the format's max-finite; values below the min normal
//! flush to zero (no subnormals).  Fixed path: clamp, scale,
//! `round_ties_even`, unscale, clamp.  Both match qformat.py bit-exactly
//! (same carrier, same operation order).

use crate::formats::Format;

/// Precomputed quantization constants for one [`Format`] — build once,
/// apply millions of times.
#[derive(Clone, Copy, Debug)]
pub struct Quantizer {
    kind: Kind,
    /// float: bits of f32 mantissa to drop (23 - m)
    shift: u32,
    /// float: min normal (f32-carrier clamped)
    min_normal: f32,
    /// saturation bound (both kinds)
    max_val: f32,
    /// fixed: 2^r and 2^-r
    scale: f32,
    inv_scale: f32,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Kind {
    Float,
    Fixed,
}

impl Quantizer {
    pub fn new(fmt: &Format) -> Quantizer {
        match *fmt {
            Format::Float { mantissa, .. } => Quantizer {
                kind: Kind::Float,
                shift: 23 - mantissa,
                min_normal: fmt.min_normal() as f32,
                max_val: fmt.max_value() as f32,
                scale: 0.0,
                inv_scale: 0.0,
            },
            Format::Fixed { frac_bits, .. } => {
                let scale = 2.0f64.powi(frac_bits as i32);
                Quantizer {
                    kind: Kind::Fixed,
                    shift: 0,
                    min_normal: 0.0,
                    max_val: fmt.max_value() as f32,
                    scale: scale as f32,
                    inv_scale: (1.0 / scale) as f32,
                }
            }
        }
    }

    /// Quantize one value.  `#[inline]` — this sits inside every MAC.
    #[inline(always)]
    pub fn q(&self, x: f32) -> f32 {
        match self.kind {
            Kind::Float => {
                let bits = x.to_bits();
                let sign = bits & 0x8000_0000;
                let mag = bits & 0x7FFF_FFFF;
                let shift = self.shift;
                let rmag = if shift == 0 {
                    mag
                } else {
                    let lsb = (mag >> shift) & 1;
                    let half = (1u32 << (shift - 1)) - 1 + lsb;
                    ((mag.wrapping_add(half)) >> shift) << shift
                };
                let y = f32::from_bits(rmag);
                // match the jnp `where` chain exactly (incl. NaN: both
                // comparisons false => NaN passes through)
                let y = if y > self.max_val { self.max_val } else { y };
                let y = if y < self.min_normal { 0.0 } else { y };
                f32::from_bits(sign | 0x3F80_0000) * y
            }
            Kind::Fixed => {
                let y = x.clamp(-self.max_val, self.max_val);
                let y = (y * self.scale).round_ties_even() * self.inv_scale;
                y.clamp(-self.max_val, self.max_val)
            }
        }
    }

    /// True if this quantizer is the identity on all normal f32 (the
    /// exact baseline F(23,8)).
    pub fn is_identity(&self) -> bool {
        self.kind == Kind::Float && self.shift == 0 && self.max_val == f32::MAX
    }
}

/// Quantize a whole value — convenience for tests/figures.
pub fn quantize(x: f32, fmt: &Format) -> f32 {
    Quantizer::new(fmt).q(x)
}

/// Quantize a slice in place.
pub fn quantize_slice(xs: &mut [f32], q: &Quantizer) {
    for x in xs.iter_mut() {
        *x = q.q(*x);
    }
}

/// One MAC step of the paper's §2 chain: `q(acc + q(a*b))`.
#[inline(always)]
pub fn mac_q(acc: f32, a: f32, b: f32, q: &Quantizer) -> f32 {
    q.q(acc + q.q(a * b))
}

/// Full per-op-truncated dot product in increasing-index order, starting
/// from a zero accumulator — the semantics of the Pallas kernel's K loop.
pub fn dot_q(a: &[f32], b: &[f32], q: &Quantizer) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        acc = mac_q(acc, a[i], b[i], q);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Format;
    use crate::testing::prop::{run_prop, Gen};

    fn qf(m: u32, e: u32) -> Quantizer {
        Quantizer::new(&Format::float(m, e))
    }

    fn qx(l: u32, r: u32) -> Quantizer {
        Quantizer::new(&Format::fixed(l, r))
    }

    #[test]
    fn float_identity_at_single() {
        let q = Quantizer::new(&Format::SINGLE);
        for &x in &[0.0f32, 1.5, -3.25e-12, 7.0e30, f32::MIN_POSITIVE] {
            assert_eq!(q.q(x).to_bits(), x.to_bits());
        }
    }

    #[test]
    fn float_round_half_even() {
        // m=2: grid 1.0, 1.25, 1.5, 1.75, 2.0; ties to even mantissa
        let q = qf(2, 4);
        assert_eq!(q.q(1.125), 1.0);
        assert_eq!(q.q(1.375), 1.5);
        assert_eq!(q.q(1.625), 1.5);
        assert_eq!(q.q(1.875), 2.0);
    }

    #[test]
    fn float_saturate_and_flush() {
        let q = qf(4, 4); // emin=-7, emax=8, max=(2-1/16)*256=496
        assert_eq!(q.q(1e6), 496.0);
        assert_eq!(q.q(-1e6), -496.0);
        assert_eq!(q.q(2.0f32.powi(-8)), 0.0);
        assert_eq!(q.q(2.0f32.powi(-7)), 2.0f32.powi(-7));
    }

    #[test]
    fn float_mantissa_carry_into_exponent() {
        // 1.1111...b rounds up to 2.0 at low mantissa widths
        let q = qf(2, 6);
        assert_eq!(q.q(1.999), 2.0);
        assert_eq!(q.q(3.999), 4.0);
    }

    #[test]
    fn fixed_grid_round_saturate() {
        let q = qx(4, 1); // step 0.5, max 15.5
        assert_eq!(q.q(0.25), 0.0); // tie to even
        assert_eq!(q.q(0.75), 1.0);
        assert_eq!(q.q(1.2), 1.0);
        assert_eq!(q.q(99.0), 15.5);
        assert_eq!(q.q(-99.0), -15.5);
    }

    #[test]
    fn paper_16bit_fixed_saturates_at_256() {
        let q = qx(8, 8);
        assert_eq!(q.q(300.0), 256.0 - 1.0 / 256.0);
    }

    #[test]
    fn dot_q_saturation_chain() {
        // paper §4.3: all-ones dot of length 64 saturates X(4,4) at ~16
        let q = qx(4, 4);
        let a = vec![1.0f32; 64];
        assert_eq!(dot_q(&a, &a, &q), 16.0 - 1.0 / 16.0);
    }

    #[test]
    fn dot_q_exact_matches_f32_serial() {
        let q = Quantizer::new(&Format::SINGLE);
        let a: Vec<f32> = (0..37).map(|i| (i as f32 * 0.37).sin()).collect();
        let b: Vec<f32> = (0..37).map(|i| (i as f32 * 0.11).cos()).collect();
        let mut acc = 0.0f32;
        for i in 0..37 {
            acc += a[i] * b[i];
        }
        assert_eq!(dot_q(&a, &b, &q), acc);
    }

    #[test]
    fn is_identity() {
        assert!(Quantizer::new(&Format::SINGLE).is_identity());
        assert!(!qf(22, 8).is_identity());
        assert!(!qx(8, 8).is_identity());
    }

    // ---- property tests ----------------------------------------------

    fn arb_float_format(g: &mut Gen) -> Format {
        Format::float(g.int_in(0, 23) as u32, g.int_in(2, 8) as u32)
    }

    fn arb_fixed_format(g: &mut Gen) -> Format {
        Format::fixed(g.int_in(0, 16) as u32, g.int_in(0, 16) as u32)
    }

    fn arb_value(g: &mut Gen) -> f32 {
        let mag = g.f32_in(0.0, 1.0) * 2.0f32.powi(g.int_in(-30, 30) as i32);
        if g.bool() {
            -mag
        } else {
            mag
        }
    }

    #[test]
    fn prop_float_idempotent() {
        run_prop("float_idempotent", 500, |g| {
            let q = Quantizer::new(&arb_float_format(g));
            let x = arb_value(g);
            let once = q.q(x);
            let twice = q.q(once);
            assert_eq!(once.to_bits(), twice.to_bits(), "x={x}");
        });
    }

    #[test]
    fn prop_fixed_idempotent() {
        run_prop("fixed_idempotent", 500, |g| {
            let q = Quantizer::new(&arb_fixed_format(g));
            let x = arb_value(g);
            let once = q.q(x);
            assert_eq!(once, q.q(once), "x={x}");
        });
    }

    #[test]
    fn prop_float_monotone() {
        run_prop("float_monotone", 500, |g| {
            let q = Quantizer::new(&arb_float_format(g));
            let (a, b) = (arb_value(g), arb_value(g));
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            assert!(q.q(lo) <= q.q(hi), "lo={lo} hi={hi}");
        });
    }

    #[test]
    fn prop_float_odd_symmetry() {
        run_prop("float_odd", 500, |g| {
            let q = Quantizer::new(&arb_float_format(g));
            let x = arb_value(g);
            // compare canonicalized (+0.0) to ignore the sign of zero
            assert_eq!((q.q(-x) + 0.0).to_bits(), (-q.q(x) + 0.0).to_bits());
        });
    }

    #[test]
    fn prop_bounded_by_max() {
        run_prop("bounded", 500, |g| {
            let fmt = if g.bool() { arb_float_format(g) } else { arb_fixed_format(g) };
            let q = Quantizer::new(&fmt);
            let y = q.q(arb_value(g) * 1e6);
            assert!(y.abs() as f64 <= fmt.max_value().max(f32::MAX as f64));
            assert!(y.is_finite());
        });
    }

    #[test]
    fn prop_error_bounded_by_half_ulp() {
        // for in-range values, |q(x) - x| <= 2^(exp(x) - m - 1) (half ULP)
        run_prop("half_ulp", 500, |g| {
            let m = g.int_in(1, 23) as u32;
            let fmt = Format::float(m, 8);
            let q = Quantizer::new(&fmt);
            let x = arb_value(g);
            if x != 0.0 && x.abs() >= fmt.min_normal() as f32 && (x.abs() as f64) < fmt.max_value() {
                let exp = x.abs().log2().floor() as i32;
                let half_ulp = 2.0f64.powi(exp - m as i32 - 1) * 1.0001;
                let err = (q.q(x) as f64 - x as f64).abs();
                assert!(err <= half_ulp, "x={x} m={m} err={err} bound={half_ulp}");
            }
        });
    }
}
