//! The branch-light f32-carrier quantizer (hot path of the entire sweep).
//!
//! Float path: integer round-half-even on the raw f32 bits — adding the
//! tie-adjusted `half` into the mantissa field lets the carry propagate
//! into the exponent, which is exactly normalized rounding.  Overflow
//! saturates to the format's max-finite; values below the min normal
//! flush to zero (no subnormals).  Fixed path: clamp, scale,
//! `round_ties_even`, unscale, clamp.  Both match qformat.py bit-exactly
//! (same carrier, same operation order).
//!
//! # Monomorphized kernels (DESIGN.md §Perf)
//!
//! Each representation kind is its own zero-branch op — [`QFloat`],
//! [`QFixed`], and the `Format::SINGLE` fast path [`QIdentity`] — all
//! implementing [`QuantOp`].  [`Quantizer`] is the thin enum that picks
//! one at construction time; hot loops dispatch ONCE per kernel call via
//! [`with_quant_op!`](crate::with_quant_op) and then run a fully
//! monomorphized instantiation (`q_slice::<Q>`, `nn::gemm_q::<Q>`), so
//! the per-MAC kind branch and the other kind's dead fields are gone
//! from the inner loops and the compiler can autovectorize them.
//! `Quantizer::q` remains the scalar reference semantics every
//! monomorphized kernel is property-tested against.

use crate::formats::Format;

/// One representation kind's quantization op: built once from a
/// [`Format`], applied millions of times.  Implementations carry ONLY
/// the constants their own kind needs (no zero-initialized fields for
/// the other kind), and their `q` contains no kind branch — which is
/// what lets `q_slice::<Q>` / [`crate::nn::gemm_q`]`::<Q>` vectorize.
pub trait QuantOp: Copy {
    /// Quantize one value.  The per-MAC op of the paper's §2 chain.
    fn q(&self, x: f32) -> f32;
}

/// Custom-float op `F(m, e)`: round-half-even on the raw f32 mantissa
/// bits, saturate to max-finite, flush below min-normal to zero.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QFloat {
    /// bits of f32 mantissa to drop (23 - m)
    shift: u32,
    /// min normal (f32-carrier clamped); smaller magnitudes flush to 0
    min_normal: f32,
    /// saturation bound (max representable finite magnitude)
    max_val: f32,
}

/// Custom-fixed op `X(l, r)`: clamp, scale by 2^r, `round_ties_even`,
/// unscale, clamp.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QFixed {
    /// 2^r
    scale: f32,
    /// 2^-r
    inv_scale: f32,
    /// saturation bound `2^l - 2^-r`
    max_val: f32,
}

/// The exact-baseline op for `Format::SINGLE` (F(23, 8)): the mantissa
/// rounding machinery is dead at m = 23, but the flush-to-zero and
/// ±inf-saturation steps are KEPT — normal operands can still cancel
/// into the subnormal window mid-chain, and dropping the flush would
/// silently break the 0-ulp contract with the Pallas/PJRT path
/// (`single_fast_path_is_bitexact_even_off_normal_range` in nn::engine).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QIdentity;

impl QuantOp for QFloat {
    #[inline(always)]
    fn q(&self, x: f32) -> f32 {
        let bits = x.to_bits();
        let sign = bits & 0x8000_0000;
        let mag = bits & 0x7FFF_FFFF;
        let shift = self.shift;
        // `shift == 0` (F(23, e<8)) skips the rounding add; this is a
        // loop-invariant, perfectly predicted branch — the per-MAC
        // *kind* branch is what monomorphization removed.
        let rmag = if shift == 0 {
            mag
        } else {
            let lsb = (mag >> shift) & 1;
            let half = (1u32 << (shift - 1)) - 1 + lsb;
            ((mag.wrapping_add(half)) >> shift) << shift
        };
        let y = f32::from_bits(rmag);
        // match the jnp `where` chain exactly (incl. NaN: both
        // comparisons false => NaN passes through)
        let y = if y > self.max_val { self.max_val } else { y };
        let y = if y < self.min_normal { 0.0 } else { y };
        f32::from_bits(sign | 0x3F80_0000) * y
    }
}

impl QuantOp for QFixed {
    #[inline(always)]
    fn q(&self, x: f32) -> f32 {
        let y = x.clamp(-self.max_val, self.max_val);
        let y = (y * self.scale).round_ties_even() * self.inv_scale;
        y.clamp(-self.max_val, self.max_val)
    }
}

impl QuantOp for QIdentity {
    /// [`QFloat::q`] at F(23, 8) with the (no-op) rounding removed:
    /// flush subnormal magnitudes to zero, saturate ±inf to max-finite,
    /// pass NaN through — the same operation order as the generic float
    /// path, so bit-exact with it on every input.
    #[inline(always)]
    fn q(&self, x: f32) -> f32 {
        let bits = x.to_bits();
        let sign = bits & 0x8000_0000;
        let mag = f32::from_bits(bits & 0x7FFF_FFFF);
        let y = if mag > f32::MAX { f32::MAX } else { mag };
        let y = if y < f32::MIN_POSITIVE { 0.0 } else { y };
        f32::from_bits(sign | 0x3F80_0000) * y
    }
}

/// The thin enum dispatcher over the three monomorphized ops: built
/// once per [`Format`], it selects which `gemm_q::<Q>` / `q_slice::<Q>`
/// instantiation a kernel call runs (via
/// [`with_quant_op!`](crate::with_quant_op)).  Each variant carries
/// exactly its own kind's constants — the old struct's zero-initialized
/// wrong-kind fields (`scale`/`inv_scale` on floats, `shift`/
/// `min_normal` on fixeds) no longer exist, see
/// `quantizer_debug_carries_no_dead_fields`.
///
/// [`Quantizer::q`] is the scalar reference semantics; it also
/// implements [`QuantOp`] itself (the *dynamic* instantiation, one kind
/// branch per call) so generic code can fall back to it — but hot paths
/// must dispatch first.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Quantizer {
    /// `Format::SINGLE` — the exact baseline fast path.
    Identity(QIdentity),
    /// Any other custom float `F(m, e)`.
    Float(QFloat),
    /// Any custom fixed `X(l, r)`.
    Fixed(QFixed),
}

// One enum discriminant + the widest op's three 4-byte constants: the
// dispatcher must never grow past four words, or it stops being "build
// once, copy into every per-layer table slot" cheap (nn::QuantTable).
const _: () = assert!(std::mem::size_of::<Quantizer>() <= 16);

impl Quantizer {
    pub fn new(fmt: &Format) -> Quantizer {
        match *fmt {
            // F(23, 8) is the only format whose rounding, saturation
            // bound, and flush threshold all coincide with the f32
            // carrier's own — the monomorphized identity fast path.
            Format::SINGLE => Quantizer::Identity(QIdentity),
            Format::Float { mantissa, .. } => Quantizer::Float(QFloat {
                shift: 23 - mantissa,
                min_normal: fmt.min_normal() as f32,
                max_val: fmt.max_value() as f32,
            }),
            Format::Fixed { frac_bits, .. } => {
                let scale = 2.0f64.powi(frac_bits as i32);
                Quantizer::Fixed(QFixed {
                    scale: scale as f32,
                    inv_scale: (1.0 / scale) as f32,
                    max_val: fmt.max_value() as f32,
                })
            }
        }
    }

    /// Quantize one value — the scalar REFERENCE path (one kind branch
    /// per call).  Every monomorphized kernel is bit-identity
    /// property-tested against this.
    #[inline(always)]
    pub fn q(&self, x: f32) -> f32 {
        match self {
            Quantizer::Identity(q) => q.q(x),
            Quantizer::Float(q) => q.q(x),
            Quantizer::Fixed(q) => q.q(x),
        }
    }

    /// True if this quantizer is the `Format::SINGLE` fast path (the
    /// exact baseline F(23,8)).
    pub fn is_identity(&self) -> bool {
        matches!(self, Quantizer::Identity(_))
    }
}

/// The dynamic fallback instantiation: a kind branch per call — the
/// pre-monomorphization behaviour, kept so generic code compiles
/// against `&Quantizer` and so the bench suite can measure what the
/// dispatch refactor bought.  Hot paths go through
/// [`with_quant_op!`](crate::with_quant_op) instead.
impl QuantOp for Quantizer {
    #[inline(always)]
    fn q(&self, x: f32) -> f32 {
        // method-call syntax resolves the *inherent* `Quantizer::q`
        // (the match), not this trait method — no recursion
        (*self).q(x)
    }
}

/// Select the monomorphized instantiation for a quantizer's kind:
/// `with_quant_op!(q, op => body)` expands to a three-way match that
/// binds `op` to the variant's [`QuantOp`] (`&QFloat` / `&QFixed` /
/// `&QIdentity`) and runs `body` once — so the kind branch is hoisted
/// out of whatever loop `body` contains.  `q` must be a `&Quantizer`.
///
/// ```
/// use precis::formats::Format;
/// use precis::numerics::{q_slice, Quantizer};
///
/// let q = Quantizer::new(&Format::float(7, 6));
/// let mut xs = vec![1.37f32, -0.002, 9.0];
/// precis::with_quant_op!(&q, op => q_slice(&mut xs, op));
/// assert_eq!(xs[0], q.q(1.37));
/// ```
#[macro_export]
macro_rules! with_quant_op {
    ($q:expr, $op:ident => $body:expr) => {
        match $q {
            $crate::numerics::Quantizer::Identity($op) => $body,
            $crate::numerics::Quantizer::Float($op) => $body,
            $crate::numerics::Quantizer::Fixed($op) => $body,
        }
    };
}

/// Quantize a whole value — convenience for tests/figures.
pub fn quantize(x: f32, fmt: &Format) -> f32 {
    Quantizer::new(fmt).q(x)
}

/// Fixed lane width of [`q_slice`]'s main loop (array-of-lanes
/// restructuring for stable-Rust auto-vectorization; DESIGN.md §Perf).
const Q_SLICE_LANES: usize = 8;

/// The monomorphized slice kernel: one `Q` instantiation per op kind,
/// no per-element kind branch — used for input staging and weight
/// staging in the engine (via [`quantize_slice`]'s dispatch).
///
/// The main loop walks fixed-width `Q_SLICE_LANES` chunks through a
/// local array, applying the identical scalar `q` per lane — same ops,
/// same bits, but a shape the vectorizer can turn into vector code for
/// the branch-minimal monomorphized op bodies.  The ragged tail runs
/// the plain scalar loop.
#[inline]
pub fn q_slice<Q: QuantOp>(xs: &mut [f32], q: &Q) {
    let mut chunks = xs.chunks_exact_mut(Q_SLICE_LANES);
    for c in &mut chunks {
        let mut v = [0f32; Q_SLICE_LANES];
        v.copy_from_slice(c);
        for lane in v.iter_mut() {
            *lane = q.q(*lane);
        }
        c.copy_from_slice(&v);
    }
    for x in chunks.into_remainder().iter_mut() {
        *x = q.q(*x);
    }
}

/// Quantize a slice in place: thin dispatch to the monomorphized
/// [`q_slice`] instantiation for `q`'s kind.
pub fn quantize_slice(xs: &mut [f32], q: &Quantizer) {
    with_quant_op!(q, op => q_slice(xs, op));
}

/// One MAC step of the paper's §2 chain: `q(acc + q(a*b))`.
#[inline(always)]
pub fn mac_q(acc: f32, a: f32, b: f32, q: &Quantizer) -> f32 {
    q.q(acc + q.q(a * b))
}

/// Full per-op-truncated dot product in increasing-index order, starting
/// from a zero accumulator — the semantics of the Pallas kernel's K loop.
pub fn dot_q(a: &[f32], b: &[f32], q: &Quantizer) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = 0.0f32;
    for i in 0..a.len() {
        acc = mac_q(acc, a[i], b[i], q);
    }
    acc
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::formats::Format;
    use crate::testing::prop::{arb_format, run_prop, Gen};

    fn qf(m: u32, e: u32) -> Quantizer {
        Quantizer::new(&Format::float(m, e))
    }

    fn qx(l: u32, r: u32) -> Quantizer {
        Quantizer::new(&Format::fixed(l, r))
    }

    #[test]
    fn float_identity_at_single() {
        let q = Quantizer::new(&Format::SINGLE);
        for &x in &[0.0f32, 1.5, -3.25e-12, 7.0e30, f32::MIN_POSITIVE] {
            assert_eq!(q.q(x).to_bits(), x.to_bits());
        }
    }

    #[test]
    fn float_round_half_even() {
        // m=2: grid 1.0, 1.25, 1.5, 1.75, 2.0; ties to even mantissa
        let q = qf(2, 4);
        assert_eq!(q.q(1.125), 1.0);
        assert_eq!(q.q(1.375), 1.5);
        assert_eq!(q.q(1.625), 1.5);
        assert_eq!(q.q(1.875), 2.0);
    }

    #[test]
    fn float_saturate_and_flush() {
        let q = qf(4, 4); // emin=-7, emax=8, max=(2-1/16)*256=496
        assert_eq!(q.q(1e6), 496.0);
        assert_eq!(q.q(-1e6), -496.0);
        assert_eq!(q.q(2.0f32.powi(-8)), 0.0);
        assert_eq!(q.q(2.0f32.powi(-7)), 2.0f32.powi(-7));
    }

    #[test]
    fn float_mantissa_carry_into_exponent() {
        // 1.1111...b rounds up to 2.0 at low mantissa widths
        let q = qf(2, 6);
        assert_eq!(q.q(1.999), 2.0);
        assert_eq!(q.q(3.999), 4.0);
    }

    #[test]
    fn fixed_grid_round_saturate() {
        let q = qx(4, 1); // step 0.5, max 15.5
        assert_eq!(q.q(0.25), 0.0); // tie to even
        assert_eq!(q.q(0.75), 1.0);
        assert_eq!(q.q(1.2), 1.0);
        assert_eq!(q.q(99.0), 15.5);
        assert_eq!(q.q(-99.0), -15.5);
    }

    #[test]
    fn paper_16bit_fixed_saturates_at_256() {
        let q = qx(8, 8);
        assert_eq!(q.q(300.0), 256.0 - 1.0 / 256.0);
    }

    #[test]
    fn dot_q_saturation_chain() {
        // paper §4.3: all-ones dot of length 64 saturates X(4,4) at ~16
        let q = qx(4, 4);
        let a = vec![1.0f32; 64];
        assert_eq!(dot_q(&a, &a, &q), 16.0 - 1.0 / 16.0);
    }

    #[test]
    fn dot_q_exact_matches_f32_serial() {
        let q = Quantizer::new(&Format::SINGLE);
        let a: Vec<f32> = (0..37).map(|i| (i as f32 * 0.37).sin()).collect();
        let b: Vec<f32> = (0..37).map(|i| (i as f32 * 0.11).cos()).collect();
        let mut acc = 0.0f32;
        for i in 0..37 {
            acc += a[i] * b[i];
        }
        assert_eq!(dot_q(&a, &b, &q), acc);
    }

    #[test]
    fn is_identity() {
        assert!(Quantizer::new(&Format::SINGLE).is_identity());
        assert!(!qf(22, 8).is_identity());
        assert!(!qx(8, 8).is_identity());
    }

    /// The dispatcher selects exactly one monomorphized op per kind:
    /// `SINGLE` → [`QIdentity`], other floats → [`QFloat`], fixeds →
    /// [`QFixed`] — the `with_quant_op!` arm that runs is the kind's own.
    #[test]
    fn new_selects_the_monomorphized_op_per_kind() {
        assert!(matches!(Quantizer::new(&Format::SINGLE), Quantizer::Identity(_)));
        assert!(matches!(qf(7, 6), Quantizer::Float(_)));
        assert!(matches!(qf(23, 4), Quantizer::Float(_))); // shift 0 but clamped
        assert!(matches!(qx(8, 8), Quantizer::Fixed(_)));
    }

    /// Regression for the dead-field cleanup (ISSUE 4): each kind's
    /// variant `Debug`-renders only its own constants — a float carries
    /// no `scale`/`inv_scale`, a fixed no `shift`/`min_normal`, and the
    /// identity op nothing at all.  (The old struct zero-initialized the
    /// wrong kind's fields and branch-guarded them per MAC.)
    #[test]
    fn quantizer_debug_carries_no_dead_fields() {
        let f = format!("{:?}", qf(7, 6));
        assert!(f.contains("Float") && f.contains("shift"), "{f}");
        assert!(!f.contains("scale"), "float op leaked fixed fields: {f}");

        let x = format!("{:?}", qx(8, 8));
        assert!(x.contains("Fixed") && x.contains("scale"), "{x}");
        assert!(
            !x.contains("shift") && !x.contains("min_normal"),
            "fixed op leaked float fields: {x}"
        );

        let i = format!("{:?}", Quantizer::new(&Format::SINGLE));
        assert!(i.contains("Identity"), "{i}");
        assert!(
            !i.contains("shift") && !i.contains("scale"),
            "identity op carries constants: {i}"
        );
    }

    // ---- property tests ----------------------------------------------

    fn arb_float_format(g: &mut Gen) -> Format {
        Format::float(g.int_in(0, 23) as u32, g.int_in(2, 8) as u32)
    }

    fn arb_fixed_format(g: &mut Gen) -> Format {
        Format::fixed(g.int_in(0, 16) as u32, g.int_in(0, 16) as u32)
    }

    fn arb_value(g: &mut Gen) -> f32 {
        let mag = g.f32_in(0.0, 1.0) * 2.0f32.powi(g.int_in(-30, 30) as i32);
        if g.bool() {
            -mag
        } else {
            mag
        }
    }

    /// Satellite (ISSUE 4): the monomorphized `q_slice::<Q>` — reached
    /// through the `quantize_slice` dispatch, so the selected `Q` is the
    /// one the engine would run — is bit-identical to the scalar
    /// `Quantizer::q` reference for every kind, including the
    /// `QIdentity`/`Format::SINGLE` fast path.
    #[test]
    fn prop_q_slice_mono_bitexact_vs_scalar_reference() {
        run_prop("q_slice_mono_vs_scalar", 300, |g| {
            let fmt = arb_format(g);
            let q = Quantizer::new(&fmt);
            let xs: Vec<f32> = (0..g.usize_in(0, 64)).map(|_| arb_value(g)).collect();
            let mut got = xs.clone();
            quantize_slice(&mut got, &q);
            for (i, (&y, &x)) in got.iter().zip(&xs).enumerate() {
                assert_eq!(
                    y.to_bits(),
                    q.q(x).to_bits(),
                    "{} elem {i}: q_slice {y} vs scalar {}",
                    fmt.id(),
                    q.q(x)
                );
            }
        });
    }

    /// The dynamic fallback (`QuantOp for Quantizer`) and the dispatched
    /// monomorphized ops are the same function, bitwise.
    #[test]
    fn prop_dynamic_fallback_matches_dispatched_op() {
        run_prop("dyn_vs_mono", 300, |g| {
            let q = Quantizer::new(&arb_format(g));
            let x = arb_value(g);
            let via_mono = with_quant_op!(&q, op => op.q(x));
            let via_dyn = QuantOp::q(&q, x);
            assert_eq!(via_mono.to_bits(), via_dyn.to_bits(), "x={x}");
        });
    }

    #[test]
    fn prop_float_idempotent() {
        run_prop("float_idempotent", 500, |g| {
            let q = Quantizer::new(&arb_float_format(g));
            let x = arb_value(g);
            let once = q.q(x);
            let twice = q.q(once);
            assert_eq!(once.to_bits(), twice.to_bits(), "x={x}");
        });
    }

    #[test]
    fn prop_fixed_idempotent() {
        run_prop("fixed_idempotent", 500, |g| {
            let q = Quantizer::new(&arb_fixed_format(g));
            let x = arb_value(g);
            let once = q.q(x);
            assert_eq!(once, q.q(once), "x={x}");
        });
    }

    #[test]
    fn prop_float_monotone() {
        run_prop("float_monotone", 500, |g| {
            let q = Quantizer::new(&arb_float_format(g));
            let (a, b) = (arb_value(g), arb_value(g));
            let (lo, hi) = if a <= b { (a, b) } else { (b, a) };
            assert!(q.q(lo) <= q.q(hi), "lo={lo} hi={hi}");
        });
    }

    #[test]
    fn prop_float_odd_symmetry() {
        run_prop("float_odd", 500, |g| {
            let q = Quantizer::new(&arb_float_format(g));
            let x = arb_value(g);
            // compare canonicalized (+0.0) to ignore the sign of zero
            assert_eq!((q.q(-x) + 0.0).to_bits(), (-q.q(x) + 0.0).to_bits());
        });
    }

    #[test]
    fn prop_bounded_by_max() {
        run_prop("bounded", 500, |g| {
            let fmt = if g.bool() { arb_float_format(g) } else { arb_fixed_format(g) };
            let q = Quantizer::new(&fmt);
            let y = q.q(arb_value(g) * 1e6);
            assert!(y.abs() as f64 <= fmt.max_value().max(f32::MAX as f64));
            assert!(y.is_finite());
        });
    }

    #[test]
    fn prop_error_bounded_by_half_ulp() {
        // for in-range values, |q(x) - x| <= 2^(exp(x) - m - 1) (half ULP)
        run_prop("half_ulp", 500, |g| {
            let m = g.int_in(1, 23) as u32;
            let fmt = Format::float(m, 8);
            let q = Quantizer::new(&fmt);
            let x = arb_value(g);
            if x != 0.0 && x.abs() >= fmt.min_normal() as f32 && (x.abs() as f64) < fmt.max_value() {
                let exp = x.abs().log2().floor() as i32;
                let half_ulp = 2.0f64.powi(exp - m as i32 - 1) * 1.0001;
                let err = (q.q(x) as f64 - x as f64).abs();
                assert!(err <= half_ulp, "x={x} m={m} err={err} bound={half_ulp}");
            }
        });
    }
}
