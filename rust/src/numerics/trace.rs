//! Accumulation tracer — the instrument behind the paper's Figure 8.
//!
//! Records the running sum of a per-op-truncated dot product after every
//! MAC step, for a set of formats plus the exact f32 baseline, and flags
//! the first saturation event (the paper's "running sum exceeds 255
//! after 60 inputs" analysis for X(8,8) on AlexNet conv3).

use crate::formats::Format;
use crate::numerics::{mac_q, Quantizer};

/// The running-sum trajectory of one format over one neuron's inputs.
#[derive(Clone, Debug)]
pub struct AccumTrace {
    pub format: Format,
    /// running sum after each MAC step (len == number of inputs)
    pub running: Vec<f32>,
    /// first step index at which |acc| hit the format's max (saturation)
    pub first_saturation: Option<usize>,
    /// final accumulated value
    pub final_value: f32,
}

/// Trace the serialized accumulation `q(acc + q(w_i * x_i))` for one
/// neuron (weights/inputs in accumulation order).
pub fn trace_accumulation(weights: &[f32], inputs: &[f32], fmt: &Format) -> AccumTrace {
    assert_eq!(weights.len(), inputs.len());
    let q = Quantizer::new(fmt);
    let max = fmt.max_value() as f32;
    let mut acc = 0.0f32;
    let mut running = Vec::with_capacity(weights.len());
    let mut first_saturation = None;
    for i in 0..weights.len() {
        acc = mac_q(acc, weights[i], inputs[i], &q);
        if first_saturation.is_none() && acc.abs() >= max {
            first_saturation = Some(i);
        }
        running.push(acc);
    }
    AccumTrace {
        format: *fmt,
        final_value: acc,
        running,
        first_saturation,
    }
}

/// Exact serial-f32 baseline trajectory (the paper's black line).
pub fn trace_exact(weights: &[f32], inputs: &[f32]) -> Vec<f32> {
    assert_eq!(weights.len(), inputs.len());
    let mut acc = 0.0f32;
    weights
        .iter()
        .zip(inputs)
        .map(|(w, x)| {
            acc += w * x;
            acc
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_trace_is_prefix_sums() {
        let w = [1.0f32, 2.0, 3.0];
        let x = [1.0f32, 1.0, 1.0];
        assert_eq!(trace_exact(&w, &x), vec![1.0, 3.0, 6.0]);
    }

    #[test]
    fn narrow_fixed_saturates_early() {
        // constant positive inflow saturates X(4,4) (max ~16) at step 16
        let w = vec![1.0f32; 64];
        let x = vec![1.0f32; 64];
        let t = trace_accumulation(&w, &x, &Format::fixed(4, 4));
        assert_eq!(t.first_saturation, Some(15));
        assert_eq!(t.final_value, 16.0 - 1.0 / 16.0);
        // once saturated with positive inflow it stays saturated
        assert!(t.running[20..].iter().all(|&v| v == t.final_value));
    }

    #[test]
    fn wide_float_matches_exact() {
        let w: Vec<f32> = (0..100).map(|i| ((i * 37) % 13) as f32 * 0.1 - 0.6).collect();
        let x: Vec<f32> = (0..100).map(|i| ((i * 17) % 7) as f32 * 0.2 - 0.5).collect();
        let t = trace_accumulation(&w, &x, &Format::SINGLE);
        let e = trace_exact(&w, &x);
        assert_eq!(t.running, e);
        assert_eq!(t.first_saturation, None);
    }

    #[test]
    fn few_mantissa_bits_stall_small_increments() {
        // paper §4.3: F(m=2) — once the sum is large, increments below
        // the ULP round away entirely
        let n = 500;
        let w = vec![1.0f32; n];
        let x = vec![1.0f32; n];
        let t = trace_accumulation(&w, &x, &Format::float(2, 8));
        // sum stalls at 256: ULP(256) = 64 for m=2, so +1 rounds away
        assert!(t.final_value <= 256.0, "final {}", t.final_value);
        let e = *trace_exact(&w, &x).last().unwrap();
        assert_eq!(e, n as f32);
        assert!(t.final_value < e * 0.6);
    }
}
