//! Softfloat/softfixed quantizers and per-op-truncated MAC chains.
//!
//! This module is the Rust half of the repository's normative semantics
//! (defined in `python/compile/kernels/qformat.py`): every function here
//! is bit-exact against the jnp implementation and the Pallas kernel —
//! the `pjrt_cross_check` integration test proves it end-to-end through
//! whole networks.

mod quant;
pub mod trace;

pub use quant::{dot_q, mac_q, quantize, quantize_slice, Quantizer};
