//! Softfloat/softfixed quantizers and per-op-truncated MAC chains.
//!
//! This module is the Rust half of the repository's normative semantics
//! (defined in `python/compile/kernels/qformat.py`): every function here
//! is bit-exact against the jnp implementation and the Pallas kernel —
//! the `pjrt_cross_check` integration test proves it end-to-end through
//! whole networks.
//!
//! The hot path is **compile-time monomorphized** (DESIGN.md §Perf):
//! [`QuantOp`] has one zero-branch impl per representation kind
//! ([`QFloat`], [`QFixed`], [`QIdentity`]), [`Quantizer`] is the thin
//! enum that picks one per [`crate::formats::Format`], and kernels like
//! [`q_slice`] / [`crate::nn::gemm_q`] dispatch once per call via
//! [`with_quant_op!`](crate::with_quant_op) instead of branching per MAC.

mod packed;
mod quant;
pub mod trace;

pub use packed::{AccInt, PackedOp, QFixedInt, I16_MAX_TOTAL_BITS, I32_MAX_TOTAL_BITS};
pub use quant::{
    dot_q, mac_q, q_slice, quantize, quantize_slice, QFixed, QFloat, QIdentity, QuantOp, Quantizer,
};
