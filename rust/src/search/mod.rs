//! Efficient customized-precision search — the paper's §3.3 contribution.
//!
//! Exhaustively measuring classification accuracy for every design in
//! the space costs (#configs × #eval inputs) forward passes.  The
//! paper's insight: the *last-layer activations* of the quantized
//! network, compared with the exact network's on a handful of inputs,
//! capture the propagation of numerical error; their linear coefficient
//! of determination (R²) predicts normalized accuracy through a single
//! linear model that transfers **across networks and representations**
//! (Fig 9, fit correlation 0.96).
//!
//! Pipeline:
//! 1. [`activation_r2`] — R² between exact & quantized last-layer
//!    activations on ~10 probe inputs (`PROBE_INPUTS`).
//! 2. [`AccuracyModel`] — OLS fit of normalized-accuracy vs R² pairs,
//!    built from *other* networks (cross-validation, §4.4).
//! 3. [`search`] — predict accuracy for every design, pick the fastest
//!    one that clears the target, then (optionally) evaluate up to N
//!    candidates for real, moving one bit at a time (§3.3 refinement).
//! 4. [`plan_search`] — the per-layer generalization: a greedy descent
//!    over mixed-precision [`crate::formats::Plan`]s, ranking one-layer
//!    narrowing moves by probe-R² through the same accuracy model and
//!    validating only the surviving plan (`ladder^layers` is far too
//!    big to enumerate — which is the point of the fast search).

mod model;
mod plan;
mod runner;

pub use model::{collect_model_points, collect_model_points_cached, AccuracyModel, ModelPoint};
pub use plan::{default_ladder, plan_search, PlanSearchOutcome, PlanSearchSpec};
pub use runner::{
    exhaustive_search, predictions_from_r2s, probe_predictions, probe_r2s, search,
    select_candidates, SearchOutcome, SearchSpec,
};

use crate::util::stats::r_squared;

/// Number of probe inputs used for R² (paper: "only ten randomly
/// selected inputs").
pub const PROBE_INPUTS: usize = 10;

/// R² between exact and quantized last-layer activations (flattened
/// over all probe inputs and classes).
pub fn activation_r2(exact: &[f32], quant: &[f32]) -> f64 {
    debug_assert_eq!(exact.len(), quant.len());
    let e: Vec<f64> = exact.iter().map(|&v| v as f64).collect();
    let q: Vec<f64> = quant.iter().map(|&v| v as f64).collect();
    r_squared(&e, &q)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn r2_of_identical_activations_is_one() {
        let a = vec![0.5f32, -1.0, 2.0, 3.5];
        assert!((activation_r2(&a, &a) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn r2_of_saturated_constant_output_is_zero() {
        // a fully saturated quantized net emits constant logits:
        // zero variance => R² = 0 (accuracy is chance)
        let exact = vec![0.1f32, 0.9, -0.3, 0.7];
        let quant = vec![5.0f32; 4];
        assert_eq!(activation_r2(&exact, &quant), 0.0);
    }

    #[test]
    fn r2_degrades_with_noise() {
        let exact: Vec<f32> = (0..100).map(|i| (i as f32 * 0.37).sin()).collect();
        let slight: Vec<f32> = exact.iter().map(|v| v + 0.01 * (v * 17.0).cos()).collect();
        let heavy: Vec<f32> = exact.iter().map(|v| v + 0.8 * (v * 17.0).cos()).collect();
        let r_slight = activation_r2(&exact, &slight);
        let r_heavy = activation_r2(&exact, &heavy);
        assert!(r_slight > 0.99);
        assert!(r_heavy < r_slight);
    }
}
