//! The linear correlation→accuracy model (Fig 9).

use std::sync::Arc;

use anyhow::Result;

use crate::eval::metrics::topk_accuracy;
use crate::eval::sweep::{forward_eval, forward_indices, EvalOptions};
use crate::formats::Format;
use crate::nn::Network;
use crate::search::{activation_r2, PROBE_INPUTS};
use crate::serving::NativeBackend;
use crate::util::rng::Pcg32;
use crate::util::stats::{ols, pearson};

/// One (R², normalized accuracy) observation from some network+format.
#[derive(Clone, Copy, Debug)]
pub struct ModelPoint {
    pub r2: f64,
    pub normalized_accuracy: f64,
}

/// The fitted linear transformation `norm_acc ≈ a·R² + b`.
#[derive(Clone, Copy, Debug)]
pub struct AccuracyModel {
    pub a: f64,
    pub b: f64,
    /// fit quality (Pearson r of the training points; paper reports 0.96)
    pub fit_r: f64,
    pub n_points: usize,
}

impl AccuracyModel {
    pub fn fit(points: &[ModelPoint]) -> AccuracyModel {
        let xs: Vec<f64> = points.iter().map(|p| p.r2).collect();
        let ys: Vec<f64> = points.iter().map(|p| p.normalized_accuracy).collect();
        let (a, b) = ols(&xs, &ys);
        AccuracyModel {
            a,
            b,
            fit_r: pearson(&xs, &ys),
            n_points: points.len(),
        }
    }

    /// Predicted normalized accuracy for an observed R².
    pub fn predict(&self, r2: f64) -> f64 {
        (self.a * r2 + self.b).clamp(0.0, 1.5)
    }
}

/// Collect (R², normalized accuracy) pairs for every format in `formats`
/// on one network — the raw material of Fig 9 and of the cross-validated
/// search models.  Accuracy uses `opts.samples` inputs; R² uses only
/// [`PROBE_INPUTS`] (that asymmetry is the point of the method: the
/// pairs are collected *once*, offline, per reference network).
///
/// Accuracy measurements go through `cache` when provided (they are the
/// same numbers the Fig 6 sweep produces, keyed identically).
pub fn collect_model_points_cached(
    net: &Arc<Network>,
    formats: &[Format],
    opts: &EvalOptions,
    seed: u64,
    cache: Option<&crate::coordinator::cache::ResultCache>,
) -> Result<Vec<(Format, ModelPoint)>> {
    let mut backend = NativeBackend::new(net.clone());
    let samples = opts.samples.min(net.eval_len());

    // exact baseline: accuracy on the subset + probe activations
    let (base_logits, labels) = forward_eval(&mut backend, &Format::SINGLE, opts)?;
    let base_acc = topk_accuracy(&base_logits, &labels, net.classes, net.topk);

    let mut rng = Pcg32::seeded(seed);
    let probe = rng.sample_indices(net.eval_len(), PROBE_INPUTS.min(net.eval_len()));
    let exact_probe = forward_indices(&mut backend, &Format::SINGLE, &probe)?;

    let mut points = Vec::with_capacity(formats.len());
    for f in formats {
        let quant_probe = forward_indices(&mut backend, f, &probe)?;
        let r2 = activation_r2(&exact_probe, &quant_probe);
        let na = if let Some(hit) = cache.and_then(|c| c.get(&net.name, &f.id(), samples)) {
            hit.normalized_accuracy
        } else {
            let (logits, _) = forward_eval(&mut backend, f, opts)?;
            let acc = topk_accuracy(&logits, &labels, net.classes, net.topk);
            let na = if base_acc > 0.0 { acc / base_acc } else { 0.0 };
            if let Some(c) = cache {
                c.put(
                    &net.name,
                    &f.id(),
                    samples,
                    crate::coordinator::cache::CachedAccuracy {
                        accuracy: acc,
                        normalized_accuracy: na,
                    },
                );
            }
            na
        };
        points.push((*f, ModelPoint { r2, normalized_accuracy: na }));
    }
    Ok(points)
}

/// Uncached variant (tests, standalone use).
pub fn collect_model_points(
    net: &Arc<Network>,
    formats: &[Format],
    opts: &EvalOptions,
    seed: u64,
) -> Result<Vec<(Format, ModelPoint)>> {
    collect_model_points_cached(net, formats, opts, seed, None)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fit_recovers_linear_relation() {
        let pts: Vec<ModelPoint> = (0..20)
            .map(|i| {
                let r2 = i as f64 / 19.0;
                ModelPoint { r2, normalized_accuracy: 0.2 + 0.8 * r2 }
            })
            .collect();
        let m = AccuracyModel::fit(&pts);
        assert!((m.a - 0.8).abs() < 1e-9);
        assert!((m.b - 0.2).abs() < 1e-9);
        assert!(m.fit_r > 0.999);
        assert!((m.predict(1.0) - 1.0).abs() < 1e-9);
    }

    #[test]
    fn predict_is_clamped() {
        let m = AccuracyModel { a: 10.0, b: -2.0, fit_r: 1.0, n_points: 0 };
        assert_eq!(m.predict(0.0), 0.0);
        assert_eq!(m.predict(1.0), 1.5);
    }

    /// The whole pipeline runs on the in-memory fixture network, so the
    /// Backend-substrate plumbing is exercised without artifacts.
    #[test]
    fn collect_points_on_fixture_network() {
        let net = crate::testing::fixtures::tiny_network(16);
        let opts = EvalOptions { samples: 16, batch: 4 };
        let pts = collect_model_points(
            &net,
            &[Format::SINGLE, Format::float(7, 6), Format::fixed(0, 2)],
            &opts,
            7,
        )
        .unwrap();
        assert_eq!(pts.len(), 3);
        // exact format: perfect correlation with itself
        assert!((pts[0].1.r2 - 1.0).abs() < 1e-12);
    }
}
