//! Greedy per-layer mixed-precision search, over BOTH format axes.
//!
//! Per-layer split-precision assignment blows the design space up
//! combinatorially (`(ladder²)^layers` weight/activation plans), which
//! is exactly where the paper's fast probe machinery pays off: instead
//! of measuring accuracy for every plan, [`plan_search`] walks a
//! **greedy descent** —
//!
//! 1. start from the uniform-wide plan (every layer's weight AND
//!    activation half at `ladder[0]`);
//! 2. each round, propose narrowing ONE layer one ladder step on ONE
//!    axis (its weight half or its activation half — two proposals per
//!    layer); rank every proposal by its last-layer probe-R² (ten
//!    inputs, §3.3) mapped through the fitted [`AccuracyModel`], and
//!    accept the best-R² proposal whose *prediction* still clears the
//!    target — so the axis order per layer is chosen by which
//!    narrowing survives the probe;
//! 3. stop when no proposal clears; only then spend full accuracy
//!    evaluations — validate the surviving plan, and walk accepted
//!    moves (layer, axis) back one at a time if the measurement misses
//!    the target.
//!
//! Cost: `O(layers² · ladder)` ten-input probes plus a handful of full
//! evaluations, against `(ladder²)^layers` full evaluations for
//! exhaustive two-axis per-layer enumeration — the `repro plan`
//! subcommand reports both numbers, plus the
//! [`crate::hw::plan_speedup`] estimate of the chosen plan (priced
//! through the pair cost model when the descent split a layer's axes).

use std::sync::Arc;

use anyhow::{bail, Result};

use crate::eval::metrics::topk_accuracy;
use crate::eval::sweep::{forward_eval, forward_indices, EvalOptions};
use crate::formats::{Format, FormatPair, Plan, PrecisionSpec};
use crate::hw;
use crate::nn::Network;
use crate::search::model::AccuracyModel;
use crate::search::{activation_r2, PROBE_INPUTS};
use crate::serving::NativeBackend;
use crate::util::rng::Pcg32;

/// What the greedy per-layer search explores.
#[derive(Clone, Debug)]
pub struct PlanSearchSpec {
    /// Shared per-layer format ladder, widest first; `ladder[0]` is the
    /// uniform-wide starting point and the search only ever narrows.
    pub ladder: Vec<Format>,
    /// Normalized-accuracy target (paper: 0.99).
    pub target: f64,
    /// Budget of full accuracy evaluations for validating/backtracking
    /// the surviving plan (the probes are not counted — they are the
    /// cheap part).
    pub max_validations: usize,
    pub opts: EvalOptions,
    pub seed: u64,
}

impl Default for PlanSearchSpec {
    fn default() -> Self {
        PlanSearchSpec {
            ladder: default_ladder(),
            target: 0.99,
            max_validations: 4,
            opts: EvalOptions::default(),
            seed: 2018,
        }
    }
}

/// The default ladder: float formats from the exact baseline down to
/// 8 total bits, tracking the sweet-spot region of the paper's Fig 6.
pub fn default_ladder() -> Vec<Format> {
    vec![
        Format::SINGLE,
        Format::float(10, 6),
        Format::float(8, 6),
        Format::float(7, 6),
        Format::float(6, 5),
        Format::float(5, 5),
        Format::float(4, 5),
        Format::float(3, 4),
    ]
}

/// Result + cost accounting of one greedy per-layer search.
#[derive(Clone, Debug)]
pub struct PlanSearchOutcome {
    /// The chosen per-layer plan (explicit, one rule per layer).
    pub plan: Plan,
    /// Model prediction for the plan the descent stopped at.
    pub predicted_norm_acc: f64,
    /// Measured normalized accuracy of the returned plan.
    pub measured_norm_acc: f64,
    /// MAC-weighted `hw` speedup estimate of the returned plan.
    pub speedup: f64,
    /// Candidate plans probed (ten-input probes — the cheap currency).
    pub plans_probed: usize,
    /// Full accuracy evaluations spent on validation/backtracking.
    pub validations_spent: usize,
    /// Total forward passes in sample units (probes + baseline +
    /// validations).
    pub sample_forwards: usize,
    /// `(ladder²)^layers`: what exhaustive two-axis per-layer
    /// enumeration (every weight/activation pair per layer) would have
    /// had to validate.
    pub exhaustive_plans: f64,
}

/// Which half of a layer's [`FormatPair`] one descent move narrows.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Axis {
    Weight,
    Activation,
}

/// Run the greedy descent described in the module docs.  `model` maps
/// probe-R² to predicted normalized accuracy (use the cross-validated
/// fit, like the single-format search).
pub fn plan_search(
    net: &Arc<Network>,
    spec: &PlanSearchSpec,
    model: &AccuracyModel,
) -> Result<PlanSearchOutcome> {
    if spec.ladder.is_empty() {
        bail!("plan_search needs a non-empty format ladder");
    }
    let layers = net.quantized_layer_names();
    if layers.is_empty() {
        bail!("{}: no quantized layers to plan", net.name);
    }
    let mut backend = NativeBackend::new(net.clone());
    let samples = spec.opts.samples.min(net.eval_len());
    let probe_n = PROBE_INPUTS.min(net.eval_len());

    // probe inputs + exact reference activations, once (§3.3)
    let mut rng = Pcg32::seeded(spec.seed);
    let probe = rng.sample_indices(net.eval_len(), probe_n);
    let exact_probe = forward_indices(&mut backend, &Format::SINGLE, &probe)?;

    let plan_of = |pos: &[(usize, usize)]| -> Plan {
        let pairs: Vec<(String, FormatPair)> = layers
            .iter()
            .cloned()
            .zip(
                pos.iter()
                    .map(|&(wi, ai)| FormatPair::split(spec.ladder[wi], spec.ladder[ai])),
            )
            .collect();
        Plan::explicit_pairs(pairs).expect("quantized layer names are unique")
    };

    // ladder position per layer and axis; (0, 0) = uniform-widest
    let mut pos = vec![(0usize, 0usize); layers.len()];
    let mut plans_probed = 0usize;
    let probe_pred = |backend: &mut NativeBackend,
                      pos: &[(usize, usize)],
                      plans_probed: &mut usize|
     -> Result<f64> {
        let cand = PrecisionSpec::from(plan_of(pos));
        let qp = forward_indices(backend, &cand, &probe)?;
        *plans_probed += 1;
        Ok(model.predict(activation_r2(&exact_probe, &qp)))
    };

    // honest prediction for the uniform-wide start
    let start_pred = probe_pred(&mut backend, &pos, &mut plans_probed)?;
    let mut predicted = start_pred;
    // accepted moves in order: (layer, axis, prediction after the move)
    let mut accepted: Vec<(usize, Axis, f64)> = Vec::new();
    loop {
        let mut best: Option<(usize, Axis, f64)> = None;
        for li in 0..layers.len() {
            for axis in [Axis::Weight, Axis::Activation] {
                let (wi, ai) = pos[li];
                let stepped = match axis {
                    Axis::Weight => (wi + 1, ai),
                    Axis::Activation => (wi, ai + 1),
                };
                if stepped.0 >= spec.ladder.len() || stepped.1 >= spec.ladder.len() {
                    continue;
                }
                let mut cand = pos.to_vec();
                cand[li] = stepped;
                let pred = probe_pred(&mut backend, &cand, &mut plans_probed)?;
                // rank by prediction (a monotone map of probe-R²):
                // narrow the (layer, axis) that damages the
                // activations least
                let improves = match best {
                    Some((_, _, bp)) => pred > bp,
                    None => true,
                };
                if pred >= spec.target && improves {
                    best = Some((li, axis, pred));
                }
            }
        }
        let Some((li, axis, pred)) = best else { break };
        match axis {
            Axis::Weight => pos[li].0 += 1,
            Axis::Activation => pos[li].1 += 1,
        }
        accepted.push((li, axis, pred));
        predicted = pred;
    }

    // validation pass: measure the survivor; if it misses, un-narrow
    // the most recent accepted move and re-measure, within budget
    let (base_logits, labels) = forward_eval(&mut backend, &Format::SINGLE, &spec.opts)?;
    let base_acc = topk_accuracy(&base_logits, &labels, net.classes, net.topk);
    let mut validations = 0usize;
    let measured = loop {
        let cur = PrecisionSpec::from(plan_of(&pos));
        let (logits, _) = forward_eval(&mut backend, &cur, &spec.opts)?;
        let acc = topk_accuracy(&logits, &labels, net.classes, net.topk);
        let na = if base_acc > 0.0 { acc / base_acc } else { 0.0 };
        validations += 1;
        if na >= spec.target || validations >= spec.max_validations.max(1) {
            break na;
        }
        let Some((li, axis, _)) = accepted.pop() else { break na };
        match axis {
            Axis::Weight => pos[li].0 -= 1,
            Axis::Activation => pos[li].1 -= 1,
        }
        predicted = accepted.last().map(|&(_, _, p)| p).unwrap_or(start_pred);
    };

    let plan = plan_of(&pos);
    let resolved = plan.resolve(net)?;
    Ok(PlanSearchOutcome {
        plan,
        predicted_norm_acc: predicted,
        measured_norm_acc: measured,
        speedup: hw::plan_speedup(net, &resolved),
        plans_probed,
        validations_spent: validations,
        sample_forwards: (plans_probed + 1) * probe_n + (validations + 1) * samples,
        exhaustive_plans: (spec.ladder.len() as f64).powi(2 * layers.len() as i32),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testing::fixtures::tiny_conv_network;

    fn identity_model() -> AccuracyModel {
        AccuracyModel { a: 1.0, b: 0.0, fit_r: 1.0, n_points: 0 }
    }

    /// End-to-end greedy search on the two-layer fixture: finishes,
    /// meets the target after validation, and spends incomparably
    /// fewer full evaluations than exhaustive per-layer enumeration.
    #[test]
    fn plan_search_runs_on_fixture_and_validates_cheaply() {
        let net = tiny_conv_network(16);
        let spec = PlanSearchSpec {
            ladder: vec![
                Format::SINGLE,
                Format::float(10, 6),
                Format::float(5, 5),
                Format::float(2, 3),
            ],
            target: 0.99,
            // enough budget to walk all the way back to uniform-wide
            // (whose normalized accuracy is exactly 1.0 on the
            // self-labeled fixture), so the target is always reachable
            max_validations: 8,
            opts: EvalOptions { samples: 16, batch: 4 },
            seed: 7,
        };
        let out = plan_search(&net, &spec, &identity_model()).unwrap();

        assert!(out.measured_norm_acc >= spec.target, "{}", out.measured_norm_acc);
        assert_eq!(out.exhaustive_plans, 256.0, "(4 ladder steps ^ 2 axes) ^ 2 layers");
        assert!(
            (out.validations_spent as f64) < out.exhaustive_plans,
            "greedy must validate fewer plans than exhaustive ({} vs {})",
            out.validations_spent,
            out.exhaustive_plans
        );
        assert!(out.plans_probed >= 1);
        assert!(out.sample_forwards > 0);
        assert!(out.speedup >= 1.0 - 1e-9, "narrowing never slows down: {}", out.speedup);
        // the chosen plan is explicit and resolves on its network
        let resolved = out.plan.resolve(&net).unwrap();
        assert_eq!(resolved.assignments.len(), 2);
        for (_, pair) in &resolved.assignments {
            assert!(spec.ladder.contains(&pair.w), "{} weight half not from the ladder", pair.id());
            assert!(
                spec.ladder.contains(&pair.a),
                "{} activation half not from the ladder",
                pair.id()
            );
        }
        // round-trips through the session-key syntax
        let key = format!("tiny@{}", out.plan.id());
        assert!(crate::serving::SessionKey::parse(&key).is_ok());
    }

    /// Both axes really descend: with the target floored at zero every
    /// proposal clears, so the greedy walk must take each layer's
    /// weight AND activation half all the way down the ladder — the
    /// final plan is uniform-narrowest on both axes.
    #[test]
    fn two_axis_descent_narrows_both_halves() {
        let net = tiny_conv_network(8);
        let ladder = vec![Format::SINGLE, Format::float(10, 6), Format::float(5, 5)];
        let spec = PlanSearchSpec {
            ladder: ladder.clone(),
            target: 0.0,
            max_validations: 1,
            opts: EvalOptions { samples: 8, batch: 4 },
            seed: 7,
        };
        let out = plan_search(&net, &spec, &identity_model()).unwrap();
        assert_eq!(out.exhaustive_plans, 81.0, "(3^2)^2 two-axis plans");
        let resolved = out.plan.resolve(&net).unwrap();
        let narrowest = *ladder.last().unwrap();
        for (name, pair) in &resolved.assignments {
            assert_eq!(
                *pair,
                FormatPair::uniform(narrowest),
                "layer {name}: both axes must bottom out, got {}",
                pair.id()
            );
        }
        // 2 layers × 2 axes × 2 ladder steps accepted moves, each found
        // by probing; the start probe rides on top
        assert!(out.plans_probed > 8, "descent probed {} plans", out.plans_probed);
    }

    /// Degenerate inputs fail cleanly.
    #[test]
    fn plan_search_rejects_empty_ladder() {
        let net = tiny_conv_network(4);
        let spec = PlanSearchSpec { ladder: Vec::new(), ..Default::default() };
        assert!(plan_search(&net, &spec, &identity_model()).is_err());
    }

    /// A one-step ladder cannot narrow anything: the outcome is the
    /// uniform-wide plan, validated once.
    #[test]
    fn plan_search_with_singleton_ladder_returns_uniform_wide() {
        let net = tiny_conv_network(8);
        let spec = PlanSearchSpec {
            ladder: vec![Format::SINGLE],
            opts: EvalOptions { samples: 8, batch: 4 },
            ..Default::default()
        };
        let out = plan_search(&net, &spec, &identity_model()).unwrap();
        assert_eq!(out.measured_norm_acc, 1.0);
        assert_eq!(out.validations_spent, 1);
        assert!((out.speedup - 1.0).abs() < 1e-9);
        let resolved = out.plan.resolve(&net).unwrap();
        assert_eq!(resolved.uniform(), Some(Format::SINGLE));
    }
}
