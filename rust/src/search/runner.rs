//! Exhaustive baseline + the model-driven search with refinement (§3.3,
//! Figs 10/11).  All measurement passes execute through
//! [`crate::serving::Backend`] (the native backend), the same substrate
//! the request path serves on.

use std::sync::Arc;

use anyhow::Result;

use crate::eval::metrics::topk_accuracy;
use crate::eval::sweep::{forward_eval, forward_indices, EvalOptions};
use crate::formats::Format;
use crate::hw;
use crate::nn::Network;
use crate::search::model::AccuracyModel;
use crate::search::{activation_r2, PROBE_INPUTS};
use crate::serving::{Backend, NativeBackend};
use crate::util::rng::Pcg32;

/// What to search.
#[derive(Clone, Debug)]
pub struct SearchSpec {
    /// candidate formats (typically `formats::float_space()` or
    /// `fixed_space()` — the paper searches the two types separately in
    /// Fig 10 and takes the overall best in Fig 11)
    pub formats: Vec<Format>,
    /// normalized-accuracy target (paper: 0.99)
    pub target: f64,
    /// number of real accuracy evaluations allowed for refinement
    /// (paper: 0, 1 or 2 — 2 recovers the exhaustive choice)
    pub refine_samples: usize,
    pub opts: EvalOptions,
    pub seed: u64,
}

/// Search result + cost accounting.
#[derive(Clone, Debug)]
pub struct SearchOutcome {
    /// the selected configuration (None if nothing clears the target)
    pub chosen: Option<Format>,
    /// hardware speedup of the chosen configuration
    pub speedup: f64,
    /// its *measured* normalized accuracy (always validated post-hoc
    /// for reporting; not counted in `evals_spent` unless refinement
    /// requested it)
    pub measured_norm_acc: f64,
    /// number of full accuracy evaluations actually spent
    pub evals_spent: usize,
    /// total forward passes spent, in sample units (probes + evals)
    pub sample_forwards: usize,
}

fn norm_acc(
    backend: &mut dyn Backend,
    fmt: &Format,
    base_acc: f64,
    labels: &[i32],
    opts: &EvalOptions,
) -> Result<f64> {
    let (classes, topk) = {
        let net = backend.network();
        (net.classes, net.topk)
    };
    let (logits, _) = forward_eval(backend, fmt, opts)?;
    let acc = topk_accuracy(&logits, labels, classes, topk);
    Ok(if base_acc > 0.0 { acc / base_acc } else { 0.0 })
}

/// Exhaustive baseline: evaluate the real accuracy of EVERY candidate
/// and return the fastest one meeting the target, with the full result
/// table (this is also Fig 6's data source).
pub fn exhaustive_search(
    net: &Arc<Network>,
    spec: &SearchSpec,
) -> Result<(SearchOutcome, Vec<(Format, f64)>)> {
    let mut backend = NativeBackend::new(net.clone());
    let (base_logits, labels) = forward_eval(&mut backend, &Format::SINGLE, &spec.opts)?;
    let base_acc = topk_accuracy(&base_logits, &labels, net.classes, net.topk);

    let mut table = Vec::with_capacity(spec.formats.len());
    for f in &spec.formats {
        let na = norm_acc(&mut backend, f, base_acc, &labels, &spec.opts)?;
        table.push((*f, na));
    }
    let chosen = table
        .iter()
        .filter(|(_, na)| *na >= spec.target)
        .max_by(|a, b| {
            hw::speedup(&a.0)
                .partial_cmp(&hw::speedup(&b.0))
                .unwrap()
        })
        .map(|(f, _)| *f);
    let measured = chosen
        .and_then(|f| table.iter().find(|(g, _)| *g == f))
        .map(|(_, na)| *na)
        .unwrap_or(0.0);
    let samples = spec.opts.samples.min(net.eval_len());
    Ok((
        SearchOutcome {
            chosen,
            speedup: chosen.map(|f| hw::speedup(&f)).unwrap_or(0.0),
            measured_norm_acc: measured,
            evals_spent: spec.formats.len(),
            sample_forwards: spec.formats.len() * samples + samples,
        },
        table,
    ))
}

/// The refinement/selection core, factored out so callers can plug in
/// either a live backend (the `search` entry point) or a precomputed
/// accuracy table (the Fig 10 harness).  `cands` must be sorted fastest
/// first; `eval` returns the *measured* normalized accuracy of a
/// candidate.  Returns (chosen index, evaluations spent, last measured
/// value if the chosen one was measured).
pub fn select_candidates(
    cands: &[(Format, f64)],
    target: f64,
    refine_samples: usize,
    mut eval: impl FnMut(&Format) -> f64,
) -> Option<(usize, usize, Option<f64>)> {
    if cands.is_empty() {
        return None;
    }
    // fastest candidate whose prediction clears the target; when none
    // does (a conservatively-biased cross-network model can top out
    // just below a 0.99 target), fall back to the fastest candidate
    // whose prediction is within the model's own residual noise
    // (~half an accuracy point) of the best prediction — §3.3's
    // refinement loop then validates and walks from there.
    const MODEL_NOISE: f64 = 0.005;
    let start_idx = cands.iter().position(|(_, pred)| *pred >= target).unwrap_or_else(|| {
        let best = cands
            .iter()
            .map(|(_, p)| *p)
            .fold(f64::NEG_INFINITY, f64::max);
        cands
            .iter()
            .position(|(_, p)| *p >= best - MODEL_NOISE)
            .unwrap()
    });
    let mut idx = start_idx;
    let mut evals = 0usize;
    let mut measured: Option<f64> = None;
    while evals < refine_samples {
        let na = eval(&cands[idx].0);
        evals += 1;
        if na >= target {
            measured = Some(na);
            // try one step faster if the budget allows
            if idx > 0 && evals < refine_samples {
                let na_fast = eval(&cands[idx - 1].0);
                evals += 1;
                if na_fast >= target {
                    idx -= 1;
                    measured = Some(na_fast);
                }
            }
            break;
        } else if idx + 1 < cands.len() {
            idx += 1; // add precision: next-slower candidate
            measured = None;
        } else {
            break;
        }
    }
    Some((idx, evals, measured))
}

/// Probe pass: last-layer R² for every candidate on [`PROBE_INPUTS`]
/// probe inputs, sorted fastest-first.  R² is independent of the
/// accuracy model, so callers (the figure harness) can compute this
/// once per network and apply several models to it.
pub fn probe_r2s(
    net: &Arc<Network>,
    formats: &[Format],
    seed: u64,
) -> Result<Vec<(Format, f64)>> {
    let mut backend = NativeBackend::new(net.clone());
    let mut rng = Pcg32::seeded(seed);
    let probe = rng.sample_indices(net.eval_len(), PROBE_INPUTS.min(net.eval_len()));
    let exact_probe = forward_indices(&mut backend, &Format::SINGLE, &probe)?;
    let mut cands = Vec::with_capacity(formats.len());
    for f in formats {
        let qp = forward_indices(&mut backend, f, &probe)?;
        cands.push((*f, activation_r2(&exact_probe, &qp)));
    }
    cands.sort_by(|a, b| hw::speedup(&b.0).partial_cmp(&hw::speedup(&a.0)).unwrap());
    Ok(cands)
}

/// Map probe R²s through the accuracy model (preserves order).
pub fn predictions_from_r2s(r2s: &[(Format, f64)], model: &AccuracyModel) -> Vec<(Format, f64)> {
    r2s.iter().map(|(f, r2)| (*f, model.predict(*r2))).collect()
}

/// Probe pass + prediction (one-shot convenience).
pub fn probe_predictions(
    net: &Arc<Network>,
    formats: &[Format],
    model: &AccuracyModel,
    seed: u64,
) -> Result<Vec<(Format, f64)>> {
    Ok(predictions_from_r2s(&probe_r2s(net, formats, seed)?, model))
}

/// The §3.3 model-driven search.
///
/// 1. Compute R² on [`PROBE_INPUTS`] probe inputs for every candidate and
///    predict normalized accuracy through `model`.
/// 2. Sort candidates by hardware speedup (descending) and pick the
///    fastest whose *prediction* clears the target.
/// 3. Refinement (up to `refine_samples` real evaluations): if the pick
///    measures below target, step to the next-slower candidate (the
///    "add a bit" move generalized to the speedup ordering, which is the
///    bit ordering within a representation kind); if it measures above,
///    probe the next-faster one and keep it only if it also clears.
pub fn search(
    net: &Arc<Network>,
    spec: &SearchSpec,
    model: &AccuracyModel,
) -> Result<SearchOutcome> {
    let mut backend = NativeBackend::new(net.clone());
    let samples = spec.opts.samples.min(net.eval_len());

    // --- probe pass (cheap): R² + prediction per candidate ------------
    let cands = probe_predictions(net, &spec.formats, model, spec.seed)?;
    let mut sample_forwards =
        (spec.formats.len() + 1) * PROBE_INPUTS.min(net.eval_len());

    // baseline for real evaluations (shared by refinement + validation)
    let (base_logits, labels) = forward_eval(&mut backend, &Format::SINGLE, &spec.opts)?;
    let base_acc = topk_accuracy(&base_logits, &labels, net.classes, net.topk);
    sample_forwards += samples;

    // the selection closure is infallible by contract; a (native-path
    // impossible) backend error is parked and re-raised after selection
    let mut eval_error: Option<anyhow::Error> = None;
    let mut evals_spent = 0usize;
    let selection = select_candidates(&cands, spec.target, spec.refine_samples, |f| {
        evals_spent += 1;
        sample_forwards += samples;
        match norm_acc(&mut backend, f, base_acc, &labels, &spec.opts) {
            Ok(na) => na,
            Err(e) => {
                eval_error.get_or_insert(e);
                0.0
            }
        }
    });
    if let Some(e) = eval_error {
        return Err(e);
    }
    let Some((idx, evals, measured)) = selection else {
        return Ok(SearchOutcome {
            chosen: None,
            speedup: 0.0,
            measured_norm_acc: 0.0,
            evals_spent: 0,
            sample_forwards,
        });
    };
    debug_assert_eq!(evals, evals_spent);

    let chosen = cands[idx].0;
    // post-hoc validation (reporting only; not charged to the search)
    let measured_norm_acc = match measured {
        Some(na) => na,
        None => norm_acc(&mut backend, &chosen, base_acc, &labels, &spec.opts)?,
    };

    Ok(SearchOutcome {
        chosen: Some(chosen),
        speedup: hw::speedup(&chosen),
        measured_norm_acc,
        evals_spent: evals,
        sample_forwards,
    })
}

#[cfg(test)]
mod tests {
    // runner logic over real networks is covered by rust/tests/integration.rs;
    // here we test the pure selection mechanics with a synthetic table.
    use super::*;

    /// A synthetic speedup-sorted candidate ladder: faster = less
    /// accurate.  truth[i] is the measured normalized accuracy.
    fn ladder() -> (Vec<(Format, f64)>, Vec<f64>) {
        // float m=2..=10 at e=6, m ascending = speedup descending
        let cands: Vec<(Format, f64)> = (2..=10)
            .map(|m| (Format::float(m, 6), if m >= 5 { 1.0 } else { 0.5 }))
            .collect();
        let truth: Vec<f64> = (2..=10)
            .map(|m| if m >= 6 { 0.995 } else { 0.80 })
            .collect();
        (cands, truth)
    }

    fn eval_fn<'a>(
        cands: &'a [(Format, f64)],
        truth: &'a [f64],
        count: &'a mut usize,
    ) -> impl FnMut(&Format) -> f64 + 'a {
        move |f: &Format| {
            *count += 1;
            let i = cands.iter().position(|(g, _)| g == f).unwrap();
            truth[i]
        }
    }

    #[test]
    fn select_no_refinement_trusts_prediction() {
        let (cands, truth) = ladder();
        let mut n = 0;
        let (idx, evals, measured) =
            select_candidates(&cands, 0.99, 0, eval_fn(&cands, &truth, &mut n)).unwrap();
        // prediction clears at m=5 (idx 3), never validated
        assert_eq!(cands[idx].0, Format::float(5, 6));
        assert_eq!(evals, 0);
        assert!(measured.is_none());
        assert_eq!(n, 0);
    }

    #[test]
    fn select_one_refinement_steps_to_slower_on_failure() {
        let (cands, truth) = ladder();
        let mut n = 0;
        let (idx, evals, _) =
            select_candidates(&cands, 0.99, 1, eval_fn(&cands, &truth, &mut n)).unwrap();
        // m=5 measures 0.80 < target: one step to m=6, budget exhausted
        assert_eq!(cands[idx].0, Format::float(6, 6));
        assert_eq!(evals, 1);
    }

    #[test]
    fn select_two_refinements_lands_on_true_optimum() {
        let (cands, truth) = ladder();
        let mut n = 0;
        let (idx, evals, measured) =
            select_candidates(&cands, 0.99, 2, eval_fn(&cands, &truth, &mut n)).unwrap();
        // m=5 fails, m=6 passes: the exhaustive optimum
        assert_eq!(cands[idx].0, Format::float(6, 6));
        assert_eq!(evals, 2);
        assert_eq!(measured, Some(0.995));
    }

    #[test]
    fn select_tries_faster_when_first_guess_passes() {
        let (cands, truth) = ladder();
        // pessimistic predictions: first predicted-passing is m=7
        let mut pess = cands.clone();
        for (f, p) in pess.iter_mut() {
            if let Format::Float { mantissa, .. } = f {
                *p = if *mantissa >= 7 { 1.0 } else { 0.5 };
            }
        }
        let mut n = 0;
        let (idx, evals, _) =
            select_candidates(&pess, 0.99, 2, eval_fn(&pess, &truth, &mut n)).unwrap();
        // m=7 measures pass; second eval tries m=6, which also passes
        assert_eq!(pess[idx].0, Format::float(6, 6));
        assert_eq!(evals, 2);
    }

    #[test]
    fn select_falls_back_to_best_prediction_when_none_clears() {
        // conservative model: nothing predicted >= target; the search
        // starts at the argmax prediction and refines from there (§3.3)
        let (cands, truth) = ladder();
        let mut conservative = cands.clone();
        for (f, p) in conservative.iter_mut() {
            if let Format::Float { mantissa, .. } = f {
                *p = 0.5 + 0.04 * *mantissa as f64; // max 0.9 at m=10
            }
        }
        let mut n = 0;
        let (idx, evals, measured) =
            select_candidates(&conservative, 0.99, 2, eval_fn(&conservative, &truth, &mut n))
                .unwrap();
        // starts at m=10 (best prediction), measures pass, steps faster
        // to m=9 which also passes
        assert_eq!(conservative[idx].0, Format::float(9, 6));
        assert_eq!(evals, 2);
        assert_eq!(measured, Some(0.995));
        // empty candidate list is the only None case now
        assert!(select_candidates(&[], 0.99, 2, |_| 1.0).is_none());
    }

    #[test]
    fn exhaustive_picks_fastest_meeting_target() {
        // emulate via the table logic: fastest format with na >= target
        let formats = vec![
            Format::float(3, 4),  // fast, inaccurate
            Format::float(8, 6),  // mid
            Format::float(16, 8), // slow, accurate
        ];
        let nas = [0.3, 0.995, 1.0];
        let target = 0.99;
        let best = formats
            .iter()
            .zip(nas.iter())
            .filter(|(_, na)| **na >= target)
            .max_by(|a, b| hw::speedup(a.0).partial_cmp(&hw::speedup(b.0)).unwrap())
            .map(|(f, _)| *f);
        assert_eq!(best, Some(Format::float(8, 6)));
    }

    /// End-to-end search over the fixture network: exercises the whole
    /// Backend-substrate pipeline without artifacts.
    #[test]
    fn search_runs_on_fixture_network() {
        let net = crate::testing::fixtures::tiny_network(16);
        let opts = EvalOptions { samples: 16, batch: 4 };
        let spec = SearchSpec {
            // the ladder tops out at m=23 e=8 == Format::SINGLE, whose
            // normalized accuracy is exactly 1.0 — so a clearing
            // candidate always exists
            formats: (4..=23).map(|m| Format::float(m, 8)).collect(),
            target: 0.99,
            refine_samples: 2,
            opts,
            seed: 7,
        };
        let model = AccuracyModel { a: 1.0, b: 0.0, fit_r: 1.0, n_points: 0 };
        let out = search(&net, &spec, &model).unwrap();
        let (ex, table) = exhaustive_search(&net, &spec).unwrap();
        assert_eq!(table.len(), spec.formats.len());
        assert!(out.chosen.is_some());
        assert!(ex.chosen.is_some(), "SINGLE must clear the target");
        assert!(out.sample_forwards > 0);
    }
}
