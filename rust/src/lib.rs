//! # precis — customized-precision DNN inference
//!
//! Reproduction of *“Rethinking Numerical Representations for Deep Neural
//! Networks”* (Hill et al., 2018) as a three-layer Rust + JAX + Pallas
//! system.  This crate is Layer 3: everything on the request path.
//!
//! * [`formats`]    — the customized-precision design space (§2.2) +
//!                    per-layer mixed-precision plans (`PrecisionSpec`,
//!                    DESIGN.md §Mixed precision)
//! * [`numerics`]   — softfloat/softfixed quantizers + MAC chains (§2.2, Fig 8)
//! * [`hw`]         — MAC delay/area/power model, speedup/energy (§2.3, Figs 4/5/7)
//! * [`tensor`]     — minimal NDArray + `.prt` container IO
//! * [`nn`]         — pure-Rust quantized inference engine (the "modified
//!                    Caffe" substitute; bit-exact vs the Pallas kernel)
//! * [`obs`]        — observability: lock-free metrics registry,
//!                    per-layer forward profiling, JSON-lines event log,
//!                    SLO burn-rate alerts (DESIGN.md §Observability)
//! * [`runtime`]    — PJRT client: load + execute `artifacts/*.hlo.txt`
//!                    (behind the `pjrt` feature; DESIGN.md §5)
//! * [`serving`]    — the unified execution API: `Backend` (the one
//!                    substrate), `Session` (dynamic batching) and the
//!                    multi-model `Gateway` (DESIGN.md §Serving)
//! * [`store`]      — pre-quantized & bit-packed weight store: each
//!                    `(net, layer, resolved format)` staged once,
//!                    shared across sessions under a byte budget with
//!                    LRU eviction (DESIGN.md §Storage)
//! * [`coordinator`]— sweep orchestrator: job queue, worker pool, cache
//! * [`search`]     — the paper's §3.3 contribution: last-layer R² →
//!                    linear accuracy model → model+N-samples search,
//!                    plus the greedy per-layer `plan_search`
//! * [`eval`]       — accuracy metrics + design-space sweep driver
//! * [`figures`]    — regenerates every paper figure's data series
//! * [`util`]       — PRNG, mini-JSON, CLI parsing, timing (offline-build
//!                    substrates; see DESIGN.md §6)
//! * [`testing`]    — in-repo property-testing framework
//! * [`bench_harness`] — micro-benchmark framework + the machine-readable
//!                    `BENCH_*.json` perf-regression pipeline
//!                    (`repro bench --json`, DESIGN.md §Perf)
//!
//! Quickstart (after `make artifacts`; see README.md):
//!
//! ```no_run
//! use precis::{formats::Format, nn::Zoo};
//!
//! let zoo = Zoo::load("artifacts").unwrap();
//! let net = zoo.network("lenet5").unwrap();
//! let fmt = Format::float(7, 6);
//! let acc = precis::eval::accuracy(&net, &fmt, 128).unwrap();
//! println!("lenet5 @ {fmt}: top-1 = {acc:.3}");
//! ```

pub mod bench_harness;
pub mod coordinator;
pub mod eval;
pub mod figures;
pub mod formats;
pub mod hw;
pub mod nn;
pub mod numerics;
pub mod obs;
pub mod runtime;
pub mod search;
pub mod serving;
pub mod store;
pub mod tensor;
pub mod testing;
pub mod util;
