//! Offline placeholder for the `xla` PJRT bindings.
//!
//! The published PJRT binding crates ship a multi-hundred-megabyte
//! `xla_extension` native bundle and are not part of this repository's
//! offline crate set (DESIGN.md §6).  This stub mirrors exactly the API
//! surface `precis::runtime` uses, so that `cargo build --features pjrt`
//! type-checks the whole PJRT code path without the native library.  At
//! runtime every entry point fails fast with an [`Error`] that points
//! back at DESIGN.md §5, and `precis` degrades to its native engine.
//!
//! To run the real thing, point the `xla` dependency in `rust/Cargo.toml`
//! at a checkout of a PJRT binding crate with this API (DESIGN.md §5).

use std::fmt;

/// Error type mirroring the binding crate's (Display is all `precis`
/// relies on — every call site wraps it in `anyhow`).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl Error {
    fn stub(what: &str) -> Error {
        Error(format!(
            "{what}: built against the offline xla stub; point the `xla` \
             dependency at a real PJRT binding crate (DESIGN.md §5)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// PJRT client handle (one per process in the real bindings).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// The real bindings spin up the PJRT CPU plugin here; the stub
    /// fails fast so callers fall back to the native engine.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::stub("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "xla-stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::stub("PjRtClient::compile"))
    }
}

/// Parsed HLO module (text interchange format).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::stub("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation wrapping an HLO module.
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A compiled executable.
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with on-host inputs; the real bindings return one buffer
    /// list per device.
    pub fn execute<T>(&self, _inputs: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::stub("PjRtLoadedExecutable::execute"))
    }
}

/// A device buffer returned by [`PjRtLoadedExecutable::execute`].
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::stub("PjRtBuffer::to_literal_sync"))
    }
}

/// A host-side literal (dense array value).
pub struct Literal {
    _private: (),
}

impl Literal {
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(self, _dims: &[i64]) -> Result<Literal> {
        Ok(self)
    }

    pub fn to_tuple1(self) -> Result<Literal> {
        Err(Error::stub("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::stub("Literal::to_vec"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_fails_fast_with_pointer_to_design_doc() {
        let e = PjRtClient::cpu().err().expect("stub must not create clients");
        let msg = e.to_string();
        assert!(msg.contains("stub"), "{msg}");
        assert!(msg.contains("DESIGN.md"), "{msg}");
    }

    #[test]
    fn literal_construction_is_infallible() {
        // runtime stages inputs before execute(); that path must not panic
        let l = Literal::vec1(&[1.0, 2.0]).reshape(&[2]).unwrap();
        assert!(l.to_vec::<f32>().is_err());
    }
}
