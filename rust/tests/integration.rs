//! End-to-end integration over the real artifacts.
//!
//! Artifacts are produced by `make artifacts` (JAX training + AOT HLO
//! lowering; see README.md).  They are a build product, not checked in,
//! so every test here degrades to a **skip** (early return with a
//! stderr note) when `artifacts/` is absent — tier-1 `cargo test` stays
//! green on a fresh clone, and turns these tests on automatically once
//! the artifacts exist.  Set `PRECIS_REQUIRE_ARTIFACTS=1` to turn a
//! missing-artifacts skip into a hard failure, so a CI lane that *did*
//! build artifacts can never go green vacuously.
//!
//! Covers: zoo loading, native-engine accuracy vs the trainer's recorded
//! exact accuracy, precision-degradation behaviour across the design
//! space, the §3.3 search against the exhaustive baseline, the parallel
//! sweep coordinator, and the serving session (the gateway proper is
//! covered by `tests/gateway.rs`).

use std::sync::Arc;
use std::time::Duration;

use precis::coordinator::cache::ResultCache;
use precis::coordinator::{sweep_formats, Coordinator};
use precis::eval::sweep::{forward_eval, EvalOptions};
use precis::eval::{accuracy, topk_accuracy};
use precis::figures;
use precis::formats::Format;
use precis::nn::{Network, Zoo};
use precis::search::{
    collect_model_points, exhaustive_search, search, AccuracyModel, SearchSpec,
};
use precis::serving::{Backend, BackendKind, NativeBackend, Session, SessionOptions};

/// `artifacts/` lives at the repo root (aot.py's default output), one
/// level above this crate.
const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts");

/// Load the zoo, or skip the calling test when artifacts are missing
/// (a hard failure instead when `PRECIS_REQUIRE_ARTIFACTS` is set).
fn zoo() -> Option<Zoo> {
    match Zoo::load(ARTIFACTS) {
        Ok(z) => Some(z),
        Err(e) => {
            if precis::testing::strict_env("PRECIS_REQUIRE_ARTIFACTS") {
                panic!("PRECIS_REQUIRE_ARTIFACTS is set but artifacts are unusable: {e:#}");
            }
            // keep the real error visible: "missing" and "corrupt" need
            // different operator responses
            eprintln!("skipping: artifacts unusable at {ARTIFACTS}: {e:#} (run `make artifacts`)");
            None
        }
    }
}

fn opts(samples: usize) -> EvalOptions {
    EvalOptions { samples, batch: 32 }
}

/// A small-but-representative slice of the design space for fast tests.
fn test_space() -> Vec<Format> {
    vec![
        Format::float(2, 4),
        Format::float(4, 5),
        Format::float(7, 6),
        Format::float(10, 6),
        Format::float(16, 8),
        Format::fixed(2, 2),
        Format::fixed(4, 8),
        Format::fixed(8, 8),
        Format::fixed(12, 12),
    ]
}

#[test]
fn zoo_loads_all_five_networks() {
    let Some(z) = zoo() else { return };
    let mut names = z.names();
    names.sort();
    assert_eq!(
        names,
        vec!["alexnet-mini", "cifarnet", "googlenet-mini", "lenet5", "vgg-mini"]
    );
    for net in z.by_size_desc() {
        assert!(net.n_params > 10_000, "{} too small", net.name);
        assert_eq!(net.eval_len(), 512);
        assert!(net.eval_acc_exact > 0.85, "{} undertrained", net.name);
    }
    // paper ordering precondition: googlenet has the longest MAC chain
    let g = z.network("googlenet-mini").unwrap();
    for other in z.by_size_desc() {
        assert!(g.max_chain >= other.max_chain);
    }
}

#[test]
fn native_exact_accuracy_matches_trainer() {
    // the native serial-K engine and jnp's parallel-reduction matmul
    // differ only in f32 association; accuracy must agree closely
    let Some(z) = zoo() else { return };
    for name in ["lenet5", "cifarnet"] {
        let net = z.network(name).unwrap();
        let acc = accuracy(&net, &Format::SINGLE, 512).unwrap();
        assert!(
            (acc - net.eval_acc_exact).abs() < 0.02,
            "{name}: native {acc} vs trainer {}",
            net.eval_acc_exact
        );
    }
}

#[test]
fn degradation_anatomy_across_formats() {
    let Some(z) = zoo() else { return };
    let net = z.network("lenet5").unwrap();
    let base = accuracy(&net, &Format::SINGLE, 96).unwrap();

    // wide float: within noise of exact
    let wide = accuracy(&net, &Format::float(16, 8), 96).unwrap();
    assert!((wide - base).abs() < 0.03, "wide {wide} vs base {base}");

    // 1-bit mantissa + 2-bit exponent: collapses to ~chance
    let tiny = accuracy(&net, &Format::float(1, 2), 96).unwrap();
    assert!(tiny < base * 0.6, "tiny float should collapse: {tiny} vs {base}");

    // fixed with zero integer bits saturates at 1: collapses
    let sat = accuracy(&net, &Format::fixed(0, 2), 96).unwrap();
    assert!(sat < base * 0.7, "saturating fixed should collapse: {sat}");
}

#[test]
fn float_beats_fixed_at_iso_accuracy_on_long_chain_net() {
    // paper finding 3, on the longest-chain network: compare the total
    // bits needed to stay within 1% of baseline
    let Some(z) = zoo() else { return };
    let net = z.network("googlenet-mini").unwrap();
    let o = opts(96);
    let mut backend = NativeBackend::new(net.clone());
    let (bl, labels) = forward_eval(&mut backend, &Format::SINGLE, &o).unwrap();
    let base = topk_accuracy(&bl, &labels, net.classes, net.topk);

    let mut need_bits = |fmts: &[Format]| -> Option<u32> {
        let mut best: Option<u32> = None;
        for f in fmts {
            let (lg, _) = forward_eval(&mut backend, f, &o).unwrap();
            let acc = topk_accuracy(&lg, &labels, net.classes, net.topk);
            if acc >= 0.99 * base {
                best = Some(best.map_or(f.total_bits(), |b| b.min(f.total_bits())));
            }
        }
        best
    };

    // total-bit ladders at representative allocations
    let floats: Vec<Format> = (4..=14).map(|m| Format::float(m, 6)).collect();
    let fixeds: Vec<Format> = (4..=14).map(|r| Format::fixed(6, r)).collect();
    let fb = need_bits(&floats).expect("some float config must reach 99%");
    if let Some(xb) = need_bits(&fixeds) {
        assert!(fb <= xb, "float needs {fb} bits, fixed needs {xb}");
    }
    assert!(fb <= 21, "float should reach 99% within 21 bits, needed {fb}");
}

#[test]
fn sweep_coordinator_matches_sequential_and_caches() {
    let Some(z) = zoo() else { return };
    let net = z.network("lenet5").unwrap();
    let o = opts(64);
    let space = test_space();
    let cache = ResultCache::ephemeral();

    let par = sweep_formats(&net, &space, &o, 4, &cache).unwrap();
    let seq = precis::eval::sweep_design_space(&net, &space, &o).unwrap();
    assert_eq!(par.len(), seq.len());
    for (p, s) in par.iter().zip(seq.iter()) {
        assert_eq!(p.format, s.format);
        assert!((p.accuracy - s.accuracy).abs() < 1e-12, "{}", p.format);
        assert!((p.speedup - s.speedup).abs() < 1e-12);
    }
    // second run hits the cache (same values, cache populated)
    assert!(cache.len() >= space.len());
    let par2 = sweep_formats(&net, &space, &o, 2, &cache).unwrap();
    for (a, b) in par.iter().zip(par2.iter()) {
        assert_eq!(a.accuracy, b.accuracy);
    }
}

#[test]
fn batch_parallel_eval_is_bit_identical_to_sequential() {
    // forward_eval_parallel fans batches over the pool; the logits must
    // match the sequential driver bitwise (DESIGN.md §7)
    let Some(z) = zoo() else { return };
    let net = z.network("lenet5").unwrap();
    let o = opts(80); // 2.5 batches: exercises the ragged tail
    for fmt in [Format::SINGLE, Format::float(7, 6), Format::fixed(8, 8)] {
        let (seq, seq_labels) =
            forward_eval(&mut NativeBackend::new(net.clone()), &fmt, &o).unwrap();
        let (par, par_labels) =
            precis::eval::forward_eval_parallel(&net, &fmt, &o, 4).unwrap();
        assert_eq!(seq_labels, par_labels);
        assert_eq!(seq.len(), par.len());
        for i in 0..seq.len() {
            assert_eq!(seq[i].to_bits(), par[i].to_bits(), "{fmt} logit {i}");
        }
    }
}

#[test]
fn accuracy_model_transfers_across_networks() {
    // fit on lenet5+cifarnet points, check it ranks alexnet-mini configs:
    // high-R² configs must predict near-1 normalized accuracy
    let Some(z) = zoo() else { return };
    let o = opts(64);
    let space = test_space();
    let mut pts = Vec::new();
    for name in ["lenet5", "cifarnet"] {
        let net = z.network(name).unwrap();
        pts.extend(
            collect_model_points(&net, &space, &o, 7)
                .unwrap()
                .into_iter()
                .map(|(_, p)| p),
        );
    }
    let model = AccuracyModel::fit(&pts);
    assert!(model.fit_r > 0.7, "fit r = {} too weak", model.fit_r);
    assert!(model.predict(1.0) > 0.9);
    assert!(model.predict(1.0) > model.predict(0.2));
}

#[test]
fn search_with_two_refinements_matches_exhaustive() {
    // the paper's Fig 10 claim, on a thinned float space over lenet5
    let Some(z) = zoo() else { return };
    let net = z.network("lenet5").unwrap();
    let o = opts(64);
    let space: Vec<Format> = (1..=18).map(|m| Format::float(m, 6)).collect();

    let mut pts = Vec::new();
    for name in ["cifarnet", "alexnet-mini"] {
        let n = z.network(name).unwrap();
        pts.extend(
            collect_model_points(&n, &space, &o, 7)
                .unwrap()
                .into_iter()
                .map(|(_, p)| p),
        );
    }
    let model = AccuracyModel::fit(&pts);

    let spec = SearchSpec {
        formats: space,
        target: 0.99,
        refine_samples: 2,
        opts: o,
        seed: 7,
    };
    let (ex, _) = exhaustive_search(&net, &spec).unwrap();
    let out = search(&net, &spec, &model).unwrap();

    let exf = ex.chosen.expect("exhaustive must find a config");
    let ouf = out.chosen.expect("search must find a config");
    // the chosen config always meets the target...
    assert!(out.measured_norm_acc >= spec.target, "{}", out.measured_norm_acc);
    // ...and is within one ladder step of the exhaustive optimum
    let d = (exf.total_bits() as i64 - ouf.total_bits() as i64).abs();
    assert!(d <= 1, "exhaustive {exf} vs search {ouf}");
    // and it is substantially cheaper.  (On this 18-config test ladder
    // the probe pass is a third of the exhaustive cost; the paper's
    // 170x ratio needs the full ~240-config space with full eval sets —
    // that ratio is reported by `repro search` / fig10.)
    assert!(
        out.sample_forwards * 3 < ex.sample_forwards,
        "search {} vs exhaustive {}",
        out.sample_forwards,
        ex.sample_forwards
    );
}

#[test]
fn serving_session_native_end_to_end() {
    let Some(z) = zoo() else { return };
    let net: Arc<Network> = z.network("lenet5").unwrap();
    let fmt = Format::float(10, 6);
    let session = Session::open_with(
        &z,
        "lenet5",
        fmt,
        BackendKind::Native,
        SessionOptions {
            batch: 8,
            max_wait: Duration::from_millis(5),
            ..SessionOptions::default()
        },
    )
    .unwrap();

    // submit 20 async requests (forces batching + a padded final batch)
    let px = net.input.iter().product::<usize>();
    let mut pending = Vec::new();
    for i in 0..20 {
        let pixels = net.eval_x.data()[i * px..(i + 1) * px].to_vec();
        pending.push((i, session.infer_async(pixels).unwrap()));
    }
    // responses must match the backend run directly
    let direct = NativeBackend::new(net.clone())
        .run_batch(&net.eval_x.slice_rows(0, 20), &fmt)
        .unwrap();
    for (i, rx) in pending {
        let got = rx.recv().unwrap().unwrap();
        let want = &direct.data()[i * net.classes..(i + 1) * net.classes];
        assert_eq!(got.as_slice(), want, "request {i}");
    }
    let stats = session.shutdown();
    assert_eq!(stats.requests, 20);
    assert!(stats.batches >= 3);
    assert_eq!(stats.backend, "native");
}

#[test]
fn session_rejects_malformed_input() {
    let Some(z) = zoo() else { return };
    let session = Session::open_with(
        &z,
        "lenet5",
        Format::SINGLE,
        BackendKind::Native,
        SessionOptions {
            batch: 4,
            max_wait: Duration::from_millis(1),
            ..SessionOptions::default()
        },
    )
    .unwrap();
    assert!(session.infer(vec![0.0; 3]).is_err());
}

#[test]
fn fig8_trace_reproduces_saturation_story() {
    let Some(z) = zoo() else { return };
    let net = z.network("alexnet-mini").unwrap();
    let t = figures::fig8(&net, 0).unwrap();
    // chain length = deepest conv K = 3*3*48
    assert_eq!(t.rows.len(), 3 * 3 * 48);
    // the exact column and the m8e6 column should end close; the m2
    // column should show visible rounding error
    let last = t.rows.last().unwrap();
    let exact: f64 = last[1].parse().unwrap();
    let idx_m8 = t.headers.iter().position(|h| h == "float:m8e6").unwrap();
    let idx_m2 = t.headers.iter().position(|h| h == "float:m2e8").unwrap();
    let m8: f64 = last[idx_m8].parse().unwrap();
    let m2: f64 = last[idx_m2].parse().unwrap();
    let scale = exact.abs().max(0.1);
    assert!((m8 - exact).abs() / scale < 0.05, "m8e6 {m8} vs exact {exact}");
    assert!((m8 - exact).abs() <= (m2 - exact).abs());
}

#[test]
fn pareto_helper_picks_fastest_meeting_target() {
    let Some(z) = zoo() else { return };
    let net = z.network("cifarnet").unwrap();
    let o = opts(64);
    let cache = ResultCache::ephemeral();
    let res = sweep_formats(&net, &test_space(), &o, 2, &cache).unwrap();
    if let Some(best) = figures::pareto(&res, 0.99) {
        assert!(best.normalized_accuracy >= 0.99);
        for r in &res {
            if r.normalized_accuracy >= 0.99 {
                assert!(best.speedup >= r.speedup);
            }
        }
    }
}

#[test]
fn coordinator_facade_sweeps_with_cache_file() {
    let Some(z) = zoo() else { return };
    let dir = std::env::temp_dir().join("precis_it_cache");
    std::fs::remove_dir_all(&dir).ok();
    let cache = ResultCache::open(dir.join("cache.json"));
    let coord = Coordinator::new(z, cache).with_workers(2);
    let res = coord.sweep("lenet5", &test_space()[..4], &opts(48)).unwrap();
    assert_eq!(res.len(), 4);
    assert!(dir.join("cache.json").exists());
    std::fs::remove_dir_all(&dir).ok();
}
