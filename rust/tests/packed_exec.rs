//! Packed-domain execution acceptance (ISSUE 6) — tier-1, fixture
//! based, no artifacts:
//!
//! * the golden 470-vector set replays through the packed kernels
//!   themselves: every python-normative (input → quantized output)
//!   pair flows through `gemm_packed_int` / `gemm_packed_lut` as a
//!   packed weight and must reproduce the staged-f32 serial-k chain
//!   bit-exactly, while wide-code formats are pinned to the staged
//!   router decision;
//! * the router's per-layer assignments are pinned through the
//!   resolved `QuantTable` (on-grid / off-grid upstream, packed off);
//! * a packed-exec forward through the real engine is bit-identical
//!   to the staged forward for every golden format and for random
//!   formats/plans (property), including the dynamic fallback when a
//!   zero-budget store rejects the packed tier.

use std::cell::Cell;
use std::collections::{BTreeMap, BTreeSet};
use std::sync::Arc;

use precis::formats::{Format, FormatPair, Plan, PrecisionSpec};
use precis::nn::QuantTable;
use precis::numerics::{PackedOp, Quantizer};
use precis::serving::{Backend, NativeBackend};
use precis::store::{
    gemm_packed_int, gemm_packed_lut, route, ExecScratch, PackedTensor, Route, WeightStore,
    LUT_MAX_WIDTH,
};
use precis::testing::fixtures::{tiny_conv_network, tiny_network};
use precis::testing::prop::{arb_format, run_prop};
use precis::util::json::Json;
use precis::with_packed_op;

const GOLDEN: &str = include_str!("golden/quant_golden.json");

/// The 13 golden formats — the conformance surface the whole repo pins.
const GOLDEN_FORMATS: [&str; 13] = [
    "fixed:l0r2",
    "fixed:l1r3",
    "fixed:l4r4",
    "fixed:l8r8",
    "fixed:l12r2",
    "fixed:l2r12",
    "float:m0e5",
    "float:m1e2",
    "float:m2e8",
    "float:m4e4",
    "float:m7e6",
    "float:m10e3",
    "float:m23e8",
];

fn hex32(j: &Json, key: &str) -> u32 {
    let s = j.req(key).unwrap().as_str().unwrap();
    u32::from_str_radix(s, 16).unwrap_or_else(|e| panic!("bad hex {key}={s:?}: {e}"))
}

fn assert_bits_eq(got: &[f32], want: &[f32], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for i in 0..want.len() {
        assert_eq!(
            got[i].to_bits(),
            want[i].to_bits(),
            "{ctx}: elem {i} ({} vs {})",
            got[i],
            want[i]
        );
    }
}

/// The staged-f32 chain the packed kernels must reproduce: serial
/// increasing-k `q(acc + q(a·w))` per output element — `gemm_q`'s
/// pinned order (no bias here; the golden replay is bias-free).
fn reference_chain(a: &[f32], wq: &[f32], m: usize, k: usize, n: usize, q: &Quantizer) -> Vec<f32> {
    let mut out = vec![0.0f32; m * n];
    for mi in 0..m {
        for ni in 0..n {
            let mut acc = 0.0f32;
            for ki in 0..k {
                acc = q.q(acc + q.q(a[mi * k + ki] * wq[ki * n + ni]));
            }
            out[mi * n + ni] = acc;
        }
    }
    out
}

/// Satellite 1: the golden differential replay.  Per format, the golden
/// raw inputs become a packed k×1 weight column and the activations a
/// one-hot k×k matrix at the representable `q(1.0)` — so output row `i`
/// runs a full serial-k chain in which golden vector `i` is the single
/// surviving product.  The packed kernels (integer lane where the
/// router admits it, LUT lane for every table-sized format) must match
/// the staged chain built from the PYTHON-normative outputs bit-for-bit
/// — and the wide-code formats must be pinned to `Route::Staged`.
#[test]
fn packed_kernels_replay_golden_vectors_bit_exactly() {
    let j = Json::parse(GOLDEN).expect("golden JSON parses");
    let cases = j.req("cases").unwrap().as_arr().unwrap();

    let mut by_fmt: BTreeMap<String, (Vec<f32>, Vec<f32>)> = BTreeMap::new();
    for case in cases {
        let id = case.req("fmt").unwrap().as_str().unwrap().to_string();
        let bucket = by_fmt.entry(id).or_default();
        bucket.0.push(f32::from_bits(hex32(case, "x")));
        bucket.1.push(f32::from_bits(hex32(case, "q")));
    }
    assert!(by_fmt.len() >= 10, "conformance needs ~10+ formats, have {}", by_fmt.len());

    let (mut checked, mut lut_formats, mut int_formats) = (0usize, 0usize, 0usize);
    for (id, (xs, wq)) in &by_fmt {
        let fmt = Format::parse(id).unwrap();
        let q = Quantizer::new(&fmt);
        let k = xs.len();
        let packed = PackedTensor::pack(xs, &fmt);
        let hot = q.q(1.0);
        assert!(hot != 0.0, "{id}: fixture needs a representable 1.0-ish activation");
        let mut a = vec![0.0f32; k * k];
        for i in 0..k {
            a[i * k + i] = hot;
        }
        let want = reference_chain(&a, wq, k, k, 1, &q);

        let lane = route(&fmt, false, true);
        if matches!(lane, Route::Int16 | Route::Int32) {
            let op = PackedOp::for_format(&fmt).expect("integer routes imply a PackedOp");
            let mut out = vec![0.0f32; k];
            with_packed_op!(&op, o => gemm_packed_int(
                &a, &packed, None, &mut out, k, k, 1, o, &mut ExecScratch::default(),
            ));
            assert_bits_eq(&out, &want, &format!("{id} integer lane"));
            int_formats += 1;
        }
        match PackedTensor::decode_table(&fmt, LUT_MAX_WIDTH) {
            Some(lut) => {
                let mut out = vec![0.0f32; k];
                gemm_packed_lut(
                    &a,
                    &packed,
                    &lut,
                    None,
                    &mut out,
                    k,
                    k,
                    1,
                    &q,
                    &mut ExecScratch::default(),
                );
                assert_bits_eq(&out, &want, &format!("{id} LUT lane"));
                lut_formats += 1;
            }
            None => {
                // no packed kernel exists for this code width: the
                // router must statically pin it to the staged tier
                assert_eq!(lane, Route::Staged, "{id}: wide codes must route staged");
            }
        }
        checked += xs.len();
    }
    assert_eq!(checked, cases.len(), "every golden case must flow through the replay");
    assert!(lut_formats >= 10, "only {lut_formats} formats ran the LUT lane");
    assert!(int_formats >= 2, "only {int_formats} formats ran the integer lane");
}

/// The router's decisions, pinned through the real resolve pass: the
/// lane each fixture layer gets under uniform specs (on-grid upstream
/// everywhere), a mixed plan whose second layer sees a FOREIGN upstream
/// grid (integer premise fails → LUT), and the packed-exec-off default.
#[test]
fn router_assignments_pin_through_the_resolved_table() {
    let net = tiny_conv_network(4);
    let labels = |spec: &str, packed: bool| {
        let spec = PrecisionSpec::parse(spec).unwrap();
        let table = QuantTable::resolve_for(&net, &spec, packed).unwrap();
        table.packed_labels(&net)
    };
    for (spec, c1, fc) in [
        ("fixed:l0r2", "int16", "int16"),
        ("fixed:l3r3", "int16", "int16"),
        ("fixed:l4r4", "int32", "int32"),
        ("fixed:l12r0", "int32", "int32"),
        // t = l + r > 12: no exact integer chain; codes are LUT-sized
        ("fixed:l8r8", "lut", "lut"),
        ("fixed:l12r2", "lut", "lut"),
        ("float:m7e6", "lut", "lut"),
        ("float:m0e5", "lut", "lut"),
        // raw carrier: no packed tier exists at all
        ("float:m23e8", "staged", "staged"),
        // mixed plan: relu/maxpool/flatten carry c1's grid into fc, so
        // fc's upstream is a foreign grid — the integer premise fails
        // and the router must fall to the (activation-agnostic) LUT
        ("plan:c1=fixed:l2r2,fc=fixed:l3r3", "int16", "lut"),
        // an identity-quantized c1 emits raw f32: fc is off-grid too
        ("plan:c1=float:m23e8,fc=fixed:l1r2", "staged", "lut"),
        // split pairs (ISSUE 9): a layer whose weight and activation
        // halves differ breaks the integer premise BY CONSTRUCTION
        // (upstream activations are never on the weight grid), so the
        // router must pin to lut/staged — never an integer lane.  The
        // downstream uniform layer still sees the split layer's
        // ACTIVATION grid: fc stays integer when it matches.
        ("plan:c1=w:fixed:l2r2+a:fixed:l3r3,fc=fixed:l3r3", "lut", "int16"),
        ("plan:c1=w:float:m23e8+a:fixed:l4r4,fc=fixed:l4r4", "staged", "int32"),
        // a split fc whose activation half matches upstream is STILL
        // not integer (weight grid differs); LUT-sized w-half → lut
        ("plan:c1=fixed:l2r2,fc=w:fixed:l3r3+a:fixed:l2r2", "int16", "lut"),
    ] {
        let got = labels(spec, true);
        let want = vec![("c1".to_string(), c1), ("fc".to_string(), fc)];
        assert_eq!(got, want, "{spec}");
    }
    // packed exec off (the default): everything stays on the staged
    // tier — the flag is a strict opt-in
    for spec in ["fixed:l3r3", "float:m7e6"] {
        assert!(
            labels(spec, false).iter().all(|(_, l)| *l == "staged"),
            "{spec}: packed lanes assigned without the opt-in"
        );
    }
}

/// Every golden format forwards bit-identically through the engine's
/// packed dispatch, and the matrix collectively exercises all four
/// lanes (int16 / int32 / lut / staged) end-to-end.
#[test]
fn golden_format_forwards_are_bit_identical_across_all_lanes() {
    let net = tiny_conv_network(6);
    let x = net.eval_x.slice_rows(0, 6);
    let mut lanes_seen: BTreeSet<&'static str> = BTreeSet::new();
    for id in GOLDEN_FORMATS {
        let spec = PrecisionSpec::parse(id).unwrap();
        let mut staged = NativeBackend::with_store(net.clone(), Arc::new(WeightStore::unbounded()));
        let mut packed = NativeBackend::with_store(net.clone(), Arc::new(WeightStore::unbounded()))
            .with_packed_exec(true);
        let want = staged.run_spec(&x, &spec).unwrap();
        let cold = packed.run_spec(&x, &spec).unwrap();
        let warm = packed.run_spec(&x, &spec).unwrap();
        assert_bits_eq(cold.data(), want.data(), &format!("{id} cold"));
        assert_bits_eq(warm.data(), want.data(), &format!("{id} warm"));
        for (_, lane) in QuantTable::resolve_for(&net, &spec, true).unwrap().packed_labels(&net) {
            lanes_seen.insert(lane);
        }
    }
    for lane in ["int16", "int32", "lut", "staged"] {
        assert!(lanes_seen.contains(lane), "golden matrix never exercised the {lane} lane");
    }
}

/// Satellite 2 (property): across random formats, plans, and both
/// fixtures, a packed-exec forward is bit-identical to the staged
/// forward — and stays so when a zero-budget store rejects every entry,
/// which forces the engine's dynamic per-layer fallback from the
/// packed plan to scratch re-staging.
#[test]
fn prop_packed_forward_bit_identical_to_staged_engine() {
    let conv = tiny_conv_network(5);
    let dense = tiny_network(5);
    let packed_layers = Cell::new(0usize);
    run_prop("packed_engine_vs_staged", 50, |g| {
        let net = if g.bool() { &conv } else { &dense };
        let x = net.eval_x.slice_rows(0, 5);
        let names: &[&str] = if Arc::ptr_eq(net, &conv) { &["c1", "fc"] } else { &["fc"] };
        let spec = match g.usize_in(0, 2) {
            0 => PrecisionSpec::parse(&arb_format(g).id()).unwrap(),
            1 => {
                let fmts: Vec<(String, Format)> =
                    names.iter().map(|n| (n.to_string(), arb_format(g))).collect();
                PrecisionSpec::from(Plan::explicit(fmts).unwrap())
            }
            // split pairs: each layer's weight and activation halves
            // drawn independently (some collapse back to uniform sugar)
            _ => {
                let pairs: Vec<(String, FormatPair)> = names
                    .iter()
                    .map(|n| (n.to_string(), FormatPair::split(arb_format(g), arb_format(g))))
                    .collect();
                PrecisionSpec::from(Plan::explicit_pairs(pairs).unwrap())
            }
        };
        let mut staged = NativeBackend::with_store(net.clone(), Arc::new(WeightStore::unbounded()));
        let mut packed = NativeBackend::with_store(net.clone(), Arc::new(WeightStore::unbounded()))
            .with_packed_exec(true);
        let mut rejected =
            NativeBackend::with_store(net.clone(), Arc::new(WeightStore::with_budget(0)))
                .with_packed_exec(true);
        let want = staged.run_spec(&x, &spec).unwrap();
        for round in 0..2 {
            let got = packed.run_spec(&x, &spec).unwrap();
            assert_bits_eq(got.data(), want.data(), &format!("{} round {round}", spec.id()));
            let fb = rejected.run_spec(&x, &spec).unwrap();
            assert_bits_eq(fb.data(), want.data(), &format!("{} fallback {round}", spec.id()));
        }
        let table = QuantTable::resolve_for(net, &spec, true).unwrap();
        let n = table.packed_labels(net).iter().filter(|(_, l)| *l != "staged").count();
        packed_layers.set(packed_layers.get() + n);
    });
    // the run must actually have exercised packed lanes somewhere, or
    // the property is vacuous
    assert!(packed_layers.get() > 0, "no case assigned a packed lane");
}
