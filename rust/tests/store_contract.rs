//! The `precis::store` acceptance contract (ISSUE 5) — tier-1, fixture
//! based, no artifacts:
//!
//! * a forward through a warm [`WeightStore`] is bit-identical to the
//!   re-staging path for every format and mixed plan in the matrix,
//!   and performs zero weight-quantization work after the first
//!   forward (proved by the store counters);
//! * two gateway sessions with overlapping resolved layer formats
//!   share store entries (the hit/miss counters prove it);
//! * eviction under a tight budget degrades to correct (bit-identical)
//!   re-staging, never to an error.

use std::cell::Cell;
use std::sync::{Arc, Barrier};
use std::time::Duration;

use precis::formats::{Format, Plan, PrecisionSpec};
use precis::obs::Registry;
use precis::serving::{Backend, Gateway, NativeBackend, Session};
use precis::store::{StoreEntry, StoreKey, WeightStore};
use precis::testing::fixtures::tiny_conv_network;
use precis::testing::prop::{arb_format, run_prop};

fn assert_bits_eq(got: &[f32], want: &[f32], ctx: &str) {
    assert_eq!(got.len(), want.len(), "{ctx}: length");
    for i in 0..want.len() {
        assert_eq!(
            got[i].to_bits(),
            want[i].to_bits(),
            "{ctx}: logit {i} ({} vs {})",
            got[i],
            want[i]
        );
    }
}

/// The cached-path bit-identity + zero-requantization acceptance, over
/// a matrix of uniform formats (both kinds, incl. the exact baseline
/// and a carrier-clamped e=8 float) and per-layer plans.
#[test]
fn warm_store_forward_is_bit_identical_and_quantization_free() {
    let net = tiny_conv_network(8);
    let x = net.eval_x.slice_rows(0, 8);
    for spec in [
        "float:m23e8",
        "float:m7e6",
        "float:m2e8",
        "float:m0e5",
        "fixed:l8r8",
        "fixed:l0r2",
        "plan:c1=fixed:l8r8,*=float:m7e6",
        "plan:c1=float:m4e5,fc=fixed:l2r12",
        "plan:c1=float:m23e8,fc=fixed:l8r8",
        "plan:*=fixed:l4r4",
    ] {
        let spec = PrecisionSpec::parse(spec).unwrap();
        // the uncached reference: a disabled store forces the engine's
        // scratch re-staging path (the pre-store behaviour)
        let mut restaged =
            NativeBackend::with_store(net.clone(), Arc::new(WeightStore::with_budget(0)));
        let want = restaged.run_spec(&x, &spec).unwrap();

        let store = Arc::new(WeightStore::unbounded());
        let mut cached = NativeBackend::with_store(net.clone(), store.clone());
        let first = cached.run_spec(&x, &spec).unwrap();
        let warm = store.stats();
        let second = cached.run_spec(&x, &spec).unwrap();
        let hot = store.stats();

        assert_bits_eq(first.data(), want.data(), &format!("{} cold", spec.id()));
        assert_bits_eq(second.data(), want.data(), &format!("{} warm", spec.id()));

        // store-eligible layers = resolved layers whose WEIGHT half is
        // not the identity-direct SINGLE fast path (fixture weights are
        // clean; staging classifies on the weight half alone)
        let store_layers = spec
            .resolve(&net)
            .unwrap()
            .assignments
            .iter()
            .filter(|(_, p)| p.w != Format::SINGLE)
            .count() as u64;
        assert_eq!(warm.misses, store_layers, "{}: one miss per staged layer", spec.id());
        assert_eq!(hot.misses, store_layers, "{}: warm forward quantizes NO weights", spec.id());
        assert_eq!(hot.hits, store_layers, "{}: warm forward only hits", spec.id());
        assert_eq!(hot.entries as u64, store_layers, "{}", spec.id());
        assert_eq!(hot.evictions, 0, "{}: nothing evicts unbounded", spec.id());

        // the disabled store rejected exactly what the engine re-staged
        let r = restaged.store_stats().unwrap();
        assert_eq!(r.rejected, store_layers, "{}: fallback path accounted", spec.id());
        assert_eq!((r.entries, r.bytes), (0, 0), "{}", spec.id());
    }
}

/// Two live gateway sessions with overlapping resolved layer formats
/// share entries: opening the second session's traffic adds only the
/// formats the first did not already stage, and the overlap HITS.
#[test]
fn gateway_sessions_share_store_entries_by_resolved_format() {
    let net = tiny_conv_network(6);
    let store = Arc::new(WeightStore::unbounded());
    let gw = Gateway::empty();
    let open = |spec: &str| {
        let n = net.clone();
        let s = store.clone();
        Session::with_factory(
            net.clone(),
            PrecisionSpec::parse(spec).unwrap(),
            4,
            Duration::from_millis(3),
            Box::new(move || Ok(Box::new(NativeBackend::with_store(n, s)) as Box<dyn Backend>)),
        )
    };
    // session 1: uniform m7e6 (stages c1@m7e6 + fc@m7e6); session 2's
    // plan resolves c1 to the SAME format, fc to a different one
    let k1 = gw.adopt(open("float:m7e6"));
    let k2 = gw.adopt(open("plan:c1=float:m7e6,fc=fixed:l8r8"));

    let px: usize = net.input.iter().product();
    let pixels = |i: usize| net.eval_x.data()[i * px..(i + 1) * px].to_vec();

    // warm session 1 fully first (infer blocks per request, so the
    // counter checkpoints are deterministic)
    for i in 0..3 {
        gw.infer(&k1, pixels(i)).unwrap();
    }
    let s1 = store.stats();
    assert_eq!((s1.misses, s1.entries), (2, 2), "c1@m7e6 + fc@m7e6");

    // session 2's first forward: c1@m7e6 is ALREADY staged (shared
    // entry → a hit, not a miss); only fc@l8r8 is new
    gw.infer(&k2, pixels(0)).unwrap();
    let s2 = store.stats();
    assert_eq!(s2.entries, 3, "one shared + two distinct entries");
    assert_eq!(s2.misses, 3, "the overlapping layer staged once, not twice");
    assert!(s2.hits > s1.hits, "sharing shows up as hits, not re-staging");

    // bit-identity across the shared entry: both sessions' responses
    // match their own direct-backend references
    for (key, spec) in [(&k1, "float:m7e6"), (&k2, "plan:c1=float:m7e6,fc=fixed:l8r8")] {
        let spec = PrecisionSpec::parse(spec).unwrap();
        let want = NativeBackend::with_store(net.clone(), Arc::new(WeightStore::with_budget(0)))
            .run_spec(&net.eval_x.slice_rows(0, 1), &spec)
            .unwrap();
        let got = gw.infer(key, pixels(0)).unwrap();
        assert_bits_eq(&got, want.data(), &key.to_string());
    }

    // the serving telemetry surfaces the shared counters: every native
    // session reports the same store, and the table renders it
    let stats = gw.stats();
    let shared = stats.store().expect("native sessions expose the store");
    assert_eq!(shared.entries, 3);
    for (key, s) in &stats.sessions {
        assert_eq!(s.store.expect("per-session snapshot").entries, 3, "{key}");
    }
    let table = stats.render();
    assert!(table.contains("store h/m"), "{table}");
    assert!(table.contains("weight store:"), "{table}");
    gw.shutdown();
}

/// The split-precision store contract (ISSUE 9): the store keys on the
/// WEIGHT half of each layer's pair, so two sessions whose specs differ
/// only in their activation formats share every entry — the second
/// session's traffic adds ZERO entries and ZERO misses, and the hit
/// counters see the sharing.
#[test]
fn sessions_differing_only_in_activation_format_share_every_entry() {
    let net = tiny_conv_network(6);
    let store = Arc::new(WeightStore::unbounded());
    let gw = Gateway::empty();
    let open = |spec: &str| {
        let n = net.clone();
        let s = store.clone();
        Session::with_factory(
            net.clone(),
            PrecisionSpec::parse(spec).unwrap(),
            4,
            Duration::from_millis(3),
            Box::new(move || Ok(Box::new(NativeBackend::with_store(n, s)) as Box<dyn Backend>)),
        )
    };
    // identical weight halves (c1@l8r8, fc@m7e6); only the activation
    // halves differ — session 1 runs the uniform sugar, session 2 splits
    // both layers onto different activation grids
    let uniform = "plan:c1=fixed:l8r8,*=float:m7e6";
    let split = "plan:c1=w:fixed:l8r8+a:float:m4e5,fc=w:float:m7e6+a:fixed:l4r8";
    let k1 = gw.adopt(open(uniform));
    let k2 = gw.adopt(open(split));

    let px: usize = net.input.iter().product();
    let pixels = |i: usize| net.eval_x.data()[i * px..(i + 1) * px].to_vec();

    for i in 0..3 {
        gw.infer(&k1, pixels(i)).unwrap();
    }
    let s1 = store.stats();
    assert_eq!((s1.misses, s1.entries), (2, 2), "c1@l8r8 + fc@m7e6 staged once");

    // session 2's first forward re-uses BOTH weight-half entries: no new
    // entries, no new misses, only hits
    gw.infer(&k2, pixels(0)).unwrap();
    let s2 = store.stats();
    assert_eq!(s2.entries, 2, "activation-only difference adds no store entries");
    assert_eq!(s2.misses, 2, "no layer re-staged for the split session");
    assert!(s2.hits > s1.hits, "the sharing shows up on the hit counters");

    // the shared entries feed DIFFERENT activation chains: both sessions
    // stay bit-identical to their own uncached references, and the split
    // session's logits diverge from the uniform session's (the
    // activation half is live, not ignored)
    let mut refs = Vec::new();
    for (key, spec) in [(&k1, uniform), (&k2, split)] {
        let spec = PrecisionSpec::parse(spec).unwrap();
        let want = NativeBackend::with_store(net.clone(), Arc::new(WeightStore::with_budget(0)))
            .run_spec(&net.eval_x.slice_rows(0, 1), &spec)
            .unwrap();
        let got = gw.infer(key, pixels(0)).unwrap();
        assert_bits_eq(&got, want.data(), &key.to_string());
        refs.push(want.data().to_vec());
    }
    assert_ne!(refs[0], refs[1], "split activation half must change the math");
    gw.shutdown();
}

/// A budget that fits only ONE of the two layers forces an eviction on
/// every staging step; the forward stays bit-identical throughout and
/// the store never exceeds its budget.
#[test]
fn tight_budget_evicts_lru_and_stays_bit_identical() {
    let net = tiny_conv_network(8);
    let x = net.eval_x.slice_rows(0, 8);
    let spec = PrecisionSpec::parse("plan:c1=fixed:l8r8,*=float:m7e6").unwrap();
    let c1 = StoreEntry::bytes_for(net.weight("c1.w").data().len(), &Format::fixed(8, 8));
    let fc = StoreEntry::bytes_for(net.weight("fc.w").data().len(), &Format::float(7, 6));
    let budget = c1.max(fc);
    assert!(budget < c1 + fc, "budget must not fit both entries");

    let store = Arc::new(WeightStore::with_budget(budget));
    let mut cached = NativeBackend::with_store(net.clone(), store.clone());
    let mut restaged =
        NativeBackend::with_store(net.clone(), Arc::new(WeightStore::with_budget(0)));
    let want = restaged.run_spec(&x, &spec).unwrap();

    for round in 0..4 {
        let got = cached.run_spec(&x, &spec).unwrap();
        assert_bits_eq(got.data(), want.data(), &format!("round {round}"));
        let s = store.stats();
        assert!(s.bytes <= budget, "round {round}: {s:?}");
        assert_eq!(s.entries, 1, "round {round}: only one layer fits");
    }
    let s = store.stats();
    // forward = stage c1 (evicting fc), then fc (evicting c1): every
    // staging after the very first insert evicts its predecessor
    assert_eq!(s.misses, 8, "{s:?}");
    assert_eq!(s.evictions, 7, "{s:?}");
    assert_eq!(s.hits, 0, "{s:?}");
}

/// ISSUE 6: packed-domain execution obeys the same store contract as
/// the staged tier — a warm packed forward is bit-identical to the
/// pre-store reference and performs zero weight-quantization work, and
/// a thrashing one-entry budget degrades to correct per-layer fallback
/// (scratch re-staging), never to divergence or an error.
#[test]
fn packed_exec_forward_obeys_the_store_contract() {
    let net = tiny_conv_network(8);
    let x = net.eval_x.slice_rows(0, 8);
    for spec in [
        "fixed:l3r3",  // integer lane (i16)
        "fixed:l4r4",  // integer lane (i32)
        "fixed:l8r8",  // LUT lane (t > 12)
        "float:m7e6",  // LUT lane (float)
        "plan:c1=fixed:l2r2,fc=fixed:l3r3", // int16 + off-grid LUT
    ] {
        let spec = PrecisionSpec::parse(spec).unwrap();
        let want = NativeBackend::with_store(net.clone(), Arc::new(WeightStore::with_budget(0)))
            .run_spec(&x, &spec)
            .unwrap();

        // warm packed store: bit-identity + zero requantization
        let store = Arc::new(WeightStore::unbounded());
        let mut packed =
            NativeBackend::with_store(net.clone(), store.clone()).with_packed_exec(true);
        let first = packed.run_spec(&x, &spec).unwrap();
        let warm = store.stats();
        let second = packed.run_spec(&x, &spec).unwrap();
        let hot = store.stats();
        assert_bits_eq(first.data(), want.data(), &format!("{} packed cold", spec.id()));
        assert_bits_eq(second.data(), want.data(), &format!("{} packed warm", spec.id()));
        assert_eq!(hot.misses, warm.misses, "{}: warm packed quantizes NO weights", spec.id());
        assert!(hot.hits > warm.hits, "{}: warm packed forward reads the store", spec.id());

        // LRU thrash: a budget that fits only one of the two layers
        // evicts on every staging step; the packed lanes keep running
        // from each freshly staged entry and stay bit-identical
        let costs: Vec<usize> = spec
            .resolve(&net)
            .unwrap()
            .assignments
            .iter()
            .map(|(n, p)| StoreEntry::bytes_for(net.weight(&format!("{n}.w")).data().len(), &p.w))
            .collect();
        let budget = costs.iter().copied().max().unwrap();
        assert!(budget < costs.iter().sum(), "budget must not fit both entries");
        let store = Arc::new(WeightStore::with_budget(budget));
        let mut thrash =
            NativeBackend::with_store(net.clone(), store.clone()).with_packed_exec(true);
        for round in 0..3 {
            let got = thrash.run_spec(&x, &spec).unwrap();
            assert_bits_eq(got.data(), want.data(), &format!("{} thrash {round}", spec.id()));
            assert!(store.stats().bytes <= budget, "{}: over budget", spec.id());
        }
        let s = store.stats();
        assert!(s.evictions > 0, "{}: the thrash regime must evict ({s:?})", spec.id());
        assert_eq!(s.hits, 0, "{}: one-entry budget never hits ({s:?})", spec.id());
    }
}

/// The serving surface of packed execution: per-session opt-in shows
/// up in [`precis::serving::SessionStats`] and the gateway's `exec`
/// column, while responses stay bit-identical to the staged reference.
#[test]
fn gateway_surfaces_the_packed_exec_lane() {
    let net = tiny_conv_network(4);
    let store = Arc::new(WeightStore::unbounded());
    let gw = Gateway::empty();
    let open = |spec: &str, packed: bool| {
        let n = net.clone();
        let s = store.clone();
        Session::with_factory(
            net.clone(),
            PrecisionSpec::parse(spec).unwrap(),
            4,
            Duration::from_millis(3),
            Box::new(move || {
                let b = NativeBackend::with_store(n, s).with_packed_exec(packed);
                Ok(Box::new(b) as Box<dyn Backend>)
            }),
        )
        .with_packed_exec(packed)
    };
    let kp = gw.adopt(open("fixed:l3r3", true));
    let ks = gw.adopt(open("float:m7e6", false));

    let px: usize = net.input.iter().product();
    let pixels = net.eval_x.data()[..px].to_vec();
    for (key, spec) in [(&kp, "fixed:l3r3"), (&ks, "float:m7e6")] {
        let spec = PrecisionSpec::parse(spec).unwrap();
        let want = NativeBackend::with_store(net.clone(), Arc::new(WeightStore::with_budget(0)))
            .run_spec(&net.eval_x.slice_rows(0, 1), &spec)
            .unwrap();
        let got = gw.infer(key, pixels.clone()).unwrap();
        assert_bits_eq(&got, want.data(), &key.to_string());
    }

    let stats = gw.stats();
    let flag = |key: &str| {
        stats
            .sessions
            .iter()
            .find(|(k, _)| k.to_string() == key)
            .expect("session listed")
            .1
            .packed_exec
    };
    assert!(flag(&kp.to_string()), "packed session reports packed_exec");
    assert!(!flag(&ks.to_string()), "staged session reports staged");
    let table = stats.render();
    assert!(table.contains("exec"), "{table}");
    assert!(table.contains("packed"), "{table}");
    assert!(table.contains("staged"), "{table}");
    gw.shutdown();
}

/// ISSUE 8 acceptance: once every session is warm, concurrent forwards
/// acquire the store mutex ZERO times — the epoch-validated lease path
/// serves every staged layer with one atomic load per layer.  Proved by
/// the data-path lock-acquisition counter staying flat across a
/// multi-session warm phase, with every logit bit-identical to the
/// uncached reference.  `clear()` then invalidates the outstanding
/// leases and the next forward degrades to the locked re-staging path,
/// still bit-identically.
///
/// ISSUE 10 extension: the whole scenario runs with a live
/// [`Registry`] adopted over the store's counters BEFORE the warm
/// phase — metrics instrumentation must not re-introduce a lock on the
/// warm path, and the registry's view must agree with `stats()`.
#[test]
fn warm_forwards_are_lockfree_across_concurrent_sessions() {
    let net = tiny_conv_network(4);
    let x = net.eval_x.slice_rows(0, 4);
    let spec = PrecisionSpec::parse("plan:c1=fixed:l8r8,fc=float:m7e6").unwrap();
    let want = NativeBackend::with_store(net.clone(), Arc::new(WeightStore::with_budget(0)))
        .run_spec(&x, &spec)
        .unwrap();

    const SESSIONS: usize = 4;
    const WARM_FORWARDS: usize = 8;
    let store = Arc::new(WeightStore::unbounded());
    let registry = Registry::new();
    store.register_into(&registry);
    // two rendezvous points bracket the snapshot: every session is warm
    // (lease cached per layer) BEFORE the counter is read, and no warm
    // forward starts until AFTER it is read
    let warmed = Barrier::new(SESSIONS + 1);
    let measured = Barrier::new(SESSIONS + 1);
    let locks_when_warm = std::thread::scope(|s| {
        for t in 0..SESSIONS {
            let (net, store) = (net.clone(), store.clone());
            let (x, want, spec) = (&x, &want, &spec);
            let (warmed, measured) = (&warmed, &measured);
            s.spawn(move || {
                let mut backend = NativeBackend::with_store(net, store);
                let cold = backend.run_spec(x, spec).unwrap();
                assert_bits_eq(cold.data(), want.data(), &format!("session {t} cold"));
                warmed.wait();
                measured.wait();
                for round in 0..WARM_FORWARDS {
                    let got = backend.run_spec(x, spec).unwrap();
                    assert_bits_eq(got.data(), want.data(), &format!("session {t} warm {round}"));
                }
            });
        }
        warmed.wait();
        let snapshot = store.lock_acquisitions();
        measured.wait();
        snapshot
    });
    assert_eq!(
        store.lock_acquisitions(),
        locks_when_warm,
        "warm forwards must acquire the store mutex zero times"
    );
    let s = store.stats();
    assert_eq!(s.misses, 2, "each layer staged exactly once across all sessions: {s:?}");
    assert_eq!(s.entries, 2, "{s:?}");
    // the warm phase alone contributes sessions * forwards * layers
    // lock-free hits on top of whatever the cold phase counted
    assert!(
        s.hits >= (SESSIONS * WARM_FORWARDS * 2) as u64,
        "warm traffic is served as hits: {s:?}"
    );

    // invalidation: clear() bumps every slot epoch, so a session's
    // cached leases go stale and its next forward re-stages through the
    // locked path — bit-identical, and the counters show the rebuild
    let mut survivor = NativeBackend::with_store(net.clone(), store.clone());
    let warm = survivor.run_spec(&x, &spec).unwrap();
    assert_bits_eq(warm.data(), want.data(), "survivor warm");
    let before = store.stats();
    store.clear();
    let rebuilt = survivor.run_spec(&x, &spec).unwrap();
    assert_bits_eq(rebuilt.data(), want.data(), "rebuilt after clear");
    let after = store.stats();
    assert_eq!(
        after.misses,
        before.misses + 2,
        "stale leases fall back to the locked prepare, which re-stages"
    );

    // the registry adopted the store's own atomics at the top: after
    // all the traffic above, its view and stats() are the same books
    for (name, value) in [
        ("store/hits", after.hits),
        ("store/misses", after.misses),
        ("store/evictions", after.evictions),
        ("store/lock_acquisitions", store.lock_acquisitions()),
    ] {
        assert_eq!(registry.counter_value(name), Some(value), "{name}");
    }
}

/// ISSUE 8 satellite: many threads calling `prepare` on the SAME key
/// concurrently keep the counters balanced — exactly one insert counts
/// as the miss, every other prepare is a hit (including the lost-race
/// adopt, which additionally ticks `races` instead of double-counting a
/// miss), and every issued lease validates lock-free against the one
/// shared entry.
#[test]
fn concurrent_same_key_prepare_balances_counters_and_leases_stay_lockfree() {
    let store = Arc::new(WeightStore::unbounded());
    let key = StoreKey::new("contract", "fc", Format::fixed(6, 6));
    let weights: Vec<f32> = (0..96).map(|i| (i as f32 - 48.0) / 16.0).collect();

    const THREADS: usize = 8;
    const PREPARES: usize = 16;
    let start = Barrier::new(THREADS);
    let leases: Vec<_> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..THREADS)
            .map(|_| {
                let store = store.clone();
                let (key, weights, start) = (&key, &weights, &start);
                s.spawn(move || {
                    start.wait();
                    let mut last = None;
                    for _ in 0..PREPARES {
                        last = store.prepare_lease(key, weights);
                    }
                    last.expect("unbounded store admits the entry")
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });

    let s = store.stats();
    assert_eq!(s.entries, 1, "{s:?}");
    assert_eq!(s.misses, 1, "one insert wins; duplicates adopt, they do not re-miss: {s:?}");
    assert_eq!(s.rejected, 0, "{s:?}");
    assert_eq!(
        s.hits + s.misses,
        (THREADS * PREPARES) as u64,
        "every prepare is exactly one hit or the single miss: {s:?}"
    );
    assert!(
        s.races <= (THREADS - 1) as u64,
        "only builds started before the winning insert can race: {s:?}"
    );

    // every surviving lease points at the one shared entry and
    // validates without touching the mutex
    let locks = store.lock_acquisitions();
    let canonical = store.hit_if_current(&leases[0]).expect("entry is resident");
    for (t, lease) in leases.iter().enumerate() {
        let entry = store.hit_if_current(lease).expect("entry is resident");
        assert!(Arc::ptr_eq(&entry, &canonical), "thread {t} adopted a different entry");
    }
    assert_eq!(store.lock_acquisitions(), locks, "lease validation is lock-free");
}

/// Property (ISSUE 5 satellite): a forward through a budget-constrained
/// store — across random per-layer formats and budgets spanning the
/// reject / thrash / fit regimes — is bit-identical to the uncached
/// forward on `tiny_conv_network`, and never an error.
#[test]
fn prop_budget_constrained_forward_bit_identical_to_uncached() {
    let net = tiny_conv_network(5);
    let x = net.eval_x.slice_rows(0, 5);
    let total_evictions = Cell::new(0u64);
    let total_rejections = Cell::new(0u64);
    run_prop("store_budget_forward_bitexact", 40, |g| {
        // four budget regimes: reject-everything, thrash (exactly one
        // entry fits → guaranteed evictions), fit-everything, random.
        // The thrash/reject regimes force non-identity formats so the
        // store actually sees traffic (SINGLE bypasses it).
        let regime = g.usize_in(0, 3);
        let fmt = |g: &mut precis::testing::prop::Gen| {
            let f = arb_format(g);
            if regime < 2 && f == Format::SINGLE {
                Format::float(7, 6)
            } else {
                f
            }
        };
        let plan = Plan::explicit(vec![
            ("c1".to_string(), fmt(g)),
            ("fc".to_string(), fmt(g)),
        ])
        .unwrap();
        let spec = PrecisionSpec::from(plan);
        let costs: Vec<usize> = spec
            .resolve(&net)
            .unwrap()
            .assignments
            .iter()
            .map(|(n, p)| {
                StoreEntry::bytes_for(net.weight(&format!("{n}.w")).data().len(), &p.w)
            })
            .collect();
        let budget = match regime {
            0 => 0,                                         // reject everything
            1 => costs.iter().copied().max().unwrap(),      // thrash: one fits
            2 => 1 << 20,                                   // everything fits
            _ => g.usize_in(0, 400),                        // anywhere in between
        };
        let store = Arc::new(WeightStore::with_budget(budget));
        let mut cached = NativeBackend::with_store(net.clone(), store.clone());
        let mut uncached =
            NativeBackend::with_store(net.clone(), Arc::new(WeightStore::with_budget(0)));
        let want = uncached.run_spec(&x, &spec).unwrap();
        for round in 0..3 {
            let got = cached.run_spec(&x, &spec).unwrap();
            assert_bits_eq(
                got.data(),
                want.data(),
                &format!("{} budget={budget} round={round}", spec.id()),
            );
        }
        let s = store.stats();
        assert!(s.budget.is_some_and(|b| s.bytes <= b), "{s:?}");
        total_evictions.set(total_evictions.get() + s.evictions);
        total_rejections.set(total_rejections.get() + s.rejected);
    });
    // the budget range must actually have exercised both degradation
    // modes somewhere in the run, or the property is vacuous
    assert!(total_evictions.get() > 0, "no case forced an eviction");
    assert!(total_rejections.get() > 0, "no case forced a rejection");
}
