//! THE three-layer equivalence proof: the AOT artifacts (JAX model +
//! Pallas per-op-rounded kernels, lowered to HLO and executed through
//! PJRT) must agree with the pure-Rust native engine **bit-exactly**,
//! for every representation kind, across real networks.
//!
//! This is what licenses using the native engine for the big sweeps
//! while the PJRT path serves requests: they are the same function.
//!
//! Compiled only with the `pjrt` feature (DESIGN.md §5).  Each test
//! additionally skips itself when the artifacts are absent or when the
//! build links the offline `xla` stub (whose client constructor fails
//! fast) — running the proof needs both `make artifacts` and a real
//! PJRT binding crate.  A CI lane that has both can set
//! `PRECIS_REQUIRE_ARTIFACTS=1` / `PRECIS_REQUIRE_PJRT=1` to promote
//! the corresponding skip to a hard failure, so it can never go green
//! vacuously.

#![cfg(feature = "pjrt")]

use precis::eval::topk_accuracy;
use precis::formats::Format;
use precis::nn::Zoo;
use precis::runtime::Runtime;
use precis::serving::{Backend, NativeBackend};
use precis::tensor::Tensor;

const ARTIFACTS: &str = concat!(env!("CARGO_MANIFEST_DIR"), "/../artifacts");

use precis::testing::strict_env as strict;

/// Zoo + PJRT client, or a skip note when either is unavailable.
fn setup() -> Option<(Zoo, Runtime)> {
    let zoo = match Zoo::load(ARTIFACTS) {
        Ok(z) => z,
        Err(e) => {
            if strict("PRECIS_REQUIRE_ARTIFACTS") {
                panic!("PRECIS_REQUIRE_ARTIFACTS is set but artifacts are unusable: {e:#}");
            }
            eprintln!("skipping: artifacts unusable at {ARTIFACTS}: {e:#} (run `make artifacts`)");
            return None;
        }
    };
    match Runtime::cpu() {
        Ok(rt) => Some((zoo, rt)),
        Err(e) => {
            if strict("PRECIS_REQUIRE_PJRT") {
                panic!("PRECIS_REQUIRE_PJRT is set but the PJRT client failed: {e:#}");
            }
            eprintln!("skipping: PJRT unavailable ({e:#})");
            None
        }
    }
}

fn max_ulp_diff(a: &[f32], b: &[f32]) -> u32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            let (bx, by) = ((x + 0.0).to_bits() as i64, (y + 0.0).to_bits() as i64);
            (bx - by).unsigned_abs() as u32
        })
        .max()
        .unwrap_or(0)
}

fn cross_check(net_name: &str, fmts: &[Format]) {
    let Some((zoo, rt)) = setup() else { return };
    let dir = std::path::PathBuf::from(ARTIFACTS);
    let net = zoo.network(net_name).unwrap();
    let mut native = NativeBackend::new(net.clone());

    let x = net.eval_x.slice_rows(0, zoo.batch);
    let mut models = std::collections::BTreeMap::new();
    for fmt in fmts {
        let kind = if fmt.is_float() { "float" } else { "fixed" };
        let model = models.entry(kind).or_insert_with(|| {
            rt.load_network(&net, &dir, kind, zoo.batch)
                .unwrap_or_else(|e| panic!("load {net_name} {kind}: {e:#}"))
        });

        let pjrt_logits = model.run_batch(&x, fmt).unwrap();
        let native_logits = native.run_batch(&x, fmt).unwrap();
        assert_eq!(pjrt_logits.shape(), native_logits.shape());
        let ulp = max_ulp_diff(pjrt_logits.data(), native_logits.data());
        assert_eq!(
            ulp, 0,
            "{net_name} @ {fmt}: PJRT and native logits differ (max {ulp} ulp)"
        );
    }
}

#[test]
fn lenet5_bitexact_across_formats() {
    cross_check(
        "lenet5",
        &[
            Format::SINGLE,
            Format::float(7, 6),
            Format::float(2, 4),
            Format::float(12, 8),
            Format::fixed(8, 8),
            Format::fixed(2, 6),
            Format::fixed(0, 4),
        ],
    );
}

#[test]
fn cifarnet_bitexact() {
    cross_check("cifarnet", &[Format::float(8, 5), Format::fixed(6, 10)]);
}

#[test]
fn googlenet_mini_bitexact_exercises_inception_and_gavgpool() {
    cross_check(
        "googlenet-mini",
        &[Format::SINGLE, Format::float(9, 6), Format::fixed(10, 8)],
    );
}

#[test]
fn vgg_and_alexnet_bitexact() {
    cross_check("vgg-mini", &[Format::float(6, 6)]);
    cross_check("alexnet-mini", &[Format::fixed(8, 12)]);
}

#[test]
fn pjrt_eval_accuracy_matches_native() {
    let Some((zoo, rt)) = setup() else { return };
    let net = zoo.network("lenet5").unwrap();
    let fmt = Format::float(10, 6);
    let model = rt
        .load_network(&net, std::path::Path::new(ARTIFACTS), "float", zoo.batch)
        .unwrap();
    let n = 96;
    let (logits, labels) = model.run_eval(n, &fmt).unwrap();
    let pjrt_acc = topk_accuracy(&logits, &labels, net.classes, net.topk);
    let native_acc = precis::eval::accuracy(&net, &fmt, n).unwrap();
    assert!(
        (pjrt_acc - native_acc).abs() < 1e-12,
        "pjrt {pjrt_acc} vs native {native_acc}"
    );
}

#[test]
fn run_batch_rejects_wrong_kind_and_shape() {
    let Some((zoo, rt)) = setup() else { return };
    let net = zoo.network("lenet5").unwrap();
    let model = rt
        .load_network(&net, std::path::Path::new(ARTIFACTS), "float", zoo.batch)
        .unwrap();
    let x = net.eval_x.slice_rows(0, zoo.batch);
    // fixed format into a float executable
    assert!(model.run_batch(&x, &Format::fixed(8, 8)).is_err());
    // wrong batch size
    let bad = net.eval_x.slice_rows(0, 3);
    assert!(model.run_batch(&bad, &Format::float(7, 6)).is_err());
    // tensor of the wrong rank entirely
    let junk = Tensor::zeros(vec![zoo.batch, 2, 2, 1]);
    assert!(model.run_batch(&junk, &Format::float(7, 6)).is_err());
}
